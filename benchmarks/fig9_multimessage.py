"""Fig. 9 (paper Sec. V-C): intra-round message budget sweep.

The paper's uncoded schemes send every result the moment it is computed
(eq. 1) — the full multi-message regime — while the coded PC baseline sends
one message per round.  This benchmark sweeps the per-round message budget
m in {1, 2, r} for CS / SS (uncoded) and PCMM (coded) at equal computation
load on the EC2-calibrated delay model, all from ONE fused sweep call: every
(scheme, m) cell scores the same delay draws (the per-message communication
delay is the draw at the message's closing slot), so per-budget gaps are
paired common-random-number estimates.

Rows:  fig9/<scheme>  with per-m completion times and the multi-message
reduction vs one-shot.  The guard row exits non-zero if full multi-message
(m = r) fails to beat single-message (m = 1) for any scheme — the paper's
Sec. V-C ordering, and the reason eq. (1) models per-slot sends at all.

Optimal message budget under per-message overhead
-------------------------------------------------
With latency alone, m = r always wins, so "how often should a worker talk
to the master" has a trivial answer.  The second panel adds the
Ozfatura et al. (arXiv:2004.04948) communication/computation trade-off: a
serialized per-message protocol overhead ``comm_eps`` (a worker's l-th
message lands (l+1)*eps late) on a straggling cluster at a high target
k = n-1, and reports the OPTIMAL budget m*(eps) per overhead level — the
first non-trivial operating point: m* walks from r down to 1 as eps grows.
The ``fig9/opt_m`` guard exits non-zero unless m* is r at eps=0 and drops
below r at some tested eps.
"""
from __future__ import annotations

from repro.core import (BimodalStragglerDelays, cyclic_to_matrix, ec2_like,
                        pcmm_spec, staircase_to_matrix, sweep, to_spec)
from .common import emit

N, R, K = 12, 4, 10
BUDGETS = (1, 2, R)
# overhead panel: straggling makes late-slot copies matter, so the
# per-message overhead actually binds (k close to n)
K_EPS = N - 1
EPS_GRID = (0.0, 1e-4, 3e-4, 1e-3)


def run(trials: int = 20000):
    model = ec2_like(N, seed=0)
    cs, ss = cyclic_to_matrix(N, R), staircase_to_matrix(N, R)
    specs = []
    for m in BUDGETS:
        specs += [to_spec(f"cs_m{m}", cs, messages=m),
                  to_spec(f"ss_m{m}", ss, messages=m),
                  pcmm_spec(R, name=f"pcmm_m{m}", messages=m)]
    res = sweep(specs, model, N, trials=trials, seed=0, ks=K)

    out, ok = {}, True
    for scheme in ("cs", "ss", "pcmm"):
        t = {m: res.at_k(f"{scheme}_m{m}", K) for m in BUDGETS}
        reduction = 100.0 * (t[1] - t[R]) / t[1]
        ok &= t[R] <= t[1]
        emit(f"fig9/{scheme}", t[R] * 1e6,
             ";".join([f"trials={trials}", f"n={N}", f"r={R}", f"k={K}"]
                      + [f"m{m}={t[m] * 1e3:.4f}ms" for m in BUDGETS]
                      + [f"mm_vs_single={reduction:+.1f}%"]))
        out[scheme] = t
    emit("fig9/mm_beats_single", 0.0,
         f"all_schemes={'PASS' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit("fig9: multi-message completion time exceeded "
                         "single-message at equal load (Sec. V-C ordering)")

    # ---- optimal m under per-message overhead (one fused sweep: every
    # (eps, m) cell scores the same straggling draws) -----------------------
    smodel = BimodalStragglerDelays(p_straggle=0.25, slow=8.0)
    especs = [to_spec(f"cs_e{ei}_m{m}", cs, messages=m, comm_eps=eps)
              for ei, eps in enumerate(EPS_GRID)
              for m in range(1, R + 1)]
    eres = sweep(especs, smodel, N, trials=trials, seed=0, ks=K_EPS)
    opt = {}
    for ei, eps in enumerate(EPS_GRID):
        t = [eres.at_k(f"cs_e{ei}_m{m}", K_EPS) for m in range(1, R + 1)]
        opt[eps] = 1 + min(range(R), key=t.__getitem__)
    nontrivial = (opt[0.0] == R
                  and any(opt[e] < R for e in EPS_GRID if e > 0))
    emit("fig9/opt_m", 0.0,
         ";".join([f"trials={trials}", f"n={N}", f"r={R}", f"k={K_EPS}"]
                  + [f"eps{eps:g}_opt_m={opt[eps]}" for eps in EPS_GRID]
                  + [f"nontrivial={'PASS' if nontrivial else 'FAIL'}"]))
    if not nontrivial:
        raise SystemExit("fig9: per-message overhead failed to produce a "
                         "non-trivial optimal message budget (expected "
                         "m*=r at eps=0 and m*<r at some eps>0)")
    out["opt_m"] = opt
    return out


if __name__ == "__main__":
    run()

"""Fig. 9 (paper Sec. V-C): intra-round message budget sweep.

The paper's uncoded schemes send every result the moment it is computed
(eq. 1) — the full multi-message regime — while the coded PC baseline sends
one message per round.  This benchmark sweeps the per-round message budget
m in {1, 2, r} for CS / SS (uncoded) and PCMM (coded) at equal computation
load on the EC2-calibrated delay model, all from ONE fused sweep call: every
(scheme, m) cell scores the same delay draws (the per-message communication
delay is the draw at the message's closing slot), so per-budget gaps are
paired common-random-number estimates.

Rows:  fig9/<scheme>  with per-m completion times and the multi-message
reduction vs one-shot.  The guard row exits non-zero if full multi-message
(m = r) fails to beat single-message (m = 1) for any scheme — the paper's
Sec. V-C ordering, and the reason eq. (1) models per-slot sends at all.
"""
from __future__ import annotations

from repro.core import (cyclic_to_matrix, ec2_like, pcmm_spec,
                        staircase_to_matrix, sweep, to_spec)
from .common import emit

N, R, K = 12, 4, 10
BUDGETS = (1, 2, R)


def run(trials: int = 20000):
    model = ec2_like(N, seed=0)
    cs, ss = cyclic_to_matrix(N, R), staircase_to_matrix(N, R)
    specs = []
    for m in BUDGETS:
        specs += [to_spec(f"cs_m{m}", cs, messages=m),
                  to_spec(f"ss_m{m}", ss, messages=m),
                  pcmm_spec(R, name=f"pcmm_m{m}", messages=m)]
    res = sweep(specs, model, N, trials=trials, seed=0, ks=K)

    out, ok = {}, True
    for scheme in ("cs", "ss", "pcmm"):
        t = {m: res.at_k(f"{scheme}_m{m}", K) for m in BUDGETS}
        reduction = 100.0 * (t[1] - t[R]) / t[1]
        ok &= t[R] <= t[1]
        emit(f"fig9/{scheme}", t[R] * 1e6,
             ";".join([f"trials={trials}", f"n={N}", f"r={R}", f"k={K}"]
                      + [f"m{m}={t[m] * 1e3:.4f}ms" for m in BUDGETS]
                      + [f"mm_vs_single={reduction:+.1f}%"]))
        out[scheme] = t
    emit("fig9/mm_beats_single", 0.0,
         f"all_schemes={'PASS' if ok else 'FAIL'}")
    if not ok:
        raise SystemExit("fig9: multi-message completion time exceeded "
                         "single-message at equal load (Sec. V-C ordering)")
    return out


if __name__ == "__main__":
    run()

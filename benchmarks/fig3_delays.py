"""Fig. 3: delay-model calibration — the truncated-Gaussian model's
histograms (computation + communication per worker). Reports moments and
the comm/comp ratio the paper observes (communication dominates)."""
import jax
import numpy as np

from repro.core import ec2_like
from .common import Timer, emit


def run(trials: int = 20000):
    n = 3
    model = ec2_like(n, seed=0, comm_over_comp=5.0)
    with Timer() as t:
        T1, T2 = model.sample(jax.random.PRNGKey(0), trials, n, 1)
        T1, T2 = np.asarray(T1), np.asarray(T2)
    for i in range(n):
        emit(f"fig3/worker{i+1}", t.us / n,
             f"comp_mean={T1[:, i].mean():.2e};comm_mean={T2[:, i].mean():.2e};"
             f"comm_over_comp={T2[:, i].mean() / T1[:, i].mean():.2f}")
    ratio = T2.mean() / T1.mean()
    emit("fig3/summary", t.us, f"comm_dominates={ratio > 2.0};ratio={ratio:.2f}")
    return ratio

"""Fig. 12 (beyond the paper): fault injection and graceful degradation.

The paper assumes every worker eventually answers; real clusters lose
workers (spot preemption, partitions, rack failures) and rounds must
close anyway.  This benchmark exercises the whole fault-tolerance layer
(``FaultProcess`` scenario zoo + round deadlines + fallback policies +
crash-aware adaptive scheduling) on the fig8/fig11 heterogeneous
persistent-straggler cell:

  1. **degrade** — under spot preemption with a round deadline and the
     ``close_partial`` policy, the censored-feedback adaptive scheme must
     beat the better static schedule on *time per realized result*
     (raw round time is not comparable when schemes miss different
     numbers of rounds; cost-per-aggregated-gradient is);
  2. **survive** — every scenario in the zoo (preemption / partition /
     rack / msgloss / diurnal) must close all rounds with finite
     completion times and sane degradation metrics: no deadlock, no NaN,
     per-round realized-k histograms that sum to one;
  3. **replay** — a recorded *fault-bearing* trace (version-2 format,
     ``+inf`` = never arrived) written to disk, read back, and replayed
     through ``TraceProcess`` must reproduce the recording run's
     per-round times AND degradation streams bit-exactly.

Rows: ``fig12/clean`` carries the fault-free baseline and the derived
deadline; ``fig12/preemption`` carries per-scheme time-per-realized-task
and the ``adapt_vs_static`` margin consumed by the CI regression gate;
``fig12/zoo_<scenario>`` one row per scenario (realized k, missed
fraction, staleness); ``fig12/replay`` the max replay deviation (must be
0).  The run exits non-zero if the adaptive margin goes negative, any
scenario deadlocks or yields non-finite metrics, or replay diverges.
"""
from __future__ import annotations

import math
import os

import numpy as np

from repro.core import (FAULT_SCENARIOS, TraceProcess, adaptive_spec,
                        cyclic_to_matrix, ec2_cluster, lb_spec, load_trace,
                        make_scenario, save_trace, scenario1,
                        staircase_to_matrix, sweep_rounds, to_spec)
from .common import emit

N, R, K = 12, 3, 9
ROUNDS = 20
PERSISTENCE, SPREAD = 0.98, 3.0
CHUNK = 1000
SCHEMES = ("cs", "ss", "adapt", "lb")
DEADLINE_SLACK = 1.5            # deadline = slack x clean static mean round


def _base():
    return ec2_cluster(N, spread=SPREAD, p_slow=0.25,
                       persistence=PERSISTENCE, slow=8.0, base=scenario1(),
                       seed=1)


def _specs():
    return [to_spec("cs", cyclic_to_matrix(N, R)),
            to_spec("ss", staircase_to_matrix(N, R)),
            adaptive_spec("adapt", cyclic_to_matrix(N, R)),
            lb_spec(R)]


def _sweep(process, trials, seed, *, deadline=None, policy="wait",
           record=False):
    return sweep_rounds(_specs(), process, N, rounds=ROUNDS, k=K,
                        trials=trials, seed=seed, chunk=CHUNK,
                        censored_feedback=True, record_trace=record,
                        deadline=deadline, deadline_policy=policy)


def _cost_per_task(res, nm: str) -> float:
    """Mean wall-clock per realized result (ms/task): mean effective round
    length over mean realized k.  The fault-aware figure of merit — a
    scheme that closes rounds fast but empty scores badly."""
    realized = float(np.mean(res.realized_k(nm)))
    return res.mean_round(nm) * 1e3 / max(realized, 1e-9)


def _finite_ok(res) -> bool:
    for nm in SCHEMES:
        if not np.isfinite(np.asarray(res.per_round[nm])).all():
            return False
        for key in ("realized_k", "missed", "stale"):
            if not np.isfinite(res.degradation[nm][key]).all():
                return False
        hist = res.khist(nm)
        if not np.allclose(hist.sum(axis=1), 1.0, atol=1e-5):
            return False
    return True


def run(trials: int = 20000, out: str = "bench_out"):
    trials = min(trials, 2000)      # 8 ROUNDS-length sweeps + recording
    common = (f"trials={trials};rounds={ROUNDS};n={N};r={R};k={K};"
              f"persistence={PERSISTENCE};spread={SPREAD:g}")

    # fault-free baseline fixes the round deadline for every scenario
    clean = _sweep(_base(), trials, seed=0)
    static_clean = min(clean.mean_round("cs"), clean.mean_round("ss"))
    deadline = DEADLINE_SLACK * static_clean
    emit("fig12/clean", clean.mean_round("adapt") * 1e3,
         f"{common};cs={clean.mean_round('cs') * 1e3:.4f}ms;"
         f"ss={clean.mean_round('ss') * 1e3:.4f}ms;"
         f"adapt={clean.mean_round('adapt') * 1e3:.4f}ms;"
         f"deadline={deadline * 1e3:.4f}ms")

    # 1. graceful degradation under spot preemption: adaptive + deadline
    #    vs the static schedules, scored as time per realized result
    pre = _sweep(make_scenario("preemption", _base(), N), trials, seed=0,
                 deadline=deadline, policy="close_partial")
    cost = {nm: _cost_per_task(pre, nm) for nm in ("cs", "ss", "adapt")}
    static = min(cost["cs"], cost["ss"])
    margin = 100.0 * (static - cost["adapt"]) / static
    realized = {nm: float(np.mean(pre.realized_k(nm)))
                for nm in ("cs", "ss", "adapt")}
    emit("fig12/preemption", cost["adapt"],
         f"{common};policy=close_partial;"
         f"cs={cost['cs']:.4f}ms/task;ss={cost['ss']:.4f}ms/task;"
         f"adapt={cost['adapt']:.4f}ms/task;"
         f"realized_cs={realized['cs']:.2f};"
         f"realized_ss={realized['ss']:.2f};"
         f"realized_adapt={realized['adapt']:.2f};"
         f"adapt_vs_static={margin:+.1f}%")

    # 2. the scenario zoo never deadlocks and never yields NaN
    zoo_ok = True
    for sc in FAULT_SCENARIOS:
        res = _sweep(make_scenario(sc, _base(), N), trials, seed=0,
                     deadline=deadline, policy="close_partial")
        ok = _finite_ok(res)
        zoo_ok = zoo_ok and ok
        emit(f"fig12/zoo_{sc}", res.mean_round("adapt") * 1e3,
             f"{common};status={'PASS' if ok else 'FAIL'};"
             f"realized_k={float(np.mean(res.realized_k('adapt'))):.2f};"
             f"missed={float(np.mean(res.missed_fraction('adapt'))):.3f};"
             f"stale={float(np.mean(res.stale_fraction('adapt'))):.3f}")

    # 3. fault-bearing trace record -> save -> load -> replay, bit-exact
    rec = _sweep(make_scenario("preemption", _base(), N), trials, seed=0,
                 deadline=deadline, policy="close_partial", record=True)
    os.makedirs(out, exist_ok=True)
    path = save_trace(os.path.join(out, "fig12_fault_trace"), rec.trace)
    trace = load_trace(path)
    assert trace == rec.trace, "on-disk fault-trace round-trip changed it"
    if not trace.has_faults:
        raise SystemExit("fig12: recorded preemption trace carries no "
                         "+inf cells — fault injection is not reaching "
                         "the recorder")
    rep = _sweep(TraceProcess(trace), trials, seed=99, deadline=deadline,
                 policy="close_partial")
    dev = max(float(np.abs(np.asarray(rep.per_round[nm])
                           - np.asarray(rec.per_round[nm])).max())
              for nm in SCHEMES)
    exact = all(np.array_equal(rep.per_round[nm], rec.per_round[nm])
                for nm in SCHEMES)
    degr_exact = all(
        np.array_equal(rep.degradation[nm][key], rec.degradation[nm][key])
        for nm in SCHEMES for key in ("realized_k", "missed", "stale",
                                      "khist"))
    emit("fig12/replay", dev,
         f"{common};status={'PASS' if exact and degr_exact else 'FAIL'};"
         f"replay_max_dev={dev:g};degradation_exact={degr_exact};"
         f"file={os.path.basename(path)};"
         f"trace_mb={trace.T1.nbytes * 2 / 1e6:.1f}MB")

    ok = (margin > 0) and zoo_ok and exact and degr_exact
    emit("fig12/fault_tolerance", 0.0,
         f"status={'PASS' if ok else 'FAIL'};"
         f"adapt_vs_static={margin:+.1f}%;zoo={'PASS' if zoo_ok else 'FAIL'};"
         f"replay={'PASS' if exact and degr_exact else 'FAIL'}")
    if not math.isfinite(margin):
        raise SystemExit("fig12: non-finite adaptive-vs-static margin — "
                         "the deadline path is leaking inf/NaN")
    if margin <= 0:
        raise SystemExit(
            f"fig12: adaptive + close_partial no longer beats the static "
            f"schedules under preemption ({margin:+.1f}% per realized "
            f"task) — crash-aware scheduling stopped paying")
    if not zoo_ok:
        raise SystemExit(
            "fig12: a fault scenario deadlocked or produced non-finite "
            "degradation metrics (see fig12/zoo_* rows)")
    if not (exact and degr_exact):
        raise SystemExit(
            f"fig12: fault-trace replay diverged from the recording run "
            f"(max deviation {dev:g}, degradation_exact={degr_exact}) — "
            f"the +inf record/replay contract is broken")
    return {"margin": margin, "deadline": deadline}


if __name__ == "__main__":
    run()

"""Fig. 4: average completion time vs computation load r (truncated
Gaussian delays, n = 16, k = n), scenarios 1 and 2.

Paper claims validated here:
  * SS slightly improves on CS; both beat PC and PCMM over the whole range;
  * PCMM beats PC (less pronounced in scenario 2);
  * at r = n, SS cuts RA's average delay by ~19.45% (scen 1) / ~16.32%
    (scen 2).
"""

from repro.core import scenario1, scenario2
from .common import Timer, emit, scheme_means


def run(trials: int = 20000):
    n, k = 16, 16
    rows = {}
    for sc_name, model in (("scen1", scenario1()), ("scen2", scenario2(n))):
        for r in (2, 4, 6, 8, 10, 12, 14, 16):
            with Timer() as t:
                m = scheme_means(model, n, r, k, trials=trials)
            derived = ";".join(f"{s}={v * 1e3:.4f}ms" for s, v in m.items())
            emit(f"fig4/{sc_name}/r{r}", t.us, derived)
            rows[(sc_name, r)] = m
    # claims
    for sc in ("scen1", "scen2"):
        full = rows[(sc, 16)]
        gain = 100 * (full["ra"] - full["ss"]) / full["ra"]
        beats = all(rows[(sc, r)]["ss"] <= rows[(sc, r)]["pc"] and
                    rows[(sc, r)]["cs"] <= rows[(sc, r)]["pc"]
                    for r in (2, 4, 8, 16))
        pcmm_beats_pc = all(rows[(sc, r)].get("pcmm", 1e9) <=
                            rows[(sc, r)]["pc"] for r in (4, 8, 16))
        emit(f"fig4/{sc}/claims", 0.0,
             f"ss_vs_ra_gain_pct={gain:.2f};cs_ss_beat_pc={beats};"
             f"pcmm_beats_pc={pcmm_beats_pc}")
    return rows

"""Fig. 6: average completion time vs number of workers n (r = n, k = n,
d = 500, N = 1000 scenario). Claims: CS/SS/RA improve with n; PCMM
*degrades* with n (its (2n-1)-message threshold grows); CS/SS >> coded.

With N fixed, each task is an (N/n)-row mini-batch, so the per-task
COMPUTATION delay scales ~1/n while the per-result COMMUNICATION delay is
constant (a d-vector either way) — that scaling is what makes the uncoded
schemes improve with n in the paper."""
import dataclasses


from repro.core import ec2_like
from .common import Timer, emit, scheme_means


def _model(n: int, n_ref: int = 10):
    m = ec2_like(n, seed=2)
    scale = n_ref / n                    # task size N/n vs the n=10 baseline
    mu1 = tuple(v * scale for v in m.mu1)
    return dataclasses.replace(m, mu1=mu1, sigma1=m.sigma1 * scale,
                               a1=m.a1 * scale)


def run(trials: int = 20000):
    rows = {}
    for n in (10, 11, 12, 13, 14, 15):
        model = _model(n)
        with Timer() as t:
            m = scheme_means(model, n, n, n, trials=trials)
        emit(f"fig6/n{n}", t.us,
             ";".join(f"{s}={v * 1e3:.4f}ms" for s, v in m.items()))
        rows[n] = m
    ss_improves = rows[15]["ss"] < rows[10]["ss"]
    ss_beats_pc = all(rows[n]["ss"] < rows[n]["pc"] for n in rows)
    # PCMM-degrades-with-n: on EC2 the paper attributes this to the 2n-1
    # communications loading the master; an iid delay model (the paper's own
    # theoretical model!) cannot produce that contention, so we REPORT the
    # trend rather than assert it (see EXPERIMENTS.md §Fig6).
    pcmm_trend = rows[15]["pcmm"] / rows[10]["pcmm"]
    emit("fig6/claims", 0.0,
         f"ss_improves_with_n={ss_improves};ss_beats_pc={ss_beats_pc};"
         f"pcmm_n15_over_n10={pcmm_trend:.3f}"
         f";pcmm_degradation_needs_contention_model=note")
    return rows

"""Roofline table from saved dry-run JSONs (deliverable (g) reader).

Reads ``experiments/dryrun/*.json`` and prints one CSV row per (mesh,
arch, shape): the three roofline terms, the dominant bottleneck, and the
useful-FLOPs ratio.

The artifacts are PRODUCED by ``repro.launch.dryrun`` — e.g.::

    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all        # every (arch, shape) combo

which compiles each jitted step against ShapeDtypeStruct inputs (no
allocation) and writes one JSON per combination into
``experiments/dryrun/``.  The directory is not checked in: dry-run
artifacts are machine/version-dependent compile measurements.  When it is
absent this reader emits a single ``roofline/skipped`` row saying exactly
that (and how to produce the inputs) instead of silently reporting an
empty table."""
import glob
import json
import os

from .common import emit


def run(dryrun_dir: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not files:
        emit("roofline/skipped", 0.0,
             f"status=SKIP;reason=no_dryrun_artifacts_in_{dryrun_dir};"
             f"produce_with=python_-m_repro.launch.dryrun_--all")
        print(f"roofline_report: skipped — no dry-run artifacts under "
              f"{dryrun_dir!r}; produce them with "
              f"`python -m repro.launch.dryrun --all` (or a single "
              f"--arch/--shape combination) first")
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("skipped"):
            continue
        ro = r["roofline"]
        emit(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
             + (f"/{t}" if (t := os.path.basename(f).split('__')[-1]
                            .removesuffix('.json')) not in
                (r['shape'],) else ""),
             r["timings"]["compile_s"] * 1e6,
             f"compute_s={ro['compute_s']:.3e};"
             f"memory_s={ro['memory_s']:.3e};"
             f"collective_s={ro['collective_s']:.3e};"
             f"dominant={ro['dominant'].removesuffix('_s')};"
             f"useful_ratio={ro['useful_ratio']:.3f}")

"""Roofline table from saved dry-run JSONs (deliverable (g) reader).
Reads experiments/dryrun/*.json and prints one CSV row per (mesh, arch,
shape): the three terms, dominant bottleneck, and useful-FLOPs ratio."""
import glob
import json
import os

from .common import emit


def run(dryrun_dir: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "no_dryrun_artifacts_yet=true")
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("skipped"):
            continue
        ro = r["roofline"]
        emit(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
             + (f"/{t}" if (t := os.path.basename(f).split('__')[-1]
                            .removesuffix('.json')) not in
                (r['shape'],) else ""),
             r["timings"]["compile_s"] * 1e6,
             f"compute_s={ro['compute_s']:.3e};"
             f"memory_s={ro['memory_s']:.3e};"
             f"collective_s={ro['collective_s']:.3e};"
             f"dominant={ro['dominant'].removesuffix('_s')};"
             f"useful_ratio={ro['useful_ratio']:.3f}")

"""Fig. 11 (beyond the paper): record -> replay -> calibrate a cluster.

The paper's headline numbers come from a *measured* EC2 cluster; this
benchmark exercises the whole trace-driven loop that lets this repo do the
same (``repro.core.trace``) on the fig8/fig10 heterogeneous
persistent-straggler cell:

  1. **record** — one ``sweep_rounds`` over the parametric cluster with
     ``record_trace=True`` captures the realized per-(round, trial,
     worker, slot) delay tables; they are written to disk in the
     versioned trace format and read back (round-tripping the on-disk
     format every CI run; the file is uploaded as a CI artifact);
  2. **replay** — the loaded trace replayed through ``TraceProcess`` must
     reproduce the recording run's per-round completion times *and*
     adaptive decisions bit-exactly, for the static CS/SS schemes, the
     censored-feedback adaptive scheme, and the oracle LB;
  3. **calibrate** — ``calibrate_trace`` fits a
     ``MarkovRegimeProcess`` (per-worker scales, slow/fast regime chain,
     truncated-Gaussian base) to the trace; the fitted cluster must
     reproduce the *decision-relevant* structure: the adaptive-vs-static
     margin keeps its sign (adaptation that pays on the real trace must
     pay on the synthetic twin).

Rows: ``fig11/<source>`` (source in model / trace / calib) carry each
delay source's per-scheme ms/round and its ``adapt_vs_static`` margin —
the ``fig11/trace`` margin is consumed by the CI regression gate.
``fig11/replay`` carries the max replay deviation (must be 0);
``fig11/calibration`` the fitted parameters and fit-quality report.  The
run exits non-zero if replay diverges or the calibrated margin's sign
flips vs the trace.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import (TraceProcess, adaptive_spec, calibrate_trace,
                        cyclic_to_matrix, ec2_cluster, lb_spec, load_trace,
                        save_trace, scenario1, staircase_to_matrix,
                        sweep_rounds, to_spec)
from .common import emit

N, R, K = 12, 3, 9
ROUNDS = 20
PERSISTENCE, SPREAD = 0.98, 3.0
CHUNK = 1000


def _process():
    return ec2_cluster(N, spread=SPREAD, p_slow=0.25,
                       persistence=PERSISTENCE, slow=8.0, base=scenario1(),
                       seed=1)


def _specs():
    return [to_spec("cs", cyclic_to_matrix(N, R)),
            to_spec("ss", staircase_to_matrix(N, R)),
            adaptive_spec("adapt", cyclic_to_matrix(N, R)),
            lb_spec(R)]


def _sweep(process, trials, seed, record=False):
    return sweep_rounds(_specs(), process, N, rounds=ROUNDS, k=K,
                        trials=trials, seed=seed, chunk=CHUNK,
                        censored_feedback=True, record_trace=record)


def _margin(res) -> float:
    """Adaptive-vs-static margin (%): how much the censored-feedback
    adaptive scheme beats the better static schedule per round."""
    ms = {nm: res.mean_round(nm) for nm in ("cs", "ss", "adapt")}
    static = min(ms["cs"], ms["ss"])
    return 100.0 * (static - ms["adapt"]) / static


def _emit_source(src: str, res, common: str) -> float:
    ms = {nm: res.mean_round(nm) * 1e3 for nm in ("cs", "ss", "adapt",
                                                  "lb")}
    margin = _margin(res)
    emit(f"fig11/{src}", ms["adapt"] * 1e3,
         f"{common};cs={ms['cs']:.4f}ms;ss={ms['ss']:.4f}ms;"
         f"adapt={ms['adapt']:.4f}ms;lb={ms['lb']:.4f}ms;"
         f"adapt_vs_static={margin:+.1f}%")
    return margin


def run(trials: int = 20000, out: str = "bench_out"):
    trials = min(trials, 3000)      # ROUNDS sims x 3 sources + recording
    common = (f"trials={trials};rounds={ROUNDS};n={N};r={R};k={K};"
              f"persistence={PERSISTENCE};spread={SPREAD:g}")

    # 1. record (statistics computed by replaying the captured tables, so
    #    step 2 must match them bit-exactly) + on-disk round-trip
    rec = _sweep(_process(), trials, seed=0, record=True)
    os.makedirs(out, exist_ok=True)
    path = save_trace(os.path.join(out, "fig11_trace"), rec.trace)
    trace = load_trace(path)
    assert trace == rec.trace, "on-disk trace round-trip changed content"

    # 2. replay the loaded trace — bit-exact or bust
    rep = _sweep(TraceProcess(trace), trials, seed=99)
    dev = max(float(np.abs(np.asarray(rep.per_round[nm])
                           - np.asarray(rec.per_round[nm])).max())
              for nm in ("cs", "ss", "adapt", "lb"))
    exact = all(np.array_equal(rep.per_round[nm], rec.per_round[nm])
                for nm in ("cs", "ss", "adapt", "lb"))
    emit("fig11/replay", dev,
         f"{common};status={'PASS' if exact else 'FAIL'};"
         f"replay_max_dev={dev:g};file={os.path.basename(path)};"
         f"trace_mb={trace.T1.nbytes * 2 / 1e6:.1f}MB")

    # 3. calibrate a synthetic twin from the trace
    cal = calibrate_trace(trace)
    emit("fig11/calibration", cal.mean_rel_err * 100.0,
         f"p_slow={cal.p_slow:.3f};persistence={cal.persistence:.3f};"
         f"slow={cal.slow:.2f}x;mean_err={cal.mean_rel_err * 100:.1f}%;"
         f"comm_err={cal.comm_mean_rel_err * 100:.1f}%;"
         f"worker_err={cal.worker_mean_rel_err * 100:.1f}%;"
         f"lag1_trace={cal.lag1_trace:+.2f};lag1_fit={cal.lag1_fit:+.2f}")

    # adaptive-vs-static margins across the three delay sources
    m_model = _emit_source("model", _sweep(_process(), trials, seed=1),
                           common)
    m_trace = _emit_source("trace", rep, common)
    m_calib = _emit_source("calib", _sweep(cal.process, trials, seed=1),
                           common)

    sign_ok = (m_calib > 0) == (m_trace > 0)
    ok = exact and sign_ok
    emit("fig11/trace_replay_calibrate", 0.0,
         f"status={'PASS' if ok else 'FAIL'};"
         f"margin_model={m_model:+.1f}%;margin_trace={m_trace:+.1f}%;"
         f"margin_calib={m_calib:+.1f}%")
    if not exact:
        raise SystemExit(
            f"fig11: trace replay diverged from the recording run "
            f"(max deviation {dev:g}) — the record/replay contract is "
            f"broken")
    if not sign_ok:
        raise SystemExit(
            f"fig11: the calibrated cluster flips the adaptive-vs-static "
            f"margin sign (trace {m_trace:+.1f}% vs calibrated "
            f"{m_calib:+.1f}%) — calibration no longer preserves the "
            f"decision-relevant delay structure")
    return {"model": m_model, "trace": m_trace, "calib": m_calib}


if __name__ == "__main__":
    run()

"""Fig. 7: average completion time vs computation target k (n = 10, r = n).
Claims: completion time increases with k; scheme gaps widen with k; SS
coincides with the lower bound for small/medium k (k in [2:6]) and stays
close for large k. Coded schemes excluded (they require k = n)."""
import numpy as np

from repro.core import ec2_like
from .common import Timer, emit, scheme_means


def run(trials: int = 20000):
    n = 10
    model = ec2_like(n, seed=3)
    rows = {}
    for k in range(2, n + 1):
        with Timer() as t:
            m = scheme_means(model, n, n, k, trials=trials,
                             include_coded=False)
        emit(f"fig7/k{k}", t.us,
             ";".join(f"{s}={v * 1e3:.4f}ms" for s, v in m.items()))
        rows[k] = m
    increases = all(rows[k]["ss"] <= rows[k + 1]["ss"] + 1e-9
                    for k in range(2, n))
    lb_tight_small_k = all((rows[k]["ss"] - rows[k]["lb"]) /
                           max(rows[k]["lb"], 1e-12) < 0.05
                           for k in range(2, 7))
    lb_close_large_k = (rows[n]["ss"] - rows[n]["lb"]) / rows[n]["lb"] < 0.25
    emit("fig7/claims", 0.0,
         f"time_increases_with_k={increases};"
         f"ss_matches_lb_small_k={lb_tight_small_k};"
         f"ss_near_lb_large_k={lb_close_large_k}")
    return rows

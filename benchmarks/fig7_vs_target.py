"""Fig. 7: average completion time vs computation target k (n = 10, r = n).
Claims: completion time increases with k; scheme gaps widen with k; SS
coincides with the lower bound for small/medium k (k in [2:6]) and stays
close for large k. Coded schemes excluded (they require k = n)."""

from repro.core import ec2_like
from .common import Timer, emit, scheme_mean_table


def run(trials: int = 20000):
    n = 10
    model = ec2_like(n, seed=3)
    # The whole k-sweep is ONE engine call: every k in 1..n comes from a
    # single sort of the shared task arrivals.
    with Timer() as t:
        table = scheme_mean_table(model, n, n, trials=trials,
                                  include_coded=False)
    emit(f"fig7/sweep_all_k", t.us, f"schemes={len(table)};ks=1..{n}")
    rows = {}
    us_per_k = t.us / (n - 1)          # amortized: one call served every k
    for k in range(2, n + 1):
        m = {s: float(v[k - 1]) for s, v in table.items()}
        emit(f"fig7/k{k}", us_per_k,
             ";".join(f"{s}={v * 1e3:.4f}ms" for s, v in m.items()))
        rows[k] = m
    increases = all(rows[k]["ss"] <= rows[k + 1]["ss"] + 1e-9
                    for k in range(2, n))
    lb_tight_small_k = all((rows[k]["ss"] - rows[k]["lb"]) /
                           max(rows[k]["lb"], 1e-12) < 0.05
                           for k in range(2, 7))
    lb_close_large_k = (rows[n]["ss"] - rows[n]["lb"]) / rows[n]["lb"] < 0.25
    emit("fig7/claims", 0.0,
         f"time_increases_with_k={increases};"
         f"ss_matches_lb_small_k={lb_tight_small_k};"
         f"ss_near_lb_large_k={lb_close_large_k}")
    return rows

"""Benchmark harness — one entry per paper table/figure (deliverable (d)).

Prints ``name,us_per_call,derived`` CSV rows:
  fig3   delay-model calibration (comm >> comp)
  fig4   avg completion vs r, truncated-Gaussian scenarios 1 & 2 (n=16)
  fig5   avg completion vs r, EC2-calibrated model (n=15)
  fig6   avg completion vs n (r=n)
  fig7   avg completion vs k (n=10, r=n)
  fig8   rounds-axis wall-clock: persistence x heterogeneity grid, static
         CS/SS vs feedback-adaptive row assignment vs oracle LB
  fig9   intra-round message budget m in {1, 2, r} for CS/SS/PCMM
         (paper Sec. V-C; exits non-zero if multi-message stops beating
         single-message), plus the Ozfatura-style per-message overhead
         sweep reporting the optimal budget m*(eps)
  fig10  adaptive load re-balancing (ragged per-worker loads, Egger-style)
         vs static CS/SS and permutation-only adaptation on the
         heterogeneous persistent cluster (exits non-zero unless
         re-balancing beats all three)
  fig11  trace record -> replay -> calibrate loop: records the
         heterogeneous cell's delays, round-trips the versioned trace
         file, replays it (exits non-zero unless bit-exact), and checks
         the calibrated synthetic twin keeps the adaptive-vs-static
         margin sign
  fig12  fault injection and graceful degradation: the failure-scenario
         zoo under round deadlines (exits non-zero unless adaptive +
         close_partial beats static under preemption, every scenario
         stays finite, and the fault-bearing trace replays bit-exactly)
  fig13  live execution layer vs the simulator: an async in-process
         master-worker run must match ``sweep_rounds`` bit-exactly
         (shared-seed tables + the engine's fused scorer), its recorded
         trace must replay bit-exactly, its mean must sit inside the MC
         prediction's sampling tolerance, and deadline degradation
         accounting must match the engine's streams (non-zero exit on
         any violation)
  mc_engine  fused sweep-engine throughput vs the seed per-scheme path
  grid   streaming grid-sweep engine (repro.core.grid) vs the naive
         loop-of-sweeps baseline: cells/sec, one-compile-per-bucket, and
         CRN bit-exactness (non-zero exit on a retrace or stats mismatch);
         also writes the GRID_result.json artifact into --out
  planner  racing planner vs the exhaustive grid on the same 64 cells:
         must name the same argmin operating point (non-zero exit on
         disagreement) while spending a fraction of the trial-evaluations
         (the ``saved`` ratio, gated via ``planner_trials_saved_min``)
  table1 end-to-end DGD iteration per scheme incl. real PC/PCMM decode
  roofline  per-(mesh, arch, shape) terms from saved dry-run artifacts

Each job also writes a machine-readable ``BENCH_<name>.json`` (the CSV rows
with parsed derived metrics) into ``--out`` for CI artifact upload and the
``benchmarks.regression_gate`` check.

Every drained row is screened for NaN/inf metric values: a non-finite
number in a derived field aborts the harness with a non-zero exit and an
explicit message, so a silently-poisoned benchmark can never look green.

Use --quick for CI-speed runs (fewer MC trials).
"""
import argparse
import json
import math
import os
import time


def _check_finite(name: str, rows: list) -> None:
    """Fail loudly (non-zero exit) when a benchmark emits NaN/inf metrics."""
    bad = [(row["name"], key, val)
           for row in rows for key, val in row.get("derived", {}).items()
           if isinstance(val, float) and not math.isfinite(val)]
    if bad:
        lines = "; ".join(f"{r}:{k}={v}" for r, k, v in bad)
        raise SystemExit(
            f"benchmarks.run: benchmark {name!r} emitted non-finite "
            f"metric(s): {lines} — refusing to report poisoned results")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer Monte-Carlo trials")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig4,fig7")
    ap.add_argument("--out", default="bench_out",
                    help="directory for BENCH_<name>.json artifacts "
                         "(created if needed; '' disables JSON output)")
    args = ap.parse_args(argv)
    trials = 4000 if args.quick else 20000
    only = set(args.only.split(",")) if args.only else None

    from . import (common, fig3_delays, fig4_vs_load, fig5_ec2,
                   fig6_vs_workers, fig7_vs_target, fig8_convergence,
                   fig9_multimessage, fig10_load_rebalance,
                   fig11_trace_replay, fig12_faults, fig13_live,
                   grid_stream, mc_engine, planner, table1_e2e,
                   roofline_report)

    jobs = {
        "fig3": lambda: fig3_delays.run(trials),
        "fig4": lambda: fig4_vs_load.run(trials),
        "fig5": lambda: fig5_ec2.run(trials),
        "fig6": lambda: fig6_vs_workers.run(trials),
        "fig7": lambda: fig7_vs_target.run(trials),
        "fig8": lambda: fig8_convergence.run(trials),
        "fig9": lambda: fig9_multimessage.run(trials),
        "fig10": lambda: fig10_load_rebalance.run(trials),
        "fig11": lambda: fig11_trace_replay.run(trials,
                                                out=args.out or "bench_out"),
        "fig12": lambda: fig12_faults.run(trials,
                                          out=args.out or "bench_out"),
        "fig13": lambda: fig13_live.run(trials),
        "mc_engine": lambda: mc_engine.run(trials),
        "grid": lambda: grid_stream.run(trials,
                                        out=args.out or "bench_out"),
        "planner": lambda: planner.run(trials),
        "table1": table1_e2e.run,
        "roofline": roofline_report.run,
    }
    if only:
        unknown = sorted(only - set(jobs))
        if unknown:
            raise SystemExit(
                f"benchmarks.run: unknown --only name(s) {unknown}; "
                f"valid names: {sorted(jobs)}")

    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if only and name not in only:
            continue
        common.drain_rows()            # drop strays from earlier jobs
        try:
            job()
        finally:
            # write the artifact even when a guard fails (fig8/fig9 exit
            # non-zero): the per-scheme rows are the diagnosis.
            rows = common.drain_rows()
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                path = os.path.join(args.out, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump({"bench": name, "quick": bool(args.quick),
                               "trials": trials, "unix_time": time.time(),
                               "rows": rows}, f, indent=2)
                    f.write("\n")
        _check_finite(name, rows)


if __name__ == "__main__":
    main()

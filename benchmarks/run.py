"""Benchmark harness — one entry per paper table/figure (deliverable (d)).

Prints ``name,us_per_call,derived`` CSV rows:
  fig3   delay-model calibration (comm >> comp)
  fig4   avg completion vs r, truncated-Gaussian scenarios 1 & 2 (n=16)
  fig5   avg completion vs r, EC2-calibrated model (n=15)
  fig6   avg completion vs n (r=n)
  fig7   avg completion vs k (n=10, r=n)
  fig8   rounds-axis wall-clock: persistence x heterogeneity grid, static
         CS/SS vs feedback-adaptive row assignment vs oracle LB
  mc_engine  fused sweep-engine throughput vs the seed per-scheme path
  table1 end-to-end DGD iteration per scheme incl. real PC/PCMM decode
  roofline  per-(mesh, arch, shape) terms from saved dry-run artifacts

Use --quick for CI-speed runs (fewer MC trials).
"""
import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer Monte-Carlo trials")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig4,fig7")
    args = ap.parse_args(argv)
    trials = 4000 if args.quick else 20000
    only = set(args.only.split(",")) if args.only else None

    from . import (fig3_delays, fig4_vs_load, fig5_ec2, fig6_vs_workers,
                   fig7_vs_target, fig8_convergence, mc_engine, table1_e2e,
                   roofline_report)

    print("name,us_per_call,derived")
    jobs = {
        "fig3": lambda: fig3_delays.run(trials),
        "fig4": lambda: fig4_vs_load.run(trials),
        "fig5": lambda: fig5_ec2.run(trials),
        "fig6": lambda: fig6_vs_workers.run(trials),
        "fig7": lambda: fig7_vs_target.run(trials),
        "fig8": lambda: fig8_convergence.run(trials),
        "mc_engine": lambda: mc_engine.run(trials),
        "table1": table1_e2e.run,
        "roofline": roofline_report.run,
    }
    for name, job in jobs.items():
        if only and name not in only:
            continue
        job()


if __name__ == "__main__":
    main()

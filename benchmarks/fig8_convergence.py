"""Fig. 8 (beyond the paper): wall-clock convergence on round-aware
clusters — straggler persistence x worker heterogeneity.

The paper's figures score a single isolated round with delays i.i.d.
across workers and rounds.  Real clusters (paper Sec. VI-A; Behrouzi-Far &
Soljanin, arXiv:1808.02838) have worker-specific, *persistent* stragglers —
exactly the regime where round-to-round adaptation pays.  This benchmark
sweeps the ``MarkovRegimeProcess`` grid (persistence in {0, 0.9, 0.98} x
speed spread in {1, 3}) and reports each scheme's mean completion time per
round over an R-round run from ONE fused ``sweep_rounds`` call per cell
(all schemes share the same cluster realizations — paired samples):

  * ``cs`` / ``ss``   — the paper's static schedules;
  * ``adapt``         — greedy feedback-driven row re-assignment of the CS
                        matrix (fastest workers take the least-covered
                        tasks first);
  * ``lb``            — the oracle lower bound (eq. 46) per round.

Rows:  fig8/p<persistence>_s<spread>  with per-scheme ms/round and the
adaptive scheme's reduction vs the better static schedule.  On the
i.i.d. homogeneous cell (p0.0_s1) adapt ~= cs (nothing to learn); on
persistent heterogeneous cells adapt must beat BOTH static schedules —
the rounds-axis regression guard.
"""
from __future__ import annotations


from repro.core import (MarkovRegimeProcess, adaptive_spec,
                        cyclic_to_matrix, ec2_cluster, lb_spec, scenario1,
                        staircase_to_matrix, sweep_rounds, to_spec)
from .common import emit


N, R, K = 12, 3, 9
ROUNDS = 24
PERSISTENCE = (0.0, 0.9, 0.98)
SPREAD = (1.0, 3.0)


def _cell_process(persistence: float, spread: float) -> MarkovRegimeProcess:
    return ec2_cluster(N, spread=spread, p_slow=0.25,
                       persistence=persistence, slow=8.0, base=scenario1(),
                       seed=1)


def run(trials: int = 20000):
    trials = min(trials, 8000)          # R*ROUNDS sims per trial
    cs = cyclic_to_matrix(N, R)
    specs = [to_spec("cs", cs), to_spec("ss", staircase_to_matrix(N, R)),
             adaptive_spec("adapt", cs), lb_spec(R)]
    out = {}
    for p in PERSISTENCE:
        for s in SPREAD:
            res = sweep_rounds(specs, _cell_process(p, s), N, rounds=ROUNDS,
                               k=K, trials=trials, seed=0, chunk=2000)
            ms = {sp.name: res.mean_round(sp.name) * 1e3 for sp in specs}
            static = min(ms["cs"], ms["ss"])
            gain = 100.0 * (static - ms["adapt"]) / static
            emit(f"fig8/p{p}_s{s:g}", res.total("adapt") * 1e6,
                 f"trials={trials};rounds={ROUNDS};"
                 f"cs={ms['cs']:.4f}ms;ss={ms['ss']:.4f}ms;"
                 f"adapt={ms['adapt']:.4f}ms;lb={ms['lb']:.4f}ms;"
                 f"adapt_vs_static={gain:+.1f}%")
            out[(p, s)] = ms
    # acceptance guard: on the persistent heterogeneous corner the adaptive
    # schedule must beat both static schedules' mean wall-clock per round.
    worst = out[(max(PERSISTENCE), max(SPREAD))]
    ok = worst["adapt"] < worst["cs"] and worst["adapt"] < worst["ss"]
    emit("fig8/adaptive_beats_static", 0.0,
         f"persistent_heterogeneous_cell={'PASS' if ok else 'FAIL'};"
         f"adapt={worst['adapt']:.4f}ms;cs={worst['cs']:.4f}ms;"
         f"ss={worst['ss']:.4f}ms")
    if not ok:
        raise SystemExit("fig8: adaptive schedule failed to beat static "
                         "CS/SS on the persistent heterogeneous cell")
    return out


if __name__ == "__main__":
    run()

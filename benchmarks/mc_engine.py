"""Monte-Carlo sweep-engine throughput: seed per-scheme path vs fused engine.

The seed evaluated each scheme with its own delay sampling pass, a
scatter-min for task arrivals, and a full sort per scheme; the fused engine
(repro.core.montecarlo) samples once, gathers task arrivals through a
static layout shared by all stacked TO matrices, and sorts once per scheme
family.  This benchmark measures both at the paper's Fig.-4 corner
(n = 16, r = 16) and reports throughput in trials*schemes/sec, plus a
large chunked sweep demonstrating O(chunk) memory at 10^6+ trials.

Rows:
  mc_engine/legacy     seed-style per-scheme evaluation
  mc_engine/fused      one engine call, same schemes, shared draws
  mc_engine/speedup    fused over legacy throughput ratio
  mc_engine/scan_overhead  the fused sweep streamed in 8 chunks: the
                       chunked-over-fused throughput ratio isolates
                       per-chunk scan cost (device-side fold_in keys +
                       masked partial sums)
  mc_engine/chunked1M  10^6-trial sweep streamed in 20k-trial chunks
  mc_engine/scaling1   sharding base point: chunked sweep on ONE device
  mc_engine/scaling    same sweep on every local device: strong speedup
                       (fixed total trials) + weak efficiency (trials
                       scaled with devices) + trials/sec — only emitted
                       with > 1 device (CPU CI forces 4 via
                       XLA_FLAGS=--xla_force_host_platform_device_count=4)
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, pc_threshold,
                        pcmm_threshold, scenario1, sweep, to_spec, lb_spec,
                        pc_spec, pcmm_spec)
from .common import emit


# ----------------------- seed-style per-scheme path --------------------------
# A faithful replica of the seed's hot path, kept here so the speedup stays
# measurable after the library switched to the fused engine.

@partial(jax.jit, static_argnames=("n", "k"))
def _legacy_to(C, T1, T2, n: int, k: int):
    s = jnp.cumsum(T1, axis=-1) + T2
    Cf = jnp.asarray(C).reshape(-1)
    sf = s.reshape(s.shape[:-2] + (-1,))
    init = jnp.full(s.shape[:-2] + (n,), jnp.inf, s.dtype)
    tau = init.at[..., Cf].min(sf)
    return jnp.sort(tau, axis=-1)[..., k - 1]


@partial(jax.jit, static_argnames=("kth",))
def _legacy_pc(T1, T2, kth: int):
    t_worker = T1.sum(axis=-1) + T2[..., -1]
    return jnp.sort(t_worker, axis=-1)[..., kth - 1]


@partial(jax.jit, static_argnames=("kth",))
def _legacy_flat_sort(T1, T2, kth: int):
    s = (jnp.cumsum(T1, axis=-1) + T2).reshape(T1.shape[0], -1)
    return jnp.sort(s, axis=-1)[..., kth - 1]


def _legacy_scheme_means(model, n: int, r: int, k: int, *, trials: int,
                         seed: int = 0) -> dict:
    """Seed behavior: every scheme re-samples its own (trials, n, r) delays
    from the same PRNGKey(seed) and runs its own jitted simulation."""
    out = {}
    for name, C in (("cs", cyclic_to_matrix(n, r)),
                    ("ss", staircase_to_matrix(n, r)),
                    ("ra", random_assignment_to_matrix(n, seed=seed))):
        T1, T2 = model.sample(jax.random.PRNGKey(seed), trials, n,
                              C.shape[1])
        out[name] = float(jnp.mean(
            _legacy_to(jnp.asarray(C), T1, T2, n, k)))
    T1, T2 = model.sample(jax.random.PRNGKey(seed), trials, n, r)
    out["pc"] = float(jnp.mean(_legacy_pc(T1, T2, pc_threshold(n, r))))
    T1, T2 = model.sample(jax.random.PRNGKey(seed), trials, n, r)
    out["pcmm"] = float(jnp.mean(_legacy_flat_sort(T1, T2,
                                                   pcmm_threshold(n))))
    T1, T2 = model.sample(jax.random.PRNGKey(seed), trials, n, r)
    out["lb"] = float(jnp.mean(_legacy_flat_sort(T1, T2, k)))
    return out


def _fused_specs(n: int, r: int, seed: int):
    return (to_spec("cs", cyclic_to_matrix(n, r)),
            to_spec("ss", staircase_to_matrix(n, r)),
            to_spec("ra", random_assignment_to_matrix(n, seed=seed)),
            pc_spec(r), pcmm_spec(r), lb_spec(r))


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warm (compile) — not timed
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(trials: int = 20000):
    n = r = k = 16
    model = scenario1()
    n_schemes = 6

    t_legacy = _time(lambda: _legacy_scheme_means(model, n, r, k,
                                                  trials=trials))
    thr_legacy = trials * n_schemes / t_legacy
    emit("mc_engine/legacy", t_legacy * 1e6,
         f"trials={trials};schemes={n_schemes};"
         f"throughput={thr_legacy:,.0f}_trials_schemes_per_s")

    specs = _fused_specs(n, r, seed=0)
    t_fused = _time(lambda: sweep(specs, model, n, trials=trials, seed=0))
    thr_fused = trials * n_schemes / t_fused
    emit("mc_engine/fused", t_fused * 1e6,
         f"trials={trials};schemes={n_schemes};"
         f"throughput={thr_fused:,.0f}_trials_schemes_per_s")

    emit("mc_engine/speedup", 0.0,
         f"fused_over_legacy={thr_fused / thr_legacy:.2f}x")

    # scan overhead: the SAME sweep as the fused row, streamed in 8 chunks.
    # The chunked scan adds only device-side work per chunk (fold_in key
    # derivation, masked partial sums) — no host key tables — so the
    # throughput ratio vs the single-chunk fused row isolates the
    # remaining per-chunk cost and keeps the chunked/fused gap tracked.
    t_chunk = _time(lambda: sweep(specs, model, n, trials=trials, seed=0,
                                  chunk=max(1, trials // 8)))
    thr_chunk = trials * n_schemes / t_chunk
    emit("mc_engine/scan_overhead", t_chunk * 1e6,
         f"trials={trials};chunks=8;"
         f"throughput={thr_chunk:,.0f}_trials_schemes_per_s;"
         f"chunked_over_fused={thr_chunk / thr_fused:.2f}")

    # chunked large sweep: memory stays O(chunk * n * r) regardless of trials
    big = 1_000_000 if trials >= 20000 else 50 * trials
    chunk = 20000
    t0 = time.perf_counter()
    res = sweep(specs, model, n, trials=big, seed=0, chunk=chunk)
    t_big = time.perf_counter() - t0
    emit("mc_engine/chunked1M", t_big * 1e6,
         f"trials={big};chunk={chunk};"
         f"throughput={big * n_schemes / t_big:,.0f}_trials_schemes_per_s;"
         f"cs_at_k={res.at_k('cs', k) * 1e3:.5f}ms"
         f"+-{float(res.stderr['cs'][k - 1]) * 1e3:.5f}ms")

    scaling = _scaling(model, n, r, trials)
    return {"legacy_s": t_legacy, "fused_s": t_fused,
            "speedup": thr_fused / thr_legacy, "big_s": t_big,
            "scan_overhead": thr_chunk / thr_fused, **scaling}


def _scaling(model, n: int, r: int, trials: int) -> dict:
    """Strong/weak device-sharding scaling of the chunked fused sweep.

    Strong: the SAME ``trials`` on 1 device vs all ``D`` local devices
    (identical chunk decomposition, so the sharded result is bit-exact —
    only wall-clock changes).  Weak: ``trials * D`` on ``D`` devices vs
    ``trials`` on one; efficiency 1.0 means per-device throughput is flat.
    The single-device base row is always emitted; the multi-device row
    needs > 1 local device (CPU CI forces 4 host devices via XLA_FLAGS).
    """
    D = len(jax.devices())
    specs = _fused_specs(n, r, seed=0)
    # enough chunks that every device gets several whole ones
    chunk = max(1, trials // 16)

    def run_sweep(tr: int, devices):
        # evaluators are cached per device tuple; _time's untimed warmup
        # call absorbs the compile either way
        return _time(lambda: sweep(specs, model, n, trials=tr, seed=0,
                                   chunk=chunk, devices=devices))

    t1 = run_sweep(trials, 1)
    tps1 = trials / t1
    emit("mc_engine/scaling1", t1 * 1e6,
         f"devices=1;trials={trials};chunk={chunk};"
         f"trials_per_sec={tps1:,.0f}")
    if D <= 1:
        return {"scaling_devices": 1, "trials_per_sec_1dev": tps1}

    t_strong = run_sweep(trials, D)
    t_weak = run_sweep(trials * D, D)
    strong = t1 / t_strong
    weak_eff = t1 / t_weak
    emit("mc_engine/scaling", t_strong * 1e6,
         f"devices={D};trials={trials};chunk={chunk};"
         f"trials_per_sec={trials / t_strong:,.0f};"
         f"strong_speedup={strong:.2f}x;"
         f"weak_efficiency={weak_eff:.2f}")
    return {"scaling_devices": D, "trials_per_sec_1dev": tps1,
            "trials_per_sec": trials / t_strong,
            "strong_speedup": strong, "weak_efficiency": weak_eff}


if __name__ == "__main__":
    run()

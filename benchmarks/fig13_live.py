"""Fig. 13 (beyond the paper): the live execution layer vs the simulator.

The live layer (``repro.live``) really runs the master-worker protocol —
async workers streaming messages, a master closing rounds at ``k`` distinct
results — so this benchmark pins the contract that makes it *the same
experiment* as the Monte Carlo engine:

  1. **exact** — a live in-process run (``run_live``, ``time_scale=0``,
     ``abort_on_close=False``) must reproduce
     ``sweep_rounds(process, trials=1, seed, record_trace=True)``
     per-round completion times BIT-EXACTLY (workers run the engine's own
     jitted capture program for the delay tables; ``record_trace=True`` is
     the engine's bit-exactly-reproducible evaluation path — a *fused*
     parametric run may differ by ulps, by design), and the live trace
     must replay bit-exactly through ``TraceProcess``;
  2. **accuracy** — the live run's mean completion must sit within the
     Monte Carlo prediction's sampling tolerance: the live run is one
     realization of the process the engine averages over ``trials``
     realizations, so ``|live - MC| <= z * sd_live / sqrt(rounds_eff) ``
     (persistence shrinks the effective sample count) with a relative
     floor;
  3. **deadline** — the same live cluster under a ``close_partial``
     deadline must match the engine's graceful-degradation streams
     (per-round realized-k and deadline misses) exactly, realization for
     realization.

Rows: ``fig13/exact`` (max deviation, must be 0), ``fig13/accuracy``
(live vs MC means; ``rel_err`` is consumed by the CI regression gate),
``fig13/deadline``.  Exits non-zero on any violation.
"""
from __future__ import annotations

import numpy as np

from repro.core import (RoundConfig, TraceProcess, ec2_cluster, scenario1,
                        sweep_rounds)
from repro.live import run_live

from .common import emit

N, R, K = 8, 2, 6
ROUNDS = 20
PERSISTENCE, SPREAD = 0.9, 3.0
SEED = 7
Z = 5.0                 # accuracy-leg tolerance: z * stderr of the live mean
REL_FLOOR = 0.10        # ... but never tighter than 10% relative


def _process():
    return ec2_cluster(N, spread=SPREAD, p_slow=0.25,
                       persistence=PERSISTENCE, slow=8.0, base=scenario1(),
                       seed=1)


def run(trials: int = 20000):
    trials = min(trials, 2000)
    cfg = RoundConfig(n=N, k=K, kind="cs", r=R, seed=SEED)
    spec = cfg.to_scheme_spec("cs")
    common = (f"n={N};r={R};k={K};rounds={ROUNDS};"
              f"persistence={PERSISTENCE};spread={SPREAD:g}")

    # ---- 1. exactness: live == engine (trials=1) == trace replay --------
    res = run_live(cfg, _process(), ROUNDS, abort_on_close=False)
    live32 = res.per_round.astype(np.float32)
    one = sweep_rounds([spec], _process(), N, rounds=ROUNDS, trials=1,
                       k=K, seed=SEED, record_trace=True)
    rep = sweep_rounds([spec], TraceProcess(res.trace), N, rounds=ROUNDS,
                       trials=1, k=K, seed=SEED)
    dev_mc = float(np.abs(live32 - one.per_round["cs"].astype(
        np.float32)).max())
    dev_rp = float(np.abs(live32 - rep.per_round["cs"].astype(
        np.float32)).max())
    exact = dev_mc == 0.0 and dev_rp == 0.0
    emit("fig13/exact", max(dev_mc, dev_rp),
         f"{common};status={'PASS' if exact else 'FAIL'};"
         f"dev_vs_engine={dev_mc:g};dev_vs_replay={dev_rp:g};"
         f"trace={res.trace.header()['digest'][:8]}")

    # ---- 2. accuracy: live mean within MC sampling tolerance ------------
    pred = sweep_rounds([spec], _process(), N, rounds=ROUNDS,
                        trials=trials, k=K, seed=1, chunk=min(trials, 500))
    mc_mean = float(pred.mean_round("cs"))
    live_mean = res.mean
    # the live run is ONE trajectory: its mean over ROUNDS rounds has
    # stderr sd/sqrt(rounds_eff); persistent regimes correlate consecutive
    # rounds, shrinking the effective count by ~(1+p)/(1-p)
    rounds_eff = ROUNDS * (1 - PERSISTENCE) / (1 + PERSISTENCE)
    sd = float(res.per_round.std(ddof=1))
    tol = max(Z * sd / np.sqrt(max(rounds_eff, 1.0)), REL_FLOOR * mc_mean)
    rel_err = abs(live_mean - mc_mean) / mc_mean
    accurate = abs(live_mean - mc_mean) <= tol
    emit("fig13/accuracy", live_mean * 1e3,
         f"{common};trials={trials};status="
         f"{'PASS' if accurate else 'FAIL'};"
         f"live_mean={live_mean * 1e3:.4f}ms;mc_mean={mc_mean * 1e3:.4f}ms;"
         f"rel_err={rel_err:.4f};tol={tol / mc_mean:.4f}")

    # ---- 3. deadline: degradation accounting matches the engine ---------
    dl = float(np.quantile(res.per_round, 0.5))
    cfg_dl = RoundConfig(n=N, k=K, kind="cs", r=R, seed=SEED, deadline=dl,
                         deadline_policy="close_partial")
    res_dl = run_live(cfg_dl, _process(), ROUNDS, abort_on_close=False)
    eng_dl = sweep_rounds([spec], _process(), N, rounds=ROUNDS, trials=1,
                          k=K, seed=SEED, deadline=dl,
                          deadline_policy="close_partial",
                          record_trace=True)
    deg = eng_dl.degradation["cs"]
    t_ok = np.array_equal(res_dl.per_round.astype(np.float32),
                          eng_dl.per_round["cs"].astype(np.float32))
    k_ok = np.array_equal(res_dl.realized.astype(np.float64),
                          np.asarray(deg["realized_k"], np.float64))
    m_ok = np.array_equal(res_dl.missed.astype(np.float64),
                          np.asarray(deg["missed"], np.float64))
    dl_ok = t_ok and k_ok and m_ok
    emit("fig13/deadline", float(res_dl.missed.sum()),
         f"{common};deadline={dl:g};status={'PASS' if dl_ok else 'FAIL'};"
         f"times_exact={t_ok};realized_exact={k_ok};missed_exact={m_ok};"
         f"missed={int(res_dl.missed.sum())}/{ROUNDS};"
         f"mean_realized_k={res_dl.realized.mean():.2f}")

    if not exact:
        raise SystemExit(
            f"fig13: live run diverged from the engine (dev_vs_engine="
            f"{dev_mc:g}, dev_vs_replay={dev_rp:g}) — the live/simulator "
            f"contract is broken")
    if not accurate:
        raise SystemExit(
            f"fig13: live mean {live_mean:g} is outside the MC prediction "
            f"tolerance ({mc_mean:g} +- {tol:g})")
    if not dl_ok:
        raise SystemExit(
            "fig13: live deadline accounting diverged from the engine's "
            "degradation streams")


if __name__ == "__main__":
    run()

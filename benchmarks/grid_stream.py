"""Streaming grid-sweep throughput: ``stream_grid`` vs the naive
loop-of-``sweep`` baseline (the pre-grid workflow: one sweep call per grid
cell, each tracing and compiling its own executor).

The grid is the full feasible (family × load × message budget × comm_eps)
product at n = 16 — ≥64 cells sharing 4 shape buckets (one per load).
``stream_grid`` fuses the cells at each load into one multi-spec dispatch
over shared delay draws and pipelines the dispatches, so the whole grid
costs 4 compiles + 4 device passes; the naive loop pays one compile AND
one full sampling pass per cell.  The naive baseline is timed on a
stratified subset of the cells (with ``clear_cache()`` before each, the
seed-style retrace-per-cell behavior) — per-cell cost has no cross-cell
amortization there, so the subset rate extrapolates; the row records the
subset size.

Rows:
  grid/stream   full-grid streaming run: cells/s, shape buckets, compiles,
                fused dispatches
  grid/naive    loop-of-sweep baseline on the subset: cells/s
  grid/speedup  stream over naive cells-per-second ratio (gated in CI via
                the ``grid_cells_per_sec`` / ``grid_speedup_min`` baseline
                entries in benchmarks/regression_gate.py)

Exits non-zero if the streamed stats are not bit-exact with the per-cell
path under CRN, or if the grid retraced more than once per shape bucket.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import stream_grid, sweep
from repro.core.grid import GridSpec
from repro.core.montecarlo import cache_stats, clear_cache
from repro.core.delays import scenario1
from .common import emit


def _grid(trials: int) -> GridSpec:
    return GridSpec(n=16, families=("cs", "ss", "ra", "lb", "pc", "pcmm"),
                    loads=(2, 4, 8, 16), messages=(None, 2),
                    comm_eps=(0.0, 0.02), trials=trials, seed=0)


def run(trials: int = 20000, out: str = "bench_out"):
    model = scenario1()
    cells = _grid(trials).cells(model)

    # ---- streamed full grid (one compile per shape bucket) ----
    clear_cache()
    s0 = cache_stats()
    t0 = time.perf_counter()
    res = stream_grid(cells, pipeline=2)
    t_stream = time.perf_counter() - t0
    s1 = cache_stats()
    compiles = s1["exec"]["misses"] - s0["exec"]["misses"]
    traces = s1["traces"] - s0["traces"]
    cps_stream = len(cells) / t_stream
    emit("grid/stream", t_stream * 1e6,
         f"cells={len(cells)};trials={trials};"
         f"cells_per_sec={cps_stream:.2f};"
         f"buckets={res.meta['buckets']};compiles={compiles};"
         f"fused_dispatches={res.meta['fused_dispatches']}")
    if traces > res.meta["buckets"]:
        raise SystemExit(
            f"grid_stream: {traces} executor retraces for "
            f"{res.meta['buckets']} shape buckets — the bucketed cache is "
            f"not holding (one compile per bucket is the contract)")

    # ---- naive baseline: per-cell sweep, retrace per cell ----
    # stratified subset: first + last cell of every load group covers every
    # bucket and both ends of each fused spec stack
    by_load = {}
    for c in cells:
        by_load.setdefault(c.r_max, []).append(c)
    subset = [c for grp in by_load.values() for c in (grp[0], grp[-1])]
    t0 = time.perf_counter()
    naive = {}
    for c in subset:
        clear_cache()                  # the pre-grid per-cell retrace cost
        naive[c.name] = sweep(c.specs, c.model, c.n, trials=c.trials,
                              seed=c.seed, chunk=c.chunk, ks=c.ks)
    t_naive = time.perf_counter() - t0
    cps_naive = len(subset) / t_naive
    emit("grid/naive", t_naive * 1e6,
         f"cells={len(subset)};subset_of={len(cells)};trials={trials};"
         f"cells_per_sec={cps_naive:.2f}")

    # ---- CRN bit-exactness of the streamed stats vs the per-cell path ----
    exact = all(
        np.array_equal(res.cell(c.name)["means"][sp.name],
                       np.atleast_1d(naive[c.name].means[sp.name]))
        and np.array_equal(res.cell(c.name)["stderr"][sp.name],
                           np.atleast_1d(naive[c.name].stderr[sp.name]))
        for c in subset for sp in c.specs)
    speedup = cps_stream / cps_naive
    emit("grid/speedup", 0.0,
         f"stream_over_naive={speedup:.2f}x;"
         f"bitexact={'PASS' if exact else 'FAIL'}")
    if not exact:
        raise SystemExit(
            "grid_stream: streamed grid stats are NOT bit-exact with the "
            "per-cell sweep path under CRN — fusion changed the draws or "
            "the combine order")

    if out:
        os.makedirs(out, exist_ok=True)
        res.meta["cache"] = cache_stats()
        res.save(os.path.join(out, "GRID_result.json"))

    return {"cells": len(cells), "cells_per_sec": cps_stream,
            "naive_cells_per_sec": cps_naive, "speedup": speedup,
            "buckets": res.meta["buckets"], "compiles": compiles}


if __name__ == "__main__":
    run()

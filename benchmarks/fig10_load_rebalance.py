"""Fig. 10 (beyond the paper): adaptive load re-balancing vs row
re-permutation on a heterogeneous, persistent-straggler cluster.

The paper fixes one computation load r for every worker, and PR 2's
adaptive scheme can only re-*order* tasks (re-assign TO-matrix rows).
Egger et al. (arXiv:2304.08589) show that *reducing the load of slow
workers* beats merely re-ordering their tasks — so this benchmark pits
four policies at the SAME total computation budget n*r against each other
on the EC2-calibrated heterogeneous cluster (fig8's hardest cell), all
from ONE fused ``sweep_rounds`` call (every scheme scores the same cluster
realizations — paired common-random-number samples), with feedback
censored to what a real master observes:

  * ``cs`` / ``ss``   — the paper's static schedules at uniform load r;
  * ``adapt``         — feedback-driven row re-permutation of the CS
                        matrix (PR 2's greedy; loads stay uniform);
  * ``rebal``         — row re-permutation PLUS per-round load
                        re-balancing: a dense CS grid of width ``CAP``
                        with an initial budget of r slots per worker;
                        each round ``greedy_load_rebalance`` moves whole
                        slots from slow workers (down to 1) to fast ones
                        (up to CAP) from the censored delay estimates;
  * ``lb``            — the oracle lower bound (eq. 46) at uniform load r.

Rows:  fig10/<scheme> with ms/round; fig10/rebalance carries the margins
``rebal_vs_static`` (vs the better static schedule) and ``rebal_vs_perm``
(vs permutation-only adaptation) consumed by the CI regression gate.  The
run exits non-zero unless re-balancing beats static CS/SS *and* the
permutation-only adaptive scheme — the load-adaptation regression guard.
"""
from __future__ import annotations

from repro.core import (adaptive_spec, cyclic_to_matrix, ec2_cluster,
                        lb_spec, scenario1, staircase_to_matrix,
                        sweep_rounds, to_spec)
from .common import emit

N, R, K = 12, 3, 9
CAP = 6                  # per-worker load cap of the re-balancing grid
ROUNDS = 20
PERSISTENCE, SPREAD = 0.98, 3.0


def _process():
    return ec2_cluster(N, spread=SPREAD, p_slow=0.25,
                       persistence=PERSISTENCE, slow=8.0, base=scenario1(),
                       seed=1)


def run(trials: int = 20000):
    trials = min(trials, 4000)          # ROUNDS sims (+ rebalance greedy)
    cs = cyclic_to_matrix(N, R)
    specs = [to_spec("cs", cs), to_spec("ss", staircase_to_matrix(N, R)),
             adaptive_spec("adapt", cs),
             adaptive_spec("rebal", cyclic_to_matrix(N, CAP),
                           loads=(R,) * N, rebalance=True),
             lb_spec(R)]
    res = sweep_rounds(specs, _process(), N, rounds=ROUNDS, k=K,
                       trials=trials, seed=0, chunk=1000,
                       censored_feedback=True)
    ms = {sp.name: res.mean_round(sp.name) * 1e3 for sp in specs}
    static = min(ms["cs"], ms["ss"])
    vs_static = 100.0 * (static - ms["rebal"]) / static
    vs_perm = 100.0 * (ms["adapt"] - ms["rebal"]) / ms["adapt"]
    common = (f"trials={trials};rounds={ROUNDS};n={N};r={R};cap={CAP};"
              f"k={K};persistence={PERSISTENCE};spread={SPREAD:g}")
    for nm in ("cs", "ss", "adapt", "lb"):
        emit(f"fig10/{nm}", ms[nm] * 1e3, f"{common};ms_round={ms[nm]:.4f}ms")
    emit("fig10/rebalance", ms["rebal"] * 1e3,
         f"{common};ms_round={ms['rebal']:.4f}ms;"
         f"rebal_vs_static={vs_static:+.1f}%;"
         f"rebal_vs_perm={vs_perm:+.1f}%")
    ok = (ms["rebal"] < ms["cs"] and ms["rebal"] < ms["ss"]
          and ms["rebal"] < ms["adapt"])
    emit("fig10/rebalance_beats_all", 0.0,
         f"status={'PASS' if ok else 'FAIL'};"
         f"rebal={ms['rebal']:.4f}ms;adapt={ms['adapt']:.4f}ms;"
         f"cs={ms['cs']:.4f}ms;ss={ms['ss']:.4f}ms;lb={ms['lb']:.4f}ms")
    if not ok:
        raise SystemExit("fig10: adaptive load re-balancing failed to beat "
                         "static CS/SS and permutation-only adaptation on "
                         "the persistent heterogeneous cluster")
    return ms


if __name__ == "__main__":
    run()

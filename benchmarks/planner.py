"""Racing planner vs exhaustive grid: same argmin, a fraction of the
trial-evaluations.

Runs the quick 64-cell operating-point grid (the ``grid_stream`` bench
grid: n=16, all six families, loads x budgets x overheads) both ways:

* exhaustively through ``stream_grid`` (every cell at the full trial
  count), selecting the winner with ``GridResult.best_cell``;
* through the racing planner (``repro.core.planner.plan``): closed-form
  dominance pruning, then successive-halving with CRN paired-difference
  elimination on the resumable sweep.

Rows:
  planner/exhaustive  full-grid streaming run: cells, trial-evaluations,
                      the ``best_cell`` winner
  planner/race        the planner run: winner, trials spent, pruned/raced
                      counts, ``saved`` = exhaustive/spent
                      trial-evaluation ratio (gated in CI via the
                      ``planner_trials_saved_min`` baseline entry)
  planner/agreement   ``agree=1`` iff both paths name the same winner
                      and their winning means coincide within sampling
                      resolution

Exits non-zero if the planner's argmin differs from the exhaustive
grid's, or if the winner's raced mean drifts from the streamed cell's
beyond sampling noise (both paths share the same CRN draws; the planner
reads per-trial float64 samples while the grid combines float32 chunk
partials, so agreement is to stderr resolution, not bitwise).
"""
from __future__ import annotations

import math
import time

from repro.core import plan, stream_grid
from repro.core.delays import scenario1

from .common import emit
from .grid_stream import _grid

K = 16   # computation target for the winner report (= n: full gradient)


def run(trials: int = 20000, out: str = "bench_out"):
    model = scenario1()
    gs = _grid(trials)

    # ---- exhaustive reference: every cell at the full trial count ----
    cells = gs.cells(model)
    t0 = time.perf_counter()
    res = stream_grid(cells, pipeline=2)
    t_ex = time.perf_counter() - t0
    best = res.best_cell(k=K)
    emit("planner/exhaustive", t_ex * 1e6,
         f"cells={len(cells)};trials={trials};"
         f"trial_evals={len(cells) * trials};best={best['cell']};"
         f"best_mean={best['mean']:.6g};ties={len(best['ties'])}")

    # ---- racing planner on the same grid ----
    t0 = time.perf_counter()
    pr = plan(gs, model, k=K)
    t_plan = time.perf_counter() - t0
    emit("planner/race", t_plan * 1e6,
         f"winner={pr.winner};trials_spent={pr.trials_spent};"
         f"exhaustive_trials={pr.exhaustive_trials};"
         f"saved={pr.savings:.2f};"
         f"pruned={pr.meta['theory_pruned']};"
         f"raced={pr.meta['raced_points']};"
         f"rungs={len(pr.trajectory)};"
         f"lb_gap={pr.lb_gap:.4f}")

    # ---- agreement: same argmin, consistent winning mean ----
    agree = pr.winner == best["cell"]
    # both paths consumed identical CRN draws for the winner; the two
    # accumulation pipelines may differ by round-off, never by more than
    # a few stderr
    se = math.hypot(pr.predicted_stderr, best["stderr"])
    mean_ok = abs(pr.predicted_mean - best["mean"]) <= 5 * max(se, 1e-300)
    emit("planner/agreement", 0.0,
         f"agree={1 if agree and mean_ok else 0};"
         f"planner={pr.winner};exhaustive={best['cell']};"
         f"mean_gap={abs(pr.predicted_mean - best['mean']):.3g}")
    if not agree:
        raise SystemExit(
            f"planner: argmin disagreement — racing picked {pr.winner!r} "
            f"but the exhaustive grid's best_cell is {best['cell']!r} "
            f"(exhaustive ties: {[t['cell'] for t in best['ties']]})")
    if not mean_ok:
        raise SystemExit(
            f"planner: winning-mean drift — planner {pr.predicted_mean} vs "
            f"exhaustive {best['mean']} exceeds 5 x combined stderr {se}")

    return {"winner": pr.winner, "saved": pr.savings,
            "trials_spent": pr.trials_spent,
            "exhaustive_trials": pr.exhaustive_trials}


if __name__ == "__main__":
    run()

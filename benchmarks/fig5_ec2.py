"""Fig. 5: average completion time vs r with the EC2-calibrated delay model
(n = 15, d = 400, N = 900). This container has no EC2 cluster; per
DESIGN.md §8 the paper's own truncated-Gaussian calibration (validated by
the paper: "the truncated Gaussian model can reasonably capture the
statistical behaviour") stands in, with communication dominating
computation as in Fig. 3.

Claims validated: CS/SS >> PC/PCMM; PC worsens with r; SS faster than RA at
r = n (paper: 28.5% on their measured EC2 delays; the %-gain is delay-
calibration-dependent — our truncated-Gaussian stand-in yields ~9-19%
depending on scenario, with every ordering claim preserved — see
EXPERIMENTS.md); SS-LB gap small and shrinking with r.
"""

from repro.core import ec2_like
from .common import Timer, emit, scheme_means


def run(trials: int = 20000):
    n, k = 15, 15
    model = ec2_like(n, seed=1)
    rows = {}
    for r in (2, 3, 5, 7, 9, 11, 13, 15):
        with Timer() as t:
            m = scheme_means(model, n, r, k, trials=trials)
        emit(f"fig5/r{r}", t.us,
             ";".join(f"{s}={v * 1e3:.4f}ms" for s, v in m.items()))
        rows[r] = m
    gain = 100 * (rows[15]["ra"] - rows[15]["ss"]) / rows[15]["ra"]
    pc_grows = rows[13]["pc"] > rows[3]["pc"]
    gap_small = (rows[15]["ss"] - rows[15]["lb"]) / rows[15]["lb"] < 0.25
    gap_shrinks = ((rows[15]["ss"] - rows[15]["lb"]) / rows[15]["lb"] <
                   (rows[3]["ss"] - rows[3]["lb"]) / rows[3]["lb"])
    emit("fig5/claims", 0.0,
         f"ss_vs_ra_gain_pct={gain:.2f};pc_increases_with_r={pc_grows};"
         f"ss_lb_gap_small={gap_small};gap_shrinks_with_r={gap_shrinks}")
    return rows

"""CI benchmark-regression gate.

Compares the machine-readable ``BENCH_*.json`` results written by
``benchmarks.run --out`` against the checked-in baseline
(``benchmarks/baselines/bench_quick_baseline.json``):

* ``mc_engine`` — the fused engine's throughput (``mc_engine/fused``) must
  stay above ``--throughput-tol`` x the baseline.  The baseline is a
  deliberately conservative low-water mark: CI machines vary, so the gate
  exists to catch structural regressions (losing evaluator caching, a
  retrace per call, an accidental un-fusing) — order-of-magnitude events,
  not 10% jitter.
* ``fig8`` — the adaptive-vs-static margin on the persistent heterogeneous
  cell must stay positive and within ``--margin-drop`` percentage points of
  the baseline.  This is a *quality* gate on the scheduler, not a timing
  one, so it is machine-independent.
* ``fig10`` — the load-rebalancing-vs-permutation-only margin must stay
  within ``--rebal-drop`` percentage points of the baseline (same kind of
  machine-independent scheduler-quality gate, for the ragged-load layer).
* ``fig11`` — the adaptive-vs-static margin measured on the *recorded
  trace* (the record -> replay path) must stay within ``--trace-drop``
  percentage points of the baseline: the trace-driven evaluation pipeline
  keeps agreeing with the parametric one about how much adaptation pays.
* ``fig12`` — the adaptive-vs-static margin in time-per-realized-result
  under spot preemption with a round deadline (``close_partial``) must
  stay positive and within ``--fault-drop`` percentage points of the
  baseline: crash-aware scheduling keeps paying under failures.
* ``fig13`` — the live execution layer must keep agreeing with the
  simulator: the bit-exact legs (live vs engine record/replay evaluation,
  deadline degradation streams) must report PASS, and the live-vs-MC
  relative mean error must stay below ``fig13_live_rel_err_max`` (a
  sampling-noise bound — the live run is one realization — not a timing
  gate, so it is machine-independent).
* ``planner`` — the racing planner must keep agreeing with the exhaustive
  grid about the argmin operating point (``planner/agreement`` must report
  ``agree=1`` — a machine-independent correctness gate) while saving at
  least ``planner_trials_saved_min`` x in trial-evaluations (the
  structural win: losing theory pruning or paired elimination collapses
  the ratio toward 1).
* ``grid`` — the streaming grid-sweep engine (``repro.core.grid``) must
  keep its structural wins: cells-per-second above ``--grid-tol`` x the
  ``grid_cells_per_sec`` baseline (machine-dependent low-water mark, like
  the throughput gate), the stream-over-naive speedup at or above
  ``grid_speedup_min`` (the acceptance floor — losing executor bucketing
  or cell fusion collapses it), no more compiles than shape buckets, and
  the benchmark's own CRN bit-exactness leg reporting PASS.
* ``scaling`` (opt-in via ``--only``) — the device-sharded sweep's strong
  speedup (same trials, 1 device vs all local devices) from the
  ``mc_engine/scaling`` row must stay above ``--scaling-tol`` x the
  baseline, and the scaling fields (``trials_per_sec``,
  ``strong_speedup``, ``weak_efficiency``) must be present and finite.
  Run it only where the benchmark saw real parallelism (the multi-device
  CI leg with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
  like the throughput gate it is a structural guard — forced host
  devices on oversubscribed runners never hit the ideal 4x.

``--only`` selects which checks run (default: every check except
``scaling``).

Every metric the gate reads — and every numeric derived field in every
consumed ``BENCH_*.json`` — must be finite: a NaN or inf anywhere fails
the gate with an explicit message (a poisoned benchmark can otherwise
sail through a ``>=`` comparison).

Exit codes: 0 all checks pass, 1 regression detected, 2 missing inputs.

Usage (CI)::

    python -m benchmarks.run --quick --only mc_engine,grid,planner,fig8,fig10,fig11,fig12,fig13 --out bench_out
    python -m benchmarks.regression_gate --results bench_out
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "bench_quick_baseline.json")


def _load_bench(results_dir: str, bench: str) -> dict:
    path = os.path.join(results_dir, f"BENCH_{bench}.json")
    if not os.path.exists(path):
        print(f"regression_gate: missing {path} (run benchmarks.run "
              f"--only {bench} --out {results_dir} first)")
        sys.exit(2)
    with open(path) as f:
        return json.load(f)


def _row(payload: dict, name: str) -> dict:
    for row in payload.get("rows", []):
        if row.get("name") == name:
            return row
    print(f"regression_gate: BENCH_{payload.get('bench')}.json has no row "
          f"{name!r}")
    sys.exit(2)


def _check_finite(payload: dict) -> None:
    """A NaN/inf in any numeric derived field is an automatic failure: a
    poisoned metric must never pass a threshold comparison silently."""
    bad = [(row.get("name"), key, val)
           for row in payload.get("rows", [])
           for key, val in row.get("derived", {}).items()
           if isinstance(val, float) and not math.isfinite(val)]
    if bad:
        lines = "; ".join(f"{r}:{k}={v}" for r, k, v in bad)
        print(f"regression_gate: BENCH_{payload.get('bench')}.json carries "
              f"non-finite metric(s): {lines}")
        sys.exit(1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="bench_out",
                    help="directory holding BENCH_<name>.json files")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="checked-in baseline JSON")
    ap.add_argument("--throughput-tol", type=float, default=0.25,
                    help="fail if fused throughput < tol * baseline")
    ap.add_argument("--margin-drop", type=float, default=6.0,
                    help="max allowed drop (percentage points) of the fig8 "
                         "adaptive-vs-static margin vs baseline")
    ap.add_argument("--rebal-drop", type=float, default=2.0,
                    help="max allowed drop (percentage points) of the fig10 "
                         "rebalance-vs-permutation margin vs baseline")
    ap.add_argument("--trace-drop", type=float, default=6.0,
                    help="max allowed drop (percentage points) of the fig11 "
                         "trace-replay adaptive-vs-static margin vs "
                         "baseline")
    ap.add_argument("--fault-drop", type=float, default=5.0,
                    help="max allowed drop (percentage points) of the fig12 "
                         "adaptive-vs-static margin under preemption vs "
                         "baseline")
    ap.add_argument("--scaling-tol", type=float, default=0.75,
                    help="fail if the multi-device strong speedup < tol * "
                         "baseline (scaling check only)")
    ap.add_argument("--grid-tol", type=float, default=0.25,
                    help="fail if grid cells-per-second < tol * baseline")
    ap.add_argument("--live-tol", type=float, default=None,
                    help="max allowed live-vs-MC relative mean error for "
                         "the fig13 check (default: the baseline's "
                         "fig13_live_rel_err_max)")
    ap.add_argument("--only",
                    default="mc_engine,grid,planner,fig8,fig10,fig11,"
                            "fig12,fig13",
                    help="comma-separated subset of checks to run; add "
                         "'scaling' on the multi-device leg")
    args = ap.parse_args(argv)

    known = {"mc_engine", "grid", "planner", "fig8", "fig10", "fig11",
             "fig12", "fig13", "scaling"}
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    unknown = sorted(only - known)
    if unknown:
        print(f"regression_gate: unknown --only check(s) {unknown}; valid: "
              f"{sorted(known)}")
        sys.exit(2)

    if not os.path.exists(args.baseline):
        print(f"regression_gate: missing baseline {args.baseline}")
        sys.exit(2)
    with open(args.baseline) as f:
        base = json.load(f)

    failures = []

    # --- mc_engine throughput ------------------------------------------------
    if "mc_engine" in only:
        mc = _load_bench(args.results, "mc_engine")
        _check_finite(mc)
        thr = _row(mc, "mc_engine/fused")["derived"].get("throughput")
        if not isinstance(thr, (int, float)):
            print("regression_gate: mc_engine/fused row lacks a numeric "
                  "'throughput' derived field")
            sys.exit(2)
        floor = base["mc_engine_fused_throughput"] * args.throughput_tol
        ok = thr >= floor
        print(f"{'PASS' if ok else 'FAIL'} mc_engine fused throughput: "
              f"{thr:,.0f} trials*schemes/s (floor {floor:,.0f} = "
              f"{args.throughput_tol} x baseline "
              f"{base['mc_engine_fused_throughput']:,.0f})")
        if not ok:
            failures.append("mc_engine throughput")

    # --- streaming grid-sweep engine -----------------------------------------
    if "grid" in only:
        grid = _load_bench(args.results, "grid")
        _check_finite(grid)
        stream = _row(grid, "grid/stream")["derived"]
        spd = _row(grid, "grid/speedup")["derived"]
        cps = stream.get("cells_per_sec")
        if not isinstance(cps, (int, float)):
            print("regression_gate: grid/stream row lacks a numeric "
                  "'cells_per_sec' derived field")
            sys.exit(2)
        floor = base["grid_cells_per_sec"] * args.grid_tol
        speedup = spd.get("stream_over_naive")
        spd_floor = base["grid_speedup_min"]
        compiles, buckets = stream.get("compiles"), stream.get("buckets")
        ok = (cps >= floor
              and isinstance(speedup, (int, float))
              and speedup >= spd_floor
              and isinstance(compiles, (int, float))
              and isinstance(buckets, (int, float))
              and compiles <= buckets
              and spd.get("bitexact") == "PASS")
        print(f"{'PASS' if ok else 'FAIL'} grid streaming engine: "
              f"{cps:.2f} cells/s (floor {floor:.2f} = {args.grid_tol} x "
              f"baseline {base['grid_cells_per_sec']:.1f}), speedup "
              f"{speedup}x (floor {spd_floor}x), compiles={compiles} for "
              f"buckets={buckets}, bitexact={spd.get('bitexact')}")
        if not ok:
            failures.append("grid streaming engine")

    # --- racing planner vs exhaustive grid -----------------------------------
    if "planner" in only:
        pl = _load_bench(args.results, "planner")
        _check_finite(pl)
        race = _row(pl, "planner/race")["derived"]
        agreement = _row(pl, "planner/agreement")["derived"]
        saved = race.get("saved")
        if not isinstance(saved, (int, float)):
            print("regression_gate: planner/race row lacks a numeric "
                  "'saved' derived field")
            sys.exit(2)
        floor = base["planner_trials_saved_min"]
        agree = agreement.get("agree")
        ok = agree == 1 and saved >= floor
        print(f"{'PASS' if ok else 'FAIL'} planner racing: "
              f"agree={agree} (planner={agreement.get('planner')}, "
              f"exhaustive={agreement.get('exhaustive')}), trial-"
              f"evaluations saved {saved}x (floor {floor}x)")
        if not ok:
            failures.append("planner racing")

    # --- device-sharded scaling (multi-device leg only) ----------------------
    if "scaling" in only:
        mc = _load_bench(args.results, "mc_engine")
        _check_finite(mc)
        row = _row(mc, "mc_engine/scaling")["derived"]
        missing = [f for f in ("trials_per_sec", "strong_speedup",
                               "weak_efficiency", "devices")
                   if not isinstance(row.get(f), (int, float))]
        if missing:
            print(f"regression_gate: mc_engine/scaling row lacks numeric "
                  f"field(s) {missing} (was the benchmark run with > 1 "
                  f"device? set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count=4)")
            sys.exit(2)
        floor = base["mc_engine_strong_speedup"] * args.scaling_tol
        ok = row["strong_speedup"] >= floor
        print(f"{'PASS' if ok else 'FAIL'} mc_engine sharded strong speedup "
              f"({row['devices']:.0f} devices): {row['strong_speedup']:.2f}x "
              f"(floor {floor:.2f}x = {args.scaling_tol} x baseline "
              f"{base['mc_engine_strong_speedup']:.1f}x; weak efficiency "
              f"{row['weak_efficiency']:.2f}, "
              f"{row['trials_per_sec']:,.0f} trials/s)")
        if not ok:
            failures.append("sharded scaling")

    # --- fig8 adaptive-vs-static margin -------------------------------------
    if "fig8" in only:
        fig8 = _load_bench(args.results, "fig8")
        _check_finite(fig8)
        cell = base.get("fig8_cell", "fig8/p0.98_s3")
        margin = _row(fig8, cell)["derived"].get("adapt_vs_static")
        if not isinstance(margin, (int, float)):
            print(f"regression_gate: {cell} row lacks a numeric "
                  f"'adapt_vs_static' derived field")
            sys.exit(2)
        floor = max(base["fig8_adapt_vs_static"] - args.margin_drop, 0.0)
        ok = margin >= floor
        print(f"{'PASS' if ok else 'FAIL'} fig8 adaptive-vs-static margin "
              f"({cell}): {margin:+.1f}% (floor {floor:+.1f}% = baseline "
              f"{base['fig8_adapt_vs_static']:+.1f}% - {args.margin_drop})")
        if not ok:
            failures.append("fig8 adaptive margin")

    # --- fig10 rebalance-vs-permutation margin ------------------------------
    if "fig10" in only:
        fig10 = _load_bench(args.results, "fig10")
        _check_finite(fig10)
        margin = _row(fig10, "fig10/rebalance")["derived"].get(
            "rebal_vs_perm")
        if not isinstance(margin, (int, float)):
            print("regression_gate: fig10/rebalance row lacks a numeric "
                  "'rebal_vs_perm' derived field")
            sys.exit(2)
        floor = max(base["fig10_rebal_vs_perm"] - args.rebal_drop, 0.0)
        ok = margin >= floor
        print(f"{'PASS' if ok else 'FAIL'} fig10 rebalance-vs-permutation "
              f"margin: {margin:+.1f}% (floor {floor:+.1f}% = baseline "
              f"{base['fig10_rebal_vs_perm']:+.1f}% - {args.rebal_drop})")
        if not ok:
            failures.append("fig10 rebalance margin")

    # --- fig11 trace-replay adaptive margin ---------------------------------
    if "fig11" in only:
        fig11 = _load_bench(args.results, "fig11")
        _check_finite(fig11)
        margin = _row(fig11, "fig11/trace")["derived"].get("adapt_vs_static")
        if not isinstance(margin, (int, float)):
            print("regression_gate: fig11/trace row lacks a numeric "
                  "'adapt_vs_static' derived field")
            sys.exit(2)
        floor = max(base["fig11_trace_adapt_vs_static"] - args.trace_drop,
                    0.0)
        ok = margin >= floor
        print(f"{'PASS' if ok else 'FAIL'} fig11 trace-replay adaptive-vs-"
              f"static margin: {margin:+.1f}% (floor {floor:+.1f}% = "
              f"baseline {base['fig11_trace_adapt_vs_static']:+.1f}% - "
              f"{args.trace_drop})")
        if not ok:
            failures.append("fig11 trace margin")

    # --- fig12 fault-tolerance adaptive margin ------------------------------
    if "fig12" in only:
        fig12 = _load_bench(args.results, "fig12")
        _check_finite(fig12)
        margin = _row(fig12, "fig12/preemption")["derived"].get(
            "adapt_vs_static")
        if not isinstance(margin, (int, float)):
            print("regression_gate: fig12/preemption row lacks a numeric "
                  "'adapt_vs_static' derived field")
            sys.exit(2)
        floor = max(base["fig12_fault_margin"] - args.fault_drop, 0.0)
        ok = margin >= floor
        print(f"{'PASS' if ok else 'FAIL'} fig12 fault-tolerance adaptive-"
              f"vs-static margin (preemption, close_partial): "
              f"{margin:+.1f}% (floor {floor:+.1f}% = baseline "
              f"{base['fig12_fault_margin']:+.1f}% - {args.fault_drop})")
        if not ok:
            failures.append("fig12 fault margin")

    # --- fig13 live-vs-simulator agreement ----------------------------------
    if "fig13" in only:
        fig13 = _load_bench(args.results, "fig13")
        _check_finite(fig13)
        exact = _row(fig13, "fig13/exact")["derived"]
        dl = _row(fig13, "fig13/deadline")["derived"]
        acc = _row(fig13, "fig13/accuracy")["derived"]
        rel = acc.get("rel_err")
        if not isinstance(rel, (int, float)):
            print("regression_gate: fig13/accuracy row lacks a numeric "
                  "'rel_err' derived field")
            sys.exit(2)
        tol = (args.live_tol if args.live_tol is not None
               else base["fig13_live_rel_err_max"])
        bit_ok = (exact.get("status") == "PASS"
                  and dl.get("status") == "PASS")
        ok = bit_ok and rel <= tol
        print(f"{'PASS' if ok else 'FAIL'} fig13 live-vs-simulator: "
              f"exact={exact.get('status')} deadline={dl.get('status')} "
              f"rel_err={rel:.4f} (max {tol:g})")
        if not ok:
            failures.append("fig13 live agreement")

    if failures:
        print(f"regression_gate: FAILED checks: {failures}")
        sys.exit(1)
    print("regression_gate: all checks passed")


if __name__ == "__main__":
    main()

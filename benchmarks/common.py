"""Shared helpers for the paper-figure benchmarks.

Common random numbers
---------------------
``scheme_means`` and ``scheme_mean_table`` evaluate EVERY scheme at a grid
point through one fused engine call (``repro.core.sweep``): the delay
tensors are sampled once, with one PRNG subkey per trial, and every scheme
(CS/SS/RA/PC/PCMM/LB) is scored against the *same* draws.  Scheme
differences are therefore paired-sample estimates — the MC noise that is
common to two schemes cancels in their gap — and the same seed yields
identical paired samples under any trial chunking.  The seed code instead
re-sampled per scheme, so cross-scheme gaps carried independent noise.
"""
from __future__ import annotations

import re
import time

import numpy as np

from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, to_spec, lb_spec,
                        pc_spec, pcmm_spec, sweep)


def _grid_specs(n: int, r: int, *, seed: int, include_coded: bool,
                include_ra: bool) -> list:
    specs = [to_spec("cs", cyclic_to_matrix(n, r)),
             to_spec("ss", staircase_to_matrix(n, r))]
    if include_ra:
        specs.append(to_spec("ra", random_assignment_to_matrix(n, seed=seed)))
    if include_coded and r >= 2:
        specs.append(pc_spec(r))
        if n * r >= 2 * n - 1:
            specs.append(pcmm_spec(r))
    specs.append(lb_spec(r))
    return specs


def scheme_means(model, n: int, r: int, k: int, *, trials: int = 20000,
                 seed: int = 0, include_coded: bool = True,
                 include_ra: bool = True, chunk: int | None = None) -> dict:
    """Average completion time of every scheme at one (n, r, k) point, from
    ONE fused sweep over shared delay draws. Times are in the delay model's
    unit (seconds for the paper's models)."""
    specs = _grid_specs(n, r, seed=seed, include_coded=include_coded,
                        include_ra=include_ra)
    res = sweep(specs, model, n, trials=trials, seed=seed, chunk=chunk)
    # coded schemes always report their own decode thresholds (k ignored)
    return {spec.name: res.at_k(spec.name, k) for spec in specs}


def scheme_mean_table(model, n: int, r: int, *, trials: int = 20000,
                      seed: int = 0, include_coded: bool = False,
                      include_ra: bool = True,
                      chunk: int | None = None) -> dict:
    """Average completion time of every scheme for EVERY k in 1..n at once
    (one sort of the shared task arrivals — the whole Fig.-7 k-sweep is a
    single engine call).  Returns ``{scheme: (n,) per-k means}``; coded
    schemes keep their own fixed thresholds (``pc`` reported at
    ``2*ceil(n/r)-1``, ``pcmm`` at ``2n-1``) broadcast across k."""
    specs = _grid_specs(n, r, seed=seed, include_coded=include_coded,
                        include_ra=include_ra)
    res = sweep(specs, model, n, trials=trials, seed=seed, chunk=chunk)
    out = {}
    for spec in specs:
        if spec.name in res.fixed:     # coded: own threshold, constant in k
            out[spec.name] = np.full(n, res.at_k(spec.name))
        else:
            out[spec.name] = np.asarray(res.means[spec.name])
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


# ------------------- machine-readable result collection ----------------------
# ``emit`` keeps printing the established CSV rows AND records each row in a
# module-level buffer; ``benchmarks.run`` drains the buffer after each job
# into a BENCH_<name>.json artifact that the CI regression gate
# (``benchmarks.regression_gate``) and workflow-artifact uploads consume.

_ROWS: list[dict] = []

_LEADING_NUMBER = re.compile(
    r"\s*[-+]?\d[\d,]*(?:\.\d+)?(?:[eE][-+]?\d+)?")


def _parse_value(v: str):
    """Best-effort numeric parse of a derived field value: strips thousands
    separators and trailing unit suffixes (``0.123ms``, ``5.85x``, ``+8.1%``,
    ``1,234_trials_schemes_per_s``); non-numeric values stay strings."""
    m = _LEADING_NUMBER.match(v)
    if m and m.group(0).strip():
        try:
            return float(m.group(0).replace(",", ""))
        except ValueError:
            pass
    return v


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        key, sep, val = part.partition("=")
        if sep:
            out[key.strip()] = _parse_value(val)
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": float(us_per_call),
                  "derived": _parse_derived(derived),
                  "derived_raw": derived})


def drain_rows() -> list[dict]:
    """Return the rows emitted since the last drain and clear the buffer."""
    out = list(_ROWS)
    _ROWS.clear()
    return out

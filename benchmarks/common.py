"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, mean_completion_time,
                        simulate_lower_bound, simulate_pc_completion,
                        simulate_pcmm_completion)


def scheme_means(model, n: int, r: int, k: int, *, trials: int = 20000,
                 seed: int = 0, include_coded: bool = True,
                 include_ra: bool = True) -> dict:
    """Average completion time of every scheme at one (n, r, k) point.
    Times are in the delay model's unit (seconds for the paper's models)."""
    out = {}
    out["cs"] = mean_completion_time(cyclic_to_matrix(n, r), model, k,
                                     trials=trials, seed=seed)
    out["ss"] = mean_completion_time(staircase_to_matrix(n, r), model, k,
                                     trials=trials, seed=seed)
    if include_ra:
        out["ra"] = mean_completion_time(
            random_assignment_to_matrix(n, seed=seed), model, k,
            trials=trials, seed=seed)
    if include_coded and r >= 2:
        out["pc"] = float(np.mean(np.asarray(
            simulate_pc_completion(model, n, r, trials=trials, seed=seed))))
        if n * r >= 2 * n - 1:
            out["pcmm"] = float(np.mean(np.asarray(
                simulate_pcmm_completion(model, n, r, trials=trials,
                                         seed=seed))))
    out["lb"] = float(np.mean(np.asarray(
        simulate_lower_bound(model, n, r, k, trials=trials, seed=seed))))
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")

"""Table I end-to-end: one DGD iteration of the linear-regression scenario
per scheme, executed for real (data encoded, workers' h() computed, master
decodes where applicable) — verifying every scheme's parameter update
matches the exact full-gradient update it should equal at k = n, including
the PC/PCMM decode the paper footnotes away (we time it)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (pc_decode, pc_encode, pc_threshold,
                        pc_worker_compute, pcmm_decode, pcmm_encode,
                        pcmm_threshold, pcmm_worker_compute)
from repro.data import regression_dataset, regression_tasks
from repro.kernels.ops import batched_gram_matvec
from .common import Timer, emit


def run():
    N, d, n, r = 240, 60, 6, 2
    key = jax.random.PRNGKey(0)
    X, y, _ = regression_dataset(key, N, d)
    Xs, ys = regression_tasks(X, y, n)          # (n, b, d), (n, b)
    Xts = np.asarray(Xs).transpose(0, 2, 1)     # (n, d, b) column layout
    theta = np.random.default_rng(7).standard_normal(d) * 0.1
    eta = 0.01
    Xf = np.asarray(X, np.float64)
    grad_full = 2 / N * (Xf.T @ (Xf @ theta) - Xf.T @ np.asarray(y))
    want = theta - eta * grad_full
    Xty = Xf.T @ np.asarray(y)

    # --- uncoded CS (k = n) via the Pallas gram_matvec kernel -------------
    with Timer() as t:
        hs = np.asarray(batched_gram_matvec(jnp.asarray(Xts),
                                            jnp.asarray(theta, jnp.float32)))
        got = theta - eta * 2 / N * (hs.sum(0) - Xty)
    err = np.abs(got - want).max()
    emit("table1/cs_uncoded", t.us, f"update_err={err:.2e};ok={err < 1e-4}")

    # --- PC ----------------------------------------------------------------
    with Timer() as t:
        Xt, alphas, _ = pc_encode(Xts, r)
        res = np.stack([pc_worker_compute(Xt[i], theta) for i in range(n)])
        kth = pc_threshold(n, r)
        dec0 = time.perf_counter()
        xxtheta = pc_decode(res[:kth], alphas[:kth], n, r)
        dec_us = (time.perf_counter() - dec0) * 1e6
        got = theta - eta * 2 / N * (xxtheta - Xty)
    err = np.abs(got - want).max()
    emit("table1/pc", t.us,
         f"update_err={err:.2e};ok={err < 1e-4};decode_us={dec_us:.0f}")

    # --- PCMM ---------------------------------------------------------------
    with Timer() as t:
        Xh, betas = pcmm_encode(Xts, r)
        res, pts = [], []
        for i in range(n):
            for j in range(r):
                res.append(pcmm_worker_compute(Xh[i, j], theta))
                pts.append(betas[i, j])
        need = pcmm_threshold(n)
        dec0 = time.perf_counter()
        xxtheta = pcmm_decode(np.stack(res)[:need], np.array(pts)[:need], n)
        dec_us = (time.perf_counter() - dec0) * 1e6
        got = theta - eta * 2 / N * (xxtheta - Xty)
    err = np.abs(got - want).max()
    emit("table1/pcmm", t.us,
         f"update_err={err:.2e};ok={err < 1e-2};decode_us={dec_us:.0f}")

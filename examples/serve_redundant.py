"""Beyond-paper example: the first-k-distinct selection rule applied to
SERVING — redundant speculative dispatch of decode requests.

A batch of requests is replicated r times across n model replicas using a
CS/SS TO matrix; each replica serves its assigned requests sequentially;
a request completes when its FIRST copy finishes. This is exactly the
paper's completion-time machinery with tasks = requests, applied to
inference tail-latency (the paper's eq. 6 with k = n).

Simulates replica latency with the bimodal straggler model and reports
p50/p99 latency for scheduled-redundant vs single-assignment dispatch,
then actually decodes the winning requests with a tiny LM to show the
plumbing end-to-end.

Run:  PYTHONPATH=src python examples/serve_redundant.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BimodalStragglerDelays, RoundConfig, scenario1,
                        slot_arrival_times, task_arrival_times)
from repro.models import ModelConfig, init_cache
from repro.train import init_train_state, make_serve_step
from repro.optim import sgd


def dispatch_matrix(n: int, r: int) -> np.ndarray:
    """Redundant dispatch as one canonical ``RoundConfig`` round: tasks =
    requests, k = n (every request must finish), redundancy = load r.
    The same document drives the simulator, the trainer, and the live
    master — serving rides the unified API rather than its own plan."""
    return RoundConfig(n=n, k=n, kind="cs", r=r).to_matrix()


def tail_latency(C, model, trials=4000, seed=0):
    n, r = C.shape
    T1, T2 = model.sample(jax.random.PRNGKey(seed), trials, n, r)
    s = slot_arrival_times(T1, T2)
    tau = np.asarray(task_arrival_times(jnp.asarray(C), s, n))  # per-request
    return np.percentile(tau, 50), np.percentile(tau, 99)


def main():
    n = 16
    model = BimodalStragglerDelays(base=scenario1(), p_straggle=0.25,
                                   slow=10.0)
    single = dispatch_matrix(n, 1)           # each request served once
    for r in (1, 2, 3):
        C = dispatch_matrix(n, r)
        p50, p99 = tail_latency(C, model)
        print(f"r={r}: request p50={p50 * 1e3:.3f} ms   "
              f"p99={p99 * 1e3:.3f} ms")
    p50_1, p99_1 = tail_latency(single, model)
    p50_2, p99_2 = tail_latency(dispatch_matrix(n, 2), model)
    print(f"\nredundancy r=2 cuts p99 by "
          f"{100 * (p99_1 - p99_2) / p99_1:.1f}% "
          f"(p50 by {100 * (p50_1 - p50_2) / p50_1:.1f}%)")

    # end-to-end: decode the 16 requests with a tiny LM
    cfg = ModelConfig(name="tiny-serve", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, param_dtype="float32",
                      dtype="float32", remat=False)
    state = init_train_state(jax.random.PRNGKey(0), cfg, sgd(0.0))
    serve = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, n, 32)
    tok = jnp.zeros((n, 1), jnp.int32)
    for _ in range(8):
        tok, cache = serve(state.params, cache, tok)
    print(f"decoded final tokens for {n} requests:",
          np.asarray(tok).ravel()[:8], "...")


if __name__ == "__main__":
    main()

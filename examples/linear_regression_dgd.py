"""End-to-end reproduction of the paper's Section VI scenario: distributed
linear regression with DGD under straggler scheduling.

Runs the full loop for CS / SS / RA / PC / PCMM with the EC2-calibrated
truncated-Gaussian delay model: every scheme really computes h(X_i) =
X_i X_i^T theta (the Pallas gram_matvec kernel for the uncoded schemes),
the coded schemes really encode/decode, the master applies eq. (61)/(49),
and the virtual clock advances by each round's completion time. Reports
final loss and total virtual wall-clock.

Run:  PYTHONPATH=src python examples/linear_regression_dgd.py [--iters 100]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import regression_config
from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, ec2_like,
                        slot_arrival_times, first_k_distinct_mask,
                        pc_encode, pc_worker_compute, pc_decode,
                        pc_threshold, pcmm_encode, pcmm_worker_compute,
                        pcmm_decode, pcmm_threshold)
from repro.data import regression_dataset, regression_tasks
from repro.kernels.ops import batched_gram_matvec


def loss_of(theta, X, y):
    res = X @ theta - y
    return float(res @ res) / X.shape[0]


def run_uncoded(C, Xs_cols, Xty_parts, N, model, k, iters, lr, seed=0):
    """The paper's uncoded DGD loop (Table I, CS/SS/RA rows)."""
    n, r = C.shape
    d = Xs_cols.shape[1]
    theta = np.zeros(d, np.float32)
    key = jax.random.PRNGKey(seed)
    clock = 0.0
    for _ in range(iters):
        key, kd = jax.random.split(key)
        T1, T2 = model.sample(kd, 1, n, r)
        s = slot_arrival_times(T1, T2)[0]
        w, t_done = first_k_distinct_mask(jnp.asarray(C), s, n, k)
        clock += float(t_done)
        # workers: sequential h(X_i) evaluations (Pallas kernel)
        hs = np.asarray(batched_gram_matvec(Xs_cols, jnp.asarray(theta)))
        # master: eq. (61) over the k winning distinct tasks
        wmask = np.asarray(w) > 0
        sel = sorted({int(C[i, j]) for i in range(n) for j in range(r)
                      if wmask[i, j]})
        assert len(sel) == k
        grad = 2 * n / (k * N) * sum(hs[p] - Xty_parts[p] for p in sel)
        theta = theta - lr * grad
    return theta, clock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=100)
    args = ap.parse_args()
    rc = regression_config()
    n, r, k, lr = rc.n, rc.r, rc.k, rc.lr
    key = jax.random.PRNGKey(0)
    X, y, _ = regression_dataset(key, rc.N, rc.d)
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    Xs, ys = regression_tasks(jnp.asarray(X), jnp.asarray(y), n)
    Xs_cols = jnp.asarray(np.asarray(Xs).transpose(0, 2, 1))  # (n, d, b)
    Xty_parts = np.stack([np.asarray(Xs[i]).T @ np.asarray(ys[i])
                          for i in range(n)])
    Xty = Xty_parts.sum(0)
    N = n * Xs.shape[1]
    model = ec2_like(n, seed=1)
    print(f"paper scenario: N={rc.N} d={rc.d} n={n} r={r} k={k} "
          f"iters={args.iters}")
    print(f"{'scheme':8s} {'final loss':>12s} {'virtual time':>14s}")

    for name, C in (("CS", cyclic_to_matrix(n, r)),
                    ("SS", staircase_to_matrix(n, r)),
                    ("RA", random_assignment_to_matrix(n, seed=0))):
        theta, clock = run_uncoded(C, Xs_cols, Xty_parts, N, model, k,
                                   args.iters, lr)
        print(f"{name:8s} {loss_of(theta, X, y):12.5f} "
              f"{clock * 1e3:11.3f} ms")

    # --- PC: one coded message per worker, threshold 2*ceil(n/r)-1 --------
    theta = np.zeros(rc.d, np.float32)
    Xt, alphas, _ = pc_encode(np.asarray(Xs_cols, np.float64), r)
    clock = 0.0
    keyp = jax.random.PRNGKey(7)
    for _ in range(args.iters):
        keyp, kd = jax.random.split(keyp)
        T1, T2 = model.sample(kd, 1, n, r)
        t_w = np.asarray(T1.sum(-1) + T2[..., -1])[0]
        kth = pc_threshold(n, r)
        order = np.argsort(t_w)[:kth]
        clock += float(np.sort(t_w)[kth - 1])
        res = np.stack([pc_worker_compute(Xt[i], theta) for i in order])
        xxt = pc_decode(res, alphas[order], n, r)
        theta = theta - lr * 2 / N * (xxt - Xty)
    print(f"{'PC':8s} {loss_of(theta, X, y):12.5f} {clock * 1e3:11.3f} ms")

    # --- PCMM: sequential coded messages, threshold 2n-1 ------------------
    theta = np.zeros(rc.d, np.float32)
    Xh, betas = pcmm_encode(np.asarray(Xs_cols, np.float64), r)
    clock = 0.0
    keyp = jax.random.PRNGKey(9)
    for _ in range(args.iters):
        keyp, kd = jax.random.split(keyp)
        T1, T2 = model.sample(kd, 1, n, r)
        s = np.asarray(slot_arrival_times(T1, T2))[0].reshape(-1)
        need = pcmm_threshold(n)
        order = np.argsort(s)[:need]
        clock += float(np.sort(s)[need - 1])
        res = np.stack([pcmm_worker_compute(
            Xh[o // r, o % r], theta) for o in order])
        pts = np.array([betas[o // r, o % r] for o in order])
        xxt = pcmm_decode(res, pts, n)
        theta = theta - lr * 2 / N * (xxt - Xty)
    print(f"{'PCMM':8s} {loss_of(theta, X, y):12.5f} {clock * 1e3:11.3f} ms")


if __name__ == "__main__":
    main()

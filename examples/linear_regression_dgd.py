"""End-to-end reproduction of the paper's Section VI scenario: distributed
linear regression with DGD under straggler scheduling.

Runs the full loop for CS / SS / RA / adaptive / PC / PCMM on a round-aware
virtual cluster: every scheme really computes h(X_i) = X_i X_i^T theta (the
Pallas gram_matvec kernel for the uncoded schemes), the coded schemes
really encode/decode, the master applies eq. (61)/(49), and the virtual
clock advances by each round's completion time.  The uncoded schemes run
through ``StragglerAggregator``'s round API, so with ``--cluster markov``
the same loop exercises heterogeneous, persistent stragglers and the
feedback-driven adaptive schedule.  Emits per-scheme loss-vs-wall-clock
curve rows (``curve,<scheme>,<iter>,<wallclock_ms>,<loss>``) plus the
final table.

Run:  PYTHONPATH=src python examples/linear_regression_dgd.py
          [--iters 100] [--cluster markov --persistence 0.95 --spread 3]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import regression_config
from repro.core import (RoundSpec, StragglerAggregator, as_process,
                        ec2_cluster, ec2_like, slot_arrival_times,
                        pc_encode, pc_worker_compute, pc_decode,
                        pc_threshold, pcmm_encode, pcmm_worker_compute,
                        pcmm_decode, pcmm_threshold)
from repro.data import regression_dataset, regression_tasks
from repro.kernels.ops import batched_gram_matvec


def loss_of(theta, X, y):
    res = X @ theta - y
    return float(res @ res) / X.shape[0]


def run_uncoded(spec, process, Xs_cols, Xty_parts, N, X, y, iters, lr, *,
                adaptive=False, curve_every=10, label="?", seed=0):
    """The paper's uncoded DGD loop (Table I rows) through the round API:
    the aggregator holds the cluster's straggler state across iterations
    and (optionally) re-permutes the schedule rows from delay feedback."""
    n, r = spec.n, spec.r
    d = Xs_cols.shape[1]
    theta = np.zeros(d, np.float32)
    agg = StragglerAggregator(spec, process, adaptive=adaptive)
    key = jax.random.PRNGKey(seed)
    clock, curve = 0.0, []
    for it in range(iters):
        key, kd = jax.random.split(key)
        C = agg.current_matrix()
        w, t_done = agg.round_mask(kd)
        clock += float(t_done)
        # workers: sequential h(X_i) evaluations (Pallas kernel)
        hs = np.asarray(batched_gram_matvec(Xs_cols, jnp.asarray(theta)))
        # master: eq. (61) over the k winning distinct tasks
        wmask = np.asarray(w) > 0
        sel = sorted({int(C[i, j]) for i in range(n) for j in range(r)
                      if wmask[i, j]})
        assert len(sel) == spec.k
        grad = 2 * n / (spec.k * N) * sum(hs[p] - Xty_parts[p] for p in sel)
        theta = theta - lr * grad
        if it % curve_every == 0 or it == iters - 1:
            curve.append((it, clock, loss_of(theta, X, y)))
    for it, c, l in curve:
        print(f"curve,{label},{it},{c * 1e3:.4f},{l:.5f}")
    return theta, clock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--cluster", default="iid", choices=("iid", "markov"))
    ap.add_argument("--persistence", type=float, default=0.95)
    ap.add_argument("--spread", type=float, default=3.0)
    args = ap.parse_args()
    rc = regression_config()
    n, r, k, lr = rc.n, rc.r, rc.k, rc.lr
    key = jax.random.PRNGKey(0)
    X, y, _ = regression_dataset(key, rc.N, rc.d)
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    Xs, ys = regression_tasks(jnp.asarray(X), jnp.asarray(y), n)
    Xs_cols = jnp.asarray(np.asarray(Xs).transpose(0, 2, 1))  # (n, d, b)
    Xty_parts = np.stack([np.asarray(Xs[i]).T @ np.asarray(ys[i])
                          for i in range(n)])
    Xty = Xty_parts.sum(0)
    N = n * Xs.shape[1]
    if args.cluster == "markov":
        process = ec2_cluster(n, spread=args.spread, p_slow=0.25,
                              persistence=args.persistence, slow=8.0,
                              base=ec2_like(n, seed=1), seed=1)
    else:
        process = as_process(ec2_like(n, seed=1))
    print(f"paper scenario: N={rc.N} d={rc.d} n={n} r={r} k={k} "
          f"iters={args.iters} cluster={args.cluster}")
    print(f"{'scheme':8s} {'final loss':>12s} {'virtual time':>14s}")

    rows = []
    for name, sched, adaptive in (("CS", "cs", False), ("SS", "ss", False),
                                  ("RA", "ra", False),
                                  ("ADAPT", "cs", True)):
        spec = RoundSpec(n=n, r=n if sched == "ra" else r, k=k,
                         schedule=sched)
        theta, clock = run_uncoded(spec, process, Xs_cols, Xty_parts, N,
                                   X, y, args.iters, lr, adaptive=adaptive,
                                   label=name)
        rows.append((name, loss_of(theta, X, y), clock))

    # --- PC: one coded message per worker, threshold 2*ceil(n/r)-1 --------
    # Coded baselines advance the SAME kind of round-aware process (fresh
    # state, own key stream): T1/T2 realizations persist across rounds.
    theta = np.zeros(rc.d, np.float32)
    Xt, alphas, _ = pc_encode(np.asarray(Xs_cols, np.float64), r)
    clock = 0.0
    keyp = jax.random.PRNGKey(7)
    pstate = process.init(jax.random.PRNGKey(70)[None], n)
    for _ in range(args.iters):
        keyp, kd = jax.random.split(keyp)
        pstate, T1, T2 = process.step(pstate, kd[None], n, r)
        t_w = np.asarray(T1.sum(-1) + T2[..., -1])[0]
        kth = pc_threshold(n, r)
        order = np.argsort(t_w)[:kth]
        clock += float(np.sort(t_w)[kth - 1])
        res = np.stack([pc_worker_compute(Xt[i], theta) for i in order])
        xxt = pc_decode(res, alphas[order], n, r)
        theta = theta - lr * 2 / N * (xxt - Xty)
    rows.append(("PC", loss_of(theta, X, y), clock))

    # --- PCMM: sequential coded messages, threshold 2n-1 ------------------
    theta = np.zeros(rc.d, np.float32)
    Xh, betas = pcmm_encode(np.asarray(Xs_cols, np.float64), r)
    clock = 0.0
    keyp = jax.random.PRNGKey(9)
    pstate = process.init(jax.random.PRNGKey(90)[None], n)
    for _ in range(args.iters):
        keyp, kd = jax.random.split(keyp)
        pstate, T1, T2 = process.step(pstate, kd[None], n, r)
        s = np.asarray(slot_arrival_times(T1, T2))[0].reshape(-1)
        need = pcmm_threshold(n)
        order = np.argsort(s)[:need]
        clock += float(np.sort(s)[need - 1])
        res = np.stack([pcmm_worker_compute(
            Xh[o // r, o % r], theta) for o in order])
        pts = np.array([betas[o // r, o % r] for o in order])
        xxt = pcmm_decode(res, pts, n)
        theta = theta - lr * 2 / N * (xxt - Xty)
    rows.append(("PCMM", loss_of(theta, X, y), clock))

    for name, loss, clock in rows:
        print(f"{name:8s} {loss:12.5f} {clock * 1e3:11.3f} ms")


if __name__ == "__main__":
    main()

"""Running a live cluster: real async master-worker rounds end-to-end.

Builds one canonical ``RoundConfig``, round-trips it through JSON (the
same document ``python -m repro.launch.train --config`` and the live
master/worker handshake ship), then:

1. runs a 4-worker in-process live cluster to ``k`` distinct results per
   round (``run_live``);
2. shows the run's recorded delay trace replaying BIT-EXACTLY through the
   Monte Carlo engine (``sweep_rounds`` over ``TraceProcess``) — the live
   layer and the simulator are the same arithmetic;
3. re-runs with a deadline under ``close_partial`` to show partial rounds
   and miss accounting;
4. demonstrates the same run over the TCP transport (ephemeral port).

Run:  PYTHONPATH=src python examples/live_cluster.py
"""
import numpy as np

from repro.core import (RoundConfig, TraceProcess, ec2_cluster,
                        sweep_rounds)
from repro.live import run_live

ROUNDS = 8


def main():
    cfg = RoundConfig(n=4, k=3, kind="cs", r=2, seed=42)
    cfg = RoundConfig.from_json(cfg.to_json())       # JSON round-trip
    print(f"config: {cfg.kind} n={cfg.n} k={cfg.k} r={cfg.width} "
          f"seed={cfg.seed}")

    process = ec2_cluster(cfg.n, spread=3.0, persistence=0.9, seed=1)

    # 1. live in-process cluster ------------------------------------------
    res = run_live(cfg, process, ROUNDS)
    print(f"\nlive:   mean={res.mean:.5f}  per_round[:4]="
          f"{np.round(res.per_round[:4], 5)}")

    # 2. the recorded trace replays bit-exactly through the MC engine -----
    spec = cfg.to_scheme_spec("live")
    replay = sweep_rounds([spec], TraceProcess(res.trace), cfg.n,
                          rounds=ROUNDS, trials=1, k=cfg.k, seed=cfg.seed)
    rp = replay.per_round["live"]
    assert np.array_equal(res.per_round.astype(np.float32),
                          rp.astype(np.float32)), "replay mismatch"
    print(f"replay: mean={float(rp.mean()):.5f}  (bit-exact: True)")

    # ... and matches the engine run on the process directly (same seed)
    direct = sweep_rounds([spec], process, cfg.n, rounds=ROUNDS, trials=1,
                          k=cfg.k, seed=cfg.seed)
    print(f"MC:     mean={float(direct.per_round['live'].mean()):.5f}  "
          f"(same shared-seed realization)")

    # 3. deadline rounds: close partial, count misses ---------------------
    dl = float(np.quantile(res.per_round, 0.5))
    cfg_dl = RoundConfig(n=4, k=3, kind="cs", r=2, seed=42, deadline=dl,
                         deadline_policy="close_partial")
    res_dl = run_live(cfg_dl, process, ROUNDS)
    print(f"\ndeadline={dl:.5f} close_partial: "
          f"missed {int(res_dl.missed.sum())}/{ROUNDS} rounds, "
          f"mean realized k = {res_dl.realized.mean():.2f} "
          f"(target {cfg_dl.k})")

    # 4. the same run over TCP (ephemeral port) ---------------------------
    res_tcp = run_live(cfg, process, ROUNDS, address="tcp://127.0.0.1:0")
    assert np.array_equal(res_tcp.per_round, res.per_round)
    print(f"\ntcp:    mean={res_tcp.mean:.5f}  (identical to inproc: True)")


if __name__ == "__main__":
    main()

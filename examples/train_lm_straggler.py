"""Train a ~100M-parameter LM for a few hundred straggler-scheduled SGD
rounds, comparing the *loss-vs-wall-clock* curves of CS / SS / RA and the
feedback-driven adaptive schedule (the estimator eq. 61 is schedule-
independent in expectation, so schedules separate on the wall-clock axis,
not the loss-per-step axis).

Every schedule sees the SAME virtual cluster realization (common random
numbers): a round-aware ``DelayProcess`` whose per-worker straggler state
persists across rounds (``--cluster markov|ar1``; ``--cluster iid``
reproduces the old stateless behavior).

~100M params: 12L, d_model=768, 12H (kv=4), d_ff=3072, vocab=32768
(~0.1B with embeddings). Data: synthetic bigram chain (learnable).

Run:  PYTHONPATH=src python examples/train_lm_straggler.py \
          [--steps 300] [--schedules ss,cs,ra,adaptive] [--n 8 --r 2 --k 6] \
          [--cluster markov --persistence 0.95 --spread 3]

Emits ``curve,<sched>,<step>,<wallclock_ms>,<loss>`` rows (the
loss-vs-wall-clock curve per schedule) plus a final summary table.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AR1Process, AdaptiveScheduler, BimodalStragglerDelays,
                        RoundSpec, ec2_cluster, heterogeneous_scales,
                        scenario1)
from repro.data import TaskPartition, lm_task_batches
from repro.models import ModelConfig, num_params
from repro.optim import adamw, cosine_schedule
from repro.train import init_train_state, make_straggler_train_step
from repro.ckpt import save_checkpoint


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", arch_type="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768,
        param_dtype="float32", dtype="float32", remat=False,
        max_seq_len=2048)


def build_cluster(args):
    """``--straggle`` layers i.i.d. bimodal slowdowns on the base delays in
    every cluster mode (matching repro.launch.train's semantics)."""
    base = (BimodalStragglerDelays(p_straggle=0.3, slow=8.0)
            if args.straggle else scenario1())
    if args.cluster == "iid":
        return base
    if args.cluster == "markov":
        return ec2_cluster(args.n, spread=args.spread, p_slow=0.25,
                           persistence=args.persistence, slow=8.0,
                           base=base, seed=1)
    return AR1Process(base=base,
                      worker_scale=heterogeneous_scales(args.n, args.spread,
                                                        seed=1),
                      rho=args.persistence, sigma=0.4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--schedules", default="ss,cs,ra")
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--straggle", action="store_true",
                    help="layer i.i.d. bimodal slowdowns on the base "
                         "delays (all cluster modes)")
    ap.add_argument("--cluster", default="iid",
                    choices=("iid", "markov", "ar1"))
    ap.add_argument("--persistence", type=float, default=0.95)
    ap.add_argument("--spread", type=float, default=3.0)
    ap.add_argument("--curve-every", type=int, default=0,
                    help="emit a curve row every N steps (0: steps//20)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = lm_100m()
    delay = build_cluster(args)
    part = TaskPartition(n=args.n, global_batch=args.batch,
                         seq_len=args.seq, vocab=cfg.vocab_size,
                         source="bigram")
    every = args.curve_every or max(args.steps // 20, 1)
    results = {}
    schedules = args.schedules.split(",")
    for sched in schedules:
        adaptive = sched == "adaptive"
        base = "cs" if adaptive else sched
        r = args.n if base == "ra" else args.r
        spec = RoundSpec(n=args.n, r=r, k=args.k, schedule=base)
        opt = adamw(cosine_schedule(3e-4, args.steps, warmup=20),
                    weight_decay=0.01)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        if sched == schedules[0]:
            print(f"model params: {num_params(state.params):,}")
        step = jax.jit(make_straggler_train_step(cfg, opt, spec, delay))
        base_C = spec.to_matrix()
        scheduler = AdaptiveScheduler(base_C) if adaptive else None
        cluster = None
        losses, vclock, curve = [], 0.0, []
        t0 = time.time()
        for i in range(args.steps):
            C = base_C if scheduler is None else scheduler.matrix()
            row = (None if scheduler is None
                   else jnp.asarray(scheduler.row_of_worker()))
            toks, labs = lm_task_batches(part, C, i)
            # same PRNG stream for every schedule -> same cluster realization
            state, m, cluster = step(state, toks, labs,
                                     jax.random.PRNGKey(1000 + i),
                                     cluster, row)
            if scheduler is not None:
                scheduler.observe(np.asarray(m["worker_t1"]))
            losses.append(float(m["loss"]))
            vclock += float(m["completion_time"])
            if i % every == 0 or i == args.steps - 1:
                curve.append((i, vclock, losses[-1]))
            if i % max(args.steps // 10, 1) == 0:
                print(f"  [{sched}] step {i:4d} loss {losses[-1]:.4f} "
                      f"vclock {vclock * 1e3:.2f} ms")
        results[sched] = (np.mean(losses[-20:]), vclock, time.time() - t0)
        for i, vc, l in curve:
            print(f"curve,{sched},{i},{vc * 1e3:.4f},{l:.4f}")
        if args.ckpt:
            save_checkpoint(f"{args.ckpt}-{sched}", state, step=args.steps)

    print(f"\n{'sched':9s} {'final loss':>11s} {'virtual time':>13s} "
          f"{'wall time':>10s}")
    for sched, (l, vc, wt) in results.items():
        print(f"{sched:9s} {l:11.4f} {vc * 1e3:10.2f} ms {wt:9.1f} s")
    if "ss" in results and "ra" in results:
        gain = 100 * (results["ra"][1] - results["ss"][1]) / results["ra"][1]
        print(f"\nSS vs RA virtual-completion-time reduction: {gain:.1f}% "
              f"(paper Fig. 5: ~28.5% at r=n; here r={args.r})")
    if "adaptive" in results and "cs" in results:
        gain = 100 * (results["cs"][1] - results["adaptive"][1]) \
            / results["cs"][1]
        print(f"adaptive vs CS wall-clock reduction: {gain:.1f}%")


if __name__ == "__main__":
    main()

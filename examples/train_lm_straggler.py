"""Train a ~100M-parameter LM for a few hundred straggler-scheduled SGD
rounds, comparing CS / SS / RA schedules' *virtual completion time* while
verifying losses track each other (the estimator eq. 61 is schedule-
independent in expectation).

~100M params: 12L, d_model=768, 12H (kv=4), d_ff=3072, vocab=32768
(~0.1B with embeddings). Data: synthetic bigram chain (learnable).

Run:  PYTHONPATH=src python examples/train_lm_straggler.py \
          [--steps 300] [--schedules ss,cs,ra] [--n 8 --r 2 --k 6]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import RoundSpec, BimodalStragglerDelays, scenario1
from repro.data import TaskPartition, lm_task_batches
from repro.models import ModelConfig, num_params
from repro.optim import adamw, cosine_schedule
from repro.train import init_train_state, make_straggler_train_step
from repro.ckpt import save_checkpoint


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", arch_type="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768,
        param_dtype="float32", dtype="float32", remat=False,
        max_seq_len=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--schedules", default="ss,cs,ra")
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--straggle", action="store_true",
                    help="bimodal persistent-straggler delays")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = lm_100m()
    model = (BimodalStragglerDelays(p_straggle=0.3, slow=8.0)
             if args.straggle else scenario1())
    part = TaskPartition(n=args.n, global_batch=args.batch,
                         seq_len=args.seq, vocab=cfg.vocab_size,
                         source="bigram")
    results = {}
    for sched in args.schedules.split(","):
        r = args.n if sched == "ra" else args.r
        spec = RoundSpec(n=args.n, r=r, k=args.k, schedule=sched)
        opt = adamw(cosine_schedule(3e-4, args.steps, warmup=20),
                    weight_decay=0.01)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        if sched == args.schedules.split(",")[0]:
            print(f"model params: {num_params(state.params):,}")
        step = jax.jit(make_straggler_train_step(cfg, opt, spec, model))
        C = spec.to_matrix()
        losses, vclock = [], 0.0
        t0 = time.time()
        for i in range(args.steps):
            toks, labs = lm_task_batches(part, C, i)
            state, m = step(state, toks, labs, jax.random.PRNGKey(1000 + i))
            losses.append(float(m["loss"]))
            vclock += float(m["completion_time"])
            if i % max(args.steps // 10, 1) == 0:
                print(f"  [{sched}] step {i:4d} loss {losses[-1]:.4f} "
                      f"vclock {vclock * 1e3:.2f} ms")
        results[sched] = (np.mean(losses[-20:]), vclock, time.time() - t0)
        if args.ckpt:
            save_checkpoint(f"{args.ckpt}-{sched}", state, step=args.steps)

    print(f"\n{'sched':6s} {'final loss':>11s} {'virtual time':>13s} "
          f"{'wall time':>10s}")
    for sched, (l, vc, wt) in results.items():
        print(f"{sched:6s} {l:11.4f} {vc * 1e3:10.2f} ms {wt:9.1f} s")
    scheds = list(results)
    if "ss" in results and "ra" in results:
        gain = 100 * (results["ra"][1] - results["ss"][1]) / results["ra"][1]
        print(f"\nSS vs RA virtual-completion-time reduction: {gain:.1f}% "
              f"(paper Fig. 5: ~28.5% at r=n; here r={args.r})")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's scheduling core in 60 seconds.

Builds CS/SS/RA TO matrices, simulates completion times under the paper's
truncated-Gaussian delay model, compares against the oracle lower bound,
and runs one straggler-scheduled SGD round of a tiny LM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (RoundSpec, adaptive_spec, cyclic_to_matrix,
                        ec2_cluster, lb_spec, mean_completion_time,
                        random_assignment_to_matrix, scenario1,
                        simulate_lower_bound, staircase_to_matrix, sweep,
                        sweep_rounds, to_spec)
from repro.data import TaskPartition, lm_task_batches
from repro.models import ModelConfig
from repro.optim import adamw
from repro.train import init_train_state, make_straggler_train_step


def main():
    n, r, k = 8, 3, 6
    model = scenario1()
    print(f"== completion times (n={n}, r={r}, k={k}) ==")
    print("CS TO matrix:\n", cyclic_to_matrix(n, r))
    print("SS TO matrix:\n", staircase_to_matrix(n, r))
    for name, C in (("CS", cyclic_to_matrix(n, r)),
                    ("SS", staircase_to_matrix(n, r)),
                    ("RA", random_assignment_to_matrix(n, seed=0))):
        kk = k if name != "RA" else k
        t = mean_completion_time(C, model, kk, trials=8000)
        print(f"  {name}: {t * 1e3:.4f} ms")
    lb = float(np.mean(np.asarray(simulate_lower_bound(model, n, r, k,
                                                       trials=8000))))
    print(f"  LB: {lb * 1e3:.4f} ms  (oracle, eq. 46)")

    print(f"\n== message budget (paper Sec. V-C, SS, n={n}, r={r}, k={k}) ==")
    ss = staircase_to_matrix(n, r)
    res = sweep([to_spec(f"ss_m{m}", ss, messages=m) for m in (1, 2, r)],
                model, n, trials=8000, ks=k)     # one fused call, paired draws
    for m in (1, 2, r):
        label = {1: "one-shot", r: "per-slot (default)"}.get(m, "grouped")
        print(f"  m={m}: {res.at_k(f'ss_m{m}', k) * 1e3:.4f} ms  ({label})")

    print(f"\n== ragged per-worker loads (n={n}, budget {r}/worker) ==")
    # slow workers carry fewer tasks, fast ones more — same total budget
    loads = (5, 1, 3, 5, 1, 3, 5, 1)
    ragged = staircase_to_matrix(n, loads=loads)    # trailing slots MASKED
    res = sweep([to_spec("ss_ragged", ragged), lb_spec(loads=loads)],
                model, n, trials=8000, ks=k)
    print(f"  static ragged SS:  {res.at_k('ss_ragged', k) * 1e3:.4f} ms  "
          f"(loads {loads})")
    print(f"  ragged oracle LB:  {res.at_k('lb', k) * 1e3:.4f} ms")
    # adaptive re-balancing learns that allocation from censored feedback:
    # dense CS grid of width 5 = load cap, 3 slots/worker initial budget
    proc = ec2_cluster(n, spread=3.0, persistence=0.95, slow=8.0)
    rres = sweep_rounds(
        [adaptive_spec("perm", cyclic_to_matrix(n, r)),
         adaptive_spec("rebal", cyclic_to_matrix(n, 5), loads=(r,) * n,
                       rebalance=True)],
        proc, n, rounds=12, k=k, trials=2000, censored_feedback=True)
    print(f"  heterogeneous cluster, permutation-only adaptation: "
          f"{rres.mean_round('perm') * 1e3:.4f} ms/round")
    print(f"  ... + load re-balancing (same budget):              "
          f"{rres.mean_round('rebal') * 1e3:.4f} ms/round")

    print("\n== one straggler-scheduled SGD round (tiny LM) ==")
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      param_dtype="float32", dtype="float32", remat=False)
    opt = adamw(1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    spec = RoundSpec(n=n, r=r, k=k, schedule="ss")
    part = TaskPartition(n=n, global_batch=n, seq_len=32, vocab=256,
                        source="bigram")
    step = jax.jit(make_straggler_train_step(cfg, opt, spec, model))
    toks, labs = lm_task_batches(part, spec.to_matrix(), 0)
    state, m, _ = step(state, toks, labs, jax.random.PRNGKey(1))
    print(f"  loss={float(m['loss']):.3f}  "
          f"completion={float(m['completion_time']) * 1e3:.4f} ms  "
          f"winners={int(m['winners'])}/{n} tasks")


if __name__ == "__main__":
    main()

from .checkpoint import save_checkpoint, load_checkpoint, latest_checkpoint

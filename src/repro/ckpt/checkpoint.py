"""npz-based pytree checkpointing (keeps the dependency closure to
jax+numpy; on a real cluster swap for a tensorstore/orbax backend).

Leaves are saved under their tree-path key; structure is rebuilt against a
template pytree on load, so arbitrary nested dict/tuple/dataclass states
round-trip as long as the template matches.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "|"


def _flatten(tree: PyTree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _key(path) -> str:
    return _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def save_checkpoint(path: str, tree: PyTree, step: Optional[int] = None
                    ) -> str:
    """Save to ``path`` (".npz" appended if missing). If ``step`` is given,
    writes ``<path>-<step>.npz``."""
    if step is not None:
        path = f"{path}-{step:08d}"
    if not path.endswith(".npz"):
        path += ".npz"
    leaves, _ = _flatten(tree)
    arrays = {_key(p): np.asarray(l) for p, l in leaves}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)
    return path


def load_checkpoint(path: str, template: PyTree) -> PyTree:
    """Restore into the structure of ``template`` (shapes/dtypes of the
    template's leaves are preserved via cast)."""
    if not path.endswith(".npz"):
        path += ".npz"
    with np.load(path) as data:
        leaves, treedef = _flatten(template)
        new = []
        for p, l in leaves:
            k = _key(p)
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k!r}")
            arr = data[k]
            if tuple(arr.shape) != tuple(l.shape):
                raise ValueError(f"shape mismatch for {k}: "
                                 f"{arr.shape} vs {l.shape}")
            new.append(jax.numpy.asarray(arr, dtype=l.dtype))
    return jax.tree_util.tree_unflatten(treedef, new)


def latest_checkpoint(directory: str, prefix: str = "") -> Optional[str]:
    pat = re.compile(re.escape(prefix) + r"-(\d+)\.npz$")
    best, best_step = None, -1
    if not os.path.isdir(directory):
        return None
    for f in os.listdir(directory):
        m = pat.search(f)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, f), int(m.group(1))
    return best

"""Arrival-time / completion-time computation (paper eqs. 1–6, 46).

Everything is expressed as vectorized JAX ops over a leading ``trials`` axis
so Monte-Carlo evaluation of the average completion time is one jitted call.

Conventions
-----------
* ``C``   — TO matrix, shape (n, r), task indices in [0, n).
* ``T1``  — per-slot computation delays, shape (trials, n, r). ``T1[t,i,j]``
            is the compute delay of worker ``i``'s j-th *slot* (the task in
            that slot is ``C[i, j]``).
* ``T2``  — per-slot communication delays, same shape.

Derived:
* slot arrival   ``s[t,i,j] = sum_{m<=j} T1[t,i,m] + T2[t,i,j]``   (eq. 1)
* task arrival   ``tau[t,p] = min over slots with C[i,j]==p``      (eq. 2)
* completion     ``t_C(r,k) = k-th smallest of tau``                (eq. 6)
* oracle LB      ``k-th smallest of all n*r slot arrivals``         (eq. 46)

``message_arrival_times`` generalizes eq. (1) to an intra-round message
budget (paper Sec. V-C): with ``messages`` messages per worker per round, a
slot's result becomes available when its *message* is sent — at the closing
slot of its group — plus that message's communication delay draw.
``messages = r`` is eq. (1) bit-exactly (per-slot sends); ``messages = 1``
is the one-shot send the coded PC baseline uses (eqs. 51-52).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import montecarlo

__all__ = [
    "slot_arrival_times", "message_arrival_times", "message_slot_layout",
    "row_layout_is_identity", "apply_row_layout", "task_arrival_times",
    "completion_time", "lower_bound_time", "first_k_distinct_mask",
    "winner_mask_gather", "simulate_completion", "simulate_lower_bound",
    "mean_completion_time",
]

Array = jax.Array
INF = jnp.inf


def slot_arrival_times(T1: Array, T2: Array) -> Array:
    """eq. (1): s[..., i, j] = cumsum_j(T1)[..., i, j] + T2[..., i, j]."""
    return jnp.cumsum(T1, axis=-1) + T2


def message_slot_layout(loads, r: int, messages: int,
                        comm_eps: float = 0.0):
    """Static per-row message layout for a (possibly ragged) slot grid:
    returns ``(smap, offsets, active)`` — the (n, r) closing-slot remap,
    per-slot overhead offsets (None when ``comm_eps`` is 0) and active-slot
    mask (None when dense) — shared by ``message_arrival_times`` and the
    aggregator's row-major arrival path."""
    lv = np.asarray(loads, np.int64)
    n = lv.shape[0]
    smap = np.broadcast_to(np.arange(r), (n, r)).copy()
    off = np.zeros((n, r), np.float32)
    active = np.zeros((n, r), bool)
    for i, l in enumerate(lv):
        mi = min(int(messages), int(l))
        smap[i, :l] = montecarlo.message_slot_map(int(l), mi)
        b = montecarlo.message_boundaries(int(l), mi)
        off[i, :l] = comm_eps * (np.searchsorted(b, np.arange(int(l))) + 1)
        active[i, :l] = True
    return (smap, off if comm_eps else None,
            None if active.all() else active)


def row_layout_is_identity(layout) -> bool:
    """True when a ``message_slot_layout`` result is a no-op (dense,
    per-slot sends, no overhead) — callers then skip ``apply_row_layout``
    entirely, keeping the established fast path bit-identical."""
    smap, off, act = layout
    n, r = smap.shape
    return (off is None and act is None
            and np.array_equal(smap, np.broadcast_to(np.arange(r), (n, r))))


def apply_row_layout(s: Array, layout) -> Array:
    """Apply a static per-row message layout (``message_slot_layout``) to
    per-slot arrivals ``s`` (..., n, r): closing-slot remap, overhead
    offsets, +inf beyond each row's load.  The single implementation
    shared by ``message_arrival_times``, the aggregator, and the train
    step."""
    smap, off, act = layout
    out = jnp.take_along_axis(
        s, jnp.broadcast_to(jnp.asarray(smap), s.shape), axis=-1)
    if off is not None:
        out = out + jnp.asarray(off)
    if act is not None:
        out = jnp.where(jnp.asarray(act), out, INF)
    return out


def message_arrival_times(T1: Array, T2: Array, messages: int, *,
                          loads=None, comm_eps: float = 0.0) -> Array:
    """Generalized eq. (1) for an intra-round message budget: slot ``j``'s
    result arrives when its message closes — cumulative compute through the
    group's closing slot ``b(j)`` plus that message's communication draw
    (``T2[..., b(j)]``, see ``cluster.message_comm_delays``).  Returns the
    same (..., n, r) layout as ``slot_arrival_times``; ``messages == r``
    reproduces it bit-exactly.

    ``loads`` makes the grouping per-worker (worker ``w`` groups its
    ``loads[w]`` active slots into ``min(messages, loads[w])`` messages;
    its masked trailing slots come out +inf — never available).
    ``comm_eps`` adds the serialized per-message protocol overhead: a
    worker's l-th message lands ``(l + 1) * comm_eps`` late."""
    r = T1.shape[-1]
    n = T1.shape[-2]
    s = slot_arrival_times(T1, T2)
    if loads is None and not comm_eps:
        if int(messages) == r:
            return s
        return s[..., jnp.asarray(montecarlo.message_slot_map(r, messages))]
    lv = (np.full(n, r, np.int64) if loads is None
          else np.asarray(loads, np.int64))
    return apply_row_layout(s, message_slot_layout(lv, r, messages,
                                                   comm_eps))


def _static_active(C) -> np.ndarray | None:
    """Static active-slot mask of a (possibly ragged) TO matrix, or None
    when ``C`` is all-active — or a traced array, which the round APIs only
    produce for dense schedules (ragged C is always static)."""
    try:
        active = np.asarray(C) >= 0
    except Exception:                      # traced C: dense by contract
        return None
    return None if active.all() else active


def task_arrival_times(C: Array, s: Array, n: int) -> Array:
    """eq. (2): per-task earliest arrival across all (worker, slot) holding
    the task. Tasks never assigned get +inf. Shapes: C (n_w, r), s
    (..., n_w, r) -> (..., n).  ``C`` may be ragged: ``MASKED`` (-1) slots
    are statically excluded (their arrivals read as +inf)."""
    active = _static_active(C)
    if active is not None:
        # masked slots never deliver: +inf before the scatter-min (the -1
        # index would otherwise wrap onto task n-1)
        s = jnp.where(jnp.asarray(active), s, INF)
    Cf = jnp.asarray(C).reshape(-1)                  # (n_w * r,)
    sf = s.reshape(s.shape[:-2] + (-1,))             # (..., n_w * r)
    init = jnp.full(s.shape[:-2] + (n,), INF, s.dtype)
    return init.at[..., Cf].min(sf)


def completion_time(tau: Array, k: int) -> Array:
    """eq. (6): time the master holds k distinct results = k-th order
    statistic of task arrivals. tau (..., n) -> (...,)."""
    return jnp.sort(tau, axis=-1)[..., k - 1]


def lower_bound_time(s: Array, k: int) -> Array:
    """eq. (46): adaptive lower bound — with delay realizations known ahead,
    an oracle TO matrix makes the first k received results distinct, so the
    completion time is the k-th order statistic over ALL n*r slot arrivals."""
    sf = s.reshape(s.shape[:-2] + (-1,))
    return jnp.sort(sf, axis=-1)[..., k - 1]


def first_k_distinct_mask(C: Array, s: Array, n: int, k: int, *,
                          deadline: float | None = None
                          ) -> Tuple[Array, Array]:
    """Which (worker, slot) results the master uses: the earliest copy of
    each of the k earliest-arriving distinct tasks.

    Returns ``(weights, t_done)`` where ``weights`` has shape
    ``s.shape`` (…, n_w, r): per-slot aggregation weight (0 for unused slots;
    winners of selected tasks share weight 1 per task — ties averaged), and
    ``t_done`` (…,) is the completion time. Everything is differentiable-free
    masking, usable inside a jitted train step.

    With per-slot sends exactly k tasks are selected almost surely.  Under a
    reduced message budget (``message_arrival_times``) arrival ties are
    structural — the closing message can deliver more distinct tasks than
    were still missing — so ``weights`` may sum to more than ``k``; consumers
    normalize by the realized sum (see ``StragglerAggregator.combine``).

    ``deadline`` caps the round (fault tolerance, see
    ``cluster.FaultProcess``): the master closes at
    ``min(t_done, deadline)`` and only results arrived by then win —
    fewer than k when arrivals are late or censored to +inf, so a
    fully-missed round has all-zero weights.
    """
    active = _static_active(C)             # static, before any jnp tracing
    tau = task_arrival_times(C, s, n)                    # (..., n)
    return _winner_weights(jnp.asarray(C), s, tau, k, active,
                           deadline=deadline)


def winner_mask_gather(C: Array, plan: np.ndarray, s: Array, n: int, k: int,
                       *, deadline: float | None = None
                       ) -> Tuple[Array, Array]:
    """``first_k_distinct_mask`` with task arrivals computed through the
    fused engine's static gather layout (``task_gather_plan(C, n)``) instead
    of a dynamic scatter-min — the TPU-friendly form used by the round API
    (aggregator / train step hot paths)."""
    active = _static_active(C)             # static, before any jnp tracing
    tau = montecarlo.task_arrival_times_gather(plan, s)  # (..., n)
    return _winner_weights(jnp.asarray(C), s, tau, k, active,
                           deadline=deadline)


def _winner_weights(C: Array, s: Array, tau: Array, k: int,
                    active: np.ndarray | None, *,
                    deadline: float | None = None) -> Tuple[Array, Array]:
    t_done = completion_time(tau, k)                     # (...,)
    if deadline is not None:
        # close the round at the deadline with whatever has arrived —
        # t_done stays finite even when fewer than k tasks ever arrive
        t_done = jnp.minimum(t_done, jnp.asarray(deadline, tau.dtype))
    # +inf-safe: a censored task (tau = +inf, fault-killed worker) must
    # not be "selected" when t_done is itself +inf (inf <= inf is True)
    selected = (tau <= t_done[..., None]) & jnp.isfinite(tau)
    # winner slots: slot arrival equals its task's earliest arrival
    tau_at_slot = tau[..., C]                            # (..., n_w, r)
    sel_at_slot = selected[..., C]                       # (..., n_w, r)
    is_winner = (s <= tau_at_slot) & sel_at_slot
    if active is not None:
        # ragged rows: a MASKED slot's -1 index aliases task n-1 above, so
        # statically bar masked slots from winning (their weight is 0)
        is_winner = is_winner & jnp.asarray(active)
    # normalize per task so duplicated winners (measure-zero ties) average
    ones = jnp.where(is_winner, 1.0, 0.0)
    per_task_count = jnp.zeros_like(tau).at[..., C.reshape(-1)].add(
        ones.reshape(ones.shape[:-2] + (-1,)))
    cnt_at_slot = jnp.maximum(per_task_count[..., C], 1.0)
    weights = ones / cnt_at_slot
    return weights, t_done


# ---------------- Monte-Carlo drivers ----------------------------------------
# Thin wrappers over the fused sweep engine (see montecarlo.py): one
# per-trial PRNG subkey stream, static gather layout for eq. (2), chunkable
# trial streaming, and lax.top_k for single-k order statistics.

def simulate_completion(C: np.ndarray, model, k: int, *, trials: int = 10000,
                        seed: int = 0, chunk: int | None = None) -> Array:
    """Sample ``trials`` rounds of the schedule ``C`` under ``model`` and
    return the completion-time samples, shape (trials,).  ``C`` may be
    ragged (trailing ``MASKED`` sentinels)."""
    n = np.asarray(C).shape[0]
    return montecarlo.completion_samples(
        montecarlo.to_spec("to", C), model, n, trials=trials, seed=seed,
        chunk=chunk, k=k)


def simulate_lower_bound(model, n: int, r: int | None = None,
                         k: int = 1, *, trials: int = 10000,
                         seed: int = 0, chunk: int | None = None,
                         loads=None) -> Array:
    """Monte-Carlo eq. (44): samples of the oracle k-th order statistic.
    ``loads`` generalizes the bound to ragged per-worker loads (the k-th
    order statistic over all ``sum(loads)`` active slot arrivals)."""
    return montecarlo.completion_samples(
        montecarlo.lb_spec(r, loads=loads), model, n, trials=trials,
        seed=seed, chunk=chunk, k=k)


def mean_completion_time(C: np.ndarray, model, k: int, *, trials: int = 10000,
                         seed: int = 0, chunk: int | None = None) -> float:
    """Paper eq. (5): average completion time of schedule C."""
    n = np.asarray(C).shape[0]
    res = montecarlo.sweep([montecarlo.to_spec("to", C)], model, n,
                           trials=trials, seed=seed, chunk=chunk, ks=k)
    return res.at_k("to", k)

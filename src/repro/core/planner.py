"""Racing planner: successive-halving operating-point search over a
``GridSpec`` (the ROADMAP's cluster planner).

The paper's central question — which (scheme family, load ``r``, message
budget, overhead, computation target ``k``) minimizes the average round
completion time (eq. 5) — is answered exhaustively by ``stream_grid``:
every feasible cell at the full trial count.  Most cells are obviously
dominated after a few hundred trials; the planner spends Monte-Carlo
trials only where the decision is actually close, through three layers:

1. **Theory pruning** (zero trials).  When the delay model's marginals
   have a closed form (``theory.delay_model_pdfs``), each operating
   point's oracle lower bound (eq. 46, ``theory.operating_point_mean_lb``)
   is compared against the best closed-form *achievable* mean (the coded
   schemes' eqs. 51-52/56-57 expectations): a point whose lower bound
   exceeds that anchor by the slack factor cannot win and is eliminated
   before any sampling.

2. **CRN paired-difference racing**.  All surviving points are evaluated
   in ONE fused :class:`~repro.core.montecarlo.ResumableSweep` — every
   scheme sees identical delay draws (common random numbers), so two
   points are compared by their *paired per-trial differences*, whose
   stderr is far below the independent-comparison stderr whenever the
   completion times are positively correlated (they share the draws).  A
   point is eliminated when the lower confidence bound of its paired gap
   to the incumbent (the current argmin) clears zero at ``z`` sigmas.

3. **Geometric rung ladder with resumable extension**.  Trials grow by
   ``eta`` per rung; survivors are *extended* — the resumable sweep
   reuses every chunk partial already computed, so a cell raced to the
   final rung costs exactly the trials of a fresh full run, and an
   eliminated cell costs only the rungs it survived.  Survivors of the
   final rung carry the full ``GridSpec.trials``, so the returned argmin
   has the *same* confidence as the exhaustive grid's (matched
   confidence), at a fraction of the trial-evaluations.

The result is a versioned :class:`PlanResult` artifact: the recommended
:class:`~repro.core.spec.RoundConfig` (feed it to ``repro.launch.train
--config`` or the live master), the predicted-vs-lower-bound gap, the
trials spent vs. the exhaustive equivalent, and the full elimination
trajectory.  CLI: ``python -m repro.launch.plan``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Dict, Optional

import numpy as np

from . import montecarlo as mc
from . import theory
from .grid import GridSpec, _cell_name, _family_spec
from .spec import RoundConfig, _internal

__all__ = ["plan", "PlanResult", "PLAN_FORMAT_VERSION"]

PLAN_FORMAT_VERSION = 1

#: families the planner can emit a ``RoundConfig`` for (the TO-matrix
#: schedules a live round actually runs; coded winners are reported but
#: have no TO-matrix round config).
_CONFIG_FAMILIES = ("cs", "ss", "ra")


@dataclasses.dataclass(frozen=True)
class _Point:
    """One operating point: a scheme spec plus a computation target.
    Points sharing a spec (several ``k`` targets) race on the same
    evaluation columns."""
    name: str                 # grid cell name (the exhaustive grid's key)
    spec_name: str            # racing spec it reads
    family: str
    r: int
    messages: Optional[int]
    comm_eps: float
    k: int                    # effective target (coded: decode threshold)
    coded: bool               # pc/pcmm: metric is their single column


@dataclasses.dataclass
class PlanResult:
    """Outcome of one planner run.

    ``points[name]`` records each operating point's fate: ``status``
    (``won`` / ``survived`` / ``eliminated`` / ``pruned`` / ``excluded``),
    the trials it consumed, its mean/stderr at that count, the rung it
    left the race (eliminations), its paired gap to the incumbent at that
    rung, and the theory guides when available.  ``trajectory`` is the
    per-rung history (trial count, survivors, eliminations).
    ``config`` is the recommended ``RoundConfig`` when the winner is a
    TO-matrix family (cs/ss/ra), else None with ``config_note`` saying
    why.  ``trials_spent`` counts every Monte-Carlo trial-evaluation the
    planner consumed (racing + the final lower-bound run);
    ``exhaustive_trials`` is what ``stream_grid`` would have spent on the
    same grid (#cells x trials)."""
    winner: str
    predicted_mean: float
    predicted_stderr: float
    config: Optional[RoundConfig]
    config_note: Optional[str]
    points: Dict[str, dict]
    trajectory: list
    trials_spent: int
    exhaustive_trials: int
    lb_mean: Optional[float]
    lb_gap: Optional[float]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def savings(self) -> float:
        """Exhaustive-equivalent trials per trial actually spent."""
        return (self.exhaustive_trials / self.trials_spent
                if self.trials_spent else float("inf"))

    def to_json(self) -> dict:
        from .grid import _jsonable
        return {
            "version": PLAN_FORMAT_VERSION, "kind": "plan-result",
            "winner": self.winner,
            "predicted_mean": self.predicted_mean,
            "predicted_stderr": self.predicted_stderr,
            "config": (None if self.config is None
                       else self.config.to_dict()),
            "config_note": self.config_note,
            "points": _jsonable(self.points),
            "trajectory": _jsonable(self.trajectory),
            "trials_spent": self.trials_spent,
            "exhaustive_trials": self.exhaustive_trials,
            "lb_mean": self.lb_mean, "lb_gap": self.lb_gap,
            "meta": _jsonable(self.meta),
        }

    def save(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)
        return path

    @classmethod
    def load(cls, path: str) -> "PlanResult":
        with open(path) as fh:
            doc = json.load(fh)
        if doc.get("kind") != "plan-result":
            raise ValueError(f"{path}: not a plan-result artifact "
                             f"(kind={doc.get('kind')!r})")
        v = doc.get("version", 0)
        if v > PLAN_FORMAT_VERSION:
            raise ValueError(f"{path}: plan-result version {v} is newer "
                             f"than this reader ({PLAN_FORMAT_VERSION})")
        cfg = doc.get("config")
        return cls(
            winner=doc["winner"], predicted_mean=doc["predicted_mean"],
            predicted_stderr=doc["predicted_stderr"],
            config=None if cfg is None else RoundConfig.from_dict(cfg),
            config_note=doc.get("config_note"),
            points=doc["points"], trajectory=doc["trajectory"],
            trials_spent=doc["trials_spent"],
            exhaustive_trials=doc["exhaustive_trials"],
            lb_mean=doc.get("lb_mean"), lb_gap=doc.get("lb_gap"),
            meta=doc.get("meta", {}))


def _enumerate_points(gs: GridSpec, k_default: int):
    """The grid's operating points and the deduplicated racing specs.

    Points differing only in the target ``k`` share one spec (same draws,
    same evaluation — ``k`` is just a column of the all-k statistic), so
    the racing sweep carries each (family, r, messages, eps) spec once.
    ``lb`` cells are excluded from the race — the oracle bound dominates
    every schedule at its own load by construction, so racing it would
    always "win" with an unrealizable operating point; it returns as the
    final predicted-vs-LB gap instead."""
    specs: Dict[str, mc.SchemeSpec] = {}
    points: list[_Point] = []
    excluded: list[str] = []
    for r in gs.loads:
        for fam in gs.families:
            for m in gs.messages:
                for eps in gs.comm_eps:
                    sp = _family_spec(fam, gs.n, r, m, eps, gs.seed)
                    if sp is None:
                        continue
                    sname = _cell_name(fam, r, m, eps, None)
                    for k in gs.ks:
                        cname = _cell_name(fam, r, m, eps, k)
                        if fam == "lb":
                            excluded.append(cname)
                            continue
                        coded = fam in ("pc", "pcmm")
                        if coded:
                            k_eff = (mc._pc_threshold(gs.n, r) if fam == "pc"
                                     else mc._pcmm_threshold(gs.n))
                        else:
                            k_eff = k if k is not None else k_default
                        if sname not in specs:
                            with _internal():
                                specs[sname] = dataclasses.replace(
                                    sp, name=sname)
                        points.append(_Point(
                            name=cname, spec_name=sname, family=fam, r=r,
                            messages=m, comm_eps=eps, k=int(k_eff),
                            coded=coded))
    if not points:
        raise ValueError("grid has no raceable operating points (only lb "
                         "cells?); nothing to plan")
    names = [p.name for p in points]
    if len(set(names)) != len(names):       # duplicate (fam,r,m,eps,k)
        raise ValueError(f"duplicate operating points in grid: "
                         f"{sorted(nm for nm in set(names) if names.count(nm) > 1)}")
    return specs, points, excluded


def _theory_prune(points, pdfs, n: int, slack: float):
    """Split points into (pruned names -> guide record, kept points).

    Anchor: the smallest closed-form *achievable* mean among the grid's
    coded points (eqs. 51-52 / 56-57).  A point whose oracle-lower-bound
    guide exceeds ``(1 + slack) * anchor`` cannot be the argmin.  Both
    sides assume FIFO in-order delivery within a worker (see
    ``theory.multimessage_coded_tail``) — the slack absorbs that
    approximation, so pruning stays conservative."""
    pdf1, pdf2, sup1, sup2 = pdfs

    def _tmax(p: _Point) -> float:
        m_eff = p.r if p.messages is None else min(p.messages, p.r)
        return 1.25 * (p.r * sup1 + sup2 + m_eff * p.comm_eps)

    anchor = None
    predicted: Dict[str, float] = {}
    for p in points:
        if not p.coded:
            continue
        if p.family == "pc":
            mu = theory.multimessage_coded_mean(
                n, p.r, 1, pdf1, pdf2, tmax=_tmax(p),
                threshold=mc._pc_threshold(n, p.r))
        else:
            m_eff = p.r if p.messages is None else min(p.messages, p.r)
            mu = theory.multimessage_coded_mean(
                n, p.r, m_eff, pdf1, pdf2, tmax=_tmax(p))
        predicted[p.name] = mu
        anchor = mu if anchor is None else min(anchor, mu)
    if anchor is None:          # no closed-form achievable mean to prune on
        return {}, list(points), predicted
    pruned: Dict[str, dict] = {}
    kept = []
    for p in points:
        guide = theory.operating_point_mean_lb(
            n, p.r, p.k, pdf1, pdf2, messages=p.messages,
            comm_eps=p.comm_eps, tmax=_tmax(p))
        if guide > (1.0 + slack) * anchor:
            pruned[p.name] = {"lb_guide": guide, "anchor": anchor}
        else:
            kept.append(p)
    if not kept:                # slack misconfigured — never prune everything
        return {}, list(points), predicted
    return pruned, kept, predicted


def _rung_ladder(trials: int, base: int, eta: int) -> list[int]:
    """Geometric rung totals ``base * eta^j`` capped at ``trials`` (the
    final rung always lands exactly on ``trials``)."""
    ladder, t = [], base
    while t < trials:
        ladder.append(t)
        t *= eta
    ladder.append(trials)
    return ladder


def _metric_column(samp: np.ndarray, p: _Point, n: int) -> np.ndarray:
    """Per-trial completion times of one operating point, float64.
    All-k sweeps give TO/lb specs one column per k; coded specs carry
    their own decode threshold in a single column."""
    x = np.asarray(samp, np.float64)
    if x.shape[1] == 1:
        return x[:, 0]
    return x[:, p.k - 1]


def plan(grid: GridSpec, model, *, k: Optional[int] = None,
         base_trials: Optional[int] = None, eta: int = 4, z: float = 3.0,
         theory_prune: bool = True, prune_slack: float = 0.25,
         devices=None) -> PlanResult:
    """Find the grid's argmin operating point by successive-halving racing
    (see the module docstring) instead of exhaustive streaming.

    Parameters
    ----------
    grid:   the ``GridSpec`` to search (same declarative object
            ``stream_grid`` consumes; ``grid.trials`` is the final rung's
            — and the exhaustive sweep's — trial count).
    model:  the delay model.
    k:      computation target for all-k cells (``grid.ks`` entries that
            are ``None``); defaults to ``n``.  Cells with an explicit
            ``ks`` race at their own target.
    base_trials: first-rung trial count (default ``grid.trials / eta^3``,
            at least 256).  Also the racing chunk size when ``grid.chunk``
            is unset, so every intermediate rung stays chunk-aligned for
            the resumable extension.
    eta:    rung growth factor (>= 2).
    z:      elimination threshold in paired-gap sigmas.  Also used for
            the survivor tie report.
    theory_prune: eliminate points whose closed-form oracle lower bound
            exceeds the best closed-form achievable mean before any MC
            (only when ``theory.delay_model_pdfs(model)`` knows the
            model's marginals, and only with coded cells in the grid to
            anchor on).
    prune_slack: safety factor on the pruning comparison (the closed
            forms assume FIFO message delivery; see
            ``theory.operating_point_mean_lb``).
    devices: shard the racing sweep's trial axis (as in ``sweep``).

    The race runs in all-k mode — one sort per trial serves every target —
    and compares points by paired per-trial differences under common
    random numbers, eliminating at ``z`` sigmas against the incumbent.
    Survivors of the final rung reach ``grid.trials`` exactly, so the
    argmin confidence matches the exhaustive grid's.
    """
    t0 = time.perf_counter()
    n = grid.n
    k_default = n if k is None else int(k)
    if not 1 <= k_default <= n:
        raise ValueError(f"need 1 <= k <= n={n}, got k={k_default}")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    if z <= 0:
        raise ValueError(f"z must be > 0, got {z}")

    specs, points, excluded = _enumerate_points(grid, k_default)
    exhaustive_cells = len(points) + len(excluded)
    exhaustive_trials = exhaustive_cells * grid.trials

    records: Dict[str, dict] = {}
    for cname in excluded:
        records[cname] = {"status": "excluded", "trials": 0,
                          "note": "lb is the oracle bound, not a "
                                  "schedulable operating point; it returns "
                                  "as the final predicted-vs-LB gap"}

    # ---- layer 1: closed-form dominance pruning (zero trials) -----------
    predicted: Dict[str, float] = {}
    pdfs = theory.delay_model_pdfs(model) if theory_prune else None
    if pdfs is not None:
        pruned, points, predicted = _theory_prune(points, pdfs, n,
                                                  prune_slack)
        for cname, rec in pruned.items():
            records[cname] = {"status": "pruned", "trials": 0, **rec}

    # ---- rung ladder ----------------------------------------------------
    if base_trials is None:
        base_trials = max(256, -(-grid.trials // eta ** 3))
    base_trials = int(min(base_trials, grid.trials))
    chunk = grid.chunk if grid.chunk is not None else base_trials
    chunk = int(min(chunk, base_trials))
    if base_trials % chunk:
        raise ValueError(
            f"base_trials ({base_trials}) must be a multiple of the grid "
            f"chunk ({chunk}) so every rung total stays chunk-aligned for "
            f"the resumable extension")
    ladder = _rung_ladder(grid.trials, base_trials, eta)

    # ---- layer 2+3: CRN-paired successive-halving race ------------------
    alive = list(points)
    needed = {p.spec_name for p in alive}
    rs = mc.resumable_sweep(
        [sp for nm, sp in specs.items() if nm in needed], model, n,
        seed=grid.seed, chunk=chunk, ks=None, devices=devices,
        keep_samples=True)
    trajectory: list[dict] = []
    spec_trials: Dict[str, int] = {}

    for rung, t in enumerate(ladder):
        rs.extend_trials(t)
        samp = rs.samples()
        cols = {p.name: _metric_column(samp[p.spec_name], p, n)
                for p in alive}
        means = {nm: float(x.mean()) for nm, x in cols.items()}
        inc = min(alive, key=lambda p: means[p.name])   # incumbent argmin
        x_inc = cols[inc.name]
        eliminated: list[dict] = []
        survivors: list[_Point] = []
        for p in alive:
            if p is inc:
                survivors.append(p)
                continue
            d = cols[p.name] - x_inc                    # paired gap, CRN
            gap = float(d.mean())
            gap_se = float(d.std(ddof=1) / math.sqrt(t)) if t > 1 else 0.0
            if rung < len(ladder) - 1 and gap - z * gap_se > 0.0:
                x = cols[p.name]
                records[p.name] = {
                    "status": "eliminated", "trials": t, "rung": rung,
                    "mean": means[p.name],
                    "stderr": float(x.std(ddof=1) / math.sqrt(t)),
                    "gap": gap, "gap_stderr": gap_se,
                    "vs": inc.name,
                }
                eliminated.append({"point": p.name, "gap": gap,
                                   "gap_stderr": gap_se})
            else:
                survivors.append(p)
        trajectory.append({
            "rung": rung, "trials": t, "incumbent": inc.name,
            "survivors": [p.name for p in survivors],
            "eliminated": [e["point"] for e in eliminated],
        })
        dropped_specs = ({p.spec_name for p in alive}
                         - {p.spec_name for p in survivors})
        for snm in dropped_specs:
            spec_trials[snm] = t
        alive = survivors
        if rung < len(ladder) - 1 and dropped_specs:
            rs.narrow([p.spec_name for p in alive])
    for snm in {p.spec_name for p in alive}:
        spec_trials[snm] = grid.trials

    # ---- final selection + survivor records -----------------------------
    samp = rs.samples()
    final_cols = {p.name: _metric_column(samp[p.spec_name], p, n)
                  for p in alive}
    winner = min(alive, key=lambda p: float(final_cols[p.name].mean()))
    w_x = final_cols[winner.name]
    w_mean = float(w_x.mean())
    w_se = float(w_x.std(ddof=1) / math.sqrt(grid.trials))
    for p in alive:
        x = final_cols[p.name]
        rec = {"status": "won" if p is winner else "survived",
               "trials": grid.trials, "mean": float(x.mean()),
               "stderr": float(x.std(ddof=1) / math.sqrt(grid.trials))}
        if p is not winner:
            d = x - w_x
            rec["gap"] = float(d.mean())
            rec["gap_stderr"] = float(d.std(ddof=1)
                                      / math.sqrt(grid.trials))
            rec["vs"] = winner.name
        records[p.name] = rec
    for nm, mu in predicted.items():
        if nm in records:
            records[nm]["theory_mean"] = mu

    # ---- predicted-vs-LB gap at the winning operating point -------------
    trials_spent = sum(spec_trials.values())
    lb_sp = mc.lb_spec(winner.r, messages=winner.messages,
                       comm_eps=winner.comm_eps)
    lb_res = mc.sweep([lb_sp], model, n, trials=grid.trials,
                      seed=grid.seed, chunk=chunk, ks=None,
                      devices=devices)
    # coded winners recover the full gradient at their decode threshold,
    # so the comparable oracle target is k = n (their own threshold can
    # exceed n and is not an order-statistic index of the lb spec).
    lb_mean = lb_res.at_k("lb", n if winner.coded else winner.k)
    lb_gap = (w_mean - lb_mean) / lb_mean if lb_mean > 0 else float("inf")
    trials_spent += grid.trials

    # ---- RoundConfig emission -------------------------------------------
    config = config_note = None
    if winner.family in _CONFIG_FAMILIES:
        config = RoundConfig(
            n=n, k=winner.k, kind=winner.family, r=winner.r,
            messages=winner.messages, comm_eps=winner.comm_eps,
            seed=grid.seed)
    else:
        config_note = (f"winner {winner.name!r} is a coded scheme "
                       f"({winner.family}); it has no TO-matrix round "
                       f"config — wire its encoder in directly")

    ties = [p.name for p in alive if p is not winner
            and records[p.name]["gap"]
            <= z * records[p.name]["gap_stderr"]]
    meta = {
        "n": n, "k": k_default, "eta": eta, "z": z,
        "base_trials": base_trials, "chunk": chunk, "ladder": ladder,
        "theory_pruned": sum(1 for r2 in records.values()
                             if r2["status"] == "pruned"),
        "raced_points": len(points), "excluded": len(excluded),
        "exhaustive_cells": exhaustive_cells,
        "ties": ties,
        "seconds": time.perf_counter() - t0,
        "devices": (devices if isinstance(devices, (int, type(None)))
                    else len(tuple(devices))),
    }
    return PlanResult(
        winner=winner.name, predicted_mean=w_mean, predicted_stderr=w_se,
        config=config, config_note=config_note, points=records,
        trajectory=trajectory, trials_spent=trials_spent,
        exhaustive_trials=exhaustive_trials, lb_mean=lb_mean,
        lb_gap=lb_gap, meta=meta)

"""Round-aware cluster delay processes — stateful straggling across SGD
rounds.

The paper models each SGD iteration as a computation *round*.  The original
``DelayModel.sample(key, trials, n, r)`` API draws delays i.i.d. across
rounds, but real clusters straggle in a worker-specific, *persistent* way
(paper Sec. VI-A EC2 measurements; Behrouzi-Far & Soljanin, arXiv:1808.02838):
a worker that was slow this round is likely still slow next round, and some
workers are simply slower machines than others.  That is the regime where
schedule order — and round-to-round adaptation — matters most.

A ``DelayProcess`` is the stateful generalization:

    state            = process.init(keys, n)          # keys (trials, 2)
    state, T1, T2    = process.step(state, keys, n, r)

``keys`` carries one PRNG subkey **per trial** (the fused MC engine's
common-random-numbers convention), so draws are chunk-invariant and every
scheme evaluated against one process sees identical delay realizations.
``state`` is a pytree of arrays with leading dimension ``trials`` that rides
through ``lax.scan`` over rounds.  ``T1``/``T2`` keep the established
``(trials, n, r)`` layout of per-slot computation / communication delays.

Processes
---------
* ``IIDProcess``          — compatibility shim: any stateless ``DelayModel``
                            as the zero-correlation special case.
* ``MarkovRegimeProcess`` — per-worker two-state (fast/slow) Markov chain.
                            ``persistence`` is the chain's one-step
                            autocorrelation; ``persistence=0`` recovers
                            i.i.d. Bernoulli straggling per round (exactly
                            ``BimodalStragglerDelays``'s marginal), and
                            ``p_slow=0`` or ``slow=1`` recovers the base
                            model.  ``worker_scale`` adds heterogeneous
                            per-worker machine speeds.
* ``AR1Process``          — continuous log-speed latent with AR(1) dynamics:
                            smooth drifts instead of regime switches.
                            ``rho=0`` is round-i.i.d., ``sigma=0`` is the
                            base model exactly.

``heterogeneous_scales`` builds geometrically spread per-worker speed
multipliers; ``ec2_cluster`` bundles the calibrated truncated-Gaussian base
with heterogeneity + persistence into one realistic cluster.

Per-message communication draws
-------------------------------
The process layer draws one ``T2`` per slot.  A round with an intra-round
message budget (paper Sec. V-C; ``SchemeSpec.messages``) sends the slots in
consecutive groups, and each *message* consumes exactly one of those draws —
the draw at its closing slot (``message_comm_delays``).  That convention
makes the message axis free at the sampling layer: ``messages = r``
reproduces per-slot sends and ``messages = 1`` the one-shot send bit-exactly,
and completion times stay paired across budgets under common random numbers
(the same draws back every ``m``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .delays import DelayModel, TruncatedGaussianDelays, ec2_like

__all__ = [
    "DelayProcess", "IIDProcess", "MarkovRegimeProcess", "AR1Process",
    "as_process", "heterogeneous_scales", "ec2_cluster",
    "message_comm_delays",
    "FaultProcess", "SpotPreemptionProcess", "NetworkPartitionProcess",
    "RackFailureProcess", "MessageLossProcess", "DiurnalLoadProcess",
    "FAULT_SCENARIOS", "make_scenario",
]

Array = jax.Array
State = Any


def _per_trial(model: DelayModel, keys: Array, n: int, r: int
               ) -> Tuple[Array, Array]:
    """Sample (trials, n, r) delay tensors with one subkey per trial — the
    same convention the fused engine uses, so results are chunk-invariant."""
    def one(kk):
        T1, T2 = model.sample(kk, 1, n, r)
        return T1[0], T2[0]
    return jax.vmap(one)(keys)


def _scale_column(worker_scale, n: int) -> Array:
    """Per-worker speed multipliers broadcast to the (trials, n, r) layout."""
    w = jnp.broadcast_to(jnp.asarray(worker_scale, jnp.float32), (n,))
    return w[None, :, None]


@dataclasses.dataclass(frozen=True)
class DelayProcess:
    """Base class.  Subclasses implement ``init``/``step``; both take
    per-trial keys of shape ``(trials, 2)``."""

    def init(self, keys: Array, n: int) -> State:
        raise NotImplementedError

    def init_trials(self, keys: Array, trial_ids: Array, n: int) -> State:
        """``init`` with explicit global trial indices.  Parametric
        processes are fully determined by their per-trial keys and ignore
        the ids; trace-backed replay (``repro.core.trace.TraceProcess``)
        uses them to read the right trial of its table under any chunking
        of the trial axis (the fused rounds engine always calls this
        form)."""
        del trial_ids
        return self.init(keys, n)

    def check_rounds(self, rounds: int) -> None:
        """Hook for finite delay sources: raise if a ``rounds``-long run
        cannot be served.  Parametric processes are unbounded (no-op);
        ``TraceProcess`` enforces its ``pad_rounds`` policy here."""

    def step(self, state: State, keys: Array, n: int, r: int
             ) -> Tuple[State, Array, Array]:
        raise NotImplementedError

    def sample_rounds(self, key: Array, trials: int, n: int, r: int,
                      rounds: int) -> Tuple[Array, Array]:
        """Convenience: unroll the process, returning delay tensors of shape
        ``(rounds, trials, n, r)`` (small-scale inspection / tests)."""
        self.check_rounds(rounds)
        allk = jax.vmap(lambda kk: jax.random.split(kk, rounds + 1))(
            jax.random.split(key, trials))           # (trials, rounds+1, 2)
        state = self.init_trials(allk[:, 0],
                                 jnp.arange(trials, dtype=jnp.int32), n)

        def body(st, kr):
            st, T1, T2 = self.step(st, kr, n, r)
            return st, (T1, T2)

        _, (T1, T2) = jax.lax.scan(body, state, jnp.swapaxes(allk[:, 1:], 0, 1))
        return T1, T2


@dataclasses.dataclass(frozen=True)
class IIDProcess(DelayProcess):
    """A stateless ``DelayModel`` as a (trivially stateful) process — the
    zero-correlation, homogeneous special case.  Single-round statistics are
    identical to the model's own."""
    model: DelayModel = TruncatedGaussianDelays()

    def init(self, keys, n):
        return ()

    def step(self, state, keys, n, r):
        T1, T2 = _per_trial(self.model, keys, n, r)
        return (), T1, T2


@dataclasses.dataclass(frozen=True)
class MarkovRegimeProcess(DelayProcess):
    """Per-worker fast/slow regime chain with persistent stragglers.

    Each worker carries a two-state Markov chain; in the slow regime all of
    the worker's delays (compute *and* communication — a busy neighbor VM
    slows both) are multiplied by ``slow``.  Parameterized by the stationary
    slow probability ``p_slow`` and the chain's one-step autocorrelation
    ``persistence`` = 1 - p_fast_to_slow - p_slow_to_fast, so

      * ``persistence = 0``  → regimes i.i.d. across rounds
        (``BimodalStragglerDelays``'s marginal every round);
      * ``persistence = 1``  → stragglers frozen at their stationary
        initial draw for the whole run.

    ``worker_scale`` (scalar or length-n tuple) multiplies every delay of
    worker i — persistent machine heterogeneity on top of the regime chain.
    The chain starts from its stationary distribution, so marginals are
    round-invariant.
    """
    base: DelayModel = TruncatedGaussianDelays()
    worker_scale: tuple | float = 1.0
    p_slow: float = 0.2
    persistence: float = 0.9
    slow: float = 5.0

    def __post_init__(self):
        if not 0.0 <= self.p_slow <= 1.0:
            raise ValueError(f"p_slow must be in [0, 1], got {self.p_slow}")
        if not 0.0 <= self.persistence <= 1.0:
            raise ValueError(
                f"persistence must be in [0, 1], got {self.persistence}")

    @property
    def _p_fs(self) -> float:            # fast -> slow
        return (1.0 - self.persistence) * self.p_slow

    @property
    def _p_sf(self) -> float:            # slow -> fast
        return (1.0 - self.persistence) * (1.0 - self.p_slow)

    def init(self, keys, n):
        def one(kk):
            return jax.random.bernoulli(kk, self.p_slow, (n,))
        return jax.vmap(one)(keys)                        # (trials, n) bool

    def step(self, state, keys, n, r):
        def split3(kk):
            return tuple(jax.random.split(kk, 3))
        kb, kc, _ = jax.vmap(split3)(keys)
        # advance the regime chain first: the sampled round reflects the
        # post-transition regime, and round-1 output already matches the
        # stationary marginal (init is stationary).
        def chain(kk):
            return jax.random.uniform(kk, (n,))
        u = jax.vmap(chain)(kc)                           # (trials, n)
        slow_now = jnp.where(state, u >= self._p_sf, u < self._p_fs)
        T1, T2 = _per_trial(self.base, kb, n, r)
        f = jnp.where(slow_now[..., None], self.slow, 1.0)
        f = f * _scale_column(self.worker_scale, n)
        return slow_now, T1 * f, T2 * f


@dataclasses.dataclass(frozen=True)
class AR1Process(DelayProcess):
    """Smoothly drifting worker speeds: a per-worker AR(1) latent
    ``x' = rho * x + sigma * sqrt(1 - rho^2) * eps`` (stationary N(0, sigma^2))
    multiplies delays by ``exp(x - sigma^2 / 2)`` (unit-mean log-normal).
    ``rho`` is the round-to-round correlation of the log speed; ``sigma``
    its dispersion.  ``worker_scale`` as in ``MarkovRegimeProcess``."""
    base: DelayModel = TruncatedGaussianDelays()
    worker_scale: tuple | float = 1.0
    rho: float = 0.9
    sigma: float = 0.3

    def __post_init__(self):
        if not -1.0 < self.rho < 1.0:
            raise ValueError(f"rho must be in (-1, 1), got {self.rho}")

    def init(self, keys, n):
        def one(kk):
            return self.sigma * jax.random.normal(kk, (n,))
        return jax.vmap(one)(keys)                        # (trials, n)

    def step(self, state, keys, n, r):
        def split3(kk):
            return tuple(jax.random.split(kk, 3))
        kb, kx, _ = jax.vmap(split3)(keys)
        eps = jax.vmap(lambda kk: jax.random.normal(kk, (n,)))(kx)
        x = self.rho * state + self.sigma * np.sqrt(1.0 - self.rho ** 2) * eps
        T1, T2 = _per_trial(self.base, kb, n, r)
        f = jnp.exp(x - 0.5 * self.sigma ** 2)[..., None]
        f = f * _scale_column(self.worker_scale, n)
        return x, T1 * f, T2 * f


def _split_each(keys: Array) -> Tuple[Array, Array]:
    """Split each per-trial key into (base, fault) streams.  Wrapping a
    process in a ``FaultProcess`` therefore changes the base draws (the
    base sees a child key), but draws stay chunk-invariant and identical
    across schemes — the CRN convention the engine relies on."""
    def two(kk):
        return tuple(jax.random.split(kk, 2))
    return jax.vmap(two)(keys)


@dataclasses.dataclass(frozen=True)
class FaultProcess(DelayProcess):
    """Composable failure overlay on any base ``DelayProcess``.

    Faults are modeled in-band: a killed/unreachable worker's delays are
    ``+inf``, so its results simply never arrive (arrival = +inf through
    ``message_arrival_times`` and the winner-mask paths).  The wrapper
    keeps the ``DelayProcess`` init/step protocol — state is the pytree
    ``(base_state, fault_state)`` and each per-trial key is split into a
    base stream and a fault stream — so any scenario stacks on any base
    process (and scenarios stack on each other, e.g. message loss on top
    of preemption).

    Subclasses implement ``fault_init(keys, n)`` and
    ``fault_step(fstate, keys, n, r, T1, T2) -> (fstate, T1, T2)``.
    """
    base: DelayProcess = dataclasses.field(default_factory=IIDProcess)

    def fault_init(self, keys: Array, n: int) -> State:
        return ()

    def fault_step(self, fstate: State, keys: Array, n: int, r: int,
                   T1: Array, T2: Array) -> Tuple[State, Array, Array]:
        raise NotImplementedError

    def init(self, keys, n):
        kb, kf = _split_each(keys)
        return (self.base.init(kb, n), self.fault_init(kf, n))

    def init_trials(self, keys, trial_ids, n):
        kb, kf = _split_each(keys)
        return (self.base.init_trials(kb, trial_ids, n),
                self.fault_init(kf, n))

    def check_rounds(self, rounds):
        self.base.check_rounds(rounds)

    def step(self, state, keys, n, r):
        bstate, fstate = state
        kb, kf = _split_each(keys)
        bstate, T1, T2 = self.base.step(bstate, kb, n, r)
        fstate, T1, T2 = self.fault_step(fstate, kf, n, r, T1, T2)
        return (bstate, fstate), T1, T2


@dataclasses.dataclass(frozen=True)
class SpotPreemptionProcess(FaultProcess):
    """Spot-instance preemption: each worker dies with probability
    ``kill_p`` per round and, once dead, respawns with probability
    ``respawn_p`` per round (geometric kill/respawn holding times).  A
    dead worker's compute delays are +inf for the round — nothing it was
    assigned ever arrives.  ``kill_p = 0`` recovers the base process."""
    kill_p: float = 0.05
    respawn_p: float = 0.3

    def __post_init__(self):
        for nm in ("kill_p", "respawn_p"):
            v = getattr(self, nm)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")

    def fault_init(self, keys, n):
        return jnp.ones((keys.shape[0], n), bool)    # everyone starts alive

    def fault_step(self, fstate, keys, n, r, T1, T2):
        alive = fstate
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (n,)))(keys)
        # advance the alive chain first (same convention as the regime
        # chain): the round reflects the post-transition liveness
        alive = jnp.where(alive, u >= self.kill_p, u < self.respawn_p)
        dead = ~alive[..., None]
        return alive, jnp.where(dead, jnp.inf, T1), T2


@dataclasses.dataclass(frozen=True)
class NetworkPartitionProcess(FaultProcess):
    """Network partition: a fixed worker subset's *communication* delays
    are +inf for a regime-length round window ``[start, start + length)``
    — the partitioned workers keep computing but their results cannot be
    delivered until the partition heals."""
    workers: tuple = (0,)
    start: int = 2
    length: int = 5

    def __post_init__(self):
        if not self.workers:
            raise ValueError("partition needs a non-empty worker subset")
        if min(self.workers) < 0:
            raise ValueError(f"negative worker index in {self.workers}")
        if self.start < 0 or self.length <= 0:
            raise ValueError(
                f"need start >= 0 and length > 0, got start={self.start} "
                f"length={self.length}")

    def fault_init(self, keys, n):
        if max(self.workers) >= n:
            raise ValueError(
                f"partition workers {self.workers} out of range for n={n}")
        return jnp.zeros((), jnp.int32)          # round counter

    def fault_step(self, fstate, keys, n, r, T1, T2):
        del keys
        t = fstate
        cut = (t >= self.start) & (t < self.start + self.length)
        member = jnp.asarray(np.isin(np.arange(n), self.workers))
        gone = cut & member[None, :, None]
        return t + 1, T1, jnp.where(gone, jnp.inf, T2)


@dataclasses.dataclass(frozen=True)
class RackFailureProcess(FaultProcess):
    """Correlated rack failure: workers are grouped into racks
    (``racks[i]`` = rack id of worker i) and the kill/respawn chain runs
    per *rack* — all workers of a failed rack die simultaneously and
    respawn together.  With one worker per rack this degenerates to
    ``SpotPreemptionProcess``."""
    racks: tuple = (0,)
    kill_p: float = 0.02
    respawn_p: float = 0.5

    def __post_init__(self):
        if not self.racks:
            raise ValueError("racks must map every worker to a rack id")
        if min(self.racks) < 0:
            raise ValueError(f"negative rack id in {self.racks}")
        for nm in ("kill_p", "respawn_p"):
            v = getattr(self, nm)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")

    def fault_init(self, keys, n):
        if len(self.racks) != n:
            raise ValueError(
                f"racks maps {len(self.racks)} workers, cluster has {n}")
        n_racks = max(self.racks) + 1
        return jnp.ones((keys.shape[0], n_racks), bool)

    def fault_step(self, fstate, keys, n, r, T1, T2):
        alive = fstate
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (max(self.racks) + 1,))
                     )(keys)
        alive = jnp.where(alive, u >= self.kill_p, u < self.respawn_p)
        rack_of = jnp.asarray(np.asarray(self.racks, np.int32))
        dead_w = ~alive[:, rack_of][..., None]    # (trials, n, 1)
        return alive, jnp.where(dead_w, jnp.inf, T1), T2


@dataclasses.dataclass(frozen=True)
class MessageLossProcess(FaultProcess):
    """Per-slot Bernoulli message loss.  Each (worker, slot) result's
    uplink drops independently with probability ``p_drop``.  Without
    retry the dropped message is simply never delivered (``T2 = +inf``);
    with ``retry_delay`` set, the sender re-sends after that backoff
    until a send survives, so the message arrives late by
    ``failures * retry_delay`` with geometrically distributed failure
    count."""
    p_drop: float = 0.1
    retry_delay: float | None = None

    def __post_init__(self):
        if not 0.0 <= self.p_drop < 1.0:
            raise ValueError(f"p_drop must be in [0, 1), got {self.p_drop}")
        if self.retry_delay is not None and self.retry_delay <= 0:
            raise ValueError(
                f"retry_delay must be positive, got {self.retry_delay}")

    def fault_step(self, fstate, keys, n, r, T1, T2):
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (n, r)))(keys)
        if self.retry_delay is None:
            return fstate, T1, jnp.where(u < self.p_drop, jnp.inf, T2)
        if self.p_drop == 0.0:
            return fstate, T1, T2
        # inverse-CDF geometric: #failed sends before the first success
        fails = jnp.floor(jnp.log(u) / np.log(self.p_drop))
        return fstate, T1, T2 + fails * self.retry_delay


@dataclasses.dataclass(frozen=True)
class DiurnalLoadProcess(FaultProcess):
    """Diurnal load swell: a shared sinusoidal multiplier on all delays,
    cycling over ``period`` rounds between 1x and ``1 + amplitude``x —
    the whole cluster slows together at "peak hours".  No worker dies;
    this is the graceful end of the zoo (deadline pressure without
    censoring)."""
    period: int = 24
    amplitude: float = 1.0
    phase: float = 0.0

    def __post_init__(self):
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")
        if self.amplitude < 0:
            raise ValueError(
                f"amplitude must be >= 0, got {self.amplitude}")

    def fault_init(self, keys, n):
        del keys
        return jnp.zeros((), jnp.int32)

    def fault_step(self, fstate, keys, n, r, T1, T2):
        del keys
        t = fstate
        ang = 2.0 * np.pi * (t.astype(jnp.float32) + self.phase) / self.period
        f = 1.0 + self.amplitude * 0.5 * (1.0 - jnp.cos(ang))
        return t + 1, T1 * f, T2 * f


FAULT_SCENARIOS = ("preemption", "partition", "rack", "msgloss", "diurnal")


def make_scenario(name: str, base, n: int, **overrides) -> FaultProcess:
    """Build a named fault scenario over ``base`` (any delay source) with
    cluster-size-derived defaults; ``overrides`` replace any scenario
    field.  Scenarios: 'preemption' (spot kill/respawn), 'partition'
    (n//3 workers unreachable for a round window), 'rack' (correlated
    kills of n//3-sized racks), 'msgloss' (per-slot Bernoulli drop),
    'diurnal' (sinusoidal cluster-wide load swell)."""
    proc = as_process(base)
    if name == "preemption":
        kw = {"kill_p": 0.1, "respawn_p": 0.25}
        kw.update(overrides)
        return SpotPreemptionProcess(base=proc, **kw)
    if name == "partition":
        kw = {"workers": tuple(range(max(1, n // 3))),
              "start": 2, "length": 6}
        kw.update(overrides)
        return NetworkPartitionProcess(base=proc, **kw)
    if name == "rack":
        size = max(2, n // 3)
        kw = {"racks": tuple(i // size for i in range(n)),
              "kill_p": 0.05, "respawn_p": 0.3}
        kw.update(overrides)
        return RackFailureProcess(base=proc, **kw)
    if name == "msgloss":
        kw = {"p_drop": 0.1, "retry_delay": None}
        kw.update(overrides)
        return MessageLossProcess(base=proc, **kw)
    if name == "diurnal":
        kw = {"period": 8, "amplitude": 2.0}
        kw.update(overrides)
        return DiurnalLoadProcess(base=proc, **kw)
    raise ValueError(
        f"unknown fault scenario {name!r}; choose from {FAULT_SCENARIOS}")


def message_comm_delays(T2: Array, messages: int,
                        eps: float = 0.0) -> Array:
    """Per-message communication delay draws for a round sending ``messages``
    messages per worker: the draw at each message's closing slot.  ``T2`` has
    shape (..., n, r); returns (..., n, messages).  ``messages = r`` returns
    the per-slot draws unchanged (when ``eps`` is 0).

    ``eps`` is the per-message protocol overhead of Ozfatura et al.
    (arXiv:2004.04948)'s communication/computation trade-off: each message
    costs a fixed ``eps`` of serialized uplink time, so a worker's l-th
    message (0-indexed) carries ``(l + 1) * eps`` of accumulated overhead.
    More messages deliver early results sooner but push the *late* messages
    further out — which is why an optimal budget ``1 <= m* <= r`` exists
    instead of ``m = r`` always winning (see ``benchmarks.fig9``)."""
    from .montecarlo import message_boundaries
    r = T2.shape[-1]
    if int(messages) == r and not eps:
        return T2
    d = (T2 if int(messages) == r
         else T2[..., jnp.asarray(message_boundaries(r, messages))])
    if eps:
        d = d + eps * jnp.arange(1, int(messages) + 1, dtype=T2.dtype)
    return d


def as_process(delay) -> DelayProcess:
    """Coerce any delay source into a ``DelayProcess``:

    * ``DelayProcess`` instances pass through unchanged;
    * a stateless ``DelayModel`` becomes the zero-correlation
      ``IIDProcess`` shim;
    * a recorded ``DelayTrace`` becomes a ``TraceProcess`` replay (default
      strict padding policies — build the ``TraceProcess`` yourself for
      cycle/hold extension).
    """
    if isinstance(delay, DelayProcess):
        return delay
    if isinstance(delay, DelayModel):
        return IIDProcess(delay)
    from .trace import DelayTrace, TraceProcess    # late: trace imports us
    if isinstance(delay, DelayTrace):
        return TraceProcess(delay)
    raise TypeError(
        f"cannot interpret {type(delay).__name__!r} as a delay source: "
        f"expected a DelayProcess (init/step protocol, e.g. IIDProcess, "
        f"MarkovRegimeProcess, AR1Process, TraceProcess), a stateless "
        f"DelayModel (e.g. TruncatedGaussianDelays), or a recorded "
        f"DelayTrace; got {delay!r}")


def heterogeneous_scales(n: int, spread: float = 2.0, seed: int = 0) -> tuple:
    """Per-worker speed multipliers geometrically spread over
    ``[1/sqrt(spread), sqrt(spread)]`` (geometric mean 1), randomly permuted
    so worker index carries no information.  ``spread=1`` is homogeneous."""
    if spread < 1.0:
        raise ValueError(f"spread must be >= 1, got {spread}")
    if n == 1 or spread == 1.0:
        return tuple([1.0] * n)
    rng = np.random.default_rng(seed)
    log_s = np.linspace(-0.5, 0.5, n) * np.log(spread)
    return tuple(np.exp(rng.permutation(log_s)).tolist())


def ec2_cluster(n: int, *, spread: float = 2.0, p_slow: float = 0.2,
                persistence: float = 0.9, slow: float = 5.0,
                base: DelayModel | None = None,
                seed: int = 0) -> MarkovRegimeProcess:
    """A realistic heterogeneous, persistent-straggler cluster: the paper's
    EC2-calibrated truncated-Gaussian base (``ec2_like``: communication
    dominates computation, mild per-worker mean spread), an additional
    machine-speed spread, and a sticky slow/fast regime chain."""
    if base is None:
        base = ec2_like(n, seed=seed)
    return MarkovRegimeProcess(
        base=base, worker_scale=heterogeneous_scales(n, spread, seed),
        p_slow=p_slow, persistence=persistence, slow=slow)

"""Round-aware cluster delay processes — stateful straggling across SGD
rounds.

The paper models each SGD iteration as a computation *round*.  The original
``DelayModel.sample(key, trials, n, r)`` API draws delays i.i.d. across
rounds, but real clusters straggle in a worker-specific, *persistent* way
(paper Sec. VI-A EC2 measurements; Behrouzi-Far & Soljanin, arXiv:1808.02838):
a worker that was slow this round is likely still slow next round, and some
workers are simply slower machines than others.  That is the regime where
schedule order — and round-to-round adaptation — matters most.

A ``DelayProcess`` is the stateful generalization:

    state            = process.init(keys, n)          # keys (trials, 2)
    state, T1, T2    = process.step(state, keys, n, r)

``keys`` carries one PRNG subkey **per trial** (the fused MC engine's
common-random-numbers convention), so draws are chunk-invariant and every
scheme evaluated against one process sees identical delay realizations.
``state`` is a pytree of arrays with leading dimension ``trials`` that rides
through ``lax.scan`` over rounds.  ``T1``/``T2`` keep the established
``(trials, n, r)`` layout of per-slot computation / communication delays.

Processes
---------
* ``IIDProcess``          — compatibility shim: any stateless ``DelayModel``
                            as the zero-correlation special case.
* ``MarkovRegimeProcess`` — per-worker two-state (fast/slow) Markov chain.
                            ``persistence`` is the chain's one-step
                            autocorrelation; ``persistence=0`` recovers
                            i.i.d. Bernoulli straggling per round (exactly
                            ``BimodalStragglerDelays``'s marginal), and
                            ``p_slow=0`` or ``slow=1`` recovers the base
                            model.  ``worker_scale`` adds heterogeneous
                            per-worker machine speeds.
* ``AR1Process``          — continuous log-speed latent with AR(1) dynamics:
                            smooth drifts instead of regime switches.
                            ``rho=0`` is round-i.i.d., ``sigma=0`` is the
                            base model exactly.

``heterogeneous_scales`` builds geometrically spread per-worker speed
multipliers; ``ec2_cluster`` bundles the calibrated truncated-Gaussian base
with heterogeneity + persistence into one realistic cluster.

Per-message communication draws
-------------------------------
The process layer draws one ``T2`` per slot.  A round with an intra-round
message budget (paper Sec. V-C; ``SchemeSpec.messages``) sends the slots in
consecutive groups, and each *message* consumes exactly one of those draws —
the draw at its closing slot (``message_comm_delays``).  That convention
makes the message axis free at the sampling layer: ``messages = r``
reproduces per-slot sends and ``messages = 1`` the one-shot send bit-exactly,
and completion times stay paired across budgets under common random numbers
(the same draws back every ``m``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .delays import DelayModel, TruncatedGaussianDelays, ec2_like

__all__ = [
    "DelayProcess", "IIDProcess", "MarkovRegimeProcess", "AR1Process",
    "as_process", "heterogeneous_scales", "ec2_cluster",
    "message_comm_delays",
]

Array = jax.Array
State = Any


def _per_trial(model: DelayModel, keys: Array, n: int, r: int
               ) -> Tuple[Array, Array]:
    """Sample (trials, n, r) delay tensors with one subkey per trial — the
    same convention the fused engine uses, so results are chunk-invariant."""
    def one(kk):
        T1, T2 = model.sample(kk, 1, n, r)
        return T1[0], T2[0]
    return jax.vmap(one)(keys)


def _scale_column(worker_scale, n: int) -> Array:
    """Per-worker speed multipliers broadcast to the (trials, n, r) layout."""
    w = jnp.broadcast_to(jnp.asarray(worker_scale, jnp.float32), (n,))
    return w[None, :, None]


@dataclasses.dataclass(frozen=True)
class DelayProcess:
    """Base class.  Subclasses implement ``init``/``step``; both take
    per-trial keys of shape ``(trials, 2)``."""

    def init(self, keys: Array, n: int) -> State:
        raise NotImplementedError

    def init_trials(self, keys: Array, trial_ids: Array, n: int) -> State:
        """``init`` with explicit global trial indices.  Parametric
        processes are fully determined by their per-trial keys and ignore
        the ids; trace-backed replay (``repro.core.trace.TraceProcess``)
        uses them to read the right trial of its table under any chunking
        of the trial axis (the fused rounds engine always calls this
        form)."""
        del trial_ids
        return self.init(keys, n)

    def check_rounds(self, rounds: int) -> None:
        """Hook for finite delay sources: raise if a ``rounds``-long run
        cannot be served.  Parametric processes are unbounded (no-op);
        ``TraceProcess`` enforces its ``pad_rounds`` policy here."""

    def step(self, state: State, keys: Array, n: int, r: int
             ) -> Tuple[State, Array, Array]:
        raise NotImplementedError

    def sample_rounds(self, key: Array, trials: int, n: int, r: int,
                      rounds: int) -> Tuple[Array, Array]:
        """Convenience: unroll the process, returning delay tensors of shape
        ``(rounds, trials, n, r)`` (small-scale inspection / tests)."""
        self.check_rounds(rounds)
        allk = jax.vmap(lambda kk: jax.random.split(kk, rounds + 1))(
            jax.random.split(key, trials))           # (trials, rounds+1, 2)
        state = self.init_trials(allk[:, 0],
                                 jnp.arange(trials, dtype=jnp.int32), n)

        def body(st, kr):
            st, T1, T2 = self.step(st, kr, n, r)
            return st, (T1, T2)

        _, (T1, T2) = jax.lax.scan(body, state, jnp.swapaxes(allk[:, 1:], 0, 1))
        return T1, T2


@dataclasses.dataclass(frozen=True)
class IIDProcess(DelayProcess):
    """A stateless ``DelayModel`` as a (trivially stateful) process — the
    zero-correlation, homogeneous special case.  Single-round statistics are
    identical to the model's own."""
    model: DelayModel = TruncatedGaussianDelays()

    def init(self, keys, n):
        return ()

    def step(self, state, keys, n, r):
        T1, T2 = _per_trial(self.model, keys, n, r)
        return (), T1, T2


@dataclasses.dataclass(frozen=True)
class MarkovRegimeProcess(DelayProcess):
    """Per-worker fast/slow regime chain with persistent stragglers.

    Each worker carries a two-state Markov chain; in the slow regime all of
    the worker's delays (compute *and* communication — a busy neighbor VM
    slows both) are multiplied by ``slow``.  Parameterized by the stationary
    slow probability ``p_slow`` and the chain's one-step autocorrelation
    ``persistence`` = 1 - p_fast_to_slow - p_slow_to_fast, so

      * ``persistence = 0``  → regimes i.i.d. across rounds
        (``BimodalStragglerDelays``'s marginal every round);
      * ``persistence = 1``  → stragglers frozen at their stationary
        initial draw for the whole run.

    ``worker_scale`` (scalar or length-n tuple) multiplies every delay of
    worker i — persistent machine heterogeneity on top of the regime chain.
    The chain starts from its stationary distribution, so marginals are
    round-invariant.
    """
    base: DelayModel = TruncatedGaussianDelays()
    worker_scale: tuple | float = 1.0
    p_slow: float = 0.2
    persistence: float = 0.9
    slow: float = 5.0

    def __post_init__(self):
        if not 0.0 <= self.p_slow <= 1.0:
            raise ValueError(f"p_slow must be in [0, 1], got {self.p_slow}")
        if not 0.0 <= self.persistence <= 1.0:
            raise ValueError(
                f"persistence must be in [0, 1], got {self.persistence}")

    @property
    def _p_fs(self) -> float:            # fast -> slow
        return (1.0 - self.persistence) * self.p_slow

    @property
    def _p_sf(self) -> float:            # slow -> fast
        return (1.0 - self.persistence) * (1.0 - self.p_slow)

    def init(self, keys, n):
        def one(kk):
            return jax.random.bernoulli(kk, self.p_slow, (n,))
        return jax.vmap(one)(keys)                        # (trials, n) bool

    def step(self, state, keys, n, r):
        def split3(kk):
            return tuple(jax.random.split(kk, 3))
        kb, kc, _ = jax.vmap(split3)(keys)
        # advance the regime chain first: the sampled round reflects the
        # post-transition regime, and round-1 output already matches the
        # stationary marginal (init is stationary).
        def chain(kk):
            return jax.random.uniform(kk, (n,))
        u = jax.vmap(chain)(kc)                           # (trials, n)
        slow_now = jnp.where(state, u >= self._p_sf, u < self._p_fs)
        T1, T2 = _per_trial(self.base, kb, n, r)
        f = jnp.where(slow_now[..., None], self.slow, 1.0)
        f = f * _scale_column(self.worker_scale, n)
        return slow_now, T1 * f, T2 * f


@dataclasses.dataclass(frozen=True)
class AR1Process(DelayProcess):
    """Smoothly drifting worker speeds: a per-worker AR(1) latent
    ``x' = rho * x + sigma * sqrt(1 - rho^2) * eps`` (stationary N(0, sigma^2))
    multiplies delays by ``exp(x - sigma^2 / 2)`` (unit-mean log-normal).
    ``rho`` is the round-to-round correlation of the log speed; ``sigma``
    its dispersion.  ``worker_scale`` as in ``MarkovRegimeProcess``."""
    base: DelayModel = TruncatedGaussianDelays()
    worker_scale: tuple | float = 1.0
    rho: float = 0.9
    sigma: float = 0.3

    def __post_init__(self):
        if not -1.0 < self.rho < 1.0:
            raise ValueError(f"rho must be in (-1, 1), got {self.rho}")

    def init(self, keys, n):
        def one(kk):
            return self.sigma * jax.random.normal(kk, (n,))
        return jax.vmap(one)(keys)                        # (trials, n)

    def step(self, state, keys, n, r):
        def split3(kk):
            return tuple(jax.random.split(kk, 3))
        kb, kx, _ = jax.vmap(split3)(keys)
        eps = jax.vmap(lambda kk: jax.random.normal(kk, (n,)))(kx)
        x = self.rho * state + self.sigma * np.sqrt(1.0 - self.rho ** 2) * eps
        T1, T2 = _per_trial(self.base, kb, n, r)
        f = jnp.exp(x - 0.5 * self.sigma ** 2)[..., None]
        f = f * _scale_column(self.worker_scale, n)
        return x, T1 * f, T2 * f


def message_comm_delays(T2: Array, messages: int,
                        eps: float = 0.0) -> Array:
    """Per-message communication delay draws for a round sending ``messages``
    messages per worker: the draw at each message's closing slot.  ``T2`` has
    shape (..., n, r); returns (..., n, messages).  ``messages = r`` returns
    the per-slot draws unchanged (when ``eps`` is 0).

    ``eps`` is the per-message protocol overhead of Ozfatura et al.
    (arXiv:2004.04948)'s communication/computation trade-off: each message
    costs a fixed ``eps`` of serialized uplink time, so a worker's l-th
    message (0-indexed) carries ``(l + 1) * eps`` of accumulated overhead.
    More messages deliver early results sooner but push the *late* messages
    further out — which is why an optimal budget ``1 <= m* <= r`` exists
    instead of ``m = r`` always winning (see ``benchmarks.fig9``)."""
    from .montecarlo import message_boundaries
    r = T2.shape[-1]
    if int(messages) == r and not eps:
        return T2
    d = (T2 if int(messages) == r
         else T2[..., jnp.asarray(message_boundaries(r, messages))])
    if eps:
        d = d + eps * jnp.arange(1, int(messages) + 1, dtype=T2.dtype)
    return d


def as_process(delay) -> DelayProcess:
    """Coerce any delay source into a ``DelayProcess``:

    * ``DelayProcess`` instances pass through unchanged;
    * a stateless ``DelayModel`` becomes the zero-correlation
      ``IIDProcess`` shim;
    * a recorded ``DelayTrace`` becomes a ``TraceProcess`` replay (default
      strict padding policies — build the ``TraceProcess`` yourself for
      cycle/hold extension).
    """
    if isinstance(delay, DelayProcess):
        return delay
    if isinstance(delay, DelayModel):
        return IIDProcess(delay)
    from .trace import DelayTrace, TraceProcess    # late: trace imports us
    if isinstance(delay, DelayTrace):
        return TraceProcess(delay)
    raise TypeError(
        f"cannot interpret {type(delay).__name__!r} as a delay source: "
        f"expected a DelayProcess (init/step protocol, e.g. IIDProcess, "
        f"MarkovRegimeProcess, AR1Process, TraceProcess), a stateless "
        f"DelayModel (e.g. TruncatedGaussianDelays), or a recorded "
        f"DelayTrace; got {delay!r}")


def heterogeneous_scales(n: int, spread: float = 2.0, seed: int = 0) -> tuple:
    """Per-worker speed multipliers geometrically spread over
    ``[1/sqrt(spread), sqrt(spread)]`` (geometric mean 1), randomly permuted
    so worker index carries no information.  ``spread=1`` is homogeneous."""
    if spread < 1.0:
        raise ValueError(f"spread must be >= 1, got {spread}")
    if n == 1 or spread == 1.0:
        return tuple([1.0] * n)
    rng = np.random.default_rng(seed)
    log_s = np.linspace(-0.5, 0.5, n) * np.log(spread)
    return tuple(np.exp(rng.permutation(log_s)).tolist())


def ec2_cluster(n: int, *, spread: float = 2.0, p_slow: float = 0.2,
                persistence: float = 0.9, slow: float = 5.0,
                base: DelayModel | None = None,
                seed: int = 0) -> MarkovRegimeProcess:
    """A realistic heterogeneous, persistent-straggler cluster: the paper's
    EC2-calibrated truncated-Gaussian base (``ec2_like``: communication
    dominates computation, mild per-worker mean spread), an additional
    machine-speed spread, and a sticky slow/fast regime chain."""
    if base is None:
        base = ec2_like(n, seed=seed)
    return MarkovRegimeProcess(
        base=base, worker_scale=heterogeneous_scales(n, spread, seed),
        p_slow=p_slow, persistence=persistence, slow=slow)

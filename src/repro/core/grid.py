"""Streaming grid-sweep engine: full (scheme family × load × message budget
× comm_eps × k) grids at 10^8-trial scale without per-cell recompilation or
dispatch stalls.

The paper's central object is the average completion time as a *function*
of computation load and computation target, but evaluating every point of
that surface as its own ``sweep`` call leaves two kinds of time on the
table:

1. **Recompiles.**  ``stream_grid`` rides the shape-bucketed executor
   cache (``montecarlo._eval_layout``): every cell whose scheme-kind
   structure lands in the same ``(n, r_max, ks, counts)`` bucket reuses
   one compiled program with its own runtime gather plans — at most one
   compile per shape bucket for the whole grid.

2. **Dispatch stalls.**  Cells that share their draw-defining coordinates
   ``(n, r_max, ks, trials, seed, chunk, model)`` are *fused* into one
   multi-spec sweep (bit-exact with the per-cell path under common random
   numbers: same ``fold_in`` trial keys, same ``(n, r_max)`` delay draws,
   independent per-spec evaluation, same global-chunk-order float64 host
   combine) — amortizing the dominant cost, delay sampling, across every
   scheme at that load.  Groups that cannot fuse are *pipelined*: group
   ``j+1`` is dispatched while group ``j``'s per-chunk float32 partials
   are still in flight (JAX async dispatch; a small double-buffered
   window), so the device never idles on the host combine.

Rounds-axis cells (``GridCell(rounds=..., k=...)``) are evaluated per cell
through ``sweep_rounds`` — the adaptive rounds scan bakes its specs into
the compiled program, so rounds cells neither fuse nor bucket; they are
supported so one grid artifact can carry both surfaces.

``stream_grid`` returns a :class:`GridResult` whose versioned JSON
artifact (``save``/``load``) is the interchange format for the planned
cluster planner (ROADMAP) and the CI grid smoke leg.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from . import montecarlo as mc
from .montecarlo import (SchemeSpec, lb_spec, pc_spec, pcmm_spec, sweep_rounds,
                         to_spec)
from .scheduling import (cyclic_to_matrix, random_assignment_to_matrix,
                         staircase_to_matrix)
from .spec import _internal

__all__ = ["GridCell", "GridSpec", "GridResult", "stream_grid",
           "GRID_FORMAT_VERSION", "FAMILIES"]

GRID_FORMAT_VERSION = 1

#: scheme families ``GridSpec`` can enumerate.  ``cs``/``ss``/``ra`` are the
#: paper's TO-matrix schedules, ``lb`` the oracle bound, ``pc``/``pcmm`` the
#: coded schemes (their decode thresholds ignore the sweep ``k``).
FAMILIES = ("cs", "ss", "ra", "lb", "pc", "pcmm")


@dataclasses.dataclass(frozen=True)
class GridCell:
    """One grid point: a named spec set evaluated at fixed MC coordinates.

    Single-round cells (``rounds=None``) go through the fused/pipelined
    ``sweep`` path; rounds cells (``rounds`` + ``k`` set) through
    ``sweep_rounds`` with the usual adaptive/deadline knobs."""
    name: str
    specs: Tuple[SchemeSpec, ...]
    n: int
    model: object
    trials: int = 20000
    seed: int = 0
    chunk: Optional[int] = None
    ks: Optional[int] = None
    # rounds-axis cells:
    rounds: Optional[int] = None
    k: Optional[int] = None
    feedback_beta: float = 0.7
    coverage_gamma: float = 0.5
    censored_feedback: bool = False
    deadline: Optional[float] = None
    deadline_policy: str = "wait"

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        if not self.specs:
            raise ValueError(f"cell {self.name!r}: need at least one spec")
        if (self.rounds is None) != (self.k is None):
            raise ValueError(f"cell {self.name!r}: rounds cells need both "
                             f"rounds= and k= (got rounds={self.rounds}, "
                             f"k={self.k})")

    @property
    def is_rounds(self) -> bool:
        return self.rounds is not None

    @property
    def r_max(self) -> int:
        """The cell's slot-grid width — the draw-shape the per-cell path
        samples at, so only cells with equal ``r_max`` may fuse."""
        return max(sp.load for sp in self.specs)


def _family_spec(fam: str, n: int, r: int, m: Optional[int], eps: float,
                 seed: int) -> Optional[SchemeSpec]:
    """The family's spec at one (r, messages, comm_eps) point, or None when
    the combination is infeasible for that family (skipped, not an error —
    a declarative grid naturally contains corners like pc × messages=4)."""
    if m is not None and m > r:
        return None
    if fam in ("cs", "ss", "ra"):
        if fam == "ra" and r != n:     # RA permutes full columns: r == n
            return None
        C = {"cs": cyclic_to_matrix, "ss": staircase_to_matrix,
             "ra": lambda nn, rr: random_assignment_to_matrix(
                 nn, rr, seed=seed)}[fam](n, r)
        return to_spec(fam, C, messages=m, comm_eps=eps)
    if fam == "lb":
        return lb_spec(r, messages=m, comm_eps=eps)
    if fam == "pc":
        # one-shot by construction; no per-message overhead model
        if eps or (m is not None and m != 1):
            return None
        return pc_spec(r)
    if fam == "pcmm":
        if eps or n * r < 2 * n - 1:       # no overhead model / infeasible
            return None
        return pcmm_spec(r, messages=m)
    raise ValueError(f"unknown scheme family {fam!r}; have {FAMILIES}")


def _cell_name(fam: str, r: int, m: Optional[int], eps: float,
               k: Optional[int]) -> str:
    parts = [fam, f"r{r}"]
    if m is not None:
        parts.append(f"m{m}")
    if eps:
        parts.append(f"eps{eps:g}")
    if k is not None:
        parts.append(f"k{k}")
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Declarative grid: the cross product of scheme families × loads ×
    message budgets × per-message overheads × computation targets, at
    shared MC coordinates.  Infeasible corners (pc × multi-message,
    pcmm below its decode threshold, budgets above the load) are skipped.

    ``ks`` entries are computation targets: ``None`` = all-k mode (one
    sort yields every k in 1..n), an int = that single order statistic.

    JSON round-trip (``to_json``/``from_json``) is the CLI input format of
    ``python -m repro.launch.grid``.
    """
    n: int
    families: Tuple[str, ...] = ("cs", "ss", "lb", "pc")
    loads: Tuple[int, ...] = (2,)
    messages: Tuple[Optional[int], ...] = (None,)
    comm_eps: Tuple[float, ...] = (0.0,)
    ks: Tuple[Optional[int], ...] = (None,)
    trials: int = 20000
    seed: int = 0
    chunk: Optional[int] = None

    def __post_init__(self):
        for f2 in ("families", "loads", "messages", "comm_eps", "ks"):
            object.__setattr__(self, f2, tuple(getattr(self, f2)))
        bad = [f2 for f2 in self.families if f2 not in FAMILIES]
        if bad:
            raise ValueError(f"unknown families {bad}; have {FAMILIES}")
        if not (self.families and self.loads and self.messages
                and self.comm_eps and self.ks):
            raise ValueError("every grid axis needs at least one value")

    def cells(self, model) -> Tuple[GridCell, ...]:
        """Enumerate the grid as one single-spec ``GridCell`` per feasible
        (family, r, messages, eps, k) point, all sharing ``model`` and the
        MC coordinates — maximally fusable by ``stream_grid``."""
        out = []
        for r in self.loads:
            for fam in self.families:
                for m in self.messages:
                    for eps in self.comm_eps:
                        sp = _family_spec(fam, self.n, r, m, eps, self.seed)
                        if sp is None:
                            continue
                        for k in self.ks:
                            out.append(GridCell(
                                name=_cell_name(fam, r, m, eps, k),
                                specs=(sp,), n=self.n, model=model,
                                trials=self.trials, seed=self.seed,
                                chunk=self.chunk, ks=k))
        if not out:
            raise ValueError("grid is empty: every (family, load, budget) "
                             "combination was infeasible")
        return tuple(out)

    def to_json(self) -> dict:
        return {"version": GRID_FORMAT_VERSION, "kind": "grid-spec",
                "n": self.n, "families": list(self.families),
                "loads": list(self.loads),
                "messages": list(self.messages),
                "comm_eps": list(self.comm_eps), "ks": list(self.ks),
                "trials": self.trials, "seed": self.seed,
                "chunk": self.chunk}

    @classmethod
    def from_json(cls, doc: dict) -> "GridSpec":
        if doc.get("kind", "grid-spec") != "grid-spec":
            raise ValueError(f"not a grid-spec document: "
                             f"kind={doc.get('kind')!r}")
        v = doc.get("version", GRID_FORMAT_VERSION)
        if v > GRID_FORMAT_VERSION:
            raise ValueError(f"grid-spec version {v} is newer than this "
                             f"reader ({GRID_FORMAT_VERSION})")
        kw = {k2: doc[k2] for k2 in ("n", "families", "loads", "messages",
                                     "comm_eps", "ks", "trials", "seed",
                                     "chunk") if k2 in doc}
        return cls(**kw)


# ------------------------------ result artifact ------------------------------

_ARRAY_FIELDS = ("means", "stderr", "per_round", "wallclock",
                 "wallclock_stderr")


def _jsonable(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.floating, np.integer)):
        return x.item()
    if isinstance(x, dict):
        return {k2: _jsonable(v) for k2, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


def _arrays_back(cell: dict) -> dict:
    out = dict(cell)
    for f2 in _ARRAY_FIELDS:
        if f2 in out:
            out[f2] = {k2: np.asarray(v, np.float64)
                       for k2, v in out[f2].items()}
    if out.get("degradation"):
        out["degradation"] = {
            nm: {k2: np.asarray(v, np.float64) for k2, v in d.items()}
            for nm, d in out["degradation"].items()}
    return out


@dataclasses.dataclass
class GridResult:
    """Per-cell statistics of one ``stream_grid`` run plus run metadata
    (cells/sec, shape-bucket count, fused dispatch count, devices).

    ``cells[name]`` is a plain dict: ``kind`` (``"sweep"``/``"rounds"``),
    the cell's MC coordinates, and its statistics — ``means``/``stderr``
    per scheme for sweep cells (one column per k in all-k mode), the
    ``sweep_rounds`` streams (``per_round``, ``wallclock``, stderrs, and
    ``degradation`` when a deadline was set) for rounds cells.  The JSON
    artifact is versioned and round-trips through ``save``/``load``.
    """
    cells: Dict[str, dict]
    meta: dict = dataclasses.field(default_factory=dict)

    def cell(self, name: str) -> dict:
        if name not in self.cells:
            raise ValueError(f"unknown grid cell {name!r}; have "
                            f"{sorted(self.cells)[:8]}...")
        return self.cells[name]

    def means(self, name: str, scheme: Optional[str] = None) -> np.ndarray:
        c = self.cell(name)
        schemes = sorted(c["means"])
        if scheme is None:
            if len(schemes) != 1:
                raise ValueError(f"cell {name!r} has schemes {schemes}; "
                                 f"pass scheme=")
            scheme = schemes[0]
        return c["means"][scheme]

    def best_cell(self, metric: str = "mean", k: Optional[int] = None,
                  exclude: Tuple[str, ...] = ("lb",),
                  z: float = 2.0) -> dict:
        """Argmin operating point of the grid at computation target ``k``
        (defaults to each cell's ``ks``, else ``n``): the (cell, scheme)
        pair with the smallest mean completion time over the sweep cells.

        ``exclude`` drops schemes by name (default: the oracle ``lb``
        bound, which would always win but is not schedulable).  Returns
        ``{"cell", "scheme", "mean", "stderr", "ties"}`` where ``ties``
        lists the runner-up (cell, scheme) pairs whose gap to the winner
        is within ``z`` combined standard errors — the resolution limit
        of the grid's trial budget.  Rounds cells are skipped (their
        metric is a stream, not a scalar)."""
        if metric != "mean":
            raise ValueError(f"unknown metric {metric!r}; only 'mean'")
        entries = []
        for nm, c in self.cells.items():
            if c.get("kind") != "sweep":
                continue
            fixed = set(c.get("fixed", ()))
            for scheme, v in c["means"].items():
                if scheme in exclude:
                    continue
                v = np.atleast_1d(np.asarray(v, np.float64))
                se = np.atleast_1d(np.asarray(c["stderr"][scheme],
                                              np.float64))
                if v.shape[-1] == 1 or scheme in fixed:
                    col = 0
                else:
                    kk = k if k is not None else (c.get("ks") or c["n"])
                    if not 1 <= kk <= v.shape[-1]:
                        raise ValueError(f"cell {nm!r} scheme {scheme!r}: "
                                         f"need 1 <= k <= {v.shape[-1]}, "
                                         f"got {kk}")
                    col = int(kk) - 1
                entries.append((nm, scheme, float(v[col]), float(se[col])))
        if not entries:
            raise ValueError("grid has no scorable sweep cells after "
                             f"excluding {exclude}")
        nm, scheme, mu, se = min(entries, key=lambda e: e[2])
        ties = [{"cell": e[0], "scheme": e[1], "mean": e[2],
                 "stderr": e[3]}
                for e in entries if e[0] != nm or e[1] != scheme
                if e[2] - mu <= z * math.hypot(se, e[3])]
        return {"cell": nm, "scheme": scheme, "mean": mu, "stderr": se,
                "ties": ties}

    @property
    def cells_per_sec(self) -> float:
        return self.meta.get("cells_per_sec", float("nan"))

    def to_json(self) -> dict:
        return {"version": GRID_FORMAT_VERSION, "kind": "grid-result",
                "meta": _jsonable(self.meta),
                "cells": {nm: _jsonable(c) for nm, c in self.cells.items()}}

    def save(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)
        return path

    @classmethod
    def load(cls, path: str) -> "GridResult":
        with open(path) as fh:
            doc = json.load(fh)
        if doc.get("kind") != "grid-result":
            raise ValueError(f"{path}: not a grid-result artifact "
                             f"(kind={doc.get('kind')!r})")
        v = doc.get("version", 0)
        if v > GRID_FORMAT_VERSION:
            raise ValueError(f"{path}: grid-result version {v} is newer "
                             f"than this reader ({GRID_FORMAT_VERSION})")
        return cls(cells={nm: _arrays_back(c)
                          for nm, c in doc["cells"].items()},
                   meta=doc.get("meta", {}))


# ----------------------------- streaming driver ------------------------------

def _model_key(model):
    """Fusion-group identity of a delay model: hashable models group by
    equality (frozen dataclasses), unhashable custom models by object
    identity — never across distinct objects."""
    try:
        hash(model)
        return model
    except TypeError:
        return id(model)


def stream_grid(cells: Sequence[GridCell], *, devices=None,
                pipeline: int = 2) -> GridResult:
    """Evaluate every cell, fusing cells that share their draw-defining
    coordinates into one multi-spec sweep and keeping up to ``pipeline``
    fused dispatches in flight (double-buffered by default).

    Bit-exactness contract: every cell's ``means``/``stderr`` are
    bit-identical to a standalone per-cell ``sweep`` (or ``sweep_rounds``)
    at the same coordinates — fusion only widens the evaluator spec stack
    over the SAME ``(n, r_max)`` delay draws, and the float64 host combine
    runs in global chunk order either way.  Pinned by
    ``tests/test_grid.py`` across dense/ragged × budgets × device counts.
    """
    cells = tuple(cells)
    if not cells:
        raise ValueError("need at least one GridCell")
    names = [c.name for c in cells]
    dup = [nm for nm, cnt in collections.Counter(names).items() if cnt > 1]
    if dup:
        raise ValueError(f"duplicate grid cell names: {dup}")
    if pipeline < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {pipeline}")

    t0 = time.perf_counter()
    sweep_cells = [c for c in cells if not c.is_rounds]
    rounds_cells = [c for c in cells if c.is_rounds]

    # ---- fuse sweep cells sharing their draw-defining coordinates ----
    groups: Dict[tuple, list] = {}
    for c in sweep_cells:
        key = (c.n, c.r_max, c.ks, c.trials, c.seed, c.chunk,
               _model_key(c.model))
        groups.setdefault(key, []).append(c)

    results: Dict[str, dict] = {}
    sigs = set()
    pending: collections.deque = collections.deque()

    def _resolve_one() -> None:
        grp, handle = pending.popleft()
        means, stderr = handle.resolve()
        for cell in grp:
            results[cell.name] = {
                "kind": "sweep", "n": cell.n, "trials": cell.trials,
                "seed": cell.seed, "ks": cell.ks,
                "means": {sp.name: np.atleast_1d(
                    means[f"{cell.name}:{sp.name}"]) for sp in cell.specs},
                "stderr": {sp.name: np.atleast_1d(
                    stderr[f"{cell.name}:{sp.name}"]) for sp in cell.specs},
                "fixed": [sp.name for sp in cell.specs
                          if sp.kind in ("pc", "pcmm")],
            }

    for key, grp in groups.items():
        c0 = grp[0]
        # spec names are only unique per cell — prefix with the cell name
        # (outside the compiled program: outputs are group-keyed, so the
        # renames never retrace).
        fused = []
        with _internal():
            for cell in grp:
                for sp in cell.specs:
                    fused.append(dataclasses.replace(
                        sp, name=f"{cell.name}:{sp.name}"))
        sig, _, _ = mc._eval_layout(tuple(fused), c0.n, c0.r_max, c0.ks)
        sigs.add(sig)
        while len(pending) >= pipeline:       # keep the window bounded
            _resolve_one()
        pending.append((grp, mc._dispatch_run(
            fused, c0.model, c0.n, trials=c0.trials, seed=c0.seed,
            chunk=c0.chunk, ks=c0.ks, want_samples=False, devices=devices)))
    while pending:
        _resolve_one()

    # ---- rounds cells: per-cell sweep_rounds (unfused, unbucketed) ----
    for cell in rounds_cells:
        res = sweep_rounds(cell.specs, cell.model, cell.n,
                           rounds=cell.rounds, k=cell.k, trials=cell.trials,
                           seed=cell.seed, chunk=cell.chunk,
                           feedback_beta=cell.feedback_beta,
                           coverage_gamma=cell.coverage_gamma,
                           censored_feedback=cell.censored_feedback,
                           deadline=cell.deadline,
                           deadline_policy=cell.deadline_policy,
                           devices=devices)
        entry = {
            "kind": "rounds", "n": cell.n, "trials": cell.trials,
            "seed": cell.seed, "rounds": cell.rounds, "k": cell.k,
            "deadline": cell.deadline,
            "deadline_policy": cell.deadline_policy,
            "per_round": res.per_round, "stderr": res.stderr,
            "wallclock": res.wallclock,
            "wallclock_stderr": res.wallclock_stderr,
        }
        if res.degradation is not None:
            entry["degradation"] = res.degradation
        results[cell.name] = entry

    seconds = time.perf_counter() - t0
    meta = {"cells": len(cells), "seconds": seconds,
            "cells_per_sec": len(cells) / seconds if seconds > 0 else 0.0,
            "fused_dispatches": len(groups), "buckets": len(sigs),
            "rounds_cells": len(rounds_cells), "pipeline": pipeline,
            "devices": (devices if isinstance(devices, (int, type(None)))
                        else len(tuple(devices)))}
    return GridResult(cells=results, meta=meta)

"""Theorem 1 (paper eqs. 7–8) and the lower bound (Sec. V).

Theorem 1 expresses the completion-time tail through joint task-arrival
survival probabilities:

  Pr{t_C(r,k) > t} = sum_{i=n-k+1}^{n} (-1)^{n-k+i+1} C(i-1, n-k)
                     * sum_{S subset [n], |S|=i} Pr{ t_j > t  for all j in S }

The joint survivals H_S(t) = Pr{t_j > t ∀ j∈S} are, in general, the
high-dimensional integrals (40); the paper evaluates them numerically. Here:

* ``theorem1_tail_from_H`` — the exact combinatorial assembly, given H.
* ``joint_survival_mc``   — H_S(t) estimated from shared delay samples.
* ``theorem1_mean_mc``    — average completion time via Thm 1 + MC H_S.
  (Validating this against the direct order-statistic simulation checks the
  inclusion–exclusion identity itself — see tests/test_theory.py.)
* ``theorem1_tail_r1_independent`` — fully analytic special case r=1 with
  independent per-worker delays: t_j = T1_j + T2_j are independent, so
  H_S(t) = prod_{j in S} S_j(t); survival of the sum via 1-D convolution.
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, Sequence

import numpy as np

from . import montecarlo

__all__ = [
    "theorem1_tail_from_H", "joint_survival_mc", "theorem1_tail_mc",
    "theorem1_mean_mc", "sum_survival_grid", "theorem1_tail_r1_independent",
]


def _coef(n: int, k: int, i: int) -> float:
    """(-1)^{n-k+i+1} * binom(i-1, n-k)."""
    return (-1.0) ** (n - k + i + 1) * math.comb(i - 1, n - k)


def theorem1_tail_from_H(H: Callable[[tuple], np.ndarray], n: int, k: int
                         ) -> np.ndarray:
    """Assemble Pr{t_C > t} from per-subset joint survivals.

    ``H(S)`` must return the vector Pr{t_j > t ∀ j∈S} over the evaluation
    grid. Exponential in n — fine for the paper-scale n ≤ 10 used in tests.
    """
    out = None
    for i in range(n - k + 1, n + 1):
        c = _coef(n, k, i)
        for S in itertools.combinations(range(n), i):
            h = np.asarray(H(S))
            out = c * h if out is None else out + c * h
    return out


def joint_survival_mc(C: np.ndarray, model, tgrid: np.ndarray, *,
                      trials: int = 20000, seed: int = 0,
                      chunk: int | None = None):
    """Return ``H(S)`` closure backed by shared MC samples of task arrivals
    (drawn through the fused sweep engine, so they are the same common
    random numbers the direct order-statistic simulation sees)."""
    tau = np.asarray(montecarlo.task_arrival_samples(
        C, model, trials=trials, seed=seed, chunk=chunk))   # (trials, n)
    tg = np.asarray(tgrid)

    def H(S: tuple) -> np.ndarray:
        # Pr{ t_j > t for all j in S } for each t in grid
        m = tau[:, list(S)].min(axis=1)        # all exceed t  <=>  min exceeds t
        return (m[:, None] > tg[None, :]).mean(axis=0)

    return H


def theorem1_tail_mc(C, model, tgrid, *, trials=20000, seed=0, k):
    """Pr{t_C(r, k) > t} over ``tgrid`` via Theorem 1 with MC-estimated
    joint survivals. ``k`` is a required keyword (the computation target)."""
    n = np.asarray(C).shape[0]
    if not isinstance(k, (int, np.integer)) or not 1 <= int(k) <= n:
        raise ValueError(
            f"k must be an integer computation target in [1, n={n}]; got "
            f"k={k!r}")
    H = joint_survival_mc(C, model, tgrid, trials=trials, seed=seed)
    return theorem1_tail_from_H(H, n, int(k))


def theorem1_mean_mc(C, model, k: int, *, tmax: float, npts: int = 512,
                     trials: int = 20000, seed: int = 0) -> float:
    """Average completion time via eq. (8): integral of the tail."""
    tgrid = np.linspace(0.0, tmax, npts)
    tail = theorem1_tail_mc(C, model, tgrid, trials=trials, seed=seed, k=k)
    return float(np.trapezoid(np.clip(tail, 0.0, 1.0), tgrid))


# -------- analytic special case: r = 1, independent delays -------------------

def sum_survival_grid(pdf1: Callable[[np.ndarray], np.ndarray],
                      pdf2: Callable[[np.ndarray], np.ndarray],
                      tmax: float, npts: int = 4096):
    """Survival function of T1 + T2 for independent T1, T2 with the given
    densities, on a uniform grid via discrete convolution. Returns (tgrid,
    survival)."""
    t = np.linspace(0.0, tmax, npts)
    dt = t[1] - t[0]
    f1 = pdf1(t)
    f2 = pdf2(t)
    fsum = np.convolve(f1, f2)[:npts] * dt          # density of the sum
    cdf = np.cumsum(fsum) * dt
    return t, np.clip(1.0 - cdf, 0.0, 1.0)


def theorem1_tail_r1_independent(survivals: Sequence[np.ndarray], k: int
                                 ) -> np.ndarray:
    """r=1, independent workers: worker i computes only task i, so
    t_j = T1_j + T2_j independent across j and H_S(t) = prod_{j in S} S_j(t).
    ``survivals[j]`` is S_j over the grid."""
    n = len(survivals)
    S_ = [np.asarray(s) for s in survivals]

    def H(Sset: tuple) -> np.ndarray:
        out = np.ones_like(S_[0])
        for j in Sset:
            out = out * S_[j]
        return out

    return theorem1_tail_from_H(H, n, k)

"""Theorem 1 (paper eqs. 7–8) and the lower bound (Sec. V).

Theorem 1 expresses the completion-time tail through joint task-arrival
survival probabilities:

  Pr{t_C(r,k) > t} = sum_{i=n-k+1}^{n} (-1)^{n-k+i+1} C(i-1, n-k)
                     * sum_{S subset [n], |S|=i} Pr{ t_j > t  for all j in S }

The joint survivals H_S(t) = Pr{t_j > t ∀ j∈S} are, in general, the
high-dimensional integrals (40); the paper evaluates them numerically. Here:

* ``theorem1_tail_from_H`` — the exact combinatorial assembly, given H.
* ``joint_survival_mc``   — H_S(t) estimated from shared delay samples.
* ``theorem1_mean_mc``    — average completion time via Thm 1 + MC H_S.
  (Validating this against the direct order-statistic simulation checks the
  inclusion–exclusion identity itself — see tests/test_theory.py.)
* ``theorem1_tail_r1_independent`` — fully analytic special case r=1 with
  independent per-worker delays: t_j = T1_j + T2_j are independent, so
  H_S(t) = prod_{j in S} S_j(t); survival of the sum via 1-D convolution.

Multi-message coded expectations (paper eqs. 51-52 / 56-57 generalized)
-----------------------------------------------------------------------
With an intra-round message budget ``m`` (Sec. V-C; ``SchemeSpec.messages``)
a coded worker's r partial computations arrive in ``m`` lumps; the master
decodes once ``threshold`` partials are in.  For i.i.d. workers the
completion tail is exact given the per-message arrival CDFs — the
delivered-units pmf of one worker convolved n times:

* ``multimessage_marginal_cdfs`` — per-message arrival CDFs on a grid
  (message l = sum of its closing slot's cumulative compute delays + one
  communication draw), via 1-D density convolutions.
* ``multimessage_coded_tail``   — Pr{completion > t} from those CDFs,
  under in-order (FIFO) message delivery within each worker.
* ``multimessage_coded_mean``   — the average completion time (tail
  integral).  ``m=1`` with ``threshold=(2*ceil(n/r)-1-1)*r+1`` is exactly
  PC's eqs. 51-52 (a single message cannot reorder); intermediate and
  ``m=r`` budgets assume FIFO channels, which the MC engine's independent
  per-message draws can violate — agreement with the engine is tight when
  communication dispersion is small against compute spacing (<1% for the
  paper's calibrated models, tested) but degrades as comm noise dominates.

The uncoded schemes' multi-message expectations come from the same
Theorem-1 machinery: ``joint_survival_mc``/``theorem1_tail_mc`` accept a
``messages`` budget and estimate H_S from the engine's remapped arrivals.
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, Sequence

import numpy as np

from . import montecarlo

__all__ = [
    "theorem1_tail_from_H", "joint_survival_mc", "theorem1_tail_mc",
    "theorem1_mean_mc", "lower_bound_tail_mc", "lower_bound_mean_mc",
    "sum_survival_grid", "theorem1_tail_r1_independent",
    "multimessage_marginal_cdfs", "multimessage_coded_tail",
    "multimessage_coded_mean",
    "truncated_gaussian_pdf", "delay_model_pdfs", "operating_point_mean_lb",
]


def _coef(n: int, k: int, i: int) -> float:
    """(-1)^{n-k+i+1} * binom(i-1, n-k)."""
    return (-1.0) ** (n - k + i + 1) * math.comb(i - 1, n - k)


def theorem1_tail_from_H(H: Callable[[tuple], np.ndarray], n: int, k: int
                         ) -> np.ndarray:
    """Assemble Pr{t_C > t} from per-subset joint survivals.

    ``H(S)`` must return the vector Pr{t_j > t ∀ j∈S} over the evaluation
    grid. Exponential in n — fine for the paper-scale n ≤ 10 used in tests.
    """
    out = None
    for i in range(n - k + 1, n + 1):
        c = _coef(n, k, i)
        for S in itertools.combinations(range(n), i):
            h = np.asarray(H(S))
            out = c * h if out is None else out + c * h
    return out


def joint_survival_mc(C: np.ndarray, model, tgrid: np.ndarray, *,
                      trials: int = 20000, seed: int = 0,
                      chunk: int | None = None,
                      messages: int | None = None,
                      loads=None):
    """Return ``H(S)`` closure backed by shared MC samples of task arrivals
    (drawn through the fused sweep engine, so they are the same common
    random numbers the direct order-statistic simulation sees).
    ``messages`` sets the per-round message budget (Sec. V-C); ``loads``
    generalizes to ragged per-worker loads (``C`` may equivalently carry
    trailing ``MASKED`` sentinels) — a task with no active copy never
    arrives, i.e. survives every ``t``."""
    tau = np.asarray(montecarlo.task_arrival_samples(
        C, model, trials=trials, seed=seed, chunk=chunk,
        messages=messages, loads=loads))                    # (trials, n)
    tg = np.asarray(tgrid)

    def H(S: tuple) -> np.ndarray:
        # Pr{ t_j > t for all j in S } for each t in grid
        m = tau[:, list(S)].min(axis=1)        # all exceed t  <=>  min exceeds t
        return (m[:, None] > tg[None, :]).mean(axis=0)

    return H


def theorem1_tail_mc(C, model, tgrid, *, trials=20000, seed=0, k,
                     messages=None, loads=None):
    """Pr{t_C(r, k) > t} over ``tgrid`` via Theorem 1 with MC-estimated
    joint survivals. ``k`` is a required keyword (the computation target).
    ``loads`` generalizes to ragged per-worker loads — Theorem 1's
    inclusion-exclusion identity holds for any joint arrival distribution,
    so the same assembly applies with the ragged ``H_S``."""
    n = np.asarray(C).shape[0]
    if not isinstance(k, (int, np.integer)) or not 1 <= int(k) <= n:
        raise ValueError(
            f"k must be an integer computation target in [1, n={n}]; got "
            f"k={k!r}")
    H = joint_survival_mc(C, model, tgrid, trials=trials, seed=seed,
                          messages=messages, loads=loads)
    return theorem1_tail_from_H(H, n, int(k))


def theorem1_mean_mc(C, model, k: int, *, tmax: float, npts: int = 512,
                     trials: int = 20000, seed: int = 0,
                     messages: int | None = None, loads=None) -> float:
    """Average completion time via eq. (8): integral of the tail."""
    tgrid = np.linspace(0.0, tmax, npts)
    tail = theorem1_tail_mc(C, model, tgrid, trials=trials, seed=seed, k=k,
                            messages=messages, loads=loads)
    return float(np.trapezoid(np.clip(tail, 0.0, 1.0), tgrid))


def lower_bound_tail_mc(model, n: int, k: int, tgrid, *, r: int | None = None,
                        loads=None, messages: int | None = None,
                        trials: int = 20000, seed: int = 0) -> np.ndarray:
    """Pr{t_LB(k) > t}: the oracle lower bound (eq. 46) generalized to a
    per-worker load vector — the k-th order statistic over all
    ``sum(loads)`` active slot arrivals, estimated from engine samples."""
    samples = np.asarray(montecarlo.completion_samples(
        montecarlo.lb_spec(r, loads=loads, messages=messages), model, n,
        trials=trials, seed=seed, k=k))
    tg = np.asarray(tgrid)
    return (samples[:, None] > tg[None, :]).mean(axis=0)


def lower_bound_mean_mc(model, n: int, k: int, *, r: int | None = None,
                        loads=None, messages: int | None = None,
                        trials: int = 20000, seed: int = 0) -> float:
    """Average oracle lower bound (eq. 46) at load ``r`` or ragged load
    vector ``loads`` (paired with the uncoded schemes' draws under common
    random numbers)."""
    samples = np.asarray(montecarlo.completion_samples(
        montecarlo.lb_spec(r, loads=loads, messages=messages), model, n,
        trials=trials, seed=seed, k=k))
    return float(samples.mean())


# -------- analytic special case: r = 1, independent delays -------------------

def sum_survival_grid(pdf1: Callable[[np.ndarray], np.ndarray],
                      pdf2: Callable[[np.ndarray], np.ndarray],
                      tmax: float, npts: int = 4096):
    """Survival function of T1 + T2 for independent T1, T2 with the given
    densities, on a uniform grid via discrete convolution. Returns (tgrid,
    survival)."""
    t = np.linspace(0.0, tmax, npts)
    dt = t[1] - t[0]
    f1 = pdf1(t)
    f2 = pdf2(t)
    fsum = np.convolve(f1, f2)[:npts] * dt          # density of the sum
    cdf = np.cumsum(fsum) * dt
    return t, np.clip(1.0 - cdf, 0.0, 1.0)


def theorem1_tail_r1_independent(survivals: Sequence[np.ndarray], k: int
                                 ) -> np.ndarray:
    """r=1, independent workers: worker i computes only task i, so
    t_j = T1_j + T2_j independent across j and H_S(t) = prod_{j in S} S_j(t).
    ``survivals[j]`` is S_j over the grid."""
    n = len(survivals)
    S_ = [np.asarray(s) for s in survivals]

    def H(Sset: tuple) -> np.ndarray:
        out = np.ones_like(S_[0])
        for j in Sset:
            out = out * S_[j]
        return out

    return theorem1_tail_from_H(H, n, k)


# -------- multi-message coded completion (eqs. 51-52 / 56-57 generalized) ----

def _convolve_density(f: np.ndarray, g: np.ndarray, dt: float) -> np.ndarray:
    """Density of the sum of two independent variables on the same uniform
    grid (discrete convolution, truncated to the grid)."""
    return np.convolve(f, g)[:len(f)] * dt


def multimessage_marginal_cdfs(pdf1: Callable[[np.ndarray], np.ndarray],
                               pdf2: Callable[[np.ndarray], np.ndarray],
                               r: int, messages: int, tmax: float,
                               npts: int = 2048
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Per-message arrival CDFs of ONE worker on a uniform grid.

    Message ``l`` closes at slot ``b_l`` (``montecarlo.message_boundaries``):
    its arrival is the sum of ``b_l + 1`` i.i.d. per-slot compute delays
    (density ``pdf1``) plus one per-message communication draw (``pdf2``) —
    the sequential-computation model of eq. (1) at the closing slot.
    Returns ``(tgrid, F)`` with ``F`` of shape ``(messages, npts)``.
    """
    t = np.linspace(0.0, tmax, npts)
    dt = t[1] - t[0]
    f1 = pdf1(t)
    f2 = pdf2(t)
    bounds = montecarlo.message_boundaries(r, messages)
    F = np.zeros((messages, npts))
    comp = None                       # density of the cumulative compute sum
    nxt = 0
    for j in range(r):
        comp = f1 if comp is None else _convolve_density(comp, f1, dt)
        if nxt < messages and bounds[nxt] == j:
            dens = _convolve_density(comp, f2, dt)
            F[nxt] = np.clip(np.cumsum(dens) * dt, 0.0, 1.0)
            nxt += 1
    return t, F


def multimessage_coded_tail(F: np.ndarray, group_sizes: Sequence[int],
                            n: int, threshold: int) -> np.ndarray:
    """Pr{completion > t} of the multi-message coded scheme under FIFO
    (in-order) message delivery within each worker.

    ``n`` i.i.d. workers; worker's message ``l`` delivers ``group_sizes[l]``
    coded partials in one lump, with arrival CDF ``F[l]`` (a row per message,
    columns = time grid); the master decodes once ``threshold`` partials
    arrived.  Assuming a worker's messages arrive in send order (a FIFO
    channel — physically natural, exact by construction for one message),
    the worker's delivered-unit count at time t has pmf {P(N=0)=1-F[0],
    P(N=c_l)=F[l]-F[l+1], P(N=c_m)=F[m-1]} over the cumulative counts c_l;
    the total across workers is that pmf convolved n times (counts >=
    threshold are absorbed — they cannot return below it), and the tail is
    Pr{total < threshold}.  Generalizes the order-statistic assemblies of
    eqs. 51-52 (one message) and 56-57 (per-slot messages).

    The MC engine draws each message's communication delay independently,
    so a later message can overtake an earlier one there; this closed form
    is then an approximation whose error grows with the communication
    dispersion relative to the compute spacing between closing slots (<1%
    on the paper's calibrated models, see tests/test_multimessage.py).
    """
    F = np.asarray(F, np.float64)
    m, T = F.shape
    gs = [int(g) for g in group_sizes]
    if len(gs) != m or min(gs) < 1:
        raise ValueError(f"need {m} positive group sizes, got {gs}")
    cum = np.cumsum(gs)
    th = int(threshold)
    if not 1 <= th <= n * int(cum[-1]):
        raise ValueError(f"need 1 <= threshold <= n*r={n * int(cum[-1])}, "
                         f"got {th}")
    probs = np.empty((m + 1, T))
    probs[0] = 1.0 - F[0]
    for l in range(m - 1):
        probs[l + 1] = F[l] - F[l + 1]
    probs[m] = F[m - 1]
    probs = np.clip(probs, 0.0, 1.0)
    counts = [0] + [int(c) for c in cum]
    poly = np.zeros((th, T))          # poly[u] = Pr{units so far == u}
    poly[0] = 1.0
    for _ in range(n):
        new = np.zeros_like(poly)
        for c, p in zip(counts, probs):
            if c < th:                # counts past th are absorbed (done)
                new[c:] += p * poly[:th - c]
        poly = new
    return poly.sum(axis=0)           # Pr{units < threshold}


def _shift_message_cdfs(t: np.ndarray, F: np.ndarray,
                        comm_eps: float) -> np.ndarray:
    """Fold the per-message protocol overhead into the arrival CDFs:
    message ``l`` lands ``(l + 1) * comm_eps`` late (the same static
    offset convention as ``montecarlo._offsets_flat_of``), i.e. its CDF
    shifts right by that amount on the grid."""
    if not comm_eps:
        return F
    return np.stack([np.interp(t - (l + 1) * comm_eps, t, F[l], left=0.0)
                     for l in range(F.shape[0])])


def multimessage_coded_mean(n: int, r: int, messages: int,
                            pdf1: Callable[[np.ndarray], np.ndarray],
                            pdf2: Callable[[np.ndarray], np.ndarray], *,
                            tmax: float, npts: int = 2048,
                            threshold: int | None = None,
                            comm_eps: float = 0.0) -> float:
    """Average completion time of the multi-message coded scheme with
    ``messages`` messages per worker under i.i.d. per-slot compute delays
    (``pdf1``), per-message communication delays (``pdf2``), and FIFO
    delivery within each worker (see ``multimessage_coded_tail``).

    ``threshold=None`` uses PCMM's ``2n - 1`` partials (eqs. 56-57);
    PC's one-shot expectation (eqs. 51-52) is ``messages=1`` with
    ``threshold=(2*ceil(n/r) - 2) * r + 1`` — i.e. ``2*ceil(n/r) - 1`` full
    workers, since units then arrive in lumps of ``r``.
    """
    t, F = multimessage_marginal_cdfs(pdf1, pdf2, r, messages, tmax, npts)
    F = _shift_message_cdfs(t, F, comm_eps)
    gs = montecarlo.message_group_sizes(r, messages)
    th = 2 * n - 1 if threshold is None else int(threshold)
    tail = multimessage_coded_tail(F, gs, n, th)
    return float(np.trapezoid(np.clip(tail, 0.0, 1.0), t))


# -------- planner dominance guides (repro.core.planner) ----------------------

def truncated_gaussian_pdf(mu: float, sigma: float, a: float,
                           b: float | None = None
                           ) -> Callable[[np.ndarray], np.ndarray]:
    """Density of ``N(mu, sigma^2)`` truncated to ``[mu - a, mu + b]``
    (``b`` defaults to ``a``, the paper's symmetric truncation) — the
    closed-form marginal of ``repro.core.delays.TruncatedGaussianDelays``
    with scalar mean and ``rho == 0``."""
    b = a if b is None else b
    lo, hi = mu - a, mu + b
    sq2 = math.sqrt(2.0)
    Z = 0.5 * (math.erf((hi - mu) / (sigma * sq2))
               - math.erf((lo - mu) / (sigma * sq2)))
    norm = sigma * math.sqrt(2.0 * math.pi) * Z

    def pdf(t: np.ndarray) -> np.ndarray:
        t = np.asarray(t, np.float64)
        z = (t - mu) / sigma
        d = np.exp(-0.5 * z * z) / norm
        return np.where((t >= lo) & (t <= hi), d, 0.0)

    return pdf


def delay_model_pdfs(model):
    """``(pdf1, pdf2, sup1, sup2)`` — closed-form per-slot compute and
    per-message communication densities plus their supports' upper ends —
    for models whose marginals are analytically known: currently
    ``TruncatedGaussianDelays`` with scalar means and ``rho == 0`` (the
    paper's scenario 1 calibration).  ``None`` otherwise (per-worker mean
    vectors or correlated slots have no shared i.i.d. marginal); the
    planner then skips its theory-pruning stage and races every cell."""
    from .delays import TruncatedGaussianDelays
    if not isinstance(model, TruncatedGaussianDelays) or model.rho:
        return None
    if not (np.isscalar(model.mu1) and np.isscalar(model.mu2)):
        return None
    b1 = model.a1 if model.b1 is None else model.b1
    b2 = model.a2 if model.b2 is None else model.b2
    pdf1 = truncated_gaussian_pdf(float(model.mu1), model.sigma1,
                                  model.a1, b1)
    pdf2 = truncated_gaussian_pdf(float(model.mu2), model.sigma2,
                                  model.a2, b2)
    return pdf1, pdf2, float(model.mu1) + b1, float(model.mu2) + b2


def operating_point_mean_lb(n: int, r: int, k: int,
                            pdf1: Callable[[np.ndarray], np.ndarray],
                            pdf2: Callable[[np.ndarray], np.ndarray], *,
                            messages: int | None = None,
                            comm_eps: float = 0.0, tmax: float,
                            npts: int = 1024) -> float:
    """Closed-form guide for the oracle lower bound (eq. 46) at one
    operating point: the mean time until ``k`` slot results arrived,
    counting every one of the ``n * r`` slots' arrivals grouped into
    ``min(messages, r)`` messages per worker (message ``l`` shifted by the
    ``(l + 1) * comm_eps`` protocol overhead).  Distinctness of the
    delivered tasks is ignored — exactly the engine's ``lb_spec``
    semantics — so no schedule at ``(r, messages, comm_eps)`` can beat it.
    Like ``multimessage_coded_tail`` this assumes in-order message
    delivery within a worker, so it is a *guide* (tight at the paper's
    calibrations, approximate when communication dispersion dominates):
    the planner prunes on it only with a slack factor."""
    m_eff = r if messages is None else int(min(messages, r))
    t, F = multimessage_marginal_cdfs(pdf1, pdf2, r, m_eff, tmax, npts)
    F = _shift_message_cdfs(t, F, comm_eps)
    gs = montecarlo.message_group_sizes(r, m_eff)
    tail = multimessage_coded_tail(F, gs, n, int(k))
    return float(np.trapezoid(np.clip(tail, 0.0, 1.0), t))

"""Trace-driven delay sources: record, replay, and calibrate real clusters.

The paper's headline results (Sec. VI) come from a *measured* Amazon EC2
cluster, while every other delay source in this repo is a parametric model
we invented.  This module closes that gap with three pieces:

``DelayTrace``
    An immutable per-(round, trial, worker, slot) table of realized
    computation (``T1``) and communication (``T2``) delays — the thing a
    real master's timestamp log reduces to.  Traces come from three
    places: ``sweep_rounds(..., record_trace=True)`` /
    ``trajectory_samples(..., record_trace=True)`` capture the delay
    tensors actually drawn inside the fused rounds scan;
    ``launch/train.py --log-delays`` logs them from a live training run;
    and ``load_trace`` reads the versioned on-disk format (an ``.npz``
    with a JSON header — see ``save_trace``).

``TraceProcess``
    The replay backend: a ``DelayProcess`` whose ``step`` *reads* the
    trace instead of sampling, so recorded clusters flow through every
    layer built on the process API — ``sweep_rounds`` figures, the
    aggregator, the train step — unchanged.  Replay is deterministic
    (PRNG keys are ignored) and common-random-number compatible: the
    per-trial table rides on the engine's trial ids, so replaying a
    recorded run reproduces its completion times and adaptive decisions
    bit-exactly under any trial chunking.  Shape mismatches between the
    trace and the requested run are governed by explicit per-axis
    policies (``pad_rounds`` / ``pad_workers`` / ``pad_slots``):
    truncation (asking for less than was recorded) is always allowed —
    delay statistics are slot-order-independent (paper Remark 6) — while
    extension either raises (``"error"``, the default), wraps around
    (``"cycle"``), or, for rounds only, holds the final round
    (``"hold"``).  The trial axis always cycles, so a single recorded
    realization replays across any number of Monte-Carlo trials.

``calibrate_trace``
    Fits the parametric cluster models to a trace so ``ec2_cluster``-style
    synthetic clusters can be *derived from data*: per-worker speed scales
    (mean-ratio estimates on the fast regime — the exact MLE for scale
    families like the shifted exponential), a slow/fast regime
    segmentation (between-class-variance threshold on log per-round
    worker means, Otsu-style) giving ``p_slow`` / ``slow`` / the chain's
    ``persistence`` from observed transition counts, and a truncated-
    Gaussian base refit.  The returned ``CalibrationReport`` carries the
    assembled ``MarkovRegimeProcess`` plus a fit-quality report (moment
    and lag-1-autocorrelation errors of the fitted process vs the trace).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .cluster import DelayProcess, MarkovRegimeProcess
from .delays import TruncatedGaussianDelays

__all__ = [
    "TRACE_FORMAT_VERSION", "DelayTrace", "TraceProcess", "save_trace",
    "load_trace", "validate_trace_file", "CalibrationReport",
    "calibrate_trace",
]

TRACE_FORMAT_VERSION = 2       # v2: +inf delay cells (fault censoring)

_PAD_ROUNDS = ("error", "cycle", "hold")
_PAD_AXES = ("error", "cycle")


# ------------------------------ the container --------------------------------

class DelayTrace:
    """Realized per-(round, trial, worker, slot) compute/comm delay tables.

    ``T1``/``T2`` are float32 arrays of shape ``(rounds, trials, n, r)``;
    a 3-D ``(rounds, n, r)`` input (a single recorded realization — what a
    real cluster log yields) gets a singleton trial axis.  Instances are
    immutable, hashable (by content digest) and comparable by content, so
    ``TraceProcess`` works with the fused engine's compiled-evaluator
    cache exactly like the parametric processes.
    """

    __slots__ = ("T1", "T2", "meta", "_digest")

    def __init__(self, T1, T2, meta: Optional[dict] = None):
        # own copies: freezing an aliased caller array in place would make
        # *their* array read-only, and a shared buffer would let later
        # caller mutations silently break the content-digest identity
        T1 = np.array(T1, np.float32)
        T2 = np.array(T2, np.float32)
        if T1.ndim == 3:
            T1, T2 = T1[:, None], (T2[:, None] if T2.ndim == 3 else T2)
        if T1.ndim != 4:
            raise ValueError(
                f"trace tables must be (rounds, n, r) or (rounds, trials, "
                f"n, r); got shape {T1.shape}")
        if T2.shape != T1.shape:
            raise ValueError(f"T1/T2 shape mismatch: {T1.shape} vs "
                             f"{T2.shape}")
        if 0 in T1.shape:
            raise ValueError(f"empty trace: shape {T1.shape}")
        # +inf is a legal cell value — fault censoring (a preempted /
        # partitioned worker's result never arrives); NaN and non-positive
        # (including -inf) delays are corrupt.
        if np.isnan(T1).any() or np.isnan(T2).any():
            raise ValueError("trace delays must not be NaN")
        if (T1 <= 0).any() or (T2 <= 0).any():
            raise ValueError("trace delays must be positive")
        T1.setflags(write=False)
        T2.setflags(write=False)
        object.__setattr__(self, "T1", T1)
        object.__setattr__(self, "T2", T2)
        object.__setattr__(self, "meta", dict(meta or {}))
        h = hashlib.sha1()
        h.update(np.int64(T1.shape).tobytes())
        h.update(T1.tobytes())
        h.update(T2.tobytes())
        object.__setattr__(self, "_digest", h.hexdigest())

    def __setattr__(self, *a):                       # immutability
        raise AttributeError("DelayTrace is immutable")

    # content identity: the engine caches compiled evaluators per process,
    # and a TraceProcess's compiled program is a function of the tables.
    def __hash__(self):
        return hash(self._digest)

    def __eq__(self, other):
        return (isinstance(other, DelayTrace)
                and self._digest == other._digest)

    def __repr__(self):
        return (f"DelayTrace(rounds={self.rounds}, trials={self.trials}, "
                f"n={self.n}, r={self.r}, digest={self._digest[:8]})")

    @property
    def rounds(self) -> int:
        return self.T1.shape[0]

    @property
    def trials(self) -> int:
        return self.T1.shape[1]

    @property
    def n(self) -> int:
        return self.T1.shape[2]

    @property
    def r(self) -> int:
        return self.T1.shape[3]

    @property
    def has_faults(self) -> bool:
        """True when any cell is +inf (fault-censored arrivals)."""
        return bool(np.isinf(self.T1).any() or np.isinf(self.T2).any())

    def header(self) -> dict:
        """The JSON header written by ``save_trace``.  Fault-free traces
        keep writing format version 1, so files produced without fault
        injection stay readable by pre-fault readers; +inf cells bump the
        header to version 2 (which those readers correctly reject)."""
        faulty = self.has_faults
        hdr = {"format": "repro.delay_trace",
               "version": 2 if faulty else 1,
               "rounds": self.rounds, "trials": self.trials,
               "n": self.n, "r": self.r, "dtype": "float32",
               "digest": self._digest, "meta": self.meta}
        if faulty:
            hdr["faults"] = True
        return hdr


# --------------------------- on-disk format ----------------------------------
# A trace file is a ``.npz`` with exactly three members:
#   header — JSON (bytes) with format/version/shape/digest/meta fields;
#   T1, T2 — float32 (rounds, trials, n, r) delay tables.
# The digest covers the tables, so corruption and header/table mismatches
# are detected at load time.  Unknown *newer* versions are rejected rather
# than misread.

def save_trace(path: str, trace: DelayTrace) -> str:
    """Write ``trace`` to ``path`` in the versioned npz+JSON-header format
    (appends ``.npz`` if missing).  Returns the path written."""
    if not str(path).endswith(".npz"):
        path = f"{path}.npz"
    hdr = trace.header()
    hdr["created_unix"] = time.time()
    np.savez_compressed(path,
                        header=np.frombuffer(
                            json.dumps(hdr).encode(), dtype=np.uint8),
                        T1=trace.T1, T2=trace.T2)
    return path


def _read_header(z) -> dict:
    if "header" not in z:
        raise ValueError("not a delay-trace file: missing 'header' member")
    try:
        hdr = json.loads(bytes(z["header"].tobytes()).decode())
    except Exception as e:
        raise ValueError(f"corrupt delay-trace header: {e}") from e
    if hdr.get("format") != "repro.delay_trace":
        raise ValueError(f"not a delay-trace file: format="
                         f"{hdr.get('format')!r}")
    if int(hdr.get("version", -1)) > TRACE_FORMAT_VERSION:
        raise ValueError(
            f"delay-trace version {hdr.get('version')} is newer than this "
            f"reader (supports <= {TRACE_FORMAT_VERSION}); upgrade repro")
    return hdr


def load_trace(path: str) -> DelayTrace:
    """Read a trace written by ``save_trace``, validating version, shapes,
    and the content digest."""
    with np.load(path) as z:
        hdr = _read_header(z)
        if "T1" not in z or "T2" not in z:
            raise ValueError(f"{path}: missing T1/T2 tables")
        trace = DelayTrace(z["T1"], z["T2"], meta=hdr.get("meta"))
    want = (hdr["rounds"], hdr["trials"], hdr["n"], hdr["r"])
    if trace.T1.shape != want:
        raise ValueError(f"{path}: header says shape {want}, tables are "
                         f"{trace.T1.shape}")
    if hdr.get("digest") and hdr["digest"] != trace._digest:
        raise ValueError(f"{path}: content digest mismatch (corrupt or "
                         f"hand-edited tables)")
    return trace


def validate_trace_file(path: str) -> dict:
    """Validate a trace file without keeping the tables; returns its
    header dict (raises ``ValueError`` on any format problem)."""
    return load_trace(path).header()


# ------------------------------ the replay backend ---------------------------

@dataclasses.dataclass(frozen=True)
class TraceProcess(DelayProcess):
    """Replay a recorded ``DelayTrace`` through the ``init``/``step`` API.

    Deterministic: the per-trial PRNG keys are ignored — trial ``t`` of a
    replay reads trial ``t % trace.trials`` of the table (so a single
    recorded realization broadcasts across any Monte-Carlo trial count,
    and a trace recorded from ``sweep_rounds`` replays per-trial
    bit-exactly at the recording's own ``trials``/any chunking).

    Axis policies when the requested run exceeds the recording:
      * ``pad_rounds``:  ``"error"`` (default) — raise where the horizon
        is known statically (``sweep_rounds``, ``sample_rounds``, the
        aggregator's live round counter); ``"cycle"`` — wrap around;
        ``"hold"`` — repeat the final recorded round.
      * ``pad_workers`` / ``pad_slots``: ``"error"`` (default) or
        ``"cycle"`` (wrap the worker / slot axis).
    Requests *smaller* than the recording always use the leading
    workers/slots/rounds (truncation; delay statistics are
    slot-order-independent, paper Remark 6).

    ``start_round`` begins replay that many rounds into the recording —
    resuming a checkpointed training run keeps its remaining steps
    aligned with the rounds they originally consumed.

    The ``pad_rounds="error"`` policy is enforced through
    ``check_rounds``, which every driver in this repo calls wherever the
    horizon is known (``sweep_rounds`` / ``sample_rounds``, the
    aggregator per round, the launcher up front).  ``step`` itself runs
    under ``jit`` and cannot raise, so a hand-rolled ``init``/``step``
    loop must call ``check_rounds(n_rounds)`` itself — stepping past the
    recorded horizon without it wraps around silently.
    """
    trace: DelayTrace = None
    pad_rounds: str = "error"
    pad_workers: str = "error"
    pad_slots: str = "error"
    start_round: int = 0

    def __post_init__(self):
        if not isinstance(self.trace, DelayTrace):
            raise TypeError(f"TraceProcess needs a DelayTrace, got "
                            f"{type(self.trace).__name__}")
        if self.pad_rounds not in _PAD_ROUNDS:
            raise ValueError(f"pad_rounds must be one of {_PAD_ROUNDS}, "
                             f"got {self.pad_rounds!r}")
        for name in ("pad_workers", "pad_slots"):
            if getattr(self, name) not in _PAD_AXES:
                raise ValueError(f"{name} must be one of {_PAD_AXES}, got "
                                 f"{getattr(self, name)!r}")
        if not 0 <= int(self.start_round):
            raise ValueError(f"start_round must be >= 0, got "
                             f"{self.start_round}")

    # --- static-shape policy resolution (python-time, informative errors) --
    def _axis_index(self, want: int, have: int, axis: str,
                    policy: str) -> Optional[np.ndarray]:
        """Wrap-around index for an over-long axis, or None when plain
        (possibly truncating) leading slices suffice."""
        if want <= have:
            return None
        if policy == "error":
            raise ValueError(
                f"replay needs {want} {axis} but the trace recorded only "
                f"{have}; pass pad_{axis}='cycle' to wrap the recording "
                f"(TraceProcess(trace, pad_{axis}='cycle'))")
        return np.arange(want) % have

    def check_rounds(self, rounds: int) -> None:
        """Raise if a ``rounds``-long run (from ``start_round``) would
        exhaust the trace under ``pad_rounds='error'`` (called by the
        engines and the aggregator wherever the horizon is known
        statically)."""
        need = rounds + int(self.start_round)
        if self.pad_rounds == "error" and need > self.trace.rounds:
            raise ValueError(
                f"replay needs {need} rounds (start_round="
                f"{self.start_round}) but the trace recorded only "
                f"{self.trace.rounds}; pass pad_rounds='cycle' (wrap) or "
                f"'hold' (repeat the final round) to extend it")

    # --- the process API ---------------------------------------------------
    def init(self, keys, n):
        # positional trial ids: correct for every unchunked caller (the
        # aggregator / train step run one lane; sample_rounds runs all
        # trials flat).  The chunked rounds engine passes global ids via
        # init_trials instead.
        trials = keys.shape[0]
        return self.init_trials(keys, jnp.arange(trials, dtype=jnp.int32), n)

    def init_trials(self, keys, trial_ids, n):
        self._axis_index(n, self.trace.n, "workers", self.pad_workers)
        tids = jnp.asarray(trial_ids, jnp.int32) % self.trace.trials
        return (jnp.asarray(int(self.start_round), jnp.int32), tids)

    def step(self, state, keys, n, r):
        t = self.trace
        ridx, tids = state
        widx = self._axis_index(n, t.n, "workers", self.pad_workers)
        sidx = self._axis_index(r, t.r, "slots", self.pad_slots)
        if self.pad_rounds == "hold":
            rnow = jnp.minimum(ridx, t.rounds - 1)
        else:
            # "cycle" semantics; under "error" the horizon checks make the
            # wrapped branch unreachable, and the modulo keeps the traced
            # index in range either way.
            rnow = ridx % t.rounds

        def pick(table):
            x = jnp.asarray(table)                    # (rounds, trials, n, r)
            x = jax.lax.dynamic_index_in_dim(x, rnow, axis=0, keepdims=False)
            x = jnp.take(x, tids, axis=0)             # (replay trials, n, r)
            # cycle-gather over-long axes; plain leading slices truncate
            x = x[:, widx] if widx is not None else x[:, :n]
            x = x[:, :, sidx] if sidx is not None else x[:, :, :r]
            return x

        return (ridx + 1, tids), pick(t.T1), pick(t.T2)


# ------------------------------- calibration ---------------------------------

def _otsu_threshold(x: np.ndarray) -> float:
    """Between-class-variance-maximizing split point of a 1-D sample
    (Otsu's method on a 64-bin histogram) — used to segment per-round
    worker means into fast/slow regimes without assuming a slow factor."""
    lo, hi = float(x.min()), float(x.max())
    edges = np.linspace(lo, hi, 65)
    hist, _ = np.histogram(x, bins=edges)
    centers = 0.5 * (edges[:-1] + edges[1:])
    w = hist / hist.sum()
    mu = centers * w
    w0 = np.cumsum(w)
    m0 = np.cumsum(mu)
    m_tot = m0[-1]
    with np.errstate(divide="ignore", invalid="ignore"):
        between = (m_tot * w0 - m0) ** 2 / (w0 * (1.0 - w0))
    between[~np.isfinite(between)] = -np.inf
    return float(centers[int(np.argmax(between))])


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """A parametric cluster fitted to a ``DelayTrace``, plus how well it
    fits.

    ``process`` is the assembled ``MarkovRegimeProcess`` (heterogeneous
    ``worker_scale``, slow/fast regime chain, truncated-Gaussian base
    refit from the trace) — drop-in wherever ``ec2_cluster`` is used.
    The ``*_rel_err`` fields compare Monte-Carlo moments of the fitted
    process against the trace: overall compute/comm delay means, the
    worst per-worker compute mean, and the lag-1 autocorrelation of
    per-(round, worker) means (the straggler-persistence signature).
    """
    process: MarkovRegimeProcess
    worker_scale: tuple
    p_slow: float
    persistence: float
    slow: float
    mean_rel_err: float
    comm_mean_rel_err: float
    worker_mean_rel_err: float
    lag1_trace: float
    lag1_fit: float

    def summary(self) -> str:
        return (f"calibrated MarkovRegimeProcess: p_slow={self.p_slow:.3f} "
                f"persistence={self.persistence:.3f} slow={self.slow:.2f}x "
                f"scale_spread={max(self.worker_scale) / min(self.worker_scale):.2f}x | "
                f"fit: mean_err={self.mean_rel_err * 100:.1f}% "
                f"comm_err={self.comm_mean_rel_err * 100:.1f}% "
                f"worst_worker_err={self.worker_mean_rel_err * 100:.1f}% "
                f"lag1 {self.lag1_trace:+.2f}->{self.lag1_fit:+.2f}")


def _lag1(m: np.ndarray) -> float:
    """Lag-1 autocorrelation over the round axis of per-(round, trial,
    worker) means, pooled across trials and workers."""
    if m.shape[0] < 2:
        return 0.0
    a, b = m[:-1].reshape(-1), m[1:].reshape(-1)
    ok = np.isfinite(a) & np.isfinite(b)     # drop fault-censored pairs
    if not ok.all():
        a, b = a[ok], b[ok]
    if a.size < 2 or a.std() == 0 or b.std() == 0:
        return 0.0
    return float(np.corrcoef(a, b)[0, 1])


def calibrate_trace(trace: DelayTrace, *, min_slow_factor: float = 1.5,
                    fit_trials: int = 512, seed: int = 0
                    ) -> CalibrationReport:
    """Fit a heterogeneous persistent-straggler cluster to a trace.

    Segmentation runs on the log per-(round, trial, worker) mean compute
    delays with each worker's median removed (so *persistent* machine-
    speed heterogeneity is not mistaken for a slow regime).  A regime is
    only declared when the fast/slow separation exceeds
    ``min_slow_factor``; otherwise the fit degenerates gracefully to a
    pure heterogeneous-scale cluster (``p_slow = 0``).

    Estimators
    ----------
    * ``worker_scale`` — per-worker mean compute delay on fast cells over
      the global fast mean (the scale MLE for scale families, normalized
      to geometric mean 1 like ``heterogeneous_scales``);
    * ``slow`` — ratio of slow-cell to fast-cell means;
    * ``p_slow`` — the stationary slow-cell fraction;
    * ``persistence`` — ``1 - p(fast->slow) - p(slow->fast)`` from the
      per-worker regime transition counts (the chain's one-step
      autocorrelation, clipped to [0, 1]);
    * base model — truncated Gaussian refit by moment matching on the
      de-scaled fast cells (mu/sigma per delay type, +-3 sigma support
      clipped to keep delays positive).
    """
    T1 = np.asarray(trace.T1, np.float64)            # (R, t, n, r)
    T2 = np.asarray(trace.T2, np.float64)
    R, _, n, r = T1.shape
    # fault censoring: +inf cells are "never arrived", not delays — mask
    # them out of every estimator (a cell is valid when it has at least
    # one finite slot; its round mean uses the finite slots only).  For a
    # finite trace cnt == r everywhere and this is the plain slot mean.
    fin1 = np.isfinite(T1)                           # (R, t, n, r)
    cnt = fin1.sum(axis=3)                           # (R, t, n)
    if not cnt.any():
        raise ValueError("cannot calibrate: every cell of the trace is "
                         "fault-censored (+inf)")
    m1 = np.where(cnt > 0,
                  np.where(fin1, T1, 0.0).sum(axis=3) / np.maximum(cnt, 1),
                  np.nan)                            # (R, t, n) round means
    valid = cnt > 0                                  # (R, t, n)
    X = np.log(m1)
    Xc = X - np.nanmedian(X, axis=(0, 1), keepdims=True)  # de-heterogenize

    thr = _otsu_threshold(Xc[valid].reshape(-1))
    slow_mask = valid & (Xc > thr)                   # (R, t, n)
    fast = valid & ~slow_mask
    n_valid = int(valid.sum())
    frac = float(slow_mask.sum() / n_valid)
    sep = (np.exp(Xc[slow_mask].mean() - Xc[fast].mean())
           if 0.0 < frac < 1.0 else 1.0)

    if not 0.0 < frac < 1.0 or sep < min_slow_factor:
        # no credible slow regime: pure heterogeneous scales
        slow_mask = np.zeros_like(slow_mask)
        fast = valid
        p_slow, slow, persistence = 0.0, 1.0, 0.0
    else:
        p_slow = frac
        slow = float(sep)
        # regime transitions counted on valid consecutive cell pairs only
        pair = valid[:-1] & valid[1:]
        n_fast = int((~slow_mask[:-1] & pair).sum())
        n_slow = int((slow_mask[:-1] & pair).sum())
        p_fs = (float((~slow_mask[:-1] & slow_mask[1:] & pair).sum())
                / n_fast if n_fast else 0.0)
        p_sf = (float((slow_mask[:-1] & ~slow_mask[1:] & pair).sum())
                / n_slow if n_slow else 0.0)
        persistence = float(np.clip(1.0 - p_fs - p_sf, 0.0, 1.0))

    # per-worker scale MLE on the fast regime (mean ratio), geometric mean 1
    glob = m1[fast].mean() if fast.any() else m1[valid].mean()

    def _wmean(i):
        if fast[..., i].any():
            return m1[..., i][fast[..., i]].mean()
        if valid[..., i].any():
            return m1[..., i][valid[..., i]].mean()
        return glob          # worker never delivered: neutral scale source

    wm = np.array([_wmean(i) for i in range(n)])
    scale = wm / np.exp(np.log(wm).mean())
    scale = tuple(float(v) for v in scale)

    # de-scaled fast-cell samples -> truncated-Gaussian base refit (slot
    # level: drop individually censored slots, e.g. message-loss T2 cells)
    f1 = T1 / np.asarray(scale)[None, None, :, None]
    f2 = T2 / np.asarray(scale)[None, None, :, None]
    sel = np.broadcast_to(fast[..., None], T1.shape)
    s1 = f1[sel & np.isfinite(f1)]
    s2 = f2[sel & np.isfinite(f2)]
    if s1.size == 0 or s2.size == 0:
        raise ValueError("cannot calibrate: no finite fast-regime delay "
                         "samples survive the fault masking")

    def _tg(s):
        mu, sd = float(s.mean()), float(max(s.std(), 1e-12 * s.mean()))
        a = min(3.0 * sd, 0.999 * mu)                # keep support positive
        return mu, sd, a

    mu1, sd1, a1 = _tg(s1)
    mu2, sd2, a2 = _tg(s2)
    base = TruncatedGaussianDelays(mu1=mu1, sigma1=sd1, a1=a1,
                                   mu2=mu2, sigma2=sd2, a2=a2)
    process = MarkovRegimeProcess(base=base, worker_scale=scale,
                                  p_slow=float(p_slow),
                                  persistence=float(persistence),
                                  slow=float(slow))

    # ---- fit-quality: MC moments of the fitted process vs the trace -------
    F1, F2 = process.sample_rounds(jax.random.PRNGKey(seed),
                                   max(int(fit_trials), 1), n, r, R)
    F1, F2 = np.asarray(F1, np.float64), np.asarray(F2, np.float64)

    def rel(a, b):
        return float(abs(a - b) / max(abs(b), 1e-30))

    def fmean(x):                    # finite-cell mean (fault-censor safe)
        f = x[np.isfinite(x)]
        return f.mean() if f.size else np.nan

    worker_err = max(rel(F1[..., i, :].mean(), fmean(T1[..., i, :]))
                     for i in range(n)
                     if np.isfinite(T1[..., i, :]).any())
    report = CalibrationReport(
        process=process, worker_scale=scale, p_slow=float(p_slow),
        persistence=float(persistence), slow=float(slow),
        mean_rel_err=rel(F1.mean(), fmean(T1)),
        comm_mean_rel_err=rel(F2.mean(), fmean(T2)),
        worker_mean_rel_err=worker_err,
        lag1_trace=_lag1(m1), lag1_fit=_lag1(F1.mean(axis=3)))
    return report

"""Fused batched Monte-Carlo sweep engine — the repo's hot path.

Every paper figure (Figs. 4-7) is an average-completion-time sweep over a
(scheme, r, k, scenario) grid.  The seed code re-sampled delays and re-jitted
a fresh simulation for every scheme at every grid point.  This module
replaces all of that with ONE jitted evaluator that:

1. draws one PRNG subkey **per trial** and samples the delay tensors once
   per scenario — every scheme sees the *same* draws (common random
   numbers), so scheme comparisons are variance-reduced paired samples and
   per-trial completion samples are bit-identical under any chunking of the
   trial axis (chunk-accumulated means agree to float32 round-off);
2. evaluates all stacked TO matrices against the shared draws in one fused
   computation (a single stacked gather + one batched sort);
3. streams trials through ``lax.scan`` in fixed-size chunks, so peak memory
   is O(chunk * n * r) and 10^6+ trials run on a laptop;
4. returns completion times for EVERY k in 1..n from one sort of the task
   arrivals (a whole Fig.-7 k-sweep is one call), while single-k queries
   take the cheaper ``lax.top_k`` partial-selection path;
5. computes task arrival times with a statically precomputed gather +
   min-reduction (each task's copy positions are known from the TO matrix
   at trace time) instead of a dynamic scatter-min — the TPU-friendly form.

Scheme kinds
------------
* ``"to"``   — a TO matrix ``C``: order statistics of the per-task arrival
               times (paper eqs. 1-2, 6).
* ``"lb"``   — the oracle lower bound at load ``r``: order statistics over
               all ``n*r`` slot arrivals (eq. 46).
* ``"pc"``   — polynomially-coded workers at load ``r``: the
               ``2*ceil(n/r)-1``-th order statistic of the per-worker
               single-message times (eqs. 51-52).  Like ``pcmm``, always a
               single column at the scheme's own decode threshold — the
               sweep's ``k`` never applies to coded schemes.
* ``"pcmm"`` — PC multi-message at load ``r``: the ``2n-1``-th order
               statistic over all slot arrivals (eqs. 56-57).
* ``"tau"``  — raw (unsorted) per-task arrival times, for estimators that
               need the joint distribution (e.g. Theorem 1's H_S).
* ``"adaptive"`` — a base TO matrix whose rows are re-assigned to workers
               every round from observed delay feedback (greedy
               least-covered-first; ``repro.core.scheduling``).  Only
               meaningful with a rounds axis: see ``sweep_rounds``.

Specs with smaller loads than the widest scheme in a sweep simply use the
leading slots of the shared delay tensors (delay statistics are
order-independent, paper Remark 6) — that is what makes cross-``r``
comparisons paired as well.

Intra-round message axis (paper Sec. V-C)
-----------------------------------------
Every spec carries a ``messages`` knob: how many messages each worker sends
per round.  The worker's ``r`` sequential slots are partitioned into
``messages`` consecutive groups; a group's results all become available when
its *closing* slot's computation finishes plus one per-message communication
delay — the ``T2`` draw at the closing slot (``cluster.message_comm_delays``),
so draws stay paired across ``messages`` values under common random numbers.

* ``messages = load`` (the default for ``to``/``tau``/``adaptive``/``lb``/
  ``pcmm``) — full multi-message: each slot is its own message, reproducing
  eq. (1)'s per-slot arrivals ``cumsum(T1) + T2`` bit-exactly (the engine's
  established semantics).
* ``messages = 1`` (the default — and only legal value — for ``pc``) — the
  one-shot semantics: every result of worker ``i`` arrives at
  ``sum_j T1[i, :] + T2[i, r-1]``, exactly the per-worker time PC has always
  used (eqs. 51-52).
* intermediate ``m`` interpolates the communication/computation latency
  trade-off of Ozfatura et al. (arXiv:2004.04948) for the uncoded schemes;
  for ``pcmm`` the master decodes once 2n-1 *partials* arrived, messages
  delivering their group's partials in a lump (eqs. 56-57 generalized).

The remap is static (``message_slot_map``) and folds into the task gather
plans, so the hot path gains zero runtime ops and ``m = load`` compiles to
the identical program as before the axis existed.  A per-message protocol
overhead ``comm_eps`` (Ozfatura et al.'s communication/computation
trade-off: a worker's l-th message arrives ``(l+1) * comm_eps`` late, a
serialized-uplink model) likewise folds into the plans as static offsets,
so an *optimal* message budget exists instead of ``m = load`` always
winning.

Ragged per-worker loads
-----------------------
Every uncoded spec (``to``/``tau``/``adaptive``/``lb``) accepts a
per-worker load vector ``loads`` (``loads[w] <= r_max``): the slot grid
stays rectangular ``(n, r_max)`` — masked trailing slots still consume
delay draws, keeping draws paired under common random numbers across load
vectors — but masked slots are *statically* dropped from the task gather
plans (they read the +inf sentinel), so the hot path gains zero runtime
ops and a uniform ``loads`` is bit-exact with the dense path.  TO matrices
may equivalently carry the raggedness themselves via trailing
``scheduling.MASKED`` (-1) sentinels; message budgets become per-worker
(worker ``w`` sends ``min(messages, loads[w])`` messages).

``adaptive_spec(..., rebalance=True)`` additionally re-allocates whole
slots between workers each round inside the rounds scan
(``greedy_load_rebalance_batch``, Egger et al. arXiv:2304.08589): the
dense base matrix's width is the per-worker cap, ``loads`` the initial
budget, and each round's per-worker loads are recomputed from the same
(optionally censored) delay estimates that drive the row re-assignment —
slow workers shed slots to fast ones under the fixed total budget.

Rounds axis (``sweep_rounds``)
------------------------------
Training runs are sequences of rounds, and real stragglers persist across
them (``repro.core.cluster``).  ``sweep_rounds`` scans a stateful
``DelayProcess`` over ``R`` rounds *inside* the jitted evaluator, carrying
per-trial straggler state (and, for adaptive schemes, per-trial feedback
state), so one call yields full wall-clock trajectories for every scheme
under common random numbers: per-round mean completion times and
cumulative wall-clock curves of shape ``(rounds,)``, or raw per-trial
trajectories ``(trials, rounds)`` via ``trajectory_samples``.

Trace recording and replay (``repro.core.trace``)
-------------------------------------------------
``sweep_rounds``/``trajectory_samples`` accept ``record_trace=True`` to
also stream the realized per-(round, trial, worker, slot) delay tables out
of the scan as a ``DelayTrace``; a ``TraceProcess`` built on that trace
replays it through the same ``init``/``step`` API — keys are ignored and
the per-trial tables ride on the engine's global trial ids, so replay is
chunk-invariant and reproduces the recording run's completion times and
adaptive decisions bit-exactly.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import shard_trials, trial_devices
from .spec import (DEADLINE_POLICIES, _internal, _legacy_warning,
                   validate_deadline)

__all__ = [
    "SchemeSpec", "SweepResult", "RoundsResult", "to_spec", "lb_spec",
    "pc_spec", "pcmm_spec", "tau_spec", "adaptive_spec", "task_gather_plan",
    "task_arrival_times_gather", "message_boundaries", "message_slot_map",
    "message_group_sizes", "sweep", "sweep_rounds",
    "completion_samples", "trajectory_samples", "task_arrival_samples",
    "ResumableSweep", "resumable_sweep",
    "trial_keys", "clear_cache", "cache_stats", "set_cache_capacity",
]

Array = jax.Array
INF = jnp.inf


# --------------------------- scheme specification ----------------------------

@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One scheme to evaluate in a sweep. Hashable (C stored as nested
    tuples) so compiled evaluators can be cached across calls."""
    name: str
    kind: str                 # "to" | "lb" | "pc" | "pcmm" | "tau" | "adaptive"
    C: Optional[tuple] = None       # TO matrix for "to"/"tau"/"adaptive"
    r: Optional[int] = None         # computation load for "lb"/"pc"/"pcmm"
    messages: Optional[int] = None  # per-round messages per worker
                                    # (None = the kind's default semantics)
    loads: Optional[tuple] = None   # per-worker loads (None = uniform/dense;
                                    # for rebalance: the initial budget)
    rebalance: bool = False         # adaptive only: re-allocate whole slots
                                    # between workers each round
    comm_eps: float = 0.0           # per-message protocol overhead: a
                                    # worker's l-th message lands (l+1)*eps
                                    # late (serialized uplink)

    def __post_init__(self):
        # no validation here — invalid specs are (and stay) rejected at
        # sweep time by ``_check_specs`` with engine-level context; direct
        # construction is merely deprecated in favor of the factories /
        # ``RoundConfig.to_scheme_spec()``.
        _legacy_warning(
            "SchemeSpec", "call .to_scheme_spec() (or use the to_spec / "
            "tau_spec / adaptive_spec / lb_spec / pc_spec / pcmm_spec "
            "factories)")

    @property
    def load(self) -> int:
        """Width of this scheme's slot grid (the maximum per-worker load;
        for rebalance specs, the per-worker load cap)."""
        if self.kind in ("to", "tau", "adaptive"):
            return len(self.C[0])
        return int(self.r)

    @property
    def n_messages(self) -> int:
        """Messages each worker sends per round.  ``None`` resolves to the
        kind's established semantics: full multi-message (one message per
        slot, eq. 1) for uncoded schemes / lb / pcmm, one-shot for pc.
        Workers with ragged load below the budget send one message per
        active slot."""
        if self.messages is not None:
            return int(self.messages)
        return 1 if self.kind == "pc" else self.load

    def load_vector(self, n: Optional[int] = None) -> np.ndarray:
        """Per-worker loads as an array (uniform when ``loads`` is None).
        ``n`` is required for matrix-less kinds (lb/pc/pcmm)."""
        if self.loads is not None:
            return np.asarray(self.loads, np.int64)
        n_w = len(self.C) if self.C is not None else n
        if n_w is None:
            raise ValueError(f"{self.name}: need n for a matrix-less spec")
        return np.full(n_w, self.load, np.int64)

    def matrix(self) -> np.ndarray:
        return np.asarray(self.C, dtype=np.int64)


def _freeze_matrix(C) -> tuple:
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    return tuple(tuple(int(v) for v in row) for row in C)


def _freeze_ragged(C, loads) -> Tuple[tuple, Optional[tuple]]:
    """Canonicalize a (possibly ragged) TO matrix + load vector: masked
    slots hold ``scheduling.MASKED`` in the frozen C, and a uniform
    full-width ``loads`` canonicalizes to ``None`` — the dense
    representation — so uniform-load specs hash/compare/evaluate
    identically to the established dense path."""
    from . import scheduling
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    if loads is not None:
        C = scheduling.mask_matrix_loads(C, loads)
    lv = scheduling.loads_of_matrix(C)             # validates trailing masks
    if (lv == C.shape[1]).all():
        return _freeze_matrix(C), None
    return _freeze_matrix(C), tuple(int(v) for v in lv)


def to_spec(name: str, C, messages: Optional[int] = None, *,
            loads=None, comm_eps: float = 0.0) -> SchemeSpec:
    """A TO-matrix scheme (CS / SS / RA / custom).  ``messages`` is the
    per-round message budget (default: one message per slot, eq. 1);
    ``loads`` masks each row's trailing slots (ragged per-worker loads,
    equivalently encoded as trailing -1 sentinels in ``C``); ``comm_eps``
    is the per-message protocol overhead."""
    Cf, lt = _freeze_ragged(C, loads)
    with _internal():
        return SchemeSpec(name=name, kind="to", C=Cf, messages=messages,
                          loads=lt, comm_eps=float(comm_eps))


def tau_spec(name: str, C, messages: Optional[int] = None, *,
             loads=None, comm_eps: float = 0.0) -> SchemeSpec:
    """Raw task-arrival samples for a TO matrix (no order statistics)."""
    Cf, lt = _freeze_ragged(C, loads)
    with _internal():
        return SchemeSpec(name=name, kind="tau", C=Cf, messages=messages,
                          loads=lt, comm_eps=float(comm_eps))


def adaptive_spec(name: str, C, messages: Optional[int] = None, *,
                  loads=None, rebalance: bool = False) -> SchemeSpec:
    """An adaptive scheme: base TO matrix ``C`` whose rows are re-assigned
    to workers each round from observed per-worker delay feedback (only
    valid in ``sweep_rounds``).  ``loads`` makes the base ragged (rows
    carry their loads through the re-permutation); with ``rebalance=True``
    the base must be dense — its width is the per-worker load *cap*,
    ``loads`` the initial budget — and per-worker loads are additionally
    re-balanced each round from the same feedback (slow workers shed whole
    slots to fast ones under the fixed total budget)."""
    if rebalance:
        # the budget stays a budget — do NOT fold it into row masks
        lt = (None if loads is None
              else tuple(int(v) for v in np.asarray(loads, np.int64)))
        with _internal():
            return SchemeSpec(name=name, kind="adaptive",
                              C=_freeze_matrix(C), messages=messages,
                              loads=lt, rebalance=True)
    Cf, lt = _freeze_ragged(C, loads)
    with _internal():
        return SchemeSpec(name=name, kind="adaptive", C=Cf,
                          messages=messages, loads=lt)


def lb_spec(r: Optional[int] = None, name: str = "lb",
            messages: Optional[int] = None, *,
            loads=None, comm_eps: float = 0.0) -> SchemeSpec:
    """Oracle lower bound (eq. 46) at computation load ``r`` (at a reduced
    ``messages`` budget: the oracle bound among schemes sending that many
    messages per round).  ``loads`` generalizes the bound to a per-worker
    load vector: the k-th order statistic over the ``sum(loads)`` active
    slot arrivals."""
    lt = None
    if loads is not None:
        lv = np.asarray(loads, np.int64)
        if lv.ndim != 1 or lv.min() < 1:
            raise ValueError(f"loads must be a vector of positive per-worker "
                             f"loads, got {loads}")
        r = int(lv.max()) if r is None else int(r)
        if lv.max() > r:
            raise ValueError(f"max load {lv.max()} exceeds r={r}")
        if not (lv == r).all():                    # uniform -> canonical dense
            lt = tuple(int(v) for v in lv)
    elif r is None:
        raise ValueError("need a load r (or a loads vector)")
    with _internal():
        return SchemeSpec(name=name, kind="lb", r=int(r), messages=messages,
                          loads=lt, comm_eps=float(comm_eps))


def pc_spec(r: int, name: str = "pc") -> SchemeSpec:
    """Polynomially-coded scheme at load ``r`` — one-shot by construction
    (the PC decoder needs a worker's full sum, eqs. 51-52); use ``pcmm_spec``
    for coded rounds with an intra-round message budget."""
    with _internal():
        return SchemeSpec(name=name, kind="pc", r=int(r))


def pcmm_spec(r: int, name: str = "pcmm",
              messages: Optional[int] = None) -> SchemeSpec:
    """Polynomially-coded multi-message scheme at load ``r``; ``messages``
    bundles its per-slot partials into fewer messages (eqs. 56-57 keep
    counting partials, they just arrive in lumps)."""
    with _internal():
        return SchemeSpec(name=name, kind="pcmm", r=int(r),
                          messages=messages)


def _pc_threshold(n: int, r: int) -> int:
    return 2 * math.ceil(n / r) - 1


def _pcmm_threshold(n: int) -> int:
    return 2 * n - 1


# ----------------------- intra-round message layout --------------------------

def message_boundaries(r: int, messages: int) -> np.ndarray:
    """Closing slot index of each message when ``r`` sequential slots are
    sent in ``messages`` as-even-as-possible consecutive groups (earlier
    messages carry the extra slot when ``messages`` does not divide ``r``).
    The last message always closes at slot ``r - 1``."""
    if int(messages) != messages:
        raise ValueError(f"messages must be an integer, got {messages!r}")
    if not 1 <= int(messages) <= r:
        raise ValueError(f"message budget out of range: need 1 <= messages "
                         f"<= r={r}, got messages={messages}")
    sizes = [len(g) for g in np.array_split(np.arange(r), int(messages))]
    return np.cumsum(sizes, dtype=np.int64) - 1


def message_group_sizes(r: int, messages: int) -> np.ndarray:
    """Number of slots (results / coded partials) each message carries."""
    b = message_boundaries(r, messages)
    return np.diff(np.concatenate([[-1], b])).astype(np.int64)


def message_slot_map(r: int, messages: int) -> np.ndarray:
    """Slot ``j`` -> the closing slot of ``j``'s message: the slot whose
    arrival time (eq. 1 at the closing slot) carries ``j``'s result.
    Identity for ``messages == r`` (every slot is its own message)."""
    b = message_boundaries(r, messages)
    return b[np.searchsorted(b, np.arange(r))]


def _slot_map_of(spec: SchemeSpec) -> Optional[np.ndarray]:
    """The spec's message remap, or None when it is the identity (full
    multi-message) — callers skip the gather entirely in that case, keeping
    the default path bit-identical to the pre-message-axis engine.

    Dense specs get the shared length-``r`` map; ragged specs a per-worker
    ``(n, r)`` map (worker ``w`` groups its ``loads[w]`` active slots into
    ``min(messages, loads[w])`` messages; masked slots keep the identity —
    they are statically dropped from every plan anyway)."""
    m = spec.n_messages
    r = spec.load
    if spec.loads is None:
        return None if m == r else message_slot_map(r, m)
    rows, nontrivial = [], False
    for l in spec.loads:
        mi = min(m, int(l))
        row = np.arange(r, dtype=np.int64)
        row[:l] = message_slot_map(int(l), mi)
        nontrivial |= mi != l
        rows.append(row)
    return np.stack(rows) if nontrivial else None


def _rebalance_remap(spec: SchemeSpec) -> Optional[np.ndarray]:
    """Per-(load, slot) closing-slot table for rebalance specs with a
    message budget.  A rebalanced worker's load is decided per round at
    runtime, so its message grouping cannot be baked into a static plan
    the way ``_slot_map_of`` does for fixed loads; instead row ``l - 1``
    of this ``(cap, cap)`` table maps slot ``j < l`` to the closing slot
    of ``j``'s message when ``l`` active slots are grouped into
    ``min(messages, l)`` messages, and slots at or beyond the load keep
    the identity (they are masked to +inf before the gather, and +inf
    reads itself).  The rounds scan indexes the table by the realized
    per-row load.  ``None`` when the budget is the identity for every
    feasible load (``messages >= cap``, every slot its own message)."""
    if not spec.rebalance:
        return None
    return _rebalance_remap_table(spec.load, spec.n_messages)


def _rebalance_remap_table(cap: int, messages: int) -> Optional[np.ndarray]:
    """The ``(cap, cap)`` load-indexed closing-slot table itself (see
    ``_rebalance_remap``); shared with the live aggregator, whose round
    function applies the same gather to its single realization."""
    if messages >= cap:
        return None
    tab = np.empty((cap, cap), np.int64)
    for l in range(1, cap + 1):
        row = np.arange(cap)
        row[:l] = message_slot_map(l, min(messages, l))
        tab[l - 1] = row
    return tab


def _apply_slot_map(s: Array, mmap: np.ndarray) -> Array:
    """Gather per-message arrivals: ``s`` (..., n, r); ``mmap`` a shared
    length-``r`` map or a per-worker ``(n, r)`` map."""
    mm = jnp.asarray(mmap)
    if mm.ndim == 1:
        return s[..., mm]
    return jnp.take_along_axis(
        s, jnp.broadcast_to(mm, s.shape[:-2] + mm.shape), axis=-1)


def _message_index_grid(spec: SchemeSpec, n: int) -> np.ndarray:
    """(n_w, r) message index (0-based) of each slot's message under the
    spec's budget and load vector (masked slots get index 0 — they are
    never read)."""
    r = spec.load
    m = spec.n_messages
    lv = spec.load_vector(n)
    grid = np.zeros((len(lv), r), np.int64)
    for i, l in enumerate(lv):
        b = message_boundaries(int(l), min(m, int(l)))
        grid[i, :l] = np.searchsorted(b, np.arange(int(l)))
    return grid


def _offsets_flat_of(spec: SchemeSpec, n: int, r_max: int
                     ) -> Optional[np.ndarray]:
    """Static per-slot arrival offsets from the per-message protocol
    overhead ``comm_eps`` (message ``l`` lands ``(l+1) * eps`` late), laid
    out flat over the row-major ``(n_w, r_max)`` slot grid plus the +inf
    sentinel position (offset 0).  ``None`` when ``eps == 0`` so the
    established zero-overhead path stays bit-identical."""
    if not spec.comm_eps:
        return None
    grid = _message_index_grid(spec, n)                   # (n_w, r)
    n_w, r = grid.shape
    smap = _slot_map_of(spec)
    if smap is None:
        smap = np.broadcast_to(np.arange(r), (n_w, r))
    elif smap.ndim == 1:
        smap = np.broadcast_to(smap, (n_w, r))
    off = np.zeros(n_w * r_max + 1, np.float32)
    # write each message's offset at its *closing* slot (the position the
    # plans gather); all slots of a message share one closing slot + index.
    for i in range(n_w):
        for j in range(r):
            off[i * r_max + int(smap[i, j])] = spec.comm_eps * (grid[i, j] + 1)
    return off


# ------------------- static gather layout for task arrivals ------------------

def task_gather_plan(C, n: int, r_max: Optional[int] = None,
                     slot_map: Optional[np.ndarray] = None) -> np.ndarray:
    """Precompute, at trace time, where every task's copies live.

    Returns an ``(n, m)`` int32 array of *flat* slot indices into the
    row-major ``(n_w, r_max)`` slot grid, where ``m`` is the maximum copy
    multiplicity.  Rows are padded with the sentinel ``n_w * r_max``, which
    callers map to +inf, so ``min`` over the gathered values reproduces the
    scatter-min of eq. (2) with a static gather — the TPU-friendly form.

    ``C`` may be ragged: slots holding the ``scheduling.MASKED`` (-1)
    sentinel are statically dropped from the plan (their grid positions
    read as +inf through the pad), so ragged loads cost zero extra runtime
    ops in the hot path.

    ``slot_map`` (length-``r`` shared, or per-worker ``(n_w, r)``, values
    in ``[0, r)``) redirects slot ``j``'s read to ``slot_map[j]`` — the
    multi-message layout folds its closing-slot remap (``message_slot_map``)
    into the plan, so per-message arrivals cost no extra runtime ops.
    """
    C = np.asarray(C)
    n_w, r = C.shape
    r_max = r if r_max is None else int(r_max)
    if r > r_max:
        raise ValueError(f"TO matrix load r={r} exceeds slot grid r_max={r_max}")
    if slot_map is None:
        slot_map = np.broadcast_to(np.arange(r), (n_w, r))
    else:
        slot_map = np.asarray(slot_map)
        if slot_map.ndim == 1:
            slot_map = np.broadcast_to(slot_map, (n_w, r))
        if (slot_map.shape != (n_w, r) or slot_map.min() < 0
                or slot_map.max() >= r):
            raise ValueError(f"slot_map must be ({r},) or ({n_w}, {r}) with "
                             f"values in [0, {r}); got shape {slot_map.shape}")
    sentinel = n_w * r_max
    positions: list[list[int]] = [[] for _ in range(n)]
    for i in range(n_w):
        for j in range(r):
            if C[i, j] < 0:            # MASKED slot: statically dropped
                continue
            positions[int(C[i, j])].append(i * r_max + int(slot_map[i, j]))
    m = max((len(p) for p in positions), default=0) or 1
    plan = np.full((n, m), sentinel, dtype=np.int32)
    for p, lst in enumerate(positions):
        plan[p, :len(lst)] = lst
    return plan


def task_arrival_times_gather(plan: np.ndarray, s: Array,
                              offsets: Optional[np.ndarray] = None) -> Array:
    """eq. (2) via the static gather plan.

    ``s`` has shape (..., n_w, r_max); ``plan`` may be ``(n, m)`` for one
    scheme or ``(S, n, m)`` for a stack, giving (..., n) or (..., S, n).
    Tasks never assigned come out +inf, matching the scatter-min version.
    ``offsets`` (same shape as ``plan``) adds static per-copy arrival
    offsets (the ``comm_eps`` per-message overhead) before the min.
    """
    sf = s.reshape(s.shape[:-2] + (-1,))
    pad = jnp.full(sf.shape[:-1] + (1,), INF, s.dtype)
    sp = jnp.concatenate([sf, pad], axis=-1)
    g = sp[..., jnp.asarray(plan)]
    if offsets is not None:
        g = g + jnp.asarray(offsets)
    return jnp.min(g, axis=-1)


def _plan_of(spec: SchemeSpec, n: int, r_max: int) -> np.ndarray:
    return task_gather_plan(spec.matrix(), n, r_max,
                            slot_map=_slot_map_of(spec))


def _plan_offsets_of(spec: SchemeSpec, plan: np.ndarray, n: int,
                     r_max: int) -> Optional[np.ndarray]:
    """Per-copy offsets aligned with ``plan`` (``comm_eps`` folded into the
    static layout), or None when the spec has no overhead."""
    off_flat = _offsets_flat_of(spec, n, r_max)
    if off_flat is None:
        return None
    return off_flat[plan]


def _stack_plans(specs: Sequence[SchemeSpec], n: int, r_max: int
                 ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    plans = [_plan_of(sp, n, r_max) for sp in specs]
    m = max(p.shape[1] for p in plans)
    sentinel = n * r_max
    out = np.full((len(plans), n, m), sentinel, dtype=np.int32)
    for i, p in enumerate(plans):
        out[i, :, :p.shape[1]] = p
    offs = None
    if any(sp.comm_eps for sp in specs):
        offs = np.zeros((len(plans), n, m), dtype=np.float32)
        for i, (sp, p) in enumerate(zip(specs, plans)):
            o = _plan_offsets_of(sp, p, n, r_max)
            if o is not None:
                offs[i, :, :p.shape[1]] = o
    return out, offs


# ----------------------------- fused evaluator -------------------------------

def _smallest(x: Array, k: int) -> Array:
    """The k smallest entries of x along the last axis, ascending — a
    partial selection via ``lax.top_k`` (no full O(L log L) sort)."""
    return -jax.lax.top_k(-x, k)[0]


def _stat_width(spec: SchemeSpec, n: int, ks: Optional[int]) -> int:
    if spec.kind in ("pc", "pcmm"):        # fixed decode thresholds
        return 1
    if spec.kind == "tau":
        return n
    return n if ks is None else 1


def _flat_window_key(sp: SchemeSpec) -> tuple:
    return (sp.load, sp.n_messages, sp.loads, sp.comm_eps)


def _build_eval(specs: Tuple[SchemeSpec, ...], n: int, r_max: int,
                ks: Optional[int], deadline: Optional[float] = None):
    """Static-scheme evaluator: slot arrivals ``s`` (chunk, n, r_max) ->
    {name: (chunk, L)}.  All static structure (gather plans, thresholds,
    slot windows, ragged-load masks, per-message overhead offsets) is baked
    in at trace time; shared by the single-round sampler and the
    rounds-axis scan body.

    With ``deadline`` set the evaluator additionally returns per-scheme
    arrival counts ``{name: (by_deadline, deliverable)}`` (each (chunk,)
    float32): how many distinct results arrive by the deadline, and how
    many would *ever* arrive (finite arrival — fault censoring makes this
    < n).  Coded schemes decode all-or-nothing, so their counts are n or
    0; the oracle bound counts slot arrivals capped at n."""
    to_specs = tuple(sp for sp in specs if sp.kind == "to")
    plan_stack = off_stack = None
    if to_specs:
        plan_stack, off_stack = _stack_plans(to_specs, n, r_max)

    # lb/pcmm both rank the same flattened per-message-arrival window; group
    # them by (load, messages, loads, eps) so each distinct window is
    # selected exactly once.  Dense zero-overhead full-multi-message windows
    # slice the shared slot grid directly (the pre-message-axis code path,
    # bit-identical); dense reduced budgets gather through the shared
    # closing-slot remap; ragged loads and/or overheads use a static flat
    # gather over the active slots only.
    flat_width: Dict[tuple, int] = {}
    flat_spec: Dict[tuple, SchemeSpec] = {}
    for sp in specs:
        if sp.kind == "lb":
            need = n if ks is None else ks
        elif sp.kind == "pcmm":
            need = _pcmm_threshold(n)
        else:
            continue
        key = _flat_window_key(sp)
        flat_width[key] = max(flat_width.get(key, 0), need)
        flat_spec[key] = sp

    def _flat_window(sp: SchemeSpec, s: Array) -> Array:
        r, m = sp.load, sp.n_messages
        if sp.loads is None and not sp.comm_eps:
            if m == r:
                return s[..., :, :r].reshape(s.shape[0], -1)
            return s[..., :, jnp.asarray(message_slot_map(r, m))].reshape(
                s.shape[0], -1)
        # ragged loads and/or per-message overhead: static gather over the
        # active (remapped) slots, plus their static offsets.
        lv = sp.load_vector(n)
        smap = _slot_map_of(sp)
        if smap is None:
            smap = np.broadcast_to(np.arange(r), (n, r))
        elif smap.ndim == 1:
            smap = np.broadcast_to(smap, (n, r))
        idx = np.asarray([i * r_max + int(smap[i, j])
                          for i in range(n) for j in range(int(lv[i]))],
                         np.int32)
        sf = s.reshape(s.shape[0], -1)
        win = sf[..., jnp.asarray(idx)]
        off_flat = _offsets_flat_of(sp, n, r_max)
        if off_flat is not None:
            win = win + jnp.asarray(off_flat[idx])
        return win

    # numpy (not jnp) scalars: builders run eagerly, and plain literals
    # fold into the traced program identically on every device, whereas a
    # concrete jax scalar closed over here is a device-resident buffer
    # (see the matching note in ``_build_rounds_fn``).  Both promote
    # identically in float32 arithmetic.
    DL = None if deadline is None else np.float32(deadline)
    nf = np.float32(n)

    def eval_fn(s: Array):
        out: Dict[str, Array] = {}
        cnts: Dict[str, Tuple[Array, Array]] = {}

        if to_specs:
            tau = task_arrival_times_gather(plan_stack, s, off_stack)
            if ks is None:
                stat = jnp.sort(tau, axis=-1)                # all k at once
            else:
                stat = _smallest(tau, ks)[..., -1:]          # k-th only
            if DL is not None:
                by_s = (tau <= DL).sum(-1).astype(jnp.float32)
                dv_s = jnp.isfinite(tau).sum(-1).astype(jnp.float32)
            for i, sp in enumerate(to_specs):
                out[sp.name] = stat[:, i]
                if DL is not None:
                    cnts[sp.name] = (by_s[:, i], dv_s[:, i])

        flat_stats = {}
        flat_cnts = {}
        for key, w in flat_width.items():
            win = _flat_window(flat_spec[key], s)
            flat_stats[key] = _smallest(win, w)      # (chunk, w) ascending
            if DL is not None:
                # oracle: first however-many received are distinct, so the
                # realized count is the slot-arrival count capped at n
                flat_cnts[key] = (
                    jnp.minimum((win <= DL).sum(-1), n).astype(jnp.float32),
                    jnp.minimum(jnp.isfinite(win).sum(-1),
                                n).astype(jnp.float32))

        for sp in specs:
            if sp.kind == "tau":
                plan = _plan_of(sp, n, r_max)
                out[sp.name] = task_arrival_times_gather(
                    plan, s, _plan_offsets_of(sp, plan, n, r_max))
            elif sp.kind == "lb":
                fs = flat_stats[_flat_window_key(sp)]
                out[sp.name] = fs[..., :n] if ks is None else fs[..., ks - 1:ks]
                if DL is not None:
                    cnts[sp.name] = flat_cnts[_flat_window_key(sp)]
            elif sp.kind == "pc":
                r = sp.load
                tw = s[..., r - 1]         # = sum_j T1[..., :r] + T2[..., r-1]
                if sp.comm_eps:
                    tw = tw + jnp.float32(sp.comm_eps)   # its single message
                th = _pc_threshold(n, r)   # PC's own decode threshold — the
                out[sp.name] = _smallest(tw, th)[..., -1:]   # sweep k never
                # applies to coded schemes (same rule as pcmm below)
            elif sp.kind == "pcmm":
                th = _pcmm_threshold(n)
                out[sp.name] = flat_stats[_flat_window_key(sp)][
                    ..., th - 1:th]
            if DL is not None and sp.kind in ("pc", "pcmm"):
                # coded decode is all-or-nothing: the full gradient (all n
                # tasks' worth) or nothing usable by the deadline
                v0 = out[sp.name][..., -1]
                cnts[sp.name] = (jnp.where(v0 <= DL, nf, 0.0),
                                 jnp.where(jnp.isfinite(v0), nf, 0.0))
        if DL is None:
            return out
        return out, cnts

    return eval_fn


# --------------------- shape-bucketed runtime evaluator ----------------------
#
# ``_build_eval`` above bakes every gather plan into the traced program, so
# its compile cache key is the full frozen spec tuple — fine for a handful
# of figures, hopeless for a grid sweep where hundreds of cells differ only
# in their TO matrices / budgets / overheads.  The single-round hot path
# therefore uses the *bucketed* twin below: all static structure (gather
# plans, flat-window indices, message offsets, decode thresholds) becomes
# runtime int32/float32 arrays with shapes padded to a small signature
# ``(n, r_max, ks, per-group counts, padded widths)``, so every cell in the
# same shape bucket shares one executable.  Padding is value-exact: padded
# plan entries read the +inf sentinel (transparent to min / top_k), padded
# offsets are 0.0 (``x + 0.0`` is bitwise ``x`` for delays), and the pc
# order statistic is taken from a full sort at a runtime index — so the
# bucketed path is bit-exact with the per-spec path under CRN.
# (``_build_eval`` stays as-is for the rounds axis, whose adaptive scan
# re-evaluates baked static specs every round.)

_GROUPS = ("to", "tau", "lb", "pcmm", "pc")


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 2 ** (x - 1).bit_length()


def _flat_indices_of(sp: SchemeSpec, n: int, r_max: int):
    """Flat indices of the spec's active (message-remapped) slots in the
    row-major ``(n, r_max)`` grid — the runtime form of ``_build_eval``'s
    lb/pcmm flat window — plus their static ``comm_eps`` offsets (None when
    the spec has no overhead)."""
    r = sp.load
    lv = sp.load_vector(n)
    smap = _slot_map_of(sp)
    if smap is None:
        smap = np.broadcast_to(np.arange(r), (n, r))
    elif smap.ndim == 1:
        smap = np.broadcast_to(smap, (n, r))
    idx = np.asarray([i * r_max + int(smap[i, j])
                      for i in range(n) for j in range(int(lv[i]))],
                     np.int32)
    off_flat = _offsets_flat_of(sp, n, r_max)
    if off_flat is None:
        return idx, None
    return idx, off_flat[idx].astype(np.float32)


def _eval_layout(specs: Tuple[SchemeSpec, ...], n: int, r_max: int,
                 ks: Optional[int]):
    """Split one sweep's specs into the fixed evaluator groups and
    materialize every per-spec static structure as *runtime* numpy arrays
    padded to the bucket signature.  Returns ``(sig, params, slots)``:

    * ``sig``    — the hashable shape bucket ``("v1", n, r_max, ks,
      S_to, M_to, S_tau, M_tau, F_lb, F_pcmm, P_pc)``; the compiled
      program depends only on this (plus model and devices).
    * ``params`` — ``{name: numpy array}`` fed to the jitted scans at call
      time (gather plans + offsets per group, flat windows, pc slots /
      thresholds / overheads).
    * ``slots``  — ``{scheme name: (group, index)}``: where each scheme's
      columns live in the group-stacked outputs.  Group-keyed (not
      name-keyed) outputs keep the scan's pytree structure independent of
      scheme names, so renamed cells never retrace.
    """
    W = n * r_max                     # flat slot-grid width; sentinel = W
    by: Dict[str, list] = {g: [] for g in _GROUPS}
    slots: Dict[str, Tuple[str, int]] = {}
    for sp in specs:
        slots[sp.name] = (sp.kind, len(by[sp.kind]))
        by[sp.kind].append(sp)

    params: Dict[str, np.ndarray] = {}

    def _plan_group(group):
        gspecs = by[group]
        if not gspecs:
            return 0, 1
        plans = [_plan_of(sp, n, r_max) for sp in gspecs]
        m = _next_pow2(max(p.shape[1] for p in plans))
        plan = np.full((len(gspecs), n, m), W, np.int32)
        offs = np.zeros((len(gspecs), n, m), np.float32)
        for i, (sp, p) in enumerate(zip(gspecs, plans)):
            plan[i, :, :p.shape[1]] = p
            o = _plan_offsets_of(sp, p, n, r_max)
            if o is not None:
                offs[i, :, :p.shape[1]] = o
        params[group + "_plan"] = plan
        params[group + "_off"] = offs
        return len(gspecs), m

    S_to, M_to = _plan_group("to")
    S_tau, M_tau = _plan_group("tau")

    def _flat_group(group):
        gspecs = by[group]
        if not gspecs:
            return 0
        idx = np.full((len(gspecs), W), W, np.int32)   # sentinel -> +inf
        offs = np.zeros((len(gspecs), W), np.float32)
        for i, sp in enumerate(gspecs):
            fi, fo = _flat_indices_of(sp, n, r_max)
            idx[i, :len(fi)] = fi
            if fo is not None:
                offs[i, :len(fi)] = fo
        params[group + "_idx"] = idx
        params[group + "_off"] = offs
        return len(gspecs)

    F_lb = _flat_group("lb")
    F_pcmm = _flat_group("pcmm")

    pc = by["pc"]
    if pc:
        params["pc_slot"] = np.asarray([sp.load - 1 for sp in pc], np.int32)
        params["pc_th"] = np.asarray(
            [_pc_threshold(n, sp.load) - 1 for sp in pc], np.int32)
        params["pc_eps"] = np.asarray([sp.comm_eps for sp in pc], np.float32)

    sig = ("v1", n, r_max, ks, S_to, M_to, S_tau, M_tau, F_lb, F_pcmm,
           len(pc))
    return sig, params, slots


def _build_bucket_eval(sig):
    """Runtime-parameterized evaluator for one shape bucket: slot arrivals
    ``s`` (chunk, n, r_max) + ``params`` -> {group: (chunk, S_g, L_g)}.
    Value-exact with ``_build_eval`` spec-by-spec (see the bucketing note
    above)."""
    _, n, r_max, ks, S_to, M_to, S_tau, M_tau, F_lb, F_pcmm, P_pc = sig

    def eval_fn(s: Array, params) -> Dict[str, Array]:
        out: Dict[str, Array] = {}
        if F_lb or F_pcmm:
            sf = s.reshape(s.shape[0], -1)
            s_pad = jnp.concatenate(
                [sf, jnp.full(sf.shape[:-1] + (1,), INF, s.dtype)], axis=-1)
        if S_to:
            tau = task_arrival_times_gather(
                params["to_plan"], s, params["to_off"])
            out["to"] = (jnp.sort(tau, axis=-1) if ks is None
                         else _smallest(tau, ks)[..., -1:])
        if S_tau:
            out["tau"] = task_arrival_times_gather(
                params["tau_plan"], s, params["tau_off"])
        if F_lb:
            win = s_pad[:, params["lb_idx"]] + params["lb_off"]
            w = n if ks is None else ks
            fs = _smallest(win, w)
            out["lb"] = fs if ks is None else fs[..., -1:]
        if F_pcmm:
            th = _pcmm_threshold(n)
            win = s_pad[:, params["pcmm_idx"]] + params["pcmm_off"]
            out["pcmm"] = _smallest(win, th)[..., -1:]
        if P_pc:
            # per-worker one-shot times at each pc spec's own closing slot,
            # ranked by a full sort so the decode threshold (which varies
            # with the runtime load) can be a runtime gather index — the
            # th-th order statistic is the same value either way.
            tw = jnp.moveaxis(s[..., params["pc_slot"]], -1, -2)
            tw = tw + params["pc_eps"][:, None]            # (chunk, P, n)
            srt = jnp.sort(tw, axis=-1)
            idx = jnp.broadcast_to(params["pc_th"][:, None],
                                   (srt.shape[0], P_pc, 1))
            out["pc"] = jnp.take_along_axis(srt, idx, axis=-1)
        return out

    return eval_fn


def _build_stats_fn(sig, model):
    """Per-chunk bucketed evaluator: (chunk, 2) per-trial keys + runtime
    ``params`` -> {group: (chunk, S, L)}.  Samples one round of delays per
    trial and scores every scheme of the bucket."""
    n, r_max = sig[1], sig[2]
    eval_fn = _build_bucket_eval(sig)

    def stats_fn(keys: Array, params) -> Dict[str, Array]:
        def one(kk):
            T1, T2 = model.sample(kk, 1, n, r_max)
            return T1[0], T2[0]

        T1, T2 = jax.vmap(one)(keys)                 # (chunk, n, r_max)
        s = jnp.cumsum(T1, axis=-1) + T2             # slot arrivals, eq. (1)
        return eval_fn(s, params)

    return stats_fn


# ----------------------- executor caches + observability ----------------------

class _LRUCache:
    """Least-recently-used bound on the compiled-executor caches.  Once a
    grid sweeps many ``(n, r_max)`` buckets (or many device tuples) an
    unbounded dict would pin every executable ever compiled; the default
    capacity comfortably holds a full grid's buckets while letting one-off
    shapes age out.  Also the home of the cache observability counters
    surfaced by ``cache_stats()``."""

    def __init__(self, capacity: int = 128):
        self.capacity = int(capacity)
        self._d: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_s = 0.0

    def get(self, key):
        hit = self._d.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        self._trim()

    def set_capacity(self, capacity: int) -> None:
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._trim()

    def _trim(self) -> None:
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)            # evict least recent
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> dict:
        return {"size": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "compile_s": round(self.compile_s, 6)}


_EXEC_CACHE = _LRUCache()
_TRACE_COUNT = 0


def _count_trace() -> None:
    """Called at the top of every scan function: the call executes during
    tracing only, i.e. once per jit specialization, so the counter measures
    (re)traces — exactly one per shape bucket when the bucketed cache is
    doing its job (pinned by the grid retrace test)."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def _timed_first(fn, cache: _LRUCache):
    """Attribute the first call's wall time to ``cache.compile_s``: tracing
    and compilation happen synchronously inside the first call while the
    actual execution is dispatched asynchronously, so first-call wall time
    is a faithful (slightly conservative) compile-seconds estimate."""
    done = False

    def wrapped(*args):
        nonlocal done
        if done:
            return fn(*args)
        t0 = time.perf_counter()
        out = fn(*args)
        cache.compile_s += time.perf_counter() - t0
        done = True
        return out

    return wrapped


def clear_cache() -> None:
    """Drop compiled evaluators (mainly for benchmarking cold starts)."""
    _EXEC_CACHE.clear()
    _ROUNDS_CACHE.clear()


def set_cache_capacity(capacity: int) -> None:
    """Bound both compiled-executor LRU caches to ``capacity`` entries
    (evicting the least-recently-used immediately if already over)."""
    _EXEC_CACHE.set_capacity(capacity)
    _ROUNDS_CACHE.set_capacity(capacity)


def cache_stats() -> dict:
    """Observability for the compiled-executor caches: sizes, hit / miss /
    eviction counts, cumulative compile seconds, and ``traces`` — the
    number of executor (re)traces since import (one per shape bucket when
    the bucketed cache works; see ``_count_trace``)."""
    return {"exec": _EXEC_CACHE.stats(), "rounds": _ROUNDS_CACHE.stats(),
            "traces": _TRACE_COUNT}


def _normalize_chunk(trials: int, chunk: Optional[int]) -> int:
    """Canonical ``chunk`` normalization shared by every sweep entry point.
    ``None`` means one chunk; anything outside ``1..trials`` is an error
    (an oversized chunk used to be silently clamped, which hid typos and,
    under shard padding, would burn whole padded chunks per device)."""
    if trials < 1:
        raise ValueError(f"trials must be >= 1, got {trials}")
    if chunk is None:
        return trials
    chunk = int(chunk)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got chunk={chunk}")
    if chunk > trials:
        raise ValueError(
            f"chunk ({chunk}) exceeds trials ({trials}); pass chunk <= "
            f"trials (or chunk=None for a single chunk)")
    return chunk


def _shard_layout(trials: int, chunk: int, devices):
    """Device/padding layout of a sharded sweep.

    The global trial axis is cut into ``ceil(trials / chunk)`` chunks (the
    same decomposition for ANY device count — that is what keeps sharded
    results bit-exact vs. the single-device path), chunks are dealt to
    devices in contiguous blocks, and the chunk count is padded up to a
    multiple of the devices actually used (at most ``d_eff - 1`` padded
    chunks; padded trials repeat real keys and are masked out of every
    statistic).  Returns ``(devs, nc_pad, padded_trials)``.
    """
    devs = trial_devices(devices)
    nc = -(-trials // chunk)                    # global chunks
    d_eff = min(len(devs), nc)
    nc_pad = -(-nc // d_eff) * d_eff
    return devs[:d_eff], nc_pad, nc_pad * chunk


def trial_keys(seed: int, trials: int) -> Array:
    """The engine's per-trial CRN keys: key ``t`` is
    ``fold_in(PRNGKey(seed), t)`` — a pure function of ``(seed, t)``, so
    every chunk of the trial axis re-derives its own keys *device-side*
    from ``(seed, global trial id)`` inside the scans instead of
    materializing a ``(trials, 2)`` key table on the host (800 MB at 10^8
    trials).  This helper is the materialized reference twin the tests pin
    the in-scan derivation against."""
    return _fold_keys(jax.random.PRNGKey(seed),
                      jnp.arange(trials, dtype=jnp.int32))


def _fold_keys(base_key: Array, tids: Array) -> Array:
    """(chunk,) global trial ids -> (chunk, 2) per-trial CRN keys."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(base_key, tids)


def _padded_keys(seed: int, trials: int, padded: int) -> Array:
    """``trial_keys`` padded to the shard layout.  Pad rows repeat the last
    real key — exactly what the scans' clamped trial ids derive — and feed
    masked lanes only, so CRN pairing across specs survives any device
    count.  Kept as the tests' reference twin of the scans' in-body
    ``min(start + offs, trials - 1)`` derivation."""
    keys = trial_keys(seed, trials)
    if padded > trials:
        pad = jnp.broadcast_to(keys[-1:], (padded - trials, 2))
        keys = jnp.concatenate([keys, pad], axis=0)
    return keys


def _register_barrier_batching() -> None:
    """``jax.lax.optimization_barrier`` (used below to pin the within-chunk
    reduction order) has no vmap batching rule in the jax versions this repo
    pins, and the device-sharded path vmaps the chunk scan over a leading
    device axis (``repro.sharding.shard_trials``).  The rule is trivially
    dimension-preserving — the barrier is a semantic identity — so register
    it when missing rather than forking the single- and multi-device
    programs (which would itself break cross-device-count bit-exactness)."""
    try:
        from jax.interpreters import batching
        p = getattr(jax.lax, "optimization_barrier_p", None)
        if p is not None and p not in batching.primitive_batchers:
            def rule(args, dims):
                return p.bind(*args), dims
            batching.primitive_batchers[p] = rule
    except Exception:  # pragma: no cover — future-jax defensive
        pass


_register_barrier_batching()


def _tree_sum(v: Array) -> Array:
    """Sum over axis 0 through an explicit balanced pairwise tree (zero-pad
    to a power of two, then halve): every add is elementwise, so the f32
    association order is a function of the axis length ALONE — the same
    trial chunk reduces bit-identically whatever the width of the spec
    stack around it (see the bit-exactness note in ``sums_scan``)."""
    m = v.shape[0]
    p = _next_pow2(m)
    if p != m:
        v = jnp.concatenate(
            [v, jnp.zeros((p - m,) + v.shape[1:], v.dtype)], axis=0)
    while v.shape[0] > 1:
        v = v[0::2] + v[1::2]
    return v[0]


def _get_exec(sig: tuple, model, devices: tuple):
    """Compiled (sums-scan, samples-scan) pair for one shape bucket, cached
    per (sig, model, devices) — the signature carries only counts and
    padded widths (see ``_eval_layout``), so every sweep with the same
    scheme-kind structure reuses one executable with its own runtime
    params (the sharded evaluator is mesh-specific, so the device tuple is
    part of the key).

    Both scans derive their per-trial CRN keys device-side from (base key,
    global trial id) via ``fold_in`` — the validity mask folds into the
    same integer arithmetic (``start + offs`` vs ``limit``), so no key
    table or mask is materialized on the host — and emit **per-chunk
    float32 partials** combined on the host in float64 in global chunk
    order, which makes the reduction independent of how chunks are dealt
    to devices: sharded stats are bit-exact vs. single-device."""
    cache_key = None
    try:
        cache_key = (sig, model, devices)
        hit = _EXEC_CACHE.get(cache_key)
        if hit is not None:
            return hit
    except TypeError:              # unhashable custom model: build uncached
        cache_key = None

    stats_fn = _build_stats_fn(sig, model)

    def sums_scan(base_key, starts, offs, limit, params):
        _count_trace()

        def body(carry, start):
            tids_raw = start + offs
            kc = _fold_keys(base_key, jnp.minimum(tids_raw, limit - 1))
            st = stats_fn(kc, params)
            ok = (tids_raw < limit)[:, None, None]
            # the barrier pins the f32 rounding of the masked values and
            # squares BEFORE the trial reduction, and ``_tree_sum`` fixes
            # the reduction's association order as a function of the chunk
            # length alone: a native ``sum(axis=0)`` lets XLA pick a
            # stack-width-dependent lane decomposition (and fuse the
            # square in as an FMA), so the same cell evaluated in two
            # different spec stacks could differ in the last ulp of its
            # partial sums — breaking the grid engine's bit-exactness
            # contract between fused and per-cell sweeps.
            s0 = {g: jnp.where(ok, v, 0.0) for g, v in st.items()}
            s1 = {g: jnp.where(ok, jnp.square(v), 0.0)
                  for g, v in st.items()}
            s0, s1 = jax.lax.optimization_barrier((s0, s1))
            s0 = {g: _tree_sum(v) for g, v in s0.items()}
            s1 = {g: _tree_sum(v) for g, v in s1.items()}
            return carry, (s0, s1)

        _, parts = jax.lax.scan(body, None, starts)
        return parts               # 2 x {group: (nc, S, L)} partials

    def samples_scan(base_key, starts, offs, limit, params):
        _count_trace()

        def body(carry, start):
            tids = jnp.minimum(start + offs, limit - 1)
            return carry, stats_fn(_fold_keys(base_key, tids), params)

        _, ys = jax.lax.scan(body, None, starts)
        return ys                  # {group: (nc, chunk, S, L)}

    if len(devices) > 1:
        # shard_trials returns a fully-jitted callable; no outer jit.
        # Only the per-chunk starts are sharded — the base key, offset
        # vector, trial limit, and runtime eval params replicate.
        exec_ = (shard_trials(sums_scan, devices, replicated=(0, 2, 3, 4)),
                 shard_trials(samples_scan, devices, replicated=(0, 2, 3, 4)))
    else:
        exec_ = (jax.jit(sums_scan), jax.jit(samples_scan))
    exec_ = (_timed_first(exec_[0], _EXEC_CACHE),
             _timed_first(exec_[1], _EXEC_CACHE))
    if cache_key is not None:
        _EXEC_CACHE.put(cache_key, exec_)
    return exec_


def _covered_tasks(sp: SchemeSpec) -> int:
    """Number of distinct tasks a (possibly ragged) TO spec can deliver.
    Row re-permutation never changes the union of active slots, so this is
    permutation-invariant; rebalance specs are validated to have a slot-0
    diagonal covering everything."""
    C = sp.matrix()
    return len(np.unique(C[C >= 0]))


def _check_specs(specs: Sequence[SchemeSpec], n: int) -> Tuple[SchemeSpec, ...]:
    from . import scheduling
    specs = tuple(specs)
    if not specs:
        raise ValueError("need at least one SchemeSpec")
    names = [sp.name for sp in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate scheme names: {names}")
    for sp in specs:
        if sp.kind in ("to", "tau", "adaptive") and len(sp.C) != n:
            raise ValueError(f"{sp.name}: TO matrix has {len(sp.C)} rows, "
                             f"expected n={n}")
        if sp.kind in ("lb", "pc", "pcmm") and not 1 <= sp.load:
            raise ValueError(f"{sp.name}: bad load r={sp.r}")
        if sp.kind == "pcmm" and n * sp.load < _pcmm_threshold(n):
            raise ValueError(
                f"{sp.name}: PCMM infeasible: n*r={n * sp.load} < "
                f"2n-1={_pcmm_threshold(n)}")
        if sp.comm_eps < 0:
            raise ValueError(f"{sp.name}: comm_eps must be >= 0, got "
                             f"{sp.comm_eps}")
        if sp.messages is not None:
            if sp.kind == "pc" and sp.messages != 1:
                raise ValueError(
                    f"{sp.name}: pc is one-shot by construction (the decoder "
                    f"needs each worker's full sum); use pcmm for "
                    f"multi-message coded rounds")
            if not 1 <= sp.messages <= sp.load:
                raise ValueError(
                    f"{sp.name}: need 1 <= messages <= load={sp.load}, got "
                    f"messages={sp.messages}")
        # ---- ragged-load validation -----------------------------------
        if sp.loads is not None:
            if sp.kind in ("pc", "pcmm"):
                raise ValueError(f"{sp.name}: ragged loads are not defined "
                                 f"for coded schemes (the decode threshold "
                                 f"assumes a uniform load)")
            lv = np.asarray(sp.loads, np.int64)
            if lv.shape != (n,) or lv.min() < 1 or lv.max() > sp.load:
                raise ValueError(
                    f"{sp.name}: loads must be ({n},) with 1 <= load <= "
                    f"{sp.load}, got {sp.loads}")
        if sp.kind in ("to", "tau", "adaptive") and not sp.rebalance:
            # masks must be a trailing suffix matching the loads field
            # (spec constructors guarantee this; direct SchemeSpec
            # construction is validated here)
            C = sp.matrix()
            if sp.loads is not None or (C < 0).any():
                scheduling.validate_to_matrix(C, n, loads=sp.loads)
        if sp.rebalance:
            if sp.kind != "adaptive":
                raise ValueError(f"{sp.name}: rebalance is only defined for "
                                 f"adaptive specs")
            C = sp.matrix()
            if (C < 0).any():
                raise ValueError(f"{sp.name}: rebalance needs a dense base "
                                 f"matrix (its width is the load cap)")
            if sp.loads is None:
                raise ValueError(f"{sp.name}: rebalance needs an initial "
                                 f"loads budget below the grid width")
            if sorted(C[:, 0].tolist()) != list(range(n)):
                raise ValueError(
                    f"{sp.name}: rebalance needs a slot-0 diagonal (every "
                    f"row's first task distinct, e.g. CS/SS) so any load "
                    f"vector keeps all tasks covered")
            if sp.comm_eps:
                raise ValueError(f"{sp.name}: rebalance does not support "
                                 f"comm_eps yet")
        elif sp.comm_eps and sp.kind == "adaptive":
            raise ValueError(f"{sp.name}: comm_eps is not supported for "
                             f"adaptive specs yet")
    return specs


class _Pending:
    """A dispatched (in-flight) sweep.  The device work was launched
    asynchronously (JAX async dispatch); ``resolve()`` blocks on the
    transfers and finishes the float64 host combine.  ``stream_grid``
    keeps a small window of these in flight so cell ``j+1``'s compute
    overlaps cell ``j``'s device->host transfer and combine."""

    __slots__ = ("_resolve", "_out", "_done")

    def __init__(self, resolve_fn):
        self._resolve = resolve_fn
        self._out = None
        self._done = False

    def resolve(self):
        if not self._done:
            self._out = self._resolve()
            self._done = True
            self._resolve = None
        return self._out


def _scan_coords(trials: int, chunk: int, nc_pad: int):
    """The scans' runtime trial-axis coordinates: per-chunk global start
    ids (the sharded axis), the in-chunk offset vector (its length carries
    the chunk size into the compiled shape), and the valid-trial limit."""
    starts = jnp.arange(nc_pad, dtype=jnp.int32) * jnp.int32(chunk)
    offs = jnp.arange(chunk, dtype=jnp.int32)
    return starts, offs, jnp.int32(trials)


def _validate_single_round(specs: Sequence[SchemeSpec], n: int,
                           ks: Optional[int]) -> Tuple[SchemeSpec, ...]:
    """Shared validation for the single-round entry points (``sweep``,
    ``completion_samples``, ``ResumableSweep``): spec well-formedness, no
    adaptive specs (those need a rounds axis), target-k range, and task
    coverage (a ragged schedule that cannot deliver ``k`` distinct tasks
    has an infinite completion time)."""
    specs = _check_specs(specs, n)
    for sp in specs:
        if sp.kind == "adaptive":
            raise ValueError(f"{sp.name}: adaptive schemes need a rounds "
                             f"axis — use sweep_rounds")
    if ks is not None and not 1 <= ks <= n:
        raise ValueError(f"need 1 <= k <= n={n}, got k={ks}")
    for sp in specs:
        if sp.kind != "to":
            continue                   # tau: raw arrivals, +inf meaningful
        covered = _covered_tasks(sp)
        if ks is not None and covered < ks:
            raise ValueError(
                f"{sp.name}: ragged schedule covers only {covered} "
                f"distinct tasks < k={ks}; the completion time would be "
                f"infinite")
        if ks is None and covered < n:
            raise ValueError(
                f"{sp.name}: schedule covers only {covered} of {n} tasks, "
                f"so all-k completion times are infinite beyond "
                f"k={covered}; sweep with ks <= {covered} instead")
    return specs


def _dispatch_run(specs: Sequence[SchemeSpec], model, n: int, *, trials: int,
                  seed: int, chunk: Optional[int], ks: Optional[int],
                  want_samples: bool, devices=None) -> _Pending:
    """Validate + launch one sweep without blocking on its results; the
    returned ``_Pending`` resolves to ``_run``'s output."""
    specs = _validate_single_round(specs, n, ks)
    r_max = max(sp.load for sp in specs)
    chunk = _normalize_chunk(trials, chunk)
    devs, nc_pad, padded = _shard_layout(trials, chunk, devices)
    sig, params, slots = _eval_layout(specs, n, r_max, ks)
    jsums, jsamples = _get_exec(sig, model, devs)

    base_key = jax.random.PRNGKey(seed)
    starts, offs, limit = _scan_coords(trials, chunk, nc_pad)
    pj = {k2: jnp.asarray(v) for k2, v in params.items()}

    if want_samples:
        ys = jsamples(base_key, starts, offs, limit, pj)

        def resolve_samples():
            out = {}
            for name, (g, i) in slots.items():
                v = ys[g]                        # (nc, chunk, S, L)
                out[name] = v[:, :, i, :].reshape(padded,
                                                  v.shape[-1])[:trials]
            return out

        return _Pending(resolve_samples)

    p0, p1 = jsums(base_key, starts, offs, limit, pj)

    def resolve_sums():
        # per-chunk float32 partials -> float64 in global chunk order: the
        # same reduction whatever the device count (bit-exact sharding).
        mu_g = {g: np.asarray(v, np.float64).sum(axis=0) / trials
                for g, v in p0.items()}
        sq_g = {g: np.asarray(v, np.float64).sum(axis=0)
                for g, v in p1.items()}
        means, stderr = {}, {}
        for name, (g, i) in slots.items():
            mu = mu_g[g][i]
            var = np.maximum(sq_g[g][i] / trials - mu * mu, 0.0)
            means[name] = mu
            stderr[name] = np.sqrt(var / trials)
        return means, stderr

    return _Pending(resolve_sums)


def _run(specs: Sequence[SchemeSpec], model, n: int, *, trials: int,
         seed: int, chunk: Optional[int], ks: Optional[int],
         want_samples: bool, devices=None):
    return _dispatch_run(specs, model, n, trials=trials, seed=seed,
                         chunk=chunk, ks=ks, want_samples=want_samples,
                         devices=devices).resolve()


# ------------------------------- public API ----------------------------------

@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Mean completion times (and MC standard errors) per scheme.

    ``means[name]`` has one column per k in 1..n when the sweep ran in
    all-k mode (``ks=None``), a single column for single-k sweeps and for
    ``pcmm`` (whose threshold ``2n-1`` exceeds ``n``).
    """
    means: Dict[str, np.ndarray]
    stderr: Dict[str, np.ndarray]
    trials: int
    n: int
    ks: Optional[int]
    fixed: frozenset = frozenset()      # pc/pcmm: scheme-defined thresholds

    def at_k(self, name: str, k: Optional[int] = None) -> float:
        """Mean completion time of ``name`` at target ``k``.  Coded schemes
        (``pc``/``pcmm``) always report their own decode threshold, so ``k``
        is ignored for them."""
        if name not in self.means:
            raise ValueError(f"unknown scheme {name!r}; have "
                             f"{sorted(self.means)}")
        v = self.means[name]
        if name in self.fixed:
            return float(v[0])
        if k is None:
            raise ValueError(f"{name} needs an explicit k")
        if v.shape[-1] == self.n:
            if not 1 <= k <= self.n:
                raise ValueError(f"need 1 <= k <= {self.n}, got {k}")
            return float(v[k - 1])
        if self.ks is not None and k != self.ks:
            raise ValueError(f"sweep ran with k={self.ks}; asked for k={k}")
        return float(v[0])


def _reject_single_round_trace(record_trace: bool, fn: str) -> None:
    """Canonical rejection of ``record_trace=`` on the single-round entry
    points (accepted for signature uniformity with the rounds axis)."""
    if record_trace:
        raise ValueError(f"record_trace is only available on the rounds "
                         f"axis (sweep_rounds / trajectory_samples); "
                         f"{fn} evaluates a single round and has no "
                         f"per-round delay tables to record")


def sweep(specs: Sequence[SchemeSpec], model, n: int, *, trials: int = 20000,
          seed: int = 0, chunk: Optional[int] = None,
          ks: Optional[int] = None, record_trace: bool = False,
          devices=None, greedy_impl: Optional[str] = None) -> SweepResult:
    """Evaluate every scheme against ONE shared set of delay draws.

    Parameters
    ----------
    specs:  schemes to evaluate (see ``to_spec``/``lb_spec``/...).
    model:  a ``DelayModel``; sampled once per trial with a per-trial subkey.
    n:      number of tasks (= workers in the paper's setting).
    trials: Monte-Carlo rounds.
    chunk:  trials are streamed through ``lax.scan`` in chunks of this size
            (default: one chunk).  The per-trial draws are chunk-invariant,
            so per-trial samples are bit-identical for any chunk size and
            means agree to accumulation round-off; memory is
            O(chunk * n * r_max) per device.
    ks:     ``None`` → all-k mode: one sort yields every k in 1..n.
            An int → only that order statistic, via ``lax.top_k``.
    record_trace: accepted for signature uniformity with ``sweep_rounds``;
            single-round sweeps have nothing to record, so ``True`` raises
            a ValueError pointing at the rounds axis.
    devices: shard the trial axis across these devices
            (``None`` = all local devices, an int = that many, or an
            explicit sequence).  Whole chunks are dealt to devices, so at
            most ``min(len(devices), ceil(trials/chunk))`` devices are
            used — pass ``chunk <= trials // len(devices)`` to engage all
            of them.  Results are bit-exact vs. the single-device path for
            the same (trials, seed, chunk).
    greedy_impl: accepted (and validated) for signature uniformity with
            ``sweep_rounds``; single-round sweeps reject adaptive specs,
            so there is no greedy pick loop to route.
    """
    from .scheduling import _resolve_greedy_impl
    _reject_single_round_trace(record_trace, "sweep")
    _resolve_greedy_impl(greedy_impl)
    means, stderr = _run(specs, model, n, trials=trials, seed=seed,
                         chunk=chunk, ks=ks, want_samples=False,
                         devices=devices)
    fixed = frozenset(sp.name for sp in specs if sp.kind in ("pc", "pcmm"))
    return SweepResult(means=means, stderr=stderr, trials=trials, n=n, ks=ks,
                       fixed=fixed)


# ----------------------------- resumable sweeps ------------------------------

class ResumableSweep:
    """A sweep whose trial axis can be *extended* instead of recomputed.

    The engine's per-trial CRN key is a pure function of ``(seed, global
    trial id)`` and its statistics are combined from per-chunk float32
    partials in global chunk order (see ``_get_exec``), so a sweep paused
    at ``t`` trials can continue by dispatching only the chunks covering
    trials ``t..total-1`` with the *same* base key and chunk size: the new
    chunk partials are bit-identical to the corresponding chunks of a
    fresh run at ``total``, and accumulating them after the stored ones
    (pad chunks contribute exact float64 zeros) reproduces a fresh
    ``sweep(..., trials=total)`` bit-for-bit.  That is what lets the
    racing planner (``repro.core.planner``) deepen only the cells whose
    comparison is still close, at zero re-evaluation cost.

    Contract and caveats:

    * ``chunk`` is required — resumability is defined by the chunk
      decomposition.  Every ``extend_trials`` total except the last must
      land on a chunk boundary: a partial final chunk clamps its trailing
      trial ids, so there is no representable continuation past it
      (extending from a non-aligned total raises).
    * ``narrow(names)`` drops schemes from subsequent extensions (the
      planner eliminating cells).  The evaluator keeps the *original*
      slot-grid width ``r_max``: delay draws have shape ``(n, r_max)``
      and CRN pairing across the surviving schemes only holds if that
      shape never changes.  ``_tree_sum`` pins the per-chunk reduction
      order as a function of the chunk length alone, so narrowing the
      spec stack keeps every survivor's partials bit-identical.
    * With ``keep_samples=True`` each extension also dispatches the
      samples scan and stores per-trial float32 statistics host-side
      (memory ``O(done * L)`` per scheme).  The sums path still comes
      from the sums scan: XLA's rounding of the squared statistics in
      the fused sums program is not reproducible from the emitted
      samples (measured: last-ulp differences in all-k mode), so
      deriving partials host-side would break the bit-exactness
      contract.
    """

    def __init__(self, specs: Sequence[SchemeSpec], model, n: int, *,
                 seed: int = 0, chunk: int, ks: Optional[int] = None,
                 devices=None, keep_samples: bool = False):
        specs = _validate_single_round(specs, n, ks)
        chunk = int(chunk)
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got chunk={chunk}")
        self._specs = specs
        self._model = model
        self._n = int(n)
        self._seed = int(seed)
        self._chunk = chunk
        self._ks = ks
        self._devices = devices
        self._keep = bool(keep_samples)
        self._r_max = max(sp.load for sp in specs)
        self._base_key = jax.random.PRNGKey(seed)
        self._done = 0
        self._p0: Dict[str, list] = {sp.name: [] for sp in specs}
        self._p1: Dict[str, list] = {sp.name: [] for sp in specs}
        self._samp: Dict[str, list] = (
            {sp.name: [] for sp in specs} if self._keep else {})

    @property
    def trials(self) -> int:
        """Trials evaluated so far."""
        return self._done

    @property
    def chunk(self) -> int:
        return self._chunk

    @property
    def spec_names(self) -> Tuple[str, ...]:
        return tuple(sp.name for sp in self._specs)

    def extend_trials(self, total: int) -> SweepResult:
        """Continue the sweep to ``total`` trials and return the combined
        result — bit-exact with ``sweep(..., trials=total)`` at the same
        (seed, chunk)."""
        total = int(total)
        if total <= self._done:
            raise ValueError(
                f"extend_trials: total ({total}) must exceed the "
                f"{self._done} trials already evaluated")
        if self._done % self._chunk != 0:
            raise ValueError(
                f"extend_trials: current total ({self._done}) is not a "
                f"multiple of chunk ({self._chunk}); a partial final chunk "
                f"clamps its trailing trial ids, so the sweep cannot be "
                f"extended past it (keep every total but the last "
                f"chunk-aligned)")
        add = total - self._done
        nc = -(-add // self._chunk)
        devs = trial_devices(self._devices)
        d_eff = min(len(devs), nc)
        nc_pad = -(-nc // d_eff) * d_eff
        sig, params, slots = _eval_layout(self._specs, self._n, self._r_max,
                                          self._ks)
        jsums, jsamples = _get_exec(sig, self._model, devs[:d_eff])
        first = self._done // self._chunk
        starts = ((jnp.arange(nc_pad, dtype=jnp.int32) + jnp.int32(first))
                  * jnp.int32(self._chunk))
        offs = jnp.arange(self._chunk, dtype=jnp.int32)
        limit = jnp.int32(total)
        pj = {k2: jnp.asarray(v) for k2, v in params.items()}
        p0, p1 = jsums(self._base_key, starts, offs, limit, pj)
        ys = (jsamples(self._base_key, starts, offs, limit, pj)
              if self._keep else None)
        for name, (g, i) in slots.items():
            self._p0[name].append(np.asarray(p0[g], np.float32)[:, i, :])
            self._p1[name].append(np.asarray(p1[g], np.float32)[:, i, :])
            if ys is not None:
                v = ys[g]                      # (nc_pad, chunk, S, L)
                flat = v[:, :, i, :].reshape(nc_pad * self._chunk,
                                             v.shape[-1])
                self._samp[name].append(np.asarray(flat[:add], np.float32))
        self._done = total
        return self.result()

    def result(self) -> SweepResult:
        """Combined result over all trials evaluated so far (same float64
        host combine as ``sweep``, in global chunk order)."""
        if self._done == 0:
            raise ValueError("no trials evaluated yet; call extend_trials")
        t = self._done
        means: Dict[str, np.ndarray] = {}
        stderr: Dict[str, np.ndarray] = {}
        for sp in self._specs:
            s0 = np.concatenate(self._p0[sp.name], axis=0).astype(np.float64)
            s1 = np.concatenate(self._p1[sp.name], axis=0).astype(np.float64)
            mu = s0.sum(axis=0) / t
            var = np.maximum(s1.sum(axis=0) / t - mu * mu, 0.0)
            means[sp.name] = mu
            stderr[sp.name] = np.sqrt(var / t)
        fixed = frozenset(sp.name for sp in self._specs
                          if sp.kind in ("pc", "pcmm"))
        return SweepResult(means=means, stderr=stderr, trials=t, n=self._n,
                           ks=self._ks, fixed=fixed)

    def samples(self) -> Dict[str, np.ndarray]:
        """Per-trial statistics ``{name: (trials, L)}`` accumulated so far
        (CRN-paired across schemes: row ``t`` of every scheme saw the same
        delay draws).  Requires ``keep_samples=True``."""
        if not self._keep:
            raise ValueError("per-trial samples were not kept; construct "
                             "with keep_samples=True")
        return {sp.name: np.concatenate(self._samp[sp.name], axis=0)
                for sp in self._specs}

    def narrow(self, names: Sequence[str]) -> None:
        """Drop every scheme not in ``names`` from subsequent extensions
        (their accumulated state is freed).  The evaluator keeps the
        original ``r_max`` so the survivors' draw coordinates — and hence
        their partials — are unchanged."""
        keep = set(names)
        have = {sp.name for sp in self._specs}
        unknown = sorted(keep - have)
        if unknown:
            raise ValueError(f"narrow: unknown scheme(s) {unknown}; have "
                             f"{sorted(have)}")
        if not keep:
            raise ValueError("narrow: need at least one surviving scheme")
        self._specs = tuple(sp for sp in self._specs if sp.name in keep)
        for d in (self._p0, self._p1, self._samp):
            for nm in list(d):
                if nm not in keep:
                    del d[nm]


def resumable_sweep(specs: Sequence[SchemeSpec], model, n: int, *,
                    seed: int = 0, chunk: int, ks: Optional[int] = None,
                    devices=None, keep_samples: bool = False
                    ) -> ResumableSweep:
    """Construct a ``ResumableSweep`` (see its docstring): a sweep whose
    trial axis extends incrementally via ``extend_trials``, bit-exact with
    a fresh ``sweep`` at the combined trial count under CRN."""
    return ResumableSweep(specs, model, n, seed=seed, chunk=chunk, ks=ks,
                          devices=devices, keep_samples=keep_samples)


def completion_samples(spec: SchemeSpec, model, n: int, *, trials: int = 10000,
                       seed: int = 0, chunk: Optional[int] = None,
                       k: Optional[int] = None, record_trace: bool = False,
                       devices=None,
                       greedy_impl: Optional[str] = None) -> Array:
    """Per-trial completion-time samples for one scheme.

    Returns shape ``(trials,)`` when ``k`` is given (or for ``pcmm``), else
    ``(trials, n)`` with column ``k-1`` holding the k-th order statistic.
    ``record_trace`` / ``greedy_impl`` are accepted for signature
    uniformity with the rounds axis (see ``sweep``).
    """
    from .scheduling import _resolve_greedy_impl
    _reject_single_round_trace(record_trace, "completion_samples")
    _resolve_greedy_impl(greedy_impl)
    out = _run([spec], model, n, trials=trials, seed=seed, chunk=chunk,
               ks=k, want_samples=True, devices=devices)[spec.name]
    return out[:, 0] if out.shape[-1] == 1 else out


def task_arrival_samples(C, model, *, trials: int = 10000, seed: int = 0,
                         chunk: Optional[int] = None,
                         messages: Optional[int] = None,
                         loads=None, comm_eps: float = 0.0,
                         record_trace: bool = False, devices=None,
                         greedy_impl: Optional[str] = None) -> Array:
    """Raw per-task arrival-time samples ``tau`` of shape (trials, n) for a
    TO matrix — shared-draw backing for joint-survival estimators.
    ``messages`` is the per-round message budget (default: per-slot sends);
    ``loads`` masks each row's trailing slots (ragged per-worker loads —
    tasks with no active copy come out +inf); ``comm_eps`` the per-message
    overhead.  ``record_trace`` / ``greedy_impl`` are accepted for
    signature uniformity with the rounds axis (see ``sweep``)."""
    from .scheduling import _resolve_greedy_impl
    _reject_single_round_trace(record_trace, "task_arrival_samples")
    _resolve_greedy_impl(greedy_impl)
    n = np.asarray(C).shape[0]
    spec = tau_spec("tau", C, messages=messages, loads=loads,
                    comm_eps=comm_eps)
    return _run([spec], model, n, trials=trials, seed=seed, chunk=chunk,
                ks=None, want_samples=True, devices=devices)[spec.name]


# ----------------------------- rounds axis -----------------------------------

def _build_rounds_fn(specs: Tuple[SchemeSpec, ...], process, n: int,
                     r_max: int, ks: int, rounds: int, beta: float,
                     gamma: float, censored: bool,
                     deadline: Optional[float] = None,
                     policy: str = "wait",
                     greedy_impl: Optional[str] = None):
    """Multi-round evaluator: (chunk, 2) per-trial keys + (chunk,) global
    trial ids -> {name: (rounds, chunk)} per-round completion times.

    Trial ids exist for trace-backed processes
    (``repro.core.trace.TraceProcess``): they tell each lane which trial
    of the recorded table it replays, so replay — like sampling — is
    invariant to how the trial axis is chunked.  Parametric processes are
    fully determined by their per-trial keys and ignore the ids.

    One ``lax.scan`` over rounds carries (a) the delay process state — the
    straggler persistence — and (b) the adaptive schemes' per-trial EMA of
    observed per-worker compute delays.  Every scheme scores the same delay
    realization each round (common random numbers), so per-round and
    cumulative scheme gaps are paired-sample estimates.

    With ``censored`` the adaptive feedback is restricted to what a real
    master sees: only messages that arrived before *that scheme's own* round
    completion are observed, each scheme carries its own estimate state, and
    a worker that delivered nothing keeps its previous estimate (new workers
    start at +inf, i.e. sorted slowest until they first deliver).  The
    uncensored path keeps the original idealized full-delay feedback,
    bit-identical to the pre-censoring engine.

    ``deadline`` caps every round (fault tolerance): the returned stream
    becomes ``(times, aux)`` with per-scheme degradation streams
    (``realized``, ``missed``, ``stale`` — each (rounds, chunk)):

    * ``wait``          — times unchanged (a round missing k arrivals
                          forever reports +inf); ``missed`` marks rounds
                          whose completion exceeded the deadline.
    * ``close_partial`` — the round closes at ``min(t_done, deadline)``
                          with however many distinct results arrived;
                          ``realized`` is that count (capped at k),
                          ``stale`` the per-round missing gradient mass
                          ``(k - realized) / k``.
    * ``reissue``       — like ``close_partial``, but undelivered tasks
                          accumulate in a per-trial backlog that adaptive
                          schemes re-gather first next round (the greedy
                          assignment's ``need`` priority); ``stale`` is
                          ``backlog / k`` (how much re-gathering is owed).

    With ``deadline=None`` the aux dict is empty and every number is
    bit-identical to the pre-deadline engine.
    """
    from . import scheduling                    # adaptive assignment

    static_specs = tuple(sp for sp in specs if sp.kind != "adaptive")
    ad_specs = tuple(sp for sp in specs if sp.kind == "adaptive")
    eval_fn = (_build_eval(static_specs, n, r_max, ks, deadline)
               if static_specs else None)
    # numpy scalars, NOT eager jnp arrays: this builder runs outside jit,
    # and concrete jax scalars closed over by the sharded rounds program
    # would be device-0-resident buffers; plain literals fold into the
    # traced program identically on every device and promote identically
    # in float32 arithmetic.
    DL = None if deadline is None else np.float32(deadline)
    reissue = deadline is not None and policy == "reissue"
    kf = np.float32(ks)
    nf = np.float32(n)

    def _policy_close(v, by, dv):
        """Apply the fallback policy to one scheme's raw completion ``v``
        (chunk,) given its arrival counts: returns (v_eff, realized,
        missed)."""
        if policy == "wait":
            return v, jnp.minimum(dv, kf), (~(v <= DL)).astype(jnp.float32)
        return (jnp.minimum(v, DL), jnp.minimum(by, kf),
                (by < kf).astype(jnp.float32))
    ad_mats = tuple(sp.matrix() for sp in ad_specs)
    # rebalance specs mask slots dynamically, so their plan must keep every
    # slot of the dense base (an identity plan — a static slot map would
    # bake the *initial* budget's message grouping into every round);
    # static ragged specs bake their masks in.
    ad_plans = tuple(task_gather_plan(sp.matrix(), n, r_max)
                     if sp.rebalance else _plan_of(sp, n, r_max)
                     for sp in ad_specs)
    ad_mmaps = tuple(None if sp.rebalance else _slot_map_of(sp)
                     for sp in ad_specs)
    # rebalance x message-budget composition: the closing-slot remap is a
    # runtime gather indexed by each row's realized load (see
    # ``_rebalance_remap``).
    ad_remap = tuple(_rebalance_remap(sp) for sp in ad_specs)
    # static per-row loads for ragged bases (rows carry their loads through
    # the re-permutation); None for dense bases (no masking needed).
    ad_lrow = tuple(None if sp.loads is None or sp.rebalance
                    else np.asarray(sp.loads, np.int64) for sp in ad_specs)
    # initial per-worker budgets for rebalance specs
    ad_l0 = tuple(np.asarray(sp.loads, np.int64) if sp.rebalance else None
                  for sp in ad_specs)

    def _assign_and_score(i, est, s, need=None):
        """Greedy row re-assignment (and, for rebalance specs, greedy load
        re-allocation) from ``est`` feedback, then this scheme's completion
        time on the permuted (and masked) slot grid.  Returns
        ``(w_of_row, loads_w, val, tau)`` with ``loads_w`` None for
        fixed-load specs.  ``need`` (reissue policy) prioritizes rows
        holding backlogged tasks in the greedy pick order."""
        sp, plan, Cb = ad_specs[i], ad_plans[i], ad_mats[i]
        # assignment uses feedback from *previous* rounds only.
        w_of_row = scheduling.greedy_row_assignment_batch(
            Cb, est, gamma=gamma, need=need,
            impl=greedy_impl)                   # (chunk, n)
        # row p's slots are executed by worker w_of_row[p]: permute the
        # worker axis, then the static gather plan applies.
        s2 = jnp.take_along_axis(s, w_of_row[..., None], axis=1)
        loads_w = None
        if sp.rebalance:
            r_sp = Cb.shape[1]
            loads_w = scheduling.greedy_load_rebalance_batch(
                est, ad_l0[i], r_max=r_sp, min_load=1)       # (chunk, n)
            # row p inherits its executor's load: mask the trailing slots
            # of the row-major grid to +inf before the static gather.
            l_row = jnp.take_along_axis(loads_w, w_of_row, axis=-1)
            s2 = jnp.where(jnp.arange(s2.shape[-1])[None, None, :]
                           < l_row[..., None], s2, INF)
            if ad_remap[i] is not None:
                # multi-message budget: slot j's result rides its message's
                # closing slot, whose position depends on the row's
                # realized load — gather the per-load remap row.
                mm = jnp.take(jnp.asarray(ad_remap[i]), l_row - 1, axis=0)
                s2 = jnp.take_along_axis(s2, mm, axis=-1)
        tau = task_arrival_times_gather(plan, s2)
        return w_of_row, loads_w, _smallest(tau, ks)[..., -1:], tau

    def _worker_arrivals(i, w_of_row, loads_w, s):
        """Worker-major per-message arrivals feeding the (censored)
        feedback: worker w's message arrivals are its own slots of ``s``
        whatever row it executes (the row permutation and its inverse
        cancel for the raw slots), masked to +inf beyond the worker's load
        this round.  A per-ROW message map travels with the assignment:
        worker w groups its slots by the layout of the row it executes."""
        Cb, mmap = ad_mats[i], ad_mmaps[i]
        r_sp = Cb.shape[1]
        s_w = s[..., :, :r_sp]
        if mmap is None:
            arr_w = s_w
        elif np.ndim(mmap) == 1:                      # row-invariant map
            arr_w = _apply_slot_map(s_w, mmap)
        else:
            # per-row map: permute the static (n, r) map to worker-major
            # (worker w uses the layout of row row_of_worker[w])
            row_of_worker = jnp.argsort(w_of_row, axis=-1)
            mm = jnp.take(jnp.asarray(mmap), row_of_worker, axis=0)
            arr_w = jnp.take_along_axis(s_w, mm, axis=-1)
        if loads_w is not None:                       # rebalance: dynamic
            if ad_remap[i] is not None:
                # each worker groups its own realized load into messages:
                # remap to closing-slot arrivals before masking.
                mm = jnp.take(jnp.asarray(ad_remap[i]), loads_w - 1, axis=0)
                arr_w = jnp.take_along_axis(arr_w, mm, axis=-1)
            act = jnp.arange(r_sp)[None, None, :] < loads_w[..., None]
            arr_w = jnp.where(act, arr_w, INF)
        elif ad_lrow[i] is not None:                  # static ragged rows
            row_of_worker = jnp.argsort(w_of_row, axis=-1)
            l_of_w = jnp.take(jnp.asarray(ad_lrow[i]), row_of_worker)
            act = jnp.arange(r_sp)[None, None, :] < l_of_w[..., None]
            arr_w = jnp.where(act, arr_w, INF)
        return arr_w

    def _eval_static(s):
        """Static-scheme raw stats + (with a deadline) arrival counts."""
        if eval_fn is None:
            return {}, {}
        if DL is None:
            return dict(eval_fn(s)), {}
        out, cnts = eval_fn(s)
        return dict(out), cnts

    def _degrade(nm, v, by, dv, backs, new_backs):
        """Policy application + degradation streams for one scheme.
        Returns (v_eff, aux | None); updates ``new_backs`` under reissue."""
        if DL is None:
            return v, None
        v_eff, realized, missed = _policy_close(v, by, dv)
        if reissue:
            nb = jnp.clip(backs[nm] + kf - jnp.minimum(by, kf), 0.0, nf)
            new_backs[nm] = nb
            stale = nb / kf
        else:
            stale = (kf - realized) / kf
        return v_eff, {"realized": realized, "missed": missed,
                       "stale": stale}

    def rounds_fn(keys: Array, tids: Array):
        chunk = keys.shape[0]
        # one subkey per (trial, round) + one for the process init, derived
        # from the per-trial key so everything stays chunk-invariant.
        allk = jax.vmap(lambda kk: jax.random.split(kk, rounds + 1))(keys)
        pstate = process.init_trials(allk[:, 0], tids, n)
        backs0 = ({sp.name: jnp.zeros((chunk,), jnp.float32)
                   for sp in specs} if reissue else {})
        needs0 = ({sp.name: jnp.zeros((chunk, n), jnp.float32)
                   for sp in ad_specs} if reissue else {})

        def _adaptive_round(i, est, s, needs, backs, new_backs, new_needs,
                            times, aux):
            """One adaptive scheme's round: assign (+ reissue priority),
            score, apply the deadline policy, update the reissue backlog /
            need.  Returns what the censored feedback update needs."""
            sp = ad_specs[i]
            need = needs.get(sp.name) if reissue else None
            w_of_row, loads_w, val, tau = _assign_and_score(i, est, s, need)
            v = val[..., 0]
            if DL is None:
                by = dv = None
            else:
                by = (tau <= DL).sum(-1).astype(jnp.float32)
                dv = jnp.isfinite(tau).sum(-1).astype(jnp.float32)
            v_eff, a = _degrade(sp.name, v, by, dv, backs, new_backs)
            if a is not None:
                aux[sp.name] = a
            if reissue:
                # undelivered tasks become next round's re-gather priority
                # (only while a backlog is actually owed)
                delivered = (tau <= v_eff[..., None]) & jnp.isfinite(tau)
                owed = (new_backs[sp.name] > 0)[..., None]
                new_needs[sp.name] = (~delivered & owed).astype(jnp.float32)
            times[sp.name] = v_eff
            return w_of_row, loads_w, v_eff

        # NB: the round index rides the scan xs (an ``arange``) instead of
        # an integer carry — numerically identical, and immune to a
        # multi-device host-mesh miscompilation (observed under
        # ``shard_map``, see ``repro.sharding.shard_trials``) where XLA
        # aliases constant-initialized scalar carries across co-resident
        # shards, so ``t == 0`` misfires on every device but the first.
        if censored:
            def body(carry, xs):
                kr, _ = xs
                pstate, ests, needs, backs = carry
                pstate, T1, T2 = process.step(pstate, kr, n, r_max)
                s = jnp.cumsum(T1, axis=-1) + T2    # eq. (1), per round
                out, cnts = _eval_static(s)
                times, aux = {}, {}
                new_backs, new_needs = {}, {}
                for sp in static_specs:
                    by, dv = cnts.get(sp.name, (None, None))
                    v_eff, a = _degrade(sp.name, out[sp.name][..., 0],
                                        by, dv, backs, new_backs)
                    times[sp.name] = v_eff
                    if a is not None:
                        aux[sp.name] = a
                new_e = []
                for i, (sp, Cb, est) in enumerate(zip(ad_specs, ad_mats,
                                                      ests)):
                    w_of_row, loads_w, v_eff = _adaptive_round(
                        i, est, s, needs, backs, new_backs, new_needs,
                        times, aux)
                    r_sp = Cb.shape[1]
                    # shared censored update: only messages that beat this
                    # scheme's own round close are observed (the deadline
                    # policies censor at the effective close).
                    arr_w = _worker_arrivals(i, w_of_row, loads_w, s)
                    new_e.append(scheduling.censored_feedback_update(
                        est, T1[..., :r_sp], arr_w, v_eff, beta=beta))
                return (pstate, tuple(new_e), new_needs, new_backs), (times,
                                                                      aux)

            init = (pstate,
                    tuple(jnp.full((chunk, n), INF, jnp.float32)
                          for _ in ad_specs), needs0, backs0)
        else:
            def body(carry, xs):
                kr, t = xs
                pstate, est, needs, backs = carry
                pstate, T1, T2 = process.step(pstate, kr, n, r_max)
                s = jnp.cumsum(T1, axis=-1) + T2    # eq. (1), per round
                out, cnts = _eval_static(s)
                times, aux = {}, {}
                new_backs, new_needs = {}, {}
                for sp in static_specs:
                    by, dv = cnts.get(sp.name, (None, None))
                    v_eff, a = _degrade(sp.name, out[sp.name][..., 0],
                                        by, dv, backs, new_backs)
                    times[sp.name] = v_eff
                    if a is not None:
                        aux[sp.name] = a
                for i in range(len(ad_specs)):
                    _adaptive_round(i, est, s, needs, backs, new_backs,
                                    new_needs, times, aux)
                obs = T1.mean(axis=-1)              # per-worker compute time
                # +inf-safe: a fault-censored worker's +inf observation
                # keeps the previous estimate (EMAing it would pin est at
                # +inf forever); bit-identical when all delays are finite.
                fin = jnp.isfinite(obs)
                upd = jnp.where(t == 0, obs, beta * est + (1.0 - beta) * obs)
                est = jnp.where(fin, upd, est)
                return (pstate, est, new_needs, new_backs), (times, aux)

            init = (pstate, jnp.ones((chunk, n), jnp.float32),
                    needs0, backs0)

        _, ys = jax.lax.scan(body, init,
                             (jnp.swapaxes(allk[:, 1:], 0, 1),
                              jnp.arange(rounds, dtype=jnp.int32)))
        return ys             # ({name: (rounds, chunk)}, {name: aux dicts})

    return rounds_fn


_ROUNDS_CACHE = _LRUCache()


def _get_rounds_exec(specs: Tuple[SchemeSpec, ...], process, n: int,
                     r_max: int, ks: int, rounds: int, beta: float,
                     gamma: float, censored: bool,
                     deadline: Optional[float] = None, policy: str = "wait",
                     devices: tuple = (), greedy_impl: Optional[str] = None):
    from .trace import TraceProcess
    cache_key = None
    if isinstance(process, TraceProcess):
        # uncached: the compiled program closes over the full delay tables
        # (hundreds of MB for big recordings) and traces are one-shot —
        # caching would pin every trace ever swept for the process's life.
        pass
    else:
        try:
            cache_key = (specs, process, n, r_max, ks, rounds, beta, gamma,
                         censored, deadline, policy, devices, greedy_impl)
            hit = _ROUNDS_CACHE.get(cache_key)
            if hit is not None:
                return hit
        except TypeError:           # unhashable custom process: uncached
            cache_key = None

    rounds_fn = _build_rounds_fn(specs, process, n, r_max, ks, rounds,
                                 beta, gamma, censored, deadline, policy,
                                 greedy_impl)
    has_dl = deadline is not None

    def _chunk_aux(aux, vd):
        """One chunk's degradation partials: valid-masked sums over the
        trial axis plus the realized-k histogram (one_hot over 0..k)."""
        ok = vd[None, :]                              # (1, chunk) bool
        okf = vd.astype(jnp.float32)[None, :, None]
        out = {}
        for nm, a in aux.items():
            hist = (jax.nn.one_hot(a["realized"].astype(jnp.int32), ks + 1)
                    * okf).sum(axis=1)
            out[nm] = {
                "realized": jnp.where(ok, a["realized"], 0.0).sum(axis=1),
                "missed": jnp.where(ok, a["missed"], 0.0).sum(axis=1),
                "stale": jnp.where(ok, a["stale"], 0.0).sum(axis=1),
                "khist": hist,
            }
        return out

    def sums_scan(base_key, starts, offs, limit):
        _count_trace()

        def body(carry, start):
            tids_raw = start + offs
            tc = jnp.minimum(tids_raw, limit - 1)
            ys, aux = rounds_fn(_fold_keys(base_key, tc), tc)
            vd = tids_raw < limit
            ok = vd[None, :]
            cum = {k2: jnp.cumsum(v, axis=0) for k2, v in ys.items()}
            s0 = {k2: jnp.where(ok, ys[k2], 0.0).sum(axis=1) for k2 in ys}
            s1 = {k2: jnp.where(ok, jnp.square(ys[k2]), 0.0).sum(axis=1)
                  for k2 in ys}
            c0 = {k2: jnp.where(ok, cum[k2], 0.0).sum(axis=1) for k2 in cum}
            c1 = {k2: jnp.where(ok, jnp.square(cum[k2]), 0.0).sum(axis=1)
                  for k2 in cum}
            ac = _chunk_aux(aux, vd) if has_dl else {}
            return carry, (s0, s1, c0, c1, ac)

        _, parts = jax.lax.scan(body, None, starts)
        return parts          # 4 x {name: (nc, rounds)} + degradation

    def samples_scan(base_key, starts, offs, limit):
        _count_trace()

        def body(carry, start):
            tc = jnp.minimum(start + offs, limit - 1)
            # times only (aux is DCE'd)
            return carry, rounds_fn(_fold_keys(base_key, tc), tc)[0]

        _, ys = jax.lax.scan(body, None, starts)
        return ys             # {name: (nc, R, chunk)}

    if len(devices) > 1:
        # shard_trials returns a fully-jitted callable; no outer jit.
        exec_ = (shard_trials(sums_scan, devices, replicated=(0, 2, 3)),
                 shard_trials(samples_scan, devices, replicated=(0, 2, 3)))
    else:
        exec_ = (jax.jit(sums_scan), jax.jit(samples_scan))
    exec_ = (_timed_first(exec_[0], _ROUNDS_CACHE),
             _timed_first(exec_[1], _ROUNDS_CACHE))
    if cache_key is not None:
        _ROUNDS_CACHE.put(cache_key, exec_)
    return exec_


def _capture_rounds_fn(process, n: int, r_max: int, rounds: int):
    """The recording pass: scan the process alone (same per-trial key
    derivation as ``_build_rounds_fn``), streaming out the realized delay
    tensors — (chunk, 2) keys + (chunk,) trial ids ->
    ``(T1, T2)`` of shape (rounds, chunk, n, r_max) each."""
    def capture_fn(keys: Array, tids: Array):
        allk = jax.vmap(lambda kk: jax.random.split(kk, rounds + 1))(keys)
        pstate = process.init_trials(allk[:, 0], tids, n)

        def body(pstate, kr):
            pstate, T1, T2 = process.step(pstate, kr, n, r_max)
            return pstate, (T1, T2)

        _, recs = jax.lax.scan(body, pstate,
                               jnp.swapaxes(allk[:, 1:], 0, 1))
        return recs

    return capture_fn


def _record_trace(process, n, r_max, *, rounds, trials, seed, chunk,
                  meta: dict):
    """Capture the delay tables a rounds run over ``process`` realizes,
    as a ``repro.core.trace.DelayTrace``.

    This is the first pass of ``record_trace=True``: the per-trial key
    derivation is identical to the evaluation scan, so the captured
    tables are exactly the delays any sweep over the same
    (process, seed, trials) draws.  The evaluation pass then *replays*
    these materialized tables (``TraceProcess``), which makes the
    reported statistics bit-exactly reproducible from the returned trace
    — XLA is free to fuse a parametric process's arithmetic into eq. (1)
    with fused-multiply-adds, so values consumed in a fused sampling run
    can differ from any materialized table by ulps; evaluating through
    the replay path removes that divergence by construction.
    """
    from .trace import DelayTrace
    capture = jax.jit(_capture_rounds_fn(process, n, r_max, rounds))
    keys = trial_keys(seed, trials)
    tids = jnp.arange(trials, dtype=jnp.int32)
    parts1, parts2 = [], []
    for lo in range(0, trials, chunk):
        T1c, T2c = capture(keys[lo:lo + chunk], tids[lo:lo + chunk])
        parts1.append(np.asarray(T1c))
        parts2.append(np.asarray(T2c))
    T1 = np.concatenate(parts1, axis=1) if len(parts1) > 1 else parts1[0]
    T2 = np.concatenate(parts2, axis=1) if len(parts2) > 1 else parts2[0]
    return DelayTrace(T1, T2, meta=meta)


def _check_rounds_args(specs, n, ks, rounds):
    specs = _check_specs(specs, n)
    for sp in specs:
        if sp.kind == "tau":
            raise ValueError(f"{sp.name}: tau specs are single-round only")
    if not 1 <= ks <= n:
        raise ValueError(f"need 1 <= k <= n={n}, got k={ks}")
    for sp in specs:
        if (sp.kind in ("to", "adaptive") and not sp.rebalance
                and _covered_tasks(sp) < ks):
            raise ValueError(
                f"{sp.name}: ragged schedule covers only "
                f"{_covered_tasks(sp)} distinct tasks < k={ks}; the "
                f"completion time would be infinite")
    if rounds < 1:
        raise ValueError(f"need rounds >= 1, got {rounds}")
    return specs


_POLICIES = DEADLINE_POLICIES        # canonical tuple lives in repro.core.spec


def _run_rounds(specs, process, n, *, rounds: int, k: int, trials: int,
                seed: int, chunk: Optional[int], beta: float, gamma: float,
                censored: bool, want_samples: bool, record: bool = False,
                deadline: Optional[float] = None,
                deadline_policy: str = "wait", devices=None,
                greedy_impl: Optional[str] = None):
    from .cluster import as_process
    from .scheduling import _resolve_greedy_impl
    process = as_process(process)
    process.check_rounds(rounds)
    specs = _check_rounds_args(specs, n, k, rounds)
    deadline = validate_deadline(deadline, deadline_policy)
    _resolve_greedy_impl(greedy_impl)       # validate early (clear error)
    r_max = max(sp.load for sp in specs)
    chunk = _normalize_chunk(trials, chunk)

    if record:
        # two-pass recording: capture the realized delay tables first,
        # then evaluate by REPLAYING them — the reported statistics are
        # then bit-exactly reproducible from the returned trace (see
        # ``_record_trace``).
        from .trace import TraceProcess
        trace = _record_trace(
            process, n, r_max, rounds=rounds, trials=trials, seed=seed,
            chunk=chunk,
            meta={"source": "sweep_rounds", "seed": int(seed), "k": int(k),
                  "process": type(process).__name__,
                  "schemes": [sp.name for sp in specs]})
        out = _run_rounds(specs, TraceProcess(trace), n, rounds=rounds,
                          k=k, trials=trials, seed=seed, chunk=chunk,
                          beta=beta, gamma=gamma, censored=censored,
                          want_samples=want_samples, deadline=deadline,
                          deadline_policy=deadline_policy, devices=devices,
                          greedy_impl=greedy_impl)
        return out[:-1] + (trace,)

    devs, nc_pad, padded = _shard_layout(trials, chunk, devices)
    jsums, jsamples = _get_rounds_exec(
        specs, process, n, r_max, k, rounds, beta, gamma, censored,
        deadline, deadline_policy, devs, greedy_impl)

    # the scans derive per-trial keys AND trial ids device-side from the
    # (base key, per-chunk start) coordinates: padded lanes replay a valid
    # (clamped) trial id — deriving the last real trial's key, exactly the
    # ``_padded_keys`` reference twin — and are masked out of every
    # statistic below, so trace replay stays invariant to chunking AND
    # sharding without a host key table.
    base_key = jax.random.PRNGKey(seed)
    starts, offs, limit = _scan_coords(trials, chunk, nc_pad)

    if want_samples:
        ys = jsamples(base_key, starts, offs, limit)
        return ({nm: jnp.moveaxis(v, 1, -1).reshape(padded, rounds)[:trials]
                 for nm, v in ys.items()}, None)  # (nc,R,chunk)->(trials,R)

    s0, s1, c0, c1, ac = jsums(base_key, starts, offs, limit)

    def moments(parts0, parts1):
        # per-chunk float32 partials -> float64 in global chunk order: the
        # same reduction whatever the device count (bit-exact sharding).
        mu = np.asarray(parts0, np.float64).sum(axis=0) / trials
        sq = np.asarray(parts1, np.float64).sum(axis=0)
        var = np.maximum(sq / trials - mu * mu, 0.0)
        return mu, np.sqrt(var / trials)

    per_round, stderr, wallclock, wc_stderr = {}, {}, {}, {}
    for nm in s0:
        per_round[nm], stderr[nm] = moments(s0[nm], s1[nm])
        wallclock[nm], wc_stderr[nm] = moments(c0[nm], c1[nm])
    degr = None
    if deadline is not None:
        degr = {nm: {"realized_k": np.asarray(d["realized"],
                                              np.float64).sum(0) / trials,
                     "missed": np.asarray(d["missed"],
                                          np.float64).sum(0) / trials,
                     "stale": np.asarray(d["stale"],
                                         np.float64).sum(0) / trials,
                     "khist": np.asarray(d["khist"],
                                         np.float64).sum(0) / trials}
                for nm, d in ac.items()}
    return per_round, stderr, wallclock, wc_stderr, degr, None


@dataclasses.dataclass(frozen=True)
class RoundsResult:
    """Wall-clock trajectories from a multi-round sweep.

    ``per_round[name]``  — (rounds,) mean completion time of each round;
    ``wallclock[name]``  — (rounds,) mean *cumulative* wall-clock after each
                           round (the x-axis of a loss-vs-time curve);
    ``stderr`` / ``wallclock_stderr`` — matching MC standard errors;
    ``trace``            — the realized delay tables of the whole sweep
                           (a ``repro.core.trace.DelayTrace``) when run
                           with ``record_trace=True``, else None;
    ``degradation``      — per-scheme graceful-degradation streams when run
                           with a ``deadline``: ``realized_k`` (rounds,)
                           mean distinct results credited per round,
                           ``missed`` (rounds,) fraction of trials whose
                           round missed the deadline, ``stale`` (rounds,)
                           mean missing-gradient fraction (reissue: owed
                           backlog / k), ``khist`` (rounds, k+1) the
                           realized-k distribution.  None without a
                           deadline.
    """
    per_round: Dict[str, np.ndarray]
    stderr: Dict[str, np.ndarray]
    wallclock: Dict[str, np.ndarray]
    wallclock_stderr: Dict[str, np.ndarray]
    trials: int
    rounds: int
    n: int
    k: int
    trace: Optional[object] = None
    deadline: Optional[float] = None
    deadline_policy: str = "wait"
    degradation: Optional[Dict[str, Dict[str, np.ndarray]]] = None

    def _get(self, d: Dict[str, np.ndarray], name: str) -> np.ndarray:
        if name not in d:
            raise ValueError(f"unknown scheme {name!r}; have "
                             f"{sorted(d)}")
        return d[name]

    def mean_round(self, name: str) -> float:
        """Mean completion time per round, averaged over the run."""
        return float(self._get(self.per_round, name).mean())

    def total(self, name: str) -> float:
        """Mean wall-clock of the whole R-round run."""
        return float(self._get(self.wallclock, name)[-1])

    def _degr(self, name: str, key: str) -> np.ndarray:
        if self.degradation is None:
            raise ValueError("no degradation metrics: run sweep_rounds "
                             "with a deadline")
        return self._get(self.degradation, name)[key]

    def realized_k(self, name: str) -> np.ndarray:
        """(rounds,) mean distinct results credited per round (<= k)."""
        return self._degr(name, "realized_k")

    def missed_fraction(self, name: str) -> np.ndarray:
        """(rounds,) fraction of trials whose round missed the deadline."""
        return self._degr(name, "missed")

    def stale_fraction(self, name: str) -> np.ndarray:
        """(rounds,) mean missing-gradient fraction per round."""
        return self._degr(name, "stale")

    def khist(self, name: str) -> np.ndarray:
        """(rounds, k+1) realized-k distribution (rows sum to 1)."""
        return self._degr(name, "khist")


def sweep_rounds(specs: Sequence[SchemeSpec], process, n: int, *,
                 rounds: int, k: int, trials: int = 20000, seed: int = 0,
                 chunk: Optional[int] = None, feedback_beta: float = 0.7,
                 coverage_gamma: float = 0.5,
                 censored_feedback: bool = False,
                 record_trace: bool = False,
                 deadline: Optional[float] = None,
                 deadline_policy: str = "wait", devices=None,
                 greedy_impl: Optional[str] = None) -> RoundsResult:
    """Evaluate every scheme over ``rounds`` consecutive rounds of ONE
    shared ``DelayProcess`` realization per trial.

    Parameters
    ----------
    specs:   schemes to evaluate; ``adaptive_spec`` entries re-assign their
             base matrix's rows each round from delay feedback (and, with
             ``rebalance=True``, re-allocate whole slots between workers
             under the fixed total budget — Egger-style load adaptation).
    process: a ``DelayProcess`` (or a stateless ``DelayModel``, coerced to
             the zero-correlation ``IIDProcess``).
    rounds:  number of consecutive SGD rounds scanned per trial.
    k:       computation target (single k; the rounds axis replaces the
             all-k axis of single-round sweeps).
    trials/seed/chunk: as in ``sweep`` — per-trial subkeys, chunk-invariant
             streaming with O(chunk * n * r_max) memory.
    feedback_beta:  EMA weight on past feedback in adaptive schemes.
    coverage_gamma: per-slot coverage discount of the greedy assignment.
    censored_feedback: restrict adaptive feedback to messages that arrived
             before the scheme's own round completion (what a real master
             observes) instead of the idealized full-delay feedback.
    record_trace: also capture the realized per-(round, trial, worker,
             slot) delay tables — the result's ``trace`` field becomes a
             ``repro.core.trace.DelayTrace``.  Recording is two-pass: the
             process is scanned once to materialize the tables, and the
             reported statistics are computed by *replaying* them, so a
             later ``TraceProcess`` replay reproduces this result
             bit-exactly (a fused sampling run may differ by float32 ulps
             — XLA contracts a process's arithmetic into eq. (1) with
             FMAs).  Memory: O(rounds * trials * n * r_max) floats x2.
    deadline: cap every round at this wall-clock budget (fault tolerance —
             with fault-injecting processes a round may otherwise never
             reach k results).  Enables the ``degradation`` metrics.
    deadline_policy: what happens at the deadline — ``"wait"`` (report the
             true completion, just flag the miss), ``"close_partial"``
             (close the round with whatever arrived), or ``"reissue"``
             (close partial + adaptive schemes re-gather the undelivered
             tasks first next round).
    devices: shard the trial axis across devices (as in ``sweep``) —
             bit-exact vs. single-device for the same (trials, seed,
             chunk); pass ``chunk <= trials // len(devices)`` to engage
             every device.
    greedy_impl: how adaptive specs run the greedy pick loop —
             ``None``/``"auto"`` (Pallas kernel on compiled backends, jnp
             scan on CPU), ``"kernel"``, or ``"scan"``.
    """
    per_round, stderr, wallclock, wc_stderr, degr, trace = _run_rounds(
        specs, process, n, rounds=rounds, k=k, trials=trials, seed=seed,
        chunk=chunk, beta=feedback_beta, gamma=coverage_gamma,
        censored=censored_feedback, want_samples=False,
        record=record_trace, deadline=deadline,
        deadline_policy=deadline_policy, devices=devices,
        greedy_impl=greedy_impl)
    return RoundsResult(per_round=per_round, stderr=stderr,
                        wallclock=wallclock, wallclock_stderr=wc_stderr,
                        trials=trials, rounds=rounds, n=n, k=k, trace=trace,
                        deadline=deadline, deadline_policy=deadline_policy,
                        degradation=degr)


def trajectory_samples(spec: SchemeSpec, process, n: int, *, rounds: int,
                       k: int, trials: int = 10000, seed: int = 0,
                       chunk: Optional[int] = None,
                       feedback_beta: float = 0.7,
                       coverage_gamma: float = 0.5,
                       censored_feedback: bool = False,
                       record_trace: bool = False,
                       deadline: Optional[float] = None,
                       deadline_policy: str = "wait", devices=None,
                       greedy_impl: Optional[str] = None):
    """Per-trial completion-time trajectories for one scheme: shape
    ``(trials, rounds)``; ``jnp.cumsum(..., axis=1)`` gives per-trial
    wall-clock curves.  With ``record_trace=True`` returns
    ``(trajectories, DelayTrace)`` — the realized delay tables alongside
    the samples.  With a ``deadline`` the trajectories are the *effective*
    round closes under ``deadline_policy`` (capped at the deadline for
    ``close_partial``/``reissue``)."""
    samples, trace = _run_rounds([spec], process, n, rounds=rounds, k=k,
                                 trials=trials, seed=seed, chunk=chunk,
                                 beta=feedback_beta, gamma=coverage_gamma,
                                 censored=censored_feedback,
                                 want_samples=True, record=record_trace,
                                 deadline=deadline,
                                 deadline_policy=deadline_policy,
                                 devices=devices, greedy_impl=greedy_impl)
    if record_trace:
        return samples[spec.name], trace
    return samples[spec.name]

"""Fused batched Monte-Carlo sweep engine — the repo's hot path.

Every paper figure (Figs. 4-7) is an average-completion-time sweep over a
(scheme, r, k, scenario) grid.  The seed code re-sampled delays and re-jitted
a fresh simulation for every scheme at every grid point.  This module
replaces all of that with ONE jitted evaluator that:

1. draws one PRNG subkey **per trial** and samples the delay tensors once
   per scenario — every scheme sees the *same* draws (common random
   numbers), so scheme comparisons are variance-reduced paired samples and
   per-trial completion samples are bit-identical under any chunking of the
   trial axis (chunk-accumulated means agree to float32 round-off);
2. evaluates all stacked TO matrices against the shared draws in one fused
   computation (a single stacked gather + one batched sort);
3. streams trials through ``lax.scan`` in fixed-size chunks, so peak memory
   is O(chunk * n * r) and 10^6+ trials run on a laptop;
4. returns completion times for EVERY k in 1..n from one sort of the task
   arrivals (a whole Fig.-7 k-sweep is one call), while single-k queries
   take the cheaper ``lax.top_k`` partial-selection path;
5. computes task arrival times with a statically precomputed gather +
   min-reduction (each task's copy positions are known from the TO matrix
   at trace time) instead of a dynamic scatter-min — the TPU-friendly form.

Scheme kinds
------------
* ``"to"``   — a TO matrix ``C``: order statistics of the per-task arrival
               times (paper eqs. 1-2, 6).
* ``"lb"``   — the oracle lower bound at load ``r``: order statistics over
               all ``n*r`` slot arrivals (eq. 46).
* ``"pc"``   — polynomially-coded workers at load ``r``: the
               ``2*ceil(n/r)-1``-th order statistic of the per-worker
               single-message times (eqs. 51-52).  Like ``pcmm``, always a
               single column at the scheme's own decode threshold — the
               sweep's ``k`` never applies to coded schemes.
* ``"pcmm"`` — PC multi-message at load ``r``: the ``2n-1``-th order
               statistic over all slot arrivals (eqs. 56-57).
* ``"tau"``  — raw (unsorted) per-task arrival times, for estimators that
               need the joint distribution (e.g. Theorem 1's H_S).

Specs with smaller loads than the widest scheme in a sweep simply use the
leading slots of the shared delay tensors (delay statistics are
order-independent, paper Remark 6) — that is what makes cross-``r``
comparisons paired as well.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SchemeSpec", "SweepResult", "to_spec", "lb_spec", "pc_spec", "pcmm_spec",
    "tau_spec", "task_gather_plan", "task_arrival_times_gather", "sweep",
    "completion_samples", "task_arrival_samples", "clear_cache",
]

Array = jax.Array
INF = jnp.inf


# --------------------------- scheme specification ----------------------------

@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One scheme to evaluate in a sweep. Hashable (C stored as nested
    tuples) so compiled evaluators can be cached across calls."""
    name: str
    kind: str                       # "to" | "lb" | "pc" | "pcmm" | "tau"
    C: Optional[tuple] = None       # TO matrix for "to"/"tau"
    r: Optional[int] = None         # computation load for "lb"/"pc"/"pcmm"

    @property
    def load(self) -> int:
        """Number of per-worker slots this scheme touches."""
        if self.kind in ("to", "tau"):
            return len(self.C[0])
        return int(self.r)

    def matrix(self) -> np.ndarray:
        return np.asarray(self.C, dtype=np.int64)


def _freeze_matrix(C) -> tuple:
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    return tuple(tuple(int(v) for v in row) for row in C)


def to_spec(name: str, C) -> SchemeSpec:
    """A TO-matrix scheme (CS / SS / RA / custom)."""
    return SchemeSpec(name=name, kind="to", C=_freeze_matrix(C))


def tau_spec(name: str, C) -> SchemeSpec:
    """Raw task-arrival samples for a TO matrix (no order statistics)."""
    return SchemeSpec(name=name, kind="tau", C=_freeze_matrix(C))


def lb_spec(r: int, name: str = "lb") -> SchemeSpec:
    """Oracle lower bound (eq. 46) at computation load ``r``."""
    return SchemeSpec(name=name, kind="lb", r=int(r))


def pc_spec(r: int, name: str = "pc") -> SchemeSpec:
    """Polynomially-coded single-message scheme at load ``r``."""
    return SchemeSpec(name=name, kind="pc", r=int(r))


def pcmm_spec(r: int, name: str = "pcmm") -> SchemeSpec:
    """Polynomially-coded multi-message scheme at load ``r``."""
    return SchemeSpec(name=name, kind="pcmm", r=int(r))


def _pc_threshold(n: int, r: int) -> int:
    return 2 * math.ceil(n / r) - 1


def _pcmm_threshold(n: int) -> int:
    return 2 * n - 1


# ------------------- static gather layout for task arrivals ------------------

def task_gather_plan(C, n: int, r_max: Optional[int] = None) -> np.ndarray:
    """Precompute, at trace time, where every task's copies live.

    Returns an ``(n, m)`` int32 array of *flat* slot indices into the
    row-major ``(n_w, r_max)`` slot grid, where ``m`` is the maximum copy
    multiplicity.  Rows are padded with the sentinel ``n_w * r_max``, which
    callers map to +inf, so ``min`` over the gathered values reproduces the
    scatter-min of eq. (2) with a static gather — the TPU-friendly form.
    """
    C = np.asarray(C)
    n_w, r = C.shape
    r_max = r if r_max is None else int(r_max)
    if r > r_max:
        raise ValueError(f"TO matrix load r={r} exceeds slot grid r_max={r_max}")
    sentinel = n_w * r_max
    positions: list[list[int]] = [[] for _ in range(n)]
    for i in range(n_w):
        for j in range(r):
            positions[int(C[i, j])].append(i * r_max + j)
    m = max((len(p) for p in positions), default=0) or 1
    plan = np.full((n, m), sentinel, dtype=np.int32)
    for p, lst in enumerate(positions):
        plan[p, :len(lst)] = lst
    return plan


def task_arrival_times_gather(plan: np.ndarray, s: Array) -> Array:
    """eq. (2) via the static gather plan.

    ``s`` has shape (..., n_w, r_max); ``plan`` may be ``(n, m)`` for one
    scheme or ``(S, n, m)`` for a stack, giving (..., n) or (..., S, n).
    Tasks never assigned come out +inf, matching the scatter-min version.
    """
    sf = s.reshape(s.shape[:-2] + (-1,))
    pad = jnp.full(sf.shape[:-1] + (1,), INF, s.dtype)
    sp = jnp.concatenate([sf, pad], axis=-1)
    return jnp.min(sp[..., jnp.asarray(plan)], axis=-1)


def _stack_plans(specs: Sequence[SchemeSpec], n: int, r_max: int) -> np.ndarray:
    plans = [task_gather_plan(sp.matrix(), n, r_max) for sp in specs]
    m = max(p.shape[1] for p in plans)
    sentinel = n * r_max
    out = np.full((len(plans), n, m), sentinel, dtype=np.int32)
    for i, p in enumerate(plans):
        out[i, :, :p.shape[1]] = p
    return out


# ----------------------------- fused evaluator -------------------------------

def _smallest(x: Array, k: int) -> Array:
    """The k smallest entries of x along the last axis, ascending — a
    partial selection via ``lax.top_k`` (no full O(L log L) sort)."""
    return -jax.lax.top_k(-x, k)[0]


def _stat_width(spec: SchemeSpec, n: int, ks: Optional[int]) -> int:
    if spec.kind in ("pc", "pcmm"):        # fixed decode thresholds
        return 1
    if spec.kind == "tau":
        return n
    return n if ks is None else 1


def _build_stats_fn(specs: Tuple[SchemeSpec, ...], model, n: int, r_max: int,
                    ks: Optional[int]):
    """Per-chunk evaluator: (chunk, 2) per-trial keys -> {name: (chunk, L)}.

    All static structure (gather plans, thresholds, slot windows) is baked
    in at trace time; the returned function is pure and jit/scan-friendly.
    """
    to_specs = tuple(sp for sp in specs if sp.kind == "to")
    plan_stack = _stack_plans(to_specs, n, r_max) if to_specs else None

    # lb/pcmm both rank the same flattened slot-arrival window; group them
    # by load so each distinct window is partially selected exactly once.
    flat_width: Dict[int, int] = {}
    for sp in specs:
        if sp.kind == "lb":
            need = n if ks is None else ks
        elif sp.kind == "pcmm":
            need = _pcmm_threshold(n)
        else:
            continue
        flat_width[sp.load] = max(flat_width.get(sp.load, 0), need)

    def stats_fn(keys: Array) -> Dict[str, Array]:
        def one(kk):
            T1, T2 = model.sample(kk, 1, n, r_max)
            return T1[0], T2[0]

        T1, T2 = jax.vmap(one)(keys)                 # (chunk, n, r_max)
        s = jnp.cumsum(T1, axis=-1) + T2             # slot arrivals, eq. (1)
        out: Dict[str, Array] = {}

        if to_specs:
            tau = task_arrival_times_gather(plan_stack, s)   # (chunk, S, n)
            if ks is None:
                stat = jnp.sort(tau, axis=-1)                # all k at once
            else:
                stat = _smallest(tau, ks)[..., -1:]          # k-th only
            for i, sp in enumerate(to_specs):
                out[sp.name] = stat[:, i]

        flat_stats = {
            r: _smallest(s[..., :, :r].reshape(s.shape[0], -1), w)
            for r, w in flat_width.items()}          # (chunk, w) ascending

        for sp in specs:
            if sp.kind == "tau":
                plan = task_gather_plan(sp.matrix(), n, r_max)
                out[sp.name] = task_arrival_times_gather(plan, s)
            elif sp.kind == "lb":
                fs = flat_stats[sp.load]
                out[sp.name] = fs[..., :n] if ks is None else fs[..., ks - 1:ks]
            elif sp.kind == "pc":
                r = sp.load
                tw = s[..., r - 1]         # = sum_j T1[..., :r] + T2[..., r-1]
                th = _pc_threshold(n, r)   # PC's own decode threshold — the
                out[sp.name] = _smallest(tw, th)[..., -1:]   # sweep k never
                # applies to coded schemes (same rule as pcmm below)
            elif sp.kind == "pcmm":
                th = _pcmm_threshold(n)
                out[sp.name] = flat_stats[sp.load][..., th - 1:th]
        return out

    return stats_fn


_EXEC_CACHE: dict = {}


def clear_cache() -> None:
    """Drop compiled evaluators (mainly for benchmarking cold starts)."""
    _EXEC_CACHE.clear()


def _get_exec(specs: Tuple[SchemeSpec, ...], model, n: int, r_max: int,
              ks: Optional[int]):
    """Compiled (stats, sums-scan, samples-scan) triple, cached per
    (specs, model, n, r_max, ks) so repeated sweep calls skip retracing."""
    cache_key = None
    try:
        cache_key = (specs, model, n, r_max, ks)
        hit = _EXEC_CACHE.get(cache_key)
        if hit is not None:
            return hit
    except TypeError:              # unhashable custom model: build uncached
        cache_key = None

    stats_fn = _build_stats_fn(specs, model, n, r_max, ks)
    widths = {sp.name: _stat_width(sp, n, ks) for sp in specs}

    def sums_scan(keys3):          # (nc, chunk, 2) -> (sum, sumsq) per name
        zeros = {name: jnp.zeros((w,), jnp.float32)
                 for name, w in widths.items()}
        init = (zeros, {k2: v for k2, v in zeros.items()})

        def body(carry, kc):
            st = stats_fn(kc)
            s0, s1 = carry
            s0 = {k2: s0[k2] + st[k2].sum(axis=0) for k2 in s0}
            s1 = {k2: s1[k2] + jnp.square(st[k2]).sum(axis=0) for k2 in s1}
            return (s0, s1), None

        carry, _ = jax.lax.scan(body, init, keys3)
        return carry

    def samples_scan(keys3):       # (nc, chunk, 2) -> {name: (nc, chunk, L)}
        def body(carry, kc):
            return carry, stats_fn(kc)

        _, ys = jax.lax.scan(body, None, keys3)
        return ys

    exec_ = (jax.jit(stats_fn), jax.jit(sums_scan), jax.jit(samples_scan))
    if cache_key is not None:
        _EXEC_CACHE[cache_key] = exec_
    return exec_


def _check_specs(specs: Sequence[SchemeSpec], n: int) -> Tuple[SchemeSpec, ...]:
    specs = tuple(specs)
    if not specs:
        raise ValueError("need at least one SchemeSpec")
    names = [sp.name for sp in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate scheme names: {names}")
    for sp in specs:
        if sp.kind in ("to", "tau") and len(sp.C) != n:
            raise ValueError(f"{sp.name}: TO matrix has {len(sp.C)} rows, "
                             f"expected n={n}")
        if sp.kind in ("lb", "pc", "pcmm") and not 1 <= sp.load:
            raise ValueError(f"{sp.name}: bad load r={sp.r}")
        if sp.kind == "pcmm" and n * sp.load < _pcmm_threshold(n):
            raise ValueError(
                f"{sp.name}: PCMM infeasible: n*r={n * sp.load} < "
                f"2n-1={_pcmm_threshold(n)}")
    return specs


def _run(specs: Sequence[SchemeSpec], model, n: int, *, trials: int,
         seed: int, chunk: Optional[int], ks: Optional[int],
         want_samples: bool):
    specs = _check_specs(specs, n)
    if ks is not None and not 1 <= ks <= n:
        raise ValueError(f"need 1 <= k <= n={n}, got k={ks}")
    r_max = max(sp.load for sp in specs)
    chunk = trials if chunk is None else max(1, min(int(chunk), trials))
    jstats, jsums, jsamples = _get_exec(specs, model, n, r_max, ks)

    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    nc = trials // chunk
    main = nc * chunk
    main_keys = keys[:main].reshape(nc, chunk, 2)
    tail_keys = keys[main:]

    if want_samples:
        ys = jsamples(main_keys)
        parts = {name: [v.reshape(main, v.shape[-1])] for name, v in ys.items()}
        if main < trials:
            for name, v in jstats(tail_keys).items():
                parts[name].append(v)
        return {name: jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0]
                for name, vs in parts.items()}

    s0, s1 = jsums(main_keys)
    if main < trials:
        st = jstats(tail_keys)
        s0 = {k2: s0[k2] + st[k2].sum(axis=0) for k2 in s0}
        s1 = {k2: s1[k2] + jnp.square(st[k2]).sum(axis=0) for k2 in s1}
    means, stderr = {}, {}
    for name in s0:
        mu = np.asarray(s0[name]) / trials
        var = np.maximum(np.asarray(s1[name]) / trials - mu * mu, 0.0)
        means[name] = mu
        stderr[name] = np.sqrt(var / trials)
    return means, stderr


# ------------------------------- public API ----------------------------------

@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Mean completion times (and MC standard errors) per scheme.

    ``means[name]`` has one column per k in 1..n when the sweep ran in
    all-k mode (``ks=None``), a single column for single-k sweeps and for
    ``pcmm`` (whose threshold ``2n-1`` exceeds ``n``).
    """
    means: Dict[str, np.ndarray]
    stderr: Dict[str, np.ndarray]
    trials: int
    n: int
    ks: Optional[int]
    fixed: frozenset = frozenset()      # pc/pcmm: scheme-defined thresholds

    def at_k(self, name: str, k: Optional[int] = None) -> float:
        """Mean completion time of ``name`` at target ``k``.  Coded schemes
        (``pc``/``pcmm``) always report their own decode threshold, so ``k``
        is ignored for them."""
        v = self.means[name]
        if name in self.fixed:
            return float(v[0])
        if k is None:
            raise ValueError(f"{name} needs an explicit k")
        if v.shape[-1] == self.n:
            if not 1 <= k <= self.n:
                raise ValueError(f"need 1 <= k <= {self.n}, got {k}")
            return float(v[k - 1])
        if self.ks is not None and k != self.ks:
            raise ValueError(f"sweep ran with k={self.ks}; asked for k={k}")
        return float(v[0])


def sweep(specs: Sequence[SchemeSpec], model, n: int, *, trials: int = 20000,
          seed: int = 0, chunk: Optional[int] = None,
          ks: Optional[int] = None) -> SweepResult:
    """Evaluate every scheme against ONE shared set of delay draws.

    Parameters
    ----------
    specs:  schemes to evaluate (see ``to_spec``/``lb_spec``/...).
    model:  a ``DelayModel``; sampled once per trial with a per-trial subkey.
    n:      number of tasks (= workers in the paper's setting).
    trials: Monte-Carlo rounds.
    chunk:  trials are streamed through ``lax.scan`` in chunks of this size
            (default: one chunk).  The per-trial draws are chunk-invariant,
            so means agree to float32 accumulation round-off (and
            ``completion_samples`` is bit-identical) for any chunk size;
            memory is O(chunk * n * r_max).
    ks:     ``None`` → all-k mode: one sort yields every k in 1..n.
            An int → only that order statistic, via ``lax.top_k``.
    """
    means, stderr = _run(specs, model, n, trials=trials, seed=seed,
                         chunk=chunk, ks=ks, want_samples=False)
    fixed = frozenset(sp.name for sp in specs if sp.kind in ("pc", "pcmm"))
    return SweepResult(means=means, stderr=stderr, trials=trials, n=n, ks=ks,
                       fixed=fixed)


def completion_samples(spec: SchemeSpec, model, n: int, *, trials: int = 10000,
                       seed: int = 0, chunk: Optional[int] = None,
                       k: Optional[int] = None) -> Array:
    """Per-trial completion-time samples for one scheme.

    Returns shape ``(trials,)`` when ``k`` is given (or for ``pcmm``), else
    ``(trials, n)`` with column ``k-1`` holding the k-th order statistic.
    """
    out = _run([spec], model, n, trials=trials, seed=seed, chunk=chunk,
               ks=k, want_samples=True)[spec.name]
    return out[:, 0] if out.shape[-1] == 1 else out


def task_arrival_samples(C, model, *, trials: int = 10000, seed: int = 0,
                         chunk: Optional[int] = None) -> Array:
    """Raw per-task arrival-time samples ``tau`` of shape (trials, n) for a
    TO matrix — shared-draw backing for joint-survival estimators."""
    n = np.asarray(C).shape[0]
    spec = tau_spec("tau", C)
    return _run([spec], model, n, trials=trials, seed=seed, chunk=chunk,
                ks=None, want_samples=True)[spec.name]

"""Task-ordering (TO) matrices — the paper's central object.

A TO matrix ``C`` is an ``(n, r)`` integer matrix. Row ``i`` lists the task
indices worker ``i`` executes, in order: worker ``i`` first computes
``h(X[C[i, 0]])``, then ``h(X[C[i, 1]])``, ... (paper Sec. II). Tasks are
0-indexed here (the paper is 1-indexed).

Implemented schedules:
  * Cyclic scheduling   (CS, paper eq. 21):  C(i,j) = g(i + j)
  * Staircase scheduling (SS, paper eq. 29): C(i,j) = g(i + (-1)^i * j)
  * Random assignment   (RA, [18]):          each row an independent random
    permutation of [n] (requires r == n)
  * round-robin block / custom matrices via validation helpers.

Adaptive row assignment
-----------------------
The static schedules fix which worker executes which row forever.  Under
heterogeneous, *persistent* stragglers (see ``repro.core.cluster``) that
leaves completion time hostage to the luck of which rows the slow machines
drew: the tasks whose early copies all sit at stragglers arrive last.
``greedy_row_assignment`` re-permutes the rows of a base TO matrix each
round from observed per-worker delay feedback — fastest workers pick first,
and each picks the row whose leading slots cover the currently
least-covered tasks (coverage discounted by slot position and weighted by
the picker's speed).  ``AdaptiveScheduler`` wraps this with an EMA of the
feedback for use in training loops; the batched JAX variant
(``greedy_row_assignment_batch``) runs per-trial inside the fused rounds
engine (``repro.core.montecarlo.sweep_rounds``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "cyclic_to_matrix",
    "staircase_to_matrix",
    "random_assignment_to_matrix",
    "block_to_matrix",
    "validate_to_matrix",
    "to_matrix",
    "SCHEDULES",
    "Schedule",
    "greedy_row_assignment",
    "greedy_row_assignment_batch",
    "censored_feedback_update",
    "AdaptiveScheduler",
]


def _g(m: np.ndarray, n: int) -> np.ndarray:
    """Paper's wrap-around map g (eq. 22), 0-indexed: fold into [0, n)."""
    return np.mod(m, n)


def cyclic_to_matrix(n: int, r: int) -> np.ndarray:
    """CS schedule (eq. 21): every worker walks the ring in the same
    direction, offset by its index, so each task has the same execution
    *position* at every worker that holds it."""
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    return _g(i + j, n).astype(np.int64)


def staircase_to_matrix(n: int, r: int) -> np.ndarray:
    """SS schedule (eq. 29): even-indexed workers walk the ring ascending,
    odd-indexed workers descending (0-indexed parity matches the paper's
    1-indexed convention: paper worker 1 ≙ row 0 ascends)."""
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    sign = np.where(i % 2 == 0, 1, -1)
    return _g(i + sign * j, n).astype(np.int64)


def random_assignment_to_matrix(n: int, r: int | None = None, *,
                                rng: np.random.Generator | None = None,
                                seed: int | None = 0) -> np.ndarray:
    """RA scheme [18]: r = n (full dataset at each worker); each row is an
    independent uniformly random permutation of [n]."""
    if r is not None and r != n:
        raise ValueError(f"RA requires r == n (got r={r}, n={n})")
    if rng is None:
        rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(n) for _ in range(n)]).astype(np.int64)


def block_to_matrix(n: int, r: int) -> np.ndarray:
    """Naive blocked redundancy baseline (not in the paper; useful ablation):
    worker i computes tasks {i, i+1, ..., i+r-1} like CS but all workers
    start from the *lowest* index of their block — i.e. identical to CS.
    Differs for the ablation where workers share a start: C(i,j) = g(⌊i/r⌋*r + j).
    """
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    return _g((i // max(r, 1)) * r + j, n).astype(np.int64)


def validate_to_matrix(C: np.ndarray, n: int | None = None,
                       require_distinct: bool = True) -> None:
    """Check C is a valid TO matrix: shape (n, r), entries in [0, n),
    optionally distinct within each row (any optimal C has distinct rows,
    paper Sec. II)."""
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    n_ = C.shape[0] if n is None else n
    if n is not None and C.shape[0] != n:
        raise ValueError(f"TO matrix has {C.shape[0]} rows, expected n={n}")
    if C.shape[1] > n_:
        raise ValueError(f"computation load r={C.shape[1]} exceeds n={n_}")
    if C.min() < 0 or C.max() >= n_:
        raise ValueError(f"task indices must lie in [0, {n_}), got "
                         f"[{C.min()}, {C.max()}]")
    if require_distinct:
        for i, row in enumerate(C):
            if len(set(row.tolist())) != len(row):
                raise ValueError(f"row {i} has repeated tasks: {row}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A named TO-matrix construction."""
    name: str
    build: Callable[..., np.ndarray]

    def __call__(self, n: int, r: int, **kw) -> np.ndarray:
        # ``r`` is passed through for every schedule — RA's builder rejects
        # r != n rather than silently ignoring the requested load.
        C = self.build(n, r, **kw)
        validate_to_matrix(C, n)
        return C


SCHEDULES: dict[str, Schedule] = {
    "cs": Schedule("cs", cyclic_to_matrix),
    "ss": Schedule("ss", staircase_to_matrix),
    "ra": Schedule("ra", random_assignment_to_matrix),
    "block": Schedule("block", block_to_matrix),
}


def to_matrix(name: str, n: int, r: int, **kw) -> np.ndarray:
    """Build a named TO matrix (``cs`` | ``ss`` | ``ra`` | ``block``)."""
    try:
        sched = SCHEDULES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; have {sorted(SCHEDULES)}")
    return sched(n, r, **kw)


# --------------------- adaptive row assignment -------------------------------

def greedy_row_assignment(C: np.ndarray, speed_est=None, *,
                          gamma: float = 0.5) -> np.ndarray:
    """Assign workers to the rows of base TO matrix ``C`` from estimated
    per-worker delays: fastest workers pick first, each taking the row whose
    leading slots cover the least-covered tasks.

    Parameters
    ----------
    C:         base (n, r) TO matrix whose rows get re-assigned.
    speed_est: length-n estimated per-task delay of each worker (smaller =
               faster); ``None`` means no feedback yet (uniform speeds —
               the greedy then just spaces coverage, e.g. rows 0, r, 2r, …
               of a cyclic matrix go to the first pickers).
    gamma:     per-slot coverage discount: slot j of a chosen row adds
               ``gamma**j / speed_est[w]`` coverage to its task — earlier
               slots (and faster workers) count for more, mirroring eq. (1)'s
               sequential arrivals.

    Returns ``worker_of_row``, a permutation with ``worker_of_row[p] = w``
    meaning worker ``w`` executes row ``p``.  The induced effective schedule
    is ``C_eff[w] = C[row_of_worker[w]]`` with ``row_of_worker`` the inverse
    permutation (``AdaptiveScheduler.matrix`` builds it).

    This delegates to the batched JAX implementation (one source of truth),
    so training loops and the fused rounds engine pick identical rows for
    identical feedback.
    """
    C = np.asarray(C)
    n, r = C.shape
    est = (np.ones(n, np.float32) if speed_est is None
           else np.asarray(speed_est, np.float32))
    if est.shape != (n,):
        raise ValueError(f"speed_est must have shape ({n},), got {est.shape}")
    fn = _jitted_greedy(tuple(tuple(int(v) for v in row) for row in C),
                        float(gamma))
    return np.asarray(fn(jnp.asarray(est)[None])[0], np.int64)


@functools.lru_cache(maxsize=None)
def _jitted_greedy(C_tup: tuple, gamma: float):
    C = np.asarray(C_tup, np.int64)
    return jax.jit(lambda est: greedy_row_assignment_batch(C, est,
                                                           gamma=gamma))


def greedy_row_assignment_batch(C: np.ndarray, est: jax.Array, *,
                                gamma: float = 0.5) -> jax.Array:
    """Batched JAX twin of ``greedy_row_assignment``: ``est`` has shape
    (..., n); returns ``worker_of_row`` of the same shape (int32).  Pure and
    jit/scan-friendly (``C`` is baked in at trace time); used per-trial
    inside the fused rounds engine."""
    C = np.asarray(C)
    n, r = C.shape
    Cj = jnp.asarray(C)
    disc = jnp.asarray(gamma ** np.arange(r), jnp.float32)
    big = jnp.float32(np.finfo(np.float32).max)

    def one(e):                                      # e (n,)
        order = jnp.argsort(e)                       # stable; fastest first

        def pick(carry, w):
            cov, taken, w_of_row = carry
            scores = (disc[None, :] * cov[Cj]).sum(-1)
            scores = jnp.where(taken, big, scores)
            p = jnp.argmin(scores)                   # ties -> lowest row
            w_of_row = w_of_row.at[p].set(w.astype(jnp.int32))
            taken = taken.at[p].set(True)
            add = disc / jnp.maximum(e[w], 1e-30)
            cov = cov.at[Cj[p]].add(add)
            return (cov, taken, w_of_row), None

        init = (jnp.zeros(n, jnp.float32), jnp.zeros(n, bool),
                jnp.zeros(n, jnp.int32))
        (_, _, w_of_row), _ = jax.lax.scan(pick, init, order)
        return w_of_row

    batch = est.shape[:-1]
    flat = est.reshape((-1, n))
    out = jax.vmap(one)(flat)
    return out.reshape(batch + (n,))


def censored_feedback_update(est: jax.Array, t1: jax.Array,
                             arrivals: jax.Array, t_done, *,
                             beta: float = 0.7) -> jax.Array:
    """One censored-feedback step — the single source of truth shared by
    ``AdaptiveScheduler.observe`` and the fused rounds engine
    (``montecarlo.sweep_rounds(..., censored_feedback=True)``), so training
    loops and MC estimates apply identical update rules to identical
    observations.

    ``est`` (..., n) is the per-worker delay estimate with +inf marking
    workers never yet observed; ``t1``/``arrivals`` (..., n, r) are the
    round's per-slot compute delays and per-message arrival times, both
    worker-major; ``t_done`` (scalar or (...,)) the round's completion time.
    Only slots whose message arrived by ``t_done`` are observed: observed
    workers get their masked-mean compute delay (replace on first
    observation, EMA with weight ``beta`` on history after), silent workers
    keep their previous estimate.  Returns the new ``est``.
    """
    td = jnp.asarray(t_done)[..., None, None]
    mobs = jnp.asarray(arrivals) <= td
    cnt = mobs.sum(axis=-1)
    obs = jnp.where(cnt > 0,
                    (jnp.asarray(t1) * mobs).sum(axis=-1)
                    / jnp.maximum(cnt, 1), 0.0)
    est = jnp.asarray(est)
    seen = jnp.isfinite(est)
    upd = jnp.where(seen, beta * est + (1.0 - beta) * obs, obs)
    return jnp.where(cnt > 0, upd, est)


class AdaptiveScheduler:
    """Stateful round-to-round re-permutation of a base TO matrix.

    Call ``matrix()`` before each round for the effective schedule,
    ``observe(t1)`` after it with the round's per-worker compute delays
    ((n,) means or the raw (n, r) slot delays).  Feedback is an EMA with
    weight ``beta`` on history, so transient hiccups don't thrash the
    assignment but persistent stragglers migrate to low-impact rows.

    Passing ``arrivals``/``t_done`` to ``observe`` censors the feedback to
    what a real master sees: only slots whose message reached the master
    before the round completed are observed.  Workers that delivered
    nothing keep their previous estimate; a worker never yet observed sits
    at +inf, i.e. is ranked slowest until it first delivers (principled: a
    worker that never beat the round deadline *is* effectively slowest).
    """

    def __init__(self, C: np.ndarray, *, beta: float = 0.7,
                 gamma: float = 0.5):
        validate_to_matrix(C)
        self.C = np.asarray(C)
        self.beta = float(beta)
        self.gamma = float(gamma)
        self.est: np.ndarray | None = None
        self._assignment: np.ndarray | None = None   # valid until observe()

    def worker_of_row(self) -> np.ndarray:
        if self._assignment is None:
            self._assignment = greedy_row_assignment(self.C, self.est,
                                                     gamma=self.gamma)
        return self._assignment

    def row_of_worker(self) -> np.ndarray:
        w_of_row = self.worker_of_row()
        inv = np.empty_like(w_of_row)
        inv[w_of_row] = np.arange(len(w_of_row))
        return inv

    def matrix(self) -> np.ndarray:
        """The effective TO matrix for the coming round: row ``w`` is what
        worker ``w`` executes."""
        return self.C[self.row_of_worker()]

    def observe(self, t1, *, arrivals=None, t_done=None) -> None:
        n = self.C.shape[0]
        obs = np.asarray(t1, np.float64)
        if (arrivals is None) != (t_done is None):
            raise ValueError("censored feedback needs BOTH arrivals and "
                             "t_done (or neither)")
        if arrivals is not None:
            # censored: only slots whose message arrived by t_done count.
            # Delegates to the shared update rule (one source of truth
            # with the fused rounds engine).
            arr = np.asarray(arrivals, np.float64)
            if obs.ndim != 2 or obs.shape[0] != n or arr.shape != obs.shape:
                raise ValueError(
                    f"censored feedback needs per-slot (n={n}, r) compute "
                    f"delays and matching arrivals; got {obs.shape} and "
                    f"{arr.shape}")
            est = (np.full(n, np.inf) if self.est is None else self.est)
            self.est = np.asarray(censored_feedback_update(
                jnp.asarray(est, jnp.float32), obs, arr, float(t_done),
                beta=self.beta), np.float64)
            self._assignment = None
            return
        if obs.ndim == 2:
            obs = obs.mean(-1)
        if obs.shape != (n,):
            raise ValueError(f"feedback must be (n,) or (n, r) for "
                             f"n={n}; got {obs.shape}")
        if self.est is None:
            self.est = obs
        else:
            # replace-on-first for workers still at the +inf never-observed
            # sentinel (left there by earlier censored rounds) — EMAing the
            # sentinel would pin them at +inf forever.
            seen = np.isfinite(self.est)
            self.est = np.where(seen,
                                self.beta * self.est + (1.0 - self.beta) * obs,
                                obs)
        self._assignment = None

"""Task-ordering (TO) matrices — the paper's central object.

A TO matrix ``C`` is an ``(n, r)`` integer matrix. Row ``i`` lists the task
indices worker ``i`` executes, in order: worker ``i`` first computes
``h(X[C[i, 0]])``, then ``h(X[C[i, 1]])``, ... (paper Sec. II). Tasks are
0-indexed here (the paper is 1-indexed).

Implemented schedules:
  * Cyclic scheduling   (CS, paper eq. 21):  C(i,j) = g(i + j)
  * Staircase scheduling (SS, paper eq. 29): C(i,j) = g(i + (-1)^i * j)
  * Random assignment   (RA, [18]):          each row an independent random
    permutation of [n] (requires r == n)
  * round-robin block / custom matrices via validation helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "cyclic_to_matrix",
    "staircase_to_matrix",
    "random_assignment_to_matrix",
    "block_to_matrix",
    "validate_to_matrix",
    "to_matrix",
    "SCHEDULES",
    "Schedule",
]


def _g(m: np.ndarray, n: int) -> np.ndarray:
    """Paper's wrap-around map g (eq. 22), 0-indexed: fold into [0, n)."""
    return np.mod(m, n)


def cyclic_to_matrix(n: int, r: int) -> np.ndarray:
    """CS schedule (eq. 21): every worker walks the ring in the same
    direction, offset by its index, so each task has the same execution
    *position* at every worker that holds it."""
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    return _g(i + j, n).astype(np.int64)


def staircase_to_matrix(n: int, r: int) -> np.ndarray:
    """SS schedule (eq. 29): even-indexed workers walk the ring ascending,
    odd-indexed workers descending (0-indexed parity matches the paper's
    1-indexed convention: paper worker 1 ≙ row 0 ascends)."""
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    sign = np.where(i % 2 == 0, 1, -1)
    return _g(i + sign * j, n).astype(np.int64)


def random_assignment_to_matrix(n: int, r: int | None = None, *,
                                rng: np.random.Generator | None = None,
                                seed: int | None = 0) -> np.ndarray:
    """RA scheme [18]: r = n (full dataset at each worker); each row is an
    independent uniformly random permutation of [n]."""
    if r is not None and r != n:
        raise ValueError(f"RA requires r == n (got r={r}, n={n})")
    if rng is None:
        rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(n) for _ in range(n)]).astype(np.int64)


def block_to_matrix(n: int, r: int) -> np.ndarray:
    """Naive blocked redundancy baseline (not in the paper; useful ablation):
    worker i computes tasks {i, i+1, ..., i+r-1} like CS but all workers
    start from the *lowest* index of their block — i.e. identical to CS.
    Differs for the ablation where workers share a start: C(i,j) = g(⌊i/r⌋*r + j).
    """
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    return _g((i // max(r, 1)) * r + j, n).astype(np.int64)


def validate_to_matrix(C: np.ndarray, n: int | None = None,
                       require_distinct: bool = True) -> None:
    """Check C is a valid TO matrix: shape (n, r), entries in [0, n),
    optionally distinct within each row (any optimal C has distinct rows,
    paper Sec. II)."""
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    n_ = C.shape[0] if n is None else n
    if n is not None and C.shape[0] != n:
        raise ValueError(f"TO matrix has {C.shape[0]} rows, expected n={n}")
    if C.shape[1] > n_:
        raise ValueError(f"computation load r={C.shape[1]} exceeds n={n_}")
    if C.min() < 0 or C.max() >= n_:
        raise ValueError(f"task indices must lie in [0, {n_}), got "
                         f"[{C.min()}, {C.max()}]")
    if require_distinct:
        for i, row in enumerate(C):
            if len(set(row.tolist())) != len(row):
                raise ValueError(f"row {i} has repeated tasks: {row}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A named TO-matrix construction."""
    name: str
    build: Callable[..., np.ndarray]

    def __call__(self, n: int, r: int, **kw) -> np.ndarray:
        # ``r`` is passed through for every schedule — RA's builder rejects
        # r != n rather than silently ignoring the requested load.
        C = self.build(n, r, **kw)
        validate_to_matrix(C, n)
        return C


SCHEDULES: dict[str, Schedule] = {
    "cs": Schedule("cs", cyclic_to_matrix),
    "ss": Schedule("ss", staircase_to_matrix),
    "ra": Schedule("ra", random_assignment_to_matrix),
    "block": Schedule("block", block_to_matrix),
}


def to_matrix(name: str, n: int, r: int, **kw) -> np.ndarray:
    """Build a named TO matrix (``cs`` | ``ss`` | ``ra`` | ``block``)."""
    try:
        sched = SCHEDULES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; have {sorted(SCHEDULES)}")
    return sched(n, r, **kw)

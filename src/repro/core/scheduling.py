"""Task-ordering (TO) matrices — the paper's central object.

A TO matrix ``C`` is an ``(n, r)`` integer matrix. Row ``i`` lists the task
indices worker ``i`` executes, in order: worker ``i`` first computes
``h(X[C[i, 0]])``, then ``h(X[C[i, 1]])``, ... (paper Sec. II). Tasks are
0-indexed here (the paper is 1-indexed).

Ragged per-worker loads
-----------------------
The paper fixes one computation load ``r`` for every worker, but real
clusters are heterogeneous (paper Sec. VI EC2 measurements), and *reducing*
a slow worker's load beats merely re-ordering its tasks (Egger et al.,
arXiv:2304.08589).  Every construction therefore accepts a per-worker load
vector ``loads`` (``loads[i] <= r_max``): the result is still a rectangular
``(n, r_max)`` grid, with row ``i``'s trailing ``r_max - loads[i]`` slots
holding the sentinel ``MASKED`` (-1).  A uniform ``loads`` is exactly the
dense matrix.  ``loads_of_matrix`` recovers the load vector from the
sentinels; ``validate_to_matrix`` checks coverage/distinctness on the
active prefix of each row.  ``greedy_load_rebalance`` reallocates whole
slots between workers from delay feedback under a fixed total budget
(slow workers shed slots to fast ones, makespan-greedy).

Implemented schedules:
  * Cyclic scheduling   (CS, paper eq. 21):  C(i,j) = g(i + j)
  * Staircase scheduling (SS, paper eq. 29): C(i,j) = g(i + (-1)^i * j)
  * Random assignment   (RA, [18]):          each row an independent random
    permutation of [n] (requires r == n)
  * round-robin block / custom matrices via validation helpers.

Adaptive row assignment
-----------------------
The static schedules fix which worker executes which row forever.  Under
heterogeneous, *persistent* stragglers (see ``repro.core.cluster``) that
leaves completion time hostage to the luck of which rows the slow machines
drew: the tasks whose early copies all sit at stragglers arrive last.
``greedy_row_assignment`` re-permutes the rows of a base TO matrix each
round from observed per-worker delay feedback — fastest workers pick first,
and each picks the row whose leading slots cover the currently
least-covered tasks (coverage discounted by slot position and weighted by
the picker's speed).  ``AdaptiveScheduler`` wraps this with an EMA of the
feedback for use in training loops; the batched JAX variant
(``greedy_row_assignment_batch``) runs per-trial inside the fused rounds
engine (``repro.core.montecarlo.sweep_rounds``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MASKED",
    "cyclic_to_matrix",
    "staircase_to_matrix",
    "random_assignment_to_matrix",
    "block_to_matrix",
    "validate_to_matrix",
    "loads_of_matrix",
    "mask_matrix_loads",
    "to_matrix",
    "SCHEDULES",
    "Schedule",
    "greedy_row_assignment",
    "greedy_row_assignment_batch",
    "greedy_load_rebalance",
    "greedy_load_rebalance_batch",
    "censored_feedback_update",
    "AdaptiveScheduler",
]

MASKED = -1      # sentinel task index for the inactive trailing slots of a
                 # ragged row (worker load < grid width)


def _g(m: np.ndarray, n: int) -> np.ndarray:
    """Paper's wrap-around map g (eq. 22), 0-indexed: fold into [0, n)."""
    return np.mod(m, n)


def _check_loads(n: int, loads, r: int | None) -> tuple[np.ndarray, int]:
    """Validate a per-worker load vector against ``n`` workers and an
    optional grid width ``r`` (defaults to ``max(loads)``).  Returns
    ``(loads, r_max)``."""
    lv = np.asarray(loads, np.int64)
    if lv.shape != (n,):
        raise ValueError(f"loads must have shape ({n},), got {lv.shape}")
    if lv.min() < 1:
        raise ValueError(f"every worker needs load >= 1, got min {lv.min()}")
    r_max = int(lv.max()) if r is None else int(r)
    if lv.max() > r_max:
        raise ValueError(f"max load {lv.max()} exceeds grid width r={r_max}")
    if not 1 <= r_max <= n:
        raise ValueError(f"need 1 <= r <= n, got r={r_max}, n={n}")
    return lv, r_max


def mask_matrix_loads(C: np.ndarray, loads) -> np.ndarray:
    """Apply a load vector to a dense TO matrix: slots ``j >= loads[i]`` of
    row ``i`` are replaced with the ``MASKED`` sentinel."""
    C = np.asarray(C).astype(np.int64).copy()
    lv, _ = _check_loads(C.shape[0], loads, C.shape[1])
    C[np.arange(C.shape[1])[None, :] >= lv[:, None]] = MASKED
    return C


def cyclic_to_matrix(n: int, r: int | None = None, *,
                     loads=None) -> np.ndarray:
    """CS schedule (eq. 21): every worker walks the ring in the same
    direction, offset by its index, so each task has the same execution
    *position* at every worker that holds it.  With ``loads``, row ``i``
    keeps only its first ``loads[i]`` slots (trailing slots ``MASKED``);
    the slot-0 diagonal ``C[i, 0] = i`` keeps every task covered for any
    load vector."""
    if loads is not None:
        _, r = _check_loads(n, loads, r)
    elif r is None:
        raise ValueError("need a load r (or a loads vector)")
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    C = _g(i + j, n).astype(np.int64)
    return C if loads is None else mask_matrix_loads(C, loads)


def staircase_to_matrix(n: int, r: int | None = None, *,
                        loads=None) -> np.ndarray:
    """SS schedule (eq. 29): even-indexed workers walk the ring ascending,
    odd-indexed workers descending (0-indexed parity matches the paper's
    1-indexed convention: paper worker 1 ≙ row 0 ascends).  ``loads`` masks
    each row's trailing slots as in ``cyclic_to_matrix``; the slot-0
    diagonal again guarantees coverage."""
    if loads is not None:
        _, r = _check_loads(n, loads, r)
    elif r is None:
        raise ValueError("need a load r (or a loads vector)")
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    sign = np.where(i % 2 == 0, 1, -1)
    C = _g(i + sign * j, n).astype(np.int64)
    return C if loads is None else mask_matrix_loads(C, loads)


def random_assignment_to_matrix(n: int, r: int | None = None, *,
                                rng: np.random.Generator | None = None,
                                seed: int | None = 0,
                                loads=None) -> np.ndarray:
    """RA scheme [18]: r = n (full dataset at each worker); each row is an
    independent uniformly random permutation of [n].  With ``loads``, row
    ``i`` starts at its own task ``i`` (restoring the coverage guarantee a
    truncated random permutation would lose) followed by a random
    permutation of the rest, truncated to ``loads[i]`` slots."""
    if loads is not None:
        lv, r_max = _check_loads(n, loads, r if r is not None else n)
        if rng is None:
            rng = np.random.default_rng(seed)
        C = np.full((n, r_max), MASKED, np.int64)
        for i in range(n):
            rest = rng.permutation(np.delete(np.arange(n), i))
            row = np.concatenate([[i], rest])
            C[i, :lv[i]] = row[:lv[i]]
        return C
    if r is not None and r != n:
        raise ValueError(f"RA requires r == n (got r={r}, n={n})")
    if rng is None:
        rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(n) for _ in range(n)]).astype(np.int64)


def block_to_matrix(n: int, r: int | None = None, *,
                    loads=None) -> np.ndarray:
    """Naive blocked redundancy baseline (not in the paper; useful ablation):
    worker i computes tasks {i, i+1, ..., i+r-1} like CS but all workers
    start from the *lowest* index of their block — i.e. identical to CS.
    Differs for the ablation where workers share a start: C(i,j) = g(⌊i/r⌋*r + j).
    ``loads`` masks trailing slots (note: unlike CS/SS, blocked rows have no
    slot-0 diagonal, so ragged blocks may leave tasks uncovered).
    """
    if loads is not None:
        _, r = _check_loads(n, loads, r)
    elif r is None:
        raise ValueError("need a load r (or a loads vector)")
    if not (1 <= r <= n):
        raise ValueError(f"need 1 <= r <= n, got r={r}, n={n}")
    i = np.arange(n)[:, None]
    j = np.arange(r)[None, :]
    C = _g((i // max(r, 1)) * r + j, n).astype(np.int64)
    return C if loads is None else mask_matrix_loads(C, loads)


def loads_of_matrix(C: np.ndarray) -> np.ndarray:
    """Per-worker load vector of a (possibly ragged) TO matrix: the number
    of active (non-``MASKED``) leading slots of each row.  Raises if a
    ``MASKED`` sentinel appears before an active slot (masks must be a
    trailing suffix) or a row is fully masked."""
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    active = C != MASKED
    loads = active.sum(axis=1).astype(np.int64)
    if loads.min() < 1:
        raise ValueError(f"row {int(loads.argmin())} has no active slots")
    # masks must be contiguous and trailing: row i active exactly at j < l_i
    expect = np.arange(C.shape[1])[None, :] < loads[:, None]
    if not np.array_equal(active, expect):
        bad = int(np.nonzero((active != expect).any(axis=1))[0][0])
        raise ValueError(f"row {bad} has a MASKED sentinel before an active "
                         f"slot; masks must be a trailing suffix: {C[bad]}")
    return loads


def validate_to_matrix(C: np.ndarray, n: int | None = None,
                       require_distinct: bool = True,
                       loads=None) -> None:
    """Check C is a valid TO matrix: shape (n, r), active entries in
    [0, n), optionally distinct within each row's active prefix (any
    optimal C has distinct rows, paper Sec. II).  Rows may be ragged:
    trailing slots holding the ``MASKED`` sentinel are inactive; ``loads``
    (optional) cross-checks the per-row active counts."""
    C = np.asarray(C)
    if C.ndim != 2:
        raise ValueError(f"TO matrix must be 2-D, got shape {C.shape}")
    n_ = C.shape[0] if n is None else n
    if n is not None and C.shape[0] != n:
        raise ValueError(f"TO matrix has {C.shape[0]} rows, expected n={n}")
    if C.shape[1] > n_:
        raise ValueError(f"computation load r={C.shape[1]} exceeds n={n_}")
    lv = loads_of_matrix(C)                # also checks trailing-mask shape
    if loads is not None:
        want, _ = _check_loads(C.shape[0], loads, C.shape[1])
        if not np.array_equal(lv, want):
            raise ValueError(f"matrix loads {lv.tolist()} do not match the "
                             f"given loads {want.tolist()}")
    act = C[C != MASKED]
    if act.min() < 0 or act.max() >= n_:
        raise ValueError(f"task indices must lie in [0, {n_}), got "
                         f"[{act.min()}, {act.max()}]")
    if require_distinct:
        for i, row in enumerate(C):
            row = row[:lv[i]]
            if len(set(row.tolist())) != len(row):
                raise ValueError(f"row {i} has repeated tasks: {row}")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A named TO-matrix construction."""
    name: str
    build: Callable[..., np.ndarray]

    def __call__(self, n: int, r: int | None = None, **kw) -> np.ndarray:
        # ``r`` is passed through for every schedule — RA's builder rejects
        # r != n rather than silently ignoring the requested load.
        C = self.build(n, r, **kw)
        validate_to_matrix(C, n, loads=kw.get("loads"))
        return C


SCHEDULES: dict[str, Schedule] = {
    "cs": Schedule("cs", cyclic_to_matrix),
    "ss": Schedule("ss", staircase_to_matrix),
    "ra": Schedule("ra", random_assignment_to_matrix),
    "block": Schedule("block", block_to_matrix),
}


def to_matrix(name: str, n: int, r: int | None = None, **kw) -> np.ndarray:
    """Build a named TO matrix (``cs`` | ``ss`` | ``ra`` | ``block``).
    ``loads=`` builds the ragged variant (per-worker loads, trailing slots
    ``MASKED``) for every schedule that supports it."""
    try:
        sched = SCHEDULES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown schedule {name!r}; have {sorted(SCHEDULES)}")
    return sched(n, r, **kw)


# --------------------- adaptive row assignment -------------------------------

def greedy_row_assignment(C: np.ndarray, speed_est=None, *,
                          gamma: float = 0.5, need=None) -> np.ndarray:
    """Assign workers to the rows of base TO matrix ``C`` from estimated
    per-worker delays: fastest workers pick first, each taking the row whose
    leading slots cover the least-covered tasks.

    Parameters
    ----------
    C:         base (n, r) TO matrix whose rows get re-assigned.
    speed_est: length-n estimated per-task delay of each worker (smaller =
               faster); ``None`` means no feedback yet (uniform speeds —
               the greedy then just spaces coverage, e.g. rows 0, r, 2r, …
               of a cyclic matrix go to the first pickers).
    gamma:     per-slot coverage discount: slot j of a chosen row adds
               ``gamma**j / speed_est[w]`` coverage to its task — earlier
               slots (and faster workers) count for more, mirroring eq. (1)'s
               sequential arrivals.

    Returns ``worker_of_row``, a permutation with ``worker_of_row[p] = w``
    meaning worker ``w`` executes row ``p``.  The induced effective schedule
    is ``C_eff[w] = C[row_of_worker[w]]`` with ``row_of_worker`` the inverse
    permutation (``AdaptiveScheduler.matrix`` builds it).

    ``need`` (optional, length-n bool over *tasks*) marks tasks whose
    previous-round results were never delivered (reissue deadline policy):
    rows containing a needed task are picked before any row without one,
    so the fastest workers re-gather the backlog first.

    This delegates to the batched JAX implementation (one source of truth),
    so training loops and the fused rounds engine pick identical rows for
    identical feedback.
    """
    C = np.asarray(C)
    n, r = C.shape
    est = (np.ones(n, np.float32) if speed_est is None
           else np.asarray(speed_est, np.float32))
    if est.shape != (n,):
        raise ValueError(f"speed_est must have shape ({n},), got {est.shape}")
    C_tup = tuple(tuple(int(v) for v in row) for row in C)
    if need is None:
        fn = _jitted_greedy(C_tup, float(gamma))
        return np.asarray(fn(jnp.asarray(est)[None])[0], np.int64)
    nd = np.asarray(need)
    if nd.shape != (n,):
        raise ValueError(f"need must have shape ({n},), got {nd.shape}")
    fn = _jitted_greedy_need(C_tup, float(gamma))
    return np.asarray(
        fn(jnp.asarray(est)[None],
           jnp.asarray(nd, jnp.float32)[None])[0], np.int64)


@functools.lru_cache(maxsize=None)
def _jitted_greedy(C_tup: tuple, gamma: float):
    C = np.asarray(C_tup, np.int64)
    return jax.jit(lambda est: greedy_row_assignment_batch(C, est,
                                                           gamma=gamma))


@functools.lru_cache(maxsize=None)
def _jitted_greedy_need(C_tup: tuple, gamma: float):
    C = np.asarray(C_tup, np.int64)
    return jax.jit(lambda est, need: greedy_row_assignment_batch(
        C, est, gamma=gamma, need=need))


GREEDY_IMPLS = ("auto", "scan", "kernel")


def _resolve_greedy_impl(impl: str | None) -> str:
    """``None``/``"auto"`` -> the Pallas kernel on compiled backends
    (TPU/GPU), the pure-jnp scan on CPU (where Pallas only interprets);
    explicit ``"scan"``/``"kernel"`` forces one path (tests, debugging)."""
    if impl in (None, "auto"):
        from ..kernels.gram_matvec import default_interpret
        return "scan" if default_interpret() else "kernel"
    if impl not in ("scan", "kernel"):
        raise ValueError(f"unknown greedy impl {impl!r}; choose from "
                         f"{GREEDY_IMPLS}")
    return impl


@functools.lru_cache(maxsize=None)
def _greedy_matrices(C_tup: tuple, gamma: float):
    """Static pick-loop matrices of a TO matrix: the coverage-weight
    matrix ``W[p, t] = sum_j gamma**j * [C[p, j] == t]`` (active slots
    only) and the 0/1 row-covers-task incidence ``A[p, t]``.  With these,
    greedy scores are ``cov @ W.T`` and the reissue row-priority is
    ``need @ A.T > 0`` — no gathers in the pick loop.  Rows with distinct
    active tasks (what ``validate_to_matrix`` enforces) make the matvec
    arithmetic term-for-term identical to the per-slot gather form."""
    C = np.asarray(C_tup)
    n, r = C.shape
    active = C != MASKED
    disc = gamma ** np.arange(r)
    W = np.zeros((n, n), np.float32)
    A = np.zeros((n, n), np.float32)
    for p in range(n):
        for j in range(r):
            if active[p, j]:
                W[p, C[p, j]] += np.float32(disc[j])
                A[p, C[p, j]] = 1.0
    return W, A


def greedy_row_assignment_batch(C: np.ndarray, est: jax.Array, *,
                                gamma: float = 0.5,
                                need: jax.Array | None = None,
                                impl: str | None = None) -> jax.Array:
    """Batched JAX twin of ``greedy_row_assignment``: ``est`` has shape
    (..., n); returns ``worker_of_row`` of the same shape (int32).  Pure and
    jit/scan-friendly (``C`` is baked in at trace time); used per-trial
    inside the fused rounds engine.  ``C`` may be ragged: ``MASKED`` slots
    contribute no coverage (their weight is statically zeroed).

    The pick loop runs as dense per-step matmuls against the static
    coverage-weight matrix of ``C`` (see ``_greedy_matrices``), either as
    a pure-jnp scan (``repro.kernels.ref.greedy_assign_ref``) or as the
    Pallas kernel (``repro.kernels.ops.greedy_assign``); ``impl`` selects
    (``None``/``"auto"`` = kernel on compiled backends, scan on CPU).

    ``need`` (traced, (..., n) or (n,) over tasks, nonzero = needed) is the
    reissue priority: while any un-taken row still holds a needed task, the
    picker's argmin runs over those rows only.  ``need=None`` (and an
    all-zero ``need``) keeps the established pick order bit-exactly."""
    from ..kernels import ops as kernel_ops
    from ..kernels.ref import greedy_assign_ref
    C = np.asarray(C)
    n, r = C.shape
    C_tup = tuple(tuple(int(v) for v in row) for row in C)
    W, A = _greedy_matrices(C_tup, float(gamma))
    Wj = jnp.asarray(W)

    batch = est.shape[:-1]
    flat = est.reshape((-1, n))
    order = jnp.argsort(flat, axis=-1).astype(jnp.int32)  # stable; fast 1st
    epick = jnp.maximum(jnp.take_along_axis(flat, order, axis=-1),
                        jnp.float32(1e-30))
    need_row = None
    if need is not None:
        ndf = jnp.broadcast_to(jnp.asarray(need, jnp.float32),
                               est.shape).reshape((-1, n))
        need_row = (ndf > 0).astype(jnp.float32) @ jnp.asarray(A).T

    if _resolve_greedy_impl(impl) == "kernel":
        out = kernel_ops.greedy_assign(Wj, order, epick, need_row)
    else:
        out = greedy_assign_ref(Wj, order, epick, need_row)
    return out.reshape(batch + (n,))


# --------------------- adaptive load re-balancing ----------------------------

def greedy_load_rebalance(speed_est, loads=None, *, total: int | None = None,
                          r_max: int, min_load: int = 1,
                          steps: int | None = None) -> np.ndarray:
    """Re-allocate whole computation slots between workers from estimated
    per-task delays, under a fixed total budget (Egger et al.,
    arXiv:2304.08589: *reducing* a slow worker's load beats re-ordering its
    tasks).

    Starting from ``loads`` (or an as-even-as-possible split of ``total``),
    the greedy repeatedly moves one slot from the worker with the largest
    estimated finish time ``est[w] * loads[w]`` to the worker whose
    post-move finish ``est[w'] * (loads[w'] + 1)`` is smallest, whenever the
    move strictly lowers the donor's finish below nothing it raises —
    i.e. classic makespan descent.  Bounds: ``min_load <= loads[w] <=
    r_max`` and ``sum(loads)`` is invariant (the total computation budget).

    ``speed_est`` may contain ``+inf`` for workers never yet observed
    (censored feedback): they shed slots down to ``min_load`` as soon as any
    finite-estimate worker has headroom.  With no observations at all
    (all-``inf`` estimates, or ``None``/all-equal estimates on a uniform
    allocation) the allocation is returned unchanged; equal *finite*
    estimates on an uneven allocation still descend toward the even split
    (that is the makespan greedy doing its job).

    Returns the new per-worker load vector (int64).  Delegates to the
    batched JAX implementation (one source of truth with the fused rounds
    engine).
    """
    if loads is None:
        if total is None or speed_est is None:
            raise ValueError("need an initial loads vector, or a total "
                             "budget plus a speed_est to size it from")
        n = np.asarray(speed_est).shape[0]
        base, extra = divmod(int(total), n)
        lv = np.full(n, base, np.int64)
        lv[:extra] += 1                    # as-even-as-possible split
    else:
        lv = np.asarray(loads, np.int64)
    n = lv.shape[0]
    if total is not None and int(lv.sum()) != int(total):
        raise ValueError(f"loads sum {lv.sum()} != total budget {total}")
    if not 1 <= min_load <= lv.min():
        raise ValueError(f"need 1 <= min_load <= min(loads); got "
                         f"min_load={min_load}, loads min {lv.min()}")
    if lv.max() > r_max:
        raise ValueError(f"max load {lv.max()} exceeds r_max={r_max}")
    est = (np.ones(n, np.float32) if speed_est is None
           else np.asarray(speed_est, np.float32))
    if est.shape != (n,):
        raise ValueError(f"speed_est must have shape ({n},), got {est.shape}")
    fn = _jitted_rebalance(tuple(int(v) for v in lv), int(r_max),
                           int(min_load), steps)
    return np.asarray(fn(jnp.asarray(est)[None])[0], np.int64)


@functools.lru_cache(maxsize=None)
def _jitted_rebalance(loads_tup: tuple, r_max: int, min_load: int,
                      steps: int | None):
    loads = np.asarray(loads_tup, np.int64)
    return jax.jit(lambda est: greedy_load_rebalance_batch(
        est, loads, r_max=r_max, min_load=min_load, steps=steps))


def greedy_load_rebalance_batch(est: jax.Array, loads: np.ndarray, *,
                                r_max: int, min_load: int = 1,
                                steps: int | None = None) -> jax.Array:
    """Batched JAX twin of ``greedy_load_rebalance``: ``est`` has shape
    (..., n) (``+inf`` = never observed), ``loads`` is the static initial
    allocation; returns per-worker loads of the same batch shape (int32),
    each summing to ``sum(loads)``.  Pure and jit/scan-friendly; used
    per-round inside the fused rounds engine.  ``steps`` bounds the number
    of single-slot moves (default ``n * r_max``, enough to reach the greedy
    fixed point from any allocation); once no strictly improving move
    exists the allocation is a no-op fixed point, so extra steps are
    harmless."""
    loads = np.asarray(loads, np.int64)
    n = loads.shape[0]
    if steps is None:
        steps = int(n * r_max)
    l0 = jnp.asarray(loads, jnp.int32)
    ninf = jnp.float32(-np.inf)
    pinf = jnp.float32(np.inf)

    def one(e):                                       # e (n,)
        def move(l, _):
            lf = l.astype(jnp.float32)
            finish = e * lf                           # est finish per worker
            can_give = l > min_load
            can_take = l < r_max
            give = jnp.where(can_give, finish, ninf)
            take = jnp.where(can_take, e * (lf + 1.0), pinf)
            d = jnp.argmax(give)                      # slowest finisher
            w = jnp.argmin(take)                      # cheapest extra slot
            # move only if it strictly lowers the donor's finish below the
            # receiver's post-move finish (makespan descent; `inf > inf`
            # is False, so an all-inf/no-feedback round keeps the split).
            ok = (give[d] > take[w]) & can_give[d] & can_take[w] & (d != w)
            l = jnp.where(ok, l.at[d].add(-1).at[w].add(1), l)
            return l, None

        l, _ = jax.lax.scan(move, l0, None, length=steps)
        return l

    batch = est.shape[:-1]
    flat = est.reshape((-1, n))
    out = jax.vmap(one)(flat)
    return out.reshape(batch + (n,))


def censored_feedback_update(est: jax.Array, t1: jax.Array,
                             arrivals: jax.Array, t_done, *,
                             beta: float = 0.7) -> jax.Array:
    """One censored-feedback step — the single source of truth shared by
    ``AdaptiveScheduler.observe`` and the fused rounds engine
    (``montecarlo.sweep_rounds(..., censored_feedback=True)``), so training
    loops and MC estimates apply identical update rules to identical
    observations.

    ``est`` (..., n) is the per-worker delay estimate with +inf marking
    workers never yet observed; ``t1``/``arrivals`` (..., n, r) are the
    round's per-slot compute delays and per-message arrival times, both
    worker-major; ``t_done`` (scalar or (...,)) the round's completion time.
    Only slots whose message arrived by ``t_done`` are observed: observed
    workers get their masked-mean compute delay (replace on first
    observation, EMA with weight ``beta`` on history after), silent workers
    keep their previous estimate.  Returns the new ``est``.

    +inf-safe: a censored slot (fault-killed worker, ``arrivals`` and/or
    ``t1`` = +inf) is never observed, even when ``t_done`` is itself +inf
    (``wait`` policy with fewer than k survivors) — ``inf <= inf`` must
    not count as an arrival, and masked +inf delays must not poison the
    observed mean with ``inf * 0 = nan``.
    """
    td = jnp.asarray(t_done)[..., None, None]
    arr = jnp.asarray(arrivals)
    mobs = (arr <= td) & jnp.isfinite(arr)
    cnt = mobs.sum(axis=-1)
    obs = jnp.where(cnt > 0,
                    jnp.where(mobs, jnp.asarray(t1), 0.0).sum(axis=-1)
                    / jnp.maximum(cnt, 1), 0.0)
    est = jnp.asarray(est)
    seen = jnp.isfinite(est)
    upd = jnp.where(seen, beta * est + (1.0 - beta) * obs, obs)
    return jnp.where(cnt > 0, upd, est)


class AdaptiveScheduler:
    """Stateful round-to-round re-permutation of a base TO matrix.

    Call ``matrix()`` before each round for the effective schedule,
    ``observe(t1)`` after it with the round's per-worker compute delays
    ((n,) means or the raw (n, r) slot delays).  Feedback is an EMA with
    weight ``beta`` on history, so transient hiccups don't thrash the
    assignment but persistent stragglers migrate to low-impact rows.

    Passing ``arrivals``/``t_done`` to ``observe`` censors the feedback to
    what a real master sees: only slots whose message reached the master
    before the round completed are observed.  Workers that delivered
    nothing keep their previous estimate; a worker never yet observed sits
    at +inf, i.e. is ranked slowest until it first delivers (principled: a
    worker that never beat the round deadline *is* effectively slowest).

    Ragged loads: the base ``C`` may itself be ragged (rows carry their
    loads through the re-permutation), or — with ``rebalance=True`` and a
    dense base ``C`` whose width is the per-worker load *cap* — the
    scheduler additionally re-allocates whole slots between workers each
    round (``greedy_load_rebalance``) under the fixed total budget
    ``sum(loads)``: slow workers shed slots to fast ones.  ``loads()``
    returns the coming round's per-worker loads; ``matrix()`` masks the
    effective schedule accordingly.

    Crash awareness (fault tolerance, see ``cluster.FaultProcess``): with
    ``dead_after`` set, a worker that has delivered nothing for that many
    consecutive observed rounds is presumed *dead* — its estimate is
    forced to +inf so the greedy assignment hands it the least-covering
    rows (survivors repair coverage by taking the high-coverage rows
    first) and, under ``rebalance``, it sheds load down to ``min_load``.
    With ``target_k`` set, ``matrix()`` additionally verifies the
    surviving assignment still spans >= ``target_k`` distinct tasks and
    raises a ``ValueError`` naming the shortfall when degradation cannot
    be graceful.  ``set_need`` feeds the reissue deadline policy: tasks
    whose results were never delivered get re-gathered first next round.
    """

    def __init__(self, C: np.ndarray, *, beta: float = 0.7,
                 gamma: float = 0.5, loads=None, rebalance: bool = False,
                 min_load: int = 1, dead_after: int | None = None,
                 target_k: int | None = None):
        self.C = np.asarray(C)
        self.rebalance = bool(rebalance)
        if self.rebalance:
            if (self.C == MASKED).any():
                raise ValueError("rebalance needs a dense base matrix (its "
                                 "width is the per-worker load cap); pass "
                                 "the budget via loads=")
            validate_to_matrix(self.C)
            if loads is None:
                raise ValueError("rebalance needs an initial loads budget "
                                 "below the grid width (loads=)")
            self.base_loads, _ = _check_loads(self.C.shape[0], loads,
                                              self.C.shape[1])
        else:
            validate_to_matrix(self.C, loads=loads)
            self.base_loads = loads_of_matrix(self.C)
        self.min_load = int(min_load)
        self.beta = float(beta)
        self.gamma = float(gamma)
        if dead_after is not None and dead_after < 1:
            raise ValueError(f"dead_after must be >= 1, got {dead_after}")
        if target_k is not None and not 1 <= target_k <= self.C.shape[0]:
            raise ValueError(f"target_k must be in [1, {self.C.shape[0]}], "
                             f"got {target_k}")
        self.dead_after = dead_after
        self.target_k = target_k
        self.est: np.ndarray | None = None
        self.silent = np.zeros(self.C.shape[0], np.int64)
        self._need: np.ndarray | None = None
        self._assignment: np.ndarray | None = None   # valid until observe()
        self._loads: np.ndarray | None = None

    def dead_workers(self) -> np.ndarray:
        """Bool (n,): workers presumed dead — nothing delivered for
        ``dead_after`` consecutive observed rounds (all-False when crash
        detection is off)."""
        if self.dead_after is None:
            return np.zeros(self.C.shape[0], bool)
        return self.silent >= self.dead_after

    def _effective_est(self) -> np.ndarray | None:
        """Feedback estimates with presumed-dead workers censored to +inf
        (ranked slowest: they pick rows last and shed load first)."""
        dead = self.dead_workers()
        if not dead.any():
            return self.est
        base = (np.ones(self.C.shape[0], np.float64) if self.est is None
                else self.est)
        return np.where(dead, np.inf, base)

    def set_need(self, need) -> None:
        """Mark tasks to re-gather first next round (reissue policy):
        ``need`` is a length-n bool over tasks (or None to clear)."""
        nd = None if need is None else np.asarray(need, bool)
        if nd is not None and nd.shape != (self.C.shape[0],):
            raise ValueError(f"need must have shape ({self.C.shape[0]},), "
                             f"got {nd.shape}")
        self._need = nd if nd is not None and nd.any() else None
        self._assignment = None

    def worker_of_row(self) -> np.ndarray:
        if self._assignment is None:
            self._assignment = greedy_row_assignment(
                self.C, self._effective_est(), gamma=self.gamma,
                need=self._need)
        return self._assignment

    def row_of_worker(self) -> np.ndarray:
        w_of_row = self.worker_of_row()
        inv = np.empty_like(w_of_row)
        inv[w_of_row] = np.arange(len(w_of_row))
        return inv

    def loads(self) -> np.ndarray:
        """Per-worker loads for the coming round: the assigned rows' own
        loads, re-balanced from feedback when ``rebalance`` is on (workers
        with no estimate yet count as slowest: +inf)."""
        if not self.rebalance:
            return self.base_loads[self.row_of_worker()]
        if self._loads is None:
            est = self._effective_est()
            if est is None:
                est = np.full(self.C.shape[0], np.inf)
            self._loads = greedy_load_rebalance(
                est, self.base_loads, r_max=self.C.shape[1],
                min_load=self.min_load)
        return self._loads

    def matrix(self) -> np.ndarray:
        """The effective TO matrix for the coming round: row ``w`` is what
        worker ``w`` executes (``MASKED`` beyond worker ``w``'s load).

        With crash detection on (``dead_after`` + ``target_k``), verifies
        the rows held by surviving workers still span >= ``target_k``
        distinct tasks — the greedy repair (dead workers rank slowest, so
        survivors picked the high-coverage rows first) usually guarantees
        this, but when too many workers died for any assignment to cover
        k tasks, degradation cannot be graceful and this raises instead
        of letting a round hang forever."""
        M = self.C[self.row_of_worker()]
        if self.rebalance:
            M = mask_matrix_loads(M, self.loads())
        dead = self.dead_workers()
        if self.target_k is not None and dead.any():
            alive_rows = M[~dead]
            act = alive_rows[alive_rows != MASKED]
            covered = int(np.unique(act).size)
            if covered < self.target_k:
                raise ValueError(
                    f"graceful degradation impossible: {int(dead.sum())} of "
                    f"{self.C.shape[0]} workers presumed dead (no delivery "
                    f"for {self.dead_after} consecutive rounds) and the "
                    f"surviving assignment covers only {covered} distinct "
                    f"tasks < k={self.target_k}; lower k, raise the "
                    f"per-worker load, or raise dead_after")
        return M

    def observe(self, t1, *, arrivals=None, t_done=None) -> None:
        n = self.C.shape[0]
        obs = np.asarray(t1, np.float64)
        if (arrivals is None) != (t_done is None):
            raise ValueError("censored feedback needs BOTH arrivals and "
                             "t_done (or neither)")
        if arrivals is not None:
            # censored: only slots whose message arrived by t_done count.
            # Delegates to the shared update rule (one source of truth
            # with the fused rounds engine).
            arr = np.asarray(arrivals, np.float64)
            if obs.ndim != 2 or obs.shape[0] != n or arr.shape != obs.shape:
                raise ValueError(
                    f"censored feedback needs per-slot (n={n}, r) compute "
                    f"delays and matching arrivals; got {obs.shape} and "
                    f"{arr.shape}")
            est = (np.full(n, np.inf) if self.est is None else self.est)
            self.est = np.asarray(censored_feedback_update(
                jnp.asarray(est, jnp.float32), obs, arr, float(t_done),
                beta=self.beta), np.float64)
            delivered = (np.isfinite(arr) & (arr <= float(t_done))).any(-1)
            self.silent = np.where(delivered, 0, self.silent + 1)
            self._assignment = None
            self._loads = None
            return
        if obs.ndim == 2:
            # +inf slot delays (fault-censored) must not drag the row mean
            # to inf — average the finite slots only
            fin = np.isfinite(obs)
            cnt = fin.sum(-1)
            obs = np.where(cnt > 0,
                           np.where(fin, obs, 0.0).sum(-1)
                           / np.maximum(cnt, 1), np.inf)
        if obs.shape != (n,):
            raise ValueError(f"feedback must be (n,) or (n, r) for "
                             f"n={n}; got {obs.shape}")
        delivered = np.isfinite(obs)
        if self.est is None:
            # never-delivering workers start at the +inf censored sentinel
            self.est = np.where(delivered, obs, np.inf)
        else:
            # replace-on-first for workers still at the +inf never-observed
            # sentinel (left there by earlier censored rounds) — EMAing the
            # sentinel would pin them at +inf forever.  A +inf observation
            # (dead worker this round) keeps the previous estimate.
            seen = np.isfinite(self.est)
            upd = np.where(seen,
                           self.beta * self.est + (1.0 - self.beta) * obs,
                           obs)
            self.est = np.where(delivered, upd, self.est)
        self.silent = np.where(delivered, 0, self.silent + 1)
        self._assignment = None
        self._loads = None

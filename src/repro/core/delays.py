"""Statistical models for per-task computation (T^(1)) and per-result
communication (T^(2)) delays (paper Sec. II and Sec. VI-C).

Every model samples a pair of arrays ``(T1, T2)`` of shape
``(trials, n_workers, n_slots)``:

  * ``T1[t, i, j]`` — computation delay of the j-th *slot* at worker i
    (the slot's task identity comes from the TO matrix; delay statistics are
    order-independent, paper Remark 6).
  * ``T2[t, i, j]`` — communication delay of that slot's result.

Delays are independent across workers. Within a worker they may be dependent
(the paper's general model); ``rho`` adds an equicorrelated worker-level
random effect so tasks at the same worker share a slow/fast tendency.

The paper's EC2 calibration (Fig. 3): truncated Gaussians,
  scenario 1: mu1=1e-4, mu2=5e-4, a1=3e-5, s1=1e-4(*), a2=2e-4, s2=2e-4
(*) the paper's "alpha E beta" notation means alpha*10^-beta: a1=3E5=3e-5,
    sigma1=1E4=1e-4, a2=2E4=2e-4, sigma2=2E4=2e-4, mu1=1E4=1e-4, mu2=5E4=5e-4.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DelayModel", "TruncatedGaussianDelays", "ShiftedExponentialDelays",
    "BimodalStragglerDelays", "EmpiricalDelays", "scenario1", "scenario2",
    "ec2_like",
]

Array = jax.Array


def _truncnorm(key, shape, mu, sigma, lo, hi):
    """Sample a truncated normal on [lo, hi] elementwise (mu/sigma/lo/hi
    broadcastable to ``shape``)."""
    a = (lo - mu) / sigma
    b = (hi - mu) / sigma
    z = jax.random.truncated_normal(key, a, b, shape)
    return mu + sigma * z


@dataclasses.dataclass(frozen=True)
class DelayModel:
    """Base class. Subclasses implement ``_sample(key, trials, n, r)``
    returning (T1, T2) with shape (trials, n, r)."""

    def sample(self, key: Array, trials: int, n: int, r: int
               ) -> Tuple[Array, Array]:
        T1, T2 = self._sample(key, trials, n, r)
        assert T1.shape == (trials, n, r) and T2.shape == (trials, n, r)
        return T1, T2

    def _sample(self, key, trials, n, r):  # pragma: no cover - abstract
        raise NotImplementedError

    def as_process(self):
        """This model as a round-stateful ``DelayProcess`` (the
        zero-correlation special case; see ``repro.core.cluster``)."""
        from .cluster import IIDProcess
        return IIDProcess(self)


@dataclasses.dataclass(frozen=True)
class TruncatedGaussianDelays(DelayModel):
    """Paper Sec. VI-C (eq. 66): per-worker truncated Gaussian delays on
    [mu - a, mu + b]. ``mu1/mu2`` may be scalars or length-n vectors
    (scenario 2 uses per-worker means). ``rho`` in [0, 1) makes slots at the
    same worker positively correlated via a shared worker effect."""
    mu1: tuple | float = 1e-4
    sigma1: float = 1e-4
    a1: float = 3e-5
    mu2: tuple | float = 5e-4
    sigma2: float = 2e-4
    a2: float = 2e-4
    b1: float | None = None  # defaults to a1 (symmetric, as in the paper)
    b2: float | None = None
    rho: float = 0.0

    def _one(self, key, trials, n, r, mu, sigma, a, b):
        mu = jnp.asarray(mu, jnp.float32)
        mu = jnp.broadcast_to(mu, (n,))[None, :, None]  # (1, n, 1)
        b = a if b is None else b
        lo, hi = mu - a, mu + b
        if self.rho > 0.0:
            kw, ks = jax.random.split(key)
            # worker-level effect + slot-level effect, equicorrelated rho.
            w = _truncnorm(kw, (trials, n, 1), 0.0, 1.0, -3.0, 3.0)
            e = _truncnorm(ks, (trials, n, r), 0.0, 1.0, -3.0, 3.0)
            z = np.sqrt(self.rho) * w + np.sqrt(1 - self.rho) * e
            t = mu + sigma * z
            return jnp.clip(t, lo, hi)
        return _truncnorm(key, (trials, n, r), mu, sigma, lo, hi)

    def _sample(self, key, trials, n, r):
        k1, k2 = jax.random.split(key)
        T1 = self._one(k1, trials, n, r, self.mu1, self.sigma1, self.a1, self.b1)
        T2 = self._one(k2, trials, n, r, self.mu2, self.sigma2, self.a2, self.b2)
        return T1, T2


@dataclasses.dataclass(frozen=True)
class ShiftedExponentialDelays(DelayModel):
    """Classic straggler model (Lee et al. [3]): T = shift + Exp(rate).
    Scale-parameterized: T1 ~ s1 + Exp(mean=m1), per slot."""
    shift1: float = 1e-4
    mean1: float = 5e-5
    shift2: float = 2e-4
    mean2: float = 1e-4

    def _sample(self, key, trials, n, r):
        k1, k2 = jax.random.split(key)
        T1 = self.shift1 + self.mean1 * jax.random.exponential(k1, (trials, n, r))
        T2 = self.shift2 + self.mean2 * jax.random.exponential(k2, (trials, n, r))
        return T1, T2


@dataclasses.dataclass(frozen=True)
class BimodalStragglerDelays(DelayModel):
    """Persistent-straggler model: with prob ``p_straggle`` a worker's entire
    row is slowed by factor ``slow`` for the round (models a busy neighbor
    VM). Base delays are truncated Gaussian."""
    base: TruncatedGaussianDelays = TruncatedGaussianDelays()
    p_straggle: float = 0.2
    slow: float = 5.0

    def _sample(self, key, trials, n, r):
        kb, ks = jax.random.split(key)
        T1, T2 = self.base._sample(kb, trials, n, r)
        mask = jax.random.bernoulli(ks, self.p_straggle, (trials, n, 1))
        f = jnp.where(mask, self.slow, 1.0)
        return T1 * f, T2 * f


@dataclasses.dataclass(frozen=True)
class EmpiricalDelays(DelayModel):
    """Bootstrap-resample measured per-task delays. ``samples1/2`` are
    arrays of shape (n_measured, n) — rows = measured rounds. On a real
    cluster these come from timestamp logs (see launch/train.py --log-delays).
    """
    samples1: tuple = ()
    samples2: tuple = ()

    def _sample(self, key, trials, n, r):
        s1 = jnp.asarray(self.samples1, jnp.float32)
        s2 = jnp.asarray(self.samples2, jnp.float32)
        if s1.ndim != 2 or s1.shape[1] != n:
            raise ValueError(f"samples1 must be (rounds, n={n}); got {s1.shape}")
        k1, k2 = jax.random.split(key)
        i1 = jax.random.randint(k1, (trials, n, r), 0, s1.shape[0])
        i2 = jax.random.randint(k2, (trials, n, r), 0, s2.shape[0])
        w = jnp.arange(n)[None, :, None]
        return s1[i1, w], s2[i2, w]


# ---- Paper's two numerical scenarios (Sec. VI-C, Fig. 4) -------------------

def scenario1() -> TruncatedGaussianDelays:
    """mu1 = 1e-4, mu2 = 5e-4 for all workers."""
    return TruncatedGaussianDelays(mu1=1e-4, mu2=5e-4)


def scenario2(n: int, seed: int = 0) -> TruncatedGaussianDelays:
    """Per-worker means: mu1 a random permutation of {1e-4, 4/3e-4, ...,
    (2+n)/3 e-4}; mu2 of {5e-4, 5.5e-4, ..., (9+n)/2 e-4}."""
    rng = np.random.default_rng(seed)
    mu1 = (2 + np.arange(1, n + 1)) / 3 * 1e-4
    mu2 = (9 + np.arange(1, n + 1)) / 2 * 1e-4
    return TruncatedGaussianDelays(mu1=tuple(rng.permutation(mu1).tolist()),
                                   mu2=tuple(rng.permutation(mu2).tolist()))


def ec2_like(n: int, seed: int = 0, comm_over_comp: float = 5.0
             ) -> TruncatedGaussianDelays:
    """Fig. 3-style: communication dominates computation by ~comm_over_comp;
    mild heterogeneity across workers."""
    rng = np.random.default_rng(seed)
    mu1 = 1e-4 * (1.0 + 0.3 * rng.random(n))
    mu2 = comm_over_comp * 1e-4 * (1.0 + 0.3 * rng.random(n))
    return TruncatedGaussianDelays(mu1=tuple(mu1.tolist()), mu2=tuple(mu2.tolist()))

"""Coded-computation baselines the paper compares against (Sec. VI-B).

* PC   — polynomially coded regression [13]: worker i stores r coded
         matrices (one per group of G = ceil(n/r) data parts), computes the
         SUM of its r Gram-vector products, sends ONE message. The master
         recovers X^T X theta from any 2G - 1 workers by polynomial
         interpolation.
* PCMM — polynomially coded multi-message [17]: worker i stores r Lagrange-
         coded matrices (each mixing ALL n parts, evaluated at distinct
         points beta_{i,j}), computes them sequentially and sends each
         result immediately. The master recovers from any 2n - 1 received
         computations.

Unlike the paper's experiments (which *ignore* encode/decode cost), the full
codec is implemented: ``pc_encode/pc_decode`` and ``pcmm_encode/pcmm_decode``
really interpolate, so tests verify exact recovery, and the optional decode
timer in benchmarks can expose the cost the paper footnotes away.

Completion-time models (used in benchmarks, matching the paper's setup):

* PC completion   = (2*ceil(n/r)-1)-th order statistic of per-worker times
                    t_i = sum_j T1[i,j] + T2[i, last]        (eq. 51-52)
* PCMM completion = (2n-1)-th order statistic of ALL slot arrivals (eq. 56-57)
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import numpy as np

from . import montecarlo

__all__ = [
    "pc_threshold", "pcmm_threshold", "pc_encode", "pc_worker_compute",
    "pc_decode", "pcmm_encode", "pcmm_worker_compute", "pcmm_decode",
    "simulate_pc_completion", "simulate_pcmm_completion",
]


def pc_threshold(n: int, r: int) -> int:
    return 2 * math.ceil(n / r) - 1


def pcmm_threshold(n: int) -> int:
    return 2 * n - 1


def _lagrange_basis(points: np.ndarray, x: np.ndarray) -> np.ndarray:
    """L[m, t] = prod_{p != m} (x[t] - points[p]) / (points[m] - points[p])."""
    P = len(points)
    L = np.ones((P, len(np.atleast_1d(x))))
    x = np.atleast_1d(x).astype(np.float64)
    for m in range(P):
        for p in range(P):
            if p != m:
                L[m] *= (x - points[p]) / (points[m] - points[p])
    return L


# --------------------------------- PC ----------------------------------------

def _pc_groups(n: int, r: int) -> Tuple[np.ndarray, int]:
    """Partition task indices [n] into r groups of size G = ceil(n/r),
    padded with -1 (zero data)."""
    G = math.ceil(n / r)
    idx = np.full((r, G), -1, dtype=np.int64)
    flat = np.arange(n)
    for j in range(r):
        chunk = flat[j * G:(j + 1) * G]
        idx[j, :len(chunk)] = chunk
    return idx, G


def pc_encode(X_parts: np.ndarray, r: int, alphas: np.ndarray | None = None
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Encode the n data parts for PC.

    X_parts: (n, d, b) — the n sub-matrices X_i (b = N/n columns each).
    Returns (Xt, alphas, group_idx): Xt[i, j] = p_j(alpha_i) where p_j is the
    degree-(G-1) polynomial through the parts of group j at points 1..G.
    Shapes: Xt (n, r, d, b).
    """
    n, d, b = X_parts.shape
    group_idx, G = _pc_groups(n, r)
    if alphas is None:
        alphas = np.arange(1, n + 1, dtype=np.float64)   # worker eval points
    pts = np.arange(1, G + 1, dtype=np.float64)          # interpolation nodes
    L = _lagrange_basis(pts, alphas)                     # (G, n)
    Xt = np.zeros((n, r, d, b))
    for j in range(r):
        for m in range(G):
            p = group_idx[j, m]
            if p >= 0:
                Xt[:, j] += L[m][:, None, None] * X_parts[p]
    return Xt, alphas, group_idx


def pc_worker_compute(Xt_i: np.ndarray, theta: np.ndarray) -> np.ndarray:
    """Worker i's single message: sum_j Xt[i,j] @ (Xt[i,j].T @ theta)."""
    return sum(Xij @ (Xij.T @ theta) for Xij in Xt_i)


def pc_decode(results: np.ndarray, alphas_rx: np.ndarray, n: int, r: int
              ) -> np.ndarray:
    """Interpolate phi(x) = sum_j p_j(x) p_j(x)^T theta (degree 2G-2) from
    >= 2G-1 worker results, then return sum_{m=1..G} phi(m) = X^T X theta.

    results: (w, d) rows phi(alpha_i) from w >= 2G-1 distinct workers.
    """
    G = math.ceil(n / r)
    need = 2 * G - 1
    if len(alphas_rx) < need:
        raise ValueError(f"PC needs {need} results, got {len(alphas_rx)}")
    A = np.vander(np.asarray(alphas_rx, np.float64), need, increasing=True)
    coef, *_ = np.linalg.lstsq(A, np.asarray(results, np.float64), rcond=None)
    pts = np.arange(1, G + 1, dtype=np.float64)
    V = np.vander(pts, need, increasing=True)            # (G, need)
    return (V @ coef).sum(axis=0)


# -------------------------------- PCMM ---------------------------------------

def pcmm_encode(X_parts: np.ndarray, r: int, betas: np.ndarray | None = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Lagrange-code all n parts; worker i's j-th matrix is the degree-(n-1)
    polynomial through X_1..X_n (at nodes 1..n) evaluated at beta[i, j].

    Returns (Xh, betas): Xh (n, r, d, b)."""
    n, d, b = X_parts.shape
    if betas is None:
        # Chebyshev points spanning the interpolation nodes [1, n]: well-
        # conditioned (evaluation at 1..n is interpolation, not extrapolation)
        m = n * r
        cheb = np.cos((2 * np.arange(1, m + 1) - 1) / (2 * m) * np.pi)
        betas = (0.5 * (1 + n) + 0.5 * (n - 0.5) * cheb).reshape(n, r)
    nodes = np.arange(1, n + 1, dtype=np.float64)
    L = _lagrange_basis(nodes, betas.reshape(-1))        # (n, n*r)
    Xh = np.einsum("mp,mdb->pdb", L, X_parts).reshape(n, r, d, b)
    return Xh, betas


def pcmm_worker_compute(Xh_ij: np.ndarray, theta: np.ndarray) -> np.ndarray:
    """One sequential message: Xh_ij @ (Xh_ij.T @ theta)."""
    return Xh_ij @ (Xh_ij.T @ theta)


def pcmm_decode(results: np.ndarray, betas_rx: np.ndarray, n: int
                ) -> np.ndarray:
    """Interpolate phi2(x) (degree 2n-2) from >= 2n-1 results, then return
    sum_{i=1..n} phi2(i) = X^T X theta.

    Uses a Chebyshev basis over the hull of {received points} ∪ {1..n}: the
    encode points are Chebyshev-distributed, so the least-squares system is
    well-conditioned even at degree 2n-2 (a monomial Vandermonde is
    numerically hopeless beyond n ~ 6 — a real cost of PCMM the paper does
    not discuss)."""
    need = 2 * n - 1
    if len(betas_rx) < need:
        raise ValueError(f"PCMM needs {need} results, got {len(betas_rx)}")
    x = np.asarray(betas_rx, np.float64)
    nodes = np.arange(1, n + 1, dtype=np.float64)
    lo = min(x.min(), nodes.min()) - 1e-9
    hi = max(x.max(), nodes.max()) + 1e-9
    tx = (2 * x - (lo + hi)) / (hi - lo)
    A = np.polynomial.chebyshev.chebvander(tx, need - 1)
    coef, *_ = np.linalg.lstsq(A, np.asarray(results, np.float64),
                               rcond=None)
    tn = (2 * nodes - (lo + hi)) / (hi - lo)
    V = np.polynomial.chebyshev.chebvander(tn, need - 1)
    return (V @ coef).sum(axis=0)


# --------------------- completion-time simulation ----------------------------
# Backed by the fused sweep engine (montecarlo.py): per-trial subkeys mean
# the draws are the common random numbers shared with the uncoded schemes
# when evaluated inside one sweep, and lax.top_k replaces the full sort.

def simulate_pc_completion(model, n: int, r: int, *, trials: int = 10000,
                           seed: int = 0, chunk: int | None = None
                           ) -> jax.Array:
    """eq. (51)-(52): worker i's single message lands at
    sum_j T1[i, j] + T2[i, -1]; completion = (2*ceil(n/r)-1)-th order stat."""
    return montecarlo.completion_samples(
        montecarlo.pc_spec(r), model, n, trials=trials, seed=seed,
        chunk=chunk)


def simulate_pcmm_completion(model, n: int, r: int, *, trials: int = 10000,
                             seed: int = 0, chunk: int | None = None
                             ) -> jax.Array:
    """eq. (56)-(57): all n*r slot arrivals; completion = (2n-1)-th order
    statistic (requires n*r >= 2n-1, i.e. r >= 2 as in the paper)."""
    if n * r < pcmm_threshold(n):
        raise ValueError(f"PCMM infeasible: n*r={n*r} < 2n-1={2*n-1}")
    return montecarlo.completion_samples(
        montecarlo.pcmm_spec(r), model, n, trials=trials, seed=seed,
        chunk=chunk)

"""repro.core — the paper's contribution: straggler-tolerant computation
scheduling for distributed SGD (Amiri & Gündüz, IEEE TSP 2019).

``RoundConfig`` is the canonical round configuration (one validator shared
by the simulator, the trainer, and the live layer); the live execution
types (``run_live``, ``Master``, ``run_worker``, ...) are re-exported
lazily from ``repro.live`` so ``import repro.core`` stays light."""
from .spec import (RoundConfig, DEADLINE_POLICIES, validate_deadline)
from .scheduling import (MASKED, cyclic_to_matrix, staircase_to_matrix,
                         random_assignment_to_matrix, to_matrix,
                         validate_to_matrix, loads_of_matrix,
                         mask_matrix_loads, SCHEDULES,
                         greedy_row_assignment, greedy_row_assignment_batch,
                         greedy_load_rebalance, greedy_load_rebalance_batch,
                         censored_feedback_update, AdaptiveScheduler)
from .delays import (DelayModel, TruncatedGaussianDelays,
                     ShiftedExponentialDelays, BimodalStragglerDelays,
                     EmpiricalDelays, scenario1, scenario2, ec2_like)
from .cluster import (DelayProcess, IIDProcess, MarkovRegimeProcess,
                      AR1Process, as_process, heterogeneous_scales,
                      ec2_cluster, message_comm_delays, FaultProcess,
                      SpotPreemptionProcess, NetworkPartitionProcess,
                      RackFailureProcess, MessageLossProcess,
                      DiurnalLoadProcess, FAULT_SCENARIOS, make_scenario)
from .trace import (TRACE_FORMAT_VERSION, DelayTrace, TraceProcess,
                    save_trace, load_trace, validate_trace_file,
                    CalibrationReport, calibrate_trace)
from .montecarlo import (SchemeSpec, SweepResult, RoundsResult, to_spec,
                         lb_spec, pc_spec, pcmm_spec, tau_spec,
                         adaptive_spec, task_gather_plan,
                         task_arrival_times_gather, message_boundaries,
                         message_slot_map, message_group_sizes, sweep,
                         sweep_rounds, completion_samples,
                         trajectory_samples, task_arrival_samples,
                         clear_cache, cache_stats, set_cache_capacity,
                         trial_keys, ResumableSweep, resumable_sweep)
from .grid import (GridCell, GridSpec, GridResult, stream_grid,
                   GRID_FORMAT_VERSION)
from .planner import plan, PlanResult, PLAN_FORMAT_VERSION
from .completion import (slot_arrival_times, message_arrival_times,
                         message_slot_layout, task_arrival_times,
                         completion_time, lower_bound_time,
                         first_k_distinct_mask, winner_mask_gather,
                         simulate_completion, simulate_lower_bound,
                         mean_completion_time)
from .theory import (theorem1_tail_from_H, theorem1_tail_mc, theorem1_mean_mc,
                     lower_bound_tail_mc, lower_bound_mean_mc,
                     theorem1_tail_r1_independent, sum_survival_grid,
                     multimessage_marginal_cdfs, multimessage_coded_tail,
                     multimessage_coded_mean, truncated_gaussian_pdf,
                     delay_model_pdfs, operating_point_mean_lb)
from .coded import (pc_threshold, pcmm_threshold, pc_encode, pc_decode,
                    pc_worker_compute, pcmm_encode, pcmm_decode,
                    pcmm_worker_compute, simulate_pc_completion,
                    simulate_pcmm_completion)
from .aggregator import RoundSpec, StragglerAggregator

# ------------------- live-layer facade (lazy re-exports) ---------------------
# repro.live imports from repro.core's submodules, so importing it eagerly
# here would be circular; PEP 562 resolves the names on first access.
_LIVE_EXPORTS = ("run_live", "Master", "LiveResult", "RoundReport",
                 "run_worker", "sample_delay_tables", "Comm", "Listener",
                 "CommClosedError", "connect", "listen")


def __getattr__(name):
    if name in _LIVE_EXPORTS:
        from .. import live
        return getattr(live, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LIVE_EXPORTS))

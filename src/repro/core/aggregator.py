"""First-k-distinct gradient aggregation (paper eq. 61) as a reusable JAX
module — the bridge between the paper's scheduling theory and the training
framework.

One SGD iteration = one *round*:

  1. the global batch is split into ``n`` logical tasks (micro-batches);
  2. worker ``i`` (a data-parallel shard group) evaluates the gradients of
     tasks ``C[i, 0..r-1]`` sequentially;
  3. a delay realization (simulated, or measured on a real cluster) gives
     each (worker, slot) result a virtual arrival time;
  4. the earliest copies of the k earliest distinct tasks are combined with
     the unbiased scaling of eq. (61):

         theta <- theta - eta * (n / k) * sum_{selected tasks} g_task

     (the n/k factor is folded into the returned gradient).

The selection mask is a deterministic function of the arrival times and is
computed identically on every shard (cheap: n*r scalars), keeping the whole
round a single SPMD step — see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import scheduling
from .completion import first_k_distinct_mask, slot_arrival_times
from .delays import DelayModel

__all__ = ["RoundSpec", "StragglerAggregator"]

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static description of one scheduling round."""
    n: int            # number of logical tasks == number of workers
    r: int            # computation load (tasks per worker)
    k: int            # computation target (distinct results needed)
    schedule: str = "ss"   # cs | ss | ra | block
    seed: int = 0          # for RA matrices

    def __post_init__(self):
        if not (1 <= self.k <= self.n):
            raise ValueError(f"need 1 <= k <= n; got k={self.k}, n={self.n}")
        if not (1 <= self.r <= self.n):
            raise ValueError(f"need 1 <= r <= n; got r={self.r}, n={self.n}")

    def to_matrix(self) -> np.ndarray:
        return scheduling.to_matrix(self.schedule, self.n, self.r,
                                    **({"seed": self.seed}
                                       if self.schedule == "ra" else {}))


class StragglerAggregator:
    """Combines per-(worker, slot) gradients into the eq.-(61) estimate.

    Usage inside a train step::

        agg = StragglerAggregator(RoundSpec(n=16, r=2, k=12, schedule="ss"),
                                  delay_model)
        weights, t_done = agg.round_mask(rng)        # (n, r) weights, scalar
        grad = agg.combine(slot_grads, weights)      # pytree

    ``slot_grads`` is a pytree whose leaves have leading dims (n, r) — the
    gradient of task C[i, j] computed by worker i at slot j (already averaged
    within the micro-batch).
    """

    def __init__(self, spec: RoundSpec, delay_model: DelayModel):
        self.spec = spec
        self.delay_model = delay_model
        self.C = jnp.asarray(spec.to_matrix())

    def round_mask(self, key: Array) -> Tuple[Array, Array]:
        """Sample one round's delays, return (weights (n, r), completion
        time scalar). weights[i, j] in [0, 1]; sums to k over all slots."""
        n, r, k = self.spec.n, self.spec.r, self.spec.k
        T1, T2 = self.delay_model.sample(key, 1, n, r)
        s = slot_arrival_times(T1, T2)[0]                # (n, r)
        weights, t_done = first_k_distinct_mask(self.C, s, n, k)
        return weights, t_done

    def combine(self, slot_grads: PyTree, weights: Array) -> PyTree:
        """eq. (61): grad = (n/k) * mean over selected tasks of task grads
        == (1/k) * sum selected (if task grads are already per-task means,
        the global-batch-equivalent estimate is sum * n/k / n = sum/k)."""
        k = self.spec.k
        def _one(g):
            w = weights.reshape(weights.shape + (1,) * (g.ndim - 2))
            return (g * w).sum(axis=(0, 1)) / k
        return jax.tree_util.tree_map(_one, slot_grads)

    def expected_completion(self, key: Array, trials: int = 4096) -> float:
        """MC estimate of the round's average completion time (eq. 5)."""
        n, r, k = self.spec.n, self.spec.r, self.spec.k
        T1, T2 = self.delay_model.sample(key, trials, n, r)
        s = slot_arrival_times(T1, T2)
        _, t_done = first_k_distinct_mask(self.C, s, n, k)
        return float(t_done.mean())

"""First-k-distinct gradient aggregation (paper eq. 61) as a reusable JAX
module — the bridge between the paper's scheduling theory and the training
framework.

One SGD iteration = one *round*:

  1. the global batch is split into ``n`` logical tasks (micro-batches);
  2. worker ``i`` (a data-parallel shard group) evaluates the gradients of
     tasks ``C[i, 0..r-1]`` sequentially;
  3. the round's delay realization comes from a stateful ``DelayProcess``
     (``repro.core.cluster``): worker-specific straggling *persists* across
     ``round_mask`` calls, so consecutive rounds see correlated delays just
     like a real cluster (stateless ``DelayModel``s are coerced to the
     zero-correlation ``IIDProcess``; a recorded ``DelayTrace`` replays a
     *measured* cluster through the same API — see ``repro.core.trace``);
  4. the earliest copies of the k earliest distinct tasks are combined with
     the unbiased scaling of eq. (61):

         theta <- theta - eta * (n / k) * sum_{selected tasks} g_task

     (the n/k factor is folded into the returned gradient).

With ``adaptive=True`` the aggregator re-permutes the base TO matrix's rows
every round from observed per-worker delay feedback (greedy
least-covered-first, ``repro.core.scheduling.AdaptiveScheduler``): fetch the
effective schedule for the coming round with ``current_matrix()`` *before*
calling ``round_mask`` (it decides which task's data each worker loads).
``censored_feedback=True`` restricts that feedback to messages that reached
the master before the round completed (what a real master observes), and
``RoundSpec.messages`` sets the per-round message budget (paper Sec. V-C):
results become available in per-message lumps instead of per slot.

Ragged rounds: ``RoundSpec.loads`` gives each TO-matrix row its own load
(trailing slots ``MASKED``; winner weights there are identically zero and
eq. (61) normalizes by the realized selected count), and
``rebalance=True`` (with ``adaptive=True``) additionally re-allocates
whole slots between workers each round from the same feedback
(``greedy_load_rebalance`` under the fixed total budget ``sum(loads)``,
per-worker cap ``r``) — fetch ``current_loads()``/``current_matrix()``
before each round.  ``RoundSpec.comm_eps`` adds the serialized per-message
protocol overhead (Ozfatura et al.'s communication/computation trade-off).

The selection mask is a deterministic function of the arrival times and is
computed identically on every shard (cheap: n*r scalars), keeping the whole
round a single SPMD step.  Task arrivals go through the fused MC engine's
static gather layout (``task_gather_plan``) rather than the old per-call
scatter-min, and ``expected_completion`` delegates to the engine's
``sweep_rounds`` — there is no separate simulation code path left here.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import montecarlo, scheduling
from . import spec as spec_mod
from .cluster import IIDProcess, as_process
from .spec import RoundConfig
from .completion import (apply_row_layout, message_arrival_times,
                         message_slot_layout, row_layout_is_identity,
                         winner_mask_gather)

__all__ = ["RoundSpec", "StragglerAggregator"]

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static description of one scheduling round.

    ``r`` is the slot-grid width (the uniform load, or — with ``loads`` —
    the per-worker load cap).  ``loads`` makes the round ragged: row ``i``
    of the TO matrix keeps only its first ``loads[i]`` slots (for adaptive
    load re-balancing, ``loads`` is the *initial budget* under the cap
    ``r``).  ``comm_eps`` is the serialized per-message protocol overhead
    (Ozfatura et al.'s communication/computation trade-off).

    ``deadline`` caps the round's wall-clock (fault tolerance — under
    fault-injecting delay processes a round may otherwise never reach k
    results); ``deadline_policy`` picks the fallback: ``"wait"`` (report
    the true completion, flag the miss), ``"close_partial"`` (close at the
    deadline with whatever arrived — eq. 61 renormalizes by the realized
    count), or ``"reissue"`` (close partial + the adaptive scheduler
    re-gathers undelivered tasks first next round).
    """
    n: int            # number of logical tasks == number of workers
    r: int            # computation load (tasks per worker) / grid width
    k: int            # computation target (distinct results needed)
    schedule: str = "ss"   # cs | ss | ra | block
    seed: int = 0          # for RA matrices
    messages: int | None = None  # per-round messages per worker
                                 # (None = one per slot, eq. 1)
    loads: tuple | None = None   # per-row loads (ragged rounds)
    comm_eps: float = 0.0        # per-message protocol overhead
    deadline: float | None = None      # per-round wall-clock cap
    deadline_policy: str = "wait"      # wait | close_partial | reissue

    def __post_init__(self):
        spec_mod._legacy_warning(
            "RoundSpec", "call .to_round_spec() (field map: schedule→kind; "
            "adaptive / censored_feedback / rebalance / dead_after now live "
            "on RoundConfig)")
        if self.loads is not None:
            object.__setattr__(self, "loads",
                               tuple(int(v) for v in self.loads))
        # one canonical validator (repro.core.spec.RoundConfig) — a bare
        # RoundSpec carries no adaptivity, so only the schedule-shape checks
        # apply (``reissue`` stands alone here: its adaptive requirement is
        # enforced where the scheduler is built, as before).
        RoundConfig(n=self.n, k=self.k, kind=self.schedule, r=self.r,
                    loads=self.loads, messages=self.messages,
                    comm_eps=self.comm_eps, deadline=self.deadline,
                    deadline_policy=self.deadline_policy,
                    adaptive=self.deadline_policy == "reissue",
                    seed=self.seed)

    @property
    def n_messages(self) -> int:
        return self.r if self.messages is None else int(self.messages)

    @property
    def load_vector(self) -> np.ndarray:
        return (np.full(self.n, self.r, np.int64) if self.loads is None
                else np.asarray(self.loads, np.int64))

    def base_matrix(self) -> np.ndarray:
        """The dense (un-masked) schedule at the grid width ``r`` — the
        load-rebalancing cap grid."""
        return scheduling.to_matrix(self.schedule, self.n, self.r,
                                    **({"seed": self.seed}
                                       if self.schedule == "ra" else {}))

    def to_matrix(self) -> np.ndarray:
        kw = {"seed": self.seed} if self.schedule == "ra" else {}
        if self.loads is not None:
            kw["loads"] = self.loads
        return scheduling.to_matrix(self.schedule, self.n, self.r, **kw)


def _seed_of(key) -> int:
    """Accept an int seed or a PRNG key — raw uint32 or new-style typed —
    (compat with the pre-round API, which took a key).  The whole key
    feeds the seed so distinct keys give distinct MC streams."""
    if key is None:
        return 0
    if isinstance(key, (int, np.integer)):
        return int(key)
    try:
        data = np.asarray(jax.random.key_data(key))
    except TypeError:
        data = np.asarray(key)
    if data.ndim == 0:
        return int(data)
    return zlib.crc32(np.ascontiguousarray(data).tobytes()) & 0x7FFFFFFF


class StragglerAggregator:
    """Combines per-(worker, slot) gradients into the eq.-(61) estimate,
    holding the cluster's straggler state across rounds.

    Usage inside a train loop::

        agg = StragglerAggregator(RoundSpec(n=16, r=2, k=12, schedule="ss"),
                                  ec2_cluster(16, persistence=0.95))
        for step in range(steps):
            C = agg.current_matrix()                 # schedule this round
            ...load each worker's task data from C...
            weights, t_done = agg.round_mask(rng)    # (n, r) weights, scalar
            grad = agg.combine(slot_grads, weights)  # pytree

    ``slot_grads`` is a pytree whose leaves have leading dims (n, r) — the
    gradient of task C[i, j] computed by worker i at slot j (already averaged
    within the micro-batch).
    """

    def __init__(self, spec: RoundSpec, delay, *, adaptive: bool = False,
                 init_key: Array | None = None, feedback_beta: float = 0.7,
                 coverage_gamma: float = 0.5,
                 censored_feedback: bool = False,
                 rebalance: bool = False,
                 dead_after: int | None = None):
        # the adaptive-family cross-field rules live in the one canonical
        # validator: re-validate the spec WITH the adaptivity flags attached.
        RoundConfig(n=spec.n, k=spec.k, kind=spec.schedule, r=spec.r,
                    loads=spec.loads, messages=spec.messages,
                    comm_eps=spec.comm_eps, deadline=spec.deadline,
                    deadline_policy=spec.deadline_policy,
                    adaptive=adaptive, rebalance=rebalance,
                    censored_feedback=censored_feedback,
                    dead_after=dead_after, feedback_beta=feedback_beta,
                    coverage_gamma=coverage_gamma, seed=spec.seed)
        self.spec = spec
        self.process = as_process(delay)
        self.rebalance = bool(rebalance)
        # rebalance masks slots dynamically, so its base is the dense cap
        # grid; otherwise the (possibly ragged) schedule bakes its masks in.
        self.base_C = spec.base_matrix() if rebalance else spec.to_matrix()
        if rebalance and sorted(self.base_C[:, 0].tolist()) != list(
                range(spec.n)):
            # e.g. a dense RA base: without a slot-0 diagonal a shed load
            # can leave tasks with no active copy (t_done = +inf)
            raise ValueError("rebalance needs a slot-0-diagonal base "
                             "schedule (cs / ss) so every task stays "
                             "covered under any load vector")
        self._plan = montecarlo.task_gather_plan(self.base_C, spec.n)
        if adaptive:
            kw = dict(beta=feedback_beta, gamma=coverage_gamma)
            if dead_after is not None:
                kw.update(dead_after=int(dead_after), target_k=spec.k)
            if rebalance:
                self.scheduler = scheduling.AdaptiveScheduler(
                    self.base_C, loads=spec.loads, rebalance=True, **kw)
            else:
                self.scheduler = scheduling.AdaptiveScheduler(self.base_C,
                                                              **kw)
        else:
            self.scheduler = None
        self.censored = bool(censored_feedback)
        if rebalance:
            # re-balanced loads are decided per round, so the message
            # grouping cannot be a static row layout (the dense base
            # would bake a full-load grouping in); the round function
            # gathers the load-indexed closing-slot table instead.
            self._row_layout = None
            self._rb_remap = montecarlo._rebalance_remap_table(
                spec.r, spec.n_messages)
        else:
            # static per-row message layout (closing-slot remap + overhead
            # offsets + ragged masks); None when it is the identity
            layout = message_slot_layout(
                scheduling.loads_of_matrix(self.base_C), spec.r,
                spec.n_messages, spec.comm_eps)
            self._row_layout = (None if row_layout_is_identity(layout)
                                else layout)
            self._rb_remap = None
        if init_key is None:
            init_key = jax.random.PRNGKey(spec.seed)
        # trial id 0: a live training run is the single realization of a
        # trace-backed process (lane 0 of its table); parametric processes
        # ignore the id.
        self._state = self.process.init_trials(
            init_key[None], jnp.zeros((1,), jnp.int32), spec.n)
        self._rounds_done = 0
        # a deadline that actually closes the round (close_partial /
        # reissue) caps the winner selection; "wait" keeps the true
        # completion and only *flags* misses.
        self._dl_close = (spec.deadline
                          if spec.deadline is not None
                          and spec.deadline_policy != "wait" else None)
        self.rounds_missed = 0          # rounds that blew the deadline
        self.realized_k_history: list[float] = []   # realized count / round
        self._round = jax.jit(self._round_fn)

    # --- one round, jitted: delays + winner weights in base-row space ------
    def _round_fn(self, state, keys, row_of_worker, loads_w):
        n, r, k = self.spec.n, self.spec.r, self.spec.k
        state, T1, T2 = self.process.step(state, keys, n, r)
        # raw per-slot availability (eq. 1), permuted to base-row space;
        # the message grouping is applied per ROW (a worker's grouping
        # follows the row it executes), so remap after the permutation —
        # for uniform loads the remap is row-invariant and this is
        # bit-identical to remapping before it.
        s = message_arrival_times(T1, T2, r)[0]          # identity: eq. (1)
        worker_of_row = jnp.argsort(row_of_worker)       # inverse permutation
        s2 = s[worker_of_row]                            # row-major arrivals
        if self._row_layout is not None:
            s2 = apply_row_layout(s2, self._row_layout)
        if self.rebalance:
            # row p inherits its executor's re-balanced load this round
            l_row = loads_w[worker_of_row]
            s2 = jnp.where(jnp.arange(r)[None, :] < l_row[:, None], s2,
                           jnp.inf)
            if self._rb_remap is not None:
                # message budget under dynamic loads: gather each row's
                # load-indexed closing-slot remap (same table the MC
                # engine's rounds scan uses)
                mm = jnp.take(jnp.asarray(self._rb_remap), l_row - 1, axis=0)
                s2 = jnp.take_along_axis(s2, mm, axis=-1)
        w2, t_done = winner_mask_gather(self.base_C, self._plan, s2, n, k,
                                        deadline=self._dl_close)
        # per-task delivery by the (capped) round close — the reissue
        # policy's re-gather signal
        tau = montecarlo.task_arrival_times_gather(self._plan, s2)
        delivered = (tau <= t_done) & jnp.isfinite(tau)
        weights = w2[row_of_worker]                      # back to worker-major
        arr_w = s2[row_of_worker]                        # worker-major arrivals
        return state, T1[0], arr_w, weights, t_done, delivered

    def current_matrix(self) -> np.ndarray:
        """The effective TO matrix for the coming round (row ``w`` = tasks
        worker ``w`` executes; ``MASKED`` beyond worker ``w``'s load).
        Static schedules return the base matrix; adaptive ones the
        feedback-driven row re-assignment (and load re-balance)."""
        if self.scheduler is None:
            return self.base_C
        return self.scheduler.matrix()

    def current_loads(self) -> np.ndarray:
        """Per-worker loads for the coming round (matches
        ``current_matrix()``'s active slots)."""
        if self.scheduler is None:
            return self.spec.load_vector
        return self.scheduler.loads()

    def round_mask(self, key: Array) -> Tuple[Array, Array]:
        """Advance the cluster one round, returning (weights (n, r),
        completion time scalar). weights[i, j] in [0, 1]; sums to the
        *realized* distinct-result count over all slots (k almost surely
        without faults/deadlines) and matches ``current_matrix()``'s
        worker/slot layout."""
        # finite sources (trace replay) enforce their horizon policy here:
        # the live loop learns it ran off the recording's end *before* the
        # round executes, with the remedy in the error message.
        self.process.check_rounds(self._rounds_done + 1)
        row_of_worker = (np.arange(self.spec.n) if self.scheduler is None
                         else self.scheduler.row_of_worker())
        loads_w = (self.scheduler.loads() if self.rebalance
                   else self.spec.load_vector)
        self._state, t1, arrivals, weights, t_done, delivered = self._round(
            self._state, key[None], jnp.asarray(row_of_worker),
            jnp.asarray(loads_w))
        self._rounds_done += 1
        realized = float(np.asarray(weights).sum())
        self.realized_k_history.append(realized)
        if self.spec.deadline is not None:
            blown = (float(t_done) > self.spec.deadline
                     if self._dl_close is None else realized < self.spec.k)
            self.rounds_missed += int(blown)
        if self.scheduler is not None:
            if self.censored:
                # a real master only sees messages that beat the deadline
                self.scheduler.observe(np.asarray(t1),
                                       arrivals=np.asarray(arrivals),
                                       t_done=float(t_done))
            else:
                self.scheduler.observe(np.asarray(t1))
            if self.spec.deadline_policy == "reissue":
                # undelivered tasks get re-gather priority next round
                self.scheduler.set_need(~np.asarray(delivered))
        return weights, t_done

    def combine(self, slot_grads: PyTree, weights: Array) -> PyTree:
        """eq. (61): grad = (n/k) * mean over selected tasks of task grads
        == (1/k) * sum selected (if task grads are already per-task means,
        the global-batch-equivalent estimate is sum * n/k / n = sum/k).

        Normalized by the *realized* selected-task count (``weights.sum()``):
        with per-slot sends that is k almost surely (eq. 61 exactly), but a
        reduced message budget makes arrival ties structural — a message can
        deliver more distinct tasks than the target still missing — and the
        unbiased scaling then divides by however many arrived.  A round
        that realized *nothing* (every arrival fault-censored past the
        deadline) yields a zero gradient instead of 0/0 NaN."""
        den_raw = weights.sum()
        den = jnp.where(den_raw > 0, den_raw, 1.0)

        def _one(g):
            w = weights.reshape(weights.shape + (1,) * (g.ndim - 2))
            return (g * w).sum(axis=(0, 1)) / den
        return jax.tree_util.tree_map(_one, slot_grads)

    def expected_completion(self, key: Array | int = 0, trials: int = 4096,
                            rounds: int | None = None) -> float:
        """MC estimate of the average per-round completion time (eq. 5),
        via the fused engine.  For stateful processes the estimate scans
        ``rounds`` consecutive rounds (default 8) and averages; for the
        i.i.d. shim one round suffices.  ``key`` may be an int seed or a
        PRNG key (compat)."""
        from .trace import TraceProcess
        if rounds is None:
            rounds = 1 if isinstance(self.process, IIDProcess) else 8
            if isinstance(self.process, TraceProcess):
                # a strict trace can only serve what remains of its
                # recorded horizon after the replay offset
                rounds = min(rounds, self.process.trace.rounds
                             - int(self.process.start_round))
        m = self.spec.messages
        if self.rebalance:
            spec = montecarlo.adaptive_spec("s", self.base_C, messages=m,
                                            loads=self.spec.loads,
                                            rebalance=True)
        elif self.scheduler is not None:
            spec = montecarlo.adaptive_spec("s", self.base_C, messages=m)
        else:
            spec = montecarlo.to_spec("s", self.base_C, messages=m,
                                      comm_eps=self.spec.comm_eps)
        kw = {}
        if self.scheduler is not None:   # estimate the policy actually run
            kw = dict(feedback_beta=self.scheduler.beta,
                      coverage_gamma=self.scheduler.gamma,
                      censored_feedback=self.censored)
        if self.spec.deadline is not None:
            kw.update(deadline=self.spec.deadline,
                      deadline_policy=self.spec.deadline_policy)
        res = montecarlo.sweep_rounds(
            [spec], self.process, self.spec.n, rounds=rounds, k=self.spec.k,
            trials=trials, seed=_seed_of(key), **kw)
        return res.mean_round("s")

"""The canonical round configuration — one frozen ``RoundConfig`` + one
validator shared by the simulator, the trainer, and the live execution layer.

Historically the same scheme/load/messages/deadline fields were re-declared
three times with drifting validation: ``SchemeSpec`` (the MC engine's
per-scheme record, validated at sweep time), ``RoundSpec.__post_init__``
(the aggregator), and ad-hoc checks in the launcher CLI.  ``RoundConfig``
subsumes all three:

* ``RoundConfig(...)`` runs the one canonical validator (k/r ranges,
  message budgets, ragged-load coverage, deadline/policy pairing, and the
  adaptive-family cross-field rules that used to live in
  ``StragglerAggregator.__init__``);
* ``.to_round_spec()`` / ``.to_scheme_spec()`` derive the legacy objects
  (bit-exact under common random numbers — they are the same matrices and
  budgets, just re-packaged);
* ``.sweep_rounds_kwargs()`` / ``.aggregator_kwargs()`` feed the MC engine
  and the trainer;
* ``to_json`` / ``from_json`` / ``save`` / ``load`` round-trip the config
  through a versioned JSON document (``python -m repro.launch.train
  --config round.json`` and the live layer's master/worker handshake both
  ship this form).

``SchemeSpec(...)`` and ``RoundSpec(...)`` remain constructible but emit a
single ``DeprecationWarning`` per process pointing at the new spelling;
every internal call site builds them through ``RoundConfig`` (or the
factory helpers), which suppresses the warning via ``_internal()``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import warnings
from pathlib import Path
from typing import Optional

import numpy as np

from . import scheduling

__all__ = [
    "RoundConfig",
    "DEADLINE_POLICIES",
    "validate_deadline",
]

#: the fallback policies a deadline-capped round may close under.
DEADLINE_POLICIES = ("wait", "close_partial", "reissue")

CONFIG_FORMAT = "repro.round_config"
CONFIG_VERSION = 1


# ------------------------- deprecation machinery -----------------------------
#
# Legacy constructors (SchemeSpec / RoundSpec) warn exactly once per class
# per process — but never when the construction comes from inside this
# package (the factories, RoundConfig conversions, and the engine itself
# build them constantly).

_INTERNAL = 0
_warned: set = set()


@contextlib.contextmanager
def _internal():
    """Mark the enclosed legacy-object constructions as internal (no
    deprecation warning)."""
    global _INTERNAL
    _INTERNAL += 1
    try:
        yield
    finally:
        _INTERNAL -= 1


def _legacy_warning(cls_name: str, hint: str) -> None:
    if _INTERNAL or cls_name in _warned:
        return
    _warned.add(cls_name)
    warnings.warn(
        f"constructing {cls_name}(...) directly is deprecated: build a "
        f"repro.core.RoundConfig(...) and {hint}",
        DeprecationWarning, stacklevel=4)


def _reset_legacy_warnings() -> None:
    """Re-arm the once-per-process deprecation warnings (test helper)."""
    _warned.clear()


# --------------------------- shared validators -------------------------------

def validate_deadline(deadline, deadline_policy: str) -> Optional[float]:
    """Canonical deadline/policy validation — the single implementation
    behind ``RoundConfig``, the MC rounds engine, and the live master.
    Returns the deadline as ``float`` (or ``None``)."""
    if deadline_policy not in DEADLINE_POLICIES:
        raise ValueError(f"deadline_policy: unknown deadline policy "
                         f"{deadline_policy!r}; choose from "
                         f"{DEADLINE_POLICIES}")
    if deadline is not None:
        deadline = float(deadline)
        if not deadline > 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
    elif deadline_policy != "wait":
        raise ValueError(f"deadline_policy={deadline_policy!r} needs a "
                         f"deadline")
    return deadline


# ------------------------------ RoundConfig ----------------------------------

@dataclasses.dataclass(frozen=True)
class RoundConfig:
    """Everything that defines one distributed-SGD round, validated once.

    Scheme/shape: ``kind`` names the TO-matrix family (``cs`` | ``ss`` |
    ``ra`` | ``block``); ``n`` is the number of tasks (= workers), ``k``
    the distinct results a round needs, ``r`` the slot-grid width (per-
    worker load cap; ``None`` = ``n``), ``loads`` per-worker loads (ragged
    rounds — for ``rebalance`` the *initial budget* under the cap ``r``),
    ``messages`` the per-round message budget (``None`` = one per slot),
    ``comm_eps`` the serialized per-message protocol overhead.

    Deadlines: ``deadline`` caps each round's wall-clock, ``deadline_policy``
    picks the fallback (``wait`` | ``close_partial`` | ``reissue``).

    Adaptivity: ``adaptive`` re-assigns the base matrix's rows each round
    from delay feedback, ``censored_feedback`` restricts that feedback to
    what a real master observes, ``rebalance`` re-allocates whole slots
    between workers, ``dead_after`` marks silent workers dead after that
    many rounds, ``feedback_beta`` / ``coverage_gamma`` tune the scheduler.

    ``seed`` seeds RA-matrix construction and the live layer's delay draws.
    """
    n: int
    k: int
    kind: str = "cs"
    r: Optional[int] = None
    loads: Optional[tuple] = None
    messages: Optional[int] = None
    comm_eps: float = 0.0
    deadline: Optional[float] = None
    deadline_policy: str = "wait"
    adaptive: bool = False
    rebalance: bool = False
    censored_feedback: bool = False
    dead_after: Optional[int] = None
    feedback_beta: float = 0.7
    coverage_gamma: float = 0.5
    seed: int = 0

    # -------------------------- the one validator ----------------------------

    def __post_init__(self):
        _set = object.__setattr__
        _set(self, "n", int(self.n))
        _set(self, "k", int(self.k))
        _set(self, "kind", str(self.kind))
        _set(self, "r", None if self.r is None else int(self.r))
        _set(self, "messages",
             None if self.messages is None else int(self.messages))
        _set(self, "comm_eps", float(self.comm_eps))
        _set(self, "adaptive", bool(self.adaptive))
        _set(self, "rebalance", bool(self.rebalance))
        _set(self, "censored_feedback", bool(self.censored_feedback))
        _set(self, "dead_after",
             None if self.dead_after is None else int(self.dead_after))
        _set(self, "feedback_beta", float(self.feedback_beta))
        _set(self, "coverage_gamma", float(self.coverage_gamma))
        _set(self, "seed", int(self.seed))
        if not (1 <= self.k <= self.n):
            raise ValueError(f"need 1 <= k <= n; got k={self.k}, n={self.n}")
        r = self.width
        if not (1 <= r <= self.n):
            raise ValueError(f"need 1 <= r <= n; got r={r}, n={self.n}")
        if self.messages is not None and not 1 <= self.messages <= r:
            raise ValueError(f"need 1 <= messages <= r={r}; got "
                             f"messages={self.messages}")
        if self.comm_eps < 0:
            raise ValueError(f"comm_eps must be >= 0, got {self.comm_eps}")
        _set(self, "deadline",
             validate_deadline(self.deadline, self.deadline_policy))
        if self.loads is not None:
            _set(self, "loads", tuple(int(v) for v in self.loads))
            lv = np.asarray(self.loads, np.int64)
            if lv.shape != (self.n,) or lv.min() < 1 or lv.max() > r:
                raise ValueError(f"loads must be ({self.n},) with 1 <= load "
                                 f"<= r={r}; got {self.loads}")
            if self.kind not in ("cs", "ss", "ra"):
                raise ValueError(
                    f"ragged loads need a slot-0-diagonal schedule (cs / ss "
                    f"/ ra) so every task stays covered; got {self.kind!r}")
        if not 0.0 <= self.feedback_beta < 1.0:
            raise ValueError(f"feedback_beta must be in [0, 1), got "
                             f"{self.feedback_beta}")
        if not 0.0 <= self.coverage_gamma <= 1.0:
            raise ValueError(f"coverage_gamma must be in [0, 1], got "
                             f"{self.coverage_gamma}")
        # adaptive-family cross-field rules (formerly scattered across
        # StragglerAggregator.__init__ and the launcher CLI).
        if self.censored_feedback and not self.adaptive:
            raise ValueError("censored_feedback requires adaptive=True — "
                             "static schedules take no feedback to censor")
        if self.rebalance and not self.adaptive:
            raise ValueError("rebalance requires adaptive=True — load "
                             "re-allocation is feedback-driven")
        if self.dead_after is not None:
            if not self.adaptive:
                raise ValueError("dead_after requires adaptive=True — crash "
                                 "detection feeds the adaptive scheduler")
            if self.dead_after < 1:
                raise ValueError(f"dead_after must be >= 1, got "
                                 f"{self.dead_after}")
        if self.deadline_policy == "reissue" and not self.adaptive:
            raise ValueError("deadline_policy='reissue' requires "
                             "adaptive=True — re-gathering undelivered "
                             "tasks is a scheduling decision")
        if self.rebalance and self.loads is None:
            raise ValueError("rebalance needs loads as the initial budget "
                             "below the cap r")
        if self.rebalance and self.comm_eps:
            raise ValueError("rebalance does not support comm_eps yet")
        if self.adaptive and self.comm_eps:
            raise ValueError("comm_eps with adaptive scheduling is not "
                             "supported yet (expected_completion could not "
                             "estimate the policy actually run)")
        # the masked assignment must still be able to deliver k distinct
        # results — catch impossible rounds up front instead of letting the
        # engine report +inf completions (or hang a waiting master).  (For
        # rebalance the budget is not baked into masks, but slot-0-diagonal
        # coverage makes the check equivalent.)
        C = self.to_matrix()
        covered = int(np.unique(C[C >= 0]).size)
        if covered < self.k:
            raise ValueError(
                f"schedule {self.kind!r} with loads={self.loads} covers "
                f"only {covered} distinct tasks < k={self.k} "
                f"({self.k - covered} short): no round can ever complete; "
                f"lower k or raise the per-worker loads")
        if self.rebalance and sorted(
                self.base_matrix()[:, 0].tolist()) != list(range(self.n)):
            raise ValueError("rebalance needs a slot-0-diagonal base "
                             "schedule (cs / ss) so every task stays "
                             "covered under any load vector")

    # ------------------------------ derived ----------------------------------

    @property
    def width(self) -> int:
        """The resolved slot-grid width (``r``; ``None`` resolves to ``n``)."""
        return self.n if self.r is None else self.r

    @property
    def n_messages(self) -> int:
        return self.width if self.messages is None else self.messages

    @property
    def load_vector(self) -> np.ndarray:
        return (np.full(self.n, self.width, np.int64) if self.loads is None
                else np.asarray(self.loads, np.int64))

    def base_matrix(self) -> np.ndarray:
        """The dense (un-masked) schedule at the grid width — the load-
        rebalancing cap grid."""
        kw = {"seed": self.seed} if self.kind == "ra" else {}
        return scheduling.to_matrix(self.kind, self.n, self.width, **kw)

    def to_matrix(self) -> np.ndarray:
        """The effective schedule with ragged loads baked in as trailing
        ``MASKED`` sentinels."""
        kw = {"seed": self.seed} if self.kind == "ra" else {}
        if self.loads is not None:
            kw["loads"] = self.loads
        return scheduling.to_matrix(self.kind, self.n, self.width, **kw)

    # -------------------------- legacy derivations ---------------------------

    def to_round_spec(self):
        """The equivalent (legacy) ``repro.core.aggregator.RoundSpec`` —
        bit-exact: same matrices, budgets, and deadline semantics."""
        from .aggregator import RoundSpec
        with _internal():
            return RoundSpec(n=self.n, r=self.width, k=self.k,
                             schedule=self.kind, seed=self.seed,
                             messages=self.messages, loads=self.loads,
                             comm_eps=self.comm_eps, deadline=self.deadline,
                             deadline_policy=self.deadline_policy)

    def to_scheme_spec(self, name: Optional[str] = None):
        """The equivalent (legacy) ``repro.core.montecarlo.SchemeSpec`` for
        the MC engine — adaptive configs map to ``adaptive_spec`` (base
        matrix + feedback re-planning), static ones to ``to_spec``."""
        from . import montecarlo
        nm = self.kind if name is None else name
        with _internal():
            if self.adaptive:
                return montecarlo.adaptive_spec(
                    nm, self.base_matrix(), messages=self.messages,
                    loads=self.loads, rebalance=self.rebalance)
            return montecarlo.to_spec(
                nm, self.base_matrix(), messages=self.messages,
                loads=self.loads, comm_eps=self.comm_eps)

    def sweep_rounds_kwargs(self) -> dict:
        """Keyword arguments for ``montecarlo.sweep_rounds`` /
        ``trajectory_samples`` matching this config's round semantics."""
        kw = dict(k=self.k, feedback_beta=self.feedback_beta,
                  coverage_gamma=self.coverage_gamma,
                  censored_feedback=self.censored_feedback)
        if self.deadline is not None:
            kw.update(deadline=self.deadline,
                      deadline_policy=self.deadline_policy)
        return kw

    def aggregator_kwargs(self) -> dict:
        """Keyword arguments for ``StragglerAggregator(spec, process,
        **kwargs)`` matching this config's adaptivity."""
        return dict(adaptive=self.adaptive,
                    feedback_beta=self.feedback_beta,
                    coverage_gamma=self.coverage_gamma,
                    censored_feedback=self.censored_feedback,
                    rebalance=self.rebalance,
                    dead_after=self.dead_after)

    # ------------------------------ JSON form --------------------------------

    def to_dict(self) -> dict:
        d = {"format": CONFIG_FORMAT, "version": CONFIG_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            d[f.name] = list(v) if isinstance(v, tuple) else v
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RoundConfig":
        d = dict(d)
        fmt = d.pop("format", CONFIG_FORMAT)
        if fmt != CONFIG_FORMAT:
            raise ValueError(f"not a round config document: format={fmt!r} "
                             f"(expected {CONFIG_FORMAT!r})")
        version = int(d.pop("version", CONFIG_VERSION))
        if version > CONFIG_VERSION:
            raise ValueError(f"round config version {version} is newer than "
                             f"this library supports ({CONFIG_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown round config fields: {unknown}")
        if d.get("loads") is not None:
            d["loads"] = tuple(int(v) for v in d["loads"])
        return cls(**d)

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "RoundConfig":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "RoundConfig":
        return cls.from_json(Path(path).read_text())

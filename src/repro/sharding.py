"""Mesh context + sharding-constraint helpers shared by models and launch.

``MeshCtx`` carries the axis names so model code never hard-codes a mesh
shape; on a single device (smoke tests) the context is ``None`` and every
helper becomes a no-op.

Divisibility fallback (DESIGN.md §4): a dim is only sharded if the axis size
divides it — otherwise that dim stays replicated and the event is recorded
in ``MeshCtx.fallbacks`` for the roofline report.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshCtx", "current_mesh_ctx", "mesh_context", "shard", "axis_size",
           "DATA", "MODEL", "BOTH"]

DATA = "__data__"    # placeholder resolved to the ctx's (possibly stacked) data axes
MODEL = "__model__"  # placeholder resolved to the ctx's model axis
BOTH = "__both__"    # data axes + model axis (fully-sharded dim)

_state = threading.local()


@dataclasses.dataclass
class MeshCtx:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"   # None = pure data parallelism
    fallbacks: list = dataclasses.field(default_factory=list)

    @property
    def data_size(self) -> int:
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.model_axis else 1

    def resolve(self, spec_entry):
        if spec_entry == DATA:
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if spec_entry == MODEL:
            return self.model_axis
        if spec_entry == BOTH:
            if self.model_axis is None:
                return self.resolve(DATA)
            return tuple(self.data_axes) + (self.model_axis,)
        return spec_entry

    def spec(self, *entries) -> P:
        return P(*[self.resolve(e) for e in entries])


def current_mesh_ctx() -> Optional[MeshCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def mesh_context(ctx: Optional[MeshCtx]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def axis_size(entry) -> int:
    """Size of a placeholder axis under the current ctx (1 if no mesh)."""
    ctx = current_mesh_ctx()
    if ctx is None:
        return 1
    ax = ctx.resolve(entry)
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= ctx.mesh.shape[a]
        return n
    return ctx.mesh.shape[ax]


def shard(x: jax.Array, *entries, note: str = "") -> jax.Array:
    """Apply a sharding constraint with divisibility fallback. ``entries``
    use DATA/MODEL placeholders or literal axis names / None."""
    ctx = current_mesh_ctx()
    if ctx is None:
        return x
    resolved = []
    for dim, e in enumerate(entries):
        if e is None:
            resolved.append(None)
            continue
        ax = ctx.resolve(e)
        size = axis_size(e)
        if size <= 1:
            resolved.append(None)
        elif x.shape[dim] % size != 0:
            ctx.fallbacks.append((note or "tensor", dim, x.shape[dim], size))
            resolved.append(None)
        else:
            resolved.append(ax)
    sh = NamedSharding(ctx.mesh, P(*resolved))
    return jax.lax.with_sharding_constraint(x, sh)

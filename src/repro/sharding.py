"""Mesh context + sharding-constraint helpers shared by models and launch.

``MeshCtx`` carries the axis names so model code never hard-codes a mesh
shape; on a single device (smoke tests) the context is ``None`` and every
helper becomes a no-op.

Divisibility fallback (DESIGN.md §4): a dim is only sharded if the axis size
divides it — otherwise that dim stays replicated and the event is recorded
in ``MeshCtx.fallbacks`` for the roofline report.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshCtx", "current_mesh_ctx", "mesh_context", "shard", "axis_size",
           "DATA", "MODEL", "BOTH", "TRIAL_AXIS", "trial_devices",
           "trial_mesh", "shard_trials"]

DATA = "__data__"    # placeholder resolved to the ctx's (possibly stacked) data axes
MODEL = "__model__"  # placeholder resolved to the ctx's model axis
BOTH = "__both__"    # data axes + model axis (fully-sharded dim)

_state = threading.local()


@dataclasses.dataclass
class MeshCtx:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"   # None = pure data parallelism
    fallbacks: list = dataclasses.field(default_factory=list)

    @property
    def data_size(self) -> int:
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.model_axis else 1

    def resolve(self, spec_entry):
        if spec_entry == DATA:
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if spec_entry == MODEL:
            return self.model_axis
        if spec_entry == BOTH:
            if self.model_axis is None:
                return self.resolve(DATA)
            return tuple(self.data_axes) + (self.model_axis,)
        return spec_entry

    def spec(self, *entries) -> P:
        return P(*[self.resolve(e) for e in entries])


def current_mesh_ctx() -> Optional[MeshCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def mesh_context(ctx: Optional[MeshCtx]):
    prev = getattr(_state, "ctx", None)
    _state.ctx = ctx
    try:
        yield ctx
    finally:
        _state.ctx = prev


def axis_size(entry) -> int:
    """Size of a placeholder axis under the current ctx (1 if no mesh)."""
    ctx = current_mesh_ctx()
    if ctx is None:
        return 1
    ax = ctx.resolve(entry)
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= ctx.mesh.shape[a]
        return n
    return ctx.mesh.shape[ax]


def shard(x: jax.Array, *entries, note: str = "") -> jax.Array:
    """Apply a sharding constraint with divisibility fallback. ``entries``
    use DATA/MODEL placeholders or literal axis names / None."""
    ctx = current_mesh_ctx()
    if ctx is None:
        return x
    resolved = []
    for dim, e in enumerate(entries):
        if e is None:
            resolved.append(None)
            continue
        ax = ctx.resolve(e)
        size = axis_size(e)
        if size <= 1:
            resolved.append(None)
        elif x.shape[dim] % size != 0:
            ctx.fallbacks.append((note or "tensor", dim, x.shape[dim], size))
            resolved.append(None)
        else:
            resolved.append(ax)
    sh = NamedSharding(ctx.mesh, P(*resolved))
    return jax.lax.with_sharding_constraint(x, sh)


# --------------------------------------------------------------------------
# trial-axis sharding (Monte-Carlo sweeps)
# --------------------------------------------------------------------------

TRIAL_AXIS = "trials"


def trial_devices(devices=None) -> Tuple[jax.Device, ...]:
    """Resolve the ``devices`` argument of ``sweep``/``sweep_rounds``.

    ``None`` means every local device; an int means the first that many
    local devices; a sequence of ``jax.Device`` is taken as-is."""
    if devices is None:
        return tuple(jax.devices())
    if isinstance(devices, int):
        ds = jax.devices()
        if not 1 <= devices <= len(ds):
            raise ValueError(f"devices must be in 1..{len(ds)} (local "
                             f"device count), got {devices}")
        return tuple(ds[:devices])
    ds = tuple(devices)
    if not ds:
        raise ValueError("devices must name at least one device")
    return ds


def trial_mesh(devices: Sequence[jax.Device]) -> Mesh:
    """1-D mesh over the Monte-Carlo trial axis."""
    return Mesh(np.asarray(devices, dtype=object), (TRIAL_AXIS,))


def shard_trials(fn, devices: Sequence[jax.Device], replicated: Tuple[int, ...] = ()):
    """Shard ``fn`` over a 1-D trial mesh: every argument and every output
    is split along its leading (chunk) axis across ``devices`` in contiguous
    blocks, each device runs ``fn`` on its block, and outputs come back
    concatenated in global chunk order.  ``fn`` must be collective-free —
    the Monte-Carlo scans qualify because trials are independent.

    ``replicated`` names positional argnums that every device sees whole
    (broadcast, not split): small runtime parameters like PRNG base keys,
    per-chunk offset vectors, and the bucketed evaluators' gather plans.
    Replicated arguments skip the leading-axis reshape and ride into the
    vmap with ``in_axes=None`` under a fully-replicated ``P()`` sharding.

    Mechanism: the leading axis is reshaped to ``(d, per_device, ...)``,
    ``fn`` is ``vmap``-ed over the device axis, and the whole thing is
    jitted with ``NamedSharding(mesh, P(TRIAL_AXIS))`` on inputs and
    outputs, so the GSPMD partitioner splits every per-iteration tensor of
    the chunk scan across devices while the scan itself stays sequential
    per shard.  This deliberately does NOT use ``shard_map``: on forced
    multi-device host meshes (jax 0.4.x CPU) ``shard_map``-wrapped scan
    programs miscompile — constant-initialized loop carries are aliased
    across co-resident shards and fusion-dependent partial sums come out
    wrong on every device but the first — while the identical program
    partitioned via ``jit``/``NamedSharding`` (and via ``pmap``) is
    bit-exact vs. the eager single-device result.

    The returned callable is fully jitted — callers must NOT wrap it in
    another ``jax.jit`` (the reshapes below are free layout changes and the
    inner jit caches per input shape)."""
    devs = tuple(devices)
    d = len(devs)
    mesh = trial_mesh(devs)
    sh = NamedSharding(mesh, P(TRIAL_AXIS))
    rep = NamedSharding(mesh, P())
    repl = frozenset(replicated)
    # the vmapped/jitted callable is built lazily on first use: in_axes /
    # in_shardings are per-argument, and the argument count is only known
    # at call time (jit caches per pytree structure after that).
    cache: dict = {}

    def sharded(*args):
        nargs = len(args)
        vfn = cache.get(nargs)
        if vfn is None:
            axes = tuple(None if i in repl else 0 for i in range(nargs))
            shard_in = tuple(rep if i in repl else sh for i in range(nargs))
            vfn = jax.jit(jax.vmap(fn, in_axes=axes),
                          in_shardings=shard_in, out_shardings=sh)
            cache[nargs] = vfn
        parts = [jax.device_put(a, rep) if i in repl else jax.device_put(
            jnp.reshape(a, (d, a.shape[0] // d) + a.shape[1:]), sh)
            for i, a in enumerate(args)]
        out = vfn(*parts)
        return jax.tree_util.tree_map(
            lambda x: jnp.reshape(x, (-1,) + x.shape[2:]), out)

    return sharded

from .pipeline import (TaskPartition, lm_task_batches, synthetic_tokens,
                       bigram_tokens, regression_dataset, regression_tasks)

"""Data pipeline: the paper's n-way task partitioning + TO-ordered
per-worker micro-batching, with deterministic synthetic sources.

One SGD round splits the global batch into ``n`` logical tasks (paper
Remark 1: each task = one mini-batch). ``lm_task_batches`` materializes the
(slot-major) tensor the straggler train step consumes:

    slots[s, i] = micro-batch of task C[i, s]   — shape (r, n, b, S)

so worker *i* scanning slot ``s`` processes exactly the task the TO matrix
prescribes, in order. Task micro-batches are generated deterministically
from (seed, step, task), so two workers assigned the same task materialize
identical data — redundancy without data exchange (the paper's "portion of
the dataset available at each worker").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TaskPartition", "synthetic_tokens", "bigram_tokens",
           "lm_task_batches", "regression_dataset", "regression_tasks"]


@dataclasses.dataclass(frozen=True)
class TaskPartition:
    """Static description of the round's data layout."""
    n: int              # number of tasks / logical workers
    global_batch: int   # sequences per round
    seq_len: int
    vocab: int
    seed: int = 0
    source: str = "uniform"   # uniform | bigram

    @property
    def task_batch(self) -> int:
        assert self.global_batch % self.n == 0, \
            f"global_batch {self.global_batch} not divisible by n={self.n}"
        return self.global_batch // self.n


def synthetic_tokens(key, batch: int, seq: int, vocab: int) -> jax.Array:
    return jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)


def bigram_tokens(key, batch: int, seq: int, vocab: int,
                  temperature: float = 0.5,
                  chain_vocab: int = 1024) -> jax.Array:
    """Learnable synthetic source: tokens follow a fixed random bigram
    chain, so an LM can actually reduce loss on it. The chain lives on the
    first min(vocab, chain_vocab) ids — a full vocab x vocab transition
    matrix would be O(V^2) memory (4 GB at V=32k)."""
    vocab = min(vocab, chain_vocab)
    tkey = jax.random.PRNGKey(1234)           # fixed chain, not per-batch
    trans = jax.random.normal(tkey, (vocab, vocab)) / temperature
    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, trans[tok])
        return nxt, nxt

    keys = jax.random.split(k1, seq - 1)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], rest], 0).T.astype(jnp.int32)


def _task_key(part: TaskPartition, step: int, task: int):
    k = jax.random.PRNGKey(part.seed)
    k = jax.random.fold_in(k, step)
    return jax.random.fold_in(k, task)


def task_tokens(part: TaskPartition, step: int, task: int) -> jax.Array:
    """Deterministic micro-batch of one task: (b, S+1) tokens (inputs +
    next-token labels via shift)."""
    key = _task_key(part, step, task)
    gen = bigram_tokens if part.source == "bigram" else synthetic_tokens
    return gen(key, part.task_batch, part.seq_len + 1, part.vocab)


def lm_task_batches(part: TaskPartition, C: np.ndarray, step: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Slot-major batches for the TO matrix ``C`` (n, r):
    returns (inputs (r, n, b, S), labels (r, n, b, S)).

    ``C`` may be ragged: slots holding the ``MASKED`` (-1) sentinel get an
    all-zero micro-batch — the straggler train step assigns them zero
    winner weight, so they contribute nothing to the gradient (the worker
    simply has fewer tasks that round)."""
    n, r = C.shape
    assert n == part.n
    # generate each distinct task once, then gather into slots
    uniq = sorted({int(t) for t in C.reshape(-1) if t >= 0})
    toks = {t: task_tokens(part, step, t) for t in uniq}
    dummy = jnp.zeros_like(toks[uniq[0]])           # masked-slot filler
    slots = jnp.stack([jnp.stack([toks[int(C[i, s])] if C[i, s] >= 0
                                  else dummy for i in range(n)])
                       for s in range(r)])          # (r, n, b, S+1)
    return slots[..., :-1], slots[..., 1:]


# ---------------- linear-regression scenario (paper Sec. VI) ----------------

def regression_dataset(key, N: int, d: int, noise: float = 0.1
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paper Sec. VI-C: X ~ N(0,1)^{N x d}; y_i = (x_i + z)^T u."""
    kx, kz, ku = jax.random.split(key, 3)
    X = jax.random.normal(kx, (N, d))
    Z = noise * jax.random.normal(kz, (N, d))
    u = jax.random.uniform(ku, (d,))
    y = (X + Z) @ u
    return X, y, u


def regression_tasks(X: jax.Array, y: jax.Array, n: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Split rows into n equal task shards: (n, N/n, d), (n, N/n)."""
    N, d = X.shape
    b = N // n
    return X[:n * b].reshape(n, b, d), y[:n * b].reshape(n, b)

"""§Perf report: baseline-vs-variant comparison table from tagged dry-run
artifacts.

  PYTHONPATH=src python -m repro.launch.perf_report [--md experiments/perf_table.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PAIRS_HEADER = ("| arch | shape | variant | compute s | memory s | "
                "collective s | max-term s | Δ max-term | arg GB | temp GB |")
SEP = "|" + "---|" * 10


def _load(path):
    with open(path) as f:
        return json.load(f)


def _maxterm(r):
    ro = r["roofline"]
    return max(ro["compute_s"], ro["memory_s"], ro["collective_s"])


def rows(dryrun_dir="experiments/dryrun", mesh="pod"):
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"{mesh}__*.json"))):
        parts = os.path.basename(f).removesuffix(".json").split("__")
        if len(parts) != 4:
            continue
        _, arch, shape, tag = parts
        base_f = os.path.join(dryrun_dir, f"{mesh}__{arch}__{shape}.json")
        if not os.path.exists(base_f):
            continue
        out.append((_load(base_f), _load(f), tag))
    return out


def to_markdown(pairs) -> str:
    lines = [PAIRS_HEADER, SEP]
    for base, var, tag in pairs:
        for r, label in ((base, "baseline"), (var, tag)):
            ro = r["roofline"]
            m = r["memory_analysis"]
            mt = _maxterm(r)
            delta = ""
            if label != "baseline":
                mb = _maxterm(base)
                delta = f"{100 * (mt - mb) / mb:+.1f}%"
            lines.append(
                f"| {r['arch']} | {r['shape']} | {label} "
                f"| {ro['compute_s']:.3e} | {ro['memory_s']:.3e} "
                f"| {ro['collective_s']:.3e} | {mt:.3e} | {delta} "
                f"| {m.get('argument_size_in_bytes', 0) / 1e9:.1f} "
                f"| {m.get('temp_size_in_bytes', 0) / 1e9:.1f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default=None)
    args = ap.parse_args(argv)
    md = to_markdown(rows(args.dir))
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()

"""Racing-planner CLI: find a grid's argmin operating point by
successive-halving with CRN paired elimination (``repro.core.planner``)
instead of streaming the exhaustive grid, and write the versioned
plan-result artifact.

The search space is the same ``GridSpec`` the grid CLI consumes — a JSON
document (``--spec``) or inline axes:

  python -m repro.launch.plan --n 16 --families cs ss ra pc \\
      --loads 2 4 8 16 --messages none 2 --trials 100000 --k 16 \\
      --out out/plan_result.json --emit-config out/round_config.json

``--emit-config`` additionally writes the winning ``RoundConfig`` JSON
when the winner is a TO-matrix family (cs/ss/ra) — feed it straight to
``python -m repro.launch.train --config`` or the live master.  ``--trials``
is the final-rung count, so the reported argmin carries the same
confidence as the exhaustive grid at that budget; the planner typically
spends >= 5x fewer trial-evaluations getting there.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from ..core.grid import FAMILIES, GridSpec
from ..core.planner import plan
from .grid import MODELS, _axis, _build_model


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan",
        description="Race a scheme/load/budget grid to its argmin "
                    "operating point and write a versioned plan-result "
                    "artifact.")
    ap.add_argument("--spec", default=None,
                    help="GridSpec JSON file (overrides the inline axes)")
    ap.add_argument("--n", type=int, default=16, help="cluster size")
    ap.add_argument("--families", nargs="+", default=["cs", "ss", "lb", "pc"],
                    choices=list(FAMILIES), help="scheme families")
    ap.add_argument("--loads", nargs="+", type=int, default=[2],
                    help="computation loads r")
    ap.add_argument("--messages", nargs="+", default=["none"],
                    help="message budgets (int or 'none' = per-task)")
    ap.add_argument("--eps", nargs="+", type=float, default=[0.0],
                    help="per-message comm overheads")
    ap.add_argument("--trials", type=int, default=20000,
                    help="final-rung (= exhaustive-equivalent) trials")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--model", default="scenario1", choices=list(MODELS))
    ap.add_argument("--k", type=int, default=None,
                    help="computation target (default: n)")
    ap.add_argument("--base-trials", type=int, default=None,
                    help="first-rung trials (default trials/eta^3, >= 256)")
    ap.add_argument("--eta", type=int, default=4, help="rung growth factor")
    ap.add_argument("--z", type=float, default=3.0,
                    help="elimination threshold in paired-gap sigmas")
    ap.add_argument("--no-theory-prune", action="store_true",
                    help="skip the closed-form dominance pruning stage")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard trials over the first N local devices")
    ap.add_argument("--emit-config", default=None,
                    help="also write the winning RoundConfig JSON here "
                         "(TO-matrix winners only)")
    ap.add_argument("--out", default="out/plan_result.json",
                    help="artifact path (directories are created)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.spec is not None:
        with open(args.spec) as fh:
            gs = GridSpec.from_json(json.load(fh))
    else:
        gs = GridSpec(n=args.n, families=tuple(args.families),
                      loads=tuple(args.loads),
                      messages=_axis(args.messages, int),
                      comm_eps=tuple(args.eps), ks=(None,),
                      trials=args.trials, seed=args.seed, chunk=args.chunk)
    model = _build_model(args.model, gs.n, gs.seed)
    print(f"plan: racing grid n={gs.n} "
          f"(final rung {gs.trials:,} trials/point, model={args.model})",
          flush=True)

    res = plan(gs, model, k=args.k, base_trials=args.base_trials,
               eta=args.eta, z=args.z,
               theory_prune=not args.no_theory_prune, devices=args.devices)
    res.meta["model"] = args.model
    res.meta["spec"] = gs.to_json()

    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    res.save(args.out)

    m = res.meta
    print(f"done: {m['raced_points']} raced / {m['theory_pruned']} pruned "
          f"/ {m['excluded']} excluded of {m['exhaustive_cells']} cells "
          f"in {m['seconds']:.2f}s")
    print(f"winner: {res.winner} mean {res.predicted_mean:.6g} "
          f"+- {res.predicted_stderr:.2g}")
    if res.lb_gap is not None:
        print(f"vs oracle LB: {res.lb_mean:.6g} (+{100 * res.lb_gap:.1f}%)")
    if m["ties"]:
        print(f"ties within {m['z']} sigma: {', '.join(m['ties'])}")
    print(f"trials: {res.trials_spent:,} spent vs "
          f"{res.exhaustive_trials:,} exhaustive ({res.savings:.1f}x saved)")
    if res.config is not None:
        if args.emit_config:
            cfg_dir = os.path.dirname(args.emit_config)
            if cfg_dir:
                os.makedirs(cfg_dir, exist_ok=True)
            res.config.save(args.emit_config)
            print(f"round config: {args.emit_config}")
    elif res.config_note:
        print(f"round config: none ({res.config_note})")
        if args.emit_config:
            print(f"(--emit-config {args.emit_config} skipped)")
    print(f"artifact: {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

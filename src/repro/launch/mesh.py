"""Production mesh builders (functions, never module-level constants — see
multi-pod dry-run spec: importing this module must not touch jax device
state)."""
from __future__ import annotations

import jax

from ..sharding import MeshCtx


def _axis_types_kwargs(n_axes: int) -> dict:
    """``jax.sharding.AxisType`` only exists in newer jax; older releases
    treat every axis as Auto already, so omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis
    (2 x 16 x 16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_mesh_ctx(*, multi_pod: bool = False) -> MeshCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshCtx(mesh=mesh, data_axes=data_axes, model_axis="model")


def make_local_mesh_ctx(data: int = 1, model: int = 1) -> MeshCtx:
    """Small mesh over however many devices exist (tests)."""
    mesh = jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kwargs(2))
    return MeshCtx(mesh=mesh, data_axes=("data",), model_axis="model")

"""Production training launcher.

Runs straggler-scheduled training of any ``--arch`` (full or ``--smoke``
reduced config) with the paper's CS/SS/RA schedules, round-aware cluster
processes, and optional adaptive row re-assignment. On real hardware the
same entrypoint shards over the production mesh (``--mesh pod|multipod``);
on this CPU container use ``--smoke --mesh local``.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --smoke --steps 20 --n 4 --r 2 --k 3 --schedule ss \
      --cluster markov --persistence 0.95 --spread 3 --adaptive

Record / replay: ``--log-delays PATH`` writes every round's realized
per-(worker, slot) delays to a versioned trace file
(``repro.core.trace``); ``--cluster trace --trace PATH`` drives a later
run from such a recording (or from delay tables recorded by
``sweep_rounds``) instead of a parametric model.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import (AR1Process, AdaptiveScheduler, BimodalStragglerDelays,
                    DelayTrace, FAULT_SCENARIOS, RoundConfig, TraceProcess,
                    ec2_cluster, heterogeneous_scales, load_trace,
                    make_scenario, save_trace, scenario1)
from ..data import TaskPartition, lm_task_batches
from ..models import num_params
from ..optim import adamw, cosine_schedule
from ..sharding import mesh_context
from ..train import init_train_state, make_straggler_train_step
from ..ckpt import save_checkpoint, load_checkpoint, latest_checkpoint
from .mesh import make_mesh_ctx


def derive_seeds(seed: int) -> dict:
    """Deterministically derive every randomness stream of a run from one
    root ``--seed``: independent keys/ints for parameter init, the data
    pipeline, the per-round delay realizations, and schedule construction
    (RA matrices), via ``fold_in`` on the root key.  Same seed -> same
    run; different seeds decorrelate every stream at once."""
    root = jax.random.PRNGKey(seed)

    def _int(i):
        return int(np.asarray(jax.random.fold_in(root, i))[1])

    return {"init_key": jax.random.fold_in(root, 0),
            "delay_root": jax.random.fold_in(root, 1),
            "data_seed": _int(2),
            "schedule_seed": _int(3),
            "cluster_seed": _int(4)}


def build_cluster(args, seeds):
    """The round delay source: an i.i.d. model, a stateful process, or a
    recorded trace replay.  ``--straggle`` layers i.i.d. bimodal slowdowns
    on the base model in the parametric modes (stateful processes add
    their own regime chain on top); ``--scenario`` overlays a named fault
    scenario (spot preemption, partition, ...) on whatever source was
    built."""
    if args.cluster == "trace":
        if not args.trace:
            raise SystemExit("--cluster trace needs --trace PATH "
                             "(a file written by --log-delays or "
                             "repro.core.save_trace)")
        delay = TraceProcess(load_trace(args.trace),
                             pad_rounds=args.trace_pad)
        if getattr(args, "scenario", "none") != "none":
            raise SystemExit("--scenario cannot overlay a trace replay: "
                             "the recording already realized its faults")
        return delay
    base = (BimodalStragglerDelays(p_straggle=0.3, slow=8.0)
            if args.straggle else scenario1())
    if args.cluster == "iid":
        delay = base
    elif args.cluster == "markov":
        delay = ec2_cluster(args.n, spread=args.spread, p_slow=args.p_slow,
                            persistence=args.persistence, slow=args.slow,
                            base=base, seed=seeds["cluster_seed"])
    else:
        delay = AR1Process(base=base,
                           worker_scale=heterogeneous_scales(
                               args.n, args.spread,
                               seed=seeds["cluster_seed"]),
                           rho=args.persistence, sigma=0.4)
    if getattr(args, "scenario", "none") != "none":
        delay = make_scenario(args.scenario, delay, args.n)
    return delay


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Straggler-scheduled training with record/replay "
                    "delay sources.",
        epilog="Determinism: a single --seed derives every randomness "
               "stream (parameter init, data pipeline, per-round delay "
               "realizations, RA schedule construction) via fold_in, so "
               "one integer pins the whole run; --log-delays / --cluster "
               "trace make the delay stream itself recordable and "
               "replayable.")
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="load the round configuration from a serialized "
                         "repro.core.RoundConfig JSON document "
                         "(RoundConfig.save / to_json); overrides --n/--r/"
                         "--k/--schedule/--loads/--adaptive/--deadline/"
                         "--deadline-policy/--dead-after")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--n", type=int, default=4)
    ap.add_argument("--r", type=int, default=2)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--schedule", default="ss", choices=("cs", "ss", "ra",
                                                         "block"))
    ap.add_argument("--adaptive", action="store_true",
                    help="re-assign schedule rows each round from feedback")
    ap.add_argument("--loads", default=None,
                    help="comma-separated per-worker loads (ragged rounds), "
                         "e.g. 3,1,2,3 — each <= r; r is then the grid "
                         "width / load cap")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed; deterministically derives the data, "
                         "delay, and schedule/init keys (fold_in streams "
                         "0..4), so one integer reproduces the whole run")
    ap.add_argument("--straggle", action="store_true",
                    help="layer i.i.d. bimodal slowdowns on the base "
                         "delays (parametric cluster modes)")
    ap.add_argument("--cluster", default="iid",
                    choices=("iid", "markov", "ar1", "trace"),
                    help="round-aware delay process for the virtual "
                         "cluster; 'trace' replays a recorded delay trace "
                         "(--trace PATH)")
    ap.add_argument("--trace", default=None,
                    help="delay-trace file (.npz from --log-delays or "
                         "repro.core.save_trace) for --cluster trace")
    ap.add_argument("--trace-pad", default="error",
                    choices=("error", "cycle", "hold"),
                    help="what to do when --steps exceeds the recorded "
                         "rounds: fail, wrap around, or hold the final "
                         "round")
    ap.add_argument("--log-delays", default=None, metavar="PATH",
                    help="record every round's realized per-(worker, "
                         "slot) compute/comm delays and write them to "
                         "PATH as a versioned delay trace (replayable "
                         "via --cluster trace)")
    ap.add_argument("--scenario", default="none",
                    choices=("none",) + FAULT_SCENARIOS,
                    help="overlay a named fault scenario (workers die / "
                         "partition / drop messages) on the parametric "
                         "cluster modes")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-round wall-clock cap (seconds, virtual); "
                         "under faults a round may otherwise never reach "
                         "k results")
    ap.add_argument("--deadline-policy", default="wait",
                    choices=("wait", "close_partial", "reissue"),
                    help="fallback at the deadline: report+flag the miss, "
                         "close with whatever arrived, or close partial "
                         "and re-gather undelivered tasks next round "
                         "(reissue needs --adaptive)")
    ap.add_argument("--dead-after", type=int, default=None,
                    help="adaptive crash detection: presume a worker dead "
                         "(shed its load) after this many consecutive "
                         "rounds with no delivery")
    ap.add_argument("--persistence", type=float, default=0.9,
                    help="straggler persistence (markov) / AR(1) rho")
    ap.add_argument("--spread", type=float, default=2.0,
                    help="worker speed heterogeneity (geometric spread)")
    ap.add_argument("--p-slow", type=float, default=0.2)
    ap.add_argument("--slow", type=float, default=5.0)
    ap.add_argument("--mesh", default="local",
                    choices=("local", "pod", "multipod"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        if cfg.arch_type == "hybrid":
            cfg = dataclasses.replace(cfg, ssm_period=2, ssm_attn_offset=1)
    if args.mesh == "local":
        ctx = None
    else:
        ctx = make_mesh_ctx(multi_pod=args.mesh == "multipod")
    if cfg.frontend_seq or cfg.encoder_layers:
        raise SystemExit("use text archs for this launcher; whisper/llava "
                         "training is exercised via tests + dryrun")

    if args.log_delays:
        # fail fast on an unwritable destination instead of after the
        # whole run has been spent recording
        out_dir = os.path.dirname(os.path.abspath(args.log_delays))
        os.makedirs(out_dir, exist_ok=True)
        if not os.access(out_dir, os.W_OK):
            raise SystemExit(f"--log-delays: cannot write to {out_dir}")
    seeds = derive_seeds(args.seed)
    # ONE validation path: every round field funnels through RoundConfig
    # (k/r ranges, ragged coverage, deadline/policy pairing, the adaptive-
    # family cross-field rules) whether it came from flags or --config.
    try:
        if args.config:
            rc = RoundConfig.load(args.config)
            args.n, args.k, args.schedule = rc.n, rc.k, rc.kind
            args.r = rc.width
            args.adaptive = rc.adaptive
            args.deadline = rc.deadline
            args.deadline_policy = rc.deadline_policy
            args.dead_after = rc.dead_after
            loads = rc.loads
        else:
            loads = (tuple(int(v) for v in args.loads.split(","))
                     if args.loads else None)
            rc = RoundConfig(
                n=args.n, k=args.k, kind=args.schedule,
                r=args.n if args.schedule == "ra" else args.r, loads=loads,
                deadline=args.deadline, deadline_policy=args.deadline_policy,
                adaptive=args.adaptive, dead_after=args.dead_after,
                seed=seeds["schedule_seed"])
    except ValueError as e:
        raise SystemExit(str(e))
    spec = rc.to_round_spec()
    delay = build_cluster(args, seeds)
    part = TaskPartition(n=args.n, global_batch=args.batch,
                         seq_len=args.seq, vocab=cfg.vocab_size,
                         source="bigram", seed=seeds["data_seed"])
    opt = adamw(cosine_schedule(args.lr, args.steps, warmup=5))

    with mesh_context(ctx):
        state = init_train_state(seeds["init_key"], cfg, opt)
        start = 0
        if args.resume and args.ckpt_dir:
            path = latest_checkpoint(args.ckpt_dir, args.arch)
            if path:
                state = load_checkpoint(path, state)
                start = int(state.step)
                print(f"resumed from {path} at step {start}")
        print(f"{cfg.name}: {num_params(state.params):,} params | "
              f"round n={spec.n} r={spec.r} k={spec.k} {args.schedule}"
              f"{'+adaptive' if args.adaptive else ''}"
              f"{' loads=' + ','.join(map(str, loads)) if loads else ''} | "
              f"cluster {args.cluster}"
              f"{' +' + args.scenario if args.scenario != 'none' else ''}"
              f"{f' deadline={args.deadline:g}/{args.deadline_policy}' if args.deadline is not None else ''}")
        if isinstance(delay, TraceProcess) and start:
            # resumed runs keep their remaining steps aligned with the
            # trace rounds those steps originally consumed
            delay = dataclasses.replace(delay, start_round=start)
        if hasattr(delay, "check_rounds"):
            # fail fast (with the remedy) instead of r rounds into the run
            delay.check_rounds(args.steps - start)
        step_fn = jax.jit(make_straggler_train_step(cfg, opt, spec, delay))
        base_C = spec.to_matrix()
        sched_kw = ({} if args.dead_after is None
                    else {"dead_after": args.dead_after, "target_k": spec.k})
        sched = (AdaptiveScheduler(base_C, **sched_kw)
                 if args.adaptive else None)
        cluster = None
        vclock = 0.0
        missed = 0
        realized_sum = 0.0
        logged_t1, logged_t2 = [], []
        t0 = time.time()
        for i in range(start, args.steps):
            C = base_C if sched is None else sched.matrix()
            row = (None if sched is None
                   else jnp.asarray(sched.row_of_worker()))
            toks, labs = lm_task_batches(part, C, i)
            state, m, cluster = step_fn(
                state, toks, labs,
                jax.random.fold_in(seeds["delay_root"], i), cluster, row)
            if sched is not None:
                sched.observe(np.asarray(m["worker_t1"]))
                if args.deadline_policy == "reissue":
                    # undelivered tasks get re-gather priority next round
                    sched.set_need(~np.asarray(m["delivered_tasks"]))
            if args.log_delays:
                logged_t1.append(np.asarray(m["slot_t1"]))
                logged_t2.append(np.asarray(m["slot_t2"]))
            vclock += float(m["completion_time"])
            missed += int(bool(m["deadline_missed"]))
            realized_sum += float(m["realized_k"])
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  "
                      f"vclock {vclock * 1e3:.2f} ms")
        rounds_run = args.steps - start
        print(f"done: {rounds_run} rounds in "
              f"{time.time() - t0:.1f}s wall, {vclock * 1e3:.2f} ms virtual")
        if args.deadline is not None and rounds_run:
            print(f"deadline {args.deadline:g}s/{args.deadline_policy}: "
                  f"{missed}/{rounds_run} rounds missed, mean realized k "
                  f"{realized_sum / rounds_run:.2f}/{spec.k}")
        if args.log_delays and logged_t1:
            trace = DelayTrace(
                np.stack(logged_t1), np.stack(logged_t2),
                meta={"source": "launch.train", "arch": args.arch,
                      "schedule": args.schedule, "cluster": args.cluster,
                      "n": args.n, "r": spec.r, "k": args.k,
                      "seed": args.seed, "start_step": start,
                      "adaptive": bool(args.adaptive)})
            p = save_trace(args.log_delays, trace)
            print(f"logged {trace.rounds} rounds of delays -> {p} "
                  f"(replay with --cluster trace --trace {p})")
        if args.ckpt_dir:
            p = save_checkpoint(f"{args.ckpt_dir}/{args.arch}", state,
                                step=args.steps)
            print("saved", p)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)) + roofline terms (deliverable (g)).

For every (architecture x input shape x mesh):
  * builds the jitted step (straggler train round / prefill / decode) with
    explicit in_shardings from launch.shardings,
  * ``.lower().compile()`` against ShapeDtypeStruct inputs (no allocation),
  * records ``memory_analysis()`` (fits-per-device proof),
    ``cost_analysis()`` (per-device FLOPs/bytes — XLA reports the
    partitioned per-device module), and the collective-bytes breakdown
    parsed from the compiled HLO,
  * derives the three roofline terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s
    HBM, ~50 GB/s/link ICI) and the MODEL_FLOPS/HLO_FLOPs ratio.

Layer scans are UNROLLED here (cfg.scan_layers=False): XLA's HLO cost
analysis counts while-loop bodies once, so scanned models would under-
report FLOPs by ~n_layers x. The inner SSM *time* scans remain loops —
their in-loop FLOPs (~1% of a layer's projections) are noted as a known
undercount in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod]    # subprocess per combo
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ARCH_IDS, SHAPES, get_config, input_specs, resolve,
                       shape_supported)
from ..core import RoundConfig, scenario1
from ..models import active_params, forward, init_cache, init_params
from ..optim import adamw
from ..sharding import MeshCtx, mesh_context
from ..train import TrainState, init_train_state, make_serve_step, \
    make_straggler_train_step
from .mesh import make_mesh_ctx
from .shardings import (batch_shardings, cache_shardings, params_shardings,
                        zero1_shardings)

VARIANTS = ("zero1", "absorb", "grouped", "batchshard", "puredp",
            "ringdecode")

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_OP_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def collective_bytes(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    compiled HLO. Handles TUPLE-shaped collectives — XLA fuses many
    gradient reductions into one `(f32[..], f32[..], ...) all-reduce` —
    by summing every shape on the LHS. ``-done`` ops are skipped (their
    ``-start`` carries the shape)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_OP_RE.search(line)
        if not m:
            continue
        lhs, op, _start = m.groups()
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            b = _DTYPE_BYTES[dt]
            for d in dims.split(","):
                if d:
                    b *= int(d)
            nbytes += b
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _cfg_for_dryrun(arch: str, shape: str, *, scan_layers: bool = False):
    cfg = resolve(get_config(arch), shape)
    return dataclasses.replace(cfg, scan_layers=scan_layers,
                               remat=SHAPES[shape].kind == "train")


def _probe_layout(cfg):
    """(L1, L2, reps_equiv): probe layer counts for the per-period linear
    cost model F(L) = base + n_periods * per_period (see module docstring).
    """
    from ..models.config import find_period, layer_specs as _ls
    specs = _ls(cfg)
    body = specs[cfg.dense_prefix:]
    p, _ = find_period(body)
    p = min(p, len(body))
    L1 = cfg.dense_prefix + p
    L2 = cfg.dense_prefix + 2 * p
    reps_equiv = (cfg.n_layers - L1) / p
    return L1, L2, reps_equiv


def _replicated(ctx, tree):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(ctx.mesh, P(*([None] * len(l.shape)))), tree)


def build_train(cfg, shape: str, ctx: MeshCtx, *, r: int, k_frac: float,
                schedule: str, zero1: bool = False):
    n = ctx.data_size
    k = max(1, int(round(k_frac * n)))
    spec = RoundConfig(n=n, k=k, kind=schedule, r=r).to_round_spec()
    opt = adamw(1e-4)
    step = make_straggler_train_step(cfg, opt, spec, scenario1(),
                                     scan_slots=False)
    ins = input_specs(cfg, shape, n=n, r=r)
    state_shapes = jax.eval_shape(
        lambda key: init_train_state(key, cfg, opt), jax.random.PRNGKey(0))
    fallbacks: list = []
    psh = params_shardings(state_shapes.params, ctx, fallbacks)
    osh = psh
    if zero1:
        osh = zero1_shardings(state_shapes.params, psh, ctx)
    state_sh = TrainState(
        params=psh,
        opt_state={"step": NamedSharding(ctx.mesh, P()),
                   "m": osh, "v": osh},
        step=NamedSharding(ctx.mesh, P()))
    tok_sh = batch_shardings(
        {"t": ins["slot_tokens"], "l": ins["slot_labels"]}, ctx,
        slot_major=True)
    extras_shapes = {}
    extras_sh = {}
    if "slot_embeds" in ins:
        extras_shapes["embeds"] = ins["slot_embeds"]
    if "slot_frames" in ins:
        extras_shapes["enc_frames"] = ins["slot_frames"]
    if extras_shapes:
        extras_sh = batch_shardings(extras_shapes, ctx, slot_major=True)
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    rng_sh = NamedSharding(ctx.mesh, P(None))

    def fn(state, toks, labs, rng, extras):
        new_state, metrics, _cluster = step(state, toks, labs, rng,
                                            extras=extras or None)
        return new_state, metrics

    jitted = jax.jit(fn, in_shardings=(state_sh, tok_sh["t"], tok_sh["l"],
                                       rng_sh, extras_sh),
                     donate_argnums=(0,))
    args = (state_shapes, ins["slot_tokens"], ins["slot_labels"], rng,
            extras_shapes)
    meta = {"round": dict(n=n, r=r, k=k, schedule=schedule),
            "fallbacks": [str(f) for f in fallbacks]}
    return jitted, args, meta


def build_prefill(cfg, shape: str, ctx: MeshCtx):
    ins = input_specs(cfg, shape)
    fallbacks: list = []
    params_shapes = jax.eval_shape(lambda key: init_params(key, cfg),
                                   jax.random.PRNGKey(0))
    psh = params_shardings(params_shapes, ctx, fallbacks)
    bsh = batch_shardings(ins, ctx)

    def fn(params, batch):
        logits, _, _ = forward(params, cfg, batch["tokens"],
                               embeds=batch.get("embeds"),
                               enc_frames=batch.get("enc_frames"))
        return jnp.argmax(logits[:, -1], axis=-1)

    jitted = jax.jit(fn, in_shardings=(psh, bsh))
    meta = {"fallbacks": [str(f) for f in fallbacks]}
    return jitted, (params_shapes, ins), meta


def build_decode(cfg, shape: str, ctx: MeshCtx):
    sh = SHAPES[shape]
    B, S = sh.global_batch, sh.seq_len
    ins = input_specs(cfg, shape)
    fallbacks: list = []
    params_shapes = jax.eval_shape(lambda key: init_params(key, cfg),
                                   jax.random.PRNGKey(0))
    psh = params_shardings(params_shapes, ctx, fallbacks)
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, S))
    csh = cache_shardings(cache_shapes, ctx, fallbacks)
    tok_sh = batch_shardings(ins, ctx)
    serve = make_serve_step(cfg)

    def fn(params, cache, tokens):
        return serve(params, cache, tokens)

    jitted = jax.jit(fn, in_shardings=(psh, csh, tok_sh["tokens"]),
                     donate_argnums=(1,))
    meta = {"fallbacks": [str(f) for f in fallbacks]}
    return jitted, (params_shapes, cache_shapes, ins["tokens"]), meta


def model_flops_global(cfg, shape: str, *, r: int = 1) -> float:
    """Useful MODEL_FLOPS for the step: 6*N_active*D train (x r redundancy
    excluded — that's the *useful* figure), 2*N*D prefill, 2*N*B decode."""
    sh = SHAPES[shape]
    N = active_params(cfg)
    if sh.kind == "train":
        return 6.0 * N * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * N * sh.global_batch * sh.seq_len
    return 2.0 * N * sh.global_batch


def _build_and_compile(cfg, shape, ctx, *, kind, r, k_frac, schedule,
                       zero1=False):
    t0 = time.time()
    with mesh_context(ctx):
        if kind == "train":
            jitted, args, meta = build_train(cfg, shape, ctx, r=r,
                                             k_frac=k_frac,
                                             schedule=schedule,
                                             zero1=zero1)
        elif kind == "prefill":
            jitted, args, meta = build_prefill(cfg, shape, ctx)
        else:
            jitted, args, meta = build_decode(cfg, shape, ctx)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
               if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    coll = collective_bytes(compiled.as_text())
    return {"flops": flops, "bytes": bytes_acc, "coll": coll, "mem": mem,
            "meta": meta, "t_lower": t_lower, "t_compile": t_compile}


# Probe-extrapolated accounting for deep train/prefill graphs: unrolling 80
# layers is exact but takes tens of minutes of XLA CPU compile per combo.
# Instead: (1) the FULL config is compiled in scan-over-layers mode — this
# is the deployable program and is the compile-proof + memory_analysis
# artifact; (2) two small UNROLLED probes (dense_prefix + 1 period, + 2
# periods) give per-period FLOPs/bytes/collectives exactly, and the linear
# model F(L) = base + n_periods*per_period extrapolates to the full depth.
# Exact for every arch whose depth is an integral number of periods (all
# but gemma3's 4-layer tail, ~2% overcount of its global-attn share).
PROBE_LAYER_THRESHOLD = 16


def run_one(arch: str, shape: str, *, multi_pod: bool, r: int = 1,
            k_frac: float = 1.0, schedule: str = "ss",
            out_dir: str = "experiments/dryrun", tag: str = "",
            exact: bool = False, variant: str = "") -> dict:
    cfg0 = get_config(arch)
    if not shape_supported(cfg0, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "DESIGN.md §5 skip (whisper long_500k)"}
    ctx = make_mesh_ctx(multi_pod=multi_pod)
    n_dev = ctx.mesh.size
    kind = SHAPES[shape].kind
    cfg = _cfg_for_dryrun(arch, shape)
    overrides = {}
    zero1 = False
    for v in filter(None, variant.split(",")):
        if v == "zero1":
            zero1 = True
        elif v == "absorb":
            overrides["mla_absorb"] = True
        elif v == "grouped":
            overrides["grouped_gqa"] = True
        elif v == "batchshard":
            overrides["attn_batch_shard_fallback"] = True
        elif v == "ringdecode":
            overrides["seq_shard_decode"] = True
        elif v == "puredp":
            # tiny-model deployment choice: no tensor-parallel axis — the
            # whole mesh becomes data parallelism (params replicated)
            ctx = MeshCtx(mesh=ctx.mesh,
                          data_axes=tuple(ctx.data_axes) +
                          (ctx.model_axis,),
                          model_axis=None)
        else:
            raise ValueError(f"unknown variant {v!r}; have {VARIANTS}")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if variant and not tag:
        tag = variant.replace(",", "+")
    use_probe = (not exact and kind in ("train", "prefill")
                 and cfg.n_layers > PROBE_LAYER_THRESHOLD)
    bc = dict(kind=kind, r=r, k_frac=k_frac, schedule=schedule,
              zero1=zero1)
    if use_probe:
        # full-config compile proof + memory, scanned (deployable form)
        full = _build_and_compile(
            dataclasses.replace(_cfg_for_dryrun(arch, shape,
                                                scan_layers=True),
                                **overrides),
            shape, ctx, **bc)
        L1, L2, reps_equiv = _probe_layout(cfg)
        p1 = _build_and_compile(
            dataclasses.replace(cfg, n_layers=L1), shape, ctx, **bc)
        p2 = _build_and_compile(
            dataclasses.replace(cfg, n_layers=L2), shape, ctx, **bc)
        flops = p1["flops"] + (p2["flops"] - p1["flops"]) * reps_equiv
        bytes_acc = p1["bytes"] + (p2["bytes"] - p1["bytes"]) * reps_equiv
        coll = {"bytes": {}, "counts": {}, "total_bytes": 0}
        for op in p1["coll"]["bytes"]:
            b = p1["coll"]["bytes"][op] + (p2["coll"]["bytes"][op] -
                                           p1["coll"]["bytes"][op]
                                           ) * reps_equiv
            coll["bytes"][op] = int(max(b, 0))
            coll["counts"][op] = p1["coll"]["counts"][op]
        coll["total_bytes"] = int(sum(coll["bytes"].values()))
        mem = full["mem"]
        meta = full["meta"]
        meta["accounting"] = (f"scan-compile + probe-extrapolated "
                              f"(L1={L1}, L2={L2}, "
                              f"reps_equiv={reps_equiv:.3f})")
        t_lower = full["t_lower"] + p1["t_lower"] + p2["t_lower"]
        t_compile = full["t_compile"] + p1["t_compile"] + p2["t_compile"]
    else:
        res = _build_and_compile(cfg, shape, ctx, **bc)
        flops, bytes_acc = res["flops"], res["bytes"]
        coll, mem, meta = res["coll"], res["mem"], res["meta"]
        meta["accounting"] = "unrolled-exact"
        t_lower, t_compile = res["t_lower"], res["t_compile"]

    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_acc / HBM_BW
    coll_t = coll["total_bytes"] / LINK_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global(cfg, shape, r=r)
    mf_per_dev = mf / n_dev
    result = {
        "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod
        else "16x16", "n_devices": n_dev, "kind": kind,
        "variant": variant or "baseline",
        "round_r": r, "round_k_frac": k_frac,
        "config_name": cfg.name,
        "active_params": active_params(cfg),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collectives": coll,
        "memory_analysis": mem,
        "roofline": {**terms, "dominant": dominant,
                     "model_flops_global": mf,
                     "model_flops_per_device": mf_per_dev,
                     "useful_ratio": (mf_per_dev / flops) if flops else 0.0},
        "meta": meta,
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = (f"{out_dir}/{'multipod' if multi_pod else 'pod'}__"
                 f"{arch}__{shape}{suffix}.json")
        with open(fname, "w") as f:
            json.dump(result, f, indent=1)
        result["file"] = fname
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every supported combo in subprocesses")
    ap.add_argument("--r", type=int, default=1, help="computation load")
    ap.add_argument("--k-frac", type=float, default=1.0,
                    help="computation target as fraction of n")
    ap.add_argument("--schedule", default="ss")
    ap.add_argument("--variant", default="",
                    help="comma list of " + ",".join(VARIANTS))
    ap.add_argument("--exact", action="store_true",
                    help="force unrolled-exact accounting (slow)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if not shape_supported(get_config(arch), shape):
                    print(f"SKIP {arch} {shape} (DESIGN.md §5)")
                    continue
                suffix = f"__{args.tag}" if args.tag else ""
                fname = (f"{args.out_dir}/"
                         f"{'multipod' if args.multi_pod else 'pod'}__"
                         f"{arch}__{shape}{suffix}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"EXISTS {fname}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--r", str(args.r), "--k-frac", str(args.k_frac),
                       "--schedule", args.schedule,
                       "--out-dir", args.out_dir]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.tag:
                    cmd += ["--tag", args.tag]
                print(f"=== {arch} {shape} "
                      f"{'multipod' if args.multi_pod else 'pod'} ===",
                      flush=True)
                rc = subprocess.run(cmd, timeout=args.timeout).returncode
                if rc != 0:
                    failures.append((arch, shape))
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("ALL DRY-RUNS PASSED")
        return

    res = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  r=args.r, k_frac=args.k_frac, schedule=args.schedule,
                  out_dir=args.out_dir, tag=args.tag, exact=args.exact,
                  variant=args.variant)
    print(json.dumps(
        {k: res[k] for k in res if k not in ("meta",)}, indent=1,
        default=str))


if __name__ == "__main__":
    main()

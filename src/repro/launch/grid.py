"""Full-grid sweep CLI: stream a (scheme family × load × message budget ×
comm_eps × k) grid through the bucketed Monte-Carlo executors and write the
versioned grid-result artifact (``repro.core.grid.GridResult``).

The grid comes from a ``GridSpec`` — either a JSON document (``--spec``,
the ``GridSpec.to_json`` format) or inline axes:

  python -m repro.launch.grid --n 16 --families cs ss lb pc \\
      --loads 2 4 8 --messages none 2 4 --trials 1000000 \\
      --out out/grid_result.json

  python -m repro.launch.grid --spec grid.json --model ec2 --devices 4

``--devices N`` shards the trial axis over the first N local devices (the
usual forced-host-mesh ``XLA_FLAGS=--xla_force_host_platform_device_count``
applies); ``--window`` sets how many fused dispatches stay in flight
(2 = double buffering; ``--pipeline`` is a compatibility alias).  The
artifact is consumable by ``GridResult.load`` and feeds the racing
planner (``python -m repro.launch.plan`` finds the same winner without
streaming the whole grid).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from ..core.delays import ec2_like, scenario1, scenario2
from ..core.grid import FAMILIES, GridResult, GridSpec, stream_grid
from ..core.montecarlo import cache_stats

MODELS = ("scenario1", "scenario2", "ec2")


def _build_model(name: str, n: int, seed: int):
    if name == "scenario1":
        return scenario1()
    if name == "scenario2":
        return scenario2(n, seed=seed)
    if name == "ec2":
        return ec2_like(n, seed=seed)
    raise SystemExit(f"unknown --model {name!r}; have {MODELS}")


def _axis(vals, cast):
    """Parse an axis list where the token ``none`` means None."""
    return tuple(None if str(v).lower() == "none" else cast(v) for v in vals)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.grid",
        description="Stream a full scheme/load/budget grid and write a "
                    "versioned grid-result artifact.")
    ap.add_argument("--spec", default=None,
                    help="GridSpec JSON file (overrides the inline axes)")
    ap.add_argument("--n", type=int, default=16, help="cluster size")
    ap.add_argument("--families", nargs="+", default=["cs", "ss", "lb", "pc"],
                    choices=list(FAMILIES), help="scheme families")
    ap.add_argument("--loads", nargs="+", type=int, default=[2],
                    help="computation loads r")
    ap.add_argument("--messages", nargs="+", default=["none"],
                    help="message budgets (int or 'none' = per-task)")
    ap.add_argument("--eps", nargs="+", type=float, default=[0.0],
                    help="per-message comm overheads")
    ap.add_argument("--ks", nargs="+", default=["none"],
                    help="computation targets (int or 'none' = all k)")
    ap.add_argument("--trials", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--model", default="scenario1", choices=list(MODELS))
    ap.add_argument("--devices", type=int, default=None,
                    help="shard trials over the first N local devices")
    ap.add_argument("--window", "--pipeline", dest="window", type=int,
                    default=2,
                    help="streaming window: fused dispatches kept in "
                         "flight (2 = double buffering; --pipeline is an "
                         "alias)")
    ap.add_argument("--k", type=int, default=None,
                    help="computation target for the winner report "
                         "(defaults to each cell's ks, else n)")
    ap.add_argument("--out", default="out/grid_result.json",
                    help="artifact path (directories are created)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.spec is not None:
        with open(args.spec) as fh:
            gs = GridSpec.from_json(json.load(fh))
    else:
        gs = GridSpec(n=args.n, families=tuple(args.families),
                      loads=tuple(args.loads),
                      messages=_axis(args.messages, int),
                      comm_eps=tuple(args.eps), ks=_axis(args.ks, int),
                      trials=args.trials, seed=args.seed, chunk=args.chunk)
    model = _build_model(args.model, gs.n, gs.seed)
    cells = gs.cells(model)
    print(f"grid: {len(cells)} cells (n={gs.n}, trials={gs.trials:,}/cell, "
          f"model={args.model})", flush=True)

    res = stream_grid(cells, devices=args.devices, pipeline=args.window)
    res.meta["model"] = args.model
    res.meta["spec"] = gs.to_json()
    res.meta["window"] = args.window
    res.meta["cache"] = cache_stats()

    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    res.save(args.out)

    m = res.meta
    print(f"done: {m['cells']} cells in {m['seconds']:.2f}s "
          f"({m['cells_per_sec']:.2f} cells/s), "
          f"{m['fused_dispatches']} fused dispatches, "
          f"{m['buckets']} shape bucket(s), window {args.window}")
    try:
        best = res.best_cell(k=args.k)
        tie = f", {len(best['ties'])} tie(s) within 2 sigma" \
            if best["ties"] else ""
        print(f"best: {best['cell']} mean {best['mean']:.6g} "
              f"+- {best['stderr']:.2g}{tie}")
    except ValueError:
        pass        # rounds-only or lb-only grids have no scalar winner
    print(f"artifact: {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

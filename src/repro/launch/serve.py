"""Serving launcher: batched greedy decode with KV cache for any --arch
(``--smoke`` for CPU). Demonstrates prefill -> decode on the public API.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import forward, init_cache, init_params
from ..train import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        if cfg.arch_type == "hybrid":
            cfg = dataclasses.replace(cfg, ssm_period=2, ssm_attn_offset=1)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B = args.batch
    max_len = args.prompt_len + args.gen + 8
    cache = init_cache(cfg, B, max_len)
    prompt = jax.random.randint(key, (B, args.prompt_len), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.encoder_layers:
        kwargs["enc_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.frontend_dim))

    t0 = time.time()
    logits, _, cache = forward(params, cfg, prompt, cache=cache, **kwargs)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    serve = jax.jit(make_serve_step(cfg))
    out = [nxt]
    t0 = time.time()
    for _ in range(args.gen - 1):
        nxt, cache = serve(params, cache, nxt)
        out.append(nxt)
    t_dec = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"{cfg.name}: prefill {args.prompt_len} tok in "
          f"{t_prefill * 1e3:.1f} ms; {args.gen - 1} decode steps in "
          f"{t_dec * 1e3:.1f} ms ({(args.gen - 1) * B / max(t_dec, 1e-9):.1f}"
          f" tok/s batch={B})")
    for b in range(min(B, 2)):
        print(f"  req{b}: {toks[b, :16].tolist()}...")


if __name__ == "__main__":
    main()

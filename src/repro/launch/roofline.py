"""Roofline aggregation (deliverable (g)): read the dry-run JSON artifacts
and emit the per-(arch x shape x mesh) table as markdown for EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
      [--md experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

HEADER = ("| arch | shape | mesh | compute s | memory s | collective s | "
          "dominant | HLO GFLOPs/dev | model GFLOPs/dev | useful | "
          "bottleneck note |")
SEP = "|" + "---|" * 11


def _note(r) -> str:
    ro = r["roofline"]
    dom = ro["dominant"]
    fb = r.get("meta", {}).get("fallbacks", [])
    bits = []
    if dom == "memory_s":
        bits.append("HBM-traffic bound")
    elif dom == "collective_s":
        bits.append("ICI bound")
    else:
        bits.append("MXU bound")
    if any("col" in f or "row" in f for f in fb):
        bits.append(f"{len(fb)} replication fallbacks")
    if any("kv-seq" in f for f in fb):
        bits.append("seq-parallel KV cache")
    return "; ".join(bits)


def rows(dryrun_dir: str, mesh_filter=None):
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        base = os.path.basename(f)
        if base.count("__") > 2:      # tagged variant (perf iteration)
            continue
        with open(f) as fh:
            r = json.load(fh)
        if r.get("skipped"):
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        out.append(r)
    return out


def to_markdown(rs) -> str:
    lines = [HEADER, SEP]
    for r in sorted(rs, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['compute_s']:.3e} | {ro['memory_s']:.3e} "
            f"| {ro['collective_s']:.3e} "
            f"| **{ro['dominant'].removesuffix('_s')}** "
            f"| {r['flops_per_device'] / 1e9:.1f} "
            f"| {ro['model_flops_per_device'] / 1e9:.1f} "
            f"| {ro['useful_ratio']:.2f} | {_note(r)} |")
    return "\n".join(lines)


def summarize(rs) -> dict:
    dom = defaultdict(int)
    for r in rs:
        dom[r["roofline"]["dominant"]] += 1
    return dict(dom)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)
    rs = rows(args.dir, args.mesh)
    md = to_markdown(rs)
    print(md)
    print("\ndominant-term counts:", summarize(rs))
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()

"""Rule-based parameter / cache / batch shardings with divisibility fallback
(DESIGN.md §4).

Params follow the Megatron tensor-parallel pattern on the ``model`` axis:
column-parallel in-projections, row-parallel out-projections, vocab-parallel
embeddings, expert-parallel MoE weight stacks. Any dim not divisible by the
axis size is left replicated and the fallback is recorded for the roofline
report.

Decode caches: batch on the data axes; KV-head dim on ``model`` when
divisible, else the sequence dim (sequence-parallel cache — how 32k/500k
caches fit when kv-heads < axis size).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..sharding import MeshCtx

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def param_spec(path: str, shape: Tuple[int, ...], ctx: MeshCtx,
               fallbacks: Optional[List] = None) -> P:
    """PartitionSpec for one parameter leaf (local shapes, no leading rep
    axis — caller offsets for stacked segments)."""
    m = ctx.model_axis
    ms = ctx.model_size
    nd = len(shape)

    def col(io=-1):
        """shard output (last) dim."""
        if _div(shape[io], ms):
            sp = [None] * nd
            sp[io] = m
            return P(*sp)
        if fallbacks is not None:
            fallbacks.append((path, shape, "col"))
        return P(*([None] * nd))

    def row(io=0):
        if _div(shape[io], ms):
            sp = [None] * nd
            sp[io] = m
            return P(*sp)
        if fallbacks is not None:
            fallbacks.append((path, shape, "row"))
        return P(*([None] * nd))

    leaf = path.rsplit("/", 1)[-1]

    if path.endswith("embed") or leaf == "pos_embed":
        return col(0) if "embed" == leaf.split("/")[-1] and nd == 2 else col(0)
    if "lm_head" in path:
        return col(-1) if leaf == "w" else col(0)
    # MoE expert stacks (E, d, f)/(E, f, d): expert-parallel on E
    if nd == 3 and ("w_gate" in path or "w_up" in path or "w_down" in path):
        return row(0)
    if "router" in path:
        return P(*([None] * nd))
    # attention / mla / general projections
    if leaf == "w":
        if any(k in path for k in ("wq/", "wk/", "wv/", "w_uq", "w_uk",
                                   "w_gate", "w_up", "w_k/", "w_r/",
                                   "w_v/", "w_g/", "in_proj", "w_lora_a",
                                   "dt_proj")):
            return col(-1)
        if any(k in path for k in ("wo/", "w_down", "out_proj", "w_o/",
                                   "w_lora_b", "x_proj")):
            return row(0)
        if any(k in path for k in ("w_dq", "w_dkv", "w_kr", "frontend")):
            return P(*([None] * nd))
        return P(*([None] * nd))
    if leaf == "b":
        if any(k in path for k in ("wq/", "wk/", "wv/", "in_proj",
                                   "dt_proj")):
            return col(0) if nd == 1 else P(*([None] * nd))
        return P(*([None] * nd))
    # mamba internals sharded on d_inner
    if leaf in ("conv_w", "conv_b", "A_log", "D"):
        io = 0 if leaf in ("conv_b", "A_log", "D") else 1
        return col(io) if leaf != "conv_w" else col(1)
    # rwkv head-structured leaves (H, dh)
    if leaf == "u" or "ln_out" in path:
        return row(0)
    return P(*([None] * nd))


def params_shardings(param_specs: PyTree, ctx: MeshCtx,
                     fallbacks: Optional[List] = None) -> PyTree:
    """NamedSharding pytree for the model params (abstract or concrete).
    Leaves under stacked segment/encoder containers get a leading None for
    the rep axis."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_specs)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = ps.startswith("segments") or "blocks" in ps
        if stacked and len(shape) >= 1:
            inner = param_spec(ps, shape[1:], ctx, fallbacks)
            spec = P(*((None,) + tuple(inner)))
        else:
            spec = param_spec(ps, shape, ctx, fallbacks)
        out.append(NamedSharding(ctx.mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _data_spec_entry(ctx: MeshCtx):
    return ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]


def zero1_shardings(shapes: PyTree, base: PyTree, ctx: MeshCtx) -> PyTree:
    """ZeRO-1 (§Perf): optimizer-state leaves additionally shard their
    first still-unsharded divisible dim over the DATA axes (the state is
    only touched at the optimizer step, so the gather cost is one
    reduce-scatter/all-gather pair per step — the memory win is
    data_size x)."""
    d = _data_spec_entry(ctx)
    ds = ctx.data_size

    def one(shape_leaf, sh):
        nd = len(shape_leaf.shape)
        spec = list(sh.spec) + [None] * (nd - len(sh.spec))
        for i, dim in enumerate(shape_leaf.shape):
            if spec[i] is None and dim % ds == 0 and dim >= ds:
                spec[i] = d
                return NamedSharding(ctx.mesh, P(*spec))
        return sh

    return jax.tree_util.tree_map(one, shapes, base)


def batch_shardings(batch_specs: PyTree, ctx: MeshCtx, *,
                    slot_major: bool = False) -> PyTree:
    """Inputs: shard the batch dim over the data axes. Slot-major straggler
    batches (r, n, b, ...) shard the WORKER dim (axis 1) — the n logical
    workers are the data-parallel shard groups."""
    d = _data_spec_entry(ctx)
    dsize = ctx.data_size

    def one(leaf):
        shape = tuple(leaf.shape)
        if slot_major:
            if len(shape) >= 2 and shape[1] % dsize == 0:
                return NamedSharding(ctx.mesh,
                                     P(*((None, d) + (None,) *
                                         (len(shape) - 2))))
            return NamedSharding(ctx.mesh, P(*([None] * len(shape))))
        if shape and shape[0] % dsize == 0:
            return NamedSharding(ctx.mesh,
                                 P(*((d,) + (None,) * (len(shape) - 1))))
        return NamedSharding(ctx.mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map(one, batch_specs)


def cache_shardings(cache_specs: PyTree, ctx: MeshCtx,
                    fallbacks: Optional[List] = None) -> PyTree:
    """Decode caches. Leaves are stacked (reps, ...) under segments.
    Heuristic per leaf kind (after the rep axis):
      k/v   (B, K, S, dh): B->data; K->model if divisible else S->model
      c_kv  (B, S, R) / k_rope (B, S, rd): B->data; S->model (if divisible)
      ssm h (B, di, N): B->data, di->model; conv (B, w, di): di->model
      rwkv S (B, H, dh, dh): B->data, H->model
      xk/xv (B, H, T, dh): B->data, H->model
      scalars (pos): replicated
    If B is not divisible by the data size (e.g. batch 1), the sequence dim
    is sharded over (data x model) when possible.
    """
    d = _data_spec_entry(ctx)
    dsize, msize = ctx.data_size, ctx.model_size
    m = ctx.model_axis
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = ps.startswith("segments")
        inner = shape[1:] if stacked else shape
        leafname = ps.rsplit("/", 1)[-1]
        spec: list = [None] * len(inner)
        if len(inner) == 0:
            out.append(NamedSharding(ctx.mesh, P(*([None] * len(shape)))))
            continue
        B = inner[0]
        b_ok = B % dsize == 0
        if b_ok:
            spec[0] = d
        if leafname in ("k", "v", "xk", "xv") and len(inner) == 4:
            K, S = inner[1], inner[2]
            if K % msize == 0:
                spec[1] = m
            elif S % msize == 0:
                spec[2] = m
                if fallbacks is not None:
                    fallbacks.append((ps, shape, "kv-seq-parallel"))
            if not b_ok and S % (dsize * msize) == 0 and spec[2] is None:
                spec[2] = (d, m) if isinstance(d, str) else tuple(
                    list(d if isinstance(d, tuple) else (d,)) + [m])
            elif not b_ok and spec[2] == m and S % (dsize * msize) == 0:
                spec[2] = tuple((list(d) if isinstance(d, tuple) else [d])
                                + [m])
        elif leafname in ("c_kv", "k_rope") and len(inner) == 3:
            S = inner[1]
            if b_ok and S % msize == 0:
                spec[1] = m
            elif not b_ok and S % (dsize * msize) == 0:
                spec[1] = tuple((list(d) if isinstance(d, tuple) else [d])
                                + [m])
            elif S % msize == 0:
                spec[1] = m
        elif leafname == "h" and len(inner) == 3:
            if inner[1] % msize == 0:
                spec[1] = m
        elif leafname == "conv" and len(inner) == 3:
            if inner[2] % msize == 0:
                spec[2] = m
        elif leafname == "S" and len(inner) == 4:
            if inner[1] % msize == 0:
                spec[1] = m
        full = P(*(((None,) if stacked else ()) + tuple(spec)))
        out.append(NamedSharding(ctx.mesh, full))
    return jax.tree_util.tree_unflatten(treedef, out)

"""Live cluster launcher: a real async master + workers over inproc/TCP.

Three subcommands, all driven by ONE serialized ``RoundConfig`` document
(``RoundConfig.save("round.json")``):

  # single-process demo cluster (master + n in-process workers)
  PYTHONPATH=src python -m repro.launch.live local \
      --config round.json --rounds 20 --cluster markov --save-trace run.npz

  # distributed: master listens, workers connect (one per machine)
  PYTHONPATH=src python -m repro.launch.live master \
      --config round.json --rounds 50 --listen tcp://0.0.0.0:5555
  PYTHONPATH=src python -m repro.launch.live worker \
      --config round.json --connect tcp://master-host:5555 --cluster markov

Workers draw their virtual delays from the same shared-seed tables the MC
engine would (the config's ``seed``), so the recorded trace replays
bit-exactly through ``sweep_rounds`` — the live run IS a realization of
the simulated process.  ``--time-scale`` maps virtual delay units to wall
seconds (0 = as fast as possible); ``--no-abort`` makes workers finish
every round even after it closed (dense tables for analysis).
"""
from __future__ import annotations

import argparse
import asyncio
import json

from ..core import FAULT_SCENARIOS, RoundConfig, save_trace
from ..live import Master, listen, run_live, run_worker
from .train import build_cluster, derive_seeds


def _add_cluster_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--cluster", default="iid",
                    choices=("iid", "markov", "ar1", "trace"))
    ap.add_argument("--trace", default=None,
                    help="delay-trace file for --cluster trace")
    ap.add_argument("--trace-pad", default="error",
                    choices=("error", "cycle", "hold"))
    ap.add_argument("--straggle", action="store_true")
    ap.add_argument("--scenario", default="none",
                    choices=("none",) + FAULT_SCENARIOS)
    ap.add_argument("--persistence", type=float, default=0.9)
    ap.add_argument("--spread", type=float, default=2.0)
    ap.add_argument("--p-slow", type=float, default=0.2)
    ap.add_argument("--slow", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed for the cluster-process construction "
                         "streams (the delay draws themselves come from "
                         "the config's seed)")


def _add_run_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--config", required=True, metavar="PATH",
                    help="serialized repro.core.RoundConfig JSON document")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--time-scale", type=float, default=0.0,
                    help="wall seconds per virtual delay unit (0 = run as "
                         "fast as possible)")
    ap.add_argument("--no-abort", action="store_true",
                    help="workers finish every round even after it closes "
                         "(dense recorded tables)")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the recorded delay trace (.npz)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write a JSON run summary")


def _process_for(args, cfg: RoundConfig):
    ns = argparse.Namespace(**vars(args))
    ns.n = cfg.n
    return build_cluster(ns, derive_seeds(args.seed))


def _finish(result, args) -> None:
    print(f"rounds={len(result.per_round)} mean={result.mean:.6g} "
          f"missed={int(result.missed.sum())} "
          f"realized_k={result.realized.mean():.3g} "
          f"trace={result.trace!r}")
    if args.save_trace:
        path = save_trace(args.save_trace, result.trace)
        print(f"trace -> {path}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"config": result.config.to_dict(),
                       "per_round": result.per_round.tolist(),
                       "realized": result.realized.tolist(),
                       "missed": result.missed.astype(int).tolist(),
                       "mean": result.mean,
                       "trace_digest": result.trace.header()["digest"]},
                      f, indent=2)
        print(f"summary -> {args.out}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Live async master-worker round execution.")
    sub = ap.add_subparsers(dest="mode", required=True)

    ap_local = sub.add_parser("local", help="master + n in-process workers")
    _add_run_args(ap_local)
    _add_cluster_args(ap_local)
    ap_local.add_argument("--address", default=None,
                          help="optional explicit address (e.g. "
                               "tcp://127.0.0.1:0 to exercise TCP)")

    ap_master = sub.add_parser("master", help="listen and drive rounds")
    _add_run_args(ap_master)
    ap_master.add_argument("--listen", required=True, metavar="ADDRESS",
                           help="e.g. tcp://0.0.0.0:5555")

    ap_worker = sub.add_parser("worker", help="connect and serve rounds")
    ap_worker.add_argument("--config", required=True, metavar="PATH",
                           help="the same RoundConfig document the master "
                                "uses (drives the shared-seed delay draws)")
    ap_worker.add_argument("--connect", required=True, metavar="ADDRESS",
                           help="master address, e.g. tcp://host:5555")
    _add_cluster_args(ap_worker)

    args = ap.parse_args(argv)
    try:
        cfg = RoundConfig.load(args.config)
    except ValueError as e:
        raise SystemExit(str(e))
    # delays are drawn by the WORKERS (shared-seed tables); the master
    # only scores what arrives, so it needs no cluster model at all
    process = None if args.mode == "master" else _process_for(args, cfg)

    if args.mode == "local":
        result = run_live(cfg, process, args.rounds, address=args.address,
                          time_scale=args.time_scale,
                          abort_on_close=not args.no_abort)
        _finish(result, args)
    elif args.mode == "master":
        async def _serve():
            listener = await listen(args.listen)
            print(f"listening on {listener.address} for {cfg.n} workers")
            try:
                master = Master(cfg, rounds=args.rounds, listener=listener,
                                time_scale=args.time_scale,
                                abort_on_close=not args.no_abort)
                return await master.run()
            finally:
                await listener.aclose()
        result = asyncio.run(_serve())
        _finish(result, args)
    else:
        asyncio.run(run_worker(args.connect, process))
        print("worker done")


if __name__ == "__main__":
    main()

from .config import ModelConfig, LayerSpec, layer_specs, find_period
from .model import (init_params, forward, encode, init_cache, plan_segments,
                    num_params, active_params, Segment)

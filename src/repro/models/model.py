"""Model assembly: periodic scan-over-layers core + unrolled tail segments.

The per-layer spec list (config.layer_specs) is compressed into segments
(config.find_period): the periodic core is applied with ``lax.scan`` over
stacked params (HLO stays small for 80-layer models); any tail is split into
runs of identical specs, each its own scanned stack.

Param pytree:
  {"embed": (V_pad, d), "segments": [seg_params...], "final_norm": ...,
   "lm_head": {...}, optional "pos_embed", "frontend_proj", "encoder": {...}}
Cache pytree mirrors the segment structure plus a global "pos" scalar.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import DATA, MODEL, shard
from . import layers as L
from .config import LayerSpec, ModelConfig, find_period, layer_specs

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Segment:
    specs: Tuple[LayerSpec, ...]   # one period of layer specs
    reps: int                      # scan length


def _run_segments(specs) -> List[Segment]:
    out: List[Segment] = []
    i = 0
    while i < len(specs):
        j = i
        while j < len(specs) and specs[j] == specs[i]:
            j += 1
        out.append(Segment((specs[i],), j - i))
        i = j
    return out


def plan_segments(cfg: ModelConfig) -> Tuple[Segment, ...]:
    """Periodic core + run-length tail; falls back to pure run-length
    segmentation when that yields fewer distinct layer bodies (e.g.
    DeepSeek's 3-dense-prefix + 58-MoE stack)."""
    specs = layer_specs(cfg)
    p, reps = find_period(specs)
    periodic: List[Segment] = [Segment(specs[:p], reps)]
    periodic += _run_segments(list(specs[p * reps:]))
    runs = _run_segments(list(specs))
    cost_p = sum(len(s.specs) for s in periodic)
    cost_r = sum(len(s.specs) for s in runs)
    return tuple(runs) if cost_r < cost_p else tuple(periodic)


# ---------------------------------------------------------------------------
# single transformer block
# ---------------------------------------------------------------------------

def _mixer_init(key, cfg: ModelConfig, spec: LayerSpec) -> PyTree:
    if spec.mixer in ("gqa", "swa"):
        return L.gqa_init(key, cfg)
    if spec.mixer == "mla":
        return L.mla_init(key, cfg)
    if spec.mixer == "mamba":
        return L.mamba_init(key, cfg)
    if spec.mixer == "rwkv6":
        return L.rwkv6_init(key, cfg)
    raise ValueError(spec.mixer)


def _ffn_init(key, cfg: ModelConfig, spec: LayerSpec) -> PyTree:
    if spec.ffn == "swiglu":
        return L.swiglu_init(key, cfg)
    if spec.ffn == "gelu":
        return L.gelu_mlp_init(key, cfg)
    if spec.ffn == "cmix":
        return L.cmix_init(key, cfg)
    if spec.ffn == "moe":
        return L.moe_init(key, cfg)
    raise ValueError(spec.ffn)


def block_init(key, cfg: ModelConfig, spec: LayerSpec) -> PyTree:
    ks = jax.random.split(key, 4)
    p = {"norm1": L.norm_init(cfg), "mixer": _mixer_init(ks[0], cfg, spec),
         "norm2": L.norm_init(cfg), "ffn": _ffn_init(ks[1], cfg, spec)}
    if spec.cross_attn:
        p["norm_x"] = L.norm_init(cfg)
        p["xattn"] = L.gqa_init(ks[2], cfg)
    return p


def block_apply(p: PyTree, cfg: ModelConfig, spec: LayerSpec, x: Array, *,
                positions: Array, cache: Optional[PyTree] = None,
                causal: bool = True, use_rope: bool = True,
                enc_out: Optional[Array] = None
                ) -> Tuple[Array, Optional[PyTree], Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    h = L.norm(cfg, p["norm1"], x)
    if spec.mixer in ("gqa", "swa"):
        window = cfg.sliding_window if spec.mixer == "swa" else None
        mc = None if cache is None else cache["attn"]
        h, mc_new = L.gqa_apply(p["mixer"], cfg, h, window=window,
                                positions=positions, cache=mc,
                                use_rope=use_rope, causal=causal)
        if new_cache is not None:
            new_cache["attn"] = mc_new
    elif spec.mixer == "mla":
        mc = None if cache is None else cache["attn"]
        h, mc_new = L.mla_apply(p["mixer"], cfg, h, positions=positions,
                                cache=mc)
        if new_cache is not None:
            new_cache["attn"] = mc_new
    elif spec.mixer == "mamba":
        st = None if cache is None else cache["ssm"]
        h, st_new = L.mamba_apply(p["mixer"], cfg, h, state=st)
        if new_cache is not None:
            new_cache["ssm"] = st_new
    elif spec.mixer == "rwkv6":
        st = None if cache is None else cache["ssm"]
        h, st_new = L.rwkv6_apply(p["mixer"], cfg, h, state=st)
        if new_cache is not None:
            new_cache["ssm"] = st_new
    else:
        raise ValueError(spec.mixer)
    x = x + h

    if spec.cross_attn:
        hx = L.norm(cfg, p["norm_x"], x)
        if enc_out is not None:
            K, dh, B = cfg.n_kv_heads, cfg.head_dim, x.shape[0]
            pa = p["xattn"]
            xk = L.dense(pa["wk"], enc_out).reshape(
                B, -1, K, dh).transpose(0, 2, 1, 3)
            xv = L.dense(pa["wv"], enc_out).reshape(
                B, -1, K, dh).transpose(0, 2, 1, 3)
            xk = L.repeat_kv(xk, cfg.n_heads // K)
            xv = L.repeat_kv(xv, cfg.n_heads // K)
            if new_cache is not None:
                new_cache["xk"], new_cache["xv"] = xk, xv
        elif cache is not None:
            xk, xv = cache["xk"], cache["xv"]
        else:
            raise ValueError("cross-attention needs enc_out or cached KV")
        hx, _ = L.gqa_apply(p["xattn"], cfg, hx, xattn_kv=(xk, xv),
                            use_rope=False)
        x = x + hx

    h = L.norm(cfg, p["norm2"], x)
    if spec.ffn == "swiglu":
        h = L.swiglu_apply(p["ffn"], h)
    elif spec.ffn == "gelu":
        h = L.gelu_mlp_apply(p["ffn"], h)
    elif spec.ffn == "cmix":
        prev = None if cache is None else cache["cmix_prev"]
        h, last = L.cmix_apply(p["ffn"], h, prev=prev)
        if new_cache is not None:
            new_cache["cmix_prev"] = last
    elif spec.ffn == "moe":
        h, aux = L.moe_apply(p["ffn"], cfg, h)
    x = x + h
    return x, new_cache, aux


def block_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int) -> PyTree:
    c: dict = {}
    if spec.mixer in ("gqa", "swa"):
        c["attn"] = L.gqa_cache_init(
            cfg, batch, max_len,
            window=cfg.sliding_window if spec.mixer == "swa" else None)
    elif spec.mixer == "mla":
        c["attn"] = L.mla_cache_init(cfg, batch, max_len)
    elif spec.mixer == "mamba":
        c["ssm"] = L.mamba_state_init(cfg, batch)
    elif spec.mixer == "rwkv6":
        c["ssm"] = L.rwkv6_state_init(cfg, batch)
    if spec.ffn == "cmix":
        c["cmix_prev"] = jnp.zeros((batch, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    if spec.cross_attn:
        dh = cfg.head_dim
        c["xk"] = jnp.zeros((batch, cfg.n_heads, cfg.encoder_seq, dh),
                            jnp.dtype(cfg.dtype))
        c["xv"] = jnp.zeros((batch, cfg.n_heads, cfg.encoder_seq, dh),
                            jnp.dtype(cfg.dtype))
    return c


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-math.log(1e4) / d))
    pe = jnp.zeros((seq, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def init_params(key, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, 8)
    V = cfg.padded_vocab
    pdt = jnp.dtype(cfg.param_dtype)
    emb_scale = 1.0 / math.sqrt(cfg.d_model)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (V, cfg.d_model), jnp.float32)
                  * emb_scale).astype(pdt),
        "final_norm": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, V, dtype=pdt)
    segs = plan_segments(cfg)
    seg_params = []
    kseg = jax.random.split(keys[2], len(segs))
    for seg, ks in zip(segs, kseg):
        def one_rep(k):
            kk = jax.random.split(k, len(seg.specs))
            return tuple(block_init(kk[j], cfg, seg.specs[j])
                         for j in range(len(seg.specs)))
        reps_keys = jax.random.split(ks, seg.reps)
        seg_params.append(jax.vmap(one_rep)(reps_keys))
    params["segments"] = seg_params

    if cfg.arch_type == "audio":
        params["pos_embed"] = (jax.random.normal(
            keys[3], (cfg.max_seq_len, cfg.d_model), jnp.float32) * 0.01
        ).astype(pdt)
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(
            keys[4], cfg.frontend_dim, cfg.d_model, bias=True, dtype=pdt)
    if cfg.encoder_layers:
        enc_spec = LayerSpec(mixer="gqa", ffn="gelu", cross_attn=False)
        def enc_rep(k):
            return (block_init(k, cfg, enc_spec),)
        ek = jax.random.split(keys[5], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(enc_rep)(ek),
            "final_norm": L.norm_init(cfg),
        }
    return params


def _apply_segments(params: PyTree, cfg: ModelConfig, x: Array, *,
                    positions: Array, cache: Optional[PyTree],
                    causal: bool = True, use_rope: bool = True,
                    enc_out: Optional[Array] = None
                    ) -> Tuple[Array, Optional[PyTree], Array]:
    segs = plan_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_seg_caches: list = []
    for si, (seg, sp) in enumerate(zip(segs, params["segments"])):
        seg_cache = None if cache is None else cache["segments"][si]

        if seg_cache is None:
            def body(xc, p_rep, _seg=seg):
                aux_rep = jnp.zeros((), jnp.float32)
                for j, spec in enumerate(_seg.specs):
                    xc, _, aux_j = block_apply(
                        p_rep[j], cfg, spec, xc, positions=positions,
                        cache=None, causal=causal, use_rope=use_rope,
                        enc_out=enc_out)
                    aux_rep = aux_rep + aux_j
                return xc, aux_rep

            body_fn = jax.checkpoint(body) if cfg.remat else body
            if cfg.scan_layers:
                x, auxs = lax.scan(body_fn, x, sp)
            else:
                aux_list = []
                for rep_i in range(seg.reps):
                    p_i = jax.tree_util.tree_map(lambda a: a[rep_i], sp)
                    x, a_i = body_fn(x, p_i)
                    aux_list.append(a_i)
                auxs = jnp.stack(aux_list)
        else:
            def body_c(xc, rep, _seg=seg):
                p_rep, c_rep = rep
                aux_rep = jnp.zeros((), jnp.float32)
                new_c = []
                for j, spec in enumerate(_seg.specs):
                    xc, cj_new, aux_j = block_apply(
                        p_rep[j], cfg, spec, xc, positions=positions,
                        cache=c_rep[j], causal=causal, use_rope=use_rope,
                        enc_out=enc_out)
                    aux_rep = aux_rep + aux_j
                    new_c.append(cj_new)
                return xc, (tuple(new_c), aux_rep)

            body_fn = jax.checkpoint(body_c) if cfg.remat else body_c
            if cfg.scan_layers:
                x, (new_c, auxs) = lax.scan(body_fn, x, (sp, seg_cache))
            else:
                nc_list, aux_list = [], []
                for rep_i in range(seg.reps):
                    rep = jax.tree_util.tree_map(lambda a: a[rep_i],
                                                 (sp, seg_cache))
                    x, (nc_i, a_i) = body_fn(x, rep)
                    nc_list.append(nc_i)
                    aux_list.append(a_i)
                new_c = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *nc_list)
                auxs = jnp.stack(aux_list)
            new_seg_caches.append(new_c)
        aux_total = aux_total + auxs.sum()
    new_cache = None if cache is None else {"segments": new_seg_caches,
                                            "pos": cache["pos"] +
                                            x.shape[1]}
    return x, new_cache, aux_total


def encode(params: PyTree, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper-style encoder over precomputed frame embeddings
    (B, T_enc, frontend_dim) -> (B, T_enc, d)."""
    x = L.dense(params["frontend_proj"], frames.astype(jnp.dtype(cfg.dtype)))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    enc_spec = LayerSpec(mixer="gqa", ffn="gelu", cross_attn=False)

    def body(xc, p_rep):
        out, _, _ = block_apply(p_rep[0], cfg, enc_spec, xc,
                                positions=jnp.arange(xc.shape[1])[None],
                                causal=False, use_rope=False)
        return out, None

    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["encoder"]["blocks"])
    else:
        for rep_i in range(cfg.encoder_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[rep_i],
                                         params["encoder"]["blocks"])
            x, _ = body(x, p_i)
    return L.norm(cfg, params["encoder"]["final_norm"], x)


def forward(params: PyTree, cfg: ModelConfig, tokens: Array, *,
            embeds: Optional[Array] = None,
            enc_frames: Optional[Array] = None,
            cache: Optional[PyTree] = None
            ) -> Tuple[Array, Array, Optional[PyTree]]:
    """Returns (logits (B, T, V_pad), aux_loss, new_cache).

    tokens (B, T_txt); ``embeds`` (B, P, frontend_dim) stub modality tokens
    prepended (VLM / early fusion); ``enc_frames`` triggers the encoder and
    requires cross-attention layers (whisper) — its KV is (re)computed and
    stored in the cache when one is provided.
    """
    dt = jnp.dtype(cfg.dtype)
    B, Tt = tokens.shape
    x = params["embed"][tokens].astype(dt)
    x = shard(x, DATA, None, None, note="embed")
    if embeds is not None:
        pe = L.dense(params["frontend_proj"], embeds.astype(dt))
        x = jnp.concatenate([pe, x], axis=1)
    T = x.shape[1]
    pos0 = cache["pos"] if cache is not None else 0
    positions = pos0 + jnp.arange(T)[None, :]
    if cfg.arch_type == "audio":
        pe = lax.dynamic_slice_in_dim(params["pos_embed"], pos0, T, 0) \
            if cache is not None else params["pos_embed"][:T]
        x = x + pe.astype(dt)[None]

    enc_out = None
    if enc_frames is not None and cfg.encoder_layers:
        enc_out = encode(params, cfg, enc_frames)

    use_rope = cfg.arch_type != "audio"
    x, new_cache, aux = _apply_segments(params, cfg, x, positions=positions,
                                        cache=cache, causal=True,
                                        use_rope=use_rope, enc_out=enc_out)
    x = L.norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(dt).T
    else:
        logits = L.dense(params["lm_head"], x)
    logits = shard(logits, DATA, None, MODEL, note="logits")
    # mask padded vocab tail
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.asarray(-1e9, logits.dtype)
        mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(mask[None, None, :], neg, logits)
    return logits, aux, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    segs = plan_segments(cfg)
    seg_caches = []
    for seg in segs:
        one = tuple(block_cache_init(cfg, spec, batch, max_len)
                    for spec in seg.specs)
        seg_caches.append(jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (seg.reps,) + l.shape), one))
    return {"segments": seg_caches, "pos": jnp.zeros((), jnp.int32)}


def num_params(params: PyTree) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))


def active_params(cfg: ModelConfig) -> int:
    """Approximate ACTIVE parameter count (MoE counts only routed-in
    experts) — used for MODEL_FLOPS = 6*N_active*D in the roofline."""
    d, V = cfg.d_model, cfg.padded_vocab
    specs = layer_specs(cfg)
    total = V * d * (1 if cfg.tie_embeddings else 2)
    for s in specs:
        if s.mixer in ("gqa", "swa"):
            dh = cfg.head_dim
            total += d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        elif s.mixer == "mla":
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            total += d * cfg.kv_lora_rank
            total += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim +
                                                       cfg.v_head_dim)
            total += d * cfg.qk_rope_dim
            if cfg.q_lora_rank:
                total += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qk
            else:
                total += d * cfg.n_heads * qk
            total += cfg.n_heads * cfg.v_head_dim * d
        elif s.mixer == "mamba":
            di = cfg.d_inner
            dt_rank = max(1, math.ceil(d / 16))
            total += d * 2 * di + cfg.d_conv * di + \
                di * (dt_rank + 2 * cfg.d_state) + dt_rank * di + di * d
        elif s.mixer == "rwkv6":
            total += 6 * d * d
        if s.cross_attn:
            dh = cfg.head_dim
            total += d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        if s.ffn == "swiglu":
            total += 3 * d * cfg.d_ff
        elif s.ffn == "gelu":
            total += 2 * d * cfg.d_ff
        elif s.ffn == "cmix":
            total += 2 * d * cfg.d_ff + d * d
        elif s.ffn == "moe":
            f = cfg.d_ff_expert or cfg.d_ff
            total += 3 * d * f * cfg.experts_per_token
            total += 3 * d * f * cfg.n_shared_experts
            total += d * cfg.n_experts  # router
    # encoder
    if cfg.encoder_layers:
        dh = cfg.head_dim
        per = d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2) + 2 * d * cfg.d_ff
        total += cfg.encoder_layers * per
    return total

"""Layer primitives for every assigned architecture family.

Pure-JAX pytree modules: ``<name>_init(key, cfg, ...) -> params`` and
``<name>_apply(params, x, ...) -> y``. No flax/optax dependency.

Mixers: GQA attention (full / sliding-window / cross), MLA (DeepSeek-style
compressed KV), Mamba selective scan, RWKV6 time-mix.
FFNs: SwiGLU, GELU (whisper), RWKV channel-mix, MoE (capacity-based grouped
GEMM with expert-parallel shard_map — exact active FLOPs, no one-hot
dispatch tensor; see DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..sharding import BOTH, DATA, MODEL, current_mesh_ctx, shard
from .config import ModelConfig

# jax < 0.5 compat: shard_map lived under jax.experimental and pvary did not
# exist (values were implicitly unreplicated there).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                                  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

Array = jax.Array
PyTree = Any


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> PyTree:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: PyTree, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rms_norm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: PyTree, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm_init(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: PyTree, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm_init(cfg: ModelConfig) -> PyTree:
    return (layer_norm_init(cfg.d_model, _pdtype(cfg))
            if cfg.arch_type == "audio" else
            rms_norm_init(cfg.d_model, _pdtype(cfg)))


def norm(cfg: ModelConfig, p: PyTree, x: Array) -> Array:
    return (layer_norm(p, x, cfg.norm_eps) if "bias" in p
            else rms_norm(p, x, cfg.norm_eps))


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., T, H, dh) or (..., T, dh); positions (..., T)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., T, dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                  # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention core — flash-style chunked online softmax (pure JAX)
# --------------------------------------------------------------------------

def attention_core(q: Array, k: Array, v: Array, *, causal: bool,
                   q_offset, window: Optional[int] = None,
                   kv_len=None, softcap: Optional[float] = None,
                   chunk_q: int = 2048, chunk_k: int = 1024) -> Array:
    """q (B, H, Tq, dh), k/v (B, H, Tk, dh_[v]) — same head count (GQA kv is
    repeated by the caller). ``q_offset`` (scalar) is the absolute position
    of q[...,0,:]; ``kv_len`` (scalar or None) masks cache positions >= len.
    Memory is bounded by (chunk_q x chunk_k) score tiles for long sequences.
    """
    B, H, Tq, dh = q.shape
    Tk = k.shape[2]
    scale = 1.0 / math.sqrt(dh)
    qpos = q_offset + jnp.arange(Tq)
    kpos = jnp.arange(Tk)

    def mask_bias(qp, kp):
        ok = jnp.ones((qp.shape[0], kp.shape[0]), bool)
        if causal:
            ok &= kp[None, :] <= qp[:, None]
        if window is not None:
            ok &= kp[None, :] > qp[:, None] - window
        if kv_len is not None:
            ok &= (kp < kv_len)[None, :]
        return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)

    if Tq * Tk <= 4096 * 4096 and Tq <= 4096:
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = s + mask_bias(qpos, kpos)[None, None]
        p = jax.nn.softmax(s, axis=-1)
        # rows with all -inf (fully masked) produce nan -> zero them
        p = jnp.where(jnp.isfinite(s).any(-1, keepdims=True), p, 0.0)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)

    # ---- chunked path ----
    nk = -(-Tk // chunk_k)
    pad_k = nk * chunk_k - Tk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    eff_len = jnp.minimum(jnp.asarray(Tk), kv_len) if kv_len is not None \
        else jnp.asarray(Tk)

    def q_block(qc, qp):
        # qc (B, H, cq, dh); online softmax over k chunks
        cq = qc.shape[2]
        m0 = jnp.full((B, H, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, v.shape[-1]), jnp.float32)

        def body(carry, i):
            m, l, acc = carry
            ks = lax.dynamic_slice_in_dim(k, i * chunk_k, chunk_k, 2)
            vs = lax.dynamic_slice_in_dim(v, i * chunk_k, chunk_k, 2)
            kp = i * chunk_k + jnp.arange(chunk_k)
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, ks,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            bias = jnp.where(kp[None, :] < eff_len, 0.0, -jnp.inf)
            ok = jnp.ones((cq, chunk_k), bool)
            if causal:
                ok &= kp[None, :] <= qp[:, None]
            if window is not None:
                ok &= kp[None, :] > qp[:, None] - window
            s = s + (jnp.where(ok, 0.0, -jnp.inf) + bias)[None, None]
            m_new = jnp.maximum(m, s.max(-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            pexp = jnp.exp(s - m_safe[..., None])
            pexp = jnp.where(jnp.isfinite(s), pexp, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + pexp.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", pexp.astype(vs.dtype), vs
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    nq = -(-Tq // chunk_q)
    pad_q = nq * chunk_q - Tq
    qp_all = qpos
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        qp_all = jnp.pad(qpos, (0, pad_q))
    qs = q.reshape(B, H, nq, chunk_q, dh).transpose(2, 0, 1, 3, 4)
    qps = qp_all.reshape(nq, chunk_q)
    out = lax.map(lambda t: q_block(t[0], t[1]), (qs, qps))
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, nq * chunk_q, -1)
    return out[:, :, :Tq]


def _shard_attn_act(cfg: ModelConfig, x: Array, note: str) -> Array:
    """(B, T, H, dh) activation sharding: heads on the model axis when
    divisible; with cfg.attn_batch_shard_fallback, batch over
    (data x model) instead of replicating (§Perf variant for archs whose
    head count is smaller than the model axis, e.g. gemma3's 8 heads)."""
    ctx = current_mesh_ctx()
    if (ctx is not None and cfg.attn_batch_shard_fallback
            and x.shape[2] % ctx.model_size != 0
            and x.shape[0] % (ctx.data_size * ctx.model_size) == 0):
        return shard(x, BOTH, None, None, None, note=note)
    return shard(x, DATA, None, MODEL, None, note=note)


def grouped_attention(q: Array, kf: Array, vf: Array, *, kv_len, scale,
                      q_offset) -> Array:
    """Decode attention without repeat_kv: q (B, H, T, dh), kf/vf
    (B, K, S, dh) stay unexpanded; scores grouped by KV head (§Perf)."""
    B, H, T, dh = q.shape
    K = kf.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, T, dh)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, kf,
                   preferred_element_type=jnp.float32) * scale
    S = kf.shape[2]
    kpos = jnp.arange(S)
    qpos = q_offset + jnp.arange(T)
    ok = (kpos[None, :] < kv_len) & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", p.astype(vf.dtype), vf)
    return out.reshape(B, H, T, dh)


def seq_sharded_decode_attention(cfg: ModelConfig, q: Array, kx: Array,
                                 vx: Array, cache: dict) -> Tuple[Array,
                                                                  dict]:
    """Single-token decode against a KV cache whose SEQUENCE dim is sharded
    over the model axis (§Perf 'ringdecode'): each shard updates its slice
    (if it owns the write position), computes a local flash partial, and
    the global softmax is assembled with one pmax + two psums of
    (B, H, dh)-sized tensors — instead of SPMD all-gathering the cache.

    q (B, H, 1, dh); kx/vx (B, K, 1, dh); cache {k, v (B, K, S, dh), pos}.
    Returns (out (B, H, 1, dh), new_cache).
    """
    ctx = current_mesh_ctx()
    B, H, _, dh = q.shape
    K = kx.shape[1]
    G = H // K
    pos = cache["pos"]
    maxes = ctx.model_axis
    dspec = ctx.resolve(DATA) if B % ctx.data_size == 0 else None
    scale = 1.0 / math.sqrt(dh)

    def block(q_l, kx_l, vx_l, ck, cv, pos_):
        Bl = q_l.shape[0]                           # local batch
        Sl = ck.shape[2]                            # local cache slice
        o = lax.axis_index(maxes) * Sl
        idx = pos_ - o
        in_range = (idx >= 0) & (idx < Sl)
        safe = jnp.clip(idx, 0, Sl - 1)
        ck = ck.at[:, :, safe].set(
            jnp.where(in_range, kx_l[:, :, 0], ck[:, :, safe]))
        cv = cv.at[:, :, safe].set(
            jnp.where(in_range, vx_l[:, :, 0], cv[:, :, safe]))
        kpos = o + jnp.arange(Sl)
        valid = kpos <= pos_                        # causal + kv_len
        qg = q_l.reshape(Bl, K, G, dh)
        s = jnp.einsum("bkgd,bksd->bkgs", qg, ck,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_loc = s.max(-1)                           # (Bl, K, G)
        m_glob = lax.pmax(m_loc, maxes)
        p = jnp.exp(s - m_glob[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        l = lax.psum(p.sum(-1), maxes)              # (Bl, K, G)
        o_part = jnp.einsum("bkgs,bksd->bkgd", p.astype(cv.dtype), cv)
        o_full = lax.psum(o_part.astype(jnp.float32), maxes)
        out = (o_full / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(Bl, H, 1, dh), ck, cv

    out, kf, vf = _shard_map(
        block, mesh=ctx.mesh,
        in_specs=(P(dspec, None, None, None), P(dspec, None, None, None),
                  P(dspec, None, None, None), P(dspec, None, maxes, None),
                  P(dspec, None, maxes, None), P()),
        out_specs=(P(dspec, None, None, None), P(dspec, None, maxes, None),
                   P(dspec, None, maxes, None)),
    )(q, kx, vx, cache["k"], cache["v"], pos)
    return out, {"k": kf, "v": vf, "pos": pos + 1}


def repeat_kv(x: Array, groups: int) -> Array:
    """(B, K, T, dh) -> (B, K*groups, T, dh)."""
    if groups == 1:
        return x
    B, K, T, dh = x.shape
    return jnp.broadcast_to(x[:, :, None], (B, K, groups, T, dh)
                            ).reshape(B, K * groups, T, dh)


# --------------------------------------------------------------------------
# GQA attention (full / sliding-window / cross) with optional KV cache
# --------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> PyTree:
    dh, H, K = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = _pdtype(cfg)
    return {
        "wq": dense_init(ks[0], cfg.d_model, H * dh, bias=cfg.qkv_bias, dtype=dt),
        "wk": dense_init(ks[1], cfg.d_model, K * dh, bias=cfg.qkv_bias, dtype=dt),
        "wv": dense_init(ks[2], cfg.d_model, K * dh, bias=cfg.qkv_bias, dtype=dt),
        "wo": dense_init(ks[3], H * dh, cfg.d_model, dtype=dt,
                         scale=1.0 / math.sqrt(H * dh)),
    }


def gqa_apply(p: PyTree, cfg: ModelConfig, x: Array, *, window=None,
              positions=None, cache=None, xattn_kv=None,
              use_rope=True, causal=True) -> Tuple[Array, Optional[PyTree]]:
    """x (B, T, d). ``cache`` = {"k","v","pos"} for decode; ``xattn_kv`` =
    (k, v) (B, H, Tk, dh) precomputed cross-attention keys/values."""
    B, T, d = x.shape
    dh, H, K = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["wq"], x).reshape(B, T, H, dh)
    q = _shard_attn_act(cfg, q, "attn.q")
    if positions is None:
        positions = jnp.arange(T)[None, :]

    if xattn_kv is not None:
        kf, vf = xattn_kv
        q = q.transpose(0, 2, 1, 3)
        out = attention_core(q, kf, vf, causal=False, q_offset=0)
        new_cache = cache
    else:
        kx = dense(p["wk"], x).reshape(B, T, K, dh)
        vx = dense(p["wv"], x).reshape(B, T, K, dh)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            kx = apply_rope(kx, positions, cfg.rope_theta)
        q = q.transpose(0, 2, 1, 3)            # (B, H, T, dh)
        kx = kx.transpose(0, 2, 1, 3)
        vx = vx.transpose(0, 2, 1, 3)
        if cache is None:
            new_cache = None
            out = attention_core(q, repeat_kv(kx, H // K),
                                 repeat_kv(vx, H // K),
                                 causal=causal, q_offset=0, window=window,
                                 softcap=cfg.attn_logit_softcap)
        else:
            pos = cache["pos"]                 # scalar int32: tokens so far
            S = cache["k"].shape[2]
            if window is not None and S < cfg.max_seq_len:
                # ring buffer of size S == window; supports chunked prefill.
                # Attend over [pre-write ring | current chunk], then write.
                slot = jnp.arange(S)
                qpos = pos + jnp.arange(T)
                # latest absolute position per ring slot BEFORE this chunk
                abs_old = (pos - 1) - ((pos - 1 - slot) % S)
                k_all = jnp.concatenate([cache["k"], kx], axis=2)
                v_all = jnp.concatenate([cache["v"], vx], axis=2)
                kpos = jnp.concatenate([abs_old, pos + jnp.arange(T)])
                valid = (kpos[None, :] >= 0) & \
                        (kpos[None, :] <= qpos[:, None]) & \
                        (kpos[None, :] > qpos[:, None] - window)
                s = jnp.einsum("bhqd,bhkd->bhqk", q,
                               repeat_kv(k_all, H // K),
                               preferred_element_type=jnp.float32
                               ) / math.sqrt(dh)
                s = jnp.where(valid[None, None], s, -jnp.inf)
                w_ = jax.nn.softmax(s, axis=-1)
                w_ = jnp.where(jnp.isfinite(s).any(-1, keepdims=True), w_, 0.)
                out = jnp.einsum("bhqk,bhkd->bhqd", w_.astype(x.dtype),
                                 repeat_kv(v_all, H // K))
                t0 = max(0, T - S)          # only the last S tokens persist
                slots_w = (pos + t0 + jnp.arange(T - t0)) % S
                kf = cache["k"].at[:, :, slots_w].set(kx[:, :, t0:])
                vf = cache["v"].at[:, :, slots_w].set(vx[:, :, t0:])
                new_cache = {"k": kf, "v": vf, "pos": pos + T}
                o = out.transpose(0, 2, 1, 3).reshape(B, T, H * dh)
                o = shard(o, DATA, None, None, note="attn.o")
                return dense(p["wo"], o), new_cache
            ctx_ = current_mesh_ctx()
            if (cfg.seq_shard_decode and T == 1 and window is None
                    and cfg.attn_logit_softcap is None and ctx_ is not None
                    and ctx_.model_size > 1
                    and S % ctx_.model_size == 0):
                out, new_cache = seq_sharded_decode_attention(
                    cfg, q, kx, vx, cache)
                o = out.transpose(0, 2, 1, 3).reshape(B, T, H * dh)
                o = shard(o, DATA, None, None, note="attn.o")
                return dense(p["wo"], o), new_cache
            kf = lax.dynamic_update_slice_in_dim(cache["k"], kx, pos, 2)
            vf = lax.dynamic_update_slice_in_dim(cache["v"], vx, pos, 2)
            new_cache = {"k": kf, "v": vf, "pos": pos + T}
            if cfg.grouped_gqa and window is None \
                    and cfg.attn_logit_softcap is None:
                out = grouped_attention(q, kf, vf, kv_len=pos + T,
                                        scale=1.0 / math.sqrt(dh),
                                        q_offset=pos)
            else:
                out = attention_core(q, repeat_kv(kf, H // K),
                                     repeat_kv(vf, H // K), causal=True,
                                     q_offset=pos, window=window,
                                     kv_len=pos + T,
                                     softcap=cfg.attn_logit_softcap)
    o = out.transpose(0, 2, 1, 3).reshape(B, T, H * dh)
    o = shard(o, DATA, None, None, note="attn.o")
    return dense(p["wo"], o), new_cache


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: Optional[int] = None) -> PyTree:
    S = min(window, max_len) if window else max_len
    dt = _dtype(cfg)
    return {"k": jnp.zeros((batch, cfg.n_kv_heads, S, cfg.head_dim), dt),
            "v": jnp.zeros((batch, cfg.n_kv_heads, S, cfg.head_dim), dt),
            "pos": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention (compressed KV cache)
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> PyTree:
    dt = _pdtype(cfg)
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[0], cfg.d_model, cfg.kv_lora_rank, dtype=dt),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank, dt),
        "w_uk": dense_init(ks[1], cfg.kv_lora_rank,
                           H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dt),
        "w_kr": dense_init(ks[2], cfg.d_model, cfg.qk_rope_dim, dtype=dt),
        "wo": dense_init(ks[3], H * cfg.v_head_dim, cfg.d_model, dtype=dt),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], cfg.d_model, cfg.q_lora_rank, dtype=dt)
        p["q_norm"] = rms_norm_init(cfg.q_lora_rank, dt)
        p["w_uq"] = dense_init(ks[5], cfg.q_lora_rank, H * qk, dtype=dt)
    else:
        p["w_uq"] = dense_init(ks[5], cfg.d_model, H * qk, dtype=dt)
    return p


def mla_apply(p: PyTree, cfg: ModelConfig, x: Array, *, positions=None,
              cache=None) -> Tuple[Array, Optional[PyTree]]:
    B, T, d = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(T)[None, :]
    # queries
    if "w_dq" in p:
        ql = rms_norm(p["q_norm"], dense(p["w_dq"], x), cfg.norm_eps)
    else:
        ql = x
    q = dense(p["w_uq"], ql).reshape(B, T, H, nd + rd)
    q = shard(q, DATA, None, MODEL, None, note="mla.q")
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # compressed kv
    c_kv = rms_norm(p["kv_norm"], dense(p["w_dkv"], x), cfg.norm_eps)  # (B,T,R)
    k_rope = apply_rope(dense(p["w_kr"], x), positions, cfg.rope_theta)  # (B,T,rd)
    if cache is not None:
        pos = cache["pos"]
        c_kv = lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, 1)
        k_rope = lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope,
                                                 pos, 1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": pos + T}
        kv_len = pos + T
        q_offset = pos
    else:
        new_cache = None
        kv_len = None
        q_offset = 0

    if cfg.mla_absorb and cache is not None:
        # --- absorbed path (§Perf): attention entirely in the compressed
        # latent space. q_nope is absorbed through W_uk's key half
        # (q̃ = q_nope · W_uk_k), scores = q̃ · c_kv^T + q_rope · k_rope^T,
        # and the context is projected out through W_uk's value half.
        # Avoids materializing (B, S, H, nd+vd) decompressed K/V.
        R = cfg.kv_lora_rank
        wk = p["w_uk"]["w"].astype(x.dtype).reshape(R, H, nd + vd)
        w_uk_k, w_uk_v = wk[..., :nd], wk[..., nd:]
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk_k)
        s = jnp.einsum("bthr,bsr->bhts", q_lat, c_kv,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope,
                           preferred_element_type=jnp.float32)
        s = s / math.sqrt(nd + rd)
        S_ = c_kv.shape[1]
        kpos = jnp.arange(S_)
        qpos = q_offset + jnp.arange(T)
        ok = (kpos[None, :] < kv_len) & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(ok[None, None], s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        ctx_lat = jnp.einsum("bhts,bsr->bthr", pr.astype(x.dtype), c_kv)
        out_h = jnp.einsum("bthr,rhv->bthv", ctx_lat, w_uk_v)
        o = out_h.reshape(B, T, H * vd)
        o = shard(o, DATA, None, None, note="mla.o")
        return dense(p["wo"], o), new_cache

    # decompress (naive path; absorbed path above is the §Perf variant)
    kv = dense(p["w_uk"], c_kv).reshape(B, c_kv.shape[1], H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], rd))], axis=-1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = attention_core(qh, kh, vh, causal=True, q_offset=q_offset,
                         kv_len=kv_len)
    o = out.transpose(0, 2, 1, 3).reshape(B, T, H * vd)
    o = shard(o, DATA, None, None, note="mla.o")
    return dense(p["wo"], o), new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dt = _dtype(cfg)
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt),
            "pos": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# FFNs
# --------------------------------------------------------------------------

def swiglu_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> PyTree:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _pdtype(cfg)
    return {"w_gate": dense_init(ks[0], cfg.d_model, d_ff, dtype=dt),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dtype=dt),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dtype=dt,
                                 scale=1.0 / math.sqrt(d_ff))}


def swiglu_apply(p: PyTree, x: Array) -> Array:
    h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    h = shard(h, DATA, None, MODEL, note="ffn.h")
    return dense(p["w_down"], h)


def gelu_mlp_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 2)
    dt = _pdtype(cfg)
    return {"w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, bias=True, dtype=dt),
            "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model, bias=True,
                                 dtype=dt, scale=1.0 / math.sqrt(cfg.d_ff))}


def gelu_mlp_apply(p: PyTree, x: Array) -> Array:
    h = jax.nn.gelu(dense(p["w_up"], x))
    h = shard(h, DATA, None, MODEL, note="ffn.h")
    return dense(p["w_down"], h)


# RWKV channel-mix (relu^2 MLP with token shift + receptance gate)
def cmix_init(key, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 3)
    dt = _pdtype(cfg)
    return {"mu_k": jnp.full((cfg.d_model,), 0.5, dt),
            "mu_r": jnp.full((cfg.d_model,), 0.5, dt),
            "w_k": dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype=dt),
            "w_v": dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype=dt,
                              scale=1.0 / math.sqrt(cfg.d_ff)),
            "w_r": dense_init(ks[2], cfg.d_model, cfg.d_model, dtype=dt)}


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x (B, T, d) -> x shifted right by one along T; position 0 gets
    ``prev`` (B, d) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def cmix_apply(p: PyTree, x: Array, prev: Optional[Array] = None
               ) -> Tuple[Array, Array]:
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["mu_k"].astype(x.dtype)
    xr = x + (xs - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(p["w_k"], xk)))
    k = shard(k, DATA, None, MODEL, note="cmix.h")
    r = jax.nn.sigmoid(dense(p["w_r"], xr))
    return r * dense(p["w_v"], k), x[:, -1]


# --------------------------------------------------------------------------
# MoE — capacity-based grouped GEMM, expert-parallel via shard_map
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> PyTree:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = _pdtype(cfg)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * s_in
                   ).astype(jnp.float32),  # router kept f32 for stable top-k
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in
                   ).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in
                 ).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out
                   ).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(
            ks[4], cfg, d_ff=(cfg.d_ff_expert or cfg.d_ff) * cfg.n_shared_experts)
    return p


def _moe_local(x2d: Array, router_w: Array, w_gate: Array, w_up: Array,
               w_down: Array, *, cfg: ModelConfig, e_start,
               n_local: int) -> Tuple[Array, Array]:
    """Grouped-GEMM MoE over ``n_local`` experts starting at ``e_start``.
    x2d (T, d). Returns (out (T, d) — contributions of local experts only,
    aux load-balance loss (scalar, local estimate))."""
    T, d = x2d.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    C = max(1, math.ceil(T * K / E * cfg.capacity_factor))
    logits = x2d.astype(jnp.float32) @ router_w              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, K)                       # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * P_e
    f_e = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * K)
    P_e = probs.mean(0)
    aux = E * jnp.sum(f_e * P_e)

    flat_i = top_i.reshape(-1)                               # (T*K,)
    flat_w = top_w.reshape(-1).astype(x2d.dtype)
    tok = jnp.arange(T * K) // K
    local = flat_i - e_start
    valid = (local >= 0) & (local < n_local)
    key_ = jnp.where(valid, local, n_local)
    order = jnp.argsort(key_, stable=True)
    skey = key_[order]
    counts = jnp.zeros((n_local + 1,), jnp.int32).at[skey].add(1)
    starts = jnp.cumsum(counts) - counts                     # exclusive
    pos = jnp.arange(T * K) - starts[skey]
    ok = (skey < n_local) & (pos < C)
    slot = jnp.where(ok, skey * C + pos, n_local * C)        # overflow -> trash
    buf = jnp.zeros((n_local * C + 1, d), x2d.dtype)
    buf = buf.at[slot].set(jnp.where(ok[:, None], x2d[tok[order]], 0))
    eb = buf[:n_local * C].reshape(n_local, C, d)
    h = jnp.einsum("ecd,edf->ecf", eb, w_gate.astype(eb.dtype))
    u = jnp.einsum("ecd,edf->ecf", eb, w_up.astype(eb.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                   w_down.astype(eb.dtype))
    yf = jnp.concatenate([y.reshape(n_local * C, d),
                          jnp.zeros((1, d), y.dtype)], 0)
    contrib = yf[slot] * (flat_w[order] * ok.astype(x2d.dtype))[:, None]
    out = jnp.zeros((T, d), x2d.dtype).at[tok[order]].add(contrib)
    return out, aux


def moe_apply(p: PyTree, cfg: ModelConfig, x: Array) -> Tuple[Array, Array]:
    """x (B, T, d) -> (out, aux_loss). Expert-parallel over the model axis
    when available and divisible; shared experts run dense (tensor-parallel).
    """
    B, T, d = x.shape
    x2 = x.reshape(B * T, d)
    ctx = current_mesh_ctx()
    E = cfg.n_experts
    msize = ctx.model_size if ctx is not None else 1
    if ctx is not None and msize > 1 and E % msize == 0:
        n_local = E // msize
        maxes = ctx.model_axis
        data_axes = tuple(ctx.data_axes)
        all_axes = data_axes + (maxes,)
        # tokens shard over the data axes when divisible; batch-1 decode
        # keeps tokens replicated (expert weights stay model-sharded).
        tokens_sharded = ctx.data_size > 1 and (B * T) % ctx.data_size == 0
        dspec = ctx.resolve(DATA) if tokens_sharded else None

        def block(xl, rw, wg, wu, wd):
            e_start = lax.axis_index(maxes) * n_local
            out, aux = _moe_local(xl, rw, wg, wu, wd, cfg=cfg,
                                  e_start=e_start, n_local=n_local)
            out = lax.psum(out, maxes)
            # aux: sum disjoint local f_e*P_e terms over experts (model
            # axis), mean over data shards; pvary the axes the tracker
            # sees as invarying, then psum over everything so the scalar
            # is replicated (out_specs P()).
            aux = _pvary(aux, (maxes,) if tokens_sharded
                                else all_axes)
            aux = lax.psum(aux, all_axes) / ctx.data_size
            return out, aux

        out, aux = _shard_map(
            block, mesh=ctx.mesh,
            in_specs=(P(dspec, None), P(None, None), P(maxes, None, None),
                      P(maxes, None, None), P(maxes, None, None)),
            out_specs=(P(dspec, None), P()),
        )(x2, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        out, aux = _moe_local(x2, p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], cfg=cfg, e_start=0, n_local=E)
    out = out.reshape(B, T, d)
    if "shared" in p:
        out = out + swiglu_apply(p["shared"], x)
    return out, aux


# --------------------------------------------------------------------------
# Mamba (selective scan, Jamba-style) — sequential lax.scan over time
# --------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig) -> PyTree:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.d_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    dt = _pdtype(cfg)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                   / math.sqrt(cfg.d_conv)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * N, dtype=dt),
        "dt_proj": dense_init(ks[3], dt_rank, di, bias=True, dtype=dt),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype=dt,
                               scale=1.0 / math.sqrt(di)),
    }


def _mamba_conv(x: Array, w: Array, b: Array, prev: Optional[Array]
                ) -> Tuple[Array, Array]:
    """Causal depthwise conv over (B, T, di) with kernel (d_conv, di).
    ``prev`` (B, d_conv-1, di) carries state for decode."""
    dconv = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], dconv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(dconv))
    new_prev = xp[:, -(dconv - 1):] if dconv > 1 else prev
    return out + b.astype(x.dtype), new_prev


def mamba_apply(p: PyTree, cfg: ModelConfig, x: Array, state=None
                ) -> Tuple[Array, Optional[PyTree]]:
    """x (B, T, d); state {"h": (B, di, N), "conv": (B, d_conv-1, di)}."""
    B, T, d = x.shape
    di, N = cfg.d_inner, cfg.d_state
    dt_rank = p["dt_proj"]["w"].shape[0]
    xz = dense(p["in_proj"], x)
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = shard(x1, DATA, None, MODEL, note="mamba.x")
    conv_prev = None if state is None else state["conv"]
    x1, conv_new = _mamba_conv(x1, p["conv_w"], p["conv_b"], conv_prev)
    x1 = jax.nn.silu(x1)
    dbc = dense(p["x_proj"], x1)
    dt_, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    delta = jax.nn.softplus(dense(p["dt_proj"], dt_))       # (B, T, di)
    A = -jnp.exp(p["A_log"])                                 # (di, N) f32
    a = jnp.exp(delta.astype(jnp.float32)[..., None] * A)    # (B, T, di, N)
    bx = (delta * x1).astype(jnp.float32)[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]                # (B, T, di, N)

    h0 = (jnp.zeros((B, di, N), jnp.float32) if state is None
          else state["h"])

    def step(h, inp):
        a_t, bx_t, c_t = inp
        h = a_t * h + bx_t                                   # (B, di, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    aT = a.transpose(1, 0, 2, 3)
    bxT = bx.transpose(1, 0, 2, 3)
    cT = Cm.astype(jnp.float32).transpose(1, 0, 2)
    hT, yT = lax.scan(step, h0, (aT, bxT, cT))
    y = yT.transpose(1, 0, 2).astype(x.dtype)                # (B, T, di)
    y = y + x1 * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    new_state = None if state is None else {"h": hT, "conv": conv_new}
    return out, new_state


def mamba_state_init(cfg: ModelConfig, batch: int) -> PyTree:
    return {"h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner),
                              _dtype(cfg))}


# --------------------------------------------------------------------------
# RWKV6 time-mix (Finch) — data-dependent decay, lax.scan over time
# --------------------------------------------------------------------------

def rwkv6_init(key, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 8)
    dt = _pdtype(cfg)
    lora = max(32, d // 32)
    return {
        "mu": jnp.full((5, d), 0.5, dt),                   # r,k,v,w,g shifts
        "w_r": dense_init(ks[0], d, d, dtype=dt),
        "w_k": dense_init(ks[1], d, d, dtype=dt),
        "w_v": dense_init(ks[2], d, d, dtype=dt),
        "w_g": dense_init(ks[3], d, d, dtype=dt),
        "w0": jnp.full((d,), -6.0, jnp.float32),           # base decay (slow)
        "w_lora_a": dense_init(ks[4], d, lora, dtype=dt),
        "w_lora_b": dense_init(ks[5], lora, d, dtype=dt, scale=0.01),
        "u": (jax.random.normal(ks[6], (H, dh), jnp.float32) * 0.1),
        "ln_out": {"scale": jnp.ones((H, dh), jnp.float32),
                   "bias": jnp.zeros((H, dh), jnp.float32)},
        "w_o": dense_init(ks[7], d, d, dtype=dt, scale=1.0 / math.sqrt(d)),
    }


def rwkv6_apply(p: PyTree, cfg: ModelConfig, x: Array, state=None
                ) -> Tuple[Array, Optional[PyTree]]:
    """x (B, T, d); state {"S": (B, H, dh, dh) f32, "x_prev": (B, d)}."""
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    prev = None if state is None else state["x_prev"]
    xs = _token_shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))
    r = dense(p["w_r"], xr).reshape(B, T, H, dh)
    k = dense(p["w_k"], xk).reshape(B, T, H, dh)
    v = dense(p["w_v"], xv).reshape(B, T, H, dh)
    g = jax.nn.silu(dense(p["w_g"], xg))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(xw)))
    wl = dense(p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], xw)))
    w = jnp.exp(-jnp.exp(p["w0"] + wl.astype(jnp.float32)))  # (B,T,d) in (0,1)
    w = w.reshape(B, T, H, dh)
    u = p["u"]                                               # (H, dh)

    S0 = (jnp.zeros((B, H, dh, dh), jnp.float32) if state is None
          else state["S"])

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                             # (B, H, dh)
        kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,dh,dh)
        y = jnp.einsum("bhj,bhji->bhi", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    rT = r.transpose(1, 0, 2, 3).astype(jnp.float32)
    kT = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vT = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    wT = w.transpose(1, 0, 2, 3)
    ST, yT = lax.scan(step, S0, (rT, kT, vT, wT))
    y = yT.transpose(1, 0, 2, 3)                             # (B, T, H, dh)
    # per-head groupnorm
    mu_ = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu_) * lax.rsqrt(var + 1e-5)
    y = y * p["ln_out"]["scale"] + p["ln_out"]["bias"]
    y = y.reshape(B, T, d).astype(x.dtype) * g
    out = dense(p["w_o"], y)
    new_state = None if state is None else {"S": ST, "x_prev": x[:, -1]}
    return out, new_state


def rwkv6_state_init(cfg: ModelConfig, batch: int) -> PyTree:
    dh = cfg.d_model // cfg.n_heads
    return {"S": jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
            "x_prev": jnp.zeros((batch, cfg.d_model), _dtype(cfg))}

"""Unified model configuration covering all assigned architecture families.

A model is a stack of layers; each layer is (mixer, ffn):
  mixer ∈ {gqa, swa, mla, mamba, rwkv6, none}
  ffn   ∈ {swiglu, gelu, moe}
plus optional encoder (whisper) and stub modality frontends (audio/vlm).

``layer_specs(cfg)`` expands the per-layer pattern; the model groups the
specs into a scannable periodic core + unrolled tail (see model.py) so the
HLO stays small for 80-layer models.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "LayerSpec", "layer_specs", "find_period"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "gqa"       # gqa | swa | mla | mamba | rwkv6
    ffn: str = "swiglu"      # swiglu | gelu | moe
    cross_attn: bool = False  # decoder cross-attention (whisper)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // n_heads

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window for 'swa' mixer layers
    local_global_pattern: Optional[Tuple[int, int]] = None  # (n_local, n_global)
    attn_logit_softcap: Optional[float] = None

    # --- MLA (DeepSeek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1                  # every p-th layer is MoE
    moe_offset: int = 0                  # first MoE layer index within period
    dense_prefix: int = 0                # first L layers always dense FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSM ---
    ssm_kind: Optional[str] = None       # mamba | rwkv6 (for ssm/hybrid archs)
    ssm_period: int = 1                  # attention every p-th layer (hybrid)
    ssm_attn_offset: int = 0             # which index in the period is attn
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                 # e.g. 1500 audio frames

    # --- modality frontend stubs ---
    frontend: Optional[str] = None       # audio_stub | vision_stub
    frontend_seq: int = 0                # patch/frame tokens prepended
    frontend_dim: int = 0                # raw embedding dim before projector

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    max_seq_len: int = 131_072
    remat: bool = True                   # checkpoint each layer group in bwd
    use_pallas: bool = False             # TPU Pallas kernels for hot spots
    scan_layers: bool = True             # False: unroll (exact dry-run FLOPs;
    #   XLA HLOCostAnalysis counts while-loop bodies once, so the roofline
    #   dry-run unrolls the layer dimension — see launch/dryrun.py)

    # --- §Perf optimization variants (EXPERIMENTS.md; all default OFF so
    #     the baseline dry-runs stay paper-faithful) ---
    mla_absorb: bool = False             # absorbed-MLA decode: attention in
    #   the compressed latent space (no per-step KV decompression)
    grouped_gqa: bool = False            # decode attention grouped by KV
    #   head (no repeat_kv materialization)
    attn_batch_shard_fallback: bool = False  # when q-heads don't divide the
    #   model axis, shard the BATCH over (data x model) for attention
    #   instead of replicating
    seq_shard_decode: bool = False       # decode attention over a sequence-
    #   sharded KV cache via shard_map partial-softmax combine (pmax/psum of
    #   (m, l, out) per layer) instead of letting SPMD all-gather the cache

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests:
        <= 2 layers (+2 encoder), d_model <= 512, <= 4 experts."""
        changes = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64,
            max_seq_len=4096,
            param_dtype="float32",
            dtype="float32",
            dense_prefix=min(self.dense_prefix, 1),
            remat=False,
        )
        if self.n_experts:
            changes.update(n_experts=4,
                           experts_per_token=min(self.experts_per_token, 2),
                           n_shared_experts=min(self.n_shared_experts, 1),
                           d_ff_expert=min(self.d_ff_expert, 256) or 256)
        if self.q_lora_rank or self.kv_lora_rank:
            changes.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                           qk_rope_dim=16, v_head_dim=32, head_dim=48)
        if self.sliding_window:
            changes.update(sliding_window=32)
        if self.encoder_layers:
            changes.update(encoder_layers=2, encoder_seq=64)
        if self.frontend:
            changes.update(frontend_seq=min(self.frontend_seq, 16),
                           frontend_dim=min(self.frontend_dim, 128) or 128)
        if self.ssm_kind:
            changes.update(d_state=8)
        return dataclasses.replace(self, name=self.name + "-smoke", **changes)


def layer_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    """Expand the config's layer pattern into one LayerSpec per layer."""
    specs = []
    for l in range(cfg.n_layers):
        # mixer
        if cfg.ssm_kind and cfg.arch_type in ("ssm", "hybrid"):
            if cfg.arch_type == "hybrid" and cfg.ssm_period > 1 \
                    and l % cfg.ssm_period == cfg.ssm_attn_offset:
                mixer = "gqa"
            else:
                mixer = cfg.ssm_kind
        elif cfg.local_global_pattern:
            nl, ng = cfg.local_global_pattern
            mixer = "swa" if (l % (nl + ng)) < nl else "gqa"
        elif cfg.kv_lora_rank:
            mixer = "mla"
        elif cfg.sliding_window and not cfg.local_global_pattern:
            mixer = "swa"
        else:
            mixer = "gqa"
        # ffn
        if cfg.n_experts and l >= cfg.dense_prefix \
                and l % cfg.moe_period == cfg.moe_offset % cfg.moe_period:
            ffn = "moe"
        else:
            ffn = "gelu" if cfg.arch_type == "audio" else "swiglu"
        specs.append(LayerSpec(mixer=mixer, ffn=ffn,
                               cross_attn=cfg.encoder_layers > 0))
    return tuple(specs)


def find_period(specs: Tuple[LayerSpec, ...], max_period: int = 16
                ) -> Tuple[int, int]:
    """Find (period, repeats) maximizing scanned coverage: the smallest p <=
    max_period such that specs is `repeats` copies of specs[:p] plus a tail.
    Returns (p, repeats) with repeats >= 1 (p = len(specs) if aperiodic)."""
    n = len(specs)
    best = (n, 1)
    best_cost = n  # distinct layer bodies in the HLO
    for p in range(1, min(max_period, n) + 1):
        reps = n // p
        if reps < 1:
            continue
        if all(specs[i] == specs[i % p] for i in range(p * reps)):
            cost = p + (n - p * reps)   # scanned bodies + unrolled tail
            if cost < best_cost or (cost == best_cost and p < best[0]):
                best = (p, reps)
                best_cost = cost
    return best

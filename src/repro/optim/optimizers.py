"""Hand-rolled pytree optimizers (no optax in the dependency closure).

API mirrors optax: ``opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply(params,
updates)`` where updates are *deltas to add*.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, total_steps: int, warmup: int = 0,
                    floor: float = 0.0) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(lambda l: l * scale, tree), g


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]  # (grads, state, params)

    def apply(self, params: PyTree, updates: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, updates)


def _sched(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


def sgd(lr) -> Optimizer:
    lr = _sched(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"]
        s = lr(step)
        upd = jax.tree_util.tree_map(
            lambda g: -s * g.astype(jnp.float32), grads)
        return upd, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr = _sched(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        step = state["step"]
        s = lr(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32),
            state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m, g: -s * (beta * m + g.astype(jnp.float32)),
                mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -s * m, mu)
        return upd, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
         ) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    lr = _sched(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        s = lr(step - 1)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return -s * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)

from .optimizers import (Optimizer, sgd, momentum, adam, adamw,
                         clip_by_global_norm, global_norm,
                         cosine_schedule, constant_schedule)

from .steps import (TrainState, make_train_step, make_straggler_train_step,
                    make_serve_step, lm_loss, init_train_state)

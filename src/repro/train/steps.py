"""Train / serve steps.

``make_straggler_train_step`` is the paper's technique as a first-class
feature: one SGD iteration = one scheduling round. The n logical workers
(data-parallel shard groups) each evaluate their r TO-assigned micro-batch
gradients *sequentially* (lax.scan over slots, mirroring the paper's
sequential computation); the first-k-distinct winner mask (repro.core)
weights the per-(worker, slot) losses so the resulting gradient equals the
unbiased eq.-(61) estimator. The round's virtual completion time is a step
metric.

Round-awareness: delays come from a stateful ``DelayProcess``
(``repro.core.cluster``) whose per-worker straggler state threads through
the step as an explicit ``cluster`` pytree — pass each step's returned
cluster state into the next step and consecutive rounds see persistent,
worker-specific straggling (stateless ``DelayModel``s remain the
zero-correlation special case with an empty state).  An optional traced
``row_of_worker`` permutation re-assigns the base TO matrix's rows to
workers for the round (the adaptive schedule; see
``repro.core.scheduling.AdaptiveScheduler``) — the caller must build the
round's data with the matching effective matrix ``C[row_of_worker]``.

The weighted-loss trick avoids materializing per-worker gradient pytrees:
    grad( sum_{i,s} w[i,s] * loss_{i,s} / k ) = (1/k) sum w[i,s] g_{i,s}.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.aggregator import RoundSpec
from ..core.cluster import as_process
from ..core.completion import (apply_row_layout, message_arrival_times,
                               message_slot_layout, row_layout_is_identity,
                               winner_mask_gather)
from ..core.montecarlo import task_arrival_times_gather, task_gather_plan
from ..core.scheduling import loads_of_matrix
from ..models import ModelConfig, forward, init_params
from ..optim import Optimizer, clip_by_global_norm
from ..sharding import DATA, shard

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_train_state(key, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def lm_loss_per_seq(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
                    labels: jax.Array, *, embeds=None, enc_frames=None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-sequence next-token cross-entropy (B,); returns (losses, aux)."""
    logits, aux, _ = forward(params, cfg, tokens, embeds=embeds,
                             enc_frames=enc_frames)
    if embeds is not None:
        logits = logits[:, embeds.shape[1]:]      # loss on text positions
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean(axis=-1), aux


def lm_loss(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, *, embeds=None, enc_frames=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Mean next-token cross-entropy; returns (loss, moe_aux)."""
    losses, aux = lm_loss_per_seq(params, cfg, tokens, labels,
                                  embeds=embeds, enc_frames=enc_frames)
    return losses.mean(), aux


def make_train_step(cfg: ModelConfig, opt: Optimizer, *,
                    clip_norm: float = 1.0,
                    loss_fn: Optional[Callable] = None):
    """Plain synchronous data-parallel step (baseline, k = n, r = 1)."""
    loss_fn = loss_fn or lm_loss

    def step(state: TrainState, tokens, labels, extras=None):
        extras = extras or {}

        def total(p):
            l, aux = loss_fn(p, cfg, tokens, labels, **extras)
            return l + cfg.router_aux_coef * aux, (l, aux)

        (ltot, (l, aux)), grads = jax.value_and_grad(total, has_aux=True)(
            state.params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = opt.apply(state.params, updates)
        metrics = {"loss": l, "aux": aux, "grad_norm": gnorm}
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


def make_straggler_train_step(cfg: ModelConfig, opt: Optimizer,
                              round_spec: RoundSpec, delay, *,
                              clip_norm: float = 1.0,
                              scan_slots: bool = True):
    """The paper's scheduled round as a jittable SGD step.

    Inputs per step: ``slot_tokens``/``slot_labels`` (r, n, b, S) from
    ``repro.data.lm_task_batches``, an rng for the delay realization, the
    previous round's ``cluster`` state (``None`` starts a fresh cluster;
    pass the returned state back in for persistent straggling), optionally
    a traced ``row_of_worker`` permutation (adaptive schedules; data must
    then come from the effective matrix ``C[row_of_worker]``), and
    optionally ``extras`` (dict of slot-major modality inputs, e.g.
    ``enc_frames`` (r, n, b, T_enc, D) for whisper). Returns
    ``(state, metrics, cluster)`` with metrics incl. the round's virtual
    completion time (eq. 6), the winner count, the per-worker observed
    compute delays (``worker_t1``) that feed adaptive scheduling, and the
    raw per-(worker, slot) delay draws (``slot_t1``/``slot_t2``) that
    ``launch/train.py --log-delays`` accumulates into a replayable
    ``DelayTrace``.

    Layout: the worker axis is FLATTENED into the batch (worker-major), so
    each data shard holds exactly its workers' sequences and the model
    forward is one plain SPMD call per slot — per-sequence losses are then
    weighted by the worker's first-k-distinct mask (eq. 61). ``scan_slots``
    mirrors the paper's sequential per-slot execution; set False to unroll
    (used by the dry-run for exact HLO cost accounting).

    Ragged rounds (``RoundSpec.loads``): rows keep only their first
    ``loads[i]`` slots — masked slots get +inf arrivals, zero winner
    weight, and all-zero micro-batches from ``lm_task_batches``, so they
    contribute nothing to the gradient while the virtual completion time
    reflects the reduced per-worker loads.  ``RoundSpec.comm_eps`` adds
    the per-message protocol overhead to every arrival.
    """
    n, r, k = round_spec.n, round_spec.r, round_spec.k
    process = as_process(delay)
    base_C = round_spec.to_matrix()          # ragged rows carry their loads
    plan = task_gather_plan(base_C, n)
    # a closing deadline (close_partial / reissue) caps the winner
    # selection at the deadline; "wait" keeps the true completion time
    dl_close = (round_spec.deadline
                if round_spec.deadline is not None
                and round_spec.deadline_policy != "wait" else None)
    # static per-row message layout: closing-slot remap, per-message
    # overhead offsets, ragged-load masks.  None when it is the identity
    # (dense, per-slot sends, no overhead) — the established fast path.
    _layout = message_slot_layout(loads_of_matrix(base_C), r,
                                  round_spec.n_messages, round_spec.comm_eps)
    if row_layout_is_identity(_layout):
        _layout = None

    def _row_arrivals(s):
        """Per-message availability in base-row space (rows carry their
        own grouping/masks whatever worker executes them)."""
        return s if _layout is None else apply_row_layout(s, _layout)

    def step(state: TrainState, slot_tokens, slot_labels, rng, cluster=None,
             row_of_worker=None, extras=None):
        extras = extras or {}
        b = slot_tokens.shape[2]
        # --- cluster round: stateful delays + first-k-distinct weights ----
        if cluster is None:
            # trial id 0: a training run is the single realization of a
            # trace-backed process (lane 0 of its recorded table)
            cluster = process.init_trials(
                jax.random.fold_in(rng, 0x0c10)[None],
                jnp.zeros((1,), jnp.int32), n)
        cluster, T1, T2 = process.step(cluster, rng[None], n, r)
        # raw per-slot availability (eq. 1); the message grouping / ragged
        # masks are applied per row after the (optional) permutation
        s = message_arrival_times(T1, T2, r)[0]
        if row_of_worker is None:
            row_arr = _row_arrivals(s)
            weights, t_done = winner_mask_gather(base_C, plan, row_arr, n, k,
                                                 deadline=dl_close)
        else:
            worker_of_row = jnp.argsort(row_of_worker)       # inverse perm
            row_arr = _row_arrivals(s[worker_of_row])
            w2, t_done = winner_mask_gather(base_C, plan, row_arr, n, k,
                                            deadline=dl_close)
            weights = w2[row_of_worker]                      # worker-major
        # per-task delivery by the (capped) round close — feeds the
        # reissue policy's re-gather priority in the driving loop
        tau = task_arrival_times_gather(plan, row_arr)
        delivered = (tau <= t_done) & jnp.isfinite(tau)

        # realized selected-task count: == k a.s. with per-slot sends, may
        # exceed k when a reduced message budget delivers tasks in lumps —
        # or fall short (even to 0) when faults/deadlines censor arrivals;
        # guard the normalizer so an empty round yields a zero gradient,
        # not NaN.
        wsum_raw = weights.sum()
        wsum = jnp.where(wsum_raw > 0, wsum_raw, 1.0)

        def slot_loss(p, s):
            toks = slot_tokens[s].reshape(n * b, -1)         # worker-major
            labs = slot_labels[s].reshape(n * b, -1)
            toks = shard(toks, DATA, None, note="slot.tokens")
            kw = {key: v[s].reshape((n * b,) + v.shape[3:])
                  for key, v in extras.items()}
            losses, aux = lm_loss_per_seq(p, cfg, toks, labs, **kw)
            w_seq = jnp.repeat(weights[:, s], b) / (wsum * b)  # eq. (61)
            return (w_seq * losses).sum(), aux * (weights[:, s].sum() / wsum)

        def total(p):
            if scan_slots:
                def slot_term(carry, s):
                    l, a = slot_loss(p, s)
                    return (carry[0] + l, carry[1] + a), None
                (loss, aux), _ = jax.lax.scan(
                    slot_term, (jnp.zeros(()), jnp.zeros(())),
                    jnp.arange(r))
            else:
                loss = aux = jnp.zeros(())
                for s in range(r):
                    l, a = slot_loss(p, s)
                    loss, aux = loss + l, aux + a
            return loss + cfg.router_aux_coef * aux, (loss, aux)

        (ltot, (l, aux)), grads = jax.value_and_grad(total, has_aux=True)(
            state.params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = opt.apply(state.params, updates)
        metrics = {"loss": l, "aux": aux, "grad_norm": gnorm,
                   "completion_time": t_done,
                   "winners": (weights > 0).sum(),
                   "realized_k": wsum_raw,
                   "delivered_tasks": delivered,
                   "deadline_missed": (jnp.zeros((), jnp.bool_)
                                       if round_spec.deadline is None else
                                       (wsum_raw < k
                                        if dl_close is not None
                                        else t_done > round_spec.deadline)),
                   "worker_t1": T1[0].mean(axis=-1),
                   # raw per-(worker, slot) delay draws of the round —
                   # what `launch/train.py --log-delays` accumulates into
                   # a replayable DelayTrace (repro.core.trace)
                   "slot_t1": T1[0], "slot_t2": T2[0]}
        return TrainState(params, opt_state, state.step + 1), metrics, cluster

    return step


def make_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    """One decode step: (params, cache, tokens (B,1)) -> (next (B,1), cache).
    """
    def step(params, cache, tokens, rng=None):
        logits, _, cache = forward(params, cfg, tokens, cache=cache)
        last = logits[:, -1]
        if greedy or rng is None:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(rng, last)
        return nxt[:, None].astype(jnp.int32), cache

    return step

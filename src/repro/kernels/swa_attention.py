"""Pallas TPU flash-style causal sliding-window attention.

Enables ``long_500k`` decode/prefill on dense architectures (DESIGN.md §5):
compute per query tile touches only the KV tiles inside the window, so cost
is O(T * W) instead of O(T^2).

Grid (H, nq, nkv_vis): for query tile i, only ``nkv_vis = W/bk + 1`` KV
tiles can be visible; the KV block index map clamps ``i - nkv_vis + 1 + j``
into range and the in-kernel mask removes any out-of-window/acausal pair.
Online softmax state (m, l, acc) lives in VMEM scratch, f32; the epilogue
normalizes on the last KV step. Block sizes are MXU-aligned (128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gram_matvec import resolve_interpret

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                window: int, bq: int, bk: int, nkv_vis: int, seq: int):
    i = pl.program_id(1)       # query tile
    j = pl.program_id(2)       # visible-KV step

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # absolute positions: the KV tile index was clamped in the index map,
    # so recompute it here the same way to build the mask. A clamped
    # (raw < 0) visit duplicates tile 0 — mask it out entirely, otherwise
    # its softmax mass would be double-counted.
    raw = i - nkv_vis + 1 + j
    kt = jnp.maximum(raw, 0)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kt * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = (kpos <= qpos) & (kpos > qpos - window) & (kpos < seq) & \
        (qpos < seq) & (raw >= 0)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nkv_vis - 1)
    def _():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def swa_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         window: int, block_q: int = 128,
                         block_k: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """q/k/v (T, H, dh) -> (T, H, dh); causal, window-limited attention.
    ``interpret`` defaults to backend-aware: compiled on TPU, interpreted
    elsewhere (the VMEM scratch shapes are TPU-specific)."""
    interpret = resolve_interpret(interpret, tpu_only=True)
    T, H, dh = q.shape
    bq, bk = min(block_q, T), min(block_k, T)
    pad = (-T) % max(bq, bk)
    bq = bk = min(bq, bk)
    pad = (-T) % bq
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nq = Tp // bq
    nkv_vis = min(nq, window // bk + 2)   # tiles a query tile can see

    qh = q.transpose(1, 0, 2)             # (H, T, dh)
    kh = k.transpose(1, 0, 2)
    vh = v.transpose(1, 0, 2)

    def kv_index(h, i, j):
        return (h, jnp.maximum(i - nkv_vis + 1 + j, 0), 0)

    out = pl.pallas_call(
        functools.partial(_swa_kernel, window=window, bq=bq, bk=bk,
                          nkv_vis=nkv_vis, seq=T),
        grid=(H, nq, nkv_vis),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Tp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(1, 0, 2)[:T]

"""Jitted public wrappers for the Pallas kernels.

``interpret=None`` (the default) resolves per backend: on CPU the kernels
run in interpret mode (no Mosaic available); on a TPU/GPU runtime the same
BlockSpecs compile natively.  Pass an explicit bool to override (e.g. to
force interpret mode while debugging on an accelerator).
"""
from __future__ import annotations

from functools import partial

import jax

from .gram_matvec import gram_matvec_pallas
from .greedy_assign import greedy_assign_pallas
from .swa_attention import swa_attention_pallas

__all__ = ["gram_matvec", "swa_attention", "batched_gram_matvec",
           "greedy_assign"]


@partial(jax.jit, static_argnames=("interpret", "block_d", "block_b"))
def gram_matvec(X: jax.Array, theta: jax.Array, *,
                interpret: bool | None = None,
                block_d: int = 256, block_b: int = 256) -> jax.Array:
    """h(X) = X X^T theta via the Pallas kernel. X (d, b), theta (d,)."""
    return gram_matvec_pallas(X, theta, interpret=interpret,
                              block_d=block_d, block_b=block_b)


@partial(jax.jit, static_argnames=("interpret",))
def batched_gram_matvec(Xs: jax.Array, theta: jax.Array, *,
                        interpret: bool | None = None) -> jax.Array:
    """vmapped over the task axis: Xs (n, d, b) -> (n, d)."""
    return jax.vmap(lambda X: gram_matvec_pallas(X, theta,
                                                 interpret=interpret))(Xs)


@partial(jax.jit, static_argnames=("window", "interpret", "block_q",
                                   "block_k"))
def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
                  interpret: bool | None = None, block_q: int = 128,
                  block_k: int = 128) -> jax.Array:
    """Causal sliding-window flash attention. q/k/v (T, H, dh)."""
    return swa_attention_pallas(q, k, v, window=window, interpret=interpret,
                                block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("interpret", "block_trials"))
def greedy_assign(W: jax.Array, order: jax.Array, epick: jax.Array,
                  need_row: jax.Array | None = None, *,
                  interpret: bool | None = None,
                  block_trials: int = 128) -> jax.Array:
    """Batched greedy row assignment via the Pallas kernel.  ``W`` (n, n)
    coverage weights, ``order``/``epick``/``need_row`` (B, n) ->
    worker-of-row (B, n) int32 (see ``ref.greedy_assign_ref``)."""
    return greedy_assign_pallas(W, order, epick, need_row,
                                interpret=interpret,
                                block_trials=block_trials)

"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gram_matvec_ref", "swa_attention_ref"]


def gram_matvec_ref(X: jax.Array, theta: jax.Array) -> jax.Array:
    """The paper's per-task computation h(X_i) = X_i X_i^T theta,
    X (d, b), theta (d,) -> (d,). Computed as X @ (X^T @ theta) — never
    materializing the (d, d) Gram matrix."""
    u = jnp.einsum("db,d->b", X.astype(jnp.float32),
                   theta.astype(jnp.float32))
    return jnp.einsum("db,b->d", X.astype(jnp.float32), u).astype(X.dtype)


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int) -> jax.Array:
    """Causal sliding-window attention. q/k/v (T, H, dh) -> (T, H, dh).
    Position t attends to positions (t-window, t]."""
    T, H, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)

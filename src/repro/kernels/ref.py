"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gram_matvec_ref", "swa_attention_ref", "greedy_assign_ref"]


def gram_matvec_ref(X: jax.Array, theta: jax.Array) -> jax.Array:
    """The paper's per-task computation h(X_i) = X_i X_i^T theta,
    X (d, b), theta (d,) -> (d,). Computed as X @ (X^T @ theta) — never
    materializing the (d, d) Gram matrix."""
    u = jnp.einsum("db,d->b", X.astype(jnp.float32),
                   theta.astype(jnp.float32))
    return jnp.einsum("db,b->d", X.astype(jnp.float32), u).astype(X.dtype)


def swa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int) -> jax.Array:
    """Causal sliding-window attention. q/k/v (T, H, dh) -> (T, H, dh).
    Position t attends to positions (t-window, t]."""
    T, H, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = (kpos <= qpos) & (kpos > qpos - window)
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def greedy_assign_ref(W: jax.Array, order: jax.Array, epick: jax.Array,
                      need_row: jax.Array | None = None) -> jax.Array:
    """Greedy row-assignment pick loop (oracle twin of the
    ``greedy_assign`` Pallas kernel; shared math with
    ``repro.core.scheduling.greedy_row_assignment_batch``).

    ``W`` is the static (n, n) float32 coverage-weight matrix of a TO
    matrix ``C``: ``W[p, t] = sum_j gamma**j * [C[p, j] == t]`` over the
    active slots of row ``p`` — so a row's greedy score is the single
    matvec ``cov @ W[p]`` and picking row ``p`` adds ``W[p] / e`` to the
    per-task coverage.  ``order`` (B, n) int32 lists each trial's pickers
    fastest-first; ``epick`` (B, n) float32 the matching sorted delay
    estimates (pre-clamped away from zero); ``need_row`` (B, n), when
    given, marks rows holding backlogged tasks — while any un-taken row is
    needed, the argmin runs over those rows only (reissue priority).

    Returns ``worker_of_row`` (B, n) int32.  Ties break to the lowest row
    index (argmin semantics), matching the per-trial scan this replaces.
    """
    B, n = order.shape
    W = W.astype(jnp.float32)
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    lanes = jnp.arange(n)[None, :]

    def pick(carry, t):
        cov, taken, wout = carry
        scores = jnp.where(taken, big, cov @ W.T)
        if need_row is None:
            sel = scores
        else:
            pref = jnp.where((need_row > 0) & ~taken, scores, big)
            has = jnp.min(pref, axis=-1, keepdims=True) < big
            sel = jnp.where(has, pref, scores)
        p = jnp.argmin(sel, axis=-1)                 # ties -> lowest row
        hit = lanes == p[:, None]
        wout = jnp.where(hit, order[:, t][:, None], wout)
        taken = taken | hit
        cov = cov + jnp.take(W, p, axis=0) / epick[:, t][:, None]
        return (cov, taken, wout), None

    init = (jnp.zeros((B, n), jnp.float32), jnp.zeros((B, n), bool),
            jnp.zeros((B, n), jnp.int32))
    (_, _, wout), _ = jax.lax.scan(pick, init, jnp.arange(n))
    return wout

"""Pallas TPU kernel for the paper's per-task computation
h(X) = X (X^T theta)  — the linear-regression DGD hot spot (Sec. VI).

TPU adaptation (DESIGN.md §6): never materialize the (d, d) Gram matrix.
Two MXU-tiled passes over X held in (128-aligned) VMEM blocks:

  pass 1:  u[j]  = sum_i X[i, j]^T theta[i]     (grid: d-tiles x b-tiles)
  pass 2:  y[i]  = sum_j X[i, j] u[j]           (grid: b-tiles x d-tiles)

Each pass accumulates its output block across the sequential TPU grid axis
(zero-init on the first visit) — the standard Pallas reduction pattern.
Vectors are carried as (n, 1) 2-D refs (TPU layout requirement).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 256
DEFAULT_BLOCK_B = 256


def default_interpret(*, tpu_only: bool = False) -> bool:
    """Interpret Pallas kernels only when no accelerator is attached: on an
    accelerator backend the same BlockSpecs compile natively; on CPU
    interpret mode is the only way to run them.  Kernels using TPU-specific
    primitives (e.g. ``pltpu.VMEM`` scratch) pass ``tpu_only=True`` so they
    stay interpreted on GPU, where Triton cannot lower them."""
    compiled = ("tpu",) if tpu_only else ("tpu", "gpu", "cuda", "rocm")
    return jax.default_backend() not in compiled


def resolve_interpret(interpret: bool | None, *, tpu_only: bool = False
                      ) -> bool:
    return default_interpret(tpu_only=tpu_only) if interpret is None \
        else interpret


def _xt_theta_kernel(x_ref, th_ref, u_ref):
    """u[b_tile] += X[d_tile, b_tile]^T theta[d_tile]; grid (nd, nb)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)          # (bd, bb)
    th = th_ref[...].astype(jnp.float32)        # (bd, 1)
    u_ref[...] += jnp.dot(x.T, th, preferred_element_type=jnp.float32)


def _x_u_kernel(x_ref, u_ref, y_ref):
    """y[d_tile] += X[d_tile, b_tile] u[b_tile]; grid (nb, nd)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...].astype(jnp.float32)          # (bd, bb)
    u = u_ref[...].astype(jnp.float32)          # (bb, 1)
    y_ref[...] += jnp.dot(x, u, preferred_element_type=jnp.float32)


def gram_matvec_pallas(X: jax.Array, theta: jax.Array, *,
                       block_d: int = DEFAULT_BLOCK_D,
                       block_b: int = DEFAULT_BLOCK_B,
                       interpret: bool | None = None) -> jax.Array:
    """h(X) = X (X^T theta). X (d, b), theta (d,) -> (d,). ``interpret``
    defaults to backend-aware: compiled on TPU/GPU, interpreted on CPU."""
    interpret = resolve_interpret(interpret)
    d, b = X.shape
    bd, bb = min(block_d, d), min(block_b, b)
    pad_d = (-d) % bd
    pad_b = (-b) % bb
    Xp = jnp.pad(X, ((0, pad_d), (0, pad_b))) if (pad_d or pad_b) else X
    thp = jnp.pad(theta, (0, pad_d)) if pad_d else theta
    dp, bp = Xp.shape
    nd, nb = dp // bd, bp // bb
    th2 = thp[:, None]

    u = pl.pallas_call(
        _xt_theta_kernel,
        grid=(nd, nb),
        in_specs=[pl.BlockSpec((bd, bb), lambda i, j: (i, j)),
                  pl.BlockSpec((bd, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bb, 1), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(Xp, th2)

    y = pl.pallas_call(
        _x_u_kernel,
        grid=(nb, nd),
        in_specs=[pl.BlockSpec((bd, bb), lambda j, i: (i, j)),
                  pl.BlockSpec((bb, 1), lambda j, i: (j, 0))],
        out_specs=pl.BlockSpec((bd, 1), lambda j, i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp, 1), jnp.float32),
        interpret=interpret,
    )(Xp, u)

    return y[:d, 0].astype(X.dtype)

"""Pallas kernel for the adaptive greedy row assignment (paper Sec. V +
Egger et al., arXiv:2304.08589), batched over Monte-Carlo trials.

The greedy is a sequential pick loop — n pickers (fastest worker first),
each taking the row with the least discounted task coverage — that the
rounds engine runs per trial per round.  The pick loop is inherently
sequential, but with the static coverage-weight matrix
``W[p, t] = sum_j gamma**j * [C[p, j] == t]`` each step collapses to
dense lane-parallel ops over a block of trials:

  scores  = cov @ W^T                 (one MXU matmul per step)
  p       = argmin over rows          (min + iota trick, ties -> lowest)
  cov    += (onehot_p @ W) / e_pick   (one more matmul)

so the whole O(n^2 * r) scan becomes n small matmuls on a (block, n)
trial block held in VMEM — no gathers, no per-trial control flow.

``greedy_assign_pallas`` is the raw kernel (grid over trial blocks,
interpret-mode fallback on CPU); ``repro.kernels.ref.greedy_assign_ref``
is the pure-jnp oracle twin; ``repro.kernels.ops.greedy_assign`` the
jitted public wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gram_matvec import resolve_interpret

DEFAULT_BLOCK_TRIALS = 128


def _greedy_kernel(w_ref, order_ref, epick_ref, need_ref, out_ref):
    """One (block, n) trial block: run all n picks to completion.

    Refs: ``w_ref`` (n, n) f32 coverage weights; ``order_ref`` (bt, n)
    i32 pickers fastest-first; ``epick_ref`` (bt, n) f32 sorted delay
    estimates (pre-clamped away from zero); ``need_ref`` (bt, n) f32
    reissue priorities (all-zero = none); ``out_ref`` (bt, n) i32
    worker-of-row."""
    W = w_ref[...]
    order = order_ref[...]
    epick = epick_ref[...]
    need = need_ref[...]
    bt, n = order.shape
    wt = W.T
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1)

    def pick(t, carry):
        cov, taken, wout = carry
        scores = jnp.where(taken, big, jnp.dot(cov, wt))
        pref = jnp.where((need > 0) & ~taken, scores, big)
        has = jnp.min(pref, axis=-1, keepdims=True) < big
        sel = jnp.where(has, pref, scores)
        m = jnp.min(sel, axis=-1, keepdims=True)
        p = jnp.min(jnp.where(sel == m, lanes, n), axis=-1, keepdims=True)
        hit = lanes == p                                   # ties -> lowest
        wid = jax.lax.dynamic_slice_in_dim(order, t, 1, axis=1)
        wout = jnp.where(hit, wid, wout)
        taken = taken | hit
        e_t = jax.lax.dynamic_slice_in_dim(epick, t, 1, axis=1)
        cov = cov + jnp.dot(hit.astype(jnp.float32), W) / e_t
        return cov, taken, wout

    init = (jnp.zeros((bt, n), jnp.float32), jnp.zeros((bt, n), jnp.bool_),
            jnp.zeros((bt, n), jnp.int32))
    _, _, wout = jax.lax.fori_loop(0, n, pick, init)
    out_ref[...] = wout


def greedy_assign_pallas(W: jax.Array, order: jax.Array, epick: jax.Array,
                         need_row: jax.Array | None = None, *,
                         block_trials: int = DEFAULT_BLOCK_TRIALS,
                         interpret: bool | None = None) -> jax.Array:
    """Batched greedy row assignment.  ``W`` (n, n) f32 static coverage
    weights, ``order``/``epick``/``need_row`` (B, n) per-trial pick data
    (see ``repro.kernels.ref.greedy_assign_ref`` for semantics) ->
    ``worker_of_row`` (B, n) int32.  ``interpret`` defaults to
    backend-aware: compiled on TPU/GPU, interpreted on CPU."""
    interpret = resolve_interpret(interpret)
    B, n = order.shape
    if need_row is None:
        need_row = jnp.zeros((B, n), jnp.float32)
    bt = min(block_trials, B)
    pad = (-B) % bt
    if pad:
        # edge-pad: padded trials recompute the last real trial's picks and
        # are sliced off — rows are independent, so real lanes are exact.
        order = jnp.pad(order, ((0, pad), (0, 0)), mode="edge")
        epick = jnp.pad(epick, ((0, pad), (0, 0)), mode="edge")
        need_row = jnp.pad(need_row, ((0, pad), (0, 0)), mode="edge")
    Bp = B + pad

    out = pl.pallas_call(
        _greedy_kernel,
        grid=(Bp // bt,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0)),
                  pl.BlockSpec((bt, n), lambda i: (i, 0)),
                  pl.BlockSpec((bt, n), lambda i: (i, 0)),
                  pl.BlockSpec((bt, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, n), jnp.int32),
        interpret=interpret,
    )(W.astype(jnp.float32), order.astype(jnp.int32),
      epick.astype(jnp.float32), need_row.astype(jnp.float32))

    return out[:B]

"""Architecture registry + assigned input shapes + abstract input specs.

``--arch <id>`` resolution, the four assigned input shapes, the long-context
variants (DESIGN.md §5 shape skips), and ``input_specs`` producing
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig
from . import (jamba_v01_52b, gemma3_4b, mistral_nemo_12b, qwen2_72b,
               deepseek_v3_671b, rwkv6_1p6b, whisper_base,
               llama4_maverick_400b, llava_next_34b, phi4_mini_3p8b,
               paper_regression)

_MODULES = {
    "jamba-v0.1-52b": jamba_v01_52b,
    "gemma3-4b": gemma3_4b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "qwen2-72b": qwen2_72b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "rwkv6-1.6b": rwkv6_1p6b,
    "whisper-base": whisper_base,
    "llama4-maverick-400b-a17b": llama4_maverick_400b,
    "llava-next-34b": llava_next_34b,
    "phi4-mini-3.8b": phi4_mini_3p8b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        return _MODULES[arch].config()
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")


def regression_config():
    return paper_regression.config()


# ---------------------------- input shapes -----------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

LONG_WINDOW = 8192


def shape_supported(cfg: ModelConfig, shape: str) -> bool:
    """DESIGN.md §5 skip rules. Only whisper-base skips long_500k (its
    decoder has no semantic analogue at 524k); everything else runs —
    dense archs via the sliding-window long-variant, DeepSeek via the MLA
    compressed cache, SSM/hybrid natively."""
    if shape == "long_500k" and cfg.arch_type == "audio":
        return False
    return True


def long_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic / bounded-memory decode variant for long_500k:

    * ssm (rwkv6): native O(1) state — unchanged.
    * mla (deepseek): compressed-KV cache is the enabler — unchanged.
    * hybrid (jamba) + all GQA dense archs: full-attention layers switch to
      a sliding-window (ring-buffer KV, window LONG_WINDOW) variant.
    """
    if cfg.ssm_kind == "rwkv6" or cfg.kv_lora_rank:
        return dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len,
                                                        SHAPES["long_500k"].seq_len + 8))
    return dataclasses.replace(
        cfg, name=cfg.name + "+swa", sliding_window=LONG_WINDOW,
        local_global_pattern=(1, 0),  # all layers local
        max_seq_len=SHAPES["long_500k"].seq_len + 8)


def resolve(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Config actually lowered for (arch, shape)."""
    if shape == "long_500k":
        return long_variant(cfg)
    if SHAPES[shape].kind == "decode":
        return dataclasses.replace(
            cfg, max_seq_len=min(cfg.max_seq_len, SHAPES[shape].seq_len))
    return cfg


# ---------------------------- abstract inputs --------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str, *, n: int = 16,
                r: int = 1) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the given shape.

    train: slot-major straggler-round batches (r, n, b, S) (+ modality
           extras); prefill: (B, S) tokens; decode: (B, 1) token + KV cache
           (the cache spec is built by the caller from init_cache's shapes).
    """
    sh = SHAPES[shape_name]
    S, B = sh.seq_len, sh.global_batch
    i32 = jnp.int32
    f32 = jnp.float32
    if sh.kind == "train":
        assert B % n == 0
        b = B // n
        St = S - (cfg.frontend_seq or 0)
        spec = {"slot_tokens": _sds((r, n, b, St), i32),
                "slot_labels": _sds((r, n, b, St), i32)}
        if cfg.frontend_seq:
            spec["slot_embeds"] = _sds((r, n, b, cfg.frontend_seq,
                                        cfg.frontend_dim), f32)
        if cfg.encoder_layers:
            spec["slot_frames"] = _sds((r, n, b, cfg.encoder_seq,
                                        cfg.frontend_dim), f32)
        return spec
    if sh.kind == "prefill":
        St = S - (cfg.frontend_seq or 0)
        spec = {"tokens": _sds((B, St), i32)}
        if cfg.frontend_seq:
            spec["embeds"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                  f32)
        if cfg.encoder_layers:
            spec["enc_frames"] = _sds((B, cfg.encoder_seq, cfg.frontend_dim),
                                      f32)
        return spec
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((B, 1), i32)}

"""whisper-base [audio] — encoder-decoder; conv/mel frontend STUBBED
(input_specs provides precomputed 1500-frame embeddings). [arXiv:2212.04356]

6L decoder + 6L encoder, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865
(padded to 51968 for model-axis sharding). Decoder positions beyond the
model card's 448 are exercised only mechanically by decode_32k (DESIGN.md
§5); long_500k is SKIPPED for this arch.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        arch_type="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51_865,
        encoder_layers=6,
        encoder_seq=1500,
        frontend="audio_stub",
        frontend_dim=512,        # post-conv frame embedding width
        max_seq_len=32_768,      # decode_32k (beyond-spec length)
    )

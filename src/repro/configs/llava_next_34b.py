"""llava-next-34b [vlm] — anyres tiling; ViT tower + projector STUBBED
(input_specs provides patch embeddings). [hf:llava-hf/llava-v1.6-*, 34B
backbone = Yi-34B dims]

60L, d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000. The assigned
input shapes allocate 1024 positions of each sequence to anyres patch
embeddings (CLIP-ViT-L/336 hidden = 1024).
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        arch_type="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64_000,
        frontend="vision_stub",
        frontend_seq=1024,       # anyres patch tokens per sequence
        frontend_dim=1024,       # CLIP-ViT-L hidden
        rope_theta=5e6,
        max_seq_len=131_072,
    )

"""mistral-nemo-12b [dense] — 128k context GQA.
[hf:mistralai/Mistral-Nemo-Base-2407]

40L, d_model=5120, 32H (GQA kv=8, head_dim=128), d_ff=14336, vocab=131072.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        arch_type="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131_072,
        rope_theta=1e6,
        max_seq_len=131_072,
    )

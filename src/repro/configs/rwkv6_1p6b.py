"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892]

24L, d_model=2048 (32 heads of 64 for the WKV state), d_ff=7168 (channel
mix), vocab=65536. Decode state is O(1) per layer -> native long_500k.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        arch_type="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,              # wkv head dim 64
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        ssm_kind="rwkv6",
        max_seq_len=1_048_576,   # state is O(1); no positional limit
    )

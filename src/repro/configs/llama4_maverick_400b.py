"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, interleaved MoE, early-fusion multimodal (vision stub).
[hf:meta-llama/Llama-4-Scout-17B-16E family card, Maverick dims]

48L, d_model=5120, 40H (GQA kv=8), d_ff=8192, vocab=202048; MoE every other
layer (128e top-1 + 1 shared), dense layers use the same 8192 width.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        arch_type="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=128,
        n_shared_experts=1,
        experts_per_token=1,
        d_ff_expert=8192,
        moe_period=2,
        moe_offset=1,
        frontend="vision_stub",  # early fusion: patch embeds prepended
        frontend_seq=0,          # text-only for the assigned input shapes
        frontend_dim=1408,
        rope_theta=5e5,
        max_seq_len=1_048_576,
    )

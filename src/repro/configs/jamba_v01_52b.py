"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536.
Jamba block = 8 layers with one attention layer (offset 4); MoE replaces the
MLP on every other layer (16 experts, top-2).
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        ssm_kind="mamba",
        ssm_period=8,
        ssm_attn_offset=4,
        d_state=16,
        d_conv=4,
        expand=2,
        n_experts=16,
        experts_per_token=2,
        d_ff_expert=14336,
        moe_period=2,
        moe_offset=1,
        rope_theta=1e6,
        max_seq_len=262_144,
    )

"""qwen2-72b [dense] — GQA with QKV bias. [arXiv:2407.10671]

80L, d_model=8192, 64H (GQA kv=8), d_ff=29568, vocab=152064.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        arch_type="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1e6,
        max_seq_len=131_072,
    )

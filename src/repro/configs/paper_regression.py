"""The paper's own reference scenario (Sec. VI): distributed linear
regression via DGD with h(X_i) = X_i X_i^T theta. Not an LM config — used
by benchmarks and examples."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RegressionConfig:
    N: int = 900          # samples (paper Fig. 5)
    d: int = 400          # features
    n: int = 15           # workers / tasks
    r: int = 3            # computation load
    k: int = 15           # computation target
    lr: float = 0.01      # paper's constant learning rate
    iterations: int = 500
    schedule: str = "ss"


def config() -> RegressionConfig:
    return RegressionConfig()

"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed experts (top-8).
[arXiv:2412.19437]

61L, d_model=7168, 128 heads (MLA; assigned GQA kv=128 ≙ full heads through
the latent), d_ff_expert=2048 (assigned d_ff), vocab=129280. First 3 layers
dense (d_ff=18432 per the paper). MLA dims: q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v_head=128. The MTP auxiliary head is out of scope
(DESIGN.md §8).
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,            # qk_nope + qk_rope
        d_ff=18432,              # dense-prefix MLP width (paper)
        vocab_size=129_280,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=256,
        n_shared_experts=1,
        experts_per_token=8,
        d_ff_expert=2048,        # assigned d_ff (routed expert width)
        dense_prefix=3,
        moe_period=1,
        rope_theta=1e4,
        max_seq_len=131_072,
    )

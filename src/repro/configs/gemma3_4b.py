"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family card, 4b dims]

34L, d_model=2560, 8H (GQA kv=4), d_ff=10240, vocab=262144.
Local layers use a 1024-token sliding window; every 6th layer is global.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        arch_type="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262_144,
        sliding_window=1024,
        local_global_pattern=(5, 1),
        attn_logit_softcap=None,
        rope_theta=1e6,
        max_seq_len=131_072,
    )

"""phi4-mini-3.8b [dense] — RoPE + SwiGLU + GQA. [arXiv:2412.08905]

32L, d_model=3072, 24H (GQA kv=8), d_ff=8192, vocab=200064.
"""
from ..models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        arch_type="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200_064,
        rope_theta=1e4,
        max_seq_len=131_072,
    )

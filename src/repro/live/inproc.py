"""In-process transport: queue-pair ``Comm``s behind a module-level
listener registry.  Deterministic (single event loop, FIFO queues) and
dependency-free — the default transport for tests and ``run_live``.

Every message still round-trips through JSON (see ``comm``), so inproc and
tcp carry byte-identical payload semantics.
"""
from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional

from .comm import Comm, CommClosedError, Listener

__all__ = ["InProcComm", "InProcListener", "connect_inproc", "listen_inproc"]

_CLOSE = object()                      # queue sentinel: peer closed

# name -> live listener (one listener per inproc address at a time)
_LISTENERS: Dict[str, "InProcListener"] = {}


class InProcComm(Comm):
    def __init__(self, rx: asyncio.Queue, tx: asyncio.Queue, name: str,
                 side: str):
        self._rx = rx
        self._tx = tx
        self._closed = False
        self._peer_closed = False
        self.local_address = f"inproc://{name}#{side}"
        self.peer_address = f"inproc://{name}"

    async def send(self, msg: dict) -> None:
        if self._closed or self._peer_closed:
            raise CommClosedError(f"{self.local_address}: channel closed")
        # serialize exactly like the tcp transport so payload semantics
        # (tuples -> lists, float repr round-trip) are transport-invariant
        self._tx.put_nowait(json.dumps(msg))

    async def recv(self) -> dict:
        if self._peer_closed:
            raise CommClosedError(f"{self.local_address}: peer closed")
        item = await self._rx.get()
        if item is _CLOSE:
            self._peer_closed = True
            raise CommClosedError(f"{self.local_address}: peer closed")
        return json.loads(item)

    async def aclose(self) -> None:
        if not self._closed:
            self._closed = True
            self._tx.put_nowait(_CLOSE)

    @property
    def closed(self) -> bool:
        return self._closed or self._peer_closed


class InProcListener(Listener):
    def __init__(self, name: str):
        self._name = name
        self._pending: asyncio.Queue = asyncio.Queue()
        self.address = f"inproc://{name}"
        self._closed = False

    def _incoming(self) -> InProcComm:
        a_to_b: asyncio.Queue = asyncio.Queue()
        b_to_a: asyncio.Queue = asyncio.Queue()
        server_side = InProcComm(a_to_b, b_to_a, self._name, "server")
        client_side = InProcComm(b_to_a, a_to_b, self._name, "client")
        self._pending.put_nowait(server_side)
        return client_side

    async def accept(self) -> InProcComm:
        if self._closed:
            raise CommClosedError(f"{self.address}: listener closed")
        return await self._pending.get()

    async def aclose(self) -> None:
        self._closed = True
        if _LISTENERS.get(self._name) is self:
            del _LISTENERS[self._name]


async def listen_inproc(name: str) -> InProcListener:
    if name in _LISTENERS:
        raise ValueError(f"inproc://{name} already has a listener")
    lst = InProcListener(name)
    _LISTENERS[name] = lst
    return lst


async def connect_inproc(name: str) -> InProcComm:
    lst: Optional[InProcListener] = _LISTENERS.get(name)
    if lst is None or lst._closed:
        raise CommClosedError(f"inproc://{name}: no listener")
    return lst._incoming()

"""Live master: drives rounds over any ``Comm`` transport, closing each
round at ``k`` distinct results (or at the deadline under the configured
fallback policy), feeding censored arrival feedback to the adaptive
scheduler, and recording every run as a replayable ``DelayTrace``.

Authoritative statistics come from the ASSEMBLED delay tables, scored with
the MC engine's own fused arithmetic (``_build_eval`` at the engine's
``(1, n, r)`` chunk shape): ``s = cumsum(T1) + T2`` (eq. 1), the gather
plan for per-task arrivals (eq. 2), ``top_k`` for the k-th order statistic.
Cells never covered by a received message stay +inf — fault-censoring
semantics, a version-2 trace.  Because the recorded tables are exactly the
scorer's input, ``sweep_rounds(TraceProcess(result.trace), trials=1)``
reproduces ``result.per_round`` bit-for-bit for static configs (adaptive
runs re-derive greedy decisions on replay, so they match in distribution,
not bitwise).

Round-close protocol: the master counts distinct tasks over incoming
``result`` messages (under a closing deadline policy, only messages whose
virtual arrival beats the deadline count) and broadcasts ``close`` at
``k``; with ``time_scale > 0`` a wall-clock timer additionally enforces
the deadline.  It then keeps draining until every worker's ``round_done``
(a dropped connection counts as done — the dead worker's cells stay +inf),
so late in-flight results still land in the trace.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.spec import RoundConfig
from ..core.trace import DelayTrace
from .comm import Comm, CommClosedError, Listener, listen
from .protocol import (CLOSE, HELLO, RESULT, ROUND, ROUND_DONE, SHUTDOWN,
                       WELCOME)
from .worker import run_worker

__all__ = ["Master", "LiveResult", "RoundReport", "run_live"]

_INPROC_SEQ = itertools.count()


@dataclasses.dataclass
class RoundReport:
    """One round's outcome, as the master saw it."""
    round: int
    t_done: float            # effective completion (deadline-capped)
    realized: int            # distinct results that made the round
    missed: bool             # blew the deadline (policy-dependent meaning)
    closed_early: bool       # master broadcast ``close`` before all done
    results: int             # result messages received (incl. post-close)
    stalled: int             # workers that reported a stuck slot
    dead: int                # connections lost by the end of the round


@dataclasses.dataclass
class LiveResult:
    """A live run: per-round completion times + the recorded trace."""
    config: RoundConfig
    per_round: np.ndarray    # (rounds,) float64 effective completion times
    realized: np.ndarray     # (rounds,) int distinct results per round
    missed: np.ndarray       # (rounds,) bool deadline misses
    trace: DelayTrace        # (rounds, 1, n, r) float32, +inf = censored
    reports: List[RoundReport]

    @property
    def mean(self) -> float:
        return float(self.per_round.mean())


def _make_scorer(cfg: RoundConfig):
    """Jitted ``(T1, T2, row_of_worker, loads_w) -> (v, tau, arr_w)`` over
    one round's machine-major (n, r) tables — the exact arithmetic the MC
    engine and the trainer's ``StragglerAggregator._round_fn`` run, at the
    same (1, n, r) chunk shape, so a recorded trace replays bit-exactly.

    ``v`` is the k-th distinct-task arrival (f32 scalar), ``tau`` the
    per-task arrivals (n,), ``arr_w`` the worker-major per-slot message
    arrivals (the censored-feedback signal, matching the aggregator's
    ``arr_w = s2[row_of_worker]``)."""
    import jax
    import jax.numpy as jnp

    from ..core import montecarlo as mc

    n, r = cfg.n, cfg.width
    if cfg.adaptive:
        base = cfg.base_matrix()
        if cfg.rebalance:
            sp_v = mc.to_spec("v", base)
            sp_tau = mc.tau_spec("tau", base)
        else:
            sp_v = mc.to_spec("v", base, messages=cfg.messages,
                              loads=cfg.loads)
            sp_tau = mc.tau_spec("tau", base, messages=cfg.messages,
                                 loads=cfg.loads)
    else:
        sp_v = cfg.to_scheme_spec("v")
        sp_tau = mc.tau_spec("tau", cfg.base_matrix(),
                             messages=cfg.messages, loads=cfg.loads,
                             comm_eps=cfg.comm_eps)
    eval_fn = mc._build_eval((sp_v, sp_tau), n, r, ks=cfg.k)
    mmap = mc._slot_map_of(sp_v)
    rebalance = cfg.rebalance

    @jax.jit
    def _score(T1, T2, row_of_worker, loads_w):
        # eq. 1 at the engine's (chunk=1, n, r) shape — the identical XLA
        # program the trace replay runs, so the two agree bit-for-bit
        s = (jnp.cumsum(T1[None], axis=-1) + T2[None])[0]
        worker_of_row = jnp.argsort(row_of_worker)
        s2 = s[worker_of_row]                            # row-major arrivals
        arr2 = s2 if mmap is None else mc._apply_slot_map(s2, mmap)
        if rebalance:
            l_row = loads_w[worker_of_row]
            live_slots = jnp.arange(r)[None, :] < l_row[:, None]
            s2 = jnp.where(live_slots, s2, jnp.inf)
            arr2 = jnp.where(live_slots, arr2, jnp.inf)
        out = eval_fn(s2[None])
        return (out["v"][0, -1], out["tau"][0], arr2[row_of_worker])

    return _score


def _make_scheduler(cfg: RoundConfig):
    """The adaptive scheduler exactly as ``StragglerAggregator`` builds it
    (or None for static schedules)."""
    if not cfg.adaptive:
        return None
    from ..core import scheduling
    kw = dict(beta=cfg.feedback_beta, gamma=cfg.coverage_gamma)
    if cfg.dead_after is not None:
        kw.update(dead_after=cfg.dead_after, target_k=cfg.k)
    if cfg.rebalance:
        return scheduling.AdaptiveScheduler(cfg.base_matrix(),
                                            loads=cfg.loads, rebalance=True,
                                            **kw)
    return scheduling.AdaptiveScheduler(cfg.to_matrix(), **kw)


async def _pump(w: int, comm: Comm, queue: asyncio.Queue) -> None:
    """Forward every message from worker ``w`` into the central queue;
    ``(w, None)`` marks a dropped connection."""
    try:
        while True:
            queue.put_nowait((w, await comm.recv()))
    except CommClosedError:
        queue.put_nowait((w, None))


class Master:
    """Owns ``n`` worker connections and runs ``rounds`` rounds.

    ``time_scale`` maps virtual delay units to wall seconds (0 = as fast
    as possible: semantics identical, no waiting); ``abort_on_close``
    tells workers to cancel outstanding work when the round closes (real
    cluster behavior — leaves +inf holes in the trace) or to finish and
    deliver everything (dense tables: the live run then matches
    ``sweep_rounds(process, trials=1, seed)`` exactly).
    """

    def __init__(self, config: RoundConfig, *, rounds: int,
                 listener: Listener, time_scale: float = 0.0,
                 abort_on_close: bool = True):
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        self.config = config
        self.rounds = int(rounds)
        self.listener = listener
        self.time_scale = float(time_scale)
        self.abort_on_close = bool(abort_on_close)
        self.scheduler = _make_scheduler(config)
        self._score = _make_scorer(config)
        self._comms: Dict[int, Comm] = {}

    async def _handshake(self) -> None:
        cfg_dict = self.config.to_dict()
        for w in range(self.config.n):
            comm = await self.listener.accept()
            hello = await comm.recv()
            if hello.get("type") != HELLO:
                raise RuntimeError(f"expected hello, got {hello!r}")
            await comm.send({"type": WELCOME, "worker": w,
                             "config": cfg_dict, "rounds": self.rounds,
                             "time_scale": self.time_scale,
                             "abort_on_close": self.abort_on_close})
            self._comms[w] = comm

    async def _broadcast(self, msg: dict, alive: Optional[set] = None):
        for w, comm in self._comms.items():
            if alive is not None and w not in alive:
                continue
            try:
                await comm.send(msg)
            except CommClosedError:
                pass

    def _plan_round(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(C_eff worker-major, row_of_worker, loads_w) for the coming
        round — adaptive schedules ask the scheduler, static ones reuse
        the config's matrix."""
        cfg = self.config
        if self.scheduler is None:
            return cfg.to_matrix(), np.arange(cfg.n), cfg.load_vector
        C_eff = self.scheduler.matrix()
        row_of_worker = self.scheduler.row_of_worker()
        loads_w = (self.scheduler.loads() if cfg.rebalance
                   else cfg.load_vector)
        return C_eff, row_of_worker, loads_w

    async def run(self) -> LiveResult:
        cfg = self.config
        n, r, k = cfg.n, cfg.width, cfg.k
        DL = None if cfg.deadline is None else np.float32(cfg.deadline)
        closing = cfg.deadline is not None and cfg.deadline_policy != "wait"

        await self._handshake()
        queue: asyncio.Queue = asyncio.Queue()
        pumps = [asyncio.create_task(_pump(w, c, queue))
                 for w, c in self._comms.items()]
        dead: set = set()
        T1_all = np.full((self.rounds, n, r), np.inf, np.float32)
        T2_all = np.full((self.rounds, n, r), np.inf, np.float32)
        per_round = np.zeros(self.rounds, np.float64)
        realized_a = np.zeros(self.rounds, np.int64)
        missed_a = np.zeros(self.rounds, bool)
        reports: List[RoundReport] = []

        try:
            for t in range(self.rounds):
                C_eff, row_of_worker, loads_w = self._plan_round()
                alive = set(range(n)) - dead
                expect = np.zeros((n, r), bool)     # cells a full round fills
                for w in alive:
                    row = [int(x) for x in C_eff[w] if x >= 0]
                    expect[w, :len(row)] = True
                    await self._comms[w].send(
                        {"type": ROUND, "round": t,
                         "row": int(row_of_worker[w]), "tasks": row,
                         "load": len(row)})
                T1_tab = T1_all[t]
                T2_tab = T2_all[t]
                got_tasks: set = set()
                done: set = set(dead)
                closed = False
                n_results = 0
                n_stalled = 0
                timer: Optional[asyncio.TimerHandle] = None
                if closing and self.time_scale > 0:
                    loop = asyncio.get_running_loop()
                    timer = loop.call_later(
                        float(DL) * self.time_scale,
                        lambda: queue.put_nowait((-1, {"type": "_deadline"})))
                while len(done) < n:
                    w, msg = await queue.get()
                    if msg is None:
                        dead.add(w)
                        done.add(w)
                        continue
                    mt = msg.get("type")
                    if mt == "_deadline":
                        if not closed:
                            closed = True
                            await self._broadcast({"type": CLOSE,
                                                   "round": t}, alive)
                        continue
                    if int(msg.get("round", -1)) != t:
                        continue               # stray late message
                    if mt == RESULT:
                        n_results += 1
                        t1 = np.asarray(msg["t1"], np.float32)
                        T1_tab[w, :t1.size] = t1
                        j1 = int(msg["slots"][1])
                        T2_tab[w, j1] = np.float32(msg["t2"])
                        arr = float(msg["arrival"])
                        if not closing or arr <= float(DL):
                            got_tasks.update(int(x) for x in msg["tasks"])
                        if not closed and len(got_tasks) >= k:
                            closed = True
                            await self._broadcast({"type": CLOSE,
                                                   "round": t}, alive)
                    elif mt == ROUND_DONE:
                        done.add(w)
                        n_stalled += int(bool(msg.get("stalled")))
                if timer is not None:
                    timer.cancel()

                # ---- authoritative stats from the assembled tables ------
                v_j, tau_j, arr_w = self._score(
                    T1_tab, T2_tab, np.asarray(row_of_worker),
                    np.asarray(loads_w))
                v = np.float32(v_j)
                tau = np.asarray(tau_j)
                if closing:                    # mirror engine _policy_close
                    v_eff = min(v, DL)
                    by = int((tau <= DL).sum())
                    realized = min(by, k)
                    missed = by < k
                elif DL is not None:           # wait: flag, don't cap
                    v_eff = v
                    realized = min(int(np.isfinite(tau).sum()), k)
                    missed = not (v <= DL)
                else:
                    v_eff = v
                    realized = min(int(np.isfinite(tau).sum()), k)
                    missed = False
                per_round[t] = float(v_eff)
                realized_a[t] = realized
                missed_a[t] = missed
                if self.scheduler is not None:
                    holes = not np.isfinite(T1_tab[expect]).all()
                    if cfg.censored_feedback or holes:
                        # a real master only sees what arrived in time;
                        # +inf holes additionally force censoring (a plain
                        # mean over a holey table would pin the EMA at inf)
                        self.scheduler.observe(T1_tab,
                                               arrivals=np.asarray(arr_w),
                                               t_done=float(v_eff))
                    else:
                        self.scheduler.observe(T1_tab)
                    if cfg.deadline_policy == "reissue":
                        delivered = ((tau <= np.float32(v_eff))
                                     & np.isfinite(tau))
                        self.scheduler.set_need(~delivered)
                reports.append(RoundReport(
                    round=t, t_done=float(v_eff), realized=realized,
                    missed=missed, closed_early=closed, results=n_results,
                    stalled=n_stalled, dead=len(dead)))
            await self._broadcast({"type": SHUTDOWN})
        finally:
            for p in pumps:
                p.cancel()
            for comm in self._comms.values():
                await comm.aclose()

        trace = DelayTrace(T1_all, T2_all, meta={
            "source": "live", "config": cfg.to_dict(),
            "rounds": self.rounds, "time_scale": self.time_scale,
            "abort_on_close": self.abort_on_close})
        return LiveResult(config=cfg, per_round=per_round,
                          realized=realized_a, missed=missed_a,
                          trace=trace, reports=reports)


async def _run_live_async(config: RoundConfig, process, rounds: int, *,
                          address: Optional[str] = None,
                          time_scale: float = 0.0,
                          abort_on_close: bool = True) -> LiveResult:
    if address is None:
        address = f"inproc://live-{next(_INPROC_SEQ)}"
    listener = await listen(address)
    master = Master(config, rounds=rounds, listener=listener,
                    time_scale=time_scale, abort_on_close=abort_on_close)
    workers = [asyncio.create_task(run_worker(listener.address, process))
               for _ in range(config.n)]
    try:
        result = await master.run()
        await asyncio.gather(*workers)
    finally:
        for wt in workers:
            wt.cancel()
        await asyncio.gather(*workers, return_exceptions=True)
        await listener.aclose()
    return result


def run_live(config: RoundConfig, process, rounds: int, *,
             address: Optional[str] = None, time_scale: float = 0.0,
             abort_on_close: bool = True) -> LiveResult:
    """One-call live run: listener + ``config.n`` in-process workers + a
    master, all on a private event loop.  ``process`` is any delay source
    accepted by ``cluster.as_process`` (parametric or a replayed trace).

    With the defaults (``inproc`` transport, ``time_scale=0``) the run is
    deterministic and the recorded trace is dense: ``result.per_round``
    equals ``sweep_rounds(process, trials=1, seed=config.seed)`` exactly.
    Pass ``address="tcp://host:0"`` to exercise the TCP transport (workers
    connect to the ephemeral bound port), ``time_scale > 0`` to race real
    wall-clock deadlines."""
    return asyncio.run(_run_live_async(
        config, process, rounds, address=address, time_scale=time_scale,
        abort_on_close=abort_on_close))

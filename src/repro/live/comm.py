"""Pluggable transport abstraction for the live execution layer.

A ``Comm`` is one bidirectional, ordered, reliable message channel between
a master and a worker; a ``Listener`` accepts incoming ``Comm``s at an
address.  Two transports ship:

* ``inproc://<name>`` — in-process queue pairs (deterministic, used by the
  tests and the default ``run_live`` orchestration);
* ``tcp://<host>:<port>`` — length-prefixed JSON over asyncio TCP streams
  (multi-process clusters; ``port`` 0 binds an ephemeral port, read the
  bound address back from ``Listener.address``).

Messages are JSON-serializable dicts.  Both transports round-trip every
message through JSON (inproc included), so a config developed against
``inproc://`` behaves identically over ``tcp://`` — in particular float
values survive exactly (a float32 delay → shortest-repr JSON → float64 →
back to float32 is the identity).
"""
from __future__ import annotations

from typing import Tuple

__all__ = ["Comm", "Listener", "CommClosedError", "parse_address",
           "connect", "listen"]


class CommClosedError(ConnectionError):
    """The peer closed (or dropped) the channel."""


class Comm:
    """One ordered, reliable message channel.  Subclasses implement
    ``send`` / ``recv`` / ``aclose``; messages are JSON-safe dicts."""

    local_address: str = ""
    peer_address: str = ""

    async def send(self, msg: dict) -> None:
        raise NotImplementedError

    async def recv(self) -> dict:
        """Next message from the peer; raises ``CommClosedError`` once the
        channel is closed and drained."""
        raise NotImplementedError

    async def aclose(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class Listener:
    """Accepts incoming ``Comm`` connections at ``address``."""

    address: str = ""

    async def accept(self) -> Comm:
        raise NotImplementedError

    async def aclose(self) -> None:
        raise NotImplementedError


def parse_address(address: str) -> Tuple[str, str]:
    """Split ``"scheme://rest"`` and validate the scheme."""
    if "://" not in address:
        raise ValueError(f"address needs a scheme://, got {address!r} "
                         f"(use inproc://<name> or tcp://<host>:<port>)")
    scheme, rest = address.split("://", 1)
    if scheme not in ("inproc", "tcp"):
        raise ValueError(f"unknown transport scheme {scheme!r}; choose "
                         f"from ('inproc', 'tcp')")
    return scheme, rest


async def connect(address: str) -> Comm:
    """Open a ``Comm`` to the listener at ``address``."""
    scheme, rest = parse_address(address)
    if scheme == "inproc":
        from .inproc import connect_inproc
        return await connect_inproc(rest)
    from .tcp import connect_tcp
    return await connect_tcp(rest)


async def listen(address: str) -> Listener:
    """Start a ``Listener`` at ``address``."""
    scheme, rest = parse_address(address)
    if scheme == "inproc":
        from .inproc import listen_inproc
        return await listen_inproc(rest)
    from .tcp import listen_tcp
    return await listen_tcp(rest)

"""Wire protocol of the live master–worker round loop.

All messages are JSON-safe dicts with a ``type`` field.  Per connection
they are FIFO; the protocol never relies on cross-connection ordering.

Handshake::

    worker -> master   {"type": "hello"}
    master -> worker   {"type": "welcome", "worker": w,
                        "config": RoundConfig.to_dict(), "rounds": R,
                        "time_scale": ts, "abort_on_close": bool}

Per round ``t`` (master initiates; workers answer with a stream of
results and exactly one ``round_done``)::

    master -> worker   {"type": "round", "round": t, "row": p,
                        "tasks": [...], "load": l}
    worker -> master   {"type": "result", "round": t, "worker": w,
                        "msg": l, "slots": [j0, j1], "tasks": [...],
                        "t1": [full T1 prefix 0..j1], "t2": t2_at_j1,
                        "arrival": virtual_arrival}         (x messages)
    master -> worker   {"type": "close", "round": t}        (optional)
    worker -> master   {"type": "round_done", "round": t, "sent": m,
                        "aborted": bool, "stalled": bool}

``result.t1`` carries the worker's FULL compute-delay prefix up to the
message's closing slot, so the master can fill table cells even when an
earlier message was cancelled by a ``close`` — any cell never covered by a
received message stays +inf (fault-censoring semantics, version-2 trace).
``result.arrival`` is the worker's virtual arrival time (pacing /
close-decision grade); the master's authoritative statistics are computed
from the assembled tables with the MC engine's own fused arithmetic.

Teardown::

    master -> worker   {"type": "shutdown"}

A worker receiving ``close`` for a round it already finished ignores it;
a master receiving ``result`` after broadcasting ``close`` records it
(the message was already in flight — exactly what a real master does).
"""
from __future__ import annotations

HELLO = "hello"
WELCOME = "welcome"
ROUND = "round"
RESULT = "result"
CLOSE = "close"
ROUND_DONE = "round_done"
SHUTDOWN = "shutdown"

__all__ = ["HELLO", "WELCOME", "ROUND", "RESULT", "CLOSE", "ROUND_DONE",
           "SHUTDOWN"]

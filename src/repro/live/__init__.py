"""Live asynchronous master–worker execution over pluggable transports.

The real counterpart of the Monte Carlo simulator: workers execute their
assigned task rows sequentially, streaming one message per completed
message group; an async master closes each round at ``k`` distinct results
(or at the deadline under ``wait`` / ``close_partial`` / ``reissue``),
feeds censored arrival feedback to the adaptive scheduler, and records the
run as a ``DelayTrace`` that replays bit-exactly through the fused engine.

Entry points: ``run_live`` (one-call in-process cluster),
``Master`` + ``run_worker`` (distributed over ``tcp://``), and the
``repro.launch.live`` CLI.
"""
from .comm import Comm, CommClosedError, Listener, connect, listen
from .master import LiveResult, Master, RoundReport, run_live
from .worker import run_worker, sample_delay_tables

__all__ = [
    "Comm", "CommClosedError", "Listener", "connect", "listen",
    "Master", "LiveResult", "RoundReport", "run_live",
    "run_worker", "sample_delay_tables",
]

"""Live worker: executes assigned task rows sequentially, streaming one
``result`` message per completed message group.

The worker mirrors the Monte Carlo engine's per-trial key derivation
exactly (``sample_delay_tables``): with a shared ``seed`` each of the ``n``
workers samples the SAME full ``(rounds, n, r)`` delay tables and consumes
only its own machine row ``w``.  Delays belong to the MACHINE (worker-major
order), matching the engine's convention — the master applies the
scheduling permutation.  The tables come from the engine's own jitted
recording pass, so they agree bit-for-bit with the trace
``sweep_rounds(process, trials=1, seed=seed, record_trace=True)`` captures,
and the live run's recorded trace replays bit-exactly through the engine.

Virtual time vs. wall clock: delays are always *virtual* float32 values
from the delay process.  With ``time_scale == 0`` the worker computes as
fast as it can (semantics only — results, closes, and traces are
unchanged); with ``time_scale > 0`` each virtual unit costs that many wall
seconds, so deadline closes actually race the compute.
"""
from __future__ import annotations

import asyncio
import math
from typing import List, Optional, Tuple

import numpy as np

from ..core.montecarlo import message_boundaries
from ..core.spec import RoundConfig
from .comm import CommClosedError, connect
from .protocol import (CLOSE, HELLO, RESULT, ROUND, ROUND_DONE, SHUTDOWN,
                       WELCOME)

__all__ = ["run_worker", "sample_delay_tables"]


def sample_delay_tables(process, seed: int, rounds: int, n: int,
                        r: int) -> Tuple[np.ndarray, np.ndarray]:
    """Draw the full ``(rounds, n, r)`` float32 delay tables exactly as the
    MC engine's recording pass does for ``trials=1`` — the SAME jitted
    capture program (``_capture_rounds_fn``), not a re-implementation:
    XLA may fuse a parametric process's arithmetic differently across
    compilations, so only running the identical program guarantees the
    tables match ``sweep_rounds(..., record_trace=True)``'s trace
    bit-for-bit (and hence that the live trace replays bit-exactly)."""
    import jax
    import jax.numpy as jnp

    from ..core.cluster import as_process
    from ..core.montecarlo import _capture_rounds_fn, trial_keys

    process = as_process(process)
    process.check_rounds(rounds)
    capture = jax.jit(_capture_rounds_fn(process, n, r, rounds))
    keys = trial_keys(seed, 1)          # the engine's trial-0 CRN key
    tids = jnp.zeros((1,), jnp.int32)
    T1, T2 = capture(keys, tids)        # (rounds, 1, n, r) each
    return (np.asarray(T1[:, 0], np.float32),
            np.asarray(T2[:, 0], np.float32))


async def _guarded(coro, comm) -> None:
    """Run one round's execution; a crash mid-round closes the channel (the
    master sees a dead worker instead of waiting forever) and re-raises
    when the round task is awaited."""
    try:
        await coro
    except asyncio.CancelledError:
        raise
    except CommClosedError:
        pass
    except BaseException:
        await comm.aclose()
        raise


async def _delayed_send(comm, msg: dict, delay_s: float,
                        close_evt: asyncio.Event, abort: bool) -> int:
    if delay_s > 0:
        await asyncio.sleep(delay_s)
    if abort and close_evt.is_set():
        return 0                       # message still in t2 flight: dropped
    try:
        await comm.send(msg)
    except CommClosedError:
        return 0
    return 1


async def _execute_round(comm, cfg: RoundConfig, msg: dict, t1: np.ndarray,
                         t2: np.ndarray, time_scale: float, abort: bool,
                         close_evt: asyncio.Event, worker: int) -> None:
    t = int(msg["round"])
    tasks = [int(x) for x in msg["tasks"]]
    load = len(tasks)
    eps = float(cfg.comm_eps)
    sent = 0
    aborted = False
    stalled = False
    sends: List[asyncio.Task] = []

    if load:
        # worker-local message grouping: load l -> min(budget, l) messages,
        # same split as the engine's per-worker slot map
        budget = min(cfg.messages or load, load)
        bounds = [int(b) for b in message_boundaries(load, budget)]
        closing = {b: li for li, b in enumerate(bounds)}
        elapsed = 0.0
        for j in range(load):
            if abort and close_evt.is_set():
                aborted = True
                break
            dt = float(t1[j])
            if not math.isfinite(dt):
                stalled = True         # slot never completes; row is stuck
                break
            if time_scale > 0:
                if abort:
                    try:
                        await asyncio.wait_for(close_evt.wait(),
                                              timeout=dt * time_scale)
                        aborted = True
                        break
                    except asyncio.TimeoutError:
                        pass
                else:
                    await asyncio.sleep(dt * time_scale)
            elapsed += dt
            li = closing.get(j)
            if li is None:
                continue
            d2 = float(t2[j])
            if not math.isfinite(d2):
                continue               # this message never arrives
            j0 = bounds[li - 1] + 1 if li else 0
            res = {"type": RESULT, "round": t, "worker": worker, "msg": li,
                   "slots": [j0, j], "tasks": tasks[j0:j + 1],
                   "t1": [float(x) for x in t1[:j + 1]], "t2": d2,
                   "arrival": elapsed + d2 + (li + 1) * eps}
            if time_scale > 0:
                sends.append(asyncio.create_task(_delayed_send(
                    comm, res, d2 * time_scale, close_evt, abort)))
            else:
                try:
                    await comm.send(res)
                    sent += 1
                except CommClosedError:
                    break
    if sends:
        sent += sum(await asyncio.gather(*sends))
    try:
        await comm.send({"type": ROUND_DONE, "round": t, "sent": sent,
                         "aborted": aborted, "stalled": stalled})
    except CommClosedError:
        pass


async def run_worker(address: str, process) -> None:
    """Connect to the master at ``address`` and serve rounds until
    ``shutdown`` (or the master hangs up)."""
    comm = await connect(address)
    try:
        await comm.send({"type": HELLO})
        welcome = await comm.recv()
        if welcome.get("type") != WELCOME:
            raise RuntimeError(f"expected welcome, got {welcome!r}")
        cfg = RoundConfig.from_dict(welcome["config"])
        w = int(welcome["worker"])
        rounds = int(welcome["rounds"])
        time_scale = float(welcome["time_scale"])
        abort = bool(welcome["abort_on_close"])
        T1, T2 = sample_delay_tables(process, cfg.seed, rounds, cfg.n,
                                     cfg.width)
        current: Optional[asyncio.Task] = None
        close_evt = asyncio.Event()
        cur_round = -1
        while True:
            try:
                msg = await comm.recv()
            except CommClosedError:
                break
            mt = msg.get("type")
            if mt == ROUND:
                if current is not None:
                    await current
                cur_round = int(msg["round"])
                close_evt = asyncio.Event()
                current = asyncio.create_task(_guarded(_execute_round(
                    comm, cfg, msg, T1[cur_round, w], T2[cur_round, w],
                    time_scale, abort, close_evt, w), comm))
            elif mt == CLOSE:
                if int(msg["round"]) == cur_round:
                    close_evt.set()
            elif mt == SHUTDOWN:
                if current is not None:
                    await current
                break
        if current is not None:
            if not current.done():
                current.cancel()
            try:
                await current            # surface a mid-round crash
            except asyncio.CancelledError:
                pass
    finally:
        await comm.aclose()

"""TCP transport: length-prefixed JSON frames over asyncio streams.

Frame format: a 4-byte big-endian unsigned length followed by that many
bytes of UTF-8 JSON.  One frame == one message; asyncio streams are
ordered and reliable, so the per-connection FIFO guarantee the live
protocol relies on holds here exactly as for ``inproc``.
"""
from __future__ import annotations

import asyncio
import json
import struct

from .comm import Comm, CommClosedError, Listener

__all__ = ["TCPComm", "TCPListener", "connect_tcp", "listen_tcp"]

_MAX_FRAME = 64 * 1024 * 1024          # sanity cap; a round message is KBs


def _split_hostport(rest: str):
    host, _, port = rest.rpartition(":")
    if not host or not port:
        raise ValueError(f"tcp address must be host:port, got tcp://{rest}")
    return host, int(port)


class TCPComm(Comm):
    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self._reader = reader
        self._writer = writer
        self._closed = False
        sock = writer.get_extra_info("sockname")
        peer = writer.get_extra_info("peername")
        self.local_address = f"tcp://{sock[0]}:{sock[1]}" if sock else "tcp://"
        self.peer_address = f"tcp://{peer[0]}:{peer[1]}" if peer else "tcp://"

    async def send(self, msg: dict) -> None:
        if self._closed:
            raise CommClosedError(f"{self.local_address}: channel closed")
        data = json.dumps(msg).encode()
        try:
            self._writer.write(struct.pack(">I", len(data)) + data)
            await self._writer.drain()
        except (ConnectionError, OSError) as e:
            self._closed = True
            raise CommClosedError(f"{self.local_address}: {e}") from e

    async def recv(self) -> dict:
        try:
            hdr = await self._reader.readexactly(4)
            (length,) = struct.unpack(">I", hdr)
            if length > _MAX_FRAME:
                raise CommClosedError(f"{self.local_address}: oversized "
                                      f"frame ({length} bytes)")
            data = await self._reader.readexactly(length)
        except (asyncio.IncompleteReadError, ConnectionError, OSError) as e:
            self._closed = True
            raise CommClosedError(f"{self.local_address}: peer closed") from e
        return json.loads(data.decode())

    async def aclose(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self._writer.close()
                await self._writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @property
    def closed(self) -> bool:
        return self._closed


class TCPListener(Listener):
    def __init__(self, server: asyncio.AbstractServer, host: str):
        self._server = server
        self._pending: asyncio.Queue = asyncio.Queue()
        port = server.sockets[0].getsockname()[1]
        self.address = f"tcp://{host}:{port}"

    def _on_connect(self, reader, writer):
        self._pending.put_nowait(TCPComm(reader, writer))

    async def accept(self) -> TCPComm:
        return await self._pending.get()

    async def aclose(self) -> None:
        self._server.close()
        await self._server.wait_closed()


async def listen_tcp(rest: str) -> TCPListener:
    host, port = _split_hostport(rest)
    holder: list = []
    server = await asyncio.start_server(
        lambda r, w: holder[0]._on_connect(r, w), host, port)
    lst = TCPListener(server, host)
    holder.append(lst)
    return lst


async def connect_tcp(rest: str) -> TCPComm:
    host, port = _split_hostport(rest)
    reader, writer = await asyncio.open_connection(host, port)
    return TCPComm(reader, writer)

"""Tests for the intra-round message axis (paper Sec. V-C) and the
censoring-aware adaptive feedback.

Covers the ISSUE-3 acceptance points:
  (a) the default message budget reproduces the pre-axis engine bit-exactly
      for every scheme kind (full multi-message for to/lb/tau/pcmm, one-shot
      for pc), and explicit ``messages=load`` equals the default;
  (b) every budget m matches an independent numpy oracle implementing the
      closing-slot grouping from raw draws (m=1 is the one-shot semantics
      the pc path has always used, applied to uncoded schemes);
  (c) ``sweep_rounds`` with m>1 is chunk-invariant;
  (d) the Sec. V-C ordering: more messages => no worse mean completion;
  (e) the closed-form multi-message coded expectations (eqs. 51-52 / 56-57
      generalized) match engine Monte-Carlo;
  (f) censored feedback: engine + AdaptiveScheduler observe only messages
      that beat the round deadline, monotonically in the deadline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MarkovRegimeProcess, ShiftedExponentialDelays,
                        adaptive_spec, completion_samples, cyclic_to_matrix,
                        ec2_cluster, heterogeneous_scales, lb_spec,
                        message_arrival_times, message_boundaries,
                        message_comm_delays, message_group_sizes,
                        message_slot_map, multimessage_coded_mean,
                        pc_spec, pc_threshold, pcmm_spec, pcmm_threshold,
                        scenario1, staircase_to_matrix, sweep, sweep_rounds,
                        task_arrival_samples, to_spec, trajectory_samples)


# ------------------------- message layout helpers ----------------------------

def test_message_layout_helpers():
    assert message_boundaries(5, 2).tolist() == [2, 4]
    assert message_group_sizes(5, 2).tolist() == [3, 2]
    assert message_slot_map(5, 2).tolist() == [2, 2, 2, 4, 4]
    assert message_slot_map(4, 1).tolist() == [3, 3, 3, 3]
    assert message_slot_map(4, 4).tolist() == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        message_boundaries(4, 0)
    with pytest.raises(ValueError):
        message_boundaries(4, 5)


def test_message_arrival_times_and_comm_delays():
    from repro.core import slot_arrival_times
    m = scenario1()
    T1, T2 = m.sample(jax.random.PRNGKey(0), 8, 5, 4)
    s = np.asarray(slot_arrival_times(T1, T2))     # eq. (1), same backend
    got = np.asarray(message_arrival_times(T1, T2, 2))
    smap = message_slot_map(4, 2)
    assert np.array_equal(got, s[..., smap])
    assert np.array_equal(np.asarray(message_arrival_times(T1, T2, 4)), s)
    d = np.asarray(message_comm_delays(T2, 2))
    assert np.array_equal(d, np.asarray(T2)[..., message_boundaries(4, 2)])
    assert np.array_equal(np.asarray(message_comm_delays(T2, 4)),
                          np.asarray(T2))


# ------------------- (a) default budget == pre-axis engine -------------------

def test_default_messages_bitmatch_explicit_full_budget():
    n, r, k, trials = 8, 4, 6, 1500
    m = scenario1()
    C = staircase_to_matrix(n, r)
    for default, explicit in (
            (to_spec("x", C), to_spec("x", C, messages=r)),
            (lb_spec(r), lb_spec(r, messages=r)),
            (pcmm_spec(r), pcmm_spec(r, messages=r))):
        a = np.asarray(completion_samples(default, m, n, trials=trials,
                                          seed=3, k=k))
        b = np.asarray(completion_samples(explicit, m, n, trials=trials,
                                          seed=3, k=k))
        assert (a == b).all(), default.kind
    tau_a = np.asarray(task_arrival_samples(C, m, trials=trials, seed=3))
    tau_b = np.asarray(task_arrival_samples(C, m, trials=trials, seed=3,
                                            messages=r))
    assert (tau_a == tau_b).all()


# ----------------- (b) every budget matches a numpy oracle -------------------

def _oracle_draws(model, n, r, trials, seed):
    """Per-trial draws under the engine's key convention: one key per
    trial, folded in from the base key by global trial id."""
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(trials, dtype=jnp.int32))
    T1s, T2s = [], []
    for i in range(trials):
        T1, T2 = model.sample(keys[i], 1, n, r)
        T1s.append(np.asarray(T1)[0])
        T2s.append(np.asarray(T2)[0])
    return np.stack(T1s), np.stack(T2s)


@pytest.mark.parametrize("messages", [1, 2, 3])
def test_engine_budgets_match_numpy_oracle(messages):
    n, r, k, trials = 7, 3, 5, 200
    model = ShiftedExponentialDelays()
    C = cyclic_to_matrix(n, r)
    T1, T2 = _oracle_draws(model, n, r, trials, seed=11)
    s = np.cumsum(T1, axis=-1) + T2
    s_msg = s[..., message_slot_map(r, messages)]
    # uncoded: min over copies, k-th order statistic
    tau = np.full((trials, n), np.inf)
    for w in range(n):
        for j in range(r):
            tau[:, C[w, j]] = np.minimum(tau[:, C[w, j]], s_msg[:, w, j])
    to_oracle = np.sort(tau, axis=-1)[:, k - 1]
    got = np.asarray(completion_samples(
        to_spec("x", C, messages=messages), model, n, trials=trials,
        seed=11, k=k))
    np.testing.assert_allclose(got, to_oracle, rtol=1e-6)
    # lb: k-th smallest over all remapped slot arrivals
    lb_oracle = np.sort(s_msg.reshape(trials, -1), axis=-1)[:, k - 1]
    got = np.asarray(completion_samples(
        lb_spec(r, messages=messages), model, n, trials=trials, seed=11,
        k=k))
    np.testing.assert_allclose(got, lb_oracle, rtol=1e-6)
    # pcmm: (2n-1)-th smallest over all remapped slot arrivals
    th = pcmm_threshold(n)
    pcmm_oracle = np.sort(s_msg.reshape(trials, -1), axis=-1)[:, th - 1]
    got = np.asarray(completion_samples(
        pcmm_spec(r, messages=messages), model, n, trials=trials, seed=11))
    np.testing.assert_allclose(got, pcmm_oracle, rtol=1e-6)


def test_m1_is_the_one_shot_semantics_for_every_kind():
    """m=1 applies the one-shot arrival the pc path has always used —
    cumulative compute through the last slot + its comm draw — to every
    scheme kind; pc itself stays bit-identical."""
    n, r, trials = 7, 3, 200
    model = ShiftedExponentialDelays()
    C = cyclic_to_matrix(n, r)
    T1, T2 = _oracle_draws(model, n, r, trials, seed=5)
    one_shot = np.cumsum(T1, axis=-1)[..., -1] + T2[..., -1]   # (trials, n)
    tau1 = np.asarray(task_arrival_samples(C, model, trials=trials, seed=5,
                                           messages=1))
    # every copy of task p arrives at its worker's one-shot time
    for p in range(n):
        holders = [w for w in range(n) if p in C[w]]
        np.testing.assert_allclose(tau1[:, p],
                                   one_shot[:, holders].min(axis=1),
                                   rtol=1e-6)
    # pc: unchanged by the axis (messages=1 is its only legal value)
    pc = np.asarray(completion_samples(pc_spec(r), model, n, trials=trials,
                                       seed=5))
    th = pc_threshold(n, r)
    np.testing.assert_allclose(
        pc[:, 0] if pc.ndim > 1 else pc,
        np.sort(one_shot, axis=-1)[:, th - 1], rtol=1e-6)


def test_messages_validation():
    n, r = 6, 3
    m = scenario1()
    C = cyclic_to_matrix(n, r)
    with pytest.raises(ValueError, match="messages"):
        sweep([to_spec("a", C, messages=0)], m, n, trials=8)
    with pytest.raises(ValueError, match="messages"):
        sweep([to_spec("a", C, messages=r + 1)], m, n, trials=8)
    with pytest.raises(ValueError, match="one-shot"):
        from repro.core import SchemeSpec
        sweep([SchemeSpec(name="p", kind="pc", r=r, messages=2)], m, n,
              trials=8)


# --------------------- (c) rounds axis chunk invariance ----------------------

def test_rounds_multimessage_chunk_invariant():
    n, r, k, trials, rounds = 6, 3, 5, 300, 4
    proc = MarkovRegimeProcess(base=scenario1(),
                               worker_scale=heterogeneous_scales(n, 2.0),
                               persistence=0.9)
    spec = to_spec("cs2", cyclic_to_matrix(n, r), messages=2)
    full = np.asarray(trajectory_samples(spec, proc, n, rounds=rounds, k=k,
                                         trials=trials, seed=0))
    part = np.asarray(trajectory_samples(spec, proc, n, rounds=rounds, k=k,
                                         trials=trials, seed=0, chunk=77))
    assert full.shape == (trials, rounds)
    assert (full == part).all()
    res = sweep_rounds([spec], proc, n, rounds=rounds, k=k, trials=trials,
                       seed=0, chunk=128)
    np.testing.assert_allclose(res.per_round["cs2"], full.mean(0), rtol=1e-5)


# ------------------------ (d) Sec. V-C mean ordering -------------------------

def test_more_messages_never_hurt_on_average():
    """Paired (common-random-number) means: completion time is
    non-increasing in the message budget for CS, SS, LB and PCMM."""
    n, r, k, trials = 10, 4, 8, 4000
    from repro.core import ec2_like
    model = ec2_like(n, seed=0)
    specs = []
    for m in (1, 2, r):
        specs += [to_spec(f"cs{m}", cyclic_to_matrix(n, r), messages=m),
                  to_spec(f"ss{m}", staircase_to_matrix(n, r), messages=m),
                  lb_spec(r, name=f"lb{m}", messages=m),
                  pcmm_spec(r, name=f"pcmm{m}", messages=m)]
    res = sweep(specs, model, n, trials=trials, seed=0, ks=k)
    for fam in ("cs", "ss", "lb", "pcmm"):
        t = [res.at_k(f"{fam}{m}", k) for m in (1, 2, r)]
        assert t[2] <= t[1] <= t[0], (fam, t)


# ------------- (e) closed-form coded expectations vs engine MC ---------------

def _sexp_pdf(shift, mean):
    return lambda t: np.where(
        t >= shift, np.exp(-np.minimum((t - shift) / mean, 700.0)) / mean,
        0.0)


def test_multimessage_closed_form_matches_mc():
    n, r = 8, 4
    model = ShiftedExponentialDelays()
    pdf1 = _sexp_pdf(1e-4, 5e-5)
    pdf2 = _sexp_pdf(2e-4, 1e-4)
    specs = [pcmm_spec(r, name=f"pcmm{m}", messages=m)
             for m in (1, 2, r)] + [pc_spec(r)]
    res = sweep(specs, model, n, trials=30000, seed=0)
    for m in (1, 2, r):
        cf = multimessage_coded_mean(n, r, m, pdf1, pdf2, tmax=8e-3,
                                     npts=4096)
        assert np.isclose(cf, res.at_k(f"pcmm{m}"), rtol=0.03), m
    # eqs. 51-52 exactly: PC is the m=1 case at the full-worker threshold
    th = (pc_threshold(n, r) - 1) * r + 1
    cf = multimessage_coded_mean(n, r, 1, pdf1, pdf2, tmax=8e-3, npts=4096,
                                 threshold=th)
    assert np.isclose(cf, res.at_k("pc"), rtol=0.03)


# -------------------------- (f) censored feedback ----------------------------

def test_censored_adaptive_still_beats_static():
    """Restricting feedback to messages that beat the round deadline keeps
    the adaptive edge on persistent heterogeneous clusters (delivered
    messages still identify the fast workers; silent workers are ranked
    slowest by construction)."""
    n, r, k = 10, 3, 8
    proc = ec2_cluster(n, spread=3.0, p_slow=0.25, persistence=0.95,
                       slow=8.0)
    cs = cyclic_to_matrix(n, r)
    specs = [to_spec("cs", cs), to_spec("ss", staircase_to_matrix(n, r)),
             adaptive_spec("adapt", cs)]
    res_c = sweep_rounds(specs, proc, n, rounds=16, k=k, trials=800, seed=0,
                         censored_feedback=True)
    adapt = res_c.mean_round("adapt")
    assert adapt < res_c.mean_round("cs")
    assert adapt < res_c.mean_round("ss")
    # censoring changes the feedback stream, so the trajectories differ
    # from the idealized full-feedback run (statics are untouched)
    res_u = sweep_rounds(specs, proc, n, rounds=16, k=k, trials=800, seed=0)
    assert np.array_equal(res_u.per_round["cs"], res_c.per_round["cs"])
    assert not np.array_equal(res_u.per_round["adapt"],
                              res_c.per_round["adapt"])


def test_censored_feedback_requires_adaptive_aggregator():
    from repro.core import RoundSpec, StragglerAggregator
    with pytest.raises(ValueError, match="adaptive"):
        StragglerAggregator(RoundSpec(n=6, r=3, k=4), scenario1(),
                            censored_feedback=True)


def test_censored_rounds_chunk_invariant():
    n, r, k = 6, 3, 5
    proc = MarkovRegimeProcess(base=scenario1(),
                               worker_scale=heterogeneous_scales(n, 2.0),
                               persistence=0.9)
    spec = adaptive_spec("a", cyclic_to_matrix(n, r), messages=2)
    full = np.asarray(trajectory_samples(spec, proc, n, rounds=5, k=k,
                                         trials=300, seed=0,
                                         censored_feedback=True))
    part = np.asarray(trajectory_samples(spec, proc, n, rounds=5, k=k,
                                         trials=300, seed=0, chunk=77,
                                         censored_feedback=True))
    assert (full == part).all()

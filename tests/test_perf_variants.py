"""§Perf optimization variants must be numerically equivalent to the
baselines they replace (EXPERIMENTS.md: 'debug forward, keep the speedup')."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params, forward, init_cache

F32 = dict(param_dtype="float32", dtype="float32", remat=False)


def _decode_logits(cfg, seed=0, T=10, prefill=4):
    p = init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, 2, 32)
    _, _, cache = forward(p, cfg, toks[:, :prefill], cache=cache)
    outs = []
    for t in range(prefill, T):
        lg, _, cache = forward(p, cfg, toks[:, t:t + 1], cache=cache)
        outs.append(np.asarray(lg[:, 0]))
    return np.stack(outs)


class TestAbsorbedMLA:
    def test_matches_naive_decode(self):
        base = ModelConfig(name="mla", arch_type="moe", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                           vocab_size=97, q_lora_rank=32, kv_lora_rank=16,
                           qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                           head_dim=24, **F32)
        naive = _decode_logits(base)
        absorbed = _decode_logits(dataclasses.replace(base,
                                                      mla_absorb=True))
        np.testing.assert_allclose(absorbed, naive, rtol=2e-4, atol=2e-4)


class TestGroupedGQA:
    def test_matches_repeat_kv_decode(self):
        base = ModelConfig(name="g", arch_type="dense", n_layers=2,
                           d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
                           vocab_size=97, **F32)
        naive = _decode_logits(base)
        grouped = _decode_logits(dataclasses.replace(base,
                                                     grouped_gqa=True))
        np.testing.assert_allclose(grouped, naive, rtol=2e-4, atol=2e-4)


class TestSeqShardedDecode:
    def test_matches_grouped_reference(self):
        """Partial-softmax combine over the (trivially 1-way) model axis
        equals the dense grouped attention with an updated cache."""
        import jax.numpy as jnp
        from repro.models import layers as L
        from repro.launch.mesh import make_local_mesh_ctx
        from repro.sharding import mesh_context
        cfg = ModelConfig(name="rd", arch_type="dense", n_layers=1,
                          d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
                          vocab_size=97, seq_shard_decode=True, **F32)
        q = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 1, 8))
        kx = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 1, 8))
        vx = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 1, 8))
        c = {"k": jax.random.normal(jax.random.PRNGKey(6), (2, 2, 16, 8)),
             "v": jax.random.normal(jax.random.PRNGKey(7), (2, 2, 16, 8)),
             "pos": jnp.asarray(5, jnp.int32)}
        with mesh_context(make_local_mesh_ctx(1, 1)):
            out, nc = L.seq_sharded_decode_attention(cfg, q, kx, vx, c)
        kf = c["k"].at[:, :, 5].set(kx[:, :, 0])
        vf = c["v"].at[:, :, 5].set(vx[:, :, 0])
        ref = L.grouped_attention(q, kf, vf, kv_len=6,
                                  scale=1 / np.sqrt(8), q_offset=5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(nc["k"]), np.asarray(kf),
                                   atol=1e-6)
        assert int(nc["pos"]) == 6


class TestBatchShardFallback:
    def test_noop_without_mesh(self):
        """Flag changes sharding hints only — numerics identical."""
        base = ModelConfig(name="b", arch_type="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab_size=97, **F32)
        p = init_params(jax.random.PRNGKey(0), base)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
        a, _, _ = forward(p, base, toks)
        b, _, _ = forward(
            p, dataclasses.replace(base, attn_batch_shard_fallback=True),
            toks)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Per-architecture smoke tests (deliverable (f)): a REDUCED variant of each
assigned architecture family (<= 2 layers, d_model <= 512, <= 4 experts)
runs one forward + one straggler train step on CPU; output shapes asserted,
no NaNs. Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, SHAPES, input_specs, \
    shape_supported, long_variant
from repro.core import RoundSpec, scenario1
from repro.data import TaskPartition, lm_task_batches
from repro.models import (init_params, forward, init_cache, num_params,
                          layer_specs)
from repro.optim import adamw
from repro.train import init_train_state, make_straggler_train_step, \
    make_train_step


def _smoke_cfg(arch):
    cfg = get_config(arch).smoke()
    if cfg.arch_type == "hybrid":
        # make sure the 2-layer smoke variant still has one attn layer
        cfg = dataclasses.replace(cfg, ssm_period=2, ssm_attn_offset=1)
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = _smoke_cfg(arch)
        assert cfg.n_layers <= 2 and cfg.d_model <= 512
        assert cfg.n_experts <= 4
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        B, T = 2, 16
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
        kwargs = {}
        if cfg.frontend_seq:
            kwargs["embeds"] = jax.random.normal(
                key, (B, cfg.frontend_seq, cfg.frontend_dim))
        if cfg.encoder_layers:
            kwargs["enc_frames"] = jax.random.normal(
                key, (B, cfg.encoder_seq, cfg.frontend_dim))
        logits, aux, _ = forward(params, cfg, toks, **kwargs)
        exp_T = T + (cfg.frontend_seq or 0)
        assert logits.shape == (B, exp_T, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), \
            f"{arch}: NaN/inf in logits"
        assert np.isfinite(float(aux))

    def test_one_train_step(self, arch):
        cfg = _smoke_cfg(arch)
        opt = adamw(1e-3)
        key = jax.random.PRNGKey(1)
        state = init_train_state(key, cfg, opt)
        B, T = 4, 16
        toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
        extras = {}
        if cfg.frontend_seq:
            extras["embeds"] = jax.random.normal(
                key, (B, cfg.frontend_seq, cfg.frontend_dim))
        if cfg.encoder_layers:
            extras["enc_frames"] = jax.random.normal(
                key, (B, cfg.encoder_seq, cfg.frontend_dim))
        step = make_train_step(cfg, opt)
        state, m = jax.jit(lambda s, t, l: step(s, t, l, extras or None))(
            state, toks[:, :-1], toks[:, 1:])
        assert np.isfinite(float(m["loss"])), f"{arch}: loss not finite"
        assert float(m["grad_norm"]) > 0
        for leaf in jax.tree_util.tree_leaves(state.params):
            assert np.isfinite(np.asarray(leaf, np.float32)).all(), \
                f"{arch}: non-finite params after step"

    def test_decode_step(self, arch):
        cfg = _smoke_cfg(arch)
        if not shape_supported(cfg, "long_500k") and cfg.arch_type == "audio":
            pass  # decode_32k still supported for whisper
        key = jax.random.PRNGKey(2)
        params = init_params(key, cfg)
        cache = init_cache(cfg, 2, 32)
        if cfg.encoder_layers:
            frames = jax.random.normal(key, (2, cfg.encoder_seq,
                                             cfg.frontend_dim))
            _, _, cache = forward(params, cfg, jnp.zeros((2, 1), jnp.int32),
                                  enc_frames=frames, cache=cache)
        tok = jnp.zeros((2, 1), jnp.int32)
        lg, _, cache = forward(params, cfg, tok, cache=cache)
        assert lg.shape == (2, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a not in ("whisper-base",
                                               "llava-next-34b")])
def test_straggler_round_on_reduced_arch(arch):
    """One full scheduling round (n=4, r=2, k=3, SS) per reduced text arch."""
    cfg = _smoke_cfg(arch)
    opt = adamw(1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    spec = RoundSpec(n=4, r=2, k=3, schedule="ss")
    part = TaskPartition(n=4, global_batch=4, seq_len=16,
                         vocab=cfg.vocab_size)
    step = jax.jit(make_straggler_train_step(cfg, opt, spec, scenario1()))
    toks, labs = lm_task_batches(part, spec.to_matrix(), 0)
    state, m, _ = step(state, toks, labs, jax.random.PRNGKey(3))
    assert np.isfinite(float(m["loss"]))
    assert int(m["winners"]) == 3
    assert float(m["completion_time"]) > 0


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    rows = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    }
    for arch, (L, d, H, kv, ff, V) in rows.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        ff_actual = cfg.d_ff_expert if arch == "deepseek-v3-671b" else cfg.d_ff
        assert ff_actual == ff, arch
        assert cfg.vocab_size == V, arch
    # MoE details
    ds = get_config("deepseek-v3-671b")
    assert ds.n_experts == 256 and ds.experts_per_token == 8
    assert ds.n_shared_experts == 1
    jm = get_config("jamba-v0.1-52b")
    assert jm.n_experts == 16 and jm.experts_per_token == 2
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.n_experts == 128 and l4.experts_per_token == 1
    # layer-pattern sanity on full configs
    sp = layer_specs(jm)
    assert sum(s.mixer == "gqa" for s in sp) == 4      # 1:7 in 32 layers
    assert sum(s.ffn == "moe" for s in sp) == 16       # every other layer
    sp = layer_specs(get_config("gemma3-4b"))
    assert sum(s.mixer == "swa" for s in sp) > sum(s.mixer == "gqa"
                                                   for s in sp)
    sp = layer_specs(ds)
    assert sum(s.ffn == "moe" for s in sp) == 58       # 61 - 3 dense prefix


def test_input_specs_cover_all_supported_combos():
    from repro.configs import resolve
    count = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_supported(cfg, shape):
                assert arch == "whisper-base" and shape == "long_500k"
                continue
            rcfg = resolve(cfg, shape)
            spec = input_specs(rcfg, shape, n=16, r=1)
            assert all(hasattr(v, "shape") for v in spec.values())
            count += 1
    assert count == 39


def test_long_variant_semantics():
    qw = get_config("qwen2-72b")
    lv = long_variant(qw)
    assert lv.sliding_window == 8192
    assert all(s.mixer == "swa" for s in layer_specs(lv))
    ds = long_variant(get_config("deepseek-v3-671b"))
    assert ds.kv_lora_rank == 512      # unchanged: MLA compressed cache
    rw = long_variant(get_config("rwkv6-1.6b"))
    assert rw.ssm_kind == "rwkv6"

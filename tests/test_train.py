"""Training substrate tests: optimizers, straggler-scheduled step (eq. 61),
data pipeline determinism, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RoundSpec, scenario1, cyclic_to_matrix, ec2_cluster,
                        greedy_row_assignment)
from repro.data import TaskPartition, lm_task_batches, bigram_tokens
from repro.models import ModelConfig, init_cache
from repro.optim import (adamw, sgd, momentum, cosine_schedule,
                         clip_by_global_norm, global_norm)
from repro.train import (init_train_state, make_train_step,
                         make_straggler_train_step, make_serve_step, lm_loss)

CFG = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  param_dtype="float32", dtype="float32", remat=False)


class TestOptimizers:
    def _quad(self, opt, steps=60):
        """Minimize ||x - 3||^2 with each optimizer."""
        params = {"x": jnp.zeros((4,))}
        state = opt.init(params)
        for _ in range(steps):
            g = {"x": 2 * (params["x"] - 3.0)}
            upd, state = opt.update(g, state, params)
            params = opt.apply(params, upd)
        return float(jnp.abs(params["x"] - 3.0).max())

    def test_sgd(self):
        assert self._quad(sgd(0.1)) < 1e-3

    def test_momentum(self):
        assert self._quad(momentum(0.02), steps=200) < 1e-2

    def test_adamw_no_decay(self):
        assert self._quad(adamw(0.3, weight_decay=0.0), steps=200) < 1e-2

    def test_cosine_schedule(self):
        s = cosine_schedule(1.0, 100, warmup=10)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(s(jnp.asarray(100))) < 1e-6

    def test_clip(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
        assert float(norm) > 1.0


class TestStragglerStep:
    def test_loss_decreases_and_metrics(self):
        opt = adamw(1e-2, weight_decay=0.0)
        state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs")
        part = TaskPartition(n=4, global_batch=8, seq_len=16,
                             vocab=64, source="bigram")
        step = jax.jit(make_straggler_train_step(CFG, opt, spec, scenario1()))
        C = spec.to_matrix()
        first = last = None
        for i in range(40):
            toks, labs = lm_task_batches(part, C, i)
            state, m, _ = step(state, toks, labs, jax.random.PRNGKey(i))
            if first is None:
                first = float(m["loss"])
            last = float(m["loss"])
            assert int(m["winners"]) == 3
            assert float(m["completion_time"]) > 0
        assert last < first - 0.3, (first, last)

    def test_k_equals_n_uses_all_tasks(self):
        opt = sgd(1e-2)
        state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        spec = RoundSpec(n=4, r=2, k=4)
        part = TaskPartition(n=4, global_batch=4, seq_len=8, vocab=64)
        step = jax.jit(make_straggler_train_step(CFG, opt, spec, scenario1()))
        toks, labs = lm_task_batches(part, spec.to_matrix(), 0)
        state, m, _ = step(state, toks, labs, jax.random.PRNGKey(0))
        assert int(m["winners"]) == 4

    def test_equals_plain_step_when_k_n_r1(self):
        """r=1, k=n: every task used exactly once -> gradient equals the
        plain full-batch step (same data, same init)."""
        opt = sgd(0.1)
        spec = RoundSpec(n=4, r=1, k=4, schedule="cs")
        part = TaskPartition(n=4, global_batch=4, seq_len=8, vocab=64)
        C = spec.to_matrix()
        toks, labs = lm_task_batches(part, C, 0)

        s1 = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        stepA = jax.jit(make_straggler_train_step(CFG, opt, spec,
                                                  scenario1(),
                                                  clip_norm=1e9))
        s1, mA, _ = stepA(s1, toks, labs, jax.random.PRNGKey(5))

        # plain step on the same data: tasks stacked into one batch.
        # C is cyclic with r=1 -> worker i computes task i, slot 0.
        flat_t = toks[0].reshape(-1, toks.shape[-1])
        flat_l = labs[0].reshape(-1, labs.shape[-1])
        s2 = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        stepB = jax.jit(make_train_step(CFG, opt, clip_norm=1e9))
        s2, mB = stepB(s2, flat_t, flat_l)

        for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                        jax.tree_util.tree_leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-6)

    def test_cluster_state_threads_through_steps(self):
        """Round-aware training: the DelayProcess state returned by one
        step feeds the next, and with near-frozen stragglers the observed
        per-worker delays stay correlated across consecutive rounds."""
        opt = sgd(1e-2)
        state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs")
        proc = ec2_cluster(4, spread=2.0, p_slow=0.5, persistence=0.98,
                           slow=50.0)
        part = TaskPartition(n=4, global_batch=8, seq_len=16, vocab=64)
        step = jax.jit(make_straggler_train_step(CFG, opt, spec, proc))
        C = spec.to_matrix()
        cluster = None
        t1s = []
        for i in range(8):
            toks, labs = lm_task_batches(part, C, i)
            state, m, cluster = step(state, toks, labs,
                                     jax.random.PRNGKey(i), cluster)
            assert m["worker_t1"].shape == (4,)
            t1s.append(np.asarray(m["worker_t1"]))
        assert cluster is not None and np.asarray(cluster).shape == (1, 4)
        t1s = np.stack(t1s)                     # (rounds, n)
        # a worker slowed 50x stays slow: per-round worker ranking is
        # essentially constant under persistence=0.98
        ranks = np.argsort(np.argsort(t1s, axis=1), axis=1)
        assert (ranks.std(axis=0).mean()) < 1.0

    def test_row_permutation_matches_identity_when_trivial(self):
        """Passing row_of_worker=arange must reproduce the static path
        exactly; a nontrivial permutation with matching data keeps the
        winner count at k."""
        opt = sgd(1e-2)
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs")
        part = TaskPartition(n=4, global_batch=8, seq_len=16, vocab=64)
        step = jax.jit(make_straggler_train_step(CFG, opt, spec, scenario1()))
        C = spec.to_matrix()
        toks, labs = lm_task_batches(part, C, 0)
        s0 = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        _, mA, _ = step(s0, toks, labs, jax.random.PRNGKey(7))
        _, mB, _ = step(s0, toks, labs, jax.random.PRNGKey(7), None,
                        jnp.arange(4))
        assert float(mA["completion_time"]) == float(mB["completion_time"])
        assert float(mA["loss"]) == float(mB["loss"])
        # nontrivial permutation: effective schedule rows permuted, data
        # built from the effective matrix
        row = np.array([2, 3, 0, 1])
        toks2, labs2 = lm_task_batches(part, C[row], 0)
        _, mC, _ = step(s0, toks2, labs2, jax.random.PRNGKey(7), None,
                        jnp.asarray(row))
        assert int(mC["winners"]) == 3
        assert float(mC["completion_time"]) > 0

    def test_unbiasedness_scaling(self):
        """eq. (61): with k < n the estimator scales by n/k — the expected
        gradient over delay randomness equals the full-data gradient.
        Verified by averaging the weighted loss value over many rounds."""
        spec = RoundSpec(n=6, r=6, k=3, schedule="cs")
        part = TaskPartition(n=6, global_batch=6, seq_len=8, vocab=64)
        C = spec.to_matrix()
        toks, labs = lm_task_batches(part, C, 0)
        opt = sgd(0.0)  # no movement; probe loss only
        state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        step = jax.jit(make_straggler_train_step(CFG, opt, spec, scenario1()))
        vals = []
        for i in range(48):
            _, m, _c = step(state, toks, labs, jax.random.PRNGKey(i))
            vals.append(float(m["loss"]))
        # full-data mean loss over the 6 distinct tasks
        full = 0.0
        for j in range(6):
            l, _ = lm_loss(state.params, CFG, toks[0, j], labs[0, j])
            full += float(l) / 6
        est = np.mean(vals)
        assert abs(est - full) / full < 0.05, (est, full)


class TestData:
    def test_task_batches_shapes_and_determinism(self):
        part = TaskPartition(n=4, global_batch=8, seq_len=16, vocab=64)
        C = cyclic_to_matrix(4, 2)
        t1, l1 = lm_task_batches(part, C, step=3)
        t2, l2 = lm_task_batches(part, C, step=3)
        assert t1.shape == (2, 4, 2, 16)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        # labels are inputs shifted by one
        np.testing.assert_array_equal(np.asarray(t1)[..., 1:],
                                      np.asarray(l1)[..., :-1])

    def test_redundant_tasks_identical_across_workers(self):
        """Two workers assigned the same task see identical data."""
        part = TaskPartition(n=4, global_batch=8, seq_len=8, vocab=64)
        C = cyclic_to_matrix(4, 3)   # task 2 at (0,2), (1,1), (2,0)
        t, _ = lm_task_batches(part, C, step=0)
        np.testing.assert_array_equal(np.asarray(t[2, 0]),
                                      np.asarray(t[1, 1]))
        np.testing.assert_array_equal(np.asarray(t[1, 1]),
                                      np.asarray(t[0, 2]))

    def test_bigram_is_learnable_structure(self):
        toks = bigram_tokens(jax.random.PRNGKey(0), 64, 32, 16)
        a = np.asarray(toks)
        # bigram chain: distribution of next token given current is peaked
        joint = np.zeros((16, 16))
        for row in a:
            for x, y in zip(row[:-1], row[1:]):
                joint[x, y] += 1
        cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
        assert (cond.max(1) > 0.3).mean() > 0.5


class TestCheckpoint:
    def test_roundtrip_train_state(self):
        from repro.ckpt import (save_checkpoint, load_checkpoint,
                                latest_checkpoint)
        opt = adamw(1e-3)
        state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
        with tempfile.TemporaryDirectory() as d:
            path = save_checkpoint(os.path.join(d, "ck"), state, step=17)
            restored = load_checkpoint(path, state)
            for a, b in zip(jax.tree_util.tree_leaves(restored),
                            jax.tree_util.tree_leaves(state)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert latest_checkpoint(d, "ck").endswith("ck-00000017.npz")

    def test_shape_mismatch_raises(self):
        from repro.ckpt import save_checkpoint, load_checkpoint
        with tempfile.TemporaryDirectory() as d:
            p = save_checkpoint(os.path.join(d, "x"), {"a": jnp.ones((3,))})
            with pytest.raises(ValueError):
                load_checkpoint(p, {"a": jnp.ones((4,))})


def test_serve_step_greedy_deterministic():
    opt = sgd(0.0)
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    serve = jax.jit(make_serve_step(CFG))
    c1 = init_cache(CFG, 1, 16)
    c2 = init_cache(CFG, 1, 16)
    t1 = t2 = jnp.zeros((1, 1), jnp.int32)
    for _ in range(5):
        t1, c1 = serve(state.params, c1, t1)
        t2, c2 = serve(state.params, c2, t2)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))

"""Per-kernel interpret-mode validation against the pure-jnp oracles:
shape/dtype sweeps + hypothesis property tests (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (gram_matvec, batched_gram_matvec,
                               greedy_assign, swa_attention)
from repro.core.scheduling import (cyclic_to_matrix,
                                   greedy_row_assignment_batch,
                                   random_assignment_to_matrix,
                                   staircase_to_matrix)


class TestGramMatvec:
    @pytest.mark.parametrize("d,b", [(64, 32), (128, 128), (300, 200),
                                     (100, 300), (512, 64), (37, 53)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, d, b, dtype):
        key = jax.random.PRNGKey(d * 1000 + b)
        X = jax.random.normal(key, (d, b), dtype)
        th = jax.random.normal(jax.random.PRNGKey(7), (d,), dtype)
        out = gram_matvec(X, th)
        want = ref.gram_matvec_ref(X, th)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        rel = (np.abs(np.asarray(out, np.float32) -
                      np.asarray(want, np.float32)).max()
               / (np.abs(np.asarray(want, np.float32)).max() + 1e-9))
        assert rel < tol, rel
        assert out.dtype == X.dtype

    def test_block_sizes(self):
        X = jax.random.normal(jax.random.PRNGKey(0), (384, 256))
        th = jax.random.normal(jax.random.PRNGKey(1), (384,))
        want = np.asarray(ref.gram_matvec_ref(X, th))
        for bd, bb in [(128, 128), (256, 64), (384, 256), (64, 256)]:
            out = np.asarray(gram_matvec(X, th, block_d=bd, block_b=bb))
            np.testing.assert_allclose(out, want, rtol=1e-5, atol=2e-3)

    def test_batched_matches_paper_gradient_piece(self):
        """sum_i h(X_i) must equal X^T X theta (paper eq. 48)."""
        n, d, b = 4, 96, 48
        Xs = jax.random.normal(jax.random.PRNGKey(0), (n, d, b))
        th = jax.random.normal(jax.random.PRNGKey(1), (d,))
        hs = batched_gram_matvec(Xs, th)
        assert hs.shape == (n, d)
        Xflat = np.concatenate([np.asarray(Xs[i]) for i in range(n)], axis=1)
        want = Xflat @ (Xflat.T @ np.asarray(th))
        np.testing.assert_allclose(np.asarray(hs.sum(0)), want,
                                   rtol=1e-4, atol=1e-3)

    @settings(deadline=None, max_examples=20)
    @given(st.integers(8, 200), st.integers(8, 200), st.integers(0, 2**16))
    def test_property_matches_oracle(self, d, b, seed):
        X = jax.random.normal(jax.random.PRNGKey(seed), (d, b))
        th = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))
        out = np.asarray(gram_matvec(X, th, block_d=64, block_b=64))
        want = np.asarray(ref.gram_matvec_ref(X, th))
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-4)


class TestSWAAttention:
    @pytest.mark.parametrize("T,H,dh,W", [
        (128, 2, 64, 32), (200, 1, 32, 64), (256, 2, 128, 100),
        (64, 4, 16, 8), (96, 1, 64, 96),      # window == seq (full causal)
        (130, 2, 32, 17),                      # odd sizes
    ])
    def test_shapes(self, T, H, dh, W):
        q = jax.random.normal(jax.random.PRNGKey(0), (T, H, dh)) * 0.5
        k = jax.random.normal(jax.random.PRNGKey(1), (T, H, dh)) * 0.5
        v = jax.random.normal(jax.random.PRNGKey(2), (T, H, dh))
        out = swa_attention(q, k, v, window=W, block_q=64, block_k=64)
        want = ref.swa_attention_ref(q, k, v, W)
        assert np.abs(np.asarray(out) - np.asarray(want)).max() < 2e-4

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                           (jnp.bfloat16, 3e-2)])
    def test_dtypes(self, dtype, tol):
        T, H, dh, W = 128, 2, 64, 48
        q = (jax.random.normal(jax.random.PRNGKey(0), (T, H, dh)) * 0.5
             ).astype(dtype)
        k = (jax.random.normal(jax.random.PRNGKey(1), (T, H, dh)) * 0.5
             ).astype(dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (T, H, dh)).astype(dtype)
        out = swa_attention(q, k, v, window=W)
        want = ref.swa_attention_ref(q, k, v, W)
        assert out.dtype == dtype
        assert np.abs(np.asarray(out, np.float32) -
                      np.asarray(want, np.float32)).max() < tol

    def test_window_1_is_self_only(self):
        """window=1: each position attends only to itself -> output = v."""
        T, H, dh = 64, 1, 32
        q = jax.random.normal(jax.random.PRNGKey(0), (T, H, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (T, H, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (T, H, dh))
        out = swa_attention(q, k, v, window=1, block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(v),
                                   rtol=1e-5, atol=1e-5)

    def test_full_window_matches_causal_softmax(self):
        """window >= T reduces to plain causal attention."""
        T, H, dh = 96, 2, 32
        q = jax.random.normal(jax.random.PRNGKey(0), (T, H, dh)) * 0.3
        k = jax.random.normal(jax.random.PRNGKey(1), (T, H, dh)) * 0.3
        v = jax.random.normal(jax.random.PRNGKey(2), (T, H, dh))
        out = swa_attention(q, k, v, window=T, block_q=32, block_k=32)
        # dense causal reference
        s = np.einsum("qhd,khd->hqk", np.asarray(q), np.asarray(k)
                      ) / np.sqrt(dh)
        mask = np.tril(np.ones((T, T), bool))
        s = np.where(mask[None], s, -np.inf)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        want = np.einsum("hqk,khd->qhd", p, np.asarray(v))
        np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4,
                                   atol=2e-4)

    @settings(deadline=None, max_examples=15)
    @given(st.integers(16, 160), st.integers(1, 3),
           st.sampled_from([16, 32, 64]), st.integers(1, 160),
           st.integers(0, 2**16))
    def test_property_matches_oracle(self, T, H, dh, W, seed):
        q = jax.random.normal(jax.random.PRNGKey(seed), (T, H, dh)) * 0.4
        k = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, H, dh)) * 0.4
        v = jax.random.normal(jax.random.PRNGKey(seed + 2), (T, H, dh))
        out = swa_attention(q, k, v, window=W, block_q=32, block_k=32)
        want = ref.swa_attention_ref(q, k, v, W)
        assert np.abs(np.asarray(out) - np.asarray(want)).max() < 3e-4


def _greedy_inputs(C, B, seed, gamma=0.5, with_need=False):
    """Kernel-shaped greedy inputs for a TO matrix: the coverage-weight
    matrix plus per-trial (order, epick, need_row) exactly as
    ``greedy_row_assignment_batch`` builds them."""
    from repro.core.scheduling import _greedy_matrices
    C = np.asarray(C)
    n = C.shape[0]
    C_tup = tuple(tuple(int(v) for v in row) for row in C)
    W, A = _greedy_matrices(C_tup, float(gamma))
    est = jax.random.uniform(jax.random.PRNGKey(seed), (B, n),
                             minval=0.01, maxval=1.0)
    order = jnp.argsort(est, axis=-1).astype(jnp.int32)
    epick = jnp.maximum(jnp.take_along_axis(est, order, axis=-1),
                        jnp.float32(1e-30))
    need_row = None
    if with_need:
        need = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (B, n))
                < 0.3).astype(jnp.float32)
        need_row = need @ jnp.asarray(A).T
    return jnp.asarray(W), order, epick, need_row


class TestGreedyAssign:
    """Pallas greedy row-assignment kernel vs the pure-jnp oracle.  The
    pick loop is integer-valued, so every comparison is bitwise."""

    @pytest.mark.parametrize("n,r,B,bt", [
        (8, 3, 64, 128),     # single partial block
        (8, 3, 128, 128),    # exactly one block
        (8, 3, 300, 128),    # multi-block with a ragged edge
        (4, 1, 17, 8),       # tiny blocks, many grid steps
        (12, 12, 50, 32),    # full load r = n
    ])
    def test_matches_oracle(self, n, r, B, bt):
        C = cyclic_to_matrix(n, r)
        W, order, epick, need_row = _greedy_inputs(C, B, seed=n * B)
        out = greedy_assign(W, order, epick, need_row, block_trials=bt)
        want = ref.greedy_assign_ref(W, order, epick, need_row)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_need_vector_reissue_priority(self):
        C = staircase_to_matrix(8, 3)
        W, order, epick, need_row = _greedy_inputs(C, 90, seed=5,
                                                   with_need=True)
        out = greedy_assign(W, order, epick, need_row, block_trials=32)
        want = ref.greedy_assign_ref(W, order, epick, need_row)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_tied_scores_break_to_lowest_row(self):
        """Identical estimates everywhere -> maximal score ties; the kernel
        must reproduce the oracle's lowest-row argmin tie-break."""
        n, B = 8, 40
        C = cyclic_to_matrix(n, 3)
        W, _, _, _ = _greedy_inputs(C, B, seed=0)
        est = jnp.full((B, n), 0.25, jnp.float32)
        order = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        out = greedy_assign(W, order, est, block_trials=16)
        want = ref.greedy_assign_ref(W, order, est)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_ragged_loads(self):
        loads = [3, 1, 2, 3, 1, 3]
        C = cyclic_to_matrix(6, loads=loads)
        W, order, epick, need_row = _greedy_inputs(C, 70, seed=11,
                                                   with_need=True)
        out = greedy_assign(W, order, epick, need_row, block_trials=64)
        want = ref.greedy_assign_ref(W, order, epick, need_row)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    @pytest.mark.parametrize("with_need", [False, True])
    def test_batch_entry_point_impls_agree(self, with_need):
        """``greedy_row_assignment_batch(impl=...)`` is bitwise identical
        between the scan and the kernel, including leading batch dims."""
        n, r = 8, 3
        C = random_assignment_to_matrix(n, seed=3)
        est = jax.random.uniform(jax.random.PRNGKey(2), (5, 13, n),
                                 minval=0.01, maxval=1.0)
        need = ((jax.random.uniform(jax.random.PRNGKey(3), (5, 13, n)) < 0.4)
                .astype(jnp.float32) if with_need else None)
        a = greedy_row_assignment_batch(C, est, need=need, impl="scan")
        b = greedy_row_assignment_batch(C, est, need=need, impl="kernel")
        assert a.shape == est.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @settings(deadline=None, max_examples=25)
    @given(st.integers(3, 10), st.integers(1, 6), st.integers(1, 150),
           st.booleans(), st.integers(0, 2**16))
    def test_property_matches_oracle(self, n, r, B, with_need, seed):
        r = min(r, n)
        C = cyclic_to_matrix(n, r) if seed % 2 else staircase_to_matrix(n, r)
        W, order, epick, need_row = _greedy_inputs(C, B, seed=seed,
                                                   with_need=with_need)
        out = greedy_assign(W, order, epick, need_row, block_trials=32)
        want = ref.greedy_assign_ref(W, order, epick, need_row)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

"""Fault injection and graceful degradation (ISSUE-6).

Covers the acceptance points:
  (a) the ``FaultProcess`` scenario zoo composes over any base delay
      source and injects +inf ("never arrives") / load swell where the
      scenario says, never NaN;
  (b) engine edge cases: every worker dead, a single survivor at k=1,
      a deadline below every arrival — all close finitely under the
      closing policies with sane degradation metrics;
  (c) the ``reissue`` policy is chunk-invariant under common random
      numbers (per-trial trajectories bit-exact across chunk sizes);
  (d) property: a fault-bearing recording replays bit-exactly through
      ``sweep_rounds`` — per-round times AND degradation streams — for
      every zoo scenario and closing policy (the v2 +inf trace format
      round-trips through disk on the way);
  (e) crash-aware scheduling: dead-worker detection, coverage repair,
      and the clear error when graceful degradation is impossible;
  (f) spec-level guards: impossible coverage and deadline-policy
      validation fail fast with explicit messages.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (MASKED, AdaptiveScheduler, DelayTrace,
                        FAULT_SCENARIOS, IIDProcess, RoundSpec,
                        TraceProcess, adaptive_spec, cyclic_to_matrix,
                        load_trace, make_scenario, save_trace, scenario1,
                        sweep_rounds, to_spec, trajectory_samples,
                        validate_trace_file)
import repro.core.trace as trace_mod

N, R, K, ROUNDS, TRIALS = 6, 2, 3, 5, 48
DEADLINE = 2e-3           # ~2x scenario1's typical round, well above arrivals
SCHEMES = ("cs", "ad")


def _specs():
    return [to_spec("cs", cyclic_to_matrix(N, R)),
            adaptive_spec("ad", cyclic_to_matrix(N, R))]


def _sweep(process, *, k=K, deadline=None, policy="wait", chunk=16,
           record=False, specs=None):
    return sweep_rounds(specs or _specs(), process, N, rounds=ROUNDS, k=k,
                        trials=TRIALS, seed=0, chunk=chunk,
                        censored_feedback=True, record_trace=record,
                        deadline=deadline, deadline_policy=policy)


# --------------------------- (a) the scenario zoo ----------------------------

def test_scenario_zoo_constructs_and_injects_cleanly():
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    for name in FAULT_SCENARIOS:
        proc = make_scenario(name, scenario1(), N)
        state = proc.init(keys, N)
        for _ in range(3):
            state, T1, T2 = proc.step(state, keys, N, R)
            for T in (np.asarray(T1), np.asarray(T2)):
                assert not np.isnan(T).any()
                assert (T[np.isfinite(T)] > 0).all()
    with pytest.raises(ValueError, match="unknown fault scenario"):
        make_scenario("meteor", scenario1(), N)


def test_partition_window_is_deterministic():
    proc = make_scenario("partition", scenario1(), N, workers=(0, 1),
                         start=1, length=1)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    state = proc.init(keys, N)
    cut_per_round = []
    for _ in range(3):
        state, T1, T2 = proc.step(state, keys, N, R)
        assert np.isfinite(np.asarray(T1)).all()   # compute keeps running
        cut_per_round.append(np.isinf(np.asarray(T2)))
    assert not cut_per_round[0].any() and not cut_per_round[2].any()
    assert cut_per_round[1][:, :2].all() and not cut_per_round[1][:, 2:].any()


def test_diurnal_swells_but_never_censors():
    proc = make_scenario("diurnal", scenario1(), N, period=4, amplitude=3.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    state = proc.init(keys, N)
    means = []
    for _ in range(3):
        state, T1, T2 = proc.step(state, keys, N, R)
        assert np.isfinite(np.asarray(T1)).all()
        assert np.isfinite(np.asarray(T2)).all()
        means.append(float(np.mean(np.asarray(T1))))
    assert means[2] > means[0]        # round 2 sits near the swell peak


# ------------------------- (b) engine edge cases -----------------------------

def test_all_workers_dead_closes_partial_rounds():
    proc = make_scenario("preemption", scenario1(), N, kill_p=1.0,
                         respawn_p=0.0)
    res = _sweep(proc, deadline=DEADLINE, policy="close_partial")
    for nm in SCHEMES:
        pr = np.asarray(res.per_round[nm])
        assert np.isfinite(pr).all() and (pr <= DEADLINE * (1 + 1e-6)).all()
        assert np.allclose(res.realized_k(nm), 0.0)
        assert np.allclose(res.missed_fraction(nm), 1.0)
        assert np.allclose(res.khist(nm)[:, 0], 1.0)
    # the wait policy reports the truth — +inf, never NaN — and still
    # flags every round as missed
    res_w = _sweep(proc, deadline=DEADLINE, policy="wait")
    for nm in SCHEMES:
        pr = np.asarray(res_w.per_round[nm])
        assert np.isinf(pr).all() and not np.isnan(pr).any()
        assert np.allclose(res_w.missed_fraction(nm), 1.0)


def test_single_survivor_completes_k1():
    proc = make_scenario("partition", scenario1(), N,
                         workers=tuple(range(N - 1)), start=0, length=ROUNDS)
    res = _sweep(proc, k=1, specs=[to_spec("cs", cyclic_to_matrix(N, R))])
    assert np.isfinite(np.asarray(res.per_round["cs"])).all()
    # ... and k beyond the survivor's rows never completes under wait
    res2 = _sweep(proc, k=K, specs=[to_spec("cs", cyclic_to_matrix(N, R))])
    assert np.isinf(np.asarray(res2.per_round["cs"])).all()


def test_deadline_below_every_arrival():
    dl = 1e-9
    res = _sweep(IIDProcess(scenario1()), deadline=dl, policy="close_partial")
    for nm in SCHEMES:
        assert np.allclose(res.per_round[nm], dl)
        assert np.allclose(res.realized_k(nm), 0.0)
        assert np.allclose(res.missed_fraction(nm), 1.0)
        assert np.allclose(res.stale_fraction(nm), 1.0)


def test_khist_is_a_distribution_over_realized_k():
    res = _sweep(make_scenario("preemption", scenario1(), N),
                 deadline=DEADLINE, policy="close_partial")
    for nm in SCHEMES:
        hist = res.khist(nm)
        assert hist.shape == (ROUNDS, K + 1)
        assert np.allclose(hist.sum(axis=1), 1.0, atol=1e-5)
        mean_from_hist = hist @ np.arange(K + 1)
        assert np.allclose(mean_from_hist, res.realized_k(nm), atol=1e-4)


def test_degradation_requires_a_deadline():
    res = _sweep(IIDProcess(scenario1()))
    assert res.degradation is None
    with pytest.raises(ValueError, match="deadline"):
        res.realized_k("cs")


# --------------------- (c) reissue chunk invariance (CRN) --------------------

def test_reissue_chunk_invariant_under_crn():
    proc = make_scenario("preemption", scenario1(), N)
    sp = adaptive_spec("ad", cyclic_to_matrix(N, R))
    a = trajectory_samples(sp, proc, N, rounds=ROUNDS, k=K, trials=TRIALS,
                           seed=0, chunk=16, censored_feedback=True,
                           deadline=DEADLINE, deadline_policy="reissue")
    b = trajectory_samples(sp, proc, N, rounds=ROUNDS, k=K, trials=TRIALS,
                           seed=0, chunk=7, censored_feedback=True,
                           deadline=DEADLINE, deadline_policy="reissue")
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # aggregate streams agree across chunkings too
    ra = _sweep(proc, deadline=DEADLINE, policy="reissue", chunk=16)
    rb = _sweep(proc, deadline=DEADLINE, policy="reissue", chunk=7)
    for nm in SCHEMES:
        assert np.allclose(ra.per_round[nm], rb.per_round[nm], rtol=1e-6)
        for key in ("realized_k", "missed", "stale", "khist"):
            assert np.allclose(ra.degradation[nm][key],
                               rb.degradation[nm][key], atol=1e-6)


# ------------------ (d) fault-bearing trace replay property ------------------

@settings(deadline=None, max_examples=10)
@given(st.sampled_from(FAULT_SCENARIOS),
       st.sampled_from(("close_partial", "reissue")))
def test_fault_trace_replay_bit_exact(scenario, policy):
    """The acceptance criterion: a recording made under any zoo scenario
    and closing policy replays bit-exactly — identical per-round times
    and identical degradation streams — after a disk round-trip."""
    proc = make_scenario(scenario, scenario1(), N)
    res = _sweep(proc, deadline=DEADLINE, policy=policy, record=True)
    rep = _sweep(TraceProcess(res.trace), deadline=DEADLINE, policy=policy)
    for nm in SCHEMES:
        assert np.array_equal(res.per_round[nm], rep.per_round[nm])
        for key in ("realized_k", "missed", "stale", "khist"):
            assert np.array_equal(res.degradation[nm][key],
                                  rep.degradation[nm][key])


def test_fault_trace_disk_roundtrip_v2(tmp_path):
    res = _sweep(make_scenario("preemption", scenario1(), N,
                               kill_p=0.5, respawn_p=0.2),
                 deadline=DEADLINE, policy="close_partial", record=True)
    assert res.trace.has_faults
    path = save_trace(str(tmp_path / "faulty"), res.trace)
    hdr = validate_trace_file(path)
    assert hdr["version"] == trace_mod.TRACE_FORMAT_VERSION == 2
    assert hdr["faults"] is True
    back = load_trace(path)
    assert back == res.trace
    rep = _sweep(TraceProcess(back), deadline=DEADLINE,
                 policy="close_partial")
    for nm in SCHEMES:
        assert np.array_equal(res.per_round[nm], rep.per_round[nm])


def test_trace_rejects_nan_but_accepts_inf():
    ones = np.ones((1, 1, 2, 2), np.float32)
    with pytest.raises(ValueError, match="NaN"):
        DelayTrace(np.where(ones > 0, np.nan, 1.0), ones)
    faulty = DelayTrace(np.where(ones > 0, np.inf, 1.0), ones)
    assert faulty.has_faults


# --------------------- (e) crash-aware adaptive scheduling -------------------

def _observe_only_worker_alive(sched, n, r, alive):
    obs = np.ones((n, r))
    arr = np.full((n, r), np.inf)
    arr[alive] = 0.5
    sched.observe(obs, arrivals=arr, t_done=1.0)


def test_scheduler_detects_dead_and_repairs_coverage():
    C = cyclic_to_matrix(N, R)
    s = AdaptiveScheduler(C, dead_after=2, target_k=2)
    assert not s.dead_workers().any()
    for _ in range(2):
        s.worker_of_row()
        _observe_only_worker_alive(s, N, R, alive=N - 1)
    dead = s.dead_workers()
    assert dead.sum() == N - 1 and not dead[N - 1]
    # the surviving worker's R rows still cover target_k=2 distinct tasks
    M = s.matrix()
    act = M[N - 1:][M[N - 1:] != MASKED]
    assert np.unique(act).size >= 2


def test_scheduler_raises_when_degradation_impossible():
    C = cyclic_to_matrix(N, R)
    s = AdaptiveScheduler(C, dead_after=2, target_k=K + 1)
    for _ in range(2):
        s.worker_of_row()
        _observe_only_worker_alive(s, N, R, alive=N - 1)
    with pytest.raises(ValueError,
                       match="graceful degradation impossible"):
        s.matrix()


def test_set_need_validates_and_prioritizes():
    C = cyclic_to_matrix(N, R)
    s = AdaptiveScheduler(C)
    with pytest.raises(ValueError, match="shape"):
        s.set_need(np.ones(N + 1, bool))
    s.set_need(None)                      # clearing is always legal
    s.set_need(np.zeros(N, bool))         # nothing needed == cleared
    assert s._need is None


# ----------------------- (f) fail-fast spec validation -----------------------

def test_engine_rejects_uncoverable_schedule():
    C = np.array([[0, MASKED], [0, MASKED], [1, MASKED],
                  [1, MASKED], [0, MASKED], [1, MASKED]])
    with pytest.raises(ValueError, match="covers only"):
        _sweep(IIDProcess(scenario1()),
               specs=[to_spec("bad", C, loads=(1,) * N)])


def test_roundspec_deadline_validation():
    with pytest.raises(ValueError, match="needs a deadline"):
        RoundSpec(n=N, r=R, k=K, schedule="cs",
                  deadline_policy="close_partial")
    with pytest.raises(ValueError, match="deadline_policy"):
        RoundSpec(n=N, r=R, k=K, schedule="cs", deadline=1.0,
                  deadline_policy="eventually")
    with pytest.raises(ValueError, match="deadline must be"):
        RoundSpec(n=N, r=R, k=K, schedule="cs", deadline=0.0)
    spec = RoundSpec(n=N, r=R, k=K, schedule="cs", deadline=1.0,
                     deadline_policy="reissue")
    assert spec.deadline == 1.0


def test_engine_rejects_bad_policy_args():
    with pytest.raises(ValueError, match="unknown deadline policy"):
        _sweep(IIDProcess(scenario1()), deadline=DEADLINE, policy="later")
    with pytest.raises(ValueError, match="needs a"):
        _sweep(IIDProcess(scenario1()), policy="close_partial")

"""Model substrate tests: forward shapes, decode/full consistency per
mixer family, segment planning, MoE dispatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, LayerSpec, layer_specs, find_period,
                          init_params, forward, init_cache, plan_segments,
                          num_params)

F32 = dict(param_dtype="float32", dtype="float32", remat=False)


def _mk(name="m", **kw):
    base = dict(name=name, arch_type="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97, **F32)
    base.update(kw)
    return ModelConfig(**base)


def _decode_vs_full(cfg, T=9, prefill=5, atol=2e-3):
    key = jax.random.PRNGKey(0)
    p = init_params(key, cfg)
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    full, _, _ = forward(p, cfg, toks)
    cache = init_cache(cfg, 2, 32)
    _, _, cache = forward(p, cfg, toks[:, :prefill], cache=cache)
    for t in range(prefill, T):
        lg, _, cache = forward(p, cfg, toks[:, t:t + 1], cache=cache)
        err = np.abs(np.asarray(lg[:, 0] - full[:, t],
                                np.float32)).max()
        assert err < atol, f"{cfg.name} step {t}: err {err}"
    return full


class TestDecodeConsistency:
    def test_gqa(self):
        _decode_vs_full(_mk("gqa"))

    def test_gqa_with_bias_and_softcap(self):
        _decode_vs_full(_mk("gqa-b", qkv_bias=True, attn_logit_softcap=30.0))

    def test_swa_ring_buffer(self):
        cfg = _mk("swa", sliding_window=4, local_global_pattern=(1, 1),
                  n_layers=4)
        _decode_vs_full(cfg, T=12, prefill=6)

    def test_mla(self):
        cfg = _mk("mla", q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16, head_dim=24, n_kv_heads=4)
        _decode_vs_full(cfg)

    def test_mamba(self):
        cfg = _mk("mamba", arch_type="ssm", ssm_kind="mamba", d_state=8)
        _decode_vs_full(cfg)

    def test_rwkv6(self):
        cfg = _mk("rwkv", arch_type="ssm", ssm_kind="rwkv6", n_kv_heads=4)
        _decode_vs_full(cfg)

    def test_moe(self):
        cfg = _mk("moe", arch_type="moe", n_experts=4, experts_per_token=2,
                  d_ff_expert=96, n_shared_experts=1, dense_prefix=1,
                  capacity_factor=8.0)  # high cf: no drops -> deterministic
        _decode_vs_full(cfg)

    def test_hybrid_jamba_like(self):
        cfg = _mk("hyb", arch_type="hybrid", n_layers=8, ssm_kind="mamba",
                  ssm_period=4, ssm_attn_offset=2, n_experts=4,
                  experts_per_token=2, d_ff_expert=96, moe_period=2,
                  moe_offset=1, d_state=8, capacity_factor=8.0)
        _decode_vs_full(cfg)


class TestSWAWindowSemantics:
    def test_window_limits_context(self):
        """A token beyond the window must not influence the output."""
        cfg = _mk("swa1", sliding_window=3, local_global_pattern=(1, 0),
                  n_layers=1)
        key = jax.random.PRNGKey(1)
        p = init_params(key, cfg)
        t1 = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
        t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab_size)
        l1, _, _ = forward(p, cfg, t1)
        l2, _, _ = forward(p, cfg, t2)
        # position 7 attends to 5,6,7 only (window 3) -> unchanged
        np.testing.assert_allclose(np.asarray(l1[:, -1]),
                                   np.asarray(l2[:, -1]), atol=1e-5)
        # position 1 is within reach of position 0 -> changed
        assert np.abs(np.asarray(l1[:, 1] - l2[:, 1])).max() > 1e-4


class TestSegmentPlanning:
    def test_uniform_stack_single_segment(self):
        cfg = _mk("u", n_layers=12)
        segs = plan_segments(cfg)
        assert len(segs) == 1 and segs[0].reps == 12

    def test_gemma_like_pattern_with_tail(self):
        cfg = _mk("g", n_layers=34, sliding_window=8,
                  local_global_pattern=(5, 1))
        segs = plan_segments(cfg)
        assert sum(len(s.specs) * s.reps for s in segs) == 34
        assert segs[0].specs[0].mixer == "swa"
        assert segs[0].specs[5].mixer == "gqa"
        assert len(segs[0].specs) == 6 and segs[0].reps == 5

    def test_deepseek_like_prefix(self):
        cfg = _mk("d", n_layers=9, arch_type="moe", n_experts=4,
                  experts_per_token=2, d_ff_expert=96, dense_prefix=3)
        specs = layer_specs(cfg)
        assert all(s.ffn == "swiglu" for s in specs[:3])
        assert all(s.ffn == "moe" for s in specs[3:])

    def test_find_period(self):
        a, b = LayerSpec("gqa"), LayerSpec("swa")
        assert find_period((a, a, a, a)) == (1, 4)
        assert find_period((a, b, a, b)) == (2, 2)
        assert find_period((b, b, a, b, b, a, b)) == (3, 2)


class TestMoEDispatch:
    def test_grouped_gemm_matches_dense_oracle(self):
        """Capacity-based grouped GEMM == explicit per-token dense compute
        when capacity is large enough for zero drops."""
        from repro.models import layers as L
        cfg = _mk("moe", arch_type="moe", n_experts=4, experts_per_token=2,
                  d_ff_expert=32, capacity_factor=16.0)
        key = jax.random.PRNGKey(0)
        p = L.moe_init(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, cfg.d_model))
        out, aux = L.moe_apply(p, cfg, x)
        # oracle: route each token through its top-k experts directly
        x2 = np.asarray(x.reshape(-1, cfg.d_model))
        logits = x2 @ np.asarray(p["router"])
        probs = jax.nn.softmax(jnp.asarray(logits), -1)
        w, ids = jax.lax.top_k(probs, 2)
        w = np.asarray(w / w.sum(-1, keepdims=True))
        ids = np.asarray(ids)
        ref = np.zeros_like(x2)
        for t in range(x2.shape[0]):
            for j in range(2):
                e = ids[t, j]
                g = np.asarray(p["w_gate"])[e]
                u = np.asarray(p["w_up"])[e]
                d = np.asarray(p["w_down"])[e]
                h = jax.nn.silu(jnp.asarray(x2[t] @ g)) * (x2[t] @ u)
                ref[t] += w[t, j] * np.asarray(h @ d)
        np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                                   ref, rtol=2e-4, atol=2e-5)
        assert np.isfinite(float(aux))

    def test_capacity_drops_tokens_gracefully(self):
        from repro.models import layers as L
        cfg = _mk("moec", arch_type="moe", n_experts=4, experts_per_token=2,
                  d_ff_expert=32, capacity_factor=0.25)
        p = L.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        out, _ = L.moe_apply(p, cfg, x)
        assert np.isfinite(np.asarray(out)).all()


class TestAttentionCore:
    def test_chunked_matches_naive(self):
        from repro.models.layers import attention_core
        key = jax.random.PRNGKey(0)
        B, H, T, dh = 1, 2, 128, 16
        q = jax.random.normal(key, (B, H, T, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, dh))
        ref = attention_core(q, k, v, causal=True, q_offset=0)
        chunked = attention_core(q, k, v, causal=True, q_offset=0,
                                 chunk_q=32, chunk_k=32)
        # force the chunked path by shrinking the naive threshold
        from repro.models import layers as Lm
        out = Lm.attention_core.__wrapped__(q, k, v, causal=True, q_offset=0) \
            if hasattr(Lm.attention_core, "__wrapped__") else chunked
        np.testing.assert_allclose(np.asarray(ref), np.asarray(chunked),
                                   rtol=2e-3, atol=2e-3)

    def test_windowed_chunked_matches_naive(self):
        from repro.models.layers import attention_core
        key = jax.random.PRNGKey(3)
        B, H, T, dh = 1, 2, 96, 8
        q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (B, H, T, dh))
                   for i in (0, 1, 2))
        ref = attention_core(q, k, v, causal=True, q_offset=0, window=17)
        out = attention_core(q, k, v, causal=True, q_offset=0, window=17,
                             chunk_q=16, chunk_k=16)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-3, atol=2e-3)


def test_vocab_padding_masks_tail():
    cfg = _mk("pad", vocab_size=100, vocab_pad_to=64)
    assert cfg.padded_vocab == 128
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    lg, _, _ = forward(p, cfg, toks)
    assert (np.asarray(lg)[..., 100:] < -1e8).all()


def test_num_params_counts_everything():
    cfg = _mk("np")
    p = init_params(jax.random.PRNGKey(0), cfg)
    n = num_params(p)
    assert n > cfg.padded_vocab * cfg.d_model  # at least the embedding

"""Trace-driven delay sources (core/trace.py + the record/replay paths of
the engine, aggregator, and launcher).

Covers the ISSUE-5 acceptance points:
  (a) the DelayTrace container + versioned on-disk format: validation,
      save/load round-trip, digest/version checks;
  (b) TraceProcess replay semantics: padding/truncation policies per axis,
      trial cycling, determinism (keys ignored);
  (c) round-trip bit-exactness — a trace recorded from ``sweep_rounds``
      under any parametric process, replayed via ``TraceProcess``,
      reproduces the recording run's per-round completion times and
      adaptive decisions exactly, across scheme kinds, message budgets,
      and ragged loads (property test), under any trial chunking;
  (d) calibration: ``calibrate_trace`` recovers a known generating
      cluster's regime parameters and worker scales;
  (e) the round API (aggregator) accepts trace-backed processes;
  (f) the ``as_process`` coercion + clear TypeError satellite.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AR1Process, CalibrationReport, DelayTrace,
                        IIDProcess, MarkovRegimeProcess, RoundSpec,
                        StragglerAggregator, TraceProcess, adaptive_spec,
                        as_process, calibrate_trace, cyclic_to_matrix,
                        ec2_cluster, lb_spec, load_trace, save_trace,
                        scenario1, staircase_to_matrix, sweep_rounds,
                        to_spec, trajectory_samples, validate_trace_file)
import repro.core.trace as trace_mod

N, R, K, ROUNDS, TRIALS = 6, 3, 4, 5, 48

PROCESSES = {
    "iid": IIDProcess(scenario1()),
    "markov": ec2_cluster(N, spread=3.0, persistence=0.9, seed=1),
    "ar1": AR1Process(base=scenario1(), rho=0.8, sigma=0.4),
}
LOADS = (3, 1, 2, 3, 2, 1)
SPEC_SETS = {
    "dense": [to_spec("cs", cyclic_to_matrix(N, R)), lb_spec(R)],
    "ragged": [to_spec("cs", cyclic_to_matrix(N, R), loads=LOADS),
               lb_spec(R, loads=LOADS)],
    "budget": [to_spec("ss", staircase_to_matrix(N, R), messages=2),
               to_spec("ss1", staircase_to_matrix(N, R), messages=1)],
    "budget-ragged": [to_spec("mix", cyclic_to_matrix(N, R), messages=2,
                              loads=LOADS)],
    "adaptive": [adaptive_spec("ad", cyclic_to_matrix(N, R)),
                 adaptive_spec("rb", cyclic_to_matrix(N, R + 1),
                               loads=(R,) * N, rebalance=True)],
}


def _small_trace(rounds=3, trials=2, n=4, r=2, seed=0):
    rng = np.random.default_rng(seed)
    T1 = rng.uniform(0.5, 1.5, (rounds, trials, n, r)).astype(np.float32)
    T2 = rng.uniform(0.5, 1.5, (rounds, trials, n, r)).astype(np.float32)
    return DelayTrace(T1, T2, meta={"source": "test"})


# --------------------- (a) container + on-disk format ------------------------

def test_trace_container_validation():
    tr = _small_trace()
    assert (tr.rounds, tr.trials, tr.n, tr.r) == (3, 2, 4, 2)
    # 3-D input gets a singleton trial axis (a single recorded realization)
    one = DelayTrace(tr.T1[:, 0], tr.T2[:, 0])
    assert one.trials == 1 and one.rounds == 3
    with pytest.raises(ValueError, match="shape"):
        DelayTrace(np.ones((3, 2)), np.ones((3, 2)))
    with pytest.raises(ValueError, match="mismatch"):
        DelayTrace(tr.T1, tr.T2[:, :, :2])
    with pytest.raises(ValueError, match="NaN"):
        DelayTrace(np.full((1, 1, 2, 2), np.nan), np.ones((1, 1, 2, 2)))
    # +inf cells are legal since format v2: a fault-censored result that
    # never arrived.  -inf / non-positive delays stay rejected.
    faulty = DelayTrace(np.full((1, 1, 2, 2), np.inf),
                        np.ones((1, 1, 2, 2)))
    assert faulty.has_faults and not tr.has_faults
    with pytest.raises(ValueError, match="positive"):
        DelayTrace(np.full((1, 1, 2, 2), -np.inf), np.ones((1, 1, 2, 2)))
    with pytest.raises(ValueError, match="positive"):
        DelayTrace(np.zeros((1, 1, 2, 2)), np.ones((1, 1, 2, 2)))
    with pytest.raises(AttributeError):
        tr.T1 = None
    # content identity: equal tables hash/compare equal, meta is advisory
    same = DelayTrace(tr.T1.copy(), tr.T2.copy(), meta={"other": 1})
    assert same == tr and hash(same) == hash(tr)
    assert _small_trace(seed=1) != tr
    # the container owns copies: the caller's float32 arrays stay writable
    # and later caller mutations don't leak into the frozen trace
    mine = np.full((1, 1, 2, 2), 2.0, np.float32)
    held = DelayTrace(mine, mine)
    mine[0, 0, 0, 0] = 9.0
    assert held.T1[0, 0, 0, 0] == 2.0


def test_save_load_roundtrip(tmp_path):
    tr = _small_trace()
    path = save_trace(str(tmp_path / "t"), tr)
    assert path.endswith(".npz")
    back = load_trace(path)
    assert back == tr
    assert back.meta["source"] == "test"
    hdr = validate_trace_file(path)
    # fault-free traces keep writing version 1 so pre-fault readers still
    # load them; only +inf cells bump the header to the current version
    assert hdr["version"] == 1 <= trace_mod.TRACE_FORMAT_VERSION
    assert hdr["rounds"] == 3 and hdr["n"] == 4


def test_load_rejects_corruption_and_new_versions(tmp_path):
    tr = _small_trace()
    path = save_trace(str(tmp_path / "t"), tr)
    # tamper with a table: digest check fires
    with np.load(path) as z:
        parts = dict(z)
    parts["T1"] = parts["T1"] + 0.25
    bad = str(tmp_path / "bad.npz")
    np.savez(bad, **parts)
    with pytest.raises(ValueError, match="digest"):
        load_trace(bad)
    # a future format version is rejected, not misread
    import json
    hdr = json.loads(bytes(parts["header"].tobytes()).decode())
    hdr["version"] = trace_mod.TRACE_FORMAT_VERSION + 1
    parts["T1"] = tr.T1
    parts["header"] = np.frombuffer(json.dumps(hdr).encode(), np.uint8)
    newer = str(tmp_path / "newer.npz")
    np.savez(newer, **parts)
    with pytest.raises(ValueError, match="newer"):
        load_trace(newer)
    # not a trace file at all
    np.savez(str(tmp_path / "x.npz"), T1=tr.T1)
    with pytest.raises(ValueError, match="header"):
        load_trace(str(tmp_path / "x.npz"))


# ------------------------- (b) replay semantics ------------------------------

def _step_tables(proc, n, r, trials=4, steps=1):
    keys = jax.random.split(jax.random.PRNGKey(0), trials)
    state = proc.init(keys, n)
    for _ in range(steps):
        state, T1, T2 = proc.step(state, keys, n, r)
    return np.asarray(T1), np.asarray(T2)


def test_replay_reads_tables_and_ignores_keys():
    tr = _small_trace(rounds=3, trials=4, n=4, r=2)
    proc = TraceProcess(tr)
    T1, _ = _step_tables(proc, 4, 2, trials=4)
    assert np.array_equal(T1, tr.T1[0])
    # second step reads round 1
    T1b, _ = _step_tables(proc, 4, 2, trials=4, steps=2)
    assert np.array_equal(T1b, tr.T1[1])
    # truncation: smaller n / r read the leading block
    T1c, _ = _step_tables(proc, 3, 1, trials=4)
    assert np.array_equal(T1c, tr.T1[0, :, :3, :1])
    # trial cycling: more trials than recorded wrap around
    T1d, _ = _step_tables(proc, 4, 2, trials=6)
    assert np.array_equal(T1d[4:], tr.T1[0, :2])


def test_replay_padding_policies():
    tr = _small_trace(rounds=2, trials=1, n=3, r=2)
    strict = TraceProcess(tr)
    with pytest.raises(ValueError, match="pad_workers='cycle'"):
        strict.init(jax.random.split(jax.random.PRNGKey(0), 2), 5)
    with pytest.raises(ValueError, match="pad_slots='cycle'"):
        _step_tables(strict, 3, 4)
    with pytest.raises(ValueError, match="pad_rounds='cycle'"):
        strict.check_rounds(3)
    strict.check_rounds(2)

    T1w, _ = _step_tables(TraceProcess(tr, pad_workers="cycle"), 5, 2,
                          trials=1)
    assert np.array_equal(T1w[:, 3:], tr.T1[0, :, :2])
    T1s, _ = _step_tables(TraceProcess(tr, pad_slots="cycle"), 3, 4,
                          trials=1)
    assert np.array_equal(T1s[..., 2:], tr.T1[0, ..., :2])
    cyc = TraceProcess(tr, pad_rounds="cycle")
    T1c, _ = _step_tables(cyc, 3, 2, trials=1, steps=3)  # round 2 -> table 0
    assert np.array_equal(T1c, tr.T1[0])
    hold = TraceProcess(tr, pad_rounds="hold")
    T1h, _ = _step_tables(hold, 3, 2, trials=1, steps=4)  # held at final
    assert np.array_equal(T1h, tr.T1[1])
    # sample_rounds honors the policy hooks
    T1all, _ = hold.sample_rounds(jax.random.PRNGKey(0), 1, 3, 2, 4)
    assert np.array_equal(np.asarray(T1all[-1]), tr.T1[1])
    with pytest.raises(ValueError, match="recorded only"):
        strict.sample_rounds(jax.random.PRNGKey(0), 1, 3, 2, 4)
    with pytest.raises(ValueError, match="pad_rounds"):
        TraceProcess(tr, pad_rounds="wrap")
    with pytest.raises(TypeError, match="DelayTrace"):
        TraceProcess(np.ones((2, 1, 3, 2)))


def test_start_round_offsets_replay():
    """Resuming a checkpointed run mid-trace: replay starts at the round
    the next step originally consumed, and the horizon check covers the
    offset."""
    tr = _small_trace(rounds=3, trials=1, n=3, r=2)
    off = TraceProcess(tr, start_round=1)
    T1, _ = _step_tables(off, 3, 2, trials=1)
    assert np.array_equal(T1, tr.T1[1])
    off.check_rounds(2)
    with pytest.raises(ValueError, match="start_round=1"):
        off.check_rounds(3)
    with pytest.raises(ValueError, match="start_round"):
        TraceProcess(tr, start_round=-1)


# ---------------------- (c) round-trip bit-exactness -------------------------

@settings(deadline=None, max_examples=10)
@given(st.sampled_from(sorted(PROCESSES)), st.sampled_from(sorted(SPEC_SETS)),
       st.integers(1, TRIALS))
def test_replay_bit_exact_property(proc_name, set_name, chunk):
    """The acceptance criterion: any (process, scheme-kind, message
    budget, ragged loads) recording replays bit-exactly — identical
    per-trial completion times (hence identical adaptive decisions) under
    any replay chunking, and identical per-round means at the recording's
    chunking."""
    process, specs = PROCESSES[proc_name], SPEC_SETS[set_name]
    censored = set_name == "adaptive"
    res = sweep_rounds(specs, process, N, rounds=ROUNDS, k=K, trials=TRIALS,
                       seed=0, chunk=16, censored_feedback=censored,
                       record_trace=True)
    assert res.trace.T1.shape == (ROUNDS, TRIALS, N,
                                  max(sp.load for sp in specs))
    rep = sweep_rounds(specs, TraceProcess(res.trace), N, rounds=ROUNDS,
                       k=K, trials=TRIALS, seed=77, chunk=16,
                       censored_feedback=censored)
    for sp in specs:
        assert np.array_equal(res.per_round[sp.name], rep.per_round[sp.name])
        assert np.array_equal(res.wallclock[sp.name], rep.wallclock[sp.name])
    # per-trial trajectories are chunking-invariant bit-exact
    sp = specs[0]
    samp, tr = trajectory_samples(sp, process, N, rounds=ROUNDS, k=K,
                                  trials=TRIALS, seed=0, chunk=16,
                                  censored_feedback=censored,
                                  record_trace=True)
    rep_s = trajectory_samples(sp, TraceProcess(tr), N, rounds=ROUNDS, k=K,
                               trials=TRIALS, seed=3, chunk=chunk,
                               censored_feedback=censored)
    assert np.array_equal(np.asarray(samp), np.asarray(rep_s))


def test_trace_field_default_none():
    res = sweep_rounds(SPEC_SETS["dense"], PROCESSES["iid"], N,
                       rounds=2, k=K, trials=8, seed=0)
    assert res.trace is None
    samp = trajectory_samples(SPEC_SETS["dense"][0], PROCESSES["iid"], N,
                              rounds=2, k=K, trials=8, seed=0)
    assert np.asarray(samp).shape == (8, 2)


# ----------------------------- (d) calibration -------------------------------

def test_calibration_recovers_generating_cluster():
    scale = (0.6, 1.0, 1.8, 0.9)
    truth = MarkovRegimeProcess(base=scenario1(), worker_scale=scale,
                                p_slow=0.3, persistence=0.85, slow=6.0)
    res = sweep_rounds([to_spec("cs", cyclic_to_matrix(4, 2))], truth, 4,
                       rounds=60, k=3, trials=32, seed=3, record_trace=True)
    rep = calibrate_trace(res.trace)
    assert isinstance(rep, CalibrationReport)
    assert abs(rep.p_slow - 0.3) < 0.08
    assert abs(rep.persistence - 0.85) < 0.08
    assert abs(rep.slow - 6.0) / 6.0 < 0.25
    # worker ordering survives (scales are normalized to geo-mean 1)
    assert (np.argsort(rep.worker_scale)
            == np.argsort(np.asarray(scale))).all()
    # fit-quality report: moments of the fitted process track the trace
    assert rep.mean_rel_err < 0.15
    assert rep.comm_mean_rel_err < 0.15
    assert rep.lag1_trace > 0.4 and rep.lag1_fit > 0.4
    assert "p_slow" in rep.summary()


def test_calibration_homogeneous_degenerates_gracefully():
    res = sweep_rounds([to_spec("cs", cyclic_to_matrix(4, 2))],
                       IIDProcess(scenario1()), 4, rounds=20, k=3,
                       trials=32, seed=0, record_trace=True)
    rep = calibrate_trace(res.trace)
    assert rep.p_slow == 0.0 and rep.slow == 1.0 and rep.persistence == 0.0
    assert max(rep.worker_scale) / min(rep.worker_scale) < 1.2
    assert rep.mean_rel_err < 0.1


# --------------------- (e) round API on trace processes ----------------------

def test_aggregator_replays_trace_deterministically():
    tr = _small_trace(rounds=4, trials=1, n=4, r=2, seed=5)
    spec = RoundSpec(n=4, r=2, k=3, schedule="ss")

    def run():
        agg = StragglerAggregator(spec, tr)        # DelayTrace coerced
        out = []
        for i in range(4):
            _, t_done = agg.round_mask(jax.random.PRNGKey(i))
            out.append(float(t_done))
        return out, agg

    a, agg = run()
    b, _ = run()
    assert a == b                    # keys are ignored: pure replay
    # horizon: a 5th round exceeds the strict trace
    with pytest.raises(ValueError, match="recorded only"):
        agg.round_mask(jax.random.PRNGKey(99))
    # expected_completion caps its default rounds at the trace horizon
    assert np.isfinite(agg.expected_completion(trials=16))


# ------------------------- (f) as_process coercion ---------------------------

def test_as_process_accepts_traces_and_names_protocol():
    tr = _small_trace()
    p = as_process(tr)
    assert isinstance(p, TraceProcess) and p.trace is tr
    tp = TraceProcess(tr, pad_rounds="cycle")
    assert as_process(tp) is tp
    with pytest.raises(TypeError) as ei:
        as_process({"not": "a delay source"})
    msg = str(ei.value)
    # the satellite: the error names the accepted types and the protocol
    for needle in ("DelayProcess", "init/step", "DelayModel", "DelayTrace",
                   "dict"):
        assert needle in msg, (needle, msg)

"""PC / PCMM coded-baseline tests (paper Sec. VI-B, Examples 4-5)."""
import numpy as np
import pytest

from repro.core import (pc_threshold, pcmm_threshold, pc_encode, pc_decode,
                        pc_worker_compute, pcmm_encode, pcmm_decode,
                        pcmm_worker_compute, simulate_pc_completion,
                        simulate_pcmm_completion, simulate_completion,
                        cyclic_to_matrix, scenario1)


def _problem(n, d, b, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d, b))
    theta = rng.standard_normal(d)
    truth = sum(X[i] @ (X[i].T @ theta) for i in range(n))
    return X, theta, truth


def test_thresholds_match_paper():
    assert pc_threshold(4, 2) == 3        # Example 4: any 3 workers
    assert pcmm_threshold(4) == 7         # Example 5: 7 computations
    assert pc_threshold(15, 3) == 9
    assert pc_threshold(15, 15) == 1


@pytest.mark.parametrize("n,r", [(4, 2), (6, 2), (6, 3), (5, 2), (8, 4)])
def test_pc_exact_recovery_from_threshold_workers(n, r):
    X, theta, truth = _problem(n, d=7, b=4)
    Xt, alphas, _ = pc_encode(X, r)
    res = np.stack([pc_worker_compute(Xt[i], theta) for i in range(n)])
    kth = pc_threshold(n, r)
    # any subset of kth workers suffices — try a few
    for sel in ([*range(kth)], [*range(n - kth, n)]):
        out = pc_decode(res[sel], alphas[sel], n, r)
        np.testing.assert_allclose(out, truth, rtol=1e-6, atol=1e-8)


def test_pc_insufficient_workers_raises():
    n, r = 4, 2
    X, theta, _ = _problem(n, 5, 3)
    Xt, alphas, _ = pc_encode(X, r)
    res = np.stack([pc_worker_compute(Xt[i], theta) for i in range(2)])
    with pytest.raises(ValueError):
        pc_decode(res, alphas[:2], n, r)


@pytest.mark.parametrize("n,r", [(3, 2), (4, 2), (5, 2)])
def test_pcmm_exact_recovery(n, r):
    X, theta, truth = _problem(n, d=6, b=4)
    Xh, betas = pcmm_encode(X, r)
    res, pts = [], []
    for i in range(n):
        for j in range(r):
            res.append(pcmm_worker_compute(Xh[i, j], theta))
            pts.append(betas[i, j])
    need = pcmm_threshold(n)
    out = pcmm_decode(np.stack(res)[:need], np.array(pts)[:need], n)
    np.testing.assert_allclose(out, truth, rtol=1e-3)


def test_pcmm_infeasible_when_too_few_slots():
    with pytest.raises(ValueError):
        simulate_pcmm_completion(scenario1(), n=4, r=1, trials=8)


def test_pc_single_message_slower_than_uncoded_partial():
    """Paper Figs. 4-5: CS/SS with partial results beat PC for homogeneous
    delays (PC waits for full r-task compute at each worker)."""
    n, r, = 8, 4
    m = scenario1()
    t_pc = float(simulate_pc_completion(m, n, r, trials=4000).mean())
    t_cs = float(np.mean(np.asarray(
        simulate_completion(cyclic_to_matrix(n, r), m, k=n, trials=4000))))
    assert t_cs < t_pc


def test_pcmm_beats_pc_like_paper():
    """Paper Fig. 4: PCMM (multi-message) improves upon PC."""
    n, r = 12, 4
    m = scenario1()
    t_pc = float(simulate_pc_completion(m, n, r, trials=4000).mean())
    t_pcmm = float(simulate_pcmm_completion(m, n, r, trials=4000).mean())
    assert t_pcmm < t_pc


def test_pc_completion_increases_with_r_homogeneous():
    """Paper Fig. 5 observation: PC completion time *increases* with r when
    worker delays are not highly skewed."""
    n = 12
    m = scenario1()
    ts = [float(simulate_pc_completion(m, n, r, trials=4000).mean())
          for r in (2, 4, 6)]
    assert ts[0] < ts[-1]

"""Tests for arrival/completion-time computation (paper eqs. 1-6, 46)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (cyclic_to_matrix, staircase_to_matrix, scenario1,
                        slot_arrival_times, task_arrival_times,
                        completion_time, lower_bound_time,
                        first_k_distinct_mask, simulate_completion,
                        simulate_lower_bound, mean_completion_time,
                        TruncatedGaussianDelays, ShiftedExponentialDelays,
                        BimodalStragglerDelays)


def test_example1_arrival_times_by_hand():
    """Paper Example 1: check t_{i,j} against the closed form (eq. 4)."""
    C = np.array([[0, 1, 2], [2, 1, 0], [2, 3, 0], [3, 2, 0]])  # eq. (3), 0-idx
    rng = np.random.default_rng(0)
    T1 = rng.random((1, 4, 3)).astype(np.float32)
    T2 = rng.random((1, 4, 3)).astype(np.float32)
    s = np.asarray(slot_arrival_times(jnp.asarray(T1), jnp.asarray(T2)))[0]
    # worker 0: t_{1,1}=T1[0,0]+T2[0,0]; t_{1,2}=T1[0,0]+T1[0,1]+T2[0,1]...
    assert np.isclose(s[0, 0], T1[0, 0, 0] + T2[0, 0, 0])
    assert np.isclose(s[0, 1], T1[0, 0, :2].sum() + T2[0, 0, 1])
    assert np.isclose(s[0, 2], T1[0, 0, :3].sum() + T2[0, 0, 2])
    tau = np.asarray(task_arrival_times(jnp.asarray(C),
                                        jnp.asarray(s)[None], 4))[0]
    # task 3 (0-idx) only at workers 2 (slot 1) and 3 (slot 0)
    assert np.isclose(tau[3], min(s[2, 1], s[3, 0]))
    # task 1 only at workers 0, 1 (slot 1 both)
    assert np.isclose(tau[1], min(s[0, 1], s[1, 1]))


def test_unassigned_task_is_inf():
    C = np.array([[0], [0]])  # task 1 never computed
    s = jnp.ones((1, 2, 1))
    tau = task_arrival_times(jnp.asarray(C), s, 2)
    assert np.isinf(np.asarray(tau)[0, 1])


def test_completion_is_kth_order_statistic():
    tau = jnp.asarray([[3.0, 1.0, 2.0, 5.0]])
    assert completion_time(tau, 1)[0] == 1.0
    assert completion_time(tau, 3)[0] == 3.0
    assert completion_time(tau, 4)[0] == 5.0


def test_lower_bound_below_all_schedules():
    n, r, k = 8, 3, 6
    m = scenario1()
    lb = float(simulate_lower_bound(m, n, r, k, trials=4000).mean())
    for C in (cyclic_to_matrix(n, r), staircase_to_matrix(n, r)):
        ub = mean_completion_time(C, m, k, trials=4000)
        assert lb <= ub + 1e-12


def test_monotonicity_in_k_and_r():
    """More targets -> slower; more load -> (weakly) faster completion."""
    n = 10
    m = scenario1()
    ts = [mean_completion_time(cyclic_to_matrix(n, 3), m, k, trials=3000)
          for k in (2, 5, 8, 10)]
    assert all(a < b for a, b in zip(ts, ts[1:]))
    ts_r = [mean_completion_time(cyclic_to_matrix(n, r), m, 8, trials=3000)
            for r in (1, 2, 4, 8)]
    assert all(a >= b - 1e-5 for a, b in zip(ts_r, ts_r[1:]))


def test_mask_weights_sum_to_k_and_respect_completion():
    n, r, k = 6, 3, 4
    C = jnp.asarray(staircase_to_matrix(n, r))
    m = scenario1()
    T1, T2 = m.sample(jax.random.PRNGKey(3), 64, n, r)
    s = slot_arrival_times(T1, T2)
    w, t_done = first_k_distinct_mask(C, s, n, k)
    assert np.allclose(np.asarray(w.sum(axis=(1, 2))), k, atol=1e-5)
    # every used slot arrived no later than the completion time
    used = np.asarray(w) > 0
    assert (np.asarray(s)[used] <= np.asarray(
        jnp.broadcast_to(t_done[:, None, None], s.shape))[used] + 1e-7).all()


def test_mask_selects_distinct_tasks():
    n, r, k = 5, 4, 3
    C = cyclic_to_matrix(n, r)
    m = scenario1()
    T1, T2 = m.sample(jax.random.PRNGKey(9), 32, n, r)
    s = slot_arrival_times(T1, T2)
    w, _ = first_k_distinct_mask(jnp.asarray(C), s, n, k)
    w = np.asarray(w)
    for t in range(32):
        tasks = {int(C[i, j]) for i in range(n) for j in range(r)
                 if w[t, i, j] > 0}
        assert len(tasks) == k


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 10), st.data())
def test_property_completion_bounds(n, data):
    """LB <= t_C for every realization; t_C(k) nondecreasing in k."""
    r = data.draw(st.integers(1, n))
    k = data.draw(st.integers(1, n))
    seed = data.draw(st.integers(0, 2**16))
    C = jnp.asarray(cyclic_to_matrix(n, r))
    m = ShiftedExponentialDelays()
    T1, T2 = m.sample(jax.random.PRNGKey(seed), 8, n, r)
    s = slot_arrival_times(T1, T2)
    tau = task_arrival_times(C, s, n)
    tc = completion_time(tau, k)
    lb = lower_bound_time(s, k)
    assert (np.asarray(lb) <= np.asarray(tc) + 1e-7).all()
    if k < n:
        assert (np.asarray(completion_time(tau, k + 1))
                >= np.asarray(tc) - 1e-7).all()


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 8), st.integers(0, 1000))
def test_property_r_equals_n_beats_smaller_r(n, seed):
    """Full load weakly dominates any smaller load for the same schedule
    realization-wise is not guaranteed, but on average it is (superset of
    opportunities). Check on means."""
    m = TruncatedGaussianDelays()
    k = max(1, n - 1)
    t_full = mean_completion_time(cyclic_to_matrix(n, n), m, k,
                                  trials=1500, seed=seed)
    t_half = mean_completion_time(cyclic_to_matrix(n, max(1, n // 2)), m, k,
                                  trials=1500, seed=seed)
    assert t_full <= t_half * 1.02  # small MC slack


def test_bimodal_straggler_model_slows_rounds():
    m0 = scenario1()
    m1 = BimodalStragglerDelays(base=m0, p_straggle=0.5, slow=10.0)
    n, r, k = 8, 2, 8
    C = cyclic_to_matrix(n, r)
    t0 = mean_completion_time(C, m0, k, trials=2000)
    t1 = mean_completion_time(C, m1, k, trials=2000)
    assert t1 > t0 * 1.5
    # but with k < n and load, scheduling recovers some of it
    t1_partial = mean_completion_time(cyclic_to_matrix(n, 4), m1, 6,
                                      trials=2000)
    assert t1_partial < t1


def test_delay_models_shapes_and_positivity():
    for m in (scenario1(), ShiftedExponentialDelays(),
              BimodalStragglerDelays()):
        T1, T2 = m.sample(jax.random.PRNGKey(0), 7, 5, 3)
        assert T1.shape == (7, 5, 3) and T2.shape == (7, 5, 3)
        assert (np.asarray(T1) > 0).all() and (np.asarray(T2) > 0).all()


def test_empirical_delays_resample():
    from repro.core import EmpiricalDelays
    rows = np.abs(np.random.default_rng(0).standard_normal((50, 4))) + 0.1
    m = EmpiricalDelays(samples1=tuple(map(tuple, rows)),
                        samples2=tuple(map(tuple, rows * 2)))
    T1, T2 = m.sample(jax.random.PRNGKey(1), 16, 4, 2)
    assert T1.shape == (16, 4, 2)
    # resampled values must come from the measured set (per worker column)
    for w in range(4):
        assert np.isin(np.asarray(T1)[:, w, :].ravel(),
                       rows[:, w].astype(np.float32)).all()

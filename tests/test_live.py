"""Live execution layer: async master-worker rounds over inproc/TCP must
be *the same experiment* as the Monte Carlo engine — shared-seed delay
tables, rounds closing at ``k`` distinct results, deadline accounting, and
a recorded trace that replays bit-exactly through ``sweep_rounds``."""
import numpy as np
import pytest

import repro.core as core
from repro.core import (RoundConfig, TraceProcess, ec2_cluster,
                        sweep_rounds)
from repro.live import run_live, sample_delay_tables

ROUNDS = 5


@pytest.fixture(scope="module")
def process():
    return ec2_cluster(4, spread=3.0, persistence=0.9, seed=1)


@pytest.fixture(scope="module")
def cfg():
    return RoundConfig(n=4, k=3, kind="cs", r=2, seed=42)


@pytest.fixture(scope="module")
def live(cfg, process):
    """One shared live run (jit warm-up dominates; results are pure)."""
    return run_live(cfg, process, ROUNDS)


class TestInprocRun:
    def test_reaches_k_each_round(self, cfg, live):
        assert live.per_round.shape == (ROUNDS,)
        assert np.isfinite(live.per_round).all()
        assert (live.per_round > 0).all()
        # no deadline: every round waits for the full k distinct results
        assert live.realized.tolist() == [cfg.k] * ROUNDS
        assert not live.missed.any()
        assert live.config == cfg

    def test_round_reports(self, cfg, live):
        assert len(live.reports) == ROUNDS
        for rep in live.reports:
            assert rep.results >= cfg.k        # k distinct needs >= k msgs
            assert not rep.dead and not rep.stalled
            assert rep.t_done == pytest.approx(live.per_round[rep.round])

    def test_worker_tables_match_engine_recording(self, cfg, process):
        """Workers must draw delays with the engine's own jitted recording
        program — the whole bit-exactness contract rests on this."""
        T1, T2 = sample_delay_tables(process, cfg.seed, ROUNDS, cfg.n,
                                     cfg.width)
        eng = sweep_rounds([cfg.to_scheme_spec("s")], process, cfg.n,
                           rounds=ROUNDS, trials=1, k=cfg.k, seed=cfg.seed,
                           record_trace=True)
        np.testing.assert_array_equal(T1, eng.trace.T1[:, 0])
        np.testing.assert_array_equal(T2, eng.trace.T2[:, 0])


class TestEngineAgreement:
    def test_matches_engine_run(self, cfg, process, live):
        """Live per-round completions == the engine's bit-exactly-
        reproducible (record -> replay) evaluation of the same seed."""
        eng = sweep_rounds([cfg.to_scheme_spec("s")], process, cfg.n,
                           rounds=ROUNDS, trials=1, k=cfg.k, seed=cfg.seed,
                           record_trace=True)
        np.testing.assert_array_equal(
            live.per_round.astype(np.float32),
            eng.per_round["s"].astype(np.float32))

    def test_trace_replays_bit_exact(self, cfg, live):
        trace = live.trace
        assert trace.rounds == ROUNDS and trace.n == cfg.n
        # dense at time_scale=0 (workers run synchronously) -> v1 header;
        # +inf-censored tables would promote the header to v2
        assert trace.header()["version"] <= core.TRACE_FORMAT_VERSION
        assert trace.meta["source"] == "live"
        rep = sweep_rounds([cfg.to_scheme_spec("s")], TraceProcess(trace),
                           cfg.n, rounds=ROUNDS, trials=1, k=cfg.k,
                           seed=cfg.seed)
        np.testing.assert_array_equal(
            live.per_round.astype(np.float32),
            rep.per_round["s"].astype(np.float32))

    def test_trace_file_round_trip(self, cfg, live, tmp_path):
        path = core.save_trace(str(tmp_path / "live.npz"), live.trace)
        back = core.load_trace(path)
        assert back.header()["digest"] == live.trace.header()["digest"]


class TestDeadline:
    def test_close_partial_matches_engine(self, cfg, process, live):
        dl = float(np.quantile(live.per_round, 0.5))
        cfg_dl = RoundConfig(n=4, k=3, kind="cs", r=2, seed=42, deadline=dl,
                             deadline_policy="close_partial")
        res = run_live(cfg_dl, process, ROUNDS)
        eng = sweep_rounds([cfg.to_scheme_spec("s")], process, cfg.n,
                           rounds=ROUNDS, trials=1, k=cfg.k, seed=cfg.seed,
                           deadline=dl, deadline_policy="close_partial",
                           record_trace=True)
        deg = eng.degradation["s"]
        np.testing.assert_array_equal(
            res.per_round.astype(np.float32),
            eng.per_round["s"].astype(np.float32))
        np.testing.assert_array_equal(res.realized.astype(np.float64),
                                      np.asarray(deg["realized_k"]))
        np.testing.assert_array_equal(res.missed.astype(np.float64),
                                      np.asarray(deg["missed"]))
        # a median-of-run deadline must actually bite
        assert 0 < int(res.missed.sum()) < ROUNDS
        assert (res.per_round <= dl + 1e-6).all()
        assert (res.realized <= cfg.k).all()
        # the deadline run's own trace also replays bit-exactly
        rep = sweep_rounds([cfg.to_scheme_spec("s")],
                           TraceProcess(res.trace), cfg.n, rounds=ROUNDS,
                           trials=1, k=cfg.k, seed=cfg.seed, deadline=dl,
                           deadline_policy="close_partial")
        np.testing.assert_array_equal(
            res.per_round.astype(np.float32),
            rep.per_round["s"].astype(np.float32))

    def test_adaptive_reissue_completes(self, process):
        cfg = RoundConfig(n=4, k=3, kind="cs", r=2, seed=7, adaptive=True,
                          censored_feedback=True, deadline=5e-4,
                          deadline_policy="reissue")
        res = run_live(cfg, process, ROUNDS)
        assert res.per_round.shape == (ROUNDS,)
        assert np.isfinite(res.per_round).all()
        assert (res.realized <= cfg.k).all()


class TestTransports:
    def test_tcp_parity(self, cfg, process, live):
        res = run_live(cfg, process, ROUNDS, address="tcp://127.0.0.1:0")
        np.testing.assert_array_equal(res.per_round, live.per_round)
        np.testing.assert_array_equal(res.trace.T1, live.trace.T1)

    def test_bad_address_scheme(self, cfg, process):
        with pytest.raises(ValueError):
            run_live(cfg, process, 2, address="carrier-pigeon://x")


class TestFacade:
    def test_core_reexports_live(self):
        # PEP 562 lazy exports: available without importing repro.live first
        assert core.run_live is run_live
        for name in ("Master", "LiveResult", "RoundReport", "run_worker",
                     "sample_delay_tables", "Comm", "Listener",
                     "CommClosedError", "connect", "listen"):
            assert getattr(core, name) is not None
        assert "run_live" in dir(core)

    def test_round_config_exported(self):
        assert core.RoundConfig is RoundConfig

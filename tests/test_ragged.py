"""Tests for ragged per-worker loads end-to-end (ISSUE-4).

Covers the acceptance points:
  (a) ragged constructions (CS/SS/RA + validation + load inference);
  (b) uniform-``loads`` specs reproduce the dense path BIT-EXACTLY under
      common random numbers for every scheme kind (to/tau/adaptive/lb),
      with and without a message budget;
  (c) ragged engine paths match independent numpy oracles (task arrivals,
      order statistics, per-worker message grouping, ragged lower bound);
  (d) ``greedy_load_rebalance``: budget conservation, bounds, slow workers
      shed slots, no-feedback fixed point, numpy/JAX batch agreement;
  (e) chunk invariance of ``sweep_rounds`` with re-balanced loads, and the
      rebalance scheme beating permutation-only adaptation on a
      heterogeneous persistent cluster;
  (f) ragged rounds through the aggregator/train API (masked slots carry
      zero winner weight; eq.-(61) weighting stays unbiased);
  (g) the per-message overhead ``comm_eps`` (Ozfatura trade-off) against a
      numpy oracle and its effect on the optimal budget.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MASKED, AdaptiveScheduler, MarkovRegimeProcess,
                        RoundSpec, ShiftedExponentialDelays,
                        StragglerAggregator, adaptive_spec, clear_cache,
                        completion_samples, cyclic_to_matrix, ec2_cluster,
                        greedy_load_rebalance, greedy_load_rebalance_batch,
                        heterogeneous_scales, lb_spec, loads_of_matrix,
                        mask_matrix_loads, message_arrival_times,
                        message_comm_delays, message_boundaries,
                        message_group_sizes, random_assignment_to_matrix,
                        scenario1, staircase_to_matrix, sweep, sweep_rounds,
                        task_arrival_samples, tau_spec, theorem1_mean_mc,
                        lower_bound_mean_mc, to_matrix, to_spec,
                        trajectory_samples, validate_to_matrix)


LOADS = (3, 1, 2, 3, 1, 2)
N6 = 6


def _oracle_draws(model, n, r, trials, seed):
    # the engine's per-trial key convention: fold_in(base, trial id)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(seed), jnp.arange(trials, dtype=jnp.int32))
    T1s, T2s = [], []
    for i in range(trials):
        T1, T2 = model.sample(keys[i], 1, n, r)
        T1s.append(np.asarray(T1)[0])
        T2s.append(np.asarray(T2)[0])
    return np.stack(T1s), np.stack(T2s)


# ------------------------- (a) ragged constructions --------------------------

class TestRaggedConstructions:
    def test_cs_ss_ragged_shapes_and_masks(self):
        for build in (cyclic_to_matrix, staircase_to_matrix):
            C = build(N6, loads=LOADS)
            assert C.shape == (N6, max(LOADS))
            assert (loads_of_matrix(C) == np.asarray(LOADS)).all()
            validate_to_matrix(C, N6, loads=LOADS)
            # active prefix rows match the dense construction
            D = build(N6, max(LOADS))
            for i, l in enumerate(LOADS):
                assert (C[i, :l] == D[i, :l]).all()
                assert (C[i, l:] == MASKED).all()

    def test_slot0_diagonal_keeps_coverage(self):
        for build in (cyclic_to_matrix, staircase_to_matrix):
            C = build(N6, loads=LOADS)
            assert sorted(C[:, 0].tolist()) == list(range(N6))

    def test_ragged_ra_coverage_and_distinctness(self):
        C = random_assignment_to_matrix(8, loads=(2, 3, 1, 8, 4, 1, 2, 5),
                                        seed=3)
        validate_to_matrix(C, 8)
        assert sorted(C[:, 0].tolist()) == list(range(8))   # diagonal start

    def test_to_matrix_passes_loads(self):
        C = to_matrix("cs", N6, loads=LOADS)
        assert (loads_of_matrix(C) == np.asarray(LOADS)).all()

    def test_wider_grid_than_max_load(self):
        C = cyclic_to_matrix(N6, 5, loads=LOADS)
        assert C.shape == (N6, 5)
        assert (loads_of_matrix(C) == np.asarray(LOADS)).all()

    def test_mask_matrix_loads_and_inference_errors(self):
        C = cyclic_to_matrix(4, 3)
        M = mask_matrix_loads(C, [2, 1, 3, 1])
        assert (loads_of_matrix(M) == [2, 1, 3, 1]).all()
        bad = C.copy()
        bad[0, 0] = MASKED                       # interior mask
        with pytest.raises(ValueError, match="trailing"):
            loads_of_matrix(bad)
        with pytest.raises(ValueError, match="active"):
            loads_of_matrix(np.full((2, 2), MASKED))
        with pytest.raises(ValueError):
            cyclic_to_matrix(4, loads=[0, 1, 1, 1])     # load 0
        with pytest.raises(ValueError):
            cyclic_to_matrix(4, loads=[1, 1, 1])        # wrong length
        with pytest.raises(ValueError):
            cyclic_to_matrix(4, 2, loads=[3, 1, 1, 1])  # load > width
        with pytest.raises(ValueError, match="match"):
            validate_to_matrix(mask_matrix_loads(C, [2, 1, 3, 1]), 4,
                               loads=[1, 1, 3, 1])


# ------------------ (b) uniform loads == dense, bit-exact --------------------

class TestUniformLoadsParity:
    @pytest.mark.parametrize("messages", [None, 1, 2])
    def test_to_and_lb_bitexact(self, messages):
        n, r, k, trials = 8, 4, 6, 1200
        m = scenario1()
        C = staircase_to_matrix(n, r)
        dense = completion_samples(to_spec("x", C, messages=messages), m, n,
                                   trials=trials, seed=3, k=k)
        ragged = completion_samples(
            to_spec("x", C, messages=messages, loads=[r] * n), m, n,
            trials=trials, seed=3, k=k)
        assert (np.asarray(dense) == np.asarray(ragged)).all()
        dlb = completion_samples(lb_spec(r, messages=messages), m, n,
                                 trials=trials, seed=3, k=k)
        rlb = completion_samples(lb_spec(messages=messages, loads=[r] * n),
                                 m, n, trials=trials, seed=3, k=k)
        assert (np.asarray(dlb) == np.asarray(rlb)).all()

    def test_tau_bitexact(self):
        n, r, trials = 8, 4, 800
        m = scenario1()
        C = cyclic_to_matrix(n, r)
        a = task_arrival_samples(C, m, trials=trials, seed=1)
        b = task_arrival_samples(C, m, trials=trials, seed=1, loads=[r] * n)
        assert (np.asarray(a) == np.asarray(b)).all()

    def test_adaptive_bitexact_in_rounds(self):
        n, r, k = 6, 3, 5
        proc = MarkovRegimeProcess(base=scenario1(),
                                   worker_scale=heterogeneous_scales(n, 2.0),
                                   persistence=0.9)
        C = cyclic_to_matrix(n, r)
        a = trajectory_samples(adaptive_spec("a", C), proc, n, rounds=4,
                               k=k, trials=200, seed=0,
                               censored_feedback=True)
        b = trajectory_samples(adaptive_spec("a", C, loads=[r] * n), proc,
                               n, rounds=4, k=k, trials=200, seed=0,
                               censored_feedback=True)
        assert (np.asarray(a) == np.asarray(b)).all()

    def test_uniform_spec_is_canonical_dense(self):
        C = cyclic_to_matrix(6, 3)
        assert to_spec("x", C, loads=[3] * 6) == to_spec("x", C)
        assert lb_spec(3, loads=[3] * 6) == lb_spec(3)


# ---------------------- (c) ragged engine vs numpy oracle --------------------

class TestRaggedOracles:
    def _setup(self, trials=250, seed=11):
        model = ShiftedExponentialDelays()
        Cr = cyclic_to_matrix(N6, loads=LOADS)
        T1, T2 = _oracle_draws(model, N6, max(LOADS), trials, seed)
        s = np.cumsum(T1, -1) + T2
        return model, Cr, s

    def test_ragged_task_arrivals_and_completion(self):
        model, Cr, s = self._setup()
        trials = s.shape[0]
        tau = np.full((trials, N6), np.inf)
        for w in range(N6):
            for j in range(LOADS[w]):
                tau[:, Cr[w, j]] = np.minimum(tau[:, Cr[w, j]], s[:, w, j])
        got_tau = np.asarray(task_arrival_samples(Cr, model, trials=trials,
                                                  seed=11))
        np.testing.assert_allclose(got_tau, tau, rtol=1e-6)
        for k in (1, 4, 6):
            got = np.asarray(completion_samples(to_spec("x", Cr), model, N6,
                                                trials=trials, seed=11, k=k))
            np.testing.assert_allclose(got, np.sort(tau, -1)[:, k - 1],
                                       rtol=1e-6)

    def test_ragged_lower_bound(self):
        model, Cr, s = self._setup()
        trials = s.shape[0]
        act = np.concatenate([s[:, w, :LOADS[w]] for w in range(N6)], axis=1)
        assert act.shape[1] == sum(LOADS)
        for k in (2, 5):
            got = np.asarray(completion_samples(lb_spec(loads=LOADS), model,
                                                N6, trials=trials, seed=11,
                                                k=k))
            np.testing.assert_allclose(got, np.sort(act, -1)[:, k - 1],
                                       rtol=1e-6)

    @pytest.mark.parametrize("messages", [1, 2])
    def test_ragged_message_grouping(self, messages):
        """Worker w groups its loads[w] active slots into
        min(messages, loads[w]) messages — per-worker closing slots."""
        model, Cr, s = self._setup()
        trials = s.shape[0]
        s_msg = np.full_like(s, np.inf)
        for w in range(N6):
            l = LOADS[w]
            mi = min(messages, l)
            bounds = message_boundaries(l, mi)
            smap = bounds[np.searchsorted(bounds, np.arange(l))]
            s_msg[:, w, :l] = s[:, w, smap]
        tau = np.full((trials, N6), np.inf)
        for w in range(N6):
            for j in range(LOADS[w]):
                tau[:, Cr[w, j]] = np.minimum(tau[:, Cr[w, j]],
                                              s_msg[:, w, j])
        got = np.asarray(completion_samples(
            to_spec("x", Cr, messages=messages), model, N6, trials=trials,
            seed=11, k=4))
        np.testing.assert_allclose(got, np.sort(tau, -1)[:, 3], rtol=1e-6)
        # engine message_arrival_times agrees with the same oracle
        T1, T2 = _oracle_draws(model, N6, max(LOADS), 16, seed=11)
        arr = np.asarray(message_arrival_times(jnp.asarray(T1),
                                               jnp.asarray(T2), messages,
                                               loads=LOADS))
        s16 = np.cumsum(T1, -1) + T2
        for w in range(N6):
            l = LOADS[w]
            mi = min(messages, l)
            bounds = message_boundaries(l, mi)
            smap = bounds[np.searchsorted(bounds, np.arange(l))]
            np.testing.assert_allclose(arr[:, w, :l], s16[:, w, smap],
                                       rtol=1e-6)
            assert np.isinf(arr[:, w, l:]).all()

    def test_ragged_theorem1_and_lb_mean(self):
        n = 5
        loads = (2, 1, 3, 1, 2)
        model = ShiftedExponentialDelays()
        Cr = cyclic_to_matrix(n, loads=loads)
        k = 4
        direct = np.asarray(completion_samples(to_spec("x", Cr), model, n,
                                               trials=20000, seed=0,
                                               k=k)).mean()
        thm = theorem1_mean_mc(Cr, model, k, tmax=4e-3, trials=20000, seed=0)
        assert np.isclose(thm, direct, rtol=0.02)
        lbm = lower_bound_mean_mc(model, n, k, loads=loads, trials=20000,
                                  seed=0)
        assert 0 < lbm <= direct + 1e-9

    def test_coverage_validation(self):
        """A ragged schedule that cannot deliver k distinct tasks is
        rejected up front instead of returning +inf means."""
        C = np.array([[0, 1], [0, MASKED], [1, MASKED]])   # covers 2 tasks
        m = scenario1()
        with pytest.raises(ValueError, match="covers only"):
            sweep([to_spec("x", C)], m, 3, trials=8, ks=3)
        with pytest.raises(ValueError, match="covers only"):
            sweep_rounds([to_spec("x", C)], m, 3, rounds=2, k=3, trials=8)


# ----------------------- (d) greedy load re-balancing ------------------------

class TestGreedyLoadRebalance:
    def test_conserves_budget_and_bounds(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            n = int(rng.integers(3, 12))
            r_max = int(rng.integers(2, 8))
            loads = rng.integers(1, r_max + 1, n)
            est = rng.random(n) + 0.01
            out = greedy_load_rebalance(est, loads, r_max=r_max)
            assert out.sum() == loads.sum()
            assert out.min() >= 1 and out.max() <= r_max

    def test_slow_workers_shed_slots(self):
        est = np.array([1.0, 1.0, 9.0, 1.0, 9.0, 1.0])
        out = greedy_load_rebalance(est, [3] * 6, r_max=6)
        assert out[2] < 3 and out[4] < 3          # slow shed
        assert out[[0, 1, 3, 5]].max() > 3        # fast gained
        assert out.sum() == 18

    def test_no_feedback_is_fixed_point(self):
        for est in (None, np.ones(6), np.full(6, np.inf)):
            out = greedy_load_rebalance(est, [3] * 6, r_max=6)
            assert (out == 3).all()

    def test_censored_inf_estimates_shed_to_min(self):
        est = np.array([1.0, np.inf, 1.0, np.inf])
        out = greedy_load_rebalance(est, [2] * 4, r_max=4)
        assert (out[[1, 3]] == 1).all()           # never-seen -> min load
        assert out.sum() == 8

    def test_numpy_and_batch_agree(self):
        rng = np.random.default_rng(1)
        loads = np.array([2, 3, 1, 2, 4, 2])
        est = rng.random((5, 6)) + 0.05
        got = np.asarray(greedy_load_rebalance_batch(jnp.asarray(est, jnp.float32),
                                                     loads, r_max=5))
        for b in range(5):
            ref = greedy_load_rebalance(est[b], loads, r_max=5)
            assert (got[b] == ref).all(), b

    def test_reduces_makespan(self):
        rng = np.random.default_rng(2)
        for _ in range(5):
            est = rng.random(8) + 0.05
            loads = np.full(8, 3)
            out = greedy_load_rebalance(est, loads, r_max=8)
            assert (est * out).max() <= (est * loads).max() + 1e-6

    def test_validation(self):
        with pytest.raises(ValueError, match="sum"):
            greedy_load_rebalance(np.ones(4), [2] * 4, total=9, r_max=4)
        with pytest.raises(ValueError, match="min_load"):
            greedy_load_rebalance(np.ones(4), [1] * 4, r_max=4, min_load=2)
        with pytest.raises(ValueError, match="r_max"):
            greedy_load_rebalance(np.ones(4), [5] * 4, r_max=4)
        with pytest.raises(ValueError, match="shape"):
            greedy_load_rebalance(np.ones(5), [2] * 4, r_max=4)
        out = greedy_load_rebalance(np.ones(4), total=9, r_max=4)
        assert out.sum() == 9                     # even split from total


# -------------------- (e) rounds axis with re-balancing ----------------------

class TestRebalanceRounds:
    def test_rebalance_chunk_invariant(self):
        n, k = 6, 5
        proc = MarkovRegimeProcess(base=scenario1(),
                                   worker_scale=heterogeneous_scales(n, 2.0),
                                   persistence=0.9)
        spec = adaptive_spec("rb", cyclic_to_matrix(n, 5), loads=[2] * n,
                             rebalance=True)
        for censored in (False, True):
            full = np.asarray(trajectory_samples(
                spec, proc, n, rounds=5, k=k, trials=300, seed=0,
                censored_feedback=censored))
            part = np.asarray(trajectory_samples(
                spec, proc, n, rounds=5, k=k, trials=300, seed=0, chunk=77,
                censored_feedback=censored))
            assert (full == part).all(), censored

    def test_rebalance_beats_permutation_only(self):
        """ISSUE-4 acceptance (small): at the same total budget, load
        re-balancing beats both static schedules AND the permutation-only
        adaptive scheme on a heterogeneous persistent cluster (paired
        samples, censored feedback)."""
        n, r, k = 10, 3, 8
        proc = ec2_cluster(n, spread=3.0, p_slow=0.25, persistence=0.95,
                           slow=8.0)
        cs = cyclic_to_matrix(n, r)
        specs = [to_spec("cs", cs),
                 to_spec("ss", staircase_to_matrix(n, r)),
                 adaptive_spec("adapt", cs),
                 adaptive_spec("rebal", cyclic_to_matrix(n, 6),
                               loads=[r] * n, rebalance=True)]
        res = sweep_rounds(specs, proc, n, rounds=16, k=k, trials=800,
                           seed=0, censored_feedback=True)
        rebal = res.mean_round("rebal")
        assert rebal < res.mean_round("cs")
        assert rebal < res.mean_round("ss")
        assert rebal < res.mean_round("adapt")

    def test_static_ragged_adaptive_chunk_invariant(self):
        n = 6
        proc = MarkovRegimeProcess(base=scenario1(),
                                   worker_scale=heterogeneous_scales(n, 2.0),
                                   persistence=0.9)
        spec = adaptive_spec("ar", staircase_to_matrix(n, loads=LOADS),
                             messages=2)
        full = np.asarray(trajectory_samples(spec, proc, n, rounds=4, k=4,
                                             trials=240, seed=0,
                                             censored_feedback=True))
        part = np.asarray(trajectory_samples(spec, proc, n, rounds=4, k=4,
                                             trials=240, seed=0, chunk=77,
                                             censored_feedback=True))
        assert (full == part).all()

    def test_rebalance_spec_validation(self):
        C = cyclic_to_matrix(6, 4)
        m = scenario1()
        with pytest.raises(ValueError, match="budget"):
            sweep_rounds([adaptive_spec("a", C, rebalance=True)], m, 6,
                         rounds=2, k=3, trials=8)
        with pytest.raises(ValueError, match="dense"):
            sweep_rounds([adaptive_spec(
                "a", staircase_to_matrix(6, loads=LOADS), loads=LOADS,
                rebalance=True)], m, 6, rounds=2, k=3, trials=8)
        from repro.core.scheduling import block_to_matrix
        with pytest.raises(ValueError, match="diagonal"):
            sweep_rounds([adaptive_spec("a", block_to_matrix(6, 4),
                                        loads=[2] * 6, rebalance=True)],
                         m, 6, rounds=2, k=3, trials=8)

    def test_scheduler_rebalance_state(self):
        sched = AdaptiveScheduler(cyclic_to_matrix(6, 6), loads=[3] * 6,
                                  rebalance=True)
        assert (sched.loads() == 3).all()          # no feedback yet
        sched.observe(np.array([1, 1, 9, 1, 9, 1.0]))
        loads = sched.loads()
        assert loads.sum() == 18 and loads[2] == 1 and loads[4] == 1
        M = sched.matrix()
        assert (loads_of_matrix(M) == loads).all()
        validate_to_matrix(M, 6)


# ------------------- (f) aggregator / train API ragged rounds ----------------

class TestRaggedAggregator:
    def test_ragged_round_weights(self):
        spec = RoundSpec(n=6, r=3, k=4, schedule="ss", loads=LOADS)
        agg = StragglerAggregator(spec, scenario1())
        C = agg.current_matrix()
        assert (loads_of_matrix(C) == np.asarray(LOADS)).all()
        w, t = agg.round_mask(jax.random.PRNGKey(0))
        w = np.asarray(w)
        assert np.isclose(w.sum(), 4.0, atol=1e-5)
        assert (w[C == MASKED] == 0).all()
        out = agg.combine({"g": jnp.ones((6, 3, 2))}, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(out["g"]), 1.0, rtol=1e-5)

    def test_rebalance_round_api(self):
        spec = RoundSpec(n=8, r=5, k=6, schedule="cs", loads=(2,) * 8)
        proc = ec2_cluster(8, spread=3.0, persistence=0.95, slow=10.0)
        agg = StragglerAggregator(spec, proc, adaptive=True,
                                  censored_feedback=True, rebalance=True)
        for i in range(4):
            C = agg.current_matrix()
            validate_to_matrix(C, 8)
            lv = agg.current_loads()
            assert lv.sum() == 16 and (loads_of_matrix(C) == lv).all()
            w, t = agg.round_mask(jax.random.PRNGKey(i))
            assert np.isclose(float(np.asarray(w).sum()), 6.0, atol=1e-4)
        assert agg.expected_completion(trials=256) > 0

    def test_rebalance_requires_adaptive_and_budget(self):
        m = scenario1()
        with pytest.raises(ValueError, match="adaptive"):
            StragglerAggregator(RoundSpec(n=4, r=2, k=3, loads=(1,) * 4), m,
                                rebalance=True)
        with pytest.raises(ValueError, match="budget"):
            StragglerAggregator(RoundSpec(n=4, r=2, k=3), m, adaptive=True,
                                rebalance=True)

    def test_roundspec_loads_validation(self):
        with pytest.raises(ValueError, match="loads"):
            RoundSpec(n=4, r=2, k=3, loads=(3, 1, 1, 1))   # load > r
        with pytest.raises(ValueError, match="diagonal"):
            RoundSpec(n=4, r=2, k=3, schedule="block", loads=(2, 1, 1, 2))
        spec = RoundSpec(n=4, r=2, k=3, schedule="cs", loads=[2, 1, 1, 2])
        assert spec.loads == (2, 1, 1, 2)                  # canonical tuple
        assert (spec.load_vector == [2, 1, 1, 2]).all()


# ----------------- (g) per-message overhead (comm_eps) -----------------------

class TestCommOverhead:
    def test_engine_matches_numpy_oracle(self):
        n, r, k, trials, eps = 7, 3, 5, 200, 2e-4
        model = ShiftedExponentialDelays()
        C = cyclic_to_matrix(n, r)
        T1, T2 = _oracle_draws(model, n, r, trials, seed=11)
        s = np.cumsum(T1, -1) + T2
        for messages in (1, 2, 3):
            b = message_boundaries(r, messages)
            msgidx = np.searchsorted(b, np.arange(r))
            sm = s[..., b[msgidx]] + eps * (msgidx + 1)
            tau = np.full((trials, n), np.inf)
            for w in range(n):
                for j in range(r):
                    tau[:, C[w, j]] = np.minimum(tau[:, C[w, j]],
                                                 sm[:, w, j])
            got = np.asarray(completion_samples(
                to_spec("x", C, messages=messages, comm_eps=eps), model, n,
                trials=trials, seed=11, k=k))
            np.testing.assert_allclose(got, np.sort(tau, -1)[:, k - 1],
                                       rtol=1e-6)

    def test_zero_eps_bitexact_and_monotone(self):
        n, r, k = 8, 4, 7
        m = scenario1()
        C = cyclic_to_matrix(n, r)
        a = completion_samples(to_spec("x", C), m, n, trials=400, seed=2,
                               k=k)
        b = completion_samples(to_spec("x", C, comm_eps=0.0), m, n,
                               trials=400, seed=2, k=k)
        assert (np.asarray(a) == np.asarray(b)).all()
        # paired draws: completion is nondecreasing in eps
        specs = [to_spec(f"e{i}", C, comm_eps=eps)
                 for i, eps in enumerate((0.0, 1e-4, 5e-4))]
        res = sweep(specs, m, n, trials=2000, seed=0, ks=k)
        t = [res.at_k(f"e{i}", k) for i in range(3)]
        assert t[0] < t[1] < t[2]

    def test_message_comm_delays_overhead(self):
        m = scenario1()
        T1, T2 = m.sample(jax.random.PRNGKey(0), 4, 5, 4)
        base = np.asarray(message_comm_delays(T2, 2))
        got = np.asarray(message_comm_delays(T2, 2, eps=1e-3))
        np.testing.assert_allclose(got - base,
                                   np.broadcast_to([1e-3, 2e-3], base.shape),
                                   rtol=1e-5)
        # identity budget + eps still applies the overhead
        got4 = np.asarray(message_comm_delays(T2, 4, eps=1e-3))
        np.testing.assert_allclose(
            got4 - np.asarray(T2),
            np.broadcast_to([1e-3, 2e-3, 3e-3, 4e-3], np.asarray(T2).shape),
            rtol=1e-4)

    def test_overhead_flips_optimal_budget(self):
        """The Ozfatura trade-off: with zero overhead m=r wins; with a
        large overhead one-shot wins (k=n on a straggling cluster)."""
        from repro.core import BimodalStragglerDelays
        n, r, k = 10, 4, 9
        model = BimodalStragglerDelays(p_straggle=0.25, slow=8.0)
        C = cyclic_to_matrix(n, r)
        specs = []
        for tag, eps in (("lo", 0.0), ("hi", 1.5e-3)):
            specs += [to_spec(f"{tag}_m{mm}", C, messages=mm, comm_eps=eps)
                      for mm in (1, r)]
        res = sweep(specs, model, n, trials=4000, seed=0, ks=k)
        assert res.at_k(f"lo_m{r}", k) < res.at_k("lo_m1", k)
        assert res.at_k("hi_m1", k) < res.at_k(f"hi_m{r}", k)


# ------------------------------ misc / exports -------------------------------

def test_message_budget_validation_messages():
    with pytest.raises(ValueError, match="messages"):
        message_boundaries(4, 0)
    with pytest.raises(ValueError, match="messages"):
        message_boundaries(4, 5)
    with pytest.raises(ValueError, match="integer"):
        message_boundaries(4, 2.5)
    with pytest.raises(ValueError, match="messages"):
        message_group_sizes(3, 4)


def test_clear_cache_exported_and_callable():
    clear_cache()          # drops compiled evaluators; next sweep recompiles
    m = scenario1()
    res = sweep([to_spec("x", cyclic_to_matrix(4, 2))], m, 4, trials=16,
                ks=2)
    assert res.at_k("x", 2) > 0


def test_ragged_spec_constructors_reject_coded_loads():
    from repro.core import SchemeSpec
    m = scenario1()
    with pytest.raises(ValueError, match="coded"):
        sweep([SchemeSpec(name="p", kind="pc", r=2, loads=(1, 2, 2, 1))],
              m, 4, trials=8)

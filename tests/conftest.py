"""Test-session bootstrap.

1. If ``hypothesis`` is not installed, register the deterministic fallback
   shim (tests/_hypothesis_fallback.py) before any test module imports it,
   so the suite still collects and runs.
2. Lock the single-device CPU backend before any test imports
   repro.launch.dryrun (whose module-level XLA_FLAGS would otherwise
   inflate the device count for the whole pytest process — the 512-device
   setting is for the dry-run subprocesses only).
"""
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()

import jax

jax.devices()

"""Lock the single-device CPU backend before any test imports
repro.launch.dryrun (whose module-level XLA_FLAGS would otherwise inflate
the device count for the whole pytest process — the 512-device setting is
for the dry-run subprocesses only)."""
import jax

jax.devices()

"""Launch-layer tests: mesh builders, sharding rules, HLO collective
parser, dry-run plumbing on a tiny local mesh."""
import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.shardings import param_spec
from repro.launch.dryrun import collective_bytes, model_flops_global
from repro.launch.mesh import make_local_mesh_ctx
from repro.sharding import MeshCtx, mesh_context, shard
from repro.models import ModelConfig, init_params, forward
from repro.configs import get_config, SHAPES


class TestCollectiveParser:
    HLO = """
  %p = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(f32[8,16]{1,0} %p), replica_groups={}
  %ag = bf16[4,256]{1,0} all-gather(bf16[4,64]{1,0} %x), dimensions={1}
  %rs = f32[2,8]{1,0} reduce-scatter(f32[16,8]{1,0} %y), dimensions={0}
  %a2a = f32[16]{0} all-to-all(f32[16]{0} %z)
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w)
  %ards = f32[8,16]{1,0} all-reduce-start(f32[8,16]{1,0} %p)
  %ardd = f32[8,16]{1,0} all-reduce-done(f32[8,16]{1,0} %ards)
"""

    def test_bytes_and_counts(self):
        res = collective_bytes(self.HLO)
        assert res["bytes"]["all-reduce"] == 8 * 16 * 4 * 2  # ar + ar-start
        assert res["bytes"]["all-gather"] == 4 * 256 * 2
        assert res["bytes"]["reduce-scatter"] == 2 * 8 * 4
        assert res["bytes"]["all-to-all"] == 16 * 4
        assert res["bytes"]["collective-permute"] == 4 * 4
        assert res["counts"]["all-reduce"] == 2
        assert res["total_bytes"] == sum(res["bytes"].values())

    def test_done_ops_not_double_counted(self):
        res = collective_bytes(self.HLO)
        # -done skipped; -start counted once
        assert res["counts"]["all-reduce"] == 2


class TestParamSpecRules:
    def _ctx(self):
        # fabricate a ctx with model_size 4 over actual devices=1: use mesh
        # of 1x1 but override sizes via a stub
        class Stub:
            model_axis = "model"
            model_size = 4
            data_axes = ("data",)
        return Stub()

    @pytest.mark.parametrize("path,shape,want", [
        ("embed", (512, 64), P("model", None)),
        ("lm_head/w", (64, 512), P(None, "model")),
        ("segments/0/0/mixer/wq/w", (64, 128), P(None, "model")),
        ("segments/0/0/mixer/wo/w", (128, 64), P("model", None)),
        ("segments/0/0/ffn/w_gate/w", (64, 256), P(None, "model")),
        ("segments/0/0/ffn/w_down/w", (256, 64), P("model", None)),
        ("segments/0/0/ffn/w_gate", (8, 64, 32), P("model", None, None)),
        ("segments/0/0/ffn/router", (64, 8), P(None, None)),
        ("segments/0/0/norm1/scale", (64,), P(None)),
        ("segments/0/0/mixer/in_proj/w", (64, 256), P(None, "model")),
        ("segments/0/0/mixer/out_proj/w", (128, 64), P("model", None)),
        # divisibility fallback: 6 not divisible by 4
        ("segments/0/0/mixer/wq/w", (64, 6), P(None, None)),
    ])
    def test_rules(self, path, shape, want):
        fb = []
        got = param_spec(path, shape, self._ctx(), fb)
        assert tuple(got) == tuple(want), (path, got, want)

    def test_fallback_recorded(self):
        fb = []
        param_spec("segments/0/0/mixer/wq/w", (64, 6), self._ctx(), fb)
        assert len(fb) == 1


class TestLocalMeshForward:
    """Tiny model under a real (1x1) mesh context: sharding constraints and
    the MoE shard_map path must still produce identical numerics."""

    def test_forward_matches_no_mesh(self):
        cfg = ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                          n_experts=4, experts_per_token=2, d_ff_expert=64,
                          capacity_factor=8.0, param_dtype="float32",
                          dtype="float32", remat=False)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
        base, _, _ = forward(params, cfg, toks)
        ctx = make_local_mesh_ctx(1, 1)
        with mesh_context(ctx):
            meshy, _, _ = forward(params, cfg, toks)
        np.testing.assert_allclose(np.asarray(base), np.asarray(meshy),
                                   rtol=1e-5, atol=1e-5)


class TestModelFlops:
    def test_kind_scaling(self):
        cfg = get_config("phi4-mini-3.8b")
        t = model_flops_global(cfg, "train_4k")
        p = model_flops_global(cfg, "prefill_32k")
        d = model_flops_global(cfg, "decode_32k")
        # train: 6*N*256*4096; prefill: 2*N*32*32768; decode: 2*N*128
        assert t / p == pytest.approx(3.0, rel=1e-6)
        assert d < p < t


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 8))
    from repro.sharding import DATA, MODEL
    y = shard(x, DATA, MODEL)
    assert y is x

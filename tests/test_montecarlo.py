"""Tests for the fused Monte-Carlo sweep engine (core/montecarlo.py).

Covers the ISSUE-1 acceptance points:
  (a) engine results bit-match the public simulate_* wrappers per scheme;
  (b) chunked streaming equals unchunked (per-trial subkeys make the draws
      chunking-invariant);
  (c) the all-k output column k equals the single-k (lax.top_k) path;
  (d) the static gather task-arrival layout equals the scatter-min version
      on random TO matrices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, scenario1, ec2_like,
                        ShiftedExponentialDelays, slot_arrival_times,
                        task_arrival_times, pc_threshold, pcmm_threshold,
                        simulate_completion, simulate_lower_bound,
                        simulate_pc_completion, simulate_pcmm_completion,
                        mean_completion_time, to_spec, lb_spec, pc_spec,
                        pcmm_spec, tau_spec, adaptive_spec, sweep,
                        sweep_rounds, completion_samples,
                        trajectory_samples, task_arrival_samples,
                        task_gather_plan, task_arrival_times_gather,
                        ec2_cluster, IIDProcess)


def _random_to_matrix(n, r, seed):
    rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(n)[:r] for _ in range(n)])


# ---------------------------- (a) bit-match ----------------------------------

def test_engine_bitmatches_simulate_completion():
    n, r, k, trials = 8, 4, 6, 2000
    m = scenario1()
    C = staircase_to_matrix(n, r)
    wrapper = np.asarray(simulate_completion(C, m, k, trials=trials, seed=3))
    engine = np.asarray(completion_samples(to_spec("x", C), m, n,
                                           trials=trials, seed=3, k=k))
    assert (wrapper == engine).all()


def test_engine_bitmatches_simulate_lower_bound():
    n, r, k, trials = 8, 3, 5, 2000
    m = scenario1()
    wrapper = np.asarray(simulate_lower_bound(m, n, r, k, trials=trials,
                                              seed=7))
    engine = np.asarray(completion_samples(lb_spec(r), m, n, trials=trials,
                                           seed=7, k=k))
    assert (wrapper == engine).all()


def test_engine_bitmatches_coded_simulators():
    n, r, trials = 8, 4, 2000
    m = scenario1()
    pc = np.asarray(simulate_pc_completion(m, n, r, trials=trials, seed=1))
    pc_eng = np.asarray(completion_samples(pc_spec(r), m, n, trials=trials,
                                           seed=1))
    assert (pc == pc_eng).all()
    pcmm = np.asarray(simulate_pcmm_completion(m, n, r, trials=trials, seed=1))
    pcmm_eng = np.asarray(completion_samples(pcmm_spec(r), m, n,
                                             trials=trials, seed=1))
    assert (pcmm == pcmm_eng).all()


def test_engine_matches_independent_oracle():
    """The engine against a from-scratch oracle sharing only the per-trial
    key convention: batch-sampled draws, scatter-min arrivals (the seed
    implementation), a plain numpy sort — none of the engine's gather /
    top_k / scan machinery.  Guards against wrapper-vs-engine tautology."""
    n, r, k, trials = 7, 3, 5, 300
    m = ShiftedExponentialDelays()
    C = cyclic_to_matrix(n, r)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        jax.random.PRNGKey(11), jnp.arange(trials, dtype=jnp.int32))
    taus = []
    for i in range(trials):                       # deliberately unvectorized
        T1, T2 = m.sample(keys[i], 1, n, r)
        s = np.asarray(slot_arrival_times(T1, T2))[0]
        tau = np.full(n, np.inf)
        for w in range(n):
            for j in range(r):
                tau[C[w, j]] = min(tau[C[w, j]], s[w, j])
        taus.append(np.sort(tau))
    oracle = np.stack(taus)                       # (trials, n), all k
    engine = np.asarray(completion_samples(to_spec("x", C), m, n,
                                           trials=trials, seed=11))
    np.testing.assert_allclose(engine, oracle, rtol=1e-6)
    # order statistics: k-th column is the k-th smallest
    single = np.asarray(completion_samples(to_spec("x", C), m, n,
                                           trials=trials, seed=11, k=k))
    np.testing.assert_allclose(single, oracle[:, k - 1], rtol=1e-6)


def test_sweep_mean_matches_sample_mean():
    n, r, k, trials = 8, 4, 6, 3000
    m = ec2_like(n, seed=5)
    C = cyclic_to_matrix(n, r)
    res = sweep([to_spec("cs", C)], m, n, trials=trials, seed=0)
    samples = np.asarray(simulate_completion(C, m, k, trials=trials, seed=0))
    assert np.isclose(res.at_k("cs", k), samples.mean(), rtol=1e-5)
    assert np.isclose(mean_completion_time(C, m, k, trials=trials, seed=0),
                      samples.mean(), rtol=1e-5)


# ------------------------- (b) chunked == unchunked --------------------------

@pytest.mark.parametrize("chunk", [1, 7, 250, 1000])
def test_chunked_samples_equal_unchunked(chunk):
    n, r, k, trials = 6, 3, 4, 1000
    m = scenario1()
    C = cyclic_to_matrix(n, r)
    full = np.asarray(completion_samples(to_spec("x", C), m, n,
                                         trials=trials, seed=0, k=k))
    part = np.asarray(completion_samples(to_spec("x", C), m, n,
                                         trials=trials, seed=0, k=k,
                                         chunk=chunk))
    assert (full == part).all()


def test_chunked_sweep_means_equal_unchunked():
    n, r, trials = 6, 6, 2000
    m = scenario1()
    specs = [to_spec("cs", cyclic_to_matrix(n, r)),
             pc_spec(r), pcmm_spec(r), lb_spec(r)]
    full = sweep(specs, m, n, trials=trials, seed=0)
    part = sweep(specs, m, n, trials=trials, seed=0, chunk=300)
    for name in full.means:
        np.testing.assert_allclose(part.means[name], full.means[name],
                                   rtol=1e-5)


def test_chunked_large_sweep_streams():
    """A trial count far above any single-batch memory budget must still
    run (O(chunk) memory) and agree statistically with a small sweep."""
    n, r, k = 6, 3, 5
    m = scenario1()
    specs = [to_spec("cs", cyclic_to_matrix(n, r))]
    big = sweep(specs, m, n, trials=60000, seed=0, chunk=4096)
    small = sweep(specs, m, n, trials=10000, seed=1)
    assert abs(big.at_k("cs", k) - small.at_k("cs", k)) < 5e-5


# ---------------------- (c) all-k column == single-k -------------------------

@pytest.mark.parametrize("k", [1, 3, 6, 8])
def test_all_k_column_equals_single_k(k):
    n, r, trials = 8, 4, 1500
    m = scenario1()
    C = staircase_to_matrix(n, r)
    allk = np.asarray(completion_samples(to_spec("x", C), m, n,
                                         trials=trials, seed=2))
    single = np.asarray(completion_samples(to_spec("x", C), m, n,
                                           trials=trials, seed=2, k=k))
    assert allk.shape == (trials, n)
    assert (allk[:, k - 1] == single).all()


def test_all_k_columns_nondecreasing():
    n, r = 8, 8
    m = scenario1()
    res = sweep([to_spec("ss", staircase_to_matrix(n, r)), lb_spec(r)], m, n,
                trials=2000, seed=0)
    for name in ("ss", "lb"):
        assert (np.diff(res.means[name]) >= -1e-9).all()
    # lower bound dominates the schedule at every k
    assert (res.means["lb"] <= res.means["ss"] + 1e-9).all()


# ----------------------- (d) gather == scatter-min ---------------------------

@pytest.mark.parametrize("seed", range(6))
def test_gather_plan_matches_scatter_min(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    r = int(rng.integers(1, n + 1))
    C = _random_to_matrix(n, r, seed)
    m = ShiftedExponentialDelays()
    T1, T2 = m.sample(jax.random.PRNGKey(seed), 32, n, r)
    s = slot_arrival_times(T1, T2)
    scatter = np.asarray(task_arrival_times(jnp.asarray(C), s, n))
    gather = np.asarray(task_arrival_times_gather(task_gather_plan(C, n), s))
    assert np.array_equal(scatter, gather)   # inf-padded tasks included


def test_gather_plan_handles_unassigned_tasks():
    C = np.array([[0], [0]])                 # task 1 never computed
    plan = task_gather_plan(C, 2)
    s = jnp.ones((1, 2, 1))
    tau = np.asarray(task_arrival_times_gather(plan, s))
    assert np.isinf(tau[0, 1]) and tau[0, 0] == 1.0


def test_gather_plan_wide_slot_grid():
    """Schemes with r < r_max read the leading slots of the shared grid."""
    n, r, r_max = 6, 2, 5
    C = cyclic_to_matrix(n, r)
    m = scenario1()
    T1, T2 = m.sample(jax.random.PRNGKey(0), 16, n, r_max)
    s = slot_arrival_times(T1, T2)
    gather = np.asarray(task_arrival_times_gather(
        task_gather_plan(C, n, r_max), s))
    scatter = np.asarray(task_arrival_times(jnp.asarray(C), s[..., :r], n))
    assert np.array_equal(scatter, gather)


# ------------------------------ misc engine ----------------------------------

def test_common_random_numbers_pair_schemes():
    """CS and SS evaluated under one seed share delay draws: the
    per-trial gap estimator has lower variance than with independent
    draws (the CRN payoff).  Compared at the trial level — 800 paired
    samples — so the check measures the true variance reduction rather
    than a handful of noisy seed-level std estimates."""
    n, r, k, trials = 10, 5, 8, 800
    m = scenario1()
    cs_s = to_spec("cs", cyclic_to_matrix(n, r))
    ss_s = to_spec("ss", staircase_to_matrix(n, r))
    cs0 = np.asarray(completion_samples(cs_s, m, n, trials=trials,
                                        seed=0, k=k)).ravel()
    ss0 = np.asarray(completion_samples(ss_s, m, n, trials=trials,
                                        seed=0, k=k)).ravel()
    ss1 = np.asarray(completion_samples(ss_s, m, n, trials=trials,
                                        seed=1, k=k)).ravel()
    # shared draws -> strongly correlated completions
    assert np.corrcoef(cs0, ss0)[0, 1] > 0.5
    # ... so the paired gap has materially lower variance than the
    # same estimator built from independent draws
    assert np.std(cs0 - ss0) < 0.8 * np.std(cs0 - ss1)


def test_task_arrival_samples_shape_and_consistency():
    n, r, trials = 6, 3, 500
    m = scenario1()
    C = cyclic_to_matrix(n, r)
    tau = np.asarray(task_arrival_samples(C, m, trials=trials, seed=0))
    assert tau.shape == (trials, n)
    # k-th order statistic of tau == engine completion samples
    allk = np.asarray(completion_samples(to_spec("x", C), m, n,
                                         trials=trials, seed=0))
    assert np.allclose(np.sort(tau, axis=1), allk)


def test_sweep_rejects_bad_input():
    m = scenario1()
    C = cyclic_to_matrix(4, 2)
    with pytest.raises(ValueError):
        sweep([to_spec("a", C), to_spec("a", C)], m, 4, trials=8)
    with pytest.raises(ValueError):
        sweep([to_spec("a", C)], m, 5, trials=8)          # row/task mismatch
    with pytest.raises(ValueError):
        sweep([to_spec("a", C)], m, 4, trials=8, ks=9)    # k out of range
    res = sweep([to_spec("a", C)], m, 4, trials=8, ks=2)
    with pytest.raises(ValueError):
        res.at_k("a", 3)                                  # wrong k for ks=2
    with pytest.raises(ValueError):
        sweep([pcmm_spec(1)], m, 4, trials=8)             # n*r < 2n-1


def test_at_k_edge_cases():
    """SweepResult.at_k: the single-k (lax.top_k) path and the all-k (full
    sort) path agree at every k on shared draws; unknown names raise."""
    n, r, trials = 8, 4, 800
    m = scenario1()
    specs = [to_spec("cs", cyclic_to_matrix(n, r)), lb_spec(r)]
    allk = sweep(specs, m, n, trials=trials, seed=4)
    for k in range(1, n + 1):
        single = sweep(specs, m, n, trials=trials, seed=4, ks=k)
        for name in ("cs", "lb"):
            assert np.isclose(allk.at_k(name, k), single.at_k(name, k),
                              rtol=1e-6), (name, k)
    with pytest.raises(ValueError, match="unknown scheme"):
        allk.at_k("nope", 3)
    with pytest.raises(ValueError):
        allk.at_k("cs")                          # all-k needs explicit k
    with pytest.raises(ValueError):
        allk.at_k("cs", 0)                       # out of range


# ----------------------------- rounds axis -----------------------------------

def test_sweep_rounds_validation():
    n, r = 6, 3
    m = scenario1()
    C = cyclic_to_matrix(n, r)
    with pytest.raises(ValueError, match="rounds axis"):
        sweep([adaptive_spec("a", C)], m, n, trials=8)
    with pytest.raises(ValueError, match="single-round"):
        sweep_rounds([tau_spec("t", C)], m, n, rounds=2, k=3, trials=8)
    with pytest.raises(ValueError):
        sweep_rounds([to_spec("a", C)], m, n, rounds=0, k=3, trials=8)
    with pytest.raises(ValueError):
        sweep_rounds([to_spec("a", C)], m, n, rounds=2, k=9, trials=8)
    res = sweep_rounds([to_spec("a", C)], m, n, rounds=2, k=3, trials=64)
    with pytest.raises(ValueError, match="unknown scheme"):
        res.mean_round("nope")


def test_rounds_trajectories_chunk_invariant_and_consistent():
    n, r, k, trials, rounds = 6, 3, 5, 400, 5
    # scalar-mean base: per-trial draws are bit-identical under any
    # chunking (vector-mean bases like ec2_like compile to slightly
    # different fusions per chunk shape — 1-ulp, covered by allclose in
    # test_ec2_cluster_chunking_close below).
    from repro.core import MarkovRegimeProcess, heterogeneous_scales
    proc = MarkovRegimeProcess(base=scenario1(),
                               worker_scale=heterogeneous_scales(n, 2.0),
                               persistence=0.9)
    spec = to_spec("cs", cyclic_to_matrix(n, r))
    full = np.asarray(trajectory_samples(spec, proc, n, rounds=rounds, k=k,
                                         trials=trials, seed=0))
    part = np.asarray(trajectory_samples(spec, proc, n, rounds=rounds, k=k,
                                         trials=trials, seed=0, chunk=77))
    assert full.shape == (trials, rounds)
    assert (full == part).all()
    # sweep_rounds moments match the raw trajectories
    res = sweep_rounds([spec], proc, n, rounds=rounds, k=k, trials=trials,
                       seed=0, chunk=128)
    np.testing.assert_allclose(res.per_round["cs"], full.mean(0), rtol=1e-5)
    np.testing.assert_allclose(res.wallclock["cs"],
                               np.cumsum(full, axis=1).mean(0), rtol=1e-5)
    np.testing.assert_allclose(res.wallclock["cs"],
                               np.cumsum(res.per_round["cs"]), rtol=1e-5)
    assert res.total("cs") > res.mean_round("cs") > 0


def test_ec2_cluster_chunking_close():
    """Vector-mean bases (ec2_like) are chunk-invariant to float32 ulp —
    XLA fuses the truncnorm math differently per chunk shape."""
    n, r, k = 6, 3, 5
    proc = ec2_cluster(n, spread=2.0, persistence=0.9)
    spec = to_spec("cs", cyclic_to_matrix(n, r))
    full = np.asarray(trajectory_samples(spec, proc, n, rounds=4, k=k,
                                         trials=300, seed=0))
    part = np.asarray(trajectory_samples(spec, proc, n, rounds=4, k=k,
                                         trials=300, seed=0, chunk=77))
    np.testing.assert_allclose(part, full, rtol=1e-5)


def test_adaptive_beats_static_on_persistent_heterogeneous_cluster():
    """ISSUE-2 acceptance: with worker-specific persistent straggling, the
    feedback-driven row re-assignment beats BOTH static schedules' mean
    wall-clock per round (paired comparison — shared realizations)."""
    n, r, k = 10, 3, 8
    proc = ec2_cluster(n, spread=3.0, p_slow=0.25, persistence=0.95,
                       slow=8.0)
    cs = cyclic_to_matrix(n, r)
    res = sweep_rounds([to_spec("cs", cs),
                        to_spec("ss", staircase_to_matrix(n, r)),
                        adaptive_spec("adapt", cs), lb_spec(r)],
                       proc, n, rounds=16, k=k, trials=1200, seed=0)
    adapt = res.mean_round("adapt")
    assert adapt < res.mean_round("cs")
    assert adapt < res.mean_round("ss")
    assert res.mean_round("lb") < adapt          # oracle still dominates
    # the adaptive edge needs feedback: round 0 (no history) is not better
    # than cs beyond noise, later rounds are.
    gap0 = res.per_round["cs"][0] - res.per_round["adapt"][0]
    gap_late = (res.per_round["cs"][-4:] - res.per_round["adapt"][-4:]).mean()
    assert gap_late > gap0


def test_pc_keeps_own_threshold_in_single_k_sweeps():
    """Coded schemes are never scored at the sweep's k: a single-k sweep
    reports pc at 2*ceil(n/r)-1 regardless of ks."""
    n, r, k = 8, 4, 2
    m = scenario1()
    allk = sweep([pc_spec(r)], m, n, trials=500, seed=0)
    single = sweep([pc_spec(r), to_spec("cs", cyclic_to_matrix(n, r))], m, n,
                   trials=500, seed=0, ks=k)
    assert single.at_k("pc") == allk.at_k("pc")           # k-independent
    assert pc_threshold(n, r) != k                        # and != sweep's k

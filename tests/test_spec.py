"""RoundConfig — the one canonical round validator + its JSON form and
legacy (SchemeSpec / RoundSpec) derivations.  Covers validation parity with
the legacy constructors, adaptive-family cross-field rules, the deprecation
shims, and config <-> JSON <-> config round-trips."""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DEADLINE_POLICIES, RoundConfig, RoundSpec,
                        ec2_cluster, sweep_rounds, validate_deadline)
from repro.core.montecarlo import SchemeSpec, adaptive_spec, to_spec
from repro.core.spec import _reset_legacy_warnings


class TestValidation:
    def test_shape_ranges(self):
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=5, r=2)            # k > n
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=5)            # r > n
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=0)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=0)
        cfg = RoundConfig(n=4, k=2)               # r=None -> width n
        assert cfg.width == 4
        assert RoundConfig(n=4, k=2, r=3).width == 3

    def test_messages_and_comm_eps(self):
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=2, messages=3)    # messages > r
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=2, messages=0)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, comm_eps=-0.1)
        cfg = RoundConfig(n=4, k=2, r=3, messages=2, comm_eps=0.5)
        assert cfg.n_messages == 2
        assert RoundConfig(n=4, k=2, r=3).n_messages == 3

    def test_deadline_pairing(self):
        for policy in ("close_partial", "reissue"):
            with pytest.raises(ValueError):          # policy needs a deadline
                RoundConfig(n=4, k=2, deadline_policy=policy, adaptive=True)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, deadline=-1.0)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, deadline=1.0, deadline_policy="bogus")
        cfg = RoundConfig(n=4, k=2, deadline=2,
                          deadline_policy="close_partial")
        assert cfg.deadline == 2.0 and isinstance(cfg.deadline, float)

    def test_validate_deadline_function(self):
        assert validate_deadline(None, "wait") is None
        assert validate_deadline(3, "close_partial") == 3.0
        with pytest.raises(ValueError):
            validate_deadline(None, "reissue")
        with pytest.raises(ValueError):
            validate_deadline(1.0, "nope")
        assert set(DEADLINE_POLICIES) == {"wait", "close_partial", "reissue"}

    def test_adaptive_family_cross_rules(self):
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, censored_feedback=True)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, rebalance=True)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, dead_after=3)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, adaptive=True, dead_after=0)
        with pytest.raises(ValueError):              # reissue is adaptive-only
            RoundConfig(n=4, k=2, deadline=1.0, deadline_policy="reissue")
        with pytest.raises(ValueError):              # rebalance needs loads
            RoundConfig(n=4, k=2, adaptive=True, rebalance=True)
        with pytest.raises(ValueError):              # adaptive + comm_eps
            RoundConfig(n=4, k=2, adaptive=True, comm_eps=0.1)
        ok = RoundConfig(n=4, k=2, r=3, adaptive=True, rebalance=True,
                         censored_feedback=True, dead_after=2,
                         loads=(2, 1, 3, 2))
        assert ok.load_vector.tolist() == [2, 1, 3, 2]

    def test_ragged_loads(self):
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=2, loads=(1, 2, 1))     # wrong shape
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=2, loads=(1, 2, 0, 1))  # load < 1
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, r=2, loads=(1, 2, 3, 1))  # load > r
        with pytest.raises(ValueError):                     # non-diagonal kind
            RoundConfig(n=4, k=2, r=2, kind="block", loads=(1, 2, 1, 2))
        cfg = RoundConfig(n=4, k=3, r=3, kind="ss", loads=[1, 2, 3, 1])
        assert cfg.loads == (1, 2, 3, 1)                    # normalized tuple

    def test_rebalance_needs_diagonal_base(self):
        # an RA base whose slot-0 column is not a permutation cannot keep
        # every task covered under arbitrary re-balanced loads (seed=1
        # yields such a column; seed=0 happens to be a permutation)
        with pytest.raises(ValueError, match="slot-0-diagonal"):
            RoundConfig(n=4, k=2, kind="ra", r=4, adaptive=True,
                        rebalance=True, loads=(2, 2, 2, 2), seed=1)
        RoundConfig(n=4, k=2, kind="ra", r=4, adaptive=True,
                    rebalance=True, loads=(2, 2, 2, 2), seed=0)

    def test_feedback_knob_ranges(self):
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, feedback_beta=1.0)
        with pytest.raises(ValueError):
            RoundConfig(n=4, k=2, coverage_gamma=1.5)


class TestLegacyParity:
    """RoundConfig and the legacy constructors accept/reject the same
    configurations and derive bit-identical objects."""

    @pytest.mark.parametrize("kw", [
        dict(n=4, k=5, r=2),
        dict(n=4, k=2, r=5),
        dict(n=4, k=2, r=2, messages=3),
        dict(n=4, k=2, r=2, loads=(1, 2, 0, 1)),
        dict(n=4, k=2, r=2, deadline=-1.0),
    ])
    def test_both_reject(self, kw):
        with pytest.raises(ValueError):
            RoundConfig(**kw)
        legacy = dict(kw)
        legacy["schedule"] = legacy.pop("kind", "cs")
        legacy.setdefault("r", legacy["n"])
        with pytest.raises(ValueError), warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            RoundSpec(**legacy)

    @pytest.mark.parametrize("kw", [
        dict(n=5, k=3, kind="cs", r=2),
        dict(n=5, k=3, kind="ss", r=3, messages=2),
        dict(n=6, k=4, kind="ra", r=6, seed=9),
        dict(n=5, k=3, kind="cs", r=3, loads=(1, 2, 3, 2, 1)),
        dict(n=5, k=3, kind="cs", r=2, comm_eps=0.25),
    ])
    def test_matrices_match_legacy(self, kw):
        cfg = RoundConfig(**kw)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            spec = RoundSpec(n=cfg.n, r=cfg.width, k=cfg.k,
                             schedule=cfg.kind, seed=cfg.seed,
                             messages=cfg.messages, loads=cfg.loads,
                             comm_eps=cfg.comm_eps)
        np.testing.assert_array_equal(cfg.to_matrix(), spec.to_matrix())
        rt = cfg.to_round_spec()
        assert rt == spec
        np.testing.assert_array_equal(rt.to_matrix(), cfg.to_matrix())

    def test_scheme_spec_matches_factories(self):
        cfg = RoundConfig(n=5, k=3, kind="cs", r=3, loads=(1, 2, 3, 2, 1),
                          messages=2)
        assert cfg.to_scheme_spec("x") == to_spec(
            "x", cfg.base_matrix(), cfg.messages, loads=cfg.loads)
        ad = RoundConfig(n=5, k=3, kind="cs", r=3, adaptive=True,
                         rebalance=True, loads=(1, 2, 3, 2, 1))
        assert ad.to_scheme_spec("x") == adaptive_spec(
            "x", ad.base_matrix(), loads=ad.loads, rebalance=True)

    def test_sweep_bit_exact_under_crn(self):
        """The derived SchemeSpec drives the engine to the same numbers a
        hand-built factory spec does (common random numbers)."""
        cfg = RoundConfig(n=4, k=3, kind="cs", r=2, seed=5)
        proc = ec2_cluster(4, spread=2.0, persistence=0.8, seed=1)
        a = sweep_rounds([cfg.to_scheme_spec("s")], proc, 4, rounds=3,
                         trials=8, k=cfg.k, seed=5, chunk=8)
        b = sweep_rounds([to_spec("s", cfg.base_matrix())], proc, 4,
                         rounds=3, trials=8, k=cfg.k, seed=5, chunk=8)
        np.testing.assert_array_equal(a.per_round["s"], b.per_round["s"])

    def test_kwargs_helpers(self):
        cfg = RoundConfig(n=4, k=3, adaptive=True, censored_feedback=True,
                          dead_after=2, deadline=1.5,
                          deadline_policy="close_partial",
                          feedback_beta=0.6, coverage_gamma=0.4)
        kw = cfg.sweep_rounds_kwargs()
        assert kw["k"] == 3 and kw["deadline"] == 1.5
        assert kw["deadline_policy"] == "close_partial"
        assert kw["feedback_beta"] == 0.6 and kw["censored_feedback"]
        ak = cfg.aggregator_kwargs()
        assert ak["adaptive"] and ak["dead_after"] == 2
        assert ak["coverage_gamma"] == 0.4


class TestDeprecationShims:
    def test_legacy_constructors_warn_once(self):
        _reset_legacy_warnings()
        with pytest.warns(DeprecationWarning, match="RoundConfig"):
            RoundSpec(n=4, r=2, k=3)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            RoundSpec(n=4, r=2, k=3)          # second build: silent
        _reset_legacy_warnings()
        with pytest.warns(DeprecationWarning, match="RoundConfig"):
            SchemeSpec(name="x", kind="to", C=((0, 1), (1, 0)))
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            SchemeSpec(name="x", kind="to", C=((0, 1), (1, 0)))

    def test_internal_paths_never_warn(self):
        _reset_legacy_warnings()
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            to_spec("s", [[0, 1], [1, 0]])
            adaptive_spec("a", [[0, 1], [1, 0]])
            cfg = RoundConfig(n=4, k=3, r=2)
            cfg.to_round_spec()
            cfg.to_scheme_spec()


class TestJSONRoundTrip:
    CONFIGS = [
        RoundConfig(n=4, k=3),
        RoundConfig(n=5, k=3, kind="ss", r=3, messages=2, comm_eps=0.1),
        RoundConfig(n=5, k=3, kind="cs", r=3, loads=(1, 2, 3, 2, 1),
                    deadline=2.5, deadline_policy="close_partial"),
        RoundConfig(n=6, k=4, kind="ra", r=6, seed=11, adaptive=True,
                    censored_feedback=True, dead_after=3,
                    feedback_beta=0.5, coverage_gamma=0.25),
        RoundConfig(n=4, k=2, r=3, adaptive=True, rebalance=True,
                    loads=(2, 1, 3, 2), deadline=1.0,
                    deadline_policy="reissue"),
    ]

    @pytest.mark.parametrize("cfg", CONFIGS,
                             ids=lambda c: f"{c.kind}-n{c.n}")
    def test_round_trip(self, cfg):
        assert RoundConfig.from_json(cfg.to_json()) == cfg
        assert RoundConfig.from_dict(cfg.to_dict()) == cfg

    def test_save_load(self, tmp_path):
        cfg = self.CONFIGS[2]
        path = tmp_path / "round.json"
        cfg.save(path)
        assert RoundConfig.load(path) == cfg

    def test_document_guards(self):
        cfg = RoundConfig(n=4, k=3)
        d = cfg.to_dict()
        assert d["format"] == "repro.round_config"
        with pytest.raises(ValueError, match="format"):
            RoundConfig.from_dict({**d, "format": "other"})
        with pytest.raises(ValueError, match="newer"):
            RoundConfig.from_dict({**d, "version": 99})
        with pytest.raises(ValueError, match="unknown"):
            RoundConfig.from_dict({**d, "stragglers": 2})
        # loads arrive as a JSON list, normalize to a tuple
        rc = RoundConfig.from_dict({"n": 4, "k": 2, "r": 2,
                                    "loads": [1, 2, 1, 2]})
        assert rc.loads == (1, 2, 1, 2)

    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_random_configs_survive_round_trip(self, data):
        n = data.draw(st.integers(2, 8))
        cfg = RoundConfig(
            n=n,
            k=data.draw(st.integers(1, n)),
            kind=data.draw(st.sampled_from(["cs", "ss"])),
            r=data.draw(st.integers(1, n)),
            adaptive=data.draw(st.sampled_from([False, True])),
            seed=data.draw(st.integers(0, 99)),
        )
        back = RoundConfig.from_json(cfg.to_json())
        assert back == cfg
        np.testing.assert_array_equal(back.to_matrix(), cfg.to_matrix())

"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The test environment may lack the real package (and installing is not
always possible).  This shim registers a minimal ``hypothesis`` module in
``sys.modules`` implementing the exact subset this repo's tests use —
``given``, ``settings``, ``strategies.integers/sampled_from/data`` — with
seeded pseudo-random example generation, so the property tests still run
as deterministic randomized tests.  When the real hypothesis is available
it is used untouched (see conftest.py); this fallback never shadows it.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value=0.0, max_value=1.0, **_) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


class _DataObject:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        return strategy._draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


def data() -> _Strategy:
    return _DataStrategy()


def given(*strategies: _Strategy):
    def decorate(test):
        @functools.wraps(test)
        def wrapper(*args, **kwargs):
            n_examples = getattr(wrapper, "_hf_max_examples",
                                 _DEFAULT_MAX_EXAMPLES)
            base = hash(test.__qualname__) & 0xFFFFFF
            for i in range(n_examples):
                rng = random.Random(base + i)
                drawn = [s._draw(rng) for s in strategies]
                test(*args, *drawn, **kwargs)

        # hide the strategy-bound (right-aligned) parameters from pytest so
        # it does not look for fixtures named after them
        sig = inspect.signature(test)
        params = list(sig.parameters.values())[:-len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=params)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis_fallback = True
        return wrapper

    return decorate


def settings(deadline=None, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_):
    def decorate(test):
        # examples are cheap shrinking-free reruns here; cap them so the
        # fallback stays faster than real hypothesis on slow MC tests
        test._hf_max_examples = min(max_examples, _DEFAULT_MAX_EXAMPLES)
        return test

    return decorate


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    st = types.ModuleType("hypothesis.strategies")
    for fn in (integers, sampled_from, booleans, floats, data):
        setattr(st, fn.__name__, fn)

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st

"""Device-sharded Monte-Carlo sweeps: bit-exactness vs the single-device
path, shard layout math, chunk validation, and evaluator-cache hygiene.

The bit-exactness classes need >= 4 devices; CI forces them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.  On a plain
single-device run those classes skip and only the device-free layout /
validation tests execute.
"""
import jax
import numpy as np
import pytest

from repro.core import (adaptive_spec, clear_cache, lb_spec, scenario1,
                        to_spec)
from repro.core import montecarlo as mc
from repro.core.cluster import MarkovRegimeProcess
from repro.core.montecarlo import (completion_samples, sweep, sweep_rounds,
                                   trajectory_samples)
from repro.core.scheduling import cyclic_to_matrix, staircase_to_matrix
from repro.sharding import trial_devices, trial_mesh, TRIAL_AXIS

multidev = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")

N = 8
C_CYC = cyclic_to_matrix(N, 3)
C_SS = staircase_to_matrix(N, 3)


def _specs():
    return [to_spec("cyc", C_CYC), to_spec("ss", C_SS), lb_spec(3, "lb"),
            adaptive_spec("adapt", C_CYC)]


def _markov():
    return MarkovRegimeProcess(base=scenario1(), p_slow=0.2, persistence=0.9)


def tree_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# device-free: shard layout + argument validation
# ---------------------------------------------------------------------------

class TestShardLayout:
    def test_chunk_decomposition_is_device_invariant(self):
        devs = jax.devices()
        _, nc_pad, padded = mc._shard_layout(100, 10, devs[:1])
        assert (nc_pad, padded) == (10, 100)

    def test_padding_rounds_up_to_devices(self):
        # synthetic 4-"device" tuple: layout math never touches the devices
        devs = tuple(jax.devices()) * 4
        used, nc_pad, padded = mc._shard_layout(403, 50, devs[:4])
        assert len(used) == 4
        assert nc_pad == 12 and padded == 600   # ceil(9/4)*4 chunks
        used, nc_pad, padded = mc._shard_layout(96, 7, devs[:4])
        assert nc_pad == 16 and padded == 112   # ceil(14/4)*4

    def test_fewer_chunks_than_devices(self):
        devs = tuple(jax.devices()) * 4
        used, nc_pad, padded = mc._shard_layout(10, 10, devs[:4])
        assert len(used) == 1 and nc_pad == 1 and padded == 10

    def test_trial_devices_forms(self):
        all_devs = tuple(jax.devices())
        assert trial_devices(None) == all_devs
        assert trial_devices(1) == all_devs[:1]
        assert trial_devices(list(all_devs)) == all_devs
        with pytest.raises(ValueError, match="devices"):
            trial_devices(0)
        with pytest.raises(ValueError, match="devices"):
            trial_devices(len(all_devs) + 1)
        with pytest.raises(ValueError, match="devices"):
            trial_devices([])

    def test_trial_mesh_axis(self):
        mesh = trial_mesh(jax.devices()[:1])
        assert mesh.axis_names == (TRIAL_AXIS,)


class TestChunkValidation:
    """The canonical ``_normalize_chunk`` raises a ValueError naming the
    argument instead of silently clamping (satellite fix)."""

    def test_chunk_exceeds_trials_named(self):
        with pytest.raises(ValueError, match=r"chunk \(50\) exceeds trials"):
            sweep(_specs()[:2], scenario1(), N, trials=20, chunk=50)

    def test_rounds_chunk_exceeds_trials_named(self):
        with pytest.raises(ValueError, match=r"chunk \(9\) exceeds trials"):
            sweep_rounds(_specs()[:1], _markov(), N, rounds=2, k=6,
                         trials=8, chunk=9)

    def test_chunk_below_one(self):
        with pytest.raises(ValueError, match="chunk"):
            sweep(_specs()[:2], scenario1(), N, trials=20, chunk=0)

    def test_chunk_none_is_one_chunk(self):
        assert mc._normalize_chunk(17, None) == 17
        assert mc._normalize_chunk(17, 5) == 5


# ---------------------------------------------------------------------------
# forced multi-device mesh: bit-exactness vs single device
# ---------------------------------------------------------------------------

@multidev
class TestShardedBitExact:
    @pytest.mark.parametrize("trials,chunk", [(200, 25), (403, 50), (96, 7)])
    def test_sweep_stats(self, trials, chunk):
        r1 = sweep(_specs()[:3], scenario1(), N, trials=trials, seed=3,
                   chunk=chunk, devices=1)
        r4 = sweep(_specs()[:3], scenario1(), N, trials=trials, seed=3,
                   chunk=chunk, devices=4)
        tree_equal(r1.means, r4.means)
        tree_equal(r1.stderr, r4.stderr)

    def test_sweep_per_trial_samples(self):
        s1 = completion_samples(_specs()[0], scenario1(), N, trials=96,
                                seed=3, chunk=7, k=6, devices=1)
        s4 = completion_samples(_specs()[0], scenario1(), N, trials=96,
                                seed=3, chunk=7, k=6, devices=4)
        tree_equal(s1, s4)

    def test_sweep_tau_and_message_budget(self):
        from repro.core.montecarlo import tau_spec
        specs = [to_spec("cs_m2", C_CYC, messages=2),
                 tau_spec("tau", C_SS),
                 to_spec("ragged", cyclic_to_matrix(N, loads=[3, 1, 2, 3,
                                                              1, 3, 2, 1]))]
        r1 = sweep(specs, scenario1(), N, trials=150, seed=2, chunk=25,
                   devices=1)
        r4 = sweep(specs, scenario1(), N, trials=150, seed=2, chunk=25,
                   devices=4)
        tree_equal(r1.means, r4.means)
        tree_equal(r1.stderr, r4.stderr)

    def test_rounds_rebalance_and_faults(self):
        from repro.core.cluster import make_scenario
        specs = [to_spec("cs", C_CYC), lb_spec(3, "lb"),
                 adaptive_spec("rebal", cyclic_to_matrix(N, 6),
                               rebalance=True, loads=[3] * N)]
        proc = make_scenario("preemption", _markov(), N)
        kw = dict(rounds=3, k=6, trials=120, seed=11, chunk=20,
                  deadline=0.004, deadline_policy="close_partial")
        r1 = sweep_rounds(specs, proc, N, devices=1, **kw)
        r4 = sweep_rounds(specs, proc, N, devices=4, **kw)
        tree_equal(r1.per_round, r4.per_round)
        tree_equal(r1.wallclock, r4.wallclock)
        tree_equal(r1.degradation, r4.degradation)

    @pytest.mark.parametrize("kw", [
        dict(),
        dict(censored_feedback=True),
        dict(deadline=0.004, deadline_policy="close_partial"),
        dict(deadline=0.004, censored_feedback=True,
             deadline_policy="reissue"),
    ], ids=["plain", "censored", "close_partial", "censored_reissue"])
    @pytest.mark.parametrize("trials", [120, 121])
    def test_sweep_rounds(self, kw, trials):
        args = (_specs(), _markov(), N)
        kw2 = dict(rounds=3, k=6, trials=trials, seed=7, chunk=20, **kw)
        r1 = sweep_rounds(*args, devices=1, **kw2)
        r4 = sweep_rounds(*args, devices=4, **kw2)
        tree_equal(r1.per_round, r4.per_round)
        tree_equal(r1.stderr, r4.stderr)
        tree_equal(r1.wallclock, r4.wallclock)
        tree_equal(r1.wallclock_stderr, r4.wallclock_stderr)
        if r1.degradation or r4.degradation:
            tree_equal(r1.degradation, r4.degradation)

    def test_trajectory_samples(self):
        kw = dict(rounds=3, k=6, trials=61, seed=5, chunk=10, deadline=0.004)
        t1 = trajectory_samples(_specs()[3], _markov(), N, devices=1, **kw)
        t4 = trajectory_samples(_specs()[3], _markov(), N, devices=4, **kw)
        tree_equal(t1, t4)

    def test_greedy_impls_agree_sharded(self):
        kw = dict(rounds=3, k=6, trials=80, seed=9, chunk=20, devices=4)
        rs = sweep_rounds(_specs(), _markov(), N, greedy_impl="scan", **kw)
        rk = sweep_rounds(_specs(), _markov(), N, greedy_impl="kernel", **kw)
        tree_equal(rs.per_round, rk.per_round)
        tree_equal(rs.wallclock, rk.wallclock)

    def test_devices_sequence_matches_int(self):
        devs = jax.devices()[:4]
        kw = dict(trials=100, seed=1, chunk=25)
        ra = sweep(_specs()[:2], scenario1(), N, devices=4, **kw)
        rb = sweep(_specs()[:2], scenario1(), N, devices=devs, **kw)
        tree_equal(ra.means, rb.means)


# ---------------------------------------------------------------------------
# evaluator-cache hygiene (satellite: no retrace, clear_cache drops all)
# ---------------------------------------------------------------------------

@multidev
class TestShardedCache:
    def test_repeated_sweeps_do_not_rebuild(self, monkeypatch):
        clear_cache()
        calls = []
        orig = mc.shard_trials
        monkeypatch.setattr(
            mc, "shard_trials",
            lambda fn, devs, **kw: calls.append(1) or orig(fn, devs, **kw))
        kw = dict(trials=100, seed=1, chunk=25, devices=4)
        sweep(_specs()[:2], scenario1(), N, **kw)
        n_first = len(calls)
        assert n_first > 0
        for _ in range(3):
            sweep(_specs()[:2], scenario1(), N, **kw)
        assert len(calls) == n_first    # cache hit: no new sharded wrap

    def test_cache_keyed_by_device_tuple(self):
        clear_cache()
        kw = dict(trials=100, seed=1, chunk=25)
        sweep(_specs()[:2], scenario1(), N, devices=1, **kw)
        n1 = len(mc._EXEC_CACHE)
        sweep(_specs()[:2], scenario1(), N, devices=4, **kw)
        assert len(mc._EXEC_CACHE) == n1 + 1   # distinct mesh, distinct entry

    def test_clear_cache_drops_sharded_entries(self):
        kw = dict(trials=100, seed=1, chunk=25, devices=4)
        sweep(_specs()[:2], scenario1(), N, **kw)
        sweep_rounds(_specs()[:1], _markov(), N, rounds=2, k=6, **kw)
        assert mc._EXEC_CACHE and mc._ROUNDS_CACHE
        clear_cache()
        assert not mc._EXEC_CACHE and not mc._ROUNDS_CACHE

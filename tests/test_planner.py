"""Racing planner (repro.core.planner) and its substrate: resumable
sweep extension bit-exactness, CRN paired-difference variance reduction,
rebalance x messages engine support, ``GridResult.best_cell``, planner
agreement with the exhaustive grid, and the ``repro.launch.plan`` CLI.

The multi-device legs need >= 4 devices; CI forces them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import json

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GridSpec, MarkovRegimeProcess, PlanResult,
                        RoundConfig, StragglerAggregator, adaptive_spec,
                        cyclic_to_matrix, delay_model_pdfs, lb_spec,
                        operating_point_mean_lb, plan, resumable_sweep,
                        scenario1, stream_grid, sweep, sweep_rounds, to_spec,
                        trajectory_samples, truncated_gaussian_pdf)
from repro.core import montecarlo as mc
from repro.core import planner as planner_mod
from repro.launch import grid as grid_cli
from repro.launch import plan as plan_cli

multidev = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")

MODEL = scenario1()
N = 8


def _specs(ragged: bool):
    C = cyclic_to_matrix(N, 4)
    if ragged:
        loads = np.array([4, 3, 2, 1, 4, 3, 2, 1])
        return [to_spec("a", C, loads=loads), lb_spec(4, name="b")]
    return [to_spec("a", C), lb_spec(4, name="b")]


def _assert_same_result(res_a, res_b):
    for nm in res_a.means:
        np.testing.assert_array_equal(res_a.means[nm], res_b.means[nm])
        np.testing.assert_array_equal(res_a.stderr[nm], res_b.stderr[nm])
    assert res_a.trials == res_b.trials


# ---------------------------------------------------------------------------
# resumable extension: bit-exact vs a fresh sweep at the combined count
# ---------------------------------------------------------------------------

class TestResumableSweep:
    @pytest.mark.parametrize("ragged", [False, True])
    @pytest.mark.parametrize("ks", [None, 5])
    def test_extension_matches_fresh_sweep_bitwise(self, ragged, ks):
        rs = resumable_sweep(_specs(ragged), MODEL, N, seed=3, chunk=64,
                             ks=ks, keep_samples=True)
        for total in (128, 256, 1024):
            rs.extend_trials(total)
            fresh = sweep(_specs(ragged), MODEL, N, trials=total, seed=3,
                          chunk=64, ks=ks)
            _assert_same_result(rs.result(), fresh)

    def test_samples_match_completion_samples(self):
        rs = resumable_sweep(_specs(False), MODEL, N, seed=0, chunk=32,
                             ks=5, keep_samples=True)
        rs.extend_trials(96)
        got = rs.samples()
        for sp in _specs(False):
            ref = mc.completion_samples(sp, MODEL, N, trials=96, seed=0,
                                        chunk=32, k=5)
            np.testing.assert_array_equal(
                np.asarray(got[sp.name]).ravel(), np.asarray(ref).ravel())

    def test_non_aligned_extension_is_terminal(self):
        rs = resumable_sweep(_specs(False), MODEL, N, seed=0, chunk=64)
        rs.extend_trials(100)          # not a multiple of 64: terminal
        fresh = sweep(_specs(False), MODEL, N, trials=100, seed=0, chunk=64)
        _assert_same_result(rs.result(), fresh)
        with pytest.raises(ValueError, match="chunk"):
            rs.extend_trials(200)

    def test_extend_must_grow(self):
        rs = resumable_sweep(_specs(False), MODEL, N, seed=0, chunk=64)
        rs.extend_trials(64)
        with pytest.raises(ValueError):
            rs.extend_trials(64)

    def test_narrow_keeps_survivor_bitwise(self):
        rs = resumable_sweep(_specs(False), MODEL, N, seed=7, chunk=64,
                             keep_samples=True)
        rs.extend_trials(128)
        rs.narrow(["a"])
        rs.extend_trials(512)
        # the survivor must equal a fresh *two-spec* run (the original
        # r_max shape is what keeps CRN pairing intact after narrowing)
        fresh = sweep(_specs(False), MODEL, N, trials=512, seed=7, chunk=64)
        got = rs.result()
        np.testing.assert_array_equal(got.means["a"], fresh.means["a"])
        np.testing.assert_array_equal(got.stderr["a"], fresh.stderr["a"])
        assert "b" not in got.means
        with pytest.raises(ValueError):
            rs.narrow(["nope"])

    @multidev
    @pytest.mark.parametrize("ragged", [False, True])
    def test_extension_bitwise_across_device_counts(self, ragged):
        res = {}
        for d in (1, 4):
            rs = resumable_sweep(_specs(ragged), MODEL, N, seed=5, chunk=64,
                                 devices=jax.devices()[:d])
            rs.extend_trials(256)
            rs.extend_trials(1024)
            res[d] = rs.result()
        _assert_same_result(res[1], res[4])
        fresh = sweep(_specs(ragged), MODEL, N, trials=1024, seed=5,
                      chunk=64)
        _assert_same_result(res[4], fresh)


# ---------------------------------------------------------------------------
# CRN pairing: the paired-difference stderr the planner eliminates on is
# never worse than the independent-comparison stderr
# ---------------------------------------------------------------------------

class TestPairedVariance:
    @settings(max_examples=8, deadline=None)
    @given(st.sampled_from([False, True]), st.sampled_from([192, 448]),
           st.integers(min_value=0, max_value=2 ** 16))
    def test_paired_stderr_at_most_independent(self, ragged, trials, seed):
        specs = _specs(ragged)
        rs = resumable_sweep(specs, MODEL, N, seed=seed, chunk=64, ks=5,
                             keep_samples=True)
        rs.extend_trials(trials)
        s = rs.samples()
        xa = np.asarray(s["a"], np.float64).ravel()
        xb = np.asarray(s["b"], np.float64).ravel()
        paired = (xa - xb).std(ddof=1)
        indep = np.hypot(xa.std(ddof=1), xb.std(ddof=1))
        # CRN makes the schemes positively correlated (they share every
        # delay draw), so pairing can only shrink the comparison stderr
        # (up to f64 round-off on the variance estimate).
        assert paired <= indep * (1 + 1e-12)


# ---------------------------------------------------------------------------
# rebalance x messages (the gap the planner's grid closes)
# ---------------------------------------------------------------------------

class TestRebalanceMessages:
    def _run(self, m, chunk=64, trials=192):
        C = cyclic_to_matrix(N, 4)
        loads = np.full(N, 2)
        proc = MarkovRegimeProcess(base=MODEL, persistence=0.8)
        sp = adaptive_spec("rb", C, messages=m, loads=loads, rebalance=True)
        return sweep_rounds([sp], proc, N, rounds=3, k=N, trials=trials,
                            seed=1, chunk=chunk)

    def test_budget_at_cap_equals_unlimited_bitwise(self):
        full = self._run(None)
        cap = self._run(4)
        np.testing.assert_array_equal(full.per_round["rb"],
                                      cap.per_round["rb"])

    @pytest.mark.parametrize("m", [1, 2])
    def test_per_trial_trajectories_chunk_invariant(self, m):
        C = cyclic_to_matrix(N, 4)
        loads = np.full(N, 2)
        proc = MarkovRegimeProcess(base=MODEL, persistence=0.8)
        sp = adaptive_spec("rb", C, messages=m, loads=loads, rebalance=True)
        a = trajectory_samples(sp, proc, N, rounds=3, k=N, trials=192,
                               seed=1, chunk=64)
        b = trajectory_samples(sp, proc, N, rounds=3, k=N, trials=192,
                               seed=1, chunk=96)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tighter_budget_is_slower_on_average(self):
        m1 = float(np.mean(self._run(1).per_round["rb"]))
        m2 = float(np.mean(self._run(2).per_round["rb"]))
        mc_ = float(np.mean(self._run(4).per_round["rb"]))
        assert np.isfinite([m1, m2, mc_]).all()
        assert m1 >= m2 >= mc_

    def test_aggregator_accepts_rebalance_with_messages(self):
        # RoundConfig no longer rejects the combination, and the
        # aggregator runs it with the dynamic per-load message remap
        def _agg(m):
            cfg = RoundConfig(n=N, k=N, kind="cs", r=4, loads=(2,) * N,
                              messages=m, adaptive=True, rebalance=True)
            return StragglerAggregator(cfg.to_round_spec(), MODEL,
                                       adaptive=True, rebalance=True)
        agg = _agg(2)
        ts = [float(agg.round_mask(jax.random.PRNGKey(i))[1])
              for i in range(3)]
        assert np.isfinite(ts).all()
        assert np.isfinite(agg.expected_completion(trials=512))
        # a tighter budget can only slow the round down on average
        assert (_agg(1).expected_completion(trials=512)
                >= _agg(2).expected_completion(trials=512))


# ---------------------------------------------------------------------------
# GridResult.best_cell
# ---------------------------------------------------------------------------

class TestBestCell:
    @pytest.fixture(scope="class")
    def grid(self):
        gs = GridSpec(n=N, families=("cs", "lb", "pc"), loads=(2, 4),
                      trials=256, seed=0)
        return stream_grid(gs.cells(MODEL))

    def test_excludes_lb_and_matches_manual_argmin(self, grid):
        best = grid.best_cell(k=N)
        assert not best["cell"].startswith("lb")
        manual = {}
        for nm, c in grid.cells.items():
            if nm.startswith("lb"):
                continue
            v = np.atleast_1d(list(c["means"].values())[0])
            manual[nm] = float(v[0] if v.shape[-1] == 1 else v[N - 1])
        assert best["cell"] == min(manual, key=manual.get)
        assert best["mean"] == pytest.approx(min(manual.values()))

    def test_tie_report_is_stderr_aware(self, grid):
        # at z=inf every other cell is a tie; at z=0 only exact equals
        loose = grid.best_cell(k=N, z=np.inf)
        tight = grid.best_cell(k=N, z=0.0)
        assert len(loose["ties"]) >= len(tight["ties"])
        assert len(loose["ties"]) == len([nm for nm in grid.cells
                                          if not nm.startswith("lb")]) - 1

    def test_k_validation(self, grid):
        with pytest.raises(ValueError, match="1 <= k"):
            grid.best_cell(k=N + 1)


# ---------------------------------------------------------------------------
# plan(): agreement with the exhaustive grid, invariances, artifact
# ---------------------------------------------------------------------------

GS = GridSpec(n=N, families=("cs", "ss", "lb", "pc"), loads=(2, 4, 8),
              messages=(None, 2), trials=2048, seed=0)


class TestPlan:
    @pytest.fixture(scope="class")
    def result(self):
        return plan(GS, MODEL, k=N, base_trials=256, eta=4)

    def test_matches_exhaustive_argmin_with_fewer_trials(self, result):
        exhaustive = stream_grid(GS.cells(MODEL)).best_cell(k=N)
        assert result.winner == exhaustive["cell"]
        assert result.predicted_mean == pytest.approx(exhaustive["mean"],
                                                      rel=1e-6)
        # this unit grid is tiny (21 cells, n=8, many near-ties), so the
        # bar here is modest; the >= 5x acceptance gate runs against the
        # 64-cell quick grid in benchmarks/planner.py
        assert result.trials_spent * 2 <= result.exhaustive_trials
        assert result.savings >= 2.0

    def test_matched_confidence_at_final_rung(self, result):
        # the winner raced to the full grid budget: same stderr resolution
        # as the exhaustive sweep
        assert result.points[result.winner]["trials"] == GS.trials
        assert result.trajectory[-1]["trials"] == GS.trials

    def test_lb_gap_and_config(self, result):
        assert result.lb_mean is not None
        assert result.lb_gap >= 0.0
        assert result.config is not None
        assert result.config.kind in ("cs", "ss", "ra")
        assert result.config.k == N
        assert result.config_note is None

    def test_point_statuses_cover_every_cell(self, result):
        assert len(result.points) == len(GS.cells(MODEL))
        statuses = {r["status"] for r in result.points.values()}
        assert statuses <= {"won", "survived", "eliminated", "pruned",
                            "excluded"}
        assert sum(1 for r in result.points.values()
                   if r["status"] == "won") == 1
        assert all(r["status"] == "excluded"
                   for nm, r in result.points.items()
                   if nm.startswith("lb"))

    def test_eliminated_points_spent_fewer_trials(self, result):
        for r in result.points.values():
            if r["status"] == "eliminated":
                assert r["trials"] < GS.trials
                assert r["gap"] > 0.0

    def test_elimination_decisions_chunk_invariant(self, result):
        # per-trial samples are bitwise chunk-invariant (CRN fold_in key
        # per trial), so every paired gap — and hence every elimination
        # decision — must be identical under a different chunking
        import dataclasses
        gs2 = dataclasses.replace(GS, chunk=128)
        r2 = plan(gs2, MODEL, k=N, base_trials=256, eta=4)
        assert r2.winner == result.winner
        assert r2.trajectory == result.trajectory
        assert r2.trials_spent == result.trials_spent

    @multidev
    def test_elimination_decisions_device_invariant(self, result):
        r4 = plan(GS, MODEL, k=N, base_trials=256, eta=4,
                  devices=jax.devices()[:4])
        assert r4.winner == result.winner
        assert ([t["survivors"] for t in r4.trajectory]
                == [t["survivors"] for t in result.trajectory])
        assert ([t["eliminated"] for t in r4.trajectory]
                == [t["eliminated"] for t in result.trajectory])

    def test_artifact_round_trip(self, result, tmp_path):
        p = result.save(str(tmp_path / "plan.json"))
        back = PlanResult.load(p)
        assert back.winner == result.winner
        assert back.config == result.config
        assert back.trials_spent == result.trials_spent
        assert back.points[back.winner]["mean"] == \
            pytest.approx(result.points[result.winner]["mean"])

    def test_version_gate(self, tmp_path, result):
        p = tmp_path / "future.json"
        doc = result.to_json()
        doc["version"] = planner_mod.PLAN_FORMAT_VERSION + 1
        p.write_text(json.dumps(doc))
        with pytest.raises(ValueError, match="newer"):
            PlanResult.load(str(p))

    def test_theory_prune_skipped_without_closed_form(self):
        # a process model has no closed-form marginals: every point races
        proc_grid = GridSpec(n=N, families=("cs", "lb"), loads=(2, 4),
                             trials=512, seed=0)
        assert delay_model_pdfs(MarkovRegimeProcess(base=MODEL)) is None
        res = plan(proc_grid, MODEL, k=N, base_trials=256, eta=4,
                   theory_prune=False)
        assert res.meta["theory_pruned"] == 0

    def test_base_trials_must_align_with_chunk(self):
        import dataclasses
        with pytest.raises(ValueError, match="multiple"):
            plan(dataclasses.replace(GS, chunk=96), MODEL, k=N,
                 base_trials=256)


class TestTheoryGuides:
    def test_truncated_gaussian_pdf_normalizes(self):
        pdf = truncated_gaussian_pdf(1e-4, 1e-4, 3e-5)
        t = np.linspace(1e-4 - 3e-5, 1e-4 + 3e-5, 20001)
        trapezoid = getattr(np, "trapezoid", np.trapz)
        assert trapezoid(pdf(t), t) == pytest.approx(1.0, abs=1e-6)

    def test_delay_model_pdfs_scenario1(self):
        pdfs = delay_model_pdfs(MODEL)
        assert pdfs is not None
        pdf1, pdf2, sup1, sup2 = pdfs
        assert sup1 > 0 and sup2 > 0

    def test_lb_guide_below_mc_lower_bound(self):
        pdf1, pdf2, sup1, sup2 = delay_model_pdfs(MODEL)
        guide = operating_point_mean_lb(N, 4, N, pdf1, pdf2,
                                        tmax=1.25 * (4 * sup1 + sup2))
        res = sweep([lb_spec(4)], MODEL, N, trials=4096, seed=0, chunk=512,
                    ks=N)
        # the guide assumes FIFO in-order delivery: a relaxation of the
        # true bound, so it must not exceed the MC estimate by more than
        # sampling noise
        assert guide <= res.at_k("lb", N) * 1.05


# ---------------------------------------------------------------------------
# CLIs
# ---------------------------------------------------------------------------

class TestCLI:
    def test_plan_cli_writes_artifact_and_config(self, tmp_path, capsys):
        out = tmp_path / "plan.json"
        cfg = tmp_path / "cfg.json"
        rc = plan_cli.main([
            "--n", str(N), "--families", "cs", "ss", "lb", "pc",
            "--loads", "2", "4", "8", "--trials", "1024",
            "--base-trials", "256", "--k", str(N),
            "--out", str(out), "--emit-config", str(cfg)])
        assert rc == 0
        text = capsys.readouterr().out
        assert "winner:" in text and "saved" in text
        res = PlanResult.load(str(out))
        assert res.savings > 1.0
        if res.config is not None:
            loaded = RoundConfig.load(cfg)
            assert loaded == res.config

    def test_grid_cli_window_flag_and_meta(self, tmp_path, capsys):
        out = tmp_path / "grid.json"
        rc = grid_cli.main([
            "--n", str(N), "--families", "cs", "lb", "--loads", "2",
            "--trials", "256", "--window", "3", "--out", str(out)])
        assert rc == 0
        text = capsys.readouterr().out
        assert "best:" in text
        from repro.core import GridResult
        res = GridResult.load(str(out))
        assert res.meta["window"] == 3
        assert res.meta["pipeline"] == 3
        assert "cache" in res.meta

    def test_grid_cli_pipeline_alias(self, tmp_path):
        out = tmp_path / "grid.json"
        rc = grid_cli.main([
            "--n", str(N), "--families", "cs", "--loads", "2",
            "--trials", "256", "--pipeline", "4", "--out", str(out)])
        assert rc == 0
        from repro.core import GridResult
        assert GridResult.load(str(out)).meta["window"] == 4

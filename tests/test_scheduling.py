"""Unit + property tests for TO-matrix constructions (paper Sec. II, IV)
and the adaptive row-assignment layer."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AdaptiveScheduler, cyclic_to_matrix,
                        greedy_row_assignment, greedy_row_assignment_batch,
                        staircase_to_matrix, random_assignment_to_matrix,
                        to_matrix, validate_to_matrix)


def test_paper_example2_cs():
    # Paper eq. (27), 1-indexed -> 0-indexed
    C = cyclic_to_matrix(4, 3)
    assert (C == np.array([[0, 1, 2], [1, 2, 3], [2, 3, 0], [3, 0, 1]])).all()


def test_paper_example3_ss():
    # Paper eq. (34)
    C = staircase_to_matrix(4, 3)
    assert (C == np.array([[0, 1, 2], [1, 0, 3], [2, 3, 0], [3, 2, 1]])).all()


def test_cs_equals_ss_for_r1():
    for n in (1, 2, 5, 8):
        assert (cyclic_to_matrix(n, 1) == staircase_to_matrix(n, 1)).all()


@pytest.mark.parametrize("name", ["cs", "ss"])
def test_invalid_r_raises(name):
    with pytest.raises(ValueError):
        to_matrix(name, 4, 5)
    with pytest.raises(ValueError):
        to_matrix(name, 4, 0)


def test_ra_requires_full_load():
    with pytest.raises(ValueError):
        random_assignment_to_matrix(4, 2)
    C = random_assignment_to_matrix(5, seed=1)
    validate_to_matrix(C, 5)
    assert C.shape == (5, 5)
    for row in C:
        assert sorted(row.tolist()) == list(range(5))


def test_validate_rejects_bad_matrices():
    with pytest.raises(ValueError):
        validate_to_matrix(np.array([[0, 0], [1, 1]]), 2)  # repeated in row
    with pytest.raises(ValueError):
        validate_to_matrix(np.array([[0, 3], [1, 0]]), 2)  # out of range
    with pytest.raises(ValueError):
        validate_to_matrix(np.zeros((2,)), 2)              # not 2-D


@settings(deadline=None, max_examples=60)
@given(st.integers(1, 24), st.data())
def test_property_cs_ss_valid_and_cover(n, data):
    """CS: each task appears in exactly r rows (cyclic symmetry). SS: same
    for even n; for odd n the alternating directions break exact balance,
    but slot-0 diagonal C(i,0)=i still guarantees full coverage."""
    r = data.draw(st.integers(1, n))
    C = cyclic_to_matrix(n, r)
    validate_to_matrix(C, n)
    assert (np.bincount(C.reshape(-1), minlength=n) == r).all()
    S = staircase_to_matrix(n, r)
    validate_to_matrix(S, n)
    counts = np.bincount(S.reshape(-1), minlength=n)
    assert counts.sum() == n * r and (counts >= 1).all()
    if n % 2 == 0:
        assert (counts == r).all()
    assert (S[:, 0] == np.arange(n)).all()  # diagonal start



@settings(deadline=None, max_examples=40)
@given(st.integers(2, 16), st.data())
def test_property_cs_task_position_constant(n, data):
    """CS's defining property: task p sits at slot j of worker g(p - j);
    i.e. each task occupies every slot position 0..r-1 exactly once."""
    r = data.draw(st.integers(1, n))
    C = cyclic_to_matrix(n, r)
    for p in range(n):
        slots = sorted(int(j) for i in range(n) for j in range(r)
                       if C[i, j] == p)
        assert slots == list(range(r))


@settings(deadline=None, max_examples=40)
@given(st.integers(2, 16), st.data())
def test_property_ss_alternating_direction(n, data):
    """SS: even rows ascend (mod n), odd rows descend."""
    r = data.draw(st.integers(2, n))
    C = staircase_to_matrix(n, r)
    for i in range(n):
        d = np.mod(np.diff(C[i].astype(int)), n)
        expect = 1 if i % 2 == 0 else n - 1
        assert (d == expect).all()


# ---------------------- adaptive row assignment ------------------------------

class TestGreedyRowAssignment:
    def test_is_permutation_any_feedback(self):
        rng = np.random.default_rng(0)
        for n, r in ((4, 1), (6, 3), (9, 4), (8, 8)):
            C = cyclic_to_matrix(n, r)
            for est in (None, rng.random(n) + 0.05):
                w = greedy_row_assignment(C, est)
                assert sorted(w.tolist()) == list(range(n))

    def test_uniform_feedback_spaces_coverage(self):
        """With no feedback the first floor(n/r) pickers take rows with
        disjoint task sets (coverage spacing of a cyclic matrix)."""
        n, r = 8, 2
        C = cyclic_to_matrix(n, r)
        w_of_row = greedy_row_assignment(C)
        row_of_worker = np.empty(n, int)
        row_of_worker[w_of_row] = np.arange(n)
        first = [set(C[row_of_worker[w]].tolist()) for w in range(n // r)]
        seen: set = set()
        for tasks in first:
            assert not (tasks & seen)
            seen |= tasks

    def test_fast_workers_pick_first(self):
        """The slowest worker is assigned last, i.e. gets the row the
        greedy ranks worst at its turn: remove it and the other
        assignments are unchanged."""
        n, r = 6, 2
        C = cyclic_to_matrix(n, r)
        est = np.array([1.0, 9.0, 1.1, 1.2, 1.3, 1.4])
        w = greedy_row_assignment(C, est)
        slow_row = int(np.where(w == 1)[0][0])
        # rows are picked in fastest-first order; the slow worker's row is
        # the one left over after every faster worker chose.
        order = np.argsort(est)
        assert order[-1] == 1
        taken = [int(np.where(w == o)[0][0]) for o in order[:-1]]
        assert slow_row not in taken and len(set(taken)) == n - 1

    def test_numpy_and_jax_batch_agree(self):
        rng = np.random.default_rng(1)
        for n, r in ((5, 2), (8, 3), (7, 7)):
            C = cyclic_to_matrix(n, r)
            est = rng.random((4, n)) + 0.05
            got = np.asarray(greedy_row_assignment_batch(C, jnp.asarray(est)))
            for b in range(4):
                ref = greedy_row_assignment(C, est[b])
                assert (got[b] == ref).all(), (n, r, b)

    def test_feedback_shape_validated(self):
        C = cyclic_to_matrix(4, 2)
        with pytest.raises(ValueError):
            greedy_row_assignment(C, np.ones(5))


class TestAdaptiveScheduler:
    def test_matrix_always_valid_and_ema_updates(self):
        C = cyclic_to_matrix(6, 3)
        s = AdaptiveScheduler(C)
        M0 = s.matrix()
        validate_to_matrix(M0, 6)
        s.observe(np.array([1, 1, 1, 9, 1, 1.0]))
        est1 = s.est.copy()
        M1 = s.matrix()
        validate_to_matrix(M1, 6)
        # rows are a permutation of the base rows
        assert sorted(map(tuple, M1.tolist())) == sorted(map(tuple,
                                                             C.tolist()))
        s.observe(np.ones((6, 3)))          # (n, r) feedback also accepted
        assert not np.allclose(s.est, est1)
        with pytest.raises(ValueError):
            s.observe(np.ones(5))

    def test_persistent_straggler_moves_to_leftover_row(self):
        """After consistent feedback, the slow worker ends up assigned the
        final leftover row (it picks last) and fast workers cover
        disjoint leading tasks."""
        n, r = 8, 2
        s = AdaptiveScheduler(cyclic_to_matrix(n, r))
        for _ in range(5):
            s.observe(np.array([1, 1, 1, 1, 20, 1, 1, 1.0]))
        w_of_row = s.worker_of_row()
        # worker 4 picked last -> its row is whatever remained
        assert sorted(w_of_row.tolist()) == list(range(n))
        M = s.matrix()
        validate_to_matrix(M, n)


class TestCensoredFeedback:
    def _fixture(self, n=6, r=2):
        t1 = np.full((n, r), 2.0)
        t1[3] = 9.0                       # worker 3 is slow
        # worker i's messages arrive at 10*i and 10*i + 5
        arrivals = 10.0 * np.arange(n)[:, None] + np.array([0.0, 5.0])
        return t1, arrivals

    def test_only_delivered_workers_update(self):
        n, r = 6, 2
        t1, arrivals = self._fixture(n, r)
        s = AdaptiveScheduler(cyclic_to_matrix(n, r))
        s.observe(t1, arrivals=arrivals, t_done=25.0)   # workers 0-2 fully in
        assert np.isfinite(s.est[:3]).all()
        assert np.isinf(s.est[3:]).all()                # silent => +inf
        np.testing.assert_allclose(s.est[:3], 2.0)
        # silent workers sort last in the greedy pick order
        w_of_row = s.worker_of_row()
        assert sorted(w_of_row.tolist()) == list(range(n))

    def test_observed_set_monotone_in_deadline(self):
        """Raising the deadline only ever adds observations: workers
        observed at the smaller t_done keep identical estimates, and the
        observed set grows."""
        n, r = 6, 2
        t1, arrivals = self._fixture(n, r)
        small = AdaptiveScheduler(cyclic_to_matrix(n, r))
        big = AdaptiveScheduler(cyclic_to_matrix(n, r))
        small.observe(t1, arrivals=arrivals, t_done=25.0)
        big.observe(t1, arrivals=arrivals, t_done=45.0)
        seen_small = np.isfinite(small.est)
        seen_big = np.isfinite(big.est)
        assert (seen_small <= seen_big).all()
        assert seen_big.sum() > seen_small.sum()
        np.testing.assert_allclose(big.est[seen_small],
                                   small.est[seen_small])

    def test_partial_delivery_uses_only_arrived_slots(self):
        n, r = 6, 2
        t1, arrivals = self._fixture(n, r)
        t1[0] = [2.0, 100.0]              # slot 1's compute was huge...
        s = AdaptiveScheduler(cyclic_to_matrix(n, r))
        s.observe(t1, arrivals=arrivals, t_done=2.0)    # ...and not observed
        np.testing.assert_allclose(s.est[0], 2.0)       # slot-0 mean only
        assert np.isinf(s.est[1:]).all()
        # EMA on subsequent censored rounds, replace-on-first for newcomers
        s.observe(np.full((n, r), 4.0), arrivals=arrivals, t_done=2.0)
        np.testing.assert_allclose(s.est[0], 0.7 * 2.0 + 0.3 * 4.0)

    def test_uncensored_observe_revives_silent_workers(self):
        """A worker left at the +inf never-observed sentinel by censored
        rounds must be replaced (not EMA'd, which would pin it at +inf)
        once full feedback resumes."""
        n, r = 6, 2
        t1, arrivals = self._fixture(n, r)
        s = AdaptiveScheduler(cyclic_to_matrix(n, r))
        s.observe(t1, arrivals=arrivals, t_done=25.0)   # workers 3+ at +inf
        assert np.isinf(s.est[3:]).all()
        s.observe(np.full(n, 4.0))                      # idealized feedback
        assert np.isfinite(s.est).all()
        np.testing.assert_allclose(s.est[3:], 4.0)      # replaced, not EMA'd
        np.testing.assert_allclose(s.est[0], 0.7 * 2.0 + 0.3 * 4.0,
                                   rtol=1e-6)

    def test_censored_observe_validation(self):
        s = AdaptiveScheduler(cyclic_to_matrix(4, 2))
        with pytest.raises(ValueError, match="BOTH"):
            s.observe(np.ones((4, 2)), arrivals=np.ones((4, 2)))
        with pytest.raises(ValueError, match="per-slot"):
            s.observe(np.ones(4), arrivals=np.ones((4, 2)), t_done=1.0)
        with pytest.raises(ValueError, match="per-slot"):
            s.observe(np.ones((4, 2)), arrivals=np.ones((4, 3)), t_done=1.0)

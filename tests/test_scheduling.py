"""Unit + property tests for TO-matrix constructions (paper Sec. II, IV)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (cyclic_to_matrix, staircase_to_matrix,
                        random_assignment_to_matrix, to_matrix,
                        validate_to_matrix)


def test_paper_example2_cs():
    # Paper eq. (27), 1-indexed -> 0-indexed
    C = cyclic_to_matrix(4, 3)
    assert (C == np.array([[0, 1, 2], [1, 2, 3], [2, 3, 0], [3, 0, 1]])).all()


def test_paper_example3_ss():
    # Paper eq. (34)
    C = staircase_to_matrix(4, 3)
    assert (C == np.array([[0, 1, 2], [1, 0, 3], [2, 3, 0], [3, 2, 1]])).all()


def test_cs_equals_ss_for_r1():
    for n in (1, 2, 5, 8):
        assert (cyclic_to_matrix(n, 1) == staircase_to_matrix(n, 1)).all()


@pytest.mark.parametrize("name", ["cs", "ss"])
def test_invalid_r_raises(name):
    with pytest.raises(ValueError):
        to_matrix(name, 4, 5)
    with pytest.raises(ValueError):
        to_matrix(name, 4, 0)


def test_ra_requires_full_load():
    with pytest.raises(ValueError):
        random_assignment_to_matrix(4, 2)
    C = random_assignment_to_matrix(5, seed=1)
    validate_to_matrix(C, 5)
    assert C.shape == (5, 5)
    for row in C:
        assert sorted(row.tolist()) == list(range(5))


def test_validate_rejects_bad_matrices():
    with pytest.raises(ValueError):
        validate_to_matrix(np.array([[0, 0], [1, 1]]), 2)  # repeated in row
    with pytest.raises(ValueError):
        validate_to_matrix(np.array([[0, 3], [1, 0]]), 2)  # out of range
    with pytest.raises(ValueError):
        validate_to_matrix(np.zeros((2,)), 2)              # not 2-D


@settings(deadline=None, max_examples=60)
@given(st.integers(1, 24), st.data())
def test_property_cs_ss_valid_and_cover(n, data):
    """CS: each task appears in exactly r rows (cyclic symmetry). SS: same
    for even n; for odd n the alternating directions break exact balance,
    but slot-0 diagonal C(i,0)=i still guarantees full coverage."""
    r = data.draw(st.integers(1, n))
    C = cyclic_to_matrix(n, r)
    validate_to_matrix(C, n)
    assert (np.bincount(C.reshape(-1), minlength=n) == r).all()
    S = staircase_to_matrix(n, r)
    validate_to_matrix(S, n)
    counts = np.bincount(S.reshape(-1), minlength=n)
    assert counts.sum() == n * r and (counts >= 1).all()
    if n % 2 == 0:
        assert (counts == r).all()
    assert (S[:, 0] == np.arange(n)).all()  # diagonal start



@settings(deadline=None, max_examples=40)
@given(st.integers(2, 16), st.data())
def test_property_cs_task_position_constant(n, data):
    """CS's defining property: task p sits at slot j of worker g(p - j);
    i.e. each task occupies every slot position 0..r-1 exactly once."""
    r = data.draw(st.integers(1, n))
    C = cyclic_to_matrix(n, r)
    for p in range(n):
        slots = sorted(int(j) for i in range(n) for j in range(r)
                       if C[i, j] == p)
        assert slots == list(range(r))


@settings(deadline=None, max_examples=40)
@given(st.integers(2, 16), st.data())
def test_property_ss_alternating_direction(n, data):
    """SS: even rows ascend (mod n), odd rows descend."""
    r = data.draw(st.integers(2, n))
    C = staircase_to_matrix(n, r)
    for i in range(n):
        d = np.mod(np.diff(C[i].astype(int)), n)
        expect = 1 if i % 2 == 0 else n - 1
        assert (d == expect).all()
